"""Shared test configuration: hypothesis profiles.

* ``dev`` (default) — the tier-1 smoke depth: few examples so the full
  suite stays fast on a laptop and in the tier-1 CI job.
* ``ci`` — the deep adversarial run (`--hypothesis-profile=ci`): fixed
  derandomized seed, higher example count, no deadline.  The dedicated
  conformance CI job uses this so the dispatch conformance suite explores
  far more schedules than the smoke does.

Tests that want profile-controlled depth must NOT pin ``max_examples`` in
their own ``@settings`` (a local setting overrides the profile).
"""

try:
    from hypothesis import HealthCheck, settings
except ImportError:  # hypothesis is an optional dev dep; bare envs skip
    pass
else:
    _COMMON = dict(
        deadline=None,  # pallas interpret launches dwarf any deadline
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    settings.register_profile("dev", max_examples=10, **_COMMON)
    settings.register_profile("ci", max_examples=40, derandomize=True, **_COMMON)
    settings.load_profile("dev")

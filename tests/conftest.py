"""Shared test configuration: hypothesis profiles + fault-drill fixtures.

* ``dev`` (default) — the tier-1 smoke depth: few examples so the full
  suite stays fast on a laptop and in the tier-1 CI job.
* ``ci`` — the deep adversarial run (`--hypothesis-profile=ci`): fixed
  derandomized seed, higher example count, no deadline.  The dedicated
  conformance CI job uses this so the dispatch conformance suite explores
  far more schedules than the smoke does.

Tests that want profile-controlled depth must NOT pin ``max_examples`` in
their own ``@settings`` (a local setting overrides the profile).

The fault-drill helpers below are thin re-exports of :mod:`repro.chaos`
(PR 9): the head-rewind / stale-advisory mechanics that used to be
duplicated inline across test_pallas_ws.py, test_steal_policy.py,
test_dispatch_conformance.py and test_wstrace.py now live on
``FaultPlan``/``RewindSpec``, and the suites import them from here
(``from conftest import ...``) or take the fixtures.  ``RewindSpec.draw``
takes the same ``draw_int``/``draw_bool`` source the check functions use,
so hypothesis and the seeded slices drive identical storm shapes —
and conformance drills can apply ONE drawn spec to several layout-parity
states.
"""

try:
    from hypothesis import HealthCheck, settings
except ImportError:  # hypothesis is an optional dev dep; bare envs skip
    pass
else:
    _COMMON = dict(
        deadline=None,  # pallas interpret launches dwarf any deadline
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    settings.register_profile("dev", max_examples=10, **_COMMON)
    settings.register_profile("ci", max_examples=40, derandomize=True, **_COMMON)
    settings.load_profile("dev")

import pytest  # noqa: E402

try:
    from repro.chaos import (  # noqa: F401  (re-exported for the suites)
        FaultPlan,
        RewindSpec,
        apply_rewind,
        resume_state,
        seed_advisory,
    )

    HAVE_CHAOS = True
except ImportError:  # bare env without src on the path
    HAVE_CHAOS = False


def full_rewind(state, res):
    """The classic maximal §7 drill: resume from a finished launch, then
    drag every head to 0 and wipe every local bound — every already-claimed
    slot becomes claimable exactly once more (mult == 2)."""
    resume_state(state, res)
    return apply_rewind(state, RewindSpec.full(state))


def drawn_rewind(state, res, draw_int, draw_bool, *, heads=None,
                 advisory_modes=("exact",)):
    """Resume from ``res`` and apply a drawn storm; returns the spec so a
    second (layout-parity) state can replay the identical rewind with
    ``apply_rewind``."""
    resume_state(state, res)
    spec = RewindSpec.draw(state, draw_int, draw_bool, heads=heads,
                           advisory_modes=advisory_modes)
    apply_rewind(state, spec)
    return spec


@pytest.fixture
def fault_plan_factory():
    """Seed -> FaultPlan (the hypothesis-friendly whole-plan constructor)."""
    if not HAVE_CHAOS:
        pytest.skip("repro.chaos unavailable")
    return FaultPlan.from_seed


@pytest.fixture
def rewind_storm():
    """The full-rewind drill as a fixture: ``rewind_storm(state, res)``."""
    if not HAVE_CHAOS:
        pytest.skip("repro.chaos unavailable")
    return full_rewind

"""Serving-layer work-stealing tests that need no model weights.

Covers the WorkStealingFrontend's weak-multiplicity tolerance — two replicas
admitting the same request after a paper-§7-style stale-Head interleaving,
deduplicated on completion — and the ragged ws attention hook for continuous
batching slots.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import EMPTY  # noqa: E402
from repro.serving.engine import Request, WorkStealingFrontend, ragged_slot_attention  # noqa: E402


class FakeBatcher:
    """Minimal ContinuousBatcher stand-in: admits up to B requests and
    finishes each after `latency` steps, echoing the prompt as output."""

    def __init__(self, slots=2, latency=2):
        self.B = slots
        self.live = [None] * slots
        self._countdown = [0] * slots
        self.latency = latency

    @property
    def n_live(self):
        return sum(r is not None for r in self.live)

    def admit(self, req):
        for i, r in enumerate(self.live):
            if r is None:
                self.live[i] = req
                self._countdown[i] = self.latency
                req.out.append(int(req.tokens[-1]) + 1)
                return True
        return False

    def step(self):
        done = []
        for i, r in enumerate(self.live):
            if r is None:
                continue
            self._countdown[i] -= 1
            r.out.append(len(r.out))
            if self._countdown[i] <= 0:
                done.append(r)
                self.live[i] = None
        return done


def _frontend(n_replicas=2):
    return WorkStealingFrontend(lambda: FakeBatcher(), n_replicas=n_replicas)


def test_duplicate_admission_dedups_on_completion():
    """Force the paper's weak-multiplicity duplicate on a request queue: the
    owner's Take reads the task, stalls before publishing Head, a thief
    Steals the same request, and the owner's stale Head write completes.
    Both replicas admit it (admission is idempotent); exactly one result
    survives and the duplicate completion is counted, not returned."""
    f = _frontend()
    req = Request(rid=42, tokens=np.array([1, 2, 3], dtype=np.int32), max_new=4)
    f.submit(0, req)

    q = f.queues[0]
    # owner (pid 0) begins its Take: reads Head and the task slot, then stalls
    head = max(q._local_head(0), q.Head.read(0))
    assert head <= q.tail
    taken_by_owner = q.tasks.read(head, 0)
    # replica 1's scheduler (thief pid 2) steals the same request meanwhile
    stolen = q.steal(pid=2)
    assert stolen is taken_by_owner is req
    # owner resumes: stale Head write publishes head+1 — the §7 interleaving
    q.Head.write(head + 1, 0)
    q._head[0] = head + 1

    # both replicas admit their copy — idempotent (same rid, same tokens)
    f.batchers[0].admit(Request(req.rid, req.tokens, req.max_new))
    f.batchers[1].admit(Request(req.rid, req.tokens, req.max_new))
    f.counters["admitted"] += 2
    f.counters["stolen"] += 1

    completed = f.run(max_iters=50)
    assert set(completed) == {42}, "exactly one result per rid"
    assert f.counters["dup_completed"] == 1, "the duplicate was observed and dropped"
    assert f.counters["stolen"] == 1
    # queues fully drained
    assert q.take() is EMPTY and q.steal(5) is EMPTY


def test_no_duplicates_without_contention():
    f = _frontend()
    for rid in range(6):
        f.submit(rid % 2, Request(rid=rid, tokens=np.array([rid], dtype=np.int32)))
    completed = f.run(max_iters=200)
    assert set(completed) == set(range(6))
    assert f.stats()["totals"]["dup_completed"] == 0


def test_idle_replica_steals_backlogged_queue():
    f = _frontend()
    for rid in range(8):
        f.submit(0, Request(rid=rid, tokens=np.array([rid], dtype=np.int32)))
    completed = f.run(max_iters=200)
    assert set(completed) == set(range(8))
    stats = f.stats()
    assert stats["totals"]["stolen"] > 0, "replica 1 should have stolen from replica 0"
    # the thief's history is attributed to the thief, not the victim
    assert stats["per_replica"][1]["stolen"] == stats["totals"]["stolen"]
    assert stats["per_replica"][0]["stolen"] == 0
    assert stats["per_replica"][0]["submitted"] == 8


def test_victim_selection_rotates_instead_of_scanning_from_zero():
    """Regression: _next_request used to scan victims from replica 0 every
    time, so a thief drained the lowest-index backlogged queue completely
    before ever visiting a higher one — high-index replicas starved under
    contention.  With the rotating cursor, consecutive steals alternate
    between backlogged victims."""
    f = _frontend(n_replicas=3)
    # queue 1 holds two requests, queue 2 holds one; replica 0 is the thief
    f.submit(1, Request(rid=10, tokens=np.array([1], dtype=np.int32)))
    f.submit(1, Request(rid=11, tokens=np.array([1], dtype=np.int32)))
    f.submit(2, Request(rid=20, tokens=np.array([2], dtype=np.int32)))

    got = [f._next_request(0).rid for _ in range(3)]
    assert f.counters["stolen"] == 3
    assert f.stats()["per_replica"][0]["stolen"] == 3
    # old behavior: [10, 11, 20] (queue 2 starved until queue 1 drained);
    # rotation must visit queue 2 before finishing queue 1
    assert got.index(20) < 2, f"queue 2 starved: steal order {got}"
    assert sorted(got) == [10, 11, 20]
    assert f._next_request(0) is None


def test_victim_rotation_covers_all_queues_when_some_are_empty():
    """The rotating cursor must not skip a backlogged victim just because the
    cursor points at an empty queue."""
    f = _frontend(n_replicas=4)
    f.submit(3, Request(rid=30, tokens=np.array([3], dtype=np.int32)))
    for _ in range(3):  # advance the cursor past failures and wrap
        got = f._next_request(0)
        assert got is not None and got.rid == 30
        f.submit(3, Request(rid=30, tokens=np.array([3], dtype=np.int32)))
    assert f.counters["stolen"] == 3
    assert f.stats()["per_replica"][0]["stolen"] == 3


def test_ragged_slot_attention_matches_oracle():
    """The continuous-batching hook: ragged per-slot lengths routed through
    the device-resident ws scheduler equal the dense masked oracle."""
    from repro.pallas_ws import ragged_decode_ref

    B, H, S, hd = 4, 2, 32, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, H, S, hd))
    v = jax.random.normal(ks[2], (B, H, S, hd))
    lengths = np.array([32, 0, 8, 16])  # slot 1 is a free slot
    out = ragged_slot_attention(q, k, v, lengths, schedule="ws", bk=8)
    ref = ragged_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)

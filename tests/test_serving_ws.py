"""Serving-layer work-stealing tests that need no model weights.

Covers the WorkStealingFrontend's weak-multiplicity tolerance — two replicas
admitting the same request after a paper-§7-style stale-Head interleaving,
deduplicated on completion — and the ragged ws attention hook for continuous
batching slots.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import EMPTY  # noqa: E402
from repro.serving.engine import Request, WorkStealingFrontend, ragged_slot_attention  # noqa: E402


class FakeBatcher:
    """Minimal ContinuousBatcher stand-in: admits up to B requests and
    finishes each after `latency` steps, echoing the prompt as output."""

    def __init__(self, slots=2, latency=2):
        self.B = slots
        self.live = [None] * slots
        self._countdown = [0] * slots
        self.latency = latency

    @property
    def n_live(self):
        return sum(r is not None for r in self.live)

    def admit(self, req):
        for i, r in enumerate(self.live):
            if r is None:
                self.live[i] = req
                self._countdown[i] = self.latency
                req.out.append(int(req.tokens[-1]) + 1)
                return True
        return False

    def step(self):
        done = []
        for i, r in enumerate(self.live):
            if r is None:
                continue
            self._countdown[i] -= 1
            r.out.append(len(r.out))
            if self._countdown[i] <= 0:
                done.append(r)
                self.live[i] = None
        return done


def _frontend(n_replicas=2):
    return WorkStealingFrontend(lambda: FakeBatcher(), n_replicas=n_replicas)


def test_duplicate_admission_dedups_on_completion():
    """Force the paper's weak-multiplicity duplicate on a request queue: the
    owner's Take reads the task, stalls before publishing Head, a thief
    Steals the same request, and the owner's stale Head write completes.
    Both replicas admit it (admission is idempotent); exactly one result
    survives and the duplicate completion is counted, not returned."""
    f = _frontend()
    req = Request(rid=42, tokens=np.array([1, 2, 3], dtype=np.int32), max_new=4)
    f.submit(0, req)

    q = f.queues[0]
    # owner (pid 0) begins its Take: reads Head and the task slot, then stalls
    head = max(q._local_head(0), q.Head.read(0))
    assert head <= q.tail
    taken_by_owner = q.tasks.read(head, 0)
    # replica 1's scheduler (thief pid 2) steals the same request meanwhile
    stolen = q.steal(pid=2)
    assert stolen is taken_by_owner is req
    # owner resumes: stale Head write publishes head+1 — the §7 interleaving
    q.Head.write(head + 1, 0)
    q._head[0] = head + 1

    # both replicas admit their copy — idempotent (same rid, same tokens)
    f.batchers[0].admit(Request(req.rid, req.tokens, req.max_new))
    f.batchers[1].admit(Request(req.rid, req.tokens, req.max_new))
    f.stats["admitted"] += 2
    f.stats["stolen"] += 1

    completed = f.run(max_iters=50)
    assert set(completed) == {42}, "exactly one result per rid"
    assert f.stats["dup_completed"] == 1, "the duplicate was observed and dropped"
    assert f.stats["stolen"] == 1
    # queues fully drained
    assert q.take() is EMPTY and q.steal(5) is EMPTY


def test_no_duplicates_without_contention():
    f = _frontend()
    for rid in range(6):
        f.submit(rid % 2, Request(rid=rid, tokens=np.array([rid], dtype=np.int32)))
    completed = f.run(max_iters=200)
    assert set(completed) == set(range(6))
    assert f.stats["dup_completed"] == 0


def test_idle_replica_steals_backlogged_queue():
    f = _frontend()
    for rid in range(8):
        f.submit(0, Request(rid=rid, tokens=np.array([rid], dtype=np.int32)))
    completed = f.run(max_iters=200)
    assert set(completed) == set(range(8))
    assert f.stats["stolen"] > 0, "replica 1 should have stolen from replica 0"


def test_ragged_slot_attention_matches_oracle():
    """The continuous-batching hook: ragged per-slot lengths routed through
    the device-resident ws scheduler equal the dense masked oracle."""
    from repro.pallas_ws import ragged_decode_ref

    B, H, S, hd = 4, 2, 32, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, H, S, hd))
    v = jax.random.normal(ks[2], (B, H, S, hd))
    lengths = np.array([32, 0, 8, 16])  # slot 1 is a free slot
    out = ragged_slot_attention(q, k, v, lengths, schedule="ws", bk=8)
    ref = ragged_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)

"""Jit-parity regression tests for the traced dropless dispatch.

The whole point of the traced Put is that the dropless WS path works where
training and serving live — under ``jit`` and ``scan``.  These tests pin
that contract:

* ``jit(moe_ffn_ws)`` == eager ``moe_ffn_ws`` == the no-drop oracle;
* ``jit(decode_step_ws)`` == eager ``decode_step_ws`` (logits and caches);
* ``moe_ffn_dispatch`` inside ``scan``-over-layers runs the **dropless**
  path when ``cfg.moe_dispatch == "ws"`` — with a capacity-starved config
  the dense path provably diverges, so if the deleted dense fallback ever
  silently returned under tracing, the scan output would snap to it;
* the traced ragged decode front-end matches the host-built one and the
  dense oracle, dead slots included;
* the vectorized ``row_divisor`` / ``divisor_from_tiles`` are equivalent to
  the original per-task loop (timing-insensitive: pure array comparison).
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.models.moe import init_moe, moe_ffn, moe_ffn_dispatch  # noqa: E402
from repro.moe_ws import (  # noqa: E402
    divisor_from_tiles,
    moe_ffn_nodrop_ref,
    moe_ffn_ws,
    route_to_tasks,
    row_divisor,
)


def _smoke_cfg(**kw):
    cfg = get_config("deepseek-v2-236b", smoke=True)
    return cfg.replace(**kw) if kw else cfg


def _moe_inputs(cfg, B=2, S=8, seed=0):
    p = init_moe(jax.random.PRNGKey(seed), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, S, cfg.d_model))
    return p, x


# ---------------------------------------------------------------------------
# jit(moe_ffn_ws) parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schedule", ["ws", "static"])
def test_jit_moe_ffn_ws_matches_eager_and_oracle(schedule):
    cfg = _smoke_cfg()
    p, x = _moe_inputs(cfg)
    ref, aux_ref = moe_ffn_nodrop_ref(x, p, cfg)
    y_e, aux_e = moe_ffn_ws(x, p, cfg, schedule=schedule, n_programs=4, bt=4)
    y_j, aux_j = jax.jit(
        lambda xx: moe_ffn_ws(xx, p, cfg, schedule=schedule, n_programs=4, bt=4)
    )(x)
    np.testing.assert_allclose(np.asarray(y_j), np.asarray(y_e), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_j), np.asarray(ref), rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(aux_j - aux_ref)) < 1e-6
    assert float(jnp.abs(aux_e - aux_ref)) < 1e-6


def test_jit_moe_ffn_ws_dropless_at_router_skew():
    """Hot-expert routing under jit: the traced dispatch must still equal
    the no-drop oracle exactly where the dense capacity path loses tokens."""
    cfg = _smoke_cfg(capacity_factor=1.0, n_shared_experts=0)
    p, x = _moe_inputs(cfg, B=2, S=16, seed=7)
    p = dict(p)
    p["router"] = jnp.asarray(np.asarray(p["router"]) * 0.05)
    p["router"] = p["router"].at[:, 0].add(10.0)

    ref, _ = moe_ffn_nodrop_ref(x, p, cfg)
    y_j, _ = jax.jit(lambda xx: moe_ffn_ws(xx, p, cfg, n_programs=4, bt=4))(x)
    y_dense, _ = moe_ffn(x, p, cfg, group_size=x.shape[0] * x.shape[1])
    np.testing.assert_allclose(np.asarray(y_j), np.asarray(ref), rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(y_dense.astype(jnp.float32) - ref).max()) > 1e-3, (
        "the capacity path should be dropping here — skew regression"
    )


def test_autodiff_through_ws_dispatch_differentiates():
    """The ws dispatch is no longer forward-only: ``jax.grad`` through
    ``moe_ffn_dispatch`` with cfg.moe_dispatch='ws' runs the custom VJP
    (no TypeError, no deep 'JVP with aliasing' crash, and — pinned by
    tests/test_moe_ws_grad.py — never a silent dense substitution) and its
    gradients match the no-drop oracle's."""
    cfg = _smoke_cfg(moe_dispatch="ws")
    p, x = _moe_inputs(cfg, B=1, S=4, seed=9)

    def loss(xx):
        y, aux = moe_ffn_dispatch(xx, p, cfg)
        return jnp.sum(y ** 2) + aux

    def loss_ref(xx):
        y, aux = moe_ffn_nodrop_ref(xx, p, cfg)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(x)
    g_ref = jax.grad(loss_ref)(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-4)
    # the idiomatic training shape — value_and_grad inside jit — too
    v, gj = jax.jit(jax.value_and_grad(loss))(x)
    assert np.isfinite(float(v))
    np.testing.assert_allclose(np.asarray(gj), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# scan-over-layers: the dense fallback can never silently return
# ---------------------------------------------------------------------------


def test_scan_over_layers_dispatch_stays_dropless():
    """Two stacked MoE layers scanned under jit with a capacity-starved
    config: the ws dispatch must track an eager no-drop reference loop,
    and must NOT equal the dense dropping path (which is what the deleted
    tracer fallback used to return)."""
    cfg = _smoke_cfg(moe_dispatch="ws", capacity_factor=0.25, n_shared_experts=0)
    B, S = 2, 32  # T*k = 128 routed pairs over 8 experts >> dense capacity
    ps = jax.vmap(lambda k: init_moe(k, cfg, jnp.float32))(
        jax.random.split(jax.random.PRNGKey(3), 2)
    )
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S, cfg.d_model))

    def body(h, pl):
        y, aux = moe_ffn_dispatch(h, pl, cfg)
        return h + y, aux

    h_ws, aux_ws = jax.jit(lambda xx: jax.lax.scan(body, xx, ps))(x)

    h_ref = x
    for i in range(2):
        pl = jax.tree_util.tree_map(lambda a: a[i], ps)
        y, _ = moe_ffn_nodrop_ref(h_ref, pl, cfg)
        h_ref = h_ref + y
    np.testing.assert_allclose(
        np.asarray(h_ws), np.asarray(h_ref), rtol=1e-4, atol=1e-4
    )

    cfg_dense = cfg.replace(moe_dispatch="dense")
    h_dense, _ = jax.jit(
        lambda xx: jax.lax.scan(
            lambda h, pl: ((h + moe_ffn_dispatch(h, pl, cfg_dense)[0]), 0.0), xx, ps
        )
    )(x)
    assert float(jnp.abs(h_ws - h_dense).max()) > 1e-3, (
        "ws-flagged scan matched the dropping dense path — fallback returned?"
    )


def test_transformer_block_scan_runs_dropless_under_jit():
    """The full transformer stack (lm_hidden: remat + scan over stacked MoE
    layers) with cfg.moe_dispatch='ws' compiles and runs the dropless
    dispatch: hidden states diverge from the dense-flagged stack because
    the capacity-starved dense path drops tokens (aux diverges too after
    layer 1 — the routers see different hiddens — so only finiteness and
    divergence are asserted here; aux parity per layer is pinned by
    test_jit_moe_ffn_ws_matches_eager_and_oracle)."""
    from repro.models.transformer import init_params, lm_hidden

    cfg = _smoke_cfg(capacity_factor=0.25, n_shared_experts=0)
    B, S = 1, 32
    params = init_params(jax.random.PRNGKey(5), cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(6), (B, S, cfg.d_model))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    cfg_ws = cfg.replace(moe_dispatch="ws")
    h_ws, aux_ws = jax.jit(
        lambda xx: lm_hidden(params, cfg_ws, xx, positions, remat=True)
    )(x)
    h_d, _ = jax.jit(
        lambda xx: lm_hidden(params, cfg, xx, positions, remat=True)
    )(x)
    assert np.isfinite(np.asarray(h_ws)).all()
    assert np.isfinite(float(aux_ws)) and float(aux_ws) > 0.0
    assert float(jnp.abs(h_ws - h_d).max()) > 1e-4, (
        "ws stack equals the capacity-starved dense stack — dropless path "
        "not taken inside the scanned transformer block"
    )


# ---------------------------------------------------------------------------
# jit(decode_step_ws) parity + traced ragged decode
# ---------------------------------------------------------------------------


def test_jit_decode_step_ws_matches_eager():
    from repro.models import decode_step, decode_step_ws, prefill
    from repro.models.transformer import init_params
    from repro.serving.engine import jit_decode_step_ws

    cfg = get_config("llama3.2-3b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.asarray(np.array([[5, 6, 7, 8], [9, 8, 7, 6]], np.int32))}
    _, caches = prefill(params, cfg, batch, capacity=32)
    tok = jnp.asarray(np.array([[3], [4]], np.int32))
    pos = jnp.asarray(np.array([4, 2], np.int32))  # heterogeneous slots

    l_e, c_e = decode_step_ws(params, cfg, caches, tok, pos)
    step = jit_decode_step_ws(cfg)
    l_j, c_j = step(params, caches, tok, pos)
    np.testing.assert_allclose(np.asarray(l_j), np.asarray(l_e), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(c_j.kv.k), np.asarray(c_e.kv.k), rtol=1e-5, atol=1e-5
    )
    l_d, _ = decode_step(params, cfg, caches, tok, pos)
    np.testing.assert_allclose(np.asarray(l_j), np.asarray(l_d), rtol=1e-4, atol=1e-4)


def test_traced_ragged_decode_matches_host_and_oracle():
    from repro.pallas_ws.ragged import ragged_decode_attention, ragged_decode_ref

    B, H, S, hd = 4, 2, 32, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, H, S, hd))
    v = jax.random.normal(ks[2], (B, H, S, hd))
    lengths = np.array([32, 0, 8, 16])  # includes a dead slot

    out_host = ragged_decode_attention(q, k, v, lengths, schedule="ws", bk=8)
    out_jit = jax.jit(
        lambda ln: ragged_decode_attention(q, k, v, ln, schedule="ws", bk=8)
    )(jnp.asarray(lengths))
    ref = ragged_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(
        np.asarray(out_jit), np.asarray(out_host), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(out_jit), np.asarray(ref), rtol=1e-5, atol=1e-5
    )
    # dead slot stays exactly zero through the traced path
    assert float(jnp.abs(out_jit[1]).max()) == 0.0


def test_batcher_jit_ws_matches_eager_ws():
    """ContinuousBatcher(jit_ws=True): the compiled ws decode step produces
    the same greedy streams as the per-step host-built default."""
    from repro.serving.engine import ContinuousBatcher, Request

    cfg = get_config("llama3.2-3b", smoke=True)
    from repro.models.transformer import init_params

    params = init_params(jax.random.PRNGKey(0), cfg)
    outs = {}
    for jit_ws in (False, True):
        b = ContinuousBatcher(params, cfg, slots=2, capacity=32, jit_ws=jit_ws)
        assert b.use_ws
        b.admit(Request(0, np.array([5, 6, 7], np.int32), max_new=4))
        b.admit(Request(1, np.array([9, 8], np.int32), max_new=4))
        done = []
        for _ in range(10):
            done += b.step()
            if not b.n_live:
                break
        outs[jit_ws] = {r.rid: r.out for r in done}
    assert outs[False] == outs[True]


# ---------------------------------------------------------------------------
# row_divisor vectorization: equivalence with the original loop
# ---------------------------------------------------------------------------


def _loop_row_divisor(tasks, mult, n_rows):
    """The original O(n_tasks) Python-loop implementation, kept as the
    reference semantics for the vectorized np.repeat version."""
    mult = np.asarray(mult)
    div = np.ones((n_rows,), dtype=np.float32)
    for t in tasks:
        div[t.row_start: t.row_start + t.row_len] = max(1, int(mult[t.tid]))
    return div


@pytest.mark.parametrize("seed", range(6))
def test_row_divisor_vectorized_equals_loop(seed):
    rng = np.random.RandomState(seed)
    T = rng.randint(1, 40)
    E = rng.randint(1, 7)
    k = rng.randint(1, min(3, E) + 1)
    bt = int(rng.choice([2, 4, 8]))
    idx = np.stack([rng.choice(E, k, replace=False) for _ in range(T)])
    gates = rng.uniform(0.1, 1.0, (T, k)).astype(np.float32)
    tasks, routed = route_to_tasks(idx, gates, E, bt=bt)
    mult = rng.randint(0, 4, size=len(tasks))
    np.testing.assert_array_equal(
        row_divisor(tasks, mult, routed.n_rows),
        _loop_row_divisor(tasks, mult, routed.n_rows),
    )


def test_row_divisor_empty_tasks():
    np.testing.assert_array_equal(
        row_divisor([], np.zeros(0), 7), np.ones(7, np.float32)
    )


def test_divisor_from_tiles_traced_uniform_matches_host():
    """The traced uniform-bt branch and the host ragged branch agree on
    full tiles, eagerly and under jit."""
    rng = np.random.RandomState(0)
    n_tiles, bt = 6, 4
    starts = np.arange(n_tiles) * bt
    lens = np.full(n_tiles, bt)
    mult = rng.randint(0, 5, size=n_tiles)
    host = divisor_from_tiles(starts, lens, mult, n_tiles * bt)
    traced = jax.jit(
        lambda m: divisor_from_tiles(jnp.asarray(starts), bt, m, n_tiles * bt)
    )(jnp.asarray(mult))
    np.testing.assert_array_equal(np.asarray(traced), host)

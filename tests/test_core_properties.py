"""Concurrency property tests: the paper's relaxations hold under adversarial
interleavings (deterministic simulator) and under real threads.

Checked properties (see repro.core.simulator):
  P1 weak multiplicity  — no process extracts the same task twice
                          (WS-MULT, WS-WMULT, B-WS-*; Defs 3.1/4.1).
  P2 multiplicity       — same-task extractions pairwise concurrent
                          (WS-MULT / B-WS-MULT only; Remark 3.2).
  P3 at-least-once FIFO — no task older than the newest extracted one is lost.
  P4 owner FIFO order   — the owner's takes respect put order.
  P5 §7 separation      — idempotent FIFO lets one thief re-extract a task an
                          unbounded number of times; the paper's algorithms
                          cap each process at one extraction per task.
"""

import threading

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install hypothesis)"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ALGORITHMS, EMPTY, MULTIPLICITY_FAMILY, ThreadBackend
from repro.core.simulator import (
    check_no_lost_tasks_fifo,
    check_no_process_duplicates,
    check_owner_fifo,
    check_pairwise_concurrent_duplicates,
    extractions,
    run_program,
)

# ---------------------------------------------------------------------------
# Simulator-based randomized schedules
# ---------------------------------------------------------------------------


def _make_program(n_tasks, n_thieves, steals_per_thief, takes):
    prog = {0: [("put", i) for i in range(1, n_tasks + 1)] + [("take", None)] * takes}
    for t in range(1, n_thieves + 1):
        prog[t] = [("steal", None)] * steals_per_thief
    return prog


schedules = st.lists(st.integers(min_value=0, max_value=3), min_size=0, max_size=400)


@pytest.mark.parametrize("name", sorted(MULTIPLICITY_FAMILY))
@settings(max_examples=12, deadline=None)
@given(schedule=schedules)
def test_multiplicity_family_random_schedules(name, schedule):
    factory = ALGORITHMS[name]

    def make(backend):
        if name in ("ws-mult", "b-ws-mult"):
            return factory(backend=backend, max_register="tree", capacity=64)
        return factory(backend=backend)

    prog = _make_program(n_tasks=8, n_thieves=3, steals_per_thief=5, takes=5)
    records = run_program(make, prog, schedule)
    check_no_process_duplicates(records)  # P1
    check_no_lost_tasks_fifo(records)  # P3
    check_owner_fifo(records)  # P4
    if name in ("ws-mult", "b-ws-mult"):
        check_pairwise_concurrent_duplicates(records)  # P2 (set-linearizability)


@settings(max_examples=12, deadline=None)
@given(schedule=schedules)
def test_wsmult_atomic_maxreg_random_schedules(schedule):
    def make(backend):
        return ALGORITHMS["ws-mult"](backend=backend, max_register="atomic")

    prog = _make_program(n_tasks=6, n_thieves=3, steals_per_thief=4, takes=4)
    records = run_program(make, prog, schedule)
    check_no_process_duplicates(records)
    check_pairwise_concurrent_duplicates(records)
    check_no_lost_tasks_fifo(records)


@settings(max_examples=10, deadline=None)
@given(schedule=schedules, order=st.sampled_from(["task_first", "bottom_first"]))
def test_wswmult_put_order_fence_freedom(schedule, order):
    """Line 2 of Put is brace-unordered: both physical write orders satisfy
    the same properties under adversarial schedules (fence-freedom)."""

    def make(backend):
        return ALGORITHMS["ws-wmult"](backend=backend, put_order=order)

    prog = _make_program(n_tasks=6, n_thieves=2, steals_per_thief=6, takes=3)
    records = run_program(make, prog, schedule)
    check_no_process_duplicates(records)
    check_no_lost_tasks_fifo(records)


@settings(max_examples=10, deadline=None)
@given(schedule=schedules)
def test_exact_ws_no_duplicates_at_all(schedule):
    """§5 'removing multiplicity': every task extracted at most once overall."""

    def make(backend):
        return ALGORITHMS["exact-ws"](backend=backend)

    prog = _make_program(n_tasks=8, n_thieves=3, steals_per_thief=5, takes=5)
    records = run_program(make, prog, schedule)
    got = [r.result for r in extractions(records)]
    assert len(got) == len(set(got)), f"exact-ws duplicated a task: {sorted(got)}"


@settings(max_examples=10, deadline=None)
@given(schedule=schedules)
def test_bounded_variant_steal_at_most_once(schedule):
    """§5: in B-WS-*, a task is extracted by at most one Take and one Steal."""

    def make(backend):
        return ALGORITHMS["b-ws-wmult"](backend=backend)

    prog = _make_program(n_tasks=8, n_thieves=3, steals_per_thief=5, takes=5)
    records = run_program(make, prog, schedule)
    by_task = {}
    for r in extractions(records):
        by_task.setdefault(r.result, []).append(r.kind)
    for task, kinds in by_task.items():
        assert kinds.count("steal") <= 1, f"task {task} stolen twice: {kinds}"
        assert kinds.count("take") <= 1, f"task {task} taken twice: {kinds}"


# ---------------------------------------------------------------------------
# §7: idempotent ≠ multiplicity — the separation witness
# ---------------------------------------------------------------------------


def test_idempotent_fifo_unbounded_re_extraction():
    """Reproduces the §7 execution: the owner's Take stalls between reading a
    task and publishing head+1; a single thief steals the whole remaining
    prefix; the owner's stale head write then rewinds the queue, so the next
    round re-extracts the same tasks.  Task i ends up extracted Θ(i) times —
    by the *same thief*, non-concurrently."""
    from repro.core.baselines import IdempotentFIFO

    z = 6
    q = IdempotentFIFO()
    for i in range(1, z + 1):
        q.put(i)

    thief_got = []
    r = z
    while r >= 1:
        # owner's take, paused before line 5 (head := h+1):
        h = q.head.read(0)
        t = q.tail.read(0)
        assert h != t
        tasks = q.tasks_ref.read(0)
        _owner_task = tasks.a[h % tasks.size]
        # thief sequentially steals r tasks
        for _ in range(r):
            got = q.steal(1)
            assert got is not EMPTY
            thief_got.append(got)
        # owner resumes: stale head write rewinds the head
        q.head.write(h + 1, 0)
        r -= 1

    counts = {v: thief_got.count(v) for v in set(thief_got)}
    # task i is stolen in every round while the head is rewound behind it:
    # unbounded growth with z — the same thief extracted some task many times.
    assert max(counts.values()) >= z - 1, counts
    # and these re-extractions are NON-concurrent (sequential steals), which
    # work-stealing with (weak) multiplicity forbids per process.


def test_wswmult_same_adversary_is_bounded():
    """The same adversarial owner-stall drill against WS-WMULT: the thief's
    persistent local head makes re-extraction impossible (≤1 per process)."""
    from repro.core import WSWMult

    z = 6
    q = WSWMult()
    for i in range(1, z + 1):
        q.put(i)

    thief_got = []
    r = z
    while r >= 1:
        # owner's take, paused between reading the task and writing Head:
        head = max(q._local_head(0), q.Head.read(0))
        if head <= q.tail:
            _x = q.tasks.read(head, 0)
            # thief steals as much as it can
            for _ in range(r):
                got = q.steal(1)
                if got is not EMPTY:
                    thief_got.append(got)
            # owner resumes: writes a stale head — rewinds Head
            q.Head.write(head + 1, 0)
            q._head[0] = head + 1
        r -= 1

    counts = {v: thief_got.count(v) for v in set(thief_got)}
    assert counts and max(counts.values()) == 1, (
        f"WS-WMULT let a single thief re-extract a task: {counts}"
    )


# ---------------------------------------------------------------------------
# Real-thread stress tests (GIL preemption provides the interleavings)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(MULTIPLICITY_FAMILY) + ["exact-ws"])
@pytest.mark.parametrize("storage", ["infinite", "linked"])
def test_thread_stress(name, storage):
    import sys

    old = sys.getswitchinterval()
    sys.setswitchinterval(1e-5)  # force frequent preemption
    try:
        n_tasks, n_thieves = 2000, 3
        kw = {"storage": storage}
        if storage == "linked":
            kw["node_len"] = 64
        if name in ("ws-mult", "b-ws-mult"):
            kw.update(max_register="atomic")
        q = ALGORITHMS[name](**kw)
        results = {pid: [] for pid in range(n_thieves + 1)}
        stop = threading.Event()

        def owner():
            for i in range(n_tasks):
                q.put(i)
                if i % 3 == 0:
                    x = q.take()
                    if x is not EMPTY:
                        results[0].append(x)
            while True:
                x = q.take()
                if x is EMPTY:
                    break
                results[0].append(x)
            stop.set()

        def thief(pid):
            misses = 0
            while misses < 3 or not stop.is_set():
                x = q.steal(pid)
                if x is EMPTY:
                    misses += 1
                else:
                    results[pid].append(x)
                    misses = 0
                if stop.is_set() and misses >= 3:
                    break

        threads = [threading.Thread(target=owner)] + [
            threading.Thread(target=thief, args=(pid,)) for pid in range(1, n_thieves + 1)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(60)

        # P1: per-process no duplicates
        for pid, got in results.items():
            assert len(got) == len(set(got)), f"{name}: process {pid} extracted a task twice"
        # P3: every task extracted at least once (collectively)
        union = set()
        for got in results.values():
            union.update(got)
        assert union == set(range(n_tasks)), (
            f"{name}: lost tasks {sorted(set(range(n_tasks)) - union)[:10]}..."
        )
        # multiplicity is bounded by the number of processes
        all_got = [x for got in results.values() for x in got]
        counts = {}
        for x in all_got:
            counts[x] = counts.get(x, 0) + 1
        assert max(counts.values()) <= n_thieves + 1
        if name == "exact-ws":
            assert max(counts.values()) == 1
    finally:
        sys.setswitchinterval(old)

"""Observability-layer tests (ISSUE 7 / DESIGN.md §8).

The event rings are written from inside the megakernel with plain stores
only, so the things worth pinning are the *decode contracts*, not the
stores themselves:

  1. ring decode round-trip — on seeded schedules the decoded stream
     accounts for every extraction, and per-program cost/steal totals match
     the aggregate ``work``/``steals`` counters bit for bit;
  2. trace=False is free — a traced-off launch returns a ``WSRunResult``
     bit-identical to the pre-trace baseline (and carries no rings);
  3. adversarial rewind drills — rings are per-launch, so a relaunch on
     rewound heads yields a second stream whose every record carries the
     post-increment multiplicity 2 and still balances the launch counters;
  4. steal provenance (hypothesis) — every steal event names a victim whose
     queue held that live slot: ``victim == queue`` owner mapping,
     ``slot < tail[queue]``, the slot's task is live, and on fresh launches
     no (queue, slot) is claimed twice;
  5. overflow-drop — a deliberately tiny ring keeps the run's prefix and
     reports the exact number of dropped records;
  6. export surfaces — Perfetto JSON structure (slices == events, balanced
     flow arrows, counter samples), mesh phase rendering, and the serving
     ``SchedulerMetrics`` snapshot.
"""

import json

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

try:
    from hypothesis import given, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.moe_ws.dispatch import route_to_tasks  # noqa: E402
from repro.moe_ws.expert_kernel import run_moe_schedule  # noqa: E402
from repro.pallas_ws.kernel import run_ws_schedule  # noqa: E402
from repro.pallas_ws.queues import make_queue_state  # noqa: E402
from repro.pallas_ws.tasks import F_OP, emit_flash_tasks  # noqa: E402
from repro.wstrace.ring import (  # noqa: E402
    EV_COST,
    EV_KIND,
    EV_MULT,
    EV_PROG,
    EV_QUEUE,
    EV_ROUND,
    EV_RUN,
    EV_SLOT,
    EV_VICTIM,
    EVENT_WIDTH,
    KIND_TAKE,
    STEAL_KINDS,
    decode_rings,
)
from repro.wstrace.metrics import SchedulerMetrics  # noqa: E402

# shared fault-drill mechanics (repro.chaos via conftest)
from conftest import full_rewind  # noqa: E402
from repro.wstrace.perfetto import PID_MESH, to_perfetto  # noqa: E402
from repro.wstrace.trace import WSTrace  # noqa: E402

P = 3
KEY = jax.random.PRNGKey(0)


def _moe_setup(idx, gates, E, bt, seed=0):
    T = idx.shape[0]
    d, f = 4, 8
    ks = jax.random.split(jax.random.PRNGKey(seed % 997), 4)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    w = (
        jax.random.normal(ks[1], (E, d, f), jnp.float32) / 2.0,
        jax.random.normal(ks[2], (E, d, f), jnp.float32) / 2.0,
        jax.random.normal(ks[3], (E, f, d), jnp.float32) / 2.0,
    )
    tasks, routed = route_to_tasks(idx, gates, E, bt=bt)
    state = make_queue_state(tasks, P, n_queues=E, partition="owner")
    return x, w, tasks, routed, state


def _run_traced(idx, gates, E, bt, policy, seed=0, **kw):
    x, w, tasks, routed, state = _moe_setup(idx, gates, E, bt, seed)
    res = run_moe_schedule(
        state, x, routed.tok_idx, *w, bt=bt, steal=True,
        steal_policy=policy, trace=True, **kw,
    )
    return state, res


def _check_stream_vs_counters(state, res):
    """The decode contract: the stream balances every aggregate counter."""
    stream, dropped = decode_rings(res.events, res.ev_cursor)
    assert (dropped == 0).all(), "default capacity must never drop"
    assert stream.shape == (res.extractions, EVENT_WIDTH)
    # (round, program)-sorted timeline
    assert (np.diff(stream[:, EV_ROUND]) >= 0).all()
    n_programs = res.events.shape[0]
    steal_mask = np.isin(stream[:, EV_KIND], STEAL_KINDS)
    for p in range(n_programs):
        mine = stream[stream[:, EV_PROG] == p]
        assert mine[:, EV_COST].sum() == res.work[p], p
        assert np.isin(mine[:, EV_KIND], STEAL_KINDS).sum() == res.steals[p], p
    assert steal_mask.sum() == int(res.steals.sum())
    assert (stream[:, EV_MULT] >= 1).all(), "mult recorded post-increment"
    return stream


def check_ring_roundtrip(idx, gates, E, bt, policy, seed):
    state, res = _run_traced(idx, gates, E, bt, policy, seed)
    stream = _check_stream_vs_counters(state, res)
    # fresh launch: every live slot claimed exactly once, and the trace
    # view agrees with WSTrace's derived analytics
    tr = WSTrace.from_run(state, res)
    assert tr.n_events == res.extractions
    assert tr.n_steals == int(res.steals.sum())
    assert abs(tr.steal_ratio - res.steal_ratio) < 1e-12
    np.testing.assert_array_equal(tr.per_queue_drain(), res.per_queue_drained)
    util = tr.utilization()
    assert len(util) == max(tr.makespan, 1)
    assert (util >= 0).all() and (util <= 1).all()
    # busy program-rounds integrate back to total work
    assert round(util.sum() * tr.n_programs) == res.total_work
    idle = tr.idle_attribution()
    assert idle["total_idle"] == res.wasted_slots
    return stream


def check_steal_provenance(idx, gates, E, bt, policy, seed):
    """§4 of the module docstring: every steal is a live claim of a victim
    queue — the advisory may be stale, the slot may not be."""
    state, res = _run_traced(idx, gates, E, bt, policy, seed)
    stream, _ = decode_rings(res.events, res.ev_cursor)
    tail = np.asarray(state.tail)
    live = np.asarray(state.tasks)[:, :, F_OP] != -1
    seen = set()
    for ev in stream:
        q, s, p = int(ev[EV_QUEUE]), int(ev[EV_SLOT]), int(ev[EV_PROG])
        assert 0 <= q < state.n_queues and 0 <= s < tail[q], (q, s)
        assert live[q, s], "claims address live tasks only"
        assert (q, s) not in seen, "fresh launch: no duplicate claims"
        seen.add((q, s))
        if int(ev[EV_KIND]) == KIND_TAKE:
            assert ev[EV_VICTIM] == -1
            assert q == p % state.n_queues, "takes hit the own queue"
        else:
            assert q != p % state.n_queues, "steals are cross-queue"
            expect = q if q < P else -1
            assert ev[EV_VICTIM] == expect, (q, int(ev[EV_VICTIM]))
            assert ev[EV_VICTIM] != p


SEED_CASES = [
    # (T, E, k, bt, skewed-to-one-expert?)
    (12, 4, 1, 2, False),
    (24, 6, 2, 4, False),
    (24, 6, 1, 4, True),
]


@pytest.mark.parametrize("policy", ["scan", "cost"])
@pytest.mark.parametrize("case", SEED_CASES)
def test_ring_decode_roundtrip_seeded(policy, case):
    T, E, k, bt, skew = case
    rng = np.random.RandomState(7)
    idx = (np.zeros((T, k), np.int32) if skew
           else rng.randint(0, E, size=(T, k)).astype(np.int32))
    gates = np.ones((T, k), np.float32)
    check_ring_roundtrip(idx, gates, E, bt, policy, seed=0)
    check_steal_provenance(idx, gates, E, bt, policy, seed=0)


if HAVE_HYPOTHESIS:

    @given(
        data=st.data(),
        T=st.integers(6, 30),
        E=st.integers(2, 6),
        policy=st.sampled_from(["scan", "cost"]),
    )
    def test_ring_decode_roundtrip_random(data, T, E, policy):
        k = data.draw(st.integers(1, 2), label="k")
        bt = data.draw(st.sampled_from([2, 4]), label="bt")
        idx = np.array(
            [data.draw(st.lists(st.integers(0, E - 1), min_size=k, max_size=k))
             for _ in range(T)], np.int32)
        gates = np.ones((T, k), np.float32)
        check_ring_roundtrip(idx, gates, E, bt, policy, seed=T)

    @given(
        data=st.data(),
        E=st.integers(2, 6),
        policy=st.sampled_from(["scan", "cost"]),
    )
    def test_steal_provenance_random(data, E, policy):
        T = data.draw(st.integers(6, 30), label="T")
        hot = data.draw(st.integers(0, E - 1), label="hot")
        # skew mass onto one expert so steals actually happen
        idx = np.full((T, 1), hot, np.int32)
        n_off = data.draw(st.integers(0, T // 3), label="n_off")
        for i in range(n_off):
            idx[i, 0] = data.draw(st.integers(0, E - 1))
        gates = np.ones((T, 1), np.float32)
        check_steal_provenance(idx, gates, E, 2, policy, seed=E)


# ---------------------------------------------------------------------------
# vectorized ring decode — bit parity with the per-ring loop it replaced
# ---------------------------------------------------------------------------


def _decode_rings_loop_ref(events, cursor):
    """The retired per-(program, slot) Python loop, kept as the oracle."""
    events = np.asarray(events)
    cursor = np.asarray(cursor)
    n_programs, cap, width = events.shape
    rows = []
    for p in range(n_programs):
        for c in range(min(int(cursor[p]), cap)):
            rows.append(events[p, c])
    stream = (np.stack(rows) if rows
              else np.empty((0, width), dtype=events.dtype))
    if len(stream):
        order = np.lexsort((stream[:, EV_PROG], stream[:, EV_ROUND]))
        stream = stream[order]
    dropped = np.maximum(cursor.astype(np.int64) - cap, 0)
    return stream, dropped


@pytest.mark.parametrize("seed", range(4))
def test_decode_rings_matches_loop_reference(seed):
    """Random rings with partial fills and overflowed cursors: the masked
    one-shot decode returns the loop's stream bit for bit (same row order
    into the same stable lexsort) and the same per-program drop counts."""
    rng = np.random.RandomState(seed)
    n_programs = rng.randint(1, 6)
    cap = rng.randint(1, 9)
    events = rng.randint(
        0, 50, size=(n_programs, cap, EVENT_WIDTH)).astype(np.int32)
    cursor = rng.randint(0, 2 * cap + 1, size=(n_programs,)).astype(np.int32)
    s_vec, d_vec = decode_rings(events, cursor)
    s_ref, d_ref = _decode_rings_loop_ref(events, cursor)
    np.testing.assert_array_equal(s_vec, s_ref)
    np.testing.assert_array_equal(d_vec, d_ref)


def test_decode_rings_empty_cursor():
    events = np.zeros((3, 4, EVENT_WIDTH), np.int32)
    stream, dropped = decode_rings(events, np.zeros(3, np.int32))
    assert stream.shape == (0, EVENT_WIDTH)
    assert (dropped == 0).all()


# ---------------------------------------------------------------------------
# half-run claims in the stream: per-slot events, amortized probes
# ---------------------------------------------------------------------------


def test_halfrun_trace_stream_balances_counters():
    """steal_run_cap>1 amortizes probes, not records: every slot of a
    claimed run still emits its own event (EV_RUN carries the run length),
    so all stream-vs-counter invariants hold unchanged — while the scanned
    counter, not the stream, shrinks."""
    T, E, k, bt = 24, 6, 1, 4
    idx = np.zeros((T, k), np.int32)  # one hot queue -> guaranteed steals
    gates = np.ones((T, k), np.float32)
    from repro.pallas_ws.kernel import default_rounds

    # the SAME round budget for both lowerings: probe traffic accumulates
    # per round, so the comparison must be launch-for-launch fair
    rounds = default_rounds(_moe_setup(idx, gates, E, bt)[4],
                            steal=True, steal_run_cap=4)
    state, res = _run_traced(idx, gates, E, bt, "cost",
                             steal_run_cap=4, rounds=rounds)
    stream = _check_stream_vs_counters(state, res)
    assert (stream[:, EV_RUN] >= 1).all()
    run_of_steals = stream[np.isin(stream[:, EV_KIND], STEAL_KINDS), EV_RUN]
    assert (run_of_steals > 1).any(), "half-run claims must appear"
    takes = stream[stream[:, EV_KIND] == KIND_TAKE, EV_RUN]
    assert (takes == 1).all(), "owner Takes stay per-slot"
    # the amortization is visible in probe traffic at the same stream size
    state1, res1 = _run_traced(idx, gates, E, bt, "cost",
                               steal_run_cap=1, rounds=rounds)
    assert res.extractions == res1.extractions == _check_stream_vs_counters(
        state1, res1).shape[0]
    assert res.slots_scanned <= res1.slots_scanned
    assert (_check_stream_vs_counters(state1, res1)[:, EV_RUN] == 1).all()


# ---------------------------------------------------------------------------
# trace=False is bit-identical to the pre-trace baseline
# ---------------------------------------------------------------------------


def test_trace_off_is_bit_identical():
    T, E, k, bt = 24, 6, 2, 4
    rng = np.random.RandomState(3)
    idx = rng.randint(0, E, size=(T, k)).astype(np.int32)
    gates = np.ones((T, k), np.float32)

    runs = {}
    for trace in (False, True):
        x, w, tasks, routed, state = _moe_setup(idx, gates, E, bt, seed=1)
        runs[trace] = run_moe_schedule(
            state, x, routed.tok_idx, *w, bt=bt, steal=True,
            steal_policy="cost", trace=trace,
        )
    off, on = runs[False], runs[True]
    assert off.events is None and off.ev_cursor is None
    assert on.events is not None
    for f in ("head", "local_head", "taken", "remaining", "clock", "work",
              "steals", "scanned", "mult"):
        np.testing.assert_array_equal(
            np.asarray(getattr(off, f)), np.asarray(getattr(on, f)), err_msg=f)
    np.testing.assert_array_equal(np.asarray(off.out), np.asarray(on.out))


# ---------------------------------------------------------------------------
# adversarial rewind drill: per-launch rings stay balanced under duplication
# ---------------------------------------------------------------------------


def test_rewind_drill_stream_consistency():
    lengths = np.array([32, 8, 8, 16])
    B, S = len(lengths), int(max(lengths))
    H, hd, bq, bk = 2, 8, 8, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, H, S, hd))
    v = jax.random.normal(ks[2], (B, H, S, hd))
    tasks = emit_flash_tasks(lengths, H, bq, bk, causal=True)
    state = make_queue_state(tasks, n_programs=4)

    res1 = run_ws_schedule(state, q, k, v, causal=True, bq=bq, bk=bk,
                           steal=True, trace=True)
    stream1 = _check_stream_vs_counters(state, res1)
    assert (stream1[:, EV_MULT] == 1).all()

    # §7-style staleness: every Head dragged to 0, local bounds wiped —
    # the shared maximal-storm drill from repro.chaos
    full_rewind(state, res1)
    res2 = run_ws_schedule(
        state, q, k, v, causal=True, bq=bq, bk=bk, steal=True,
        out=res1.out, mult=jnp.asarray(res1.mult), trace=True,
    )
    stream2, dropped = decode_rings(res2.events, res2.ev_cursor)
    assert (dropped == 0).all()
    # rings are per-launch: the second stream holds exactly the re-claims
    assert len(stream2) == state.n_tasks
    assert (stream2[:, EV_MULT] == 2).all(), "post-increment mult of the dup"
    for p in range(4):
        mine = stream2[stream2[:, EV_PROG] == p]
        assert mine[:, EV_COST].sum() == res2.work[p]
        assert np.isin(mine[:, EV_KIND], STEAL_KINDS).sum() == res2.steals[p]


# ---------------------------------------------------------------------------
# overflow-drop semantics
# ---------------------------------------------------------------------------


def test_overflow_drop_keeps_prefix_and_counts():
    T, E, k, bt = 24, 6, 1, 4
    idx = np.zeros((T, k), np.int32)
    gates = np.ones((T, k), np.float32)
    cap = 2
    state, res = _run_traced(idx, gates, E, bt, "cost", trace_capacity=cap)
    stream, dropped = decode_rings(res.events, res.ev_cursor)
    assert len(stream) + int(dropped.sum()) == res.extractions
    assert len(stream) <= cap * P
    np.testing.assert_array_equal(
        dropped, np.maximum(np.asarray(res.ev_cursor) - cap, 0))
    # the surviving records are each program's *first* claims: rounds
    # nondecreasing per program and nothing is garbage
    for p in range(P):
        mine = stream[stream[:, EV_PROG] == p]
        assert (np.diff(mine[:, EV_ROUND]) >= 0).all()
        assert (mine[:, EV_COST] > 0).all()
    tr = WSTrace.from_run(state, res)
    assert tr.summary()["dropped"] == int(dropped.sum())


# ---------------------------------------------------------------------------
# compressed no-steal drain still traces every claim
# ---------------------------------------------------------------------------


def test_compressed_static_drain_traces_every_claim():
    T, E, k, bt = 18, 3, 1, 2
    rng = np.random.RandomState(11)
    idx = rng.randint(0, E, size=(T, k)).astype(np.int32)
    gates = np.ones((T, k), np.float32)
    x, w, tasks, routed, state = _moe_setup(idx, gates, E, bt, seed=2)
    res = run_moe_schedule(
        state, x, routed.tok_idx, *w, bt=bt, steal=False,
        compress_runs=True, trace=True,
    )
    stream, dropped = decode_rings(res.events, res.ev_cursor)
    assert (dropped == 0).all(), "compressed capacity defaults to state.capacity"
    assert len(stream) == res.extractions
    assert (stream[:, EV_KIND] == KIND_TAKE).all(), "no thieves when steal=False"
    # virtual rounds: each record's busy interval ends inside the makespan
    ends = stream[:, EV_ROUND] + stream[:, EV_COST]
    assert int(ends.max()) == res.makespan


# ---------------------------------------------------------------------------
# export surfaces
# ---------------------------------------------------------------------------


def test_perfetto_export_structure():
    T, E, k, bt = 24, 6, 1, 4
    idx = np.zeros((T, k), np.int32)  # one hot queue -> guaranteed steals
    gates = np.ones((T, k), np.float32)
    state, res = _run_traced(idx, gates, E, bt, "cost")
    tr = WSTrace.from_run(state, res)
    doc = to_perfetto(tr)
    json.dumps(doc)  # must be serializable as-is
    evs = doc["traceEvents"]
    slices = [e for e in evs if e["ph"] == "X" and e["pid"] == 0]
    assert len(slices) == tr.n_events
    starts = [e for e in evs if e["ph"] == "s"]
    ends = [e for e in evs if e["ph"] == "f"]
    assert len(starts) == len(ends) == tr.n_steals, "one flow arrow per steal"
    assert {e["id"] for e in starts} == {e["id"] for e in ends}
    counters = [e for e in evs if e["ph"] == "C"]
    # one initial sample per queue + one per claim
    assert len(counters) == tr.n_queues + tr.n_events
    final = {}
    for c in counters:
        final[c["name"]] = c["args"]["tiles"]
    assert all(v == 0 for v in final.values()), "every queue drains to 0"


def test_perfetto_mesh_phases():
    from repro.mesh_ws import mesh_wstrace

    tele = np.array(
        # phase1, phase2, steal, advisory, victim, stole, take_tiles, mult
        [[4, 3, 0, 6, 0, 0, 0, 6],
         [4, 0, 2, 1, 0, 1, 3, 4]], np.int64)
    tr = mesh_wstrace(tele, collective_bytes=512)
    assert tr.makespan == 7
    doc = to_perfetto(tr)
    json.dumps(doc)
    mesh = [e for e in doc["traceEvents"] if e.get("pid") == PID_MESH]
    names = [e["name"] for e in mesh if e["ph"] == "X"]
    assert names.count("phase1 local drain") == 2
    assert "phase2 remote steal" in names
    flows = [e for e in mesh if e["ph"] in ("s", "f")]
    assert len(flows) == 2, "one victim->thief arrow for the one remote steal"
    byte_counters = [e for e in mesh if e["ph"] == "C"
                     and e["name"].startswith("collective bytes")]
    assert len(byte_counters) == 2
    assert all(c["args"]["value"] == 512 for c in byte_counters)


def test_scheduler_metrics_snapshot():
    m = SchedulerMetrics(slots=4)
    empty = m.snapshot()
    assert empty["steps"] == 0 and empty["latency_ms"] is None
    for i in range(10):
        m.record_step(0.001 * (i + 1), n_live=2)
    m.record_admission(3)
    m.record_completion()
    snap = m.snapshot()
    json.dumps(snap)
    assert snap["steps"] == 10
    assert snap["admitted"] == 3 and snap["completed"] == 1
    assert snap["slot_utilization"] == pytest.approx(0.5)
    assert snap["latency_ms"]["p50"] == pytest.approx(5.5)
    assert snap["latency_ms"]["max"] == pytest.approx(10.0)
    assert snap["latency_ms"]["p50"] <= snap["latency_ms"]["p99"]

"""Chaos harness tests (ISSUE 9 / DESIGN.md §9).

The fault model is the paper's §7 adversary made executable: every fault a
:class:`repro.chaos.FaultPlan` injects — program stalls, advisory
corruption, kill-and-relaunch, head-rewind storms, dropped/stale host
advisory writes — is a legal relaxed-memory behavior of the fence-free
protocol, so the *only* acceptable outcomes are the WS-WMULT guarantees:

  1. scheduler chaos — any seeded plan driven through
     ``run_with_faults`` leaves a trace the ``SafetyChecker`` accepts:
     no lost task, per-(program,queue,slot) uniqueness within a launch,
     the stale-republish multiplicity bound, and output parity with the
     fault-free oracle (bitwise via exact float replay for the
     single-source moe rows; allclose after normalization for the
     multi-source attention rows — the repo's existing rewind bar);
  2. fault-off bit-parity — ``fault_plan=None``, an omitted kwarg, and a
     zero ``FaultPlan()`` produce bitwise-identical ``WSRunResult``s
     (injection is free when off, like ``trace=False``);
  3. host-shim faults — dropped advisory writes and stale post-claim
     head republishes on ``PallasWSHost`` stay inside weak multiplicity
     under the deterministic adversarial simulator;
  4. serving chaos — replica crashes re-admit in-flight requests
     idempotently (no duplicate tokens, streams identical to an
     uninterrupted run), transient admissions back off and give up
     visibly, and the unified-step watchdog degrades to the split path
     on poisoned logits / blown deadlines without changing any token;
  5. checkpoint crash drill — a crash mid-publish can never tear
     ``latest_step`` (write-then-rename), and the async writer surfaces
     the error instead of swallowing it.

Scheduler checks are plain functions over a seed: hypothesis drives them
through arbitrary plans (deep under ``--hypothesis-profile=ci``), and
seeded deterministic slices always run.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

try:
    from hypothesis import given, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.chaos import (  # noqa: E402
    EngineFaultPlan,
    FaultPlan,
    ReplicaCrashPlan,
    SafetyChecker,
    run_with_faults,
)
from repro.core.simulator import (  # noqa: E402
    check_no_lost_tasks_fifo,
    check_no_process_duplicates,
    run_program,
)
from repro.moe_ws.dispatch import route_to_tasks, row_divisor  # noqa: E402
from repro.moe_ws.expert_kernel import run_moe_schedule  # noqa: E402
from repro.pallas_ws import (  # noqa: E402
    PallasWSHost,
    emit_flash_tasks,
    make_queue_state,
    multiplicity_divisor,
    ragged_attention_ref,
)
from repro.pallas_ws.kernel import default_rounds, run_ws_schedule  # noqa: E402
from repro.pallas_ws.queues import copy_state  # noqa: E402
from repro.serving.engine import (  # noqa: E402
    ContinuousBatcher,
    Request,
    WorkStealingFrontend,
)

P = 3  # programs: fewer than the expert count, so thieves roam


# ---------------------------------------------------------------------------
# problem builders (the steal-policy suite's fixed-size moe problem and the
# rewind drill's attention problem, reused as chaos substrates)
# ---------------------------------------------------------------------------


def _moe_problem(seed):
    rng = np.random.RandomState(seed % 2**31)
    E, T, k, bt = 4, int(rng.randint(6, 12)), 1, 2
    d, f = 4, 8
    idx = np.stack([rng.choice(E, k, replace=False) for _ in range(T)])
    gates = rng.uniform(0.1, 1.0, (T, k)).astype(np.float32)
    gates /= gates.sum(1, keepdims=True)
    ks = jax.random.split(jax.random.PRNGKey(seed % 997), 4)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    w = (
        jax.random.normal(ks[1], (E, d, f), jnp.float32) / 2.0,
        jax.random.normal(ks[2], (E, d, f), jnp.float32) / 2.0,
        jax.random.normal(ks[3], (E, f, d), jnp.float32) / 2.0,
    )
    tasks, routed = route_to_tasks(idx, gates, E, bt=bt)
    state = make_queue_state(tasks, P, n_queues=E, partition="owner")
    return x, w, bt, tasks, routed, state


def _moe_launch(x, routed, w, bt, policy="cost", steal_run_cap=1):
    def launch(state, *, rounds, out, mult, fault_plan):
        return run_moe_schedule(
            state, x, routed.tok_idx, *w, bt=bt, steal=True,
            steal_policy=policy, rounds=rounds, out=out,
            mult=None if mult is None else jnp.asarray(mult),
            steal_run_cap=steal_run_cap, trace=True, fault_plan=fault_plan,
        )
    return launch


def check_moe_chaos(seed, policy="cost", steal_run_cap=1):
    """Any seeded plan through the moe megakernel: checker-clean, and the
    faulted accumulation is the BITWISE float replay of the fault-free
    output times the multiplicity (moe rows are single-source)."""
    x, w, bt, tasks, routed, state = _moe_problem(seed)
    plan = FaultPlan.from_seed(seed, n_programs=P)
    rounds = default_rounds(state, steal=True, steal_run_cap=steal_run_cap)
    oracle = run_moe_schedule(
        copy_state(state), x, routed.tok_idx, *w, bt=bt, steal=True,
        steal_policy=policy, rounds=rounds, steal_run_cap=steal_run_cap,
    )
    assert (oracle.mult[: state.n_tasks] == 1).all()

    chaos = run_with_faults(
        state, _moe_launch(x, routed, w, bt, policy,
                           steal_run_cap=steal_run_cap),
        plan, rounds=rounds)
    row_mult = row_divisor(tasks, chaos.res.mult, routed.n_rows)
    report = SafetyChecker().check(
        chaos, n_tasks=state.n_tasks,
        oracle_accumulated=np.asarray(oracle.out), row_mult=row_mult,
    )
    assert report.ok, report.summary()
    assert report.normalized_parity == "bitwise", report.summary()
    # segment structure mirrors the plan: kills, storms, then the final
    # full-budget drain
    kinds = [s.kind for s in chaos.segments]
    assert kinds == (["kill"] * len(plan.kills)
                     + ["storm"] * plan.storms + ["final"])
    return report


def check_attention_chaos(seed):
    """Attention rows are multi-source (several k-tiles each duplicated
    independently), so parity is allclose after multiplicity
    normalization — the same bar the repo's rewind drills use."""
    rng = np.random.RandomState(seed % 2**31)
    lengths = np.array([32, 8, 8, 16])[rng.permutation(4)]
    H, hd, bq, bk = 2, 8, 8, 8
    B, S = len(lengths), int(max(lengths))
    ks = jax.random.split(jax.random.PRNGKey(seed % 997), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, H, S, hd))
    v = jax.random.normal(ks[2], (B, H, S, hd))
    tasks = emit_flash_tasks(lengths, H, bq, bk, causal=True)
    state = make_queue_state(tasks, n_programs=4)
    plan = FaultPlan.from_seed(seed, n_programs=4)
    rounds = default_rounds(state, steal=True)

    def launch(state, *, rounds, out, mult, fault_plan):
        return run_ws_schedule(
            state, q, k, v, causal=True, bq=bq, bk=bk, steal=True,
            rounds=rounds, out=out,
            mult=None if mult is None else jnp.asarray(mult),
            trace=True, fault_plan=fault_plan,
        )

    chaos = run_with_faults(state, launch, plan, rounds=rounds)
    div = multiplicity_divisor(tasks, chaos.res.mult, (B, H, S))
    normalized = np.asarray(chaos.res.out) / np.asarray(div)[..., None]
    report = SafetyChecker().check(
        chaos, n_tasks=state.n_tasks,
        normalized=normalized,
        oracle_normalized=np.asarray(ragged_attention_ref(q, k, v, lengths)),
        rtol=1e-5, atol=1e-5,
    )
    assert report.ok, report.summary()
    assert report.normalized_parity in ("bitwise", "close"), report.summary()
    return report


# -- hypothesis sweeps (deep under --hypothesis-profile=ci) ----------------

if HAVE_HYPOTHESIS:

    @given(seed=st.integers(0, 2**31 - 1))
    def test_moe_chaos_any_plan_is_safe(seed):
        check_moe_chaos(seed)

    @given(seed=st.integers(0, 2**31 - 1))
    def test_attention_chaos_any_plan_is_safe(seed):
        check_attention_chaos(seed)


# -- seeded slices: always run, even without hypothesis --------------------


@pytest.mark.parametrize("seed", range(4))
def test_moe_chaos_seeded(seed):
    check_moe_chaos(seed)


@pytest.mark.parametrize("seed", range(2))
def test_attention_chaos_seeded(seed):
    check_attention_chaos(seed)


@pytest.mark.parametrize("seed", range(2))
def test_moe_chaos_halfrun_seeded(seed):
    """Half-run claims under the full fault battery: kills mid-run, head
    rewinds that re-arm whole claimed runs, garbage advisories — still
    checker-clean with bitwise normalized parity."""
    check_moe_chaos(seed, steal_run_cap=4)


def test_storm_halfrun_produces_real_duplication():
    """A head-rewind storm against run-length claims: the rewound head
    re-arms slots a thief already claimed as part of a run, so the relaunch
    duplicates real work (max_mult ≥ 2) and normalization must still
    recover the fault-free answer bitwise."""
    x, w, bt, tasks, routed, state = _moe_problem(3)
    plan = FaultPlan(seed=3, kills=(1,), storms=1, full_first_storm=True)
    rounds = default_rounds(state, steal=True, steal_run_cap=4)
    oracle = run_moe_schedule(
        copy_state(state), x, routed.tok_idx, *w, bt=bt, steal=True,
        rounds=rounds, steal_run_cap=4,
    )
    chaos = run_with_faults(
        state, _moe_launch(x, routed, w, bt, steal_run_cap=4), plan,
        rounds=rounds)
    report = SafetyChecker().check(
        chaos, n_tasks=state.n_tasks,
        oracle_accumulated=np.asarray(oracle.out),
        row_mult=row_divisor(tasks, chaos.res.mult, routed.n_rows),
    )
    assert report.ok, report.summary()
    assert report.max_mult >= 2, "the full storm re-armed nothing"
    assert report.normalized_parity == "bitwise"


def test_storm_plan_produces_real_duplication():
    """A kill + full storm must actually exercise the multiplicity path
    (max_mult ≥ 2), not vacuously pass an empty drill."""
    x, w, bt, tasks, routed, state = _moe_problem(3)
    plan = FaultPlan(seed=3, kills=(1,), storms=1, full_first_storm=True)
    rounds = default_rounds(state, steal=True)
    oracle = run_moe_schedule(
        copy_state(state), x, routed.tok_idx, *w, bt=bt, steal=True,
        rounds=rounds,
    )
    chaos = run_with_faults(state, _moe_launch(x, routed, w, bt), plan,
                            rounds=rounds)
    report = SafetyChecker().check(
        chaos, n_tasks=state.n_tasks,
        oracle_accumulated=np.asarray(oracle.out),
        row_mult=row_divisor(tasks, chaos.res.mult, routed.n_rows),
    )
    assert report.ok, report.summary()
    assert report.max_mult >= 2, "the full storm re-armed nothing"
    assert report.normalized_parity == "bitwise"


def test_checker_catches_violations():
    """The checker is not a rubber stamp: corrupt a clean run's counters /
    outputs and the matching clause must trip."""
    import dataclasses as dc

    x, w, bt, tasks, routed, state = _moe_problem(1)
    plan = FaultPlan(seed=1, storms=1, full_first_storm=True)
    rounds = default_rounds(state, steal=True)
    oracle = run_moe_schedule(
        copy_state(state), x, routed.tok_idx, *w, bt=bt, steal=True,
        rounds=rounds,
    )
    chaos = run_with_faults(state, _moe_launch(x, routed, w, bt), plan,
                            rounds=rounds)
    checker = SafetyChecker()

    # a lost task: zero one mult counter on the final segment's result
    clean_res = chaos.segments[-1].res
    mult = np.array(clean_res.mult)
    mult[0] = 0
    chaos.segments[-1].res = dc.replace(clean_res, mult=mult)
    rep = checker.check(chaos, n_tasks=state.n_tasks)
    assert not rep.ok
    assert any(v.kind in ("lost-task", "stream-mult-mismatch")
               for v in rep.violations)
    chaos.segments[-1].res = clean_res

    # output corruption: one flipped element must break bitwise parity
    bad_out = np.array(oracle.out)
    bad_out.flat[0] += 1.0
    rep = checker.check(
        chaos, n_tasks=state.n_tasks,
        normalized=bad_out, oracle_normalized=np.asarray(oracle.out),
    )
    assert rep.normalized_parity == "diverged"
    assert any(v.kind == "normalized-parity" for v in rep.violations)


# ---------------------------------------------------------------------------
# fault-off bit-parity: injection is free when off
# ---------------------------------------------------------------------------

_WS_FIELDS = ("out", "mult", "head", "local_head", "taken", "remaining",
              "clock", "work", "steals", "scanned")


def test_fault_plan_none_is_bit_identical():
    x, w, bt, tasks, routed, state = _moe_problem(7)
    rounds = default_rounds(state, steal=True)

    def run(**kw):
        return run_moe_schedule(
            copy_state(state), x, routed.tok_idx, *w, bt=bt, steal=True,
            rounds=rounds, **kw,
        )

    base = run()                       # kwarg omitted entirely
    off_none = run(fault_plan=None)    # explicit None
    off_zero = run(fault_plan=FaultPlan())  # a zero plan
    assert FaultPlan().is_off
    for res in (off_none, off_zero):
        for f in _WS_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(base, f)), np.asarray(getattr(res, f)),
                err_msg=f)


def test_stalled_programs_extract_nothing_before_release():
    """A stall is an initial clock offset: the stalled program's first
    trace event lands at round ≥ its stall, and the drain (with the
    auto-extended budget) still completes exactly once."""
    from repro.wstrace.ring import EV_PROG, EV_ROUND, decode_rings

    lengths = np.array([32, 8, 8, 16])
    H, bq, bk = 2, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    B, S = len(lengths), int(max(lengths))
    q = jax.random.normal(ks[0], (B, H, S, 8))
    k = jax.random.normal(ks[1], (B, H, S, 8))
    v = jax.random.normal(ks[2], (B, H, S, 8))
    tasks = emit_flash_tasks(lengths, H, bq, bk, causal=True)
    state = make_queue_state(tasks, n_programs=4)
    plan = FaultPlan(stalls=(3, 0, 2, 0))
    res = run_ws_schedule(state, q, k, v, causal=True, bq=bq, bk=bk,
                          steal=True, trace=True, fault_plan=plan)
    assert (res.mult[: state.n_tasks] == 1).all(), "stalls must not drop work"
    stream, dropped = decode_rings(res.events, res.ev_cursor)
    assert (np.asarray(dropped) == 0).all()
    for p, stall in enumerate(plan.stalls):
        mine = stream[stream[:, EV_PROG] == p]
        if mine.shape[0]:
            assert int(mine[:, EV_ROUND].min()) >= stall, (
                f"program {p} extracted before its stall {stall} expired")


# ---------------------------------------------------------------------------
# host-shim faults under the adversarial simulator
# ---------------------------------------------------------------------------


def test_host_dropped_advisories_never_block_progress():
    plan = FaultPlan(drop_advisory_every=2)
    q = PallasWSHost(capacity=64, fault_plan=plan)
    for i in range(12):
        q.put(i)
    got = [q.take() for _ in range(6)] + [q.steal(1) for _ in range(6)]
    assert got == list(range(12)), "advisory drops are selection-only"
    assert q.faults_injected["dropped_advisories"] > 0


def test_host_stale_republish_creates_bounded_duplicates():
    """Republishing the pre-claim head after a claim is the §7 stale write:
    a thief may re-claim the slot (multiplicity!) but never the same
    process twice, and FIFO at-least-once still holds."""
    plan = FaultPlan(stale_head_every=1)
    q = PallasWSHost(capacity=64, fault_plan=plan)
    for i in range(4):
        q.put(i)
    a = q.take()          # owner claims slot 0, then republishes head=0
    b = q.steal(1)        # the thief re-claims the re-armed slot 0
    assert a == 0 and b == 0, "stale republish re-armed the claimed slot"
    assert q.faults_injected["stale_republishes"] >= 1
    # the same thief cannot take it a third time (its local bound advanced)
    c = q.steal(1)
    assert c != 0


@pytest.mark.parametrize("seed", range(4))
def test_host_faults_respect_weak_multiplicity(seed):
    import random as _random

    rng = _random.Random(seed)
    schedule = [rng.randrange(4) for _ in range(rng.randrange(50, 300))]
    prog = {0: [("put", i) for i in range(1, 9)] + [("take", None)] * 5}
    for t in (1, 2, 3):
        prog[t] = [("steal", None)] * 5
    plan = FaultPlan(drop_advisory_every=2, stale_head_every=3)
    records = run_program(
        lambda backend: PallasWSHost(backend=backend, capacity=64,
                                     fault_plan=plan),
        prog, schedule,
    )
    check_no_process_duplicates(records)  # weak multiplicity survives faults
    check_no_lost_tasks_fifo(records)     # at-least-once, FIFO prefix


# ---------------------------------------------------------------------------
# serving chaos: crash re-admission, backoff give-up, watchdog fallback
# ---------------------------------------------------------------------------


class SeqLenBatcher:
    """Deterministic greedy-decode stand-in: token k of a request is
    ``len(prompt) + k`` (the total sequence length at emission), so a
    crash-resumed stream — prompt extended by the tokens already emitted,
    budget reduced — continues EXACTLY where the uninterrupted stream
    would be.  Mirrors the engine's admit-emits-first-token contract."""

    def __init__(self, slots=2, cap=64):
        self.B, self.cap = slots, cap
        self.live = [None] * slots

    @property
    def n_live(self):
        return sum(r is not None for r in self.live)

    def admit(self, req):
        if not 0 < len(req.tokens) < self.cap:
            return False
        try:
            slot = self.live.index(None)
        except ValueError:
            return False
        req.out.append(len(req.tokens))  # first token at admit
        self.live[slot] = req
        return True

    def step(self):
        done = []
        for i, r in enumerate(self.live):
            if r is None:
                continue
            if len(r.out) < r.max_new:
                r.out.append(len(r.tokens) + len(r.out))
            if len(r.out) >= r.max_new:
                done.append(r)
                self.live[i] = None
        return done


def _expected_stream(prompt_len, max_new):
    return [prompt_len + i for i in range(max_new)]


def test_replica_crash_readmits_without_duplicate_tokens():
    prompts = {rid: np.arange(3 + rid % 4, dtype=np.int32)
               for rid in range(6)}
    fe = WorkStealingFrontend(
        lambda: SeqLenBatcher(slots=2), n_replicas=2,
        crash_plan=ReplicaCrashPlan({0: 2}),
    )
    for rid, p in prompts.items():
        fe.submit(rid % 2, Request(rid, p, max_new=5))
    completed = fe.run(max_iters=500)
    assert not fe.rejected
    assert set(completed) == set(prompts), "every request completed"
    for rid, r in completed.items():
        assert list(r.out) == _expected_stream(len(prompts[rid]), 5), (
            rid, r.out)
        np.testing.assert_array_equal(np.asarray(r.tokens), prompts[rid])
    assert fe.counters["crashed"] == 1
    # replica 0 had in-flight work at iteration 2: those requests were
    # resumed on the survivor, keyed by rid + tokens-so-far
    assert fe.counters["readmitted"] >= 1
    assert fe.counters["dup_completed"] == 0


def test_replica_crash_on_empty_engine_is_harmless():
    fe = WorkStealingFrontend(
        lambda: SeqLenBatcher(slots=1), n_replicas=2,
        crash_plan=ReplicaCrashPlan({1: 0}),
    )
    fe.submit(0, Request(0, np.array([1, 2], np.int32), max_new=3))
    completed = fe.run(max_iters=100)
    assert set(completed) == {0}
    assert fe.counters["crashed"] == 1
    assert fe.counters["readmitted"] == 0


def test_dead_replica_queue_remains_stealable():
    """The crash kills the engine, not the queue: work submitted to the
    dead replica's queue is stolen and completed by the survivor."""
    fe = WorkStealingFrontend(
        lambda: SeqLenBatcher(slots=2), n_replicas=2,
        crash_plan=ReplicaCrashPlan({0: 0}),
    )
    for rid in range(3):
        fe.submit(0, Request(rid, np.arange(2 + rid, dtype=np.int32),
                             max_new=3))
    completed = fe.run(max_iters=200)
    assert set(completed) == {0, 1, 2}
    for rid, r in completed.items():
        assert list(r.out) == _expected_stream(2 + rid, 3)
    assert fe.counters["stolen"] >= 3, "survivor stole from the dead queue"


def test_transient_admission_backs_off_and_gives_up():
    class Stuck:
        B, cap = 1, 64

        def __init__(self):
            self.live = [None]

        @property
        def n_live(self):
            return 0

        def admit(self, req):
            return False  # transient: the prompt fits, no slot frees up

        def step(self):
            return []

    fe = WorkStealingFrontend(lambda: Stuck(), n_replicas=1,
                              max_admission_retries=4)
    fe.submit(0, Request(0, np.array([1, 2], np.int32), max_new=2))
    completed = fe.run(max_iters=10_000)
    assert not completed
    assert 0 in fe.rejected, "the give-up is surfaced, not silently dropped"
    assert fe.counters["gave_up"] == 1
    assert fe.counters["rejected"] == 1
    # exponential backoff actually waited (2+4+8+16 iterations), and the
    # loop terminated instead of spinning to max_iters
    assert 30 <= fe._iter < 200, fe._iter


def test_permanent_rejection_bypasses_backoff():
    fe = WorkStealingFrontend(lambda: SeqLenBatcher(slots=1, cap=4),
                              n_replicas=1)
    fe.submit(0, Request(0, np.arange(9, dtype=np.int32), max_new=2))
    fe.run(max_iters=50)
    assert 0 in fe.rejected
    assert fe.counters["gave_up"] == 0, "over-capacity is permanent"


# -- watchdog: unified -> split graceful degradation (real smoke model) ----


@pytest.fixture(scope="module")
def smoke_model():
    from repro.configs import get_config
    from repro.models import init_params

    cfg = get_config("llama3.2-3b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _drain(b, reqs, iters=24):
    for r in reqs:
        assert b.admit(r)
    done = []
    for _ in range(iters):
        done += b.step()
        if not b.n_live:
            break
    assert not b.n_live
    return {r.rid: list(r.out) for r in done}


def _watchdog_requests():
    return [
        Request(0, np.array([5, 6, 7, 8], np.int32), max_new=3),
        Request(1, np.array([9, 8, 7], np.int32), max_new=3),
    ]


def test_watchdog_poisoned_logits_fall_back_bitwise(smoke_model):
    """Poisoned (NaN) unified logits: the step is discarded and redone on
    the split path the same step — greedy streams stay identical to the
    fault-free unified run, and the degradations are recorded."""
    params, cfg = smoke_model
    streams = {}
    for label, fp in (("clean", None),
                      ("poisoned", EngineFaultPlan(poison_steps=(0, 2)))):
        b = ContinuousBatcher(params, cfg, slots=2, capacity=32,
                              unified_step=True, fault_plan=fp)
        streams[label] = _drain(b, _watchdog_requests())
        if label == "poisoned":
            kinds = [d["kind"] for d in b.degradations]
            assert kinds == ["non-finite", "non-finite"], b.degradations
            assert b.stats()["degradations"] == {"non-finite": 2}
        else:
            assert b.degradations == []
    assert streams["clean"] == streams["poisoned"]


def test_watchdog_deadline_routes_cooldown_steps_split(smoke_model):
    """A blown step deadline routes the next `watchdog_cooldown` steps
    through the split path directly — same tokens, one recorded
    degradation event."""
    params, cfg = smoke_model
    streams = {}
    # the deadline sits far above honest interpret-mode step times (~1-2s)
    # so only the injected 1e9 s latency can breach it
    for label, kw in (
        ("clean", {}),
        ("slow", dict(step_deadline_s=120.0, watchdog_cooldown=2,
                      fault_plan=EngineFaultPlan(slow_steps=(1,),
                                                 added_latency_s=1e9))),
    ):
        b = ContinuousBatcher(params, cfg, slots=2, capacity=32,
                              unified_step=True, **kw)
        streams[label] = _drain(b, _watchdog_requests())
        if label == "slow":
            kinds = [d["kind"] for d in b.degradations]
            assert kinds == ["deadline"], b.degradations
            assert b.degradations[0]["step"] == 1
    assert streams["clean"] == streams["slow"]


# ---------------------------------------------------------------------------
# checkpoint crash-mid-write drill (satellite b)
# ---------------------------------------------------------------------------


def test_checkpoint_crash_mid_publish_never_tears(tmp_path, monkeypatch):
    from repro.checkpoint import checkpoint as ckpt

    d = str(tmp_path)
    tree = {"w": np.arange(4.0), "b": np.zeros(2)}
    ckpt.save(d, 1, tree)
    assert ckpt.latest_step(d) == 1

    # crash exactly at the publish rename: the new step must never become
    # visible, the old step must never be damaged
    def crash(src, dst):
        raise OSError("simulated crash mid-publish")

    monkeypatch.setattr(ckpt.os, "rename", crash)
    with pytest.raises(OSError):
        ckpt.save(d, 2, {"w": np.arange(4.0) + 1, "b": np.ones(2)})
    assert ckpt.latest_step(d) == 1, "latest_step torn by a failed publish"

    # a crash that leaves a stale tmp dir behind (no cleanup ran at all):
    # restore/latest_step must ignore it even though it holds a manifest
    stale = tmp_path / "step_00000009.tmp-dead"
    stale.mkdir()
    (stale / "manifest.json").write_text("{}")
    assert ckpt.latest_step(d) == 1
    monkeypatch.undo()

    restored, step = ckpt.restore(d, tree)
    assert step == 1
    np.testing.assert_array_equal(restored["w"], tree["w"])


def test_async_checkpointer_surfaces_crash(tmp_path, monkeypatch):
    from repro.checkpoint import checkpoint as ckpt

    d = str(tmp_path)
    ckpt.save(d, 1, {"w": np.zeros(3)})
    ac = ckpt.AsyncCheckpointer(d)

    def crash(src, dst):
        raise OSError("simulated crash in the background writer")

    monkeypatch.setattr(ckpt.os, "rename", crash)
    ac.save(2, {"w": np.ones(3)})
    with pytest.raises(OSError):
        ac.wait()  # the error is surfaced, not swallowed
    assert ckpt.latest_step(d) == 1

"""Adversarial conformance suite: host Put vs traced Put, one protocol.

The dropless dispatch now has two queue builders — ``route_to_tasks`` +
``make_queue_state`` (host-side numpy, compact padding) and
``route_to_tasks_jax`` + ``make_queue_state_jax`` (jit-compatible, static
worst-case padding with live masks).  Correctness under duplicated steals is
a *scheduling-order* property, so happy-path parity is not enough: for ANY
routing, and ANY adversarial schedule (steals via per-expert queues >
programs, head rewinds between launches, wiped per-program bounds,
under-provisioned partial relaunches that duplicate extractions), the two
builders must

1. lay out **identical Fig. 7 queue arrays** — identical live task prefixes
   per queue (op/expert/row_len/cost fields equal, ``row_start`` equal
   relative to each layout's expert offsets, ``tid`` equal under the static
   remap ``(e, i) ↦ e·tiles_per_expert + i``), identical tails, all-⊥
   suffixes, all-(-1) announcement rows;
2. drive the megakernel through **identical extraction sequences** — equal
   heads, clocks, work/steal counters, and per-tile multiplicities after
   every adversarial relaunch (the scan only sees queue contents, so layout
   conformance must imply schedule conformance);
3. produce **bit-identical multiplicity-normalized per-row outputs** (same
   tile membership → same kernel arithmetic → same floats), and combines
   that both match the ``moe_ffn_nodrop_ref``-style no-drop oracle.

The traced builder is additionally certified shape-stable: building under
``jit`` and eagerly yields bit-identical arrays.

The checks are plain functions over a ``draw_int``/``draw_bool`` source:
hypothesis drives them through arbitrary schedules (deep under the CI
``--hypothesis-profile=ci`` job), and seeded deterministic slices always
run so the tier-1 smoke keeps coverage even without hypothesis installed.
"""

import random

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

try:
    from hypothesis import given, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.moe_ws.dispatch import (  # noqa: E402
    divisor_from_tiles,
    expert_queue_candidates,
    expert_rounds_bound,
    route_to_tasks,
    route_to_tasks_jax,
    route_to_tasks_pool_jax,
    row_divisor,
)
from repro.moe_ws.expert_kernel import run_moe_schedule  # noqa: E402
from repro.moe_ws.layer import expert_ffn_nodrop_ref  # noqa: E402
from repro.pallas_ws.queues import (  # noqa: E402
    make_pool_queue_state_jax,
    make_queue_state,
    make_queue_state_jax,
    owner_queue_candidates,
)
from repro.pallas_ws.tasks import (  # noqa: E402
    BOTTOM,
    F_COST,
    F_OP,
    F_RL,
    F_RS,
    F_TID,
    emit_decode_tasks,
)

# shared fault-drill mechanics (repro.chaos via conftest)
from conftest import apply_rewind, drawn_rewind, resume_state  # noqa: E402

P = 3  # programs: fewer than most drawn expert counts, so thieves roam


def _cdiv(a, b):
    return -(-a // b)


def _routing_from(draw_int):
    E = draw_int(2, 5)
    T = draw_int(1, 10)
    k = draw_int(1, min(2, E))
    bt = (2, 4)[draw_int(0, 1)]
    seed = draw_int(0, 2**16)
    rng = np.random.RandomState(seed)
    idx = np.stack([rng.choice(E, k, replace=False) for _ in range(T)])
    gates = rng.uniform(0.1, 1.0, (T, k)).astype(np.float32)
    gates /= gates.sum(1, keepdims=True)
    return E, T, k, bt, seed, idx, gates


def _host_state(idx, gates, E, bt):
    tasks, routed = route_to_tasks(idx, gates, E, bt=bt)
    state = make_queue_state(tasks, P, n_queues=E, partition="owner")
    return tasks, routed, state


def _traced_state(idx, gates, E, bt, *, under_jit):
    def build(i, g):
        records, live, routed = route_to_tasks_jax(i, g, E, bt=bt)
        cand, cand_live = expert_queue_candidates(records, live, E)
        return records, live, routed, cand, cand_live

    if under_jit:
        build = jax.jit(build)
    records, live, routed, cand, cand_live = build(idx, gates)
    state = make_queue_state_jax(
        cand, cand_live, P, n_tasks=records.shape[0] * records.shape[1]
    )
    # concrete jnp -> numpy so adversarial drills can mutate heads/bounds
    for f in ("tasks", "head", "tail", "local_head", "taken", "remaining"):
        setattr(state, f, np.asarray(getattr(state, f)))
    return np.asarray(records), np.asarray(live), routed, state


def _pool_state(idx, gates, E, bt, *, under_jit):
    """Shared-pool traced Put (route_to_tasks_pool_jax), numpy-ified for the
    adversarial drills."""

    def build(i, g):
        return route_to_tasks_pool_jax(i, g, E, bt=bt)

    if under_jit:
        build = jax.jit(build)
    records, tail, pool_off, routed = build(idx, gates)
    state = make_pool_queue_state_jax(
        records, tail, pool_off, routed.loads, P, n_tasks=records.shape[0]
    )
    for f in ("tasks", "head", "tail", "local_head", "taken", "remaining",
              "pool_off"):
        setattr(state, f, np.asarray(getattr(state, f)))
    return np.asarray(records), routed, state


def _tid_remap(loads, bt, tiles_per_e, layout="padded"):
    """Host tid (expert-major sequential over live tiles) -> traced tid.

    Padded layout: static ``e·tiles_per_e + i``.  Pool layout: dynamic pool
    slot ``toff[e] + i`` with ``toff`` the cumsum of per-expert live tile
    counts (recomputed host-side from the loads)."""
    remap = []
    if layout == "pool":
        toff = 0
        for load in loads:
            n_e = _cdiv(int(load), bt)
            remap.extend(toff + i for i in range(n_e))
            toff += n_e
    else:
        for e, load in enumerate(loads):
            remap.extend(e * tiles_per_e + i for i in range(_cdiv(int(load), bt)))
    return np.asarray(remap, dtype=np.int64)


# ---------------------------------------------------------------------------
# check 1: Fig. 7 layout conformance
# ---------------------------------------------------------------------------


def check_fig7_layout_conformance(draw_int):
    E, T, k, bt, seed, idx, gates = _routing_from(draw_int)
    tasks, routed_h, sh = _host_state(idx, gates, E, bt)
    rec_j, live_j, routed_j, sj = _traced_state(idx, gates, E, bt, under_jit=True)
    rec_e, live_e, routed_e, se = _traced_state(idx, gates, E, bt, under_jit=False)

    # jit-built == eager-built, bit for bit
    np.testing.assert_array_equal(rec_j, rec_e)
    np.testing.assert_array_equal(live_j, live_e)
    np.testing.assert_array_equal(sj.tasks, se.tasks)
    np.testing.assert_array_equal(
        np.asarray(routed_j.tok_idx), np.asarray(routed_e.tok_idx)
    )
    np.testing.assert_array_equal(
        np.asarray(routed_j.gates), np.asarray(routed_e.gates)
    )

    loads = np.bincount(idx.reshape(-1), minlength=E)
    np.testing.assert_array_equal(np.asarray(routed_j.loads), loads)
    np.testing.assert_array_equal(routed_h.loads, loads)

    tiles_per_e = _cdiv(min(T, T * k), bt)  # top-k: distinct experts/token
    off_h = routed_h.expert_off
    off_j = np.asarray(routed_j.expert_off)
    assert sh.n_queues == sj.n_queues == E
    np.testing.assert_array_equal(sj.head, np.zeros(E))
    assert (sj.taken == -1).all() and (sh.taken == -1).all()

    for e in range(E):
        n_e = _cdiv(int(loads[e]), bt)
        # identical tails: the owner's Put counter
        assert int(sh.tail[e]) == int(sj.tail[e]) == n_e
        h_rec = sh.tasks[e, :n_e]
        j_rec = sj.tasks[e, :n_e]
        # family-agnostic fields + operands, compared in queue order
        np.testing.assert_array_equal(h_rec[:, F_OP], j_rec[:, F_OP])
        np.testing.assert_array_equal(h_rec[:, 1], j_rec[:, 1])  # expert
        np.testing.assert_array_equal(h_rec[:, F_RL], j_rec[:, F_RL])
        np.testing.assert_array_equal(h_rec[:, F_COST], j_rec[:, F_COST])
        # row_start agrees relative to each layout's expert offset
        np.testing.assert_array_equal(
            h_rec[:, F_RS] - off_h[e], j_rec[:, F_RS] - off_j[e]
        )
        # traced tid is the static (e, i) code
        np.testing.assert_array_equal(
            j_rec[:, F_TID], e * tiles_per_e + np.arange(n_e)
        )
        # whole suffix is ⊥ in both layouts
        assert (sh.tasks[e, n_e:, F_OP] == BOTTOM).all()
        assert (sj.tasks[e, n_e:, F_OP] == BOTTOM).all()
        # routed rows carry the same tokens/gates at remapped positions
        ln = int(loads[e])
        np.testing.assert_array_equal(
            np.asarray(routed_h.tok_idx)[off_h[e]: off_h[e] + ln],
            np.asarray(routed_j.tok_idx)[off_j[e]: off_j[e] + ln],
        )
        np.testing.assert_array_equal(
            np.asarray(routed_h.gates)[off_h[e]: off_h[e] + ln],
            np.asarray(routed_j.gates)[off_j[e]: off_j[e] + ln],
        )
    # dead rows of the static layout are inert: gate 0 (token 0 by init)
    live_rows = np.zeros(routed_j.n_rows, dtype=bool)
    for e in range(E):
        live_rows[off_j[e]: off_j[e] + int(loads[e])] = True
    assert (np.asarray(routed_j.gates)[~live_rows] == 0).all()


def check_pool_layout_conformance(draw_int):
    """Shared-pool layout (DESIGN.md §3.6): queue ``e``'s pool segment must
    hold exactly the host layout's live records for expert ``e``, in queue
    order, with ``tid == pool slot``, an all-⊥ pool suffix, and the routed
    rows at the compact dynamic offsets."""
    E, T, k, bt, seed, idx, gates = _routing_from(draw_int)
    tasks, routed_h, sh = _host_state(idx, gates, E, bt)
    rec_j, routed_j, sj = _pool_state(idx, gates, E, bt, under_jit=True)
    rec_e, routed_e, se = _pool_state(idx, gates, E, bt, under_jit=False)

    # jit-built == eager-built, bit for bit
    np.testing.assert_array_equal(rec_j, rec_e)
    np.testing.assert_array_equal(sj.tasks, se.tasks)
    np.testing.assert_array_equal(sj.pool_off, se.pool_off)
    np.testing.assert_array_equal(
        np.asarray(routed_j.tok_idx), np.asarray(routed_e.tok_idx)
    )
    np.testing.assert_array_equal(
        np.asarray(routed_j.gates), np.asarray(routed_e.gates)
    )

    loads = np.bincount(idx.reshape(-1), minlength=E)
    n_tiles = -(-loads // bt)
    toff = np.concatenate([[0], np.cumsum(n_tiles)])
    pool_tiles = _cdiv(T * k, bt) + E
    assert sj.tasks.shape == (pool_tiles, 8)
    np.testing.assert_array_equal(sj.pool_off, toff)
    np.testing.assert_array_equal(sj.tail, n_tiles)
    np.testing.assert_array_equal(sj.remaining, loads)
    np.testing.assert_array_equal(np.asarray(routed_j.loads), loads)
    assert (sj.taken == -1).all() and sj.taken.shape == (pool_tiles,)
    assert routed_j.n_rows == pool_tiles * bt

    off_h = routed_h.expert_off
    off_j = np.asarray(routed_j.expert_off)
    np.testing.assert_array_equal(off_j, toff * bt)
    for e in range(E):
        n_e = int(n_tiles[e])
        assert int(sh.tail[e]) == n_e  # host agrees on live tile counts
        h_rec = sh.tasks[e, :n_e]
        j_rec = sj.tasks[toff[e]: toff[e] + n_e]
        np.testing.assert_array_equal(h_rec[:, F_OP], j_rec[:, F_OP])
        np.testing.assert_array_equal(h_rec[:, 1], j_rec[:, 1])  # expert
        np.testing.assert_array_equal(h_rec[:, F_RL], j_rec[:, F_RL])
        np.testing.assert_array_equal(h_rec[:, F_COST], j_rec[:, F_COST])
        # row_start agrees relative to each layout's expert offset
        np.testing.assert_array_equal(
            h_rec[:, F_RS] - off_h[e], j_rec[:, F_RS] - off_j[e]
        )
        # pool tid IS the pool slot index (mult needs no remap table)
        np.testing.assert_array_equal(
            j_rec[:, F_TID], toff[e] + np.arange(n_e)
        )
        # routed rows carry the same tokens/gates at the compact offsets
        ln = int(loads[e])
        np.testing.assert_array_equal(
            np.asarray(routed_h.tok_idx)[off_h[e]: off_h[e] + ln],
            np.asarray(routed_j.tok_idx)[off_j[e]: off_j[e] + ln],
        )
        np.testing.assert_array_equal(
            np.asarray(routed_h.gates)[off_h[e]: off_h[e] + ln],
            np.asarray(routed_j.gates)[off_j[e]: off_j[e] + ln],
        )
    # the pool suffix past the last live tile is all-⊥ with gate-0 rows
    assert (sj.tasks[toff[E]:, F_OP] == BOTTOM).all()
    live_rows = np.zeros(routed_j.n_rows, dtype=bool)
    for e in range(E):
        live_rows[off_j[e]: off_j[e] + int(loads[e])] = True
    assert (np.asarray(routed_j.gates)[~live_rows] == 0).all()
    # compactness: the whole point — pool never exceeds ceil(Tk/bt) + E
    # tiles, vs the padded layout's E · ceil(min(T, Tk)/bt)
    assert toff[E] <= pool_tiles


# ---------------------------------------------------------------------------
# checks 2+3: adversarial schedules — identical runs, exact combines
# ---------------------------------------------------------------------------


def check_adversarial_schedules(draw_int, draw_bool, steal_policy="cost",
                                layout="padded", steal_run_cap=1):
    E, T, k, bt, seed, idx, gates = _routing_from(draw_int)
    d, f = 4, 8
    ks = jax.random.split(jax.random.PRNGKey(seed % 997), 4)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    w = (
        jax.random.normal(ks[1], (E, d, f), jnp.float32) / 2.0,
        jax.random.normal(ks[2], (E, d, f), jnp.float32) / 2.0,
        jax.random.normal(ks[3], (E, f, d), jnp.float32) / 2.0,
    )
    tasks, routed_h, sh = _host_state(idx, gates, E, bt)
    if layout == "pool":
        _, routed_j, sj = _pool_state(idx, gates, E, bt, under_jit=True)
    else:
        _, _, routed_j, sj = _traced_state(idx, gates, E, bt, under_jit=True)

    loads = np.bincount(idx.reshape(-1), minlength=E)
    tiles_per_e = _cdiv(min(T, T * k), bt)  # top-k: distinct experts/token
    remap = _tid_remap(loads, bt, tiles_per_e, layout)
    rounds = expert_rounds_bound(T * k, bt, E, P, steal=True,
                                 steal_run_cap=steal_run_cap)

    def launch(state, tok_idx, out=None, mult=None, r=rounds):
        return run_moe_schedule(
            state, x, jnp.asarray(tok_idx), *w, bt=bt, steal=True,
            steal_policy=steal_policy, rounds=r, out=out, mult=mult,
            steal_run_cap=steal_run_cap, interpret=True,
        )

    res_h = launch(sh, routed_h.tok_idx)
    res_j = launch(sj, routed_j.tok_idx)

    n_relaunches = draw_int(1, 2)
    for step in range(n_relaunches):
        # identical adversarial staleness on both sides: ONE drawn
        # RewindSpec (targets read from the host heads — they agree, so
        # the spec is valid for both) replayed onto each layout-parity
        # state via the shared repro.chaos drill
        np.testing.assert_array_equal(res_h.head, res_j.head)
        spec = drawn_rewind(sh, res_h, draw_int, draw_bool,
                            heads=res_h.head)
        resume_state(sj, res_j)
        apply_rewind(sj, spec)
        # sometimes under-provision the relaunch: partial drains leave
        # uneven duplicate counts behind — the combine must still be exact
        r = draw_int(1, rounds)
        res_h = launch(sh, routed_h.tok_idx, out=res_h.out,
                       mult=jnp.asarray(res_h.mult), r=r)
        res_j = launch(sj, routed_j.tok_idx, out=res_j.out,
                       mult=jnp.asarray(res_j.mult), r=r)

    # identical extraction behavior, slot for slot
    np.testing.assert_array_equal(res_h.head, res_j.head)
    np.testing.assert_array_equal(res_h.clock, res_j.clock)
    np.testing.assert_array_equal(res_h.work, res_j.work)
    np.testing.assert_array_equal(res_h.steals, res_j.steals)
    mult_h = res_h.mult[: len(tasks)]
    np.testing.assert_array_equal(mult_h, res_j.mult[remap])
    # traced tiles outside the live remap never execute
    n_mult_j = res_j.mult.shape[0]
    dead = np.setdiff1d(np.arange(n_mult_j), remap)
    assert (res_j.mult[dead] == 0).all()
    assert (mult_h >= 1).all(), "first launch drained: dropless"

    # bit-identical multiplicity-normalized per-row outputs
    div_h = row_divisor(tasks, res_h.mult, routed_h.n_rows)
    starts_j = jnp.arange(n_mult_j, dtype=jnp.int32) * bt
    div_j = np.asarray(
        divisor_from_tiles(starts_j, bt, res_j.mult, routed_j.n_rows)
    )
    yr_h = np.asarray(res_h.out) / div_h[:, None]
    yr_j = np.asarray(res_j.out) / div_j[:, None]
    off_h, off_j = routed_h.expert_off, np.asarray(routed_j.expert_off)
    for e in range(E):
        ln = int(loads[e])
        np.testing.assert_array_equal(
            yr_h[off_h[e]: off_h[e] + ln], yr_j[off_j[e]: off_j[e] + ln]
        )

    # both combines reproduce the no-drop oracle
    ref = np.asarray(expert_ffn_nodrop_ref(idx, gates, x, *w))
    for routed, yr in ((routed_h, yr_h), (routed_j, yr_j)):
        y = np.zeros((T, d), np.float32)
        np.add.at(
            y, np.asarray(routed.tok_idx),
            np.asarray(routed.gates)[:, None] * yr,
        )
        np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# decode family: traced candidates compact to the host emitter's queues
# ---------------------------------------------------------------------------


def check_decode_layout_conformance(draw_int):
    from repro.pallas_ws.ragged import emit_decode_tasks_jax

    B = draw_int(1, 5)
    H = draw_int(1, 3)
    bk = (4, 8)[draw_int(0, 1)]
    nq = draw_int(1, 4)
    lengths = np.asarray([draw_int(0, 32) for _ in range(B)], dtype=np.int64)

    tasks = emit_decode_tasks(lengths, H, bk)
    sh = make_queue_state(tasks, P, n_queues=nq, partition="batch")

    records, live = jax.jit(
        lambda ln: emit_decode_tasks_jax(ln, H, bk)
    )(jnp.asarray(lengths))
    cand, cand_live = owner_queue_candidates(records, live, nq)
    sj = make_queue_state_jax(cand, cand_live, P, n_tasks=B * H)

    sj_tasks = np.asarray(sj.tasks)
    sj_tail = np.asarray(sj.tail)
    for q in range(nq):
        n_q = int(sh.tail[q])
        assert int(sj_tail[q]) == n_q
        # identical live records except tid (host: dense sequential; traced:
        # static b·H + h) — the task payload the kernel reads is equal
        h_rec = sh.tasks[q, :n_q]
        j_rec = sj_tasks[q, :n_q]
        cols = [c for c in range(h_rec.shape[1]) if c != F_TID]
        np.testing.assert_array_equal(h_rec[:, cols], j_rec[:, cols])
        # traced tid encodes (b, h) statically
        np.testing.assert_array_equal(
            j_rec[:, F_TID], j_rec[:, 1] * H + j_rec[:, 2]
        )
        assert (sj_tasks[q, n_q:, F_OP] == BOTTOM).all()
        assert (sh.tasks[q, n_q:, F_OP] == BOTTOM).all()


# ---------------------------------------------------------------------------
# hypothesis drivers (depth set by the conftest profile; the CI conformance
# job runs --hypothesis-profile=ci for the deep derandomized sweep)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @given(data=st.data())
    def test_fig7_layout_conformance(data):
        check_fig7_layout_conformance(
            lambda lo, hi: data.draw(st.integers(lo, hi))
        )

    @given(data=st.data())
    def test_pool_layout_conformance(data):
        check_pool_layout_conformance(
            lambda lo, hi: data.draw(st.integers(lo, hi))
        )

    @given(data=st.data())
    def test_adversarial_schedules_identical_runs_and_exact_combines(data):
        policy = data.draw(st.sampled_from(["cost", "scan"]))
        # half-run claims require the cost policy (victim bounds feed the
        # run length); cap=1 keeps scan-policy draws on the per-slot path
        cap = data.draw(st.sampled_from([1, 2, 4])) if policy == "cost" else 1
        check_adversarial_schedules(
            lambda lo, hi: data.draw(st.integers(lo, hi)),
            lambda: data.draw(st.booleans()),
            steal_policy=policy,
            layout=data.draw(st.sampled_from(["padded", "pool"])),
            steal_run_cap=cap,
        )

    @given(data=st.data())
    def test_decode_family_layout_conformance(data):
        check_decode_layout_conformance(
            lambda lo, hi: data.draw(st.integers(lo, hi))
        )


# ---------------------------------------------------------------------------
# deterministic seeded slices — always run (no hypothesis needed), so the
# tier-1 smoke keeps conformance coverage in bare environments
# ---------------------------------------------------------------------------


def _rng_draws(seed):
    rng = random.Random(seed)
    return (lambda lo, hi: rng.randint(lo, hi)), (lambda: rng.random() < 0.5)


@pytest.mark.parametrize("seed", range(4))
def test_fig7_layout_conformance_seeded(seed):
    draw_int, _ = _rng_draws(seed)
    check_fig7_layout_conformance(draw_int)


@pytest.mark.parametrize("seed", range(4))
def test_pool_layout_conformance_seeded(seed):
    draw_int, _ = _rng_draws(500 + seed)
    check_pool_layout_conformance(draw_int)


@pytest.mark.parametrize("steal_policy", ["cost", "scan"])
@pytest.mark.parametrize("layout", ["padded", "pool"])
@pytest.mark.parametrize("seed", range(2))
def test_adversarial_schedules_seeded(seed, layout, steal_policy):
    draw_int, draw_bool = _rng_draws(100 + seed)
    check_adversarial_schedules(draw_int, draw_bool,
                                steal_policy=steal_policy, layout=layout)


@pytest.mark.parametrize("layout", ["padded", "pool"])
@pytest.mark.parametrize("seed", range(2))
def test_adversarial_schedules_halfrun_seeded(seed, layout):
    """The conformance contract survives run-length claims: padded and pool
    layouts stay slot-for-slot identical under steal_run_cap=4, including
    through drawn head-rewind relaunches."""
    draw_int, draw_bool = _rng_draws(900 + seed)
    check_adversarial_schedules(draw_int, draw_bool, steal_policy="cost",
                                layout=layout, steal_run_cap=4)


@pytest.mark.parametrize("seed", range(4))
def test_decode_layout_conformance_seeded(seed):
    draw_int, _ = _rng_draws(200 + seed)
    check_decode_layout_conformance(draw_int)


# ---------------------------------------------------------------------------
# mesh conformance (DESIGN.md §7): the cross-device dispatch must be
# bit-identical (after multiplicity normalization) to the single-device
# no-drop oracle — for skewed/empty-expert routings, under arbitrarily
# stale advisories, and under adversarial steal plans whose duplication is
# a power of two (odd duplication counts fall back to allclose: fl(3ŷ)/3
# is not ŷ in float32, and no scheduler controls that).
#
# The emulation path (`emulate_mesh_dispatch`: same protocol, collectives
# replaced by stacking, certified bitwise-equal to the shard_map path by
# test_mesh_shard_map_matches_emulation) runs on one device, so the whole
# suite is tier-1; the real-collective path additionally runs via the D=1
# degenerate mesh, a skip-if-single-device multi-device case, and the
# forced-8-device subprocess selfcheck.
# ---------------------------------------------------------------------------

import os  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402

from repro.mesh_ws import (  # noqa: E402
    StealPlan,
    emulate_mesh_dispatch,
    expert_ffn_mesh_ws,
    expert_shard,
    route_local_pool_jax,
)

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_ENV = dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src"))


def _mesh_problem_from(draw_int):
    """Draw a mesh-sharded MoE problem: device count, expert shard, routing
    (uniform / hot-shard skewed / empty-expert), inputs and weights."""
    D = (2, 4)[draw_int(0, 1)]
    El = draw_int(1, 2)
    E = D * El
    T = draw_int(1, 10)
    k = draw_int(1, min(2, E))
    bt = (2, 4)[draw_int(0, 1)]
    seed = draw_int(0, 2**16)
    rng = np.random.RandomState(seed)
    shape = draw_int(0, 2)
    if shape == 0:        # uniform
        idx = np.stack([rng.choice(E, k, replace=False) for _ in range(T)])
    elif shape == 1:      # hot: mass on device 0's shard (the steal driver)
        hot = max(k, El)
        idx = np.stack([
            rng.choice(hot if rng.rand() < 0.75 else E, k, replace=False)
            for _ in range(T)
        ])
    else:                 # empty experts: restrict to a drawn subset
        alive = rng.choice(E, max(k, draw_int(k, E)), replace=False)
        idx = np.stack([rng.choice(alive, k, replace=False) for _ in range(T)])
    idx = idx.astype(np.int32)
    gates = rng.uniform(0.1, 1.0, (T, k)).astype(np.float32)
    gates /= gates.sum(1, keepdims=True)
    d, f = 4, 8
    x = rng.randn(T, d).astype(np.float32)
    wg = (0.1 * rng.randn(E, d, f)).astype(np.float32)
    wu = (0.1 * rng.randn(E, d, f)).astype(np.float32)
    wd = (0.1 * rng.randn(E, f, d)).astype(np.float32)
    return D, E, T, k, bt, idx, gates, x, wg, wu, wd


def _assert_mesh_coverage(em):
    """Every live tile of every device executed at least once."""
    for tail, mult in zip(em.tails, em.mult_total):
        n_live = int(np.asarray(tail).sum())
        if n_live:
            assert (np.asarray(mult)[:n_live] >= 1).all()


def check_mesh_oracle_conformance(draw_int):
    """Clean runs: the emulated mesh dispatch is bit-identical to the
    no-drop oracle for any drawn routing/skew/device count."""
    D, E, T, k, bt, idx, gates, x, wg, wu, wd = _mesh_problem_from(draw_int)
    em = emulate_mesh_dispatch(
        x, idx, gates, wg, wu, wd, n_devices=D, bt=bt, n_programs=2,
    )
    ref = expert_ffn_nodrop_ref(idx, gates, x, wg, wu, wd)
    np.testing.assert_array_equal(np.asarray(em.y), np.asarray(ref))
    _assert_mesh_coverage(em)


def check_mesh_stale_advisories(draw_int):
    """Arbitrarily corrupt exchanged advisories (claiming load where none
    remains, hiding real load, everyone-idle): victim ranking degrades but
    the answer stays bit-identical — segment bounds come from the gathered
    head/tail snapshots, never from the advisory."""
    D, E, T, k, bt, idx, gates, x, wg, wu, wd = _mesh_problem_from(draw_int)
    adv = np.array([draw_int(0, T * k) for _ in range(D)], np.int32)
    em = emulate_mesh_dispatch(
        x, idx, gates, wg, wu, wd, n_devices=D, bt=bt, n_programs=2,
        adv_override=adv,
    )
    ref = expert_ffn_nodrop_ref(idx, gates, x, wg, wu, wd)
    np.testing.assert_array_equal(np.asarray(em.y), np.asarray(ref))
    _assert_mesh_coverage(em)


def check_mesh_adversarial_plans(draw_int, draw_bool):
    """Forced steal plans: a thief pulls a drawn segment of a victim's pool
    while the victim's donation accounting is adversarially *withheld*
    (``aware=False`` keeps the victim's full tails), so the segment
    executes on both devices — cross-device duplication only the
    multiplicity normalization can absorb.  A second thief may duplicate
    the same segment.  Total per-tile counts are 1/2/4 with an aware
    victim, 2/3 with an unaware one — power-of-two counts must stay
    bitwise, count 3 falls back to allclose."""
    D, E, T, k, bt, idx, gates, x, wg, wu, wd = _mesh_problem_from(draw_int)
    El = expert_shard(E, D)
    puts = [
        route_local_pool_jax(idx, gates, E, m * El, El, bt)
        for m in range(D)
    ]
    tails = [np.asarray(p.tail, np.int32) for p in puts]

    victim = draw_int(0, D - 1)
    thieves = [m for m in range(D) if m != victim]
    thief = thieves[draw_int(0, len(thieves) - 1)]
    double = draw_bool() and len(thieves) > 1
    thief2 = next(m for m in thieves if m != thief) if double else None
    aware = draw_bool()

    # drawn per-queue segment of the victim's live tiles
    s_head = np.zeros(El, np.int32)
    s_tail = np.zeros(El, np.int32)
    for q in range(El):
        if tails[victim][q]:
            s_head[q] = draw_int(0, int(tails[victim][q]) - 1)
            s_tail[q] = draw_int(int(s_head[q]), int(tails[victim][q]))
    take = int((s_tail - s_head).sum())

    def plan(m):
        new_tail = jnp.asarray(tails[m])
        stole = m == thief or (double and m == thief2)
        if m == victim and aware:
            new_tail = jnp.asarray(s_head)  # victim truncates to the donation
        return StealPlan(
            victim=jnp.int32(victim), stole=jnp.bool_(stole),
            s_head=jnp.asarray(s_head if stole else np.zeros(El, np.int32)),
            s_tail=jnp.asarray(s_tail if stole else np.zeros(El, np.int32)),
            new_tail=new_tail, take_tiles=jnp.int32(take if stole else 0),
        )

    em = emulate_mesh_dispatch(
        x, idx, gates, wg, wu, wd, n_devices=D, bt=bt, n_programs=2,
        plans_override=[plan(m) for m in range(D)],
    )
    ref = expert_ffn_nodrop_ref(idx, gates, x, wg, wu, wd)
    # aware victim: the stolen segment runs once (or per extra thief) on top
    # of nothing local -> counts {1, 2}; unaware: {2, 3} with a double thief
    mults = np.concatenate([np.asarray(m) for m in em.mult_total])
    power_of_two = ((mults & (mults - 1)) == 0).all()  # 0 and 2^k pass
    if aware and not double:
        _assert_mesh_coverage(em)
    if power_of_two:
        np.testing.assert_array_equal(np.asarray(em.y), np.asarray(ref))
    else:
        np.testing.assert_allclose(
            np.asarray(em.y), np.asarray(ref), rtol=1e-5, atol=1e-6
        )


def check_mesh_shard_map_conformance(draw_int):
    """The real-collective path (shard_map + ppermute/psum) over however
    many forced host devices this process has: bit-identical to both the
    oracle and the emulation."""
    import jax as _jax

    from repro.launch.mesh import make_expert_mesh

    avail = len(_jax.devices())
    if avail < 2:
        pytest.skip("single-device process; mesh CI job runs this at D=8")
    D, E, T, k, bt, idx, gates, x, wg, wu, wd = _mesh_problem_from(draw_int)
    while D > avail:
        D //= 2  # E = D_drawn · El stays divisible by any halving of D
    mesh = make_expert_mesh(E, D)
    y = expert_ffn_mesh_ws(
        idx, gates, x, wg, wu, wd, mesh=mesh, bt=bt, n_programs=2,
    )
    em = emulate_mesh_dispatch(
        x, idx, gates, wg, wu, wd, n_devices=D, bt=bt, n_programs=2,
    )
    ref = expert_ffn_nodrop_ref(idx, gates, x, wg, wu, wd)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(em.y))


if HAVE_HYPOTHESIS:

    @given(data=st.data())
    def test_mesh_oracle_conformance(data):
        check_mesh_oracle_conformance(
            lambda lo, hi: data.draw(st.integers(lo, hi))
        )

    @given(data=st.data())
    def test_mesh_stale_advisories(data):
        check_mesh_stale_advisories(
            lambda lo, hi: data.draw(st.integers(lo, hi))
        )

    @given(data=st.data())
    def test_mesh_adversarial_steal_plans(data):
        check_mesh_adversarial_plans(
            lambda lo, hi: data.draw(st.integers(lo, hi)),
            lambda: data.draw(st.booleans()),
        )

    @given(data=st.data())
    def test_mesh_shard_map_conformance(data):
        check_mesh_shard_map_conformance(
            lambda lo, hi: data.draw(st.integers(lo, hi))
        )


@pytest.mark.parametrize("seed", range(3))
def test_mesh_oracle_conformance_seeded(seed):
    draw_int, _ = _rng_draws(300 + seed)
    check_mesh_oracle_conformance(draw_int)


@pytest.mark.parametrize("seed", range(3))
def test_mesh_stale_advisories_seeded(seed):
    draw_int, _ = _rng_draws(400 + seed)
    check_mesh_stale_advisories(draw_int)


@pytest.mark.parametrize("seed", range(3))
def test_mesh_adversarial_plans_seeded(seed):
    draw_int, draw_bool = _rng_draws(600 + seed)
    check_mesh_adversarial_plans(draw_int, draw_bool)


def test_mesh_degenerate_single_device():
    """D=1 mesh: the full shard_map code path (ring of one, empty plan) on
    any host — must equal the oracle bitwise."""
    from repro.launch.mesh import make_expert_mesh

    draw_int, _ = _rng_draws(700)
    _, E, T, k, bt, idx, gates, x, wg, wu, wd = _mesh_problem_from(draw_int)
    mesh = make_expert_mesh(E, 1)
    y = expert_ffn_mesh_ws(
        idx, gates, x, wg, wu, wd, mesh=mesh, bt=bt, n_programs=2,
    )
    ref = expert_ffn_nodrop_ref(idx, gates, x, wg, wu, wd)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ref))


@pytest.mark.parametrize("seed", range(2))
def test_mesh_shard_map_conformance_seeded(seed):
    draw_int, _ = _rng_draws(800 + seed)
    check_mesh_shard_map_conformance(draw_int)


def test_mesh_selfcheck_subprocess_8_devices():
    """The acceptance gate on every host: re-exec with 8 forced host
    devices and assert the real shard_map dispatch bit-identical to the
    oracle with cross-device steals observed."""
    p = subprocess.run(
        [sys.executable, "-m", "repro.mesh_ws.selfcheck",
         "--devices", "8", "--seeds", "2"],
        env=_ENV, capture_output=True, text=True, timeout=900, cwd=_ROOT,
    )
    assert p.returncode == 0, (p.stdout[-2000:], p.stderr[-2000:])

"""Tests for repro.pallas_ws — the device-resident fence-free WS scheduler.

Four layers:
  1. host shim (`pallas-ws` in ALGORITHMS) satisfies the paper's properties
     under the deterministic adversarial simulator — weak multiplicity (no
     process re-extracts a task it extracted), at-least-once FIFO, owner FIFO;
  2. the megakernel's ragged attention matches the dense length-masked oracle
     for skewed length distributions, for both schedules, flash and decode;
  3. multiplicity tolerance on-device: adversarially rewound queue state makes
     programs re-execute every task, and the multiplicity counters normalize
     the accumulated output back to exact;
  4. scheduling telemetry: stealing strictly improves makespan on skewed
     loads, and the queue arrays drain consistently (layout parity).
"""

import random

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import ALGORITHMS, EMPTY, ThreadBackend  # noqa: E402
from repro.core.simulator import (  # noqa: E402
    check_no_lost_tasks_fifo,
    check_no_process_duplicates,
    check_owner_fifo,
    run_program,
)
from repro.pallas_ws import (  # noqa: E402
    PallasWSHost,
    emit_flash_tasks,
    make_queue_state,
    multiplicity_divisor,
    queue_costs,
    ragged_attention_ref,
    ragged_decode_attention,
    ragged_decode_ref,
    ragged_flash_attention,
    run_ws_schedule,
)

# shared fault-drill mechanics (repro.chaos via conftest)
from conftest import full_rewind  # noqa: E402

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# 1. host shim under the adversarial simulator
# ---------------------------------------------------------------------------


def _program(n_tasks, n_thieves, steals_per_thief, takes):
    prog = {0: [("put", i) for i in range(1, n_tasks + 1)] + [("take", None)] * takes}
    for t in range(1, n_thieves + 1):
        prog[t] = [("steal", None)] * steals_per_thief
    return prog


@pytest.mark.parametrize("seed", range(10))
def test_host_weak_multiplicity_random_schedules(seed):
    rng = random.Random(seed)
    schedule = [rng.randrange(4) for _ in range(rng.randrange(50, 400))]
    prog = _program(n_tasks=8, n_thieves=3, steals_per_thief=5, takes=5)
    records = run_program(
        lambda backend: PallasWSHost(backend=backend, capacity=64), prog, schedule
    )
    check_no_process_duplicates(records)  # no process extracts a task twice
    check_no_lost_tasks_fifo(records)    # at-least-once, FIFO prefix
    check_owner_fifo(records)            # owner respects put order


def test_host_registered_in_core_registry():
    q = ALGORITHMS["pallas-ws"]()
    for i in range(20):
        q.put(i)
    assert [q.take() for _ in range(10)] == list(range(10))
    assert [q.steal(1) for _ in range(10)] == list(range(10, 20))
    assert q.take() is EMPTY and q.steal(2) is EMPTY


def test_host_stale_head_rewind_is_bounded_per_process():
    """The §7 drill on the device layout: a stalled owner Take rewinds Head,
    but the thief's persistent local bound caps it at one extraction per
    task per process (weak multiplicity), unlike the idempotent baselines."""
    z = 6
    q = PallasWSHost(capacity=64)
    for i in range(1, z + 1):
        q.put(i)

    thief_got = []
    r = z
    while r >= 1:
        head = max(q._local_head(0), q.Head.read(0))
        if head < q.tail:
            _stalled_read = q.tasks.read(head, 0)
            for _ in range(r):
                got = q.steal(1)
                if got is not EMPTY:
                    thief_got.append(got)
            q.Head.write(head + 1, 0)  # stale write rewinds Head
            q._local[0] = head + 1
        r -= 1

    counts = {v: thief_got.count(v) for v in set(thief_got)}
    assert counts and max(counts.values()) == 1, counts


def test_host_announcement_row_records_extractors():
    q = PallasWSHost(capacity=32)
    for i in range(4):
        q.put(i)
    q.take()
    q.steal(2)
    q.steal(1)
    head, tail, taken = q.snapshot()
    assert head == 3 and tail == 4
    assert taken == {(0, 0): 0, (2, 1): 2, (1, 2): 1}


def test_host_put_full_is_a_verdict_not_an_exception():
    """The declared `-> bool` contract: a full queue makes `put` return
    False with no state touched (`strict=True` restores the raise).  The
    two-⊥-slot pre-clear invariant caps the fill at capacity-1 tasks and
    survives the last accepted Put."""
    from repro.core.backend import BOTTOM

    q = PallasWSHost(capacity=8)
    accepted = 0
    while q.put(accepted):
        accepted += 1
    assert accepted == q.capacity - 1
    # the rejected put touched nothing
    head, tail, taken = q.snapshot()
    assert (head, tail, taken) == (0, q.capacity - 1, {})
    assert q.remaining_estimate() == accepted  # advisory not bumped
    with pytest.raises(RuntimeError):
        q.put(99, strict=True)
    # the slot past the last accepted task still reads ⊥
    assert q.tasks.read(q.tail, q.OWNER) is BOTTOM
    # and the accepted prefix drains FIFO, exactly
    assert [q.take() for _ in range(accepted)] == list(range(accepted))
    assert q.take() is EMPTY


def test_host_put_segment_matches_put_loop():
    """Batched Put is a pure access-count optimization: the final queue
    state (head, tail, announcements, advisory, payload order) is
    identical to the task-at-a-time loop."""
    xs = list(range(10))
    a = PallasWSHost(capacity=32)
    b = PallasWSHost(capacity=32)
    for x in xs:
        assert a.put(x)
    assert b.put_segment(xs)
    assert a.snapshot() == b.snapshot()
    assert a.remaining_estimate() == b.remaining_estimate()
    assert [b.take() for _ in xs] == xs
    assert b.take() is EMPTY


def test_host_put_segment_all_or_none():
    q = PallasWSHost(capacity=8)
    assert q.put_segment([])          # empty segment: trivial success
    assert q.put_segment([1, 2, 3])
    # 5 more would need tail 8 >= capacity: rejected with nothing written
    assert not q.put_segment([4, 5, 6, 7, 8])
    assert q.tail == 3 and q.remaining_estimate() == 3
    # 4 more exactly fill to the capacity-1 bound put itself enforces
    assert q.put_segment([4, 5, 6, 7])
    assert q.tail == q.capacity - 1
    with pytest.raises(RuntimeError):
        q.put_segment([9], strict=True)
    assert [q.take() for _ in range(7)] == [1, 2, 3, 4, 5, 6, 7]


def test_host_put_segment_amortizes_shared_writes():
    """The amortization claim, counted: one pre-clear pair + ONE advisory
    per segment instead of per task — strictly fewer shared-array writes
    for the same final state, still zero RMWs and zero lock acquisitions."""
    instrument = pytest.importorskip("benchmarks.instrument")
    n = 16
    cb_loop = instrument.CountingBackend()
    q_loop = PallasWSHost(backend=cb_loop, capacity=64)
    for i in range(n):
        assert q_loop.put(i)
    cb_seg = instrument.CountingBackend()
    q_seg = PallasWSHost(backend=cb_seg, capacity=64)
    assert q_seg.put_segment(range(n))
    loop, seg = cb_loop.counts.snapshot(), cb_seg.counts.snapshot()
    assert q_loop.snapshot() == q_seg.snapshot()
    assert seg["writes"] < loop["writes"]
    assert seg["writes"] <= n + 3  # n records + 2 pre-clears + 1 advisory
    for counts in (loop, seg):
        assert counts["rmws"] == 0 and counts["locks"] == 0


# ---------------------------------------------------------------------------
# 2. ragged attention == dense oracle
# ---------------------------------------------------------------------------

SKEWED_LENGTHS = [
    np.array([64, 8, 8, 8]),            # 8x skew
    np.array([64, 64, 16, 8]),          # mixed
    np.array([40, 24, 8, 56]),          # non-multiples of the block size
    np.array([64, 0, 8, 8]),            # an empty row
]


@pytest.mark.parametrize("lengths", SKEWED_LENGTHS, ids=["8x", "mixed", "ragged", "empty-row"])
@pytest.mark.parametrize("schedule", ["ws", "static"])
def test_ragged_flash_matches_reference(lengths, schedule):
    B, H, Hkv, S, hd = 4, 4, 2, 64, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, Hkv, S, hd))
    v = jax.random.normal(ks[2], (B, Hkv, S, hd))
    out, stats = ragged_flash_attention(
        q, k, v, lengths, schedule=schedule, n_programs=4, bq=16, bk=16,
        return_stats=True,
    )
    ref = ragged_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
    # single launch in interpret mode is sequentially-exact: no duplicates
    assert stats.mult_max == 1


@pytest.mark.parametrize("schedule", ["ws", "static"])
def test_ragged_decode_matches_reference(schedule):
    B, H, Hkv, S, hd = 4, 4, 4, 64, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, Hkv, S, hd))
    v = jax.random.normal(ks[2], (B, Hkv, S, hd))
    lengths = np.array([64, 8, 0, 24])
    out = ragged_decode_attention(q, k, v, lengths, schedule=schedule, n_programs=4, bk=8)
    ref = ragged_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_ragged_noncausal_and_gqa():
    B, H, Hkv, S, hd = 2, 4, 1, 32, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, Hkv, S, hd))
    v = jax.random.normal(ks[2], (B, Hkv, S, hd))
    lengths = np.array([32, 8])
    out = ragged_flash_attention(
        q, k, v, lengths, causal=False, schedule="ws", n_programs=2, bq=8, bk=8
    )
    ref = ragged_attention_ref(q, k, v, lengths, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# 3. multiplicity on-device: duplicates are count-normalized, not forbidden
# ---------------------------------------------------------------------------


def _ragged_inputs(lengths, H=2, Hkv=2, hd=8):
    B = len(lengths)
    S = int(max(lengths))
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, Hkv, S, hd))
    v = jax.random.normal(ks[2], (B, Hkv, S, hd))
    return q, k, v


def test_device_multiplicity_normalization_under_head_rewind():
    """Relaunch the megakernel on adversarially rewound queue state (every
    Head dragged back to 0, every local bound wiped — the worst §7-style
    staleness).  Every task is re-extracted and re-accumulated; mult == 2
    everywhere and the divisor recovers the exact output."""
    lengths = np.array([32, 8, 8, 16])
    q, k, v = _ragged_inputs(lengths)
    B, H, S, hd = q.shape
    bq = bk = 8
    tasks = emit_flash_tasks(lengths, H, bq, bk, causal=True)
    state = make_queue_state(tasks, n_programs=4)

    res1 = run_ws_schedule(state, q, k, v, causal=True, bq=bq, bk=bk, steal=True)
    assert (res1.mult[: state.n_tasks] == 1).all()

    # adversarial rewind: stale Head writes + fresh processes (no local
    # bounds) — the shared maximal-storm drill from repro.chaos
    full_rewind(state, res1)
    res2 = run_ws_schedule(
        state, q, k, v, causal=True, bq=bq, bk=bk, steal=True,
        out=res1.out, mult=jnp.asarray(res1.mult),
    )
    assert (res2.mult[: state.n_tasks] == 2).all(), "every task re-extracted once"

    div = multiplicity_divisor(tasks, res2.mult, (B, H, S))
    out = (res2.out / jnp.asarray(div)[..., None]).astype(q.dtype)
    ref = ragged_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_device_no_program_re_extracts_within_launch():
    """Weak multiplicity on-device: within a launch each queue slot is
    claimed at most once per program — with fresh state, exactly once in
    total (announcement rows prove who took what)."""
    lengths = np.array([32, 8, 8, 16])
    q, k, v = _ragged_inputs(lengths)
    bq = bk = 8
    tasks = emit_flash_tasks(lengths, 2, bq, bk, causal=True)
    state = make_queue_state(tasks, n_programs=4)
    res = run_ws_schedule(state, q, k, v, causal=True, bq=bq, bk=bk, steal=True)
    live = state.tasks[:, :, 0] != -1
    assert (res.taken[live] >= 0).all(), "every live slot extracted"
    assert (res.taken[~live] == -1).all(), "no phantom extraction"
    assert (res.mult[: state.n_tasks] == 1).all()
    # heads ended exactly past each queue's last live slot
    np.testing.assert_array_equal(res.head, live.sum(axis=1))


# ---------------------------------------------------------------------------
# 4. scheduling telemetry
# ---------------------------------------------------------------------------


def test_stealing_beats_static_on_skewed_load():
    lengths = np.array([64, 8, 8, 8])
    q, k, v = _ragged_inputs(lengths)
    _, st_static = ragged_flash_attention(
        q, k, v, lengths, schedule="static", n_programs=4, bq=8, bk=8,
        return_stats=True,
    )
    _, st_ws = ragged_flash_attention(
        q, k, v, lengths, schedule="ws", n_programs=4, bq=8, bk=8,
        return_stats=True,
    )
    assert st_ws.total_work == st_static.total_work, "same tiles executed"
    assert st_ws.steals > 0
    assert st_ws.makespan < st_static.makespan, (st_ws, st_static)
    assert st_ws.wasted_slots < st_static.wasted_slots


def test_balanced_load_needs_no_steals_to_match():
    lengths = np.array([16, 16, 16, 16])
    q, k, v = _ragged_inputs(lengths)
    _, st_static = ragged_flash_attention(
        q, k, v, lengths, schedule="static", n_programs=4, bq=8, bk=8,
        return_stats=True,
    )
    _, st_ws = ragged_flash_attention(
        q, k, v, lengths, schedule="ws", n_programs=4, bq=8, bk=8,
        return_stats=True,
    )
    assert st_ws.makespan == st_static.makespan


def test_queue_costs_reflect_partition():
    lengths = np.array([32, 8])
    tasks = emit_flash_tasks(lengths, 2, 8, 8, causal=True)
    state = make_queue_state(tasks, n_programs=2, partition="batch")
    loads = queue_costs(state)
    assert loads[0] > loads[1]  # the long sequence's queue is heavier
    assert loads.sum() == sum(t.cost for t in tasks)

"""Per-kernel allclose tests: sweep shapes/dtypes in interpret mode against
the pure-jnp oracles (ref.py), forward and backward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import decode_attention as decode_kernel
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.kernel import ssd_scan as ssd_kernel
from repro.kernels.ssd_scan.ops import ssd_scan as ssd_op
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.models.attention import flash_ref as model_flash_ref
from repro.models.ssm import ssd_chunked

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# flash attention


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Hkv,S,hd,bq,bk,causal,window",
    [
        (1, 2, 2, 32, 8, 16, 16, True, 0),
        (2, 4, 2, 64, 16, 16, 32, True, 0),
        (2, 4, 1, 64, 16, 32, 16, False, 0),
        (1, 8, 4, 128, 32, 32, 32, True, 24),
        (1, 2, 2, 48, 8, 16, 16, True, 16),
    ],
)
def test_flash_attention_fwd(B, H, Hkv, S, hd, bq, bk, causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, hd), dtype)
    out, lse = flash_attention_fwd(
        q, k, v, causal=causal, window=window, bq=bq, bk=bk, interpret=True
    )
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )
    assert np.all(np.isfinite(np.asarray(lse)))


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 8), (False, 0)])
def test_flash_attention_grad(causal, window):
    B, H, Hkv, S, hd = 2, 4, 2, 32, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, Hkv, S, hd))
    v = jax.random.normal(ks[2], (B, Hkv, S, hd))

    def f(q, k, v):
        return (flash_attention(q, k, v, causal, window, 16, 16, True) ** 2).sum()

    def g(q, k, v):
        return (attention_ref(q, k, v, causal=causal, window=window) ** 2).sum()

    ga = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gb = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(ga, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)


def test_model_flash_ref_matches_oracle():
    """The model-side chunked jnp attention equals the kernel oracle."""
    B, H, S, hd = 2, 4, 64, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    for causal, window in [(True, 0), (True, 16)]:
        out = model_flash_ref(q, k, v, causal=causal, window=window, chunk=16)
        ref = attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            causal=causal, window=window,
        ).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# ssd scan


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,S,H,P,N,chunk",
    [(1, 16, 2, 4, 4, 8), (2, 32, 3, 8, 4, 8), (1, 64, 2, 16, 8, 16), (2, 24, 1, 8, 8, 8)],
)
def test_ssd_scan_fwd(b, S, H, P, N, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (b, S, N), dtype)
    C = jax.random.normal(ks[4], (b, S, N), dtype)
    y, fin = ssd_kernel(x, dt, A, B, C, chunk=chunk, interpret=True)
    yr, finr = ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), **_tol(dtype)
    )
    np.testing.assert_allclose(np.asarray(fin), np.asarray(finr), **_tol(dtype))


def test_ssd_grad_matches_chunked():
    b, S, H, P, N = 1, 32, 2, 8, 4
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (b, S, N))
    C = jax.random.normal(ks[4], (b, S, N))

    def f(x, dt, A, B, C):
        return (ssd_op(x, dt, A, B, C, 8, True) ** 2).sum()

    def g(x, dt, A, B, C):
        return (ssd_ref(x, dt, A, B, C)[0].astype(x.dtype) ** 2).sum()

    ga = jax.grad(f, argnums=(0, 1, 2, 3, 4))(x, dt, A, B, C)
    gb = jax.grad(g, argnums=(0, 1, 2, 3, 4))(x, dt, A, B, C)
    for a, b_ in zip(ga, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# decode attention


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Hkv,S,hd,bk,pos,window",
    [
        (1, 2, 1, 32, 8, 8, 31, 0),
        (2, 4, 2, 64, 16, 16, 30, 0),
        (2, 4, 2, 64, 16, 16, 63, 16),
        (1, 8, 8, 128, 32, 32, 5, 0),
    ],
)
def test_decode_attention(B, H, Hkv, S, hd, bk, pos, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, hd), dtype)
    out = decode_kernel(q, k, v, jnp.int32(pos), window=window, bk=bk, interpret=True)
    ref = decode_attention_ref(q, k, v, jnp.int32(pos), window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )

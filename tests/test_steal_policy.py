"""Steal-policy invariance drills (DESIGN.md §3.6).

Victim *selection* is performance advice layered on the paper's claim
protocol: which queue an idle program probes next may come from arbitrarily
stale plain reads (the advisory ``remaining[q]`` cost summaries), because
the claim itself still re-checks the actual slot against ⊥ and multiplicity
normalization absorbs any duplication.  These tests pin that separation:

  1. policy invariance — for any routing, ``steal_policy="scan"`` and
     ``"cost"`` both drain within the *tightened* rounds bound and produce
     the oracle answer (bit-identical outputs on fresh interpret launches);
  2. adversarial advisories — garbage ``remaining`` seeds (zeros, reversed,
     random) may change makespan but never results, and never progress:
     the ``head < tail`` victim mask alone guarantees drain;
  3. head-rewind drills under the cost policy (the §7 staleness analogue)
     keep the dropless invariant and the exact combine;
  4. tight bounds — ``default_rounds`` is Graham's ``ceil(total/P) +
     max_cost`` with no scan slack, verified on the worst one-queue skew;
  5. the guarded clamp-read — a queue whose head view sits at/over capacity
     issues zero slot loads (``scanned`` counts every probe);
  6. round compression — the no-steal drain in O(1) rounds leaves telemetry
     identical to the per-round lockstep drain it replaces.

Plain check functions over a ``draw_int``/``draw_bool`` source: hypothesis
drives them through arbitrary schedules, and seeded deterministic slices
always run (coverage without hypothesis, mirroring the conformance suite).
"""

import random

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

try:
    from hypothesis import given, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.moe_ws.dispatch import route_to_tasks  # noqa: E402
from repro.moe_ws.expert_kernel import run_moe_schedule  # noqa: E402
from repro.moe_ws.layer import combine_routed, expert_ffn_nodrop_ref  # noqa: E402
from repro.pallas_ws.kernel import (  # noqa: E402
    STATIC_COMPRESSED_ROUNDS,
    default_rounds,
    run_ws_schedule,
)
from repro.pallas_ws.queues import make_queue_state, queue_costs  # noqa: E402
from repro.pallas_ws.tasks import emit_flash_tasks, max_cost  # noqa: E402

# shared fault-drill mechanics (repro.chaos via conftest): the advisory
# seeding and head-rewind storms these drills used to hand-roll
from conftest import drawn_rewind, seed_advisory as _seed_advisory  # noqa: E402

P = 3


def _cdiv(a, b):
    return -(-a // b)


def _routing_from(draw_int):
    E = draw_int(2, 5)
    T = draw_int(1, 10)
    k = draw_int(1, min(2, E))
    bt = (2, 4)[draw_int(0, 1)]
    seed = draw_int(0, 2**16)
    rng = np.random.RandomState(seed)
    idx = np.stack([rng.choice(E, k, replace=False) for _ in range(T)])
    gates = rng.uniform(0.1, 1.0, (T, k)).astype(np.float32)
    gates /= gates.sum(1, keepdims=True)
    return E, T, k, bt, seed, idx, gates


def _setup(idx, gates, E, bt, seed):
    T = idx.shape[0]
    d, f = 4, 8
    ks = jax.random.split(jax.random.PRNGKey(seed % 997), 4)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    w = (
        jax.random.normal(ks[1], (E, d, f), jnp.float32) / 2.0,
        jax.random.normal(ks[2], (E, d, f), jnp.float32) / 2.0,
        jax.random.normal(ks[3], (E, f, d), jnp.float32) / 2.0,
    )
    tasks, routed = route_to_tasks(idx, gates, E, bt=bt)
    state = make_queue_state(tasks, P, n_queues=E, partition="owner")
    return x, w, tasks, routed, state


# ---------------------------------------------------------------------------
# 1+2: policy invariance + adversarial advisories, at the tight bound
# ---------------------------------------------------------------------------


def check_policy_invariance(draw_int):
    E, T, k, bt, seed, idx, gates = _routing_from(draw_int)
    rng = np.random.RandomState(seed ^ 0xA5A5)
    ref = None
    outs = {}
    for policy in ("scan", "cost"):
        for adv in ("exact", "zeros", "reversed", "random"):
            if policy == "scan" and adv != "exact":
                continue  # the scan never reads the advisory
            x, w, tasks, routed, state = _setup(idx, gates, E, bt, seed)
            _seed_advisory(state, adv, rng)
            rounds = default_rounds(state, steal=True)
            # the tightened Graham bound, no slack — drain must still hold
            assert rounds == _cdiv(sum(t.cost for t in tasks), P) + max_cost(tasks)
            res = run_moe_schedule(
                state, x, routed.tok_idx, *w, bt=bt, steal=True,
                steal_policy=policy, rounds=rounds,
            )
            mult = res.mult[: state.n_tasks]
            assert (mult == 1).all(), (
                f"{policy}/{adv}: fresh interpret launch must drain exactly "
                f"once within the tight bound (mult={mult})"
            )
            y = combine_routed(routed, tasks, res)
            if ref is None:
                ref = np.asarray(expert_ffn_nodrop_ref(idx, gates, x, *w))
            np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-4)
            outs[(policy, adv)] = (np.asarray(res.out), res.slots_scanned,
                                   res.extractions)
    # fresh launches execute every tile exactly once -> bit-identical
    # accumulations no matter which victim order the policy walked
    base = outs[("scan", "exact")][0]
    for key, (out, _, _) in outs.items():
        np.testing.assert_array_equal(out, base, err_msg=str(key))
    # the O(1) policy never probes more slots than the sequential scan
    assert outs[("cost", "exact")][1] <= outs[("scan", "exact")][1]


# ---------------------------------------------------------------------------
# 3: head-rewind drills under the cost policy with garbage advisories
# ---------------------------------------------------------------------------


def check_cost_policy_rewind_drills(draw_int, draw_bool):
    E, T, k, bt, seed, idx, gates = _routing_from(draw_int)
    x, w, tasks, routed, state = _setup(idx, gates, E, bt, seed)
    rounds = default_rounds(state, steal=True)
    res = run_moe_schedule(
        state, x, routed.tok_idx, *w, bt=bt, steal=True,
        steal_policy="cost", rounds=rounds,
    )
    assert (res.mult[: state.n_tasks] >= 1).all(), "first launch drains"
    for _ in range(draw_int(1, 2)):
        # shared storm drill: resume from the finished launch, rewind drawn
        # heads to stale values, wipe drawn local bounds, and re-corrupt the
        # advisories — the worst §7-style staleness for victim selection
        drawn_rewind(state, res, draw_int, draw_bool,
                     advisory_modes=("zeros", "reversed", "random"))
        res = run_moe_schedule(
            state, x, routed.tok_idx, *w, bt=bt, steal=True,
            steal_policy="cost", rounds=draw_int(1, rounds),
            out=res.out, mult=jnp.asarray(res.mult),
        )
    y = combine_routed(routed, tasks, res)
    ref = expert_ffn_nodrop_ref(idx, gates, x, *w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# 4: the tight bound survives the worst skew (everything on one queue)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["scan", "cost"])
def test_tight_bound_drains_one_queue_skew(policy):
    """Adversarial placement: every routed pair on one expert.  The old
    bound carried `+ n_queues + 8` slack; the tightened Graham bound alone
    must still drain — an idle program always claims while work remains."""
    T, E, k, bt = 24, 6, 1, 4
    idx = np.zeros((T, k), dtype=np.int32)  # all to expert 0
    gates = np.ones((T, k), dtype=np.float32)
    x, w, tasks, routed, state = _setup(idx, gates, E, bt, seed=0)
    rounds = default_rounds(state, steal=True)
    assert rounds == _cdiv(T, P) + bt  # total=T rows, max tile = bt
    res = run_moe_schedule(
        state, x, routed.tok_idx, *w, bt=bt, steal=True,
        steal_policy=policy, rounds=rounds,
    )
    assert (res.mult[: state.n_tasks] == 1).all()
    # thieves flattened the one hot queue: near-perfect split
    assert res.makespan <= _cdiv(T, P) + bt
    assert res.steal_ratio > 0
    # all T/bt tiles sat in the one hot queue and every one was claimed
    assert res.per_queue_drained[0] == _cdiv(T, bt)
    assert res.per_queue_drained[1:].sum() == 0


@pytest.mark.parametrize("policy", ["scan", "cost"])
def test_scan_traffic_cost_vs_scan(policy):
    """The telemetry the cost policy exists to win: per-extraction slot
    probes stay O(1) while the scan policy pays O(n_queues) once queues
    start draining.  (The full-size separation at E in {64, 160, 384} is
    benchmarks/steal_policy.py; this pins the mechanism at test scale.)"""
    T, E, k, bt = 32, 16, 2, 2
    rng = np.random.RandomState(3)
    idx = np.stack([rng.choice(E, k, replace=False) for _ in range(T)])
    gates = np.ones((T, k), dtype=np.float32) / k
    x, w, tasks, routed, state = _setup(idx, gates, E, bt, seed=3)
    res = run_moe_schedule(
        state, x, routed.tok_idx, *w, bt=bt, steal=True, steal_policy=policy,
    )
    assert (res.mult[: state.n_tasks] == 1).all()
    per = res.scan_per_extraction
    if policy == "cost":
        # own probe + at most one victim probe per claim, plus idle-round
        # probes near the drain tail
        assert per <= 4.0, per
    else:
        assert per >= 3.0, per  # sequential scan pays many ⊥ probes


# ---------------------------------------------------------------------------
# 5: guarded clamp-read — out-of-range heads issue no slot loads
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["scan", "cost"])
def test_capacity_guard_suppresses_reads(policy):
    lengths = np.array([16, 8, 8, 8])
    tasks = emit_flash_tasks(lengths, 2, 8, 8, causal=True)
    state = make_queue_state(tasks, n_programs=4)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S = len(lengths), int(max(lengths))
    q = jax.random.normal(ks[0], (B, 2, S, 8))
    k = jax.random.normal(ks[1], (B, 2, S, 8))
    v = jax.random.normal(ks[2], (B, 2, S, 8))
    # every head view at/above capacity: the pre-fix kernel still issued the
    # clamped load at capacity-1 each probe; the guard must issue none
    state.head = np.full_like(state.head, state.capacity)
    res = run_ws_schedule(
        state, q, k, v, causal=True, bq=8, bk=8, steal=True,
        steal_policy=policy, rounds=3,
    )
    assert res.slots_scanned == 0, res.scanned
    assert res.extractions == 0 and (res.mult == 0).all()


# ---------------------------------------------------------------------------
# 6: round compression — O(1)-round no-steal drain, identical telemetry
# ---------------------------------------------------------------------------


def test_static_compression_matches_per_round_drain():
    lengths = np.array([64, 8, 8, 16])
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S = len(lengths), 64
    q = jax.random.normal(ks[0], (B, 2, S, 8))
    k = jax.random.normal(ks[1], (B, 2, S, 8))
    v = jax.random.normal(ks[2], (B, 2, S, 8))
    tasks = emit_flash_tasks(lengths, 2, 8, 8, causal=True)

    def launch(compress):
        state = make_queue_state(tasks, n_programs=4)
        rounds = default_rounds(state, steal=False, compress_runs=compress)
        if compress:
            assert rounds == STATIC_COMPRESSED_ROUNDS
        else:
            assert rounds == int(queue_costs(state).max())
        return state, run_ws_schedule(
            state, q, k, v, causal=True, bq=8, bk=8, steal=False,
            compress_runs=compress, rounds=rounds,
        )

    state_c, res_c = launch(True)
    state_r, res_r = launch(False)
    # one owner per queue: the compressed run IS the serial drain the
    # per-round lockstep was modeling — every counter must agree
    for f in ("head", "clock", "work", "steals", "mult"):
        np.testing.assert_array_equal(
            np.asarray(getattr(res_c, f)), np.asarray(getattr(res_r, f)), f
        )
    np.testing.assert_array_equal(np.asarray(res_c.out), np.asarray(res_r.out))
    assert res_c.makespan == int(queue_costs(state_c).max())
    assert (res_c.mult[: state_c.n_tasks] == 1).all()


# ---------------------------------------------------------------------------
# 7: half-run amortized Steal (steal_run_cap > 1) — the same contract, fewer
# probes: one ⊥-probe certifies a whole contiguous run of ceil(rem/2) slots
# ---------------------------------------------------------------------------


def check_halfrun_invariance(draw_int):
    """Raising ``steal_run_cap`` never changes results: fresh launches stay
    mult==1 within the cap-adjusted Graham bound, outputs are bit-identical
    to the per-slot (cap=1) lowering, and probe traffic never grows."""
    E, T, k, bt, seed, idx, gates = _routing_from(draw_int)
    cap = (2, 3, 4)[draw_int(0, 2)]
    ref = None
    outs = {}
    for c in (1, cap):
        x, w, tasks, routed, state = _setup(idx, gates, E, bt, seed)
        # both runs get the SAME round budget (the cap-adjusted bound) so
        # the probe comparison below is launch-for-launch fair
        rounds = default_rounds(state, steal=True, steal_run_cap=cap)
        assert rounds == (
            _cdiv(sum(t.cost for t in tasks), P) + cap * max_cost(tasks)
        )
        res = run_moe_schedule(
            state, x, routed.tok_idx, *w, bt=bt, steal=True,
            steal_policy="cost", rounds=rounds, steal_run_cap=c,
        )
        mult = res.mult[: state.n_tasks]
        assert (mult == 1).all(), (
            f"cap={c}: fresh interpret launch must drain exactly once "
            f"(mult={mult})"
        )
        y = combine_routed(routed, tasks, res)
        if ref is None:
            ref = np.asarray(expert_ffn_nodrop_ref(idx, gates, x, *w))
        np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-4)
        outs[c] = (np.asarray(res.out), res.slots_scanned)
    # every tile executed exactly once in both lowerings: the accumulated
    # expert outputs are bit-identical regardless of who claimed what
    np.testing.assert_array_equal(outs[cap][0], outs[1][0])
    # one probe claims up to cap slots: traffic never exceeds per-slot
    assert outs[cap][1] <= outs[1][1], (outs[cap][1], outs[1][1])


@pytest.mark.parametrize("n_tiles", [1, 2, 3])
def test_halfrun_tiny_victim_runs(n_tiles):
    """Victim ``rem`` in {1, 2, 3}: the half-run claim ``min(ceil(rem/2),
    cap)`` clips to >= 1, never walks past the live prefix, and rem=2 takes
    only one slot (``(2+1)//2 == 1`` — the donation rule leaves the victim
    its half)."""
    T, E, k, bt = n_tiles * 4, 6, 1, 4  # n_tiles tiles, all on expert 0
    idx = np.zeros((T, k), dtype=np.int32)
    gates = np.ones((T, k), dtype=np.float32)
    x, w, tasks, routed, state = _setup(idx, gates, E, bt, seed=0)
    rounds = default_rounds(state, steal=True, steal_run_cap=4)
    res = run_moe_schedule(
        state, x, routed.tok_idx, *w, bt=bt, steal=True,
        steal_policy="cost", rounds=rounds, steal_run_cap=4,
    )
    assert (res.mult[: state.n_tasks] == 1).all()
    assert res.per_queue_drained[0] == n_tiles
    assert res.per_queue_drained[1:].sum() == 0
    y = combine_routed(routed, tasks, res)
    ref = expert_ffn_nodrop_ref(idx, gates, x, *w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_halfrun_amortizes_probe_traffic():
    """The telemetry the half-run exists to win: on a deep one-queue skew
    the cap>1 launch issues at least 2x fewer slot probes than per-slot
    claims at the SAME round budget.  (The full-size separation is
    benchmarks/steal_policy.py; this pins the mechanism at test scale.)"""
    T, E, k, bt = 96, 8, 1, 1
    idx = np.zeros((T, k), dtype=np.int32)
    gates = np.ones((T, k), dtype=np.float32)
    scans = {}
    for cap in (1, 8):
        x, w, tasks, routed, state = _setup(idx, gates, E, bt, seed=5)
        rounds = default_rounds(state, steal=True, steal_run_cap=8)
        res = run_moe_schedule(
            state, x, routed.tok_idx, *w, bt=bt, steal=True,
            steal_policy="cost", rounds=rounds, steal_run_cap=cap,
        )
        assert (res.mult[: state.n_tasks] == 1).all()
        assert res.per_queue_drained[0] == _cdiv(T, bt)
        scans[cap] = res.slots_scanned
    assert scans[8] * 2 <= scans[1], scans


def check_halfrun_rewind_drills(draw_int, draw_bool):
    """§7 staleness with runs in flight: head rewinds + wiped local bounds
    make whole claimed runs re-claimable.  Over-claims are multiplicity
    events, never correctness events — the combine still matches the
    oracle after normalization."""
    E, T, k, bt, seed, idx, gates = _routing_from(draw_int)
    cap = (2, 4)[draw_int(0, 1)]
    x, w, tasks, routed, state = _setup(idx, gates, E, bt, seed)
    rounds = default_rounds(state, steal=True, steal_run_cap=cap)
    res = run_moe_schedule(
        state, x, routed.tok_idx, *w, bt=bt, steal=True,
        steal_policy="cost", rounds=rounds, steal_run_cap=cap,
    )
    assert (res.mult[: state.n_tasks] >= 1).all(), "first launch drains"
    for _ in range(draw_int(1, 2)):
        drawn_rewind(state, res, draw_int, draw_bool,
                     advisory_modes=("zeros", "reversed", "random"))
        res = run_moe_schedule(
            state, x, routed.tok_idx, *w, bt=bt, steal=True,
            steal_policy="cost", rounds=draw_int(1, rounds),
            steal_run_cap=cap, out=res.out, mult=jnp.asarray(res.mult),
        )
    assert (res.mult[: state.n_tasks] >= 1).all(), "no task lost"
    y = combine_routed(routed, tasks, res)
    ref = expert_ffn_nodrop_ref(idx, gates, x, *w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_halfrun_requires_cost_policy():
    lengths = np.array([16, 8, 8, 8])
    tasks = emit_flash_tasks(lengths, 2, 8, 8, causal=True)
    state = make_queue_state(tasks, n_programs=4)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S = len(lengths), int(max(lengths))
    q = jax.random.normal(ks[0], (B, 2, S, 8))
    k = jax.random.normal(ks[1], (B, 2, S, 8))
    v = jax.random.normal(ks[2], (B, 2, S, 8))
    with pytest.raises(ValueError):
        run_ws_schedule(state, q, k, v, causal=True, bq=8, bk=8,
                        steal=True, steal_policy="scan", steal_run_cap=2)
    with pytest.raises(ValueError):
        run_ws_schedule(state, q, k, v, causal=True, bq=8, bk=8,
                        steal=False, steal_run_cap=2)


# ---------------------------------------------------------------------------
# hypothesis drivers + seeded deterministic slices
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @given(data=st.data())
    def test_policy_invariance(data):
        check_policy_invariance(lambda lo, hi: data.draw(st.integers(lo, hi)))

    @given(data=st.data())
    def test_cost_policy_rewind_drills(data):
        check_cost_policy_rewind_drills(
            lambda lo, hi: data.draw(st.integers(lo, hi)),
            lambda: data.draw(st.booleans()),
        )

    @given(data=st.data())
    def test_halfrun_invariance(data):
        check_halfrun_invariance(lambda lo, hi: data.draw(st.integers(lo, hi)))

    @given(data=st.data())
    def test_halfrun_rewind_drills(data):
        check_halfrun_rewind_drills(
            lambda lo, hi: data.draw(st.integers(lo, hi)),
            lambda: data.draw(st.booleans()),
        )


def _rng_draws(seed):
    rng = random.Random(seed)
    return (lambda lo, hi: rng.randint(lo, hi)), (lambda: rng.random() < 0.5)


@pytest.mark.parametrize("seed", range(4))
def test_policy_invariance_seeded(seed):
    draw_int, _ = _rng_draws(300 + seed)
    check_policy_invariance(draw_int)


@pytest.mark.parametrize("seed", range(4))
def test_cost_policy_rewind_drills_seeded(seed):
    draw_int, draw_bool = _rng_draws(400 + seed)
    check_cost_policy_rewind_drills(draw_int, draw_bool)


@pytest.mark.parametrize("seed", range(4))
def test_halfrun_invariance_seeded(seed):
    draw_int, _ = _rng_draws(500 + seed)
    check_halfrun_invariance(draw_int)


@pytest.mark.parametrize("seed", range(4))
def test_halfrun_rewind_drills_seeded(seed):
    draw_int, draw_bool = _rng_draws(600 + seed)
    check_halfrun_rewind_drills(draw_int, draw_bool)

"""Hypothesis property: the dropless invariant of repro.moe_ws.

For ANY routing and ANY adversarial steal/duplication schedule — random
stale-Head rewinds and wiped per-program bounds between megakernel launches,
the device analogue of the paper's §7 interleavings — every routed
(token, expert) pair is executed at least once and the multiplicity-
normalized combine equals the dense no-drop reference within tolerance.

Separate module: hypothesis is an optional dev dependency (CI installs it;
bare environments skip this file, mirroring test_core_properties.py).
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
pytest.importorskip("hypothesis")

import jax.numpy as jnp  # noqa: E402
from hypothesis import given, strategies as st  # noqa: E402

from repro.moe_ws import (  # noqa: E402
    combine_routed,
    expert_ffn_nodrop_ref,
    route_to_tasks,
    run_moe_schedule,
)
from repro.pallas_ws import make_queue_state  # noqa: E402


# depth comes from the conftest hypothesis profile: 10 examples in the
# tier-1 smoke (`dev`), more under the CI conformance job (`ci`)
@given(data=st.data())
def test_dropless_invariant_any_adversarial_schedule(data):
    E = data.draw(st.integers(2, 5), label="E")
    T = data.draw(st.integers(1, 10), label="T")
    k = data.draw(st.integers(1, min(2, E)), label="k")
    bt = data.draw(st.sampled_from([2, 4]), label="bt")
    seed = data.draw(st.integers(0, 2**16), label="seed")

    rng = np.random.RandomState(seed)
    idx = np.stack([rng.choice(E, k, replace=False) for _ in range(T)])
    gates = rng.uniform(0.1, 1.0, (T, k)).astype(np.float32)
    gates /= gates.sum(1, keepdims=True)
    d, f = 4, 8
    ks = jax.random.split(jax.random.PRNGKey(seed % 997), 4)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    w = (
        jax.random.normal(ks[1], (E, d, f), jnp.float32) / 2.0,
        jax.random.normal(ks[2], (E, d, f), jnp.float32) / 2.0,
        jax.random.normal(ks[3], (E, f, d), jnp.float32) / 2.0,
    )
    tasks, routed = route_to_tasks(idx, gates, E, bt=bt)
    state = make_queue_state(tasks, n_programs=3, n_queues=E, partition="owner")

    res = run_moe_schedule(state, x, routed.tok_idx, *w, bt=bt, steal=True)
    n_relaunches = data.draw(st.integers(1, 2), label="relaunches")
    for _ in range(n_relaunches):
        # adversarial staleness: rewind a random subset of shared heads to a
        # random earlier value and wipe a random subset of local bounds —
        # the worst §7-style interleaving the protocol admits
        for q in range(state.n_queues):
            if data.draw(st.booleans(), label=f"rewind_q{q}"):
                state.head[q] = rng.randint(0, max(1, state.head[q] + 1))
        for pidx in range(state.local_head.shape[0]):
            if data.draw(st.booleans(), label=f"wipe_p{pidx}"):
                state.local_head[pidx] = 0
        res = run_moe_schedule(
            state, x, routed.tok_idx, *w, bt=bt, steal=True,
            out=res.out, mult=jnp.asarray(res.mult),
        )

    mult = res.mult[: state.n_tasks]
    assert (mult >= 1).all(), "dropless: every expert tile executed at least once"
    y = combine_routed(routed, tasks, res)
    ref = expert_ffn_nodrop_ref(idx, gates, x, *w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-4, atol=1e-4)

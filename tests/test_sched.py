"""Tests for the SPMD work-stealing scheduler (the paper's TPU adaptation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install hypothesis)"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.sched import (
    async_makespan,
    run_lockstep_rounds,
    ws_accumulate_grads,
)
from repro.sched.policy import pick_tasks, queue_bases


def test_queue_bases():
    assert queue_bases(jnp.array([3, 0, 2])).tolist() == [0, 3, 3]


def test_pick_prefers_own_queue():
    tails = jnp.array([2, 2], dtype=jnp.int32)
    view = jnp.zeros(2, dtype=jnp.int32)
    task, q, nv = pick_tasks(view, tails, jnp.int32(1))
    assert int(task) == 2 and int(q) == 1  # own base = 2
    assert nv.tolist() == [0, 1]


def test_pick_steals_from_richest_when_empty():
    tails = jnp.array([5, 0, 1], dtype=jnp.int32)
    view = jnp.array([1, 0, 0], dtype=jnp.int32)
    task, q, _ = pick_tasks(view, tails, jnp.int32(1))
    assert int(q) == 0 and int(task) == 1  # queue 0 richest, its head is 1


def test_pick_idle_when_all_empty():
    tails = jnp.array([1, 1], dtype=jnp.int32)
    view = jnp.array([1, 1], dtype=jnp.int32)
    task, q, nv = pick_tasks(view, tails, jnp.int32(0))
    assert int(task) == -1 and int(q) == -1
    assert nv.tolist() == [1, 1]


MODES = ["static", "ws-mult", "ws-mult-ranked", "ws-wmult", "ws-wmult-deque"]


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize(
    "tails", [[4, 4, 4, 4], [13, 1, 1, 1], [0, 0, 16, 0], [7, 0, 3, 2]]
)
def test_lockstep_at_least_once(mode, tails):
    """Every task extracted >= once; per-extraction counts bounded by workers."""
    assignment, counts, stats = run_lockstep_rounds(tails, n_workers=4, mode=mode)
    assert (counts >= 1).all(), f"{mode} lost tasks: {counts}"
    assert counts.max() <= 4
    if mode in ("static", "ws-mult", "ws-mult-ranked"):
        assert counts.max() == 1, f"{mode} must be exact: {counts}"


@pytest.mark.parametrize("mode", ["ws-mult-ranked", "ws-wmult-deque"])
def test_stealing_beats_static_on_skew(mode):
    """Skewed queues: stealing finishes in ~n_tasks/n_workers rounds, static in
    max(tails) rounds — the lockstep win of the adaptation."""
    tails = [13, 1, 1, 1]
    _, _, st_static = run_lockstep_rounds(tails, 4, mode="static")
    _, _, st_ws = run_lockstep_rounds(tails, 4, mode=mode)
    assert st_static.rounds_used == 13
    # ranked is exact: 1 + ceil(12/4) = 4; deque drains head+tail (2/round on a
    # single hot queue) while staying collective-free
    bound = 4 if mode == "ws-mult-ranked" else 9
    assert st_ws.rounds_used <= bound, st_ws
    assert st_ws.rounds_used < st_static.rounds_used


def test_wswmult_head_only_is_honest_in_lockstep():
    """FIFO head-only stealing admits <=1 net extraction per queue per round in
    BSP — ws-wmult cannot beat static on a single hot queue (it duplicates the
    owner's takes).  This measured fact motivates ws-wmult-deque; the paper's
    FIFO queue shines in the ASYNC regime (see simulator tests)."""
    tails = [13, 1, 1, 1]
    _, counts, stats = run_lockstep_rounds(tails, 4, mode="ws-wmult")
    assert (counts >= 1).all()
    assert stats.rounds_used >= 12  # no better than static
    assert stats.duplicate_picks > 0  # and it paid duplicates for it


def test_claims_mode_head_contention_is_honest():
    """Paper-faithful claims mode (B-WS Swap analogue) on a single hot queue:
    every thief chases the same head as the owner and loses the claim — the
    lockstep degeneration DESIGN.md documents (motivates ws-mult-ranked)."""
    tails = [13, 1, 1, 1]
    _, counts, stats = run_lockstep_rounds(tails, 4, mode="ws-mult")
    assert (counts == 1).all()  # still exact, nothing lost
    assert stats.rounds_used >= 10  # but barely better than static


def test_wsmult_blocking_collectives_vs_wswmult_async():
    """The paper's fence-freedom analogue: ws-wmult/-deque issue ZERO blocking
    collectives; the exact modes pay one per round."""
    tails = [8, 0, 8, 0]
    _, _, s_mult = run_lockstep_rounds(tails, 4, mode="ws-mult-ranked")
    for m in ("ws-wmult", "ws-wmult-deque"):
        _, _, s_wmult = run_lockstep_rounds(tails, 4, mode=m)
        assert s_wmult.blocking_collectives == 0
        assert s_wmult.async_collectives > 0
    assert s_mult.blocking_collectives == s_mult.rounds_used > 0


def test_wswmult_weak_multiplicity_no_worker_repeats():
    """No worker extracts the same task twice (local view monotonicity)."""
    tails = [6, 2, 0, 0]
    assignment, counts, _ = run_lockstep_rounds(tails, 4, mode="ws-wmult")
    for w in range(4):
        col = [int(t) for t in assignment[:, w] if t >= 0]
        assert len(col) == len(set(col)), f"worker {w} repeated a task: {col}"


@settings(max_examples=20, deadline=None)
@given(
    tails=st.lists(st.integers(min_value=0, max_value=9), min_size=4, max_size=4),
    sync_every=st.integers(min_value=1, max_value=4),
)
def test_lockstep_property_random_tails(tails, sync_every):
    if sum(tails) == 0:
        return
    for mode in ("ws-mult", "ws-mult-ranked", "ws-wmult", "ws-wmult-deque"):
        assignment, counts, stats = run_lockstep_rounds(
            tails, 4, mode=mode, sync_every=sync_every
        )
        assert (counts >= 1).all(), (mode, tails, counts)
        assert counts.max() <= 4
        # per-worker no repeats (weak multiplicity)
        for w in range(4):
            col = [int(t) for t in assignment[:, w] if t >= 0]
            assert len(col) == len(set(col))


# ---------------------------------------------------------------------------
# gradient accumulation: multiplicity-corrected grads are EXACT
# ---------------------------------------------------------------------------


def _toy_loss(params, micro):
    # micro: dict(x=[n_w, d]); per-worker quadratic loss
    return ((micro["x"] - params["w"]) ** 2).mean(axis=-1)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("tails", [[4, 4, 4, 4], [10, 2, 2, 2]])
def test_ws_accumulate_matches_full_batch(mode, tails):
    if mode == "ws-mult" and tails == [10, 2, 2, 2]:
        pytest.skip("claims mode needs max_rounds=n_tasks on skew (see honest test)")
    """1/count weighting makes the relaxed schedule's gradient IDENTICAL to the
    exact full-batch gradient — multiplicity is free for SGD."""
    rng = np.random.default_rng(0)
    n_tasks = sum(tails)
    batch = {"x": jnp.asarray(rng.normal(size=(n_tasks, 8)), dtype=jnp.float32)}
    params = {"w": jnp.asarray(rng.normal(size=(8,)), dtype=jnp.float32)}

    loss, grads, aux = ws_accumulate_grads(
        _toy_loss,
        params,
        batch,
        jnp.asarray(tails, dtype=jnp.int32),
        n_workers=4,
        mode=mode,
        slack=4,
    )
    assert float(aux["coverage"]) == 1.0, aux

    # reference: plain mean over all tasks
    def ref_loss(p):
        return ((batch["x"] - p["w"]) ** 2).mean(axis=-1).mean()

    ref_l, ref_g = jax.value_and_grad(ref_loss)(params)
    np.testing.assert_allclose(float(loss), float(ref_l), rtol=1e-5)
    np.testing.assert_allclose(grads["w"], ref_g["w"], rtol=1e-5, atol=1e-6)


def test_ws_accumulate_duplicates_still_exact():
    """Force staleness-heavy config (sync_every large) and verify exactness."""
    tails = [12, 0, 0, 0]
    n_tasks = 12
    rng = np.random.default_rng(1)
    batch = {"x": jnp.asarray(rng.normal(size=(n_tasks, 4)), dtype=jnp.float32)}
    params = {"w": jnp.asarray(rng.normal(size=(4,)), dtype=jnp.float32)}
    loss, grads, aux = ws_accumulate_grads(
        _toy_loss, params, batch, jnp.asarray(tails, dtype=jnp.int32),
        n_workers=4, mode="ws-wmult", sync_every=3, slack=8,
    )
    assert float(aux["coverage"]) == 1.0
    assert int(aux["extractions"]) >= n_tasks  # duplicates happened or not; >= is the relaxation

    def ref_loss(p):
        return ((batch["x"] - p["w"]) ** 2).mean(axis=-1).mean()

    ref_l, ref_g = jax.value_and_grad(ref_loss)(params)
    np.testing.assert_allclose(float(loss), float(ref_l), rtol=1e-5)
    np.testing.assert_allclose(grads["w"], ref_g["w"], rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# async simulator
# ---------------------------------------------------------------------------


def test_async_sim_stealing_beats_static_with_straggler():
    rng = np.random.default_rng(0)
    n_tasks, n_workers = 256, 8
    durations = rng.lognormal(mean=-7, sigma=0.5, size=n_tasks)
    owner = np.arange(n_tasks) % n_workers
    speed = np.ones(n_workers)
    speed[0] = 0.25  # straggler

    r_static = async_makespan(durations, owner, n_workers, "static", worker_speed=speed)
    r_wmult = async_makespan(durations, owner, n_workers, "ws-wmult", worker_speed=speed)
    assert r_wmult.makespan < 0.7 * r_static.makespan, (r_static, r_wmult)


def test_async_sim_wswmult_avoids_sync_cost():
    rng = np.random.default_rng(0)
    n_tasks, n_workers = 512, 8
    durations = np.full(n_tasks, 2e-6)  # tiny tasks: sync cost dominates
    owner = np.arange(n_tasks) % n_workers
    r_mult = async_makespan(durations, owner, n_workers, "ws-mult", sync_cost=5e-6)
    r_wmult = async_makespan(
        durations, owner, n_workers, "ws-wmult", refresh_period=1e-4
    )
    assert r_wmult.makespan < r_mult.makespan, (r_mult, r_wmult)
    assert r_mult.sync_time > 0 and r_wmult.sync_time == 0

"""Per-architecture smoke tests (reduced configs, CPU, one real device).

For every assigned arch: (1) forward + grad of the training loss on a tiny
batch — shapes and finiteness; (2) prefill -> step-by-step decode must
reproduce the last-token logits of a longer prefill (validates KV/SSM cache
updates, RoPE offsets, window masks and the MLA absorbed-decode identity).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, cell_plan, get_config
from repro.models import (
    Caches,
    decode_step,
    init_caches,
    init_params,
    loss_fn,
    prefill,
)

B, S = 2, 16


def _batch(cfg, key, seq=S):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, seq), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(ks[1], (B, cfg.n_patches, cfg.d_model)) * 0.02
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(ks[2], (B, cfg.enc_seq_len, cfg.d_model)) * 0.02
    return batch


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_forward_and_grad(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = init_params(rng, cfg)
    batch = _batch(cfg, jax.random.fold_in(rng, 1))

    def scalar_loss(p):
        loss, metrics = loss_fn(p, cfg, batch)
        return loss, metrics

    (loss, metrics), grads = jax.jit(jax.value_and_grad(scalar_loss, has_aux=True))(params)
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert float(loss) > 0
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(l))) for l in leaves), arch
    # at least one nonzero grad leaf
    assert any(float(jnp.abs(l).max()) > 0 for l in leaves), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_equivalence(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.fold_in(rng, 2), cfg)
    batch = _batch(cfg, jax.random.fold_in(rng, 3))
    tokens = batch["tokens"]
    T0 = S // 2
    offset = cfg.n_patches if cfg.family == "vlm" else 0
    cap = S + offset

    def sub(b, t):
        out = dict(b)
        out["tokens"] = b["tokens"][:, :t]
        return out

    logits_full, _ = jax.jit(lambda p, b: prefill(p, cfg, b, capacity=cap))(params, batch)

    _, caches = jax.jit(lambda p, b: prefill(p, cfg, b, capacity=cap))(
        params, sub(batch, T0)
    )
    dec = jax.jit(
        lambda p, c, t, pos: decode_step(params, cfg, c, t, pos),
        static_argnums=(),
    )
    logits = None
    for i in range(T0, S):
        logits, caches = decode_step(
            params, cfg, caches, tokens[:, i : i + 1], jnp.int32(offset + i)
        )
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(logits_full), rtol=2e-3, atol=2e-3
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_init_caches_shapes(arch, rng):
    cfg = get_config(arch, smoke=True)
    caches = init_caches(cfg, batch=B, capacity=S)
    assert isinstance(caches, Caches)
    leaves = jax.tree_util.tree_leaves(caches)
    assert leaves, arch
    for l in leaves:
        assert np.all(np.isfinite(np.asarray(l, dtype=np.float32)))


def test_cell_plan_rules():
    assert cell_plan(get_config("llama3.2-3b"))["long_500k"].startswith("skip")
    assert cell_plan(get_config("mamba2-2.7b"))["long_500k"] == "run"
    assert cell_plan(get_config("h2o-danube-1.8b"))["long_500k"] == "run"
    assert cell_plan(get_config("gemma3-12b"))["long_500k"] == "run"
    assert cell_plan(get_config("whisper-base"))["decode_32k"].startswith("skip")
    plan = cell_plan(get_config("deepseek-v2-236b"))
    assert plan["train_4k"] == "run" and plan["prefill_32k"] == "run"


def test_param_counts_match_scale():
    # analytic param_count should be in the right ballpark for the big archs
    import math

    expect = {
        "llama3.2-3b": (2.5e9, 4.5e9),
        "deepseek-v2-236b": (1.8e8 * 1000, 2.8e8 * 1000),  # 180-280B
        "kimi-k2-1t-a32b": (0.8e12, 1.3e12),
        "mamba2-2.7b": (2.0e9, 3.4e9),
        "zamba2-2.7b": (2.0e9, 3.4e9),
        "gemma3-12b": (9e9, 15e9),
        "pixtral-12b": (9e9, 15e9),
        "minicpm-2b": (1.8e9, 3.2e9),
        "h2o-danube-1.8b": (1.4e9, 2.4e9),
        "whisper-base": (5e7, 1.5e8),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, f"{n:.3e}", lo, hi)


def test_pad_heads_exact_equivalence(rng):
    """Zero-padded attention heads must be forward- AND gradient-equivalent.

    Padding is applied by reusing the unpadded weights inside the padded
    allocation (group-major for GQA), so logits and grads must match the
    unpadded model exactly (§Perf hillclimb #1 safety proof).
    """
    cfg = get_config("llama3.2-3b", smoke=True)  # 4H/2kv, G=2
    cfg_p = cfg.replace(pad_heads=True)
    # force a padding situation: pretend mesh multiple is irrelevant; eff
    # pads only when % 16 != 0 — smoke 4H pads to 16.
    assert cfg_p.eff_heads[0] > cfg.n_heads

    params = init_params(rng, cfg)
    params_p = init_params(rng, cfg_p)
    # graft the real weights into the padded allocation (group-major):
    # wq/wo head axis is dim 1 / dim 0 resp.; wk/wv head axis is dim 1.
    H, Hkv, G = cfg.n_heads, cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    Hp, Hkvp = cfg_p.eff_heads
    Gp = Hp // Hkvp
    la, lp = params["layers"]["attn"], params_p["layers"]["attn"]
    wq = np.zeros(lp["wq"].shape, np.float32)
    wo = np.zeros(lp["wo"].shape, np.float32)
    for kv in range(Hkv):
        for g in range(G):
            wq[:, :, kv * Gp + g, :] = np.asarray(la["wq"])[:, :, kv * G + g, :]
            wo[:, kv * Gp + g, :, :] = np.asarray(la["wo"])[:, kv * G + g, :, :]
    wk = np.zeros(lp["wk"].shape, np.float32)
    wv = np.zeros(lp["wv"].shape, np.float32)
    wk[:, :, :Hkv, :] = np.asarray(la["wk"])
    wv[:, :, :Hkv, :] = np.asarray(la["wv"])
    params_p["layers"]["attn"] = {
        "wq": jnp.asarray(wq), "wk": jnp.asarray(wk),
        "wv": jnp.asarray(wv), "wo": jnp.asarray(wo),
    }
    for k in ("embed", "unembed", "final_norm"):
        params_p[k] = params[k]
    params_p["layers"]["attn_norm"] = params["layers"]["attn_norm"]
    params_p["layers"]["mlp_norm"] = params["layers"]["mlp_norm"]
    params_p["layers"]["mlp"] = params["layers"]["mlp"]

    batch = _batch(cfg, jax.random.fold_in(rng, 9))
    (l0, _), g0 = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
    (l1, _), g1 = jax.value_and_grad(lambda p: loss_fn(p, cfg_p, batch), has_aux=True)(params_p)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
    # mlp grads identical; padded attention slices must have ZERO grads
    np.testing.assert_allclose(
        np.asarray(g0["layers"]["mlp"]["wg"]), np.asarray(g1["layers"]["mlp"]["wg"]),
        rtol=1e-4, atol=1e-6,
    )
    gq = np.asarray(g1["layers"]["attn"]["wq"])
    pad_heads_idx = [kv * Gp + g for kv in range(Hkvp) for g in range(Gp)
                     if not (g < G and kv < Hkv)]
    assert np.abs(gq[:, :, pad_heads_idx, :]).max() < 1e-6

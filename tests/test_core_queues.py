"""Unit tests: sequential behaviour of every queue + MaxRegister objects."""

import pytest

from repro.core import (
    ALGORITHMS,
    EMPTY,
    AtomicMaxRegister,
    RangeMaxRegister,
    ThreadBackend,
    TreeMaxRegister,
)
from repro.core.simulator import ExactFIFOOracle, ExactLIFOOracle, run_sequential

FIFO_ALGOS = [
    "ws-mult", "ws-wmult", "b-ws-mult", "b-ws-wmult", "exact-ws",
    "idempotent-fifo", "pallas-ws", "moe-ws",
]
DEQUE_ALGOS = ["chase-lev", "the-cilk", "idempotent-deque"]
LIFO_ALGOS = ["idempotent-lifo"]


def _oracle_for(name):
    if name in FIFO_ALGOS:
        return ExactFIFOOracle()
    if name in LIFO_ALGOS:
        return ExactLIFOOracle(steal_end="tail")
    return ExactLIFOOracle(steal_end="head")


SEQ_PROGRAMS = [
    # (pid, kind, arg) sequences exercising put/take/steal/empty transitions
    [(0, "put", 1), (0, "put", 2), (0, "take", None), (1, "steal", None),
     (0, "take", None), (1, "steal", None)],
    [(0, "take", None), (1, "steal", None), (0, "put", 1), (1, "steal", None),
     (1, "steal", None), (0, "take", None)],
    [(0, "put", i) for i in range(1, 9)]
    + [(0, "take", None)] * 3 + [(1, "steal", None)] * 3 + [(2, "steal", None)] * 4,
    [(0, "put", 1), (0, "take", None), (0, "put", 2), (0, "put", 3),
     (1, "steal", None), (0, "take", None), (2, "steal", None), (2, "steal", None)],
]


@pytest.mark.parametrize("name", sorted(ALGORITHMS))
@pytest.mark.parametrize("prog_i", range(len(SEQ_PROGRAMS)))
def test_sequentially_exact(name, prog_i):
    """Every algorithm behaves exactly (no relaxation) in sequential executions.

    This is Remark 3.1 / the sequentially-exact requirement of §4 for the
    paper's algorithms, and plain correctness for the baselines.
    """
    prog = SEQ_PROGRAMS[prog_i]
    q = ALGORITHMS[name]()
    oracle = _oracle_for(name)
    got = run_sequential(q, prog)
    want = run_sequential(oracle, prog)
    assert [g[3] for g in got] == [w[3] for w in want], f"{name} diverges from oracle"


@pytest.mark.parametrize("name", sorted(ALGORITHMS))
def test_drain_everything(name):
    q = ALGORITHMS[name]()
    for i in range(100):
        q.put(i)
    got = []
    while True:
        x = q.take()
        if x is EMPTY:
            break
        got.append(x)
    assert sorted(got) == list(range(100))
    assert q.take() is EMPTY
    assert q.steal(1) is EMPTY


@pytest.mark.parametrize("name", ["ws-mult", "ws-wmult", "b-ws-wmult"])
@pytest.mark.parametrize("storage", ["infinite", "growable", "linked"])
def test_storage_schemes(name, storage):
    """§6: the finite-array schemes are drop-in replacements."""
    kw = {"storage": storage}
    if storage in ("growable",):
        kw["initial_len"] = 8
    if storage == "linked":
        kw["node_len"] = 8
    q = ALGORITHMS[name](**kw)
    for i in range(1000):  # forces several expansions / node links
        q.put(i)
    out = []
    for _ in range(500):
        out.append(q.take())
    for i in range(1000, 1500):
        q.put(i)
    while True:
        x = q.steal(1)
        if x is EMPTY:
            break
        out.append(x)
    assert [x for x in out if x is not EMPTY] == list(range(1500))


def test_tree_max_register_monotone():
    m = TreeMaxRegister(capacity=64)
    assert m.max_read() == 0
    for v, want in [(5, 5), (3, 5), (17, 17), (16, 17), (63, 63), (2, 63)]:
        m.max_write(v)
        assert m.max_read() == want


def test_tree_max_register_capacity_pow2_rounding():
    m = TreeMaxRegister(capacity=100)
    assert m.capacity == 128
    m.max_write(99)
    assert m.max_read() == 99
    with pytest.raises(ValueError):
        m.max_write(128)


def test_tree_max_register_sweep_against_running_max():
    import random

    rng = random.Random(0)
    m = TreeMaxRegister(capacity=1024)
    cur = 0
    for _ in range(500):
        v = rng.randrange(1024)
        m.max_write(v)
        cur = max(cur, v)
        assert m.max_read() == cur


def test_atomic_max_register():
    m = AtomicMaxRegister(init=1)
    m.max_write(10)
    m.max_write(4)
    assert m.max_read() == 10


def test_range_max_register_sequential_is_exact():
    """Theorem 4.4: in sequential executions the RangeMaxRegister behaves as a
    MaxRegister."""
    r = RangeMaxRegister(init=1)
    cur = 1
    import random

    rng = random.Random(1)
    for _ in range(200):
        pid = rng.randrange(4)
        if rng.random() < 0.5:
            v = rng.randrange(1, 100)
            r.rmax_write(v, pid)
            cur = max(cur, v)
        else:
            assert r.rmax_read(pid) == cur


def test_range_max_register_range_property():
    """RMaxRead returns a value in [local lower bound, true max]."""
    r = RangeMaxRegister(init=1)
    r.rmax_write(10, pid=0)
    # pid 1 has never seen anything: its read must be in [1, 10]
    got = r.rmax_read(pid=1)
    assert 1 <= got <= 10
    # after reading, its lower bound has risen
    assert r.rmax_read(pid=1) >= got


def test_wsmult_uninitialized_read_guard():
    """The paper's two-slot-⊥ invariant: thieves never read UNINIT memory."""
    from repro.core import UNINIT, WSMult

    q = WSMult(max_register="atomic")
    q.put("a")
    assert q.steal(1) == "a"
    # Head is now 2; slots 2 and 3 were initialized ⊥ by init+put.
    assert q.steal(1) is EMPTY
    assert q.steal(2) is EMPTY


def test_put_order_irrelevant():
    """Line 2's brace notation: both write orders behave identically."""
    for order in ("task_first", "bottom_first"):
        q = ALGORITHMS["ws-wmult"](put_order=order)
        for i in range(10):
            q.put(i)
        got = [q.take() for _ in range(10)]
        assert got == list(range(10))

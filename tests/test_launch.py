"""Launch-layer tests: train loop, WS-gradient exactness, resume, dry-run
smoke (subprocess with forced host devices), HLO analysis."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.hlo_analysis import analyze
from repro.launch.steps import make_optimizer, make_train_step, train_policy
from repro.models import init_params
from repro.sched import MODES

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))


# ---------------------------------------------------------------------------
# work-stealing gradient EXACTNESS: every scheduler mode must produce the
# same updated parameters as the plain full-batch step (the 1/count
# multiplicity correction makes the relaxation exact for SGD).


@pytest.mark.parametrize("mode", MODES)
def test_ws_modes_match_plain_step(mode):
    cfg = get_config("llama3.2-3b", smoke=True)
    opt = make_optimizer(cfg, total_steps=10)
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = {"params": params, "opt": opt.init(params)}

    n_tasks, rows, seq, n_workers = 8, 2, 16, 4
    tokens = jax.random.randint(jax.random.PRNGKey(1), (n_tasks, rows, seq), 0, cfg.vocab_size)
    tails = jnp.array([5, 1, 1, 1], jnp.int32)  # skewed queues

    plain_step = jax.jit(make_train_step(cfg, opt))
    plain_state, plain_metrics = plain_step(
        state, {"tokens": tokens.reshape(n_tasks * rows, seq)}
    )

    ws_step = jax.jit(make_train_step(cfg, opt, ws_mode=mode, n_workers=n_workers))
    ws_state, ws_metrics = ws_step(state, {"tokens": tokens, "tails": tails})

    assert float(ws_metrics.get("ws_coverage", 1.0)) == 1.0  # at-least-once
    np.testing.assert_allclose(
        float(ws_metrics["loss"]), float(plain_metrics["loss"]), rtol=2e-5
    )
    for a, b in zip(
        jax.tree_util.tree_leaves(ws_state["params"]),
        jax.tree_util.tree_leaves(plain_state["params"]),
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-4, atol=3e-5)


def test_train_loss_decreases(tmp_path):
    from repro.launch.train import train

    _, losses = train(
        "llama3.2-3b", smoke=True, steps=30, rows=4, seq=32, lr=5e-3,
        ckpt_dir=str(tmp_path / "ck"), ckpt_every=10, log_every=50,
    )
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_train_resume_continues(tmp_path):
    from repro.checkpoint import latest_step
    from repro.launch.train import train

    d = str(tmp_path / "ck")
    train("llama3.2-3b", smoke=True, steps=11, rows=2, seq=16, ckpt_dir=d, ckpt_every=5, log_every=50)
    s0 = latest_step(d)
    assert s0 == 10
    _, losses = train(
        "llama3.2-3b", smoke=True, steps=16, rows=2, seq=16, ckpt_dir=d,
        ckpt_every=5, resume=True, log_every=50,
    )
    assert latest_step(d) == 15
    assert len(losses) == 5  # only steps 11..15 ran


def test_train_policy_tiers():
    assert train_policy(get_config("llama3.2-3b"))["fsdp"] is False
    assert train_policy(get_config("gemma3-12b"))["fsdp"] is True
    pol = train_policy(get_config("kimi-k2-1t-a32b"))
    assert pol["fsdp"] == "pods" and pol["optimizer"] == "adafactor_momentum"


# ---------------------------------------------------------------------------
# dry-run smoke: the real dryrun.py code path on 8 forced host devices,
# one arch per step-kind family.


@pytest.mark.parametrize(
    "arch,shape",
    [
        ("llama3.2-3b", "train_4k"),
        ("deepseek-v2-236b", "decode_32k"),
        ("mamba2-2.7b", "prefill_32k"),
        ("zamba2-2.7b", "long_500k"),
        ("whisper-base", "train_4k"),
    ],
)
def test_dryrun_smoke_subprocess(arch, shape, tmp_path):
    out = str(tmp_path / "rec.jsonl")
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--smoke", "--out", out],
        env=ENV, capture_output=True, text=True, timeout=600, cwd=ROOT,
    )
    assert p.returncode == 0, p.stderr[-3000:]
    rec = json.loads(open(out).read().strip())
    assert rec["plan"] == "run"
    assert rec["compile_s"] > 0
    assert rec["hlo_flops_per_device"] > 0
    assert rec["bottleneck"] in ("compute_s", "memory_s", "collective_s")


def test_dryrun_smoke_multipod(tmp_path):
    out = str(tmp_path / "rec.jsonl")
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "llama3.2-3b",
         "--shape", "train_4k", "--smoke", "--multi-pod", "--out", out],
        env=ENV, capture_output=True, text=True, timeout=600, cwd=ROOT,
    )
    assert p.returncode == 0, p.stderr[-3000:]
    rec = json.loads(open(out).read().strip())
    assert rec["mesh"] == "2x2x2" and rec["plan"] == "run"


# ---------------------------------------------------------------------------
# HLO analysis unit tests (crafted fixture: while loop with trip count 5)

_FIXTURE = """
HloModule test, entry_computation_layout={()->f32[8,16]{1,0}}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %trip = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %trip), direction=LT
}

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %ar = f32[8,16]{1,0} all-reduce(%x), replica_groups={}, to_apply=%sum
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%ip, %ar)
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main () -> f32[8,16] {
  %init = f32[8,16]{1,0} constant(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8,16]{1,0}) tuple(%zero, %init)
  %w = (s32[], f32[8,16]{1,0}) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_analysis_trip_counts():
    res = analyze(_FIXTURE)
    ar = res["per_kind"]["all-reduce"]
    assert ar["count"] == 5  # 1 op x trip 5
    assert ar["bytes"] == 5 * 8 * 16 * 4
    assert res["collective_bytes"] == ar["bytes"]


def test_hlo_analysis_dot_flops():
    hlo = """
HloModule t, entry_computation_layout={()->f32[4,6]{1,0}}

ENTRY %main () -> f32[4,6] {
  %a = f32[4,8]{1,0} constant(0)
  %b = f32[8,6]{1,0} constant(0)
  ROOT %d = f32[4,6]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    res = analyze(hlo)
    assert res["flops"] == 2 * 4 * 6 * 8

"""Gradient conformance suite for the differentiable dropless dispatch.

The WS-WMULT expert dispatch computes exactly the no-drop MoE function
(multiplicity-count normalization makes duplicated tile executions
idempotent), so the correct VJP of the megakernel *is* the VJP of
``expert_ffn_nodrop_ref`` — that identity is what the custom VJP in
``repro.moe_ws.layer`` implements, and what this suite certifies:

1. core parity — ``jax.grad`` of ``expert_ffn_ws`` matches ``jax.grad`` of
   the no-drop reference to fp32 tolerance over adversarial routings
   (skewed, uniform, empty-expert, duplicate-token, repeated-expert),
   hypothesis-drawn plus always-run seeded slices, across
   ``queue_layout`` × ``steal_policy`` × ``grad_dispatch`` × schedule;
2. ``jax.test_util.check_grads`` on the custom VJP (numerical vjp check);
3. layer parity — ``moe_ffn_ws`` gradients (x AND every param: router,
   expert weights, shared experts; aux loss included) match the oracle's,
   eager, under ``jit``, and under ``scan``-over-layers;
4. multiplicity invariance — the backward's per-row tile launch is driven
   through an adversarial head-rewind drill: every grad tile re-executed,
   the divisor normalizes it out, gradients bit-identical.  Backward
   gradients are also bit-identical across steal policies (schedule order
   cannot leak into the VJP);
5. no silent dense substitution on the training path (lm_hidden probe) and
   a 3-step train-step regression: ws tracks dense where dense is
   drop-free, diverges where dense drops tokens;
6. the zero-cost audit of the backward lowering: the VJP's forward and
   ``grad_dispatch="ws"`` backward launches contain 0 RMW / 0 locks /
   0 fences (``benchmarks.zero_cost.audit_traced_put``).
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402
from jax.test_util import check_grads  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.models.moe import init_moe  # noqa: E402
from repro.moe_ws import (  # noqa: E402
    expert_ffn_nodrop_ref,
    expert_ffn_ws,
    moe_ffn_nodrop_ref,
    moe_ffn_ws,
    route_to_tasks_pool_jax,
    run_moe_grad_schedule,
)
from repro.moe_ws.layer import (  # noqa: E402
    _assemble_row_grads,
    _grad_dense,
)
from repro.pallas_ws import make_pool_queue_state_jax  # noqa: E402

try:
    from hypothesis import given
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dep — seeded slices still run
    HAVE_HYPOTHESIS = False


def _smoke_cfg(**kw):
    cfg = get_config("deepseek-v2-236b", smoke=True)
    return cfg.replace(**kw) if kw else cfg


def _core_case(seed=0, T=10, E=5, k=2, d=8, f=16, kind="uniform"):
    """One routed-core problem instance.  ``kind`` shapes the routing:
    uniform, skewed (hot expert 0), empty-expert (expert E-1 never routed),
    duplicate-token (token rows repeated), repeat-expert (a token lists the
    same expert twice — the shared-pool layout must carry it)."""
    rng = np.random.RandomState(seed)
    if kind == "skewed":
        # expert 0 takes every token's first choice
        rest = np.stack([rng.choice(np.arange(1, E), k - 1, replace=False)
                         for _ in range(T)]) if k > 1 else np.zeros((T, 0), int)
        idx = np.concatenate([np.zeros((T, 1), int), rest], axis=1)
    elif kind == "empty-expert":
        idx = np.stack([rng.choice(E - 1, k, replace=False) for _ in range(T)])
    elif kind == "repeat-expert":
        e = rng.randint(E, size=(T, 1))
        idx = np.concatenate([e] * k, axis=1)  # same expert k times
    else:
        idx = np.stack([rng.choice(E, k, replace=False) for _ in range(T)])
    idx = idx.astype(np.int32)
    gates = rng.uniform(0.1, 1.0, (T, k)).astype(np.float32)
    gates /= gates.sum(1, keepdims=True)
    x = rng.randn(T, d).astype(np.float32)
    if kind == "duplicate-token":
        x[1::2] = x[0::2][: x[1::2].shape[0]]
        idx[1::2] = idx[0::2][: idx[1::2].shape[0]]
    wg = (rng.randn(E, d, f) / np.sqrt(d)).astype(np.float32)
    wu = (rng.randn(E, d, f) / np.sqrt(d)).astype(np.float32)
    wd = (rng.randn(E, f, d) / np.sqrt(f)).astype(np.float32)
    return idx, gates, x, wg, wu, wd


def _core_grads(fn, idx, gates, x, wg, wu, wd):
    """d/d(gates, x, wg, wu, wd) of sum(fn(...)**2) — a curvature-carrying
    scalarization so every cotangent direction is exercised."""
    def loss(gates, x, wg, wu, wd):
        return (fn(idx, gates, x, wg, wu, wd) ** 2).sum()

    return jax.grad(loss, argnums=(0, 1, 2, 3, 4))(gates, x, wg, wu, wd)


def _assert_grads_close(got, want, atol=2e-4, rtol=2e-4):
    for g, w, name in zip(got, want, ("gates", "x", "wg", "wu", "wd")):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=rtol, atol=atol,
            err_msg=f"gradient mismatch on {name}",
        )


# ---------------------------------------------------------------------------
# 1. core parity vs the no-drop reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("grad_dispatch", ["dense", "ws"])
@pytest.mark.parametrize("steal_policy", ["cost", "scan"])
@pytest.mark.parametrize("queue_layout", ["pool", "padded"])
def test_core_grad_matches_nodrop_ref(queue_layout, steal_policy, grad_dispatch):
    idx, gates, x, wg, wu, wd = _core_case(seed=1)
    want = _core_grads(expert_ffn_nodrop_ref, idx, gates, x, wg, wu, wd)

    def ws(idx, gates, x, wg, wu, wd):
        return expert_ffn_ws(
            idx, gates, x, wg, wu, wd, queue_layout=queue_layout,
            steal_policy=steal_policy, grad_dispatch=grad_dispatch,
            n_programs=4, bt=4,
        )

    _assert_grads_close(_core_grads(ws, idx, gates, x, wg, wu, wd), want)
    # acceptance shape: jit(grad) of a .sum() objective, no TypeError
    jg = jax.jit(jax.grad(
        lambda xx: ws(idx, gates, xx, wg, wu, wd).sum()
    ))(x)
    jw = jax.grad(
        lambda xx: expert_ffn_nodrop_ref(idx, gates, xx, wg, wu, wd).sum()
    )(x)
    np.testing.assert_allclose(np.asarray(jg), np.asarray(jw),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("schedule", ["ws", "static"])
def test_core_grad_under_both_schedules(schedule):
    """The backward is schedule-independent (it differentiates the function
    the scheduler computes, not the schedule): static-baseline forwards get
    the same gradients."""
    idx, gates, x, wg, wu, wd = _core_case(seed=2)
    want = _core_grads(expert_ffn_nodrop_ref, idx, gates, x, wg, wu, wd)

    def ws(idx, gates, x, wg, wu, wd):
        return expert_ffn_ws(idx, gates, x, wg, wu, wd, schedule=schedule,
                             n_programs=4, bt=4)

    _assert_grads_close(_core_grads(ws, idx, gates, x, wg, wu, wd), want)


KINDS = ("uniform", "skewed", "empty-expert", "duplicate-token",
         "repeat-expert")


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("grad_dispatch", ["dense", "ws"])
def test_core_grad_adversarial_routings_seeded(kind, grad_dispatch):
    """Always-run seeded slice of the hypothesis sweep: the four adversarial
    routing shapes (plus uniform) from the suite docstring."""
    idx, gates, x, wg, wu, wd = _core_case(seed=3, T=9, E=4, k=2, kind=kind)
    want = _core_grads(expert_ffn_nodrop_ref, idx, gates, x, wg, wu, wd)

    def ws(idx, gates, x, wg, wu, wd):
        return expert_ffn_ws(idx, gates, x, wg, wu, wd,
                             grad_dispatch=grad_dispatch, n_programs=3, bt=4)

    _assert_grads_close(_core_grads(ws, idx, gates, x, wg, wu, wd), want)


if HAVE_HYPOTHESIS:

    @given(
        seed=st.integers(0, 2**16),
        T=st.integers(1, 10),
        E=st.integers(2, 6),
        k=st.integers(1, 3),
        kind=st.sampled_from(KINDS),
        grad_dispatch=st.sampled_from(["dense", "ws"]),
    )
    def test_core_grad_matches_ref_hypothesis(seed, T, E, k, kind,
                                              grad_dispatch):
        k = min(k, E - 1) or 1
        idx, gates, x, wg, wu, wd = _core_case(
            seed=seed, T=T, E=E, k=k, d=4, f=8, kind=kind
        )
        want = _core_grads(expert_ffn_nodrop_ref, idx, gates, x, wg, wu, wd)

        def ws(idx, gates, x, wg, wu, wd):
            return expert_ffn_ws(idx, gates, x, wg, wu, wd,
                                 grad_dispatch=grad_dispatch,
                                 n_programs=3, bt=4)

        _assert_grads_close(_core_grads(ws, idx, gates, x, wg, wu, wd), want)


# ---------------------------------------------------------------------------
# 2. numerical check of the custom VJP
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("grad_dispatch", ["dense", "ws"])
def test_check_grads_on_custom_vjp(grad_dispatch):
    idx, gates, x, wg, wu, wd = _core_case(seed=4, T=6, E=3, k=2, d=4, f=8)

    def f(gates, x, wg, wu, wd):
        return expert_ffn_ws(idx, gates, x, wg, wu, wd,
                             grad_dispatch=grad_dispatch, n_programs=3, bt=4)

    check_grads(f, (gates, x, wg, wu, wd), order=1, modes=["rev"],
                atol=5e-2, rtol=5e-2)


# ---------------------------------------------------------------------------
# 3. layer-level parity: router, aux loss, shared experts, jit, scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("grad_dispatch", ["dense", "ws"])
def test_layer_grads_match_oracle_including_router_and_aux(grad_dispatch):
    """Full-layer gradients — x and every param (router via gates AND aux
    loss, expert weights through the VJP, shared experts outside it) —
    match the no-drop oracle's."""
    cfg = _smoke_cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))

    def loss_ws(p, x):
        y, aux = moe_ffn_ws(x, p, cfg, n_programs=4, bt=4,
                            grad_dispatch=grad_dispatch)
        return (y ** 2).sum() + aux

    def loss_ref(p, x):
        y, aux = moe_ffn_nodrop_ref(x, p, cfg)
        return (y ** 2).sum() + aux

    gp, gx = jax.grad(loss_ws, argnums=(0, 1))(p, x)
    rp, rx = jax.grad(loss_ref, argnums=(0, 1))(p, x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx),
                               rtol=1e-4, atol=1e-4)
    for name in rp:
        np.testing.assert_allclose(
            np.asarray(gp[name]), np.asarray(rp[name]), rtol=1e-4, atol=1e-4,
            err_msg=f"param gradient mismatch on {name}",
        )
    # aux-loss-only gradients flow through the same VJP'd layer unchanged
    ga = jax.grad(lambda p: moe_ffn_ws(x, p, cfg, n_programs=4, bt=4,
                                       grad_dispatch=grad_dispatch)[1])(p)
    ra = jax.grad(lambda p: moe_ffn_nodrop_ref(x, p, cfg)[1])(p)
    np.testing.assert_allclose(np.asarray(ga["router"]),
                               np.asarray(ra["router"]),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("grad_dispatch", ["dense", "ws"])
def test_layer_grads_under_jit_and_scan(grad_dispatch):
    """jit(value_and_grad) and jit(grad(scan-over-layers)) both run the
    custom VJP and match an eager no-drop reference loop."""
    cfg = _smoke_cfg(n_shared_experts=0)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, cfg.d_model))
    ps = jax.vmap(lambda k: init_moe(k, cfg, jnp.float32))(
        jax.random.split(jax.random.PRNGKey(3), 2)
    )

    def scan_loss(ps):
        def body(h, pl):
            y, aux = moe_ffn_ws(h, pl, cfg, n_programs=4, bt=4,
                                grad_dispatch=grad_dispatch)
            return h + y, aux
        h, auxs = jax.lax.scan(body, x, ps)
        return (h ** 2).sum() + auxs.sum()

    def ref_loss(ps):
        h, auxs = x, 0.0
        for i in range(2):
            pl = jax.tree_util.tree_map(lambda a: a[i], ps)
            y, aux = moe_ffn_nodrop_ref(h, pl, cfg)
            h, auxs = h + y, auxs + aux
        return (h ** 2).sum() + auxs

    v, g = jax.jit(jax.value_and_grad(scan_loss))(ps)
    rv, rg = jax.value_and_grad(ref_loss)(ps)
    assert abs(float(v) - float(rv)) < 1e-3
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4
        ),
        g, rg,
    )


# ---------------------------------------------------------------------------
# 4. multiplicity cannot leak into the backward
# ---------------------------------------------------------------------------


def test_backward_multiplicity_normalization_under_head_rewind():
    """Adversarial duplicate execution of the backward's grad tiles: rewind
    every head and wipe the local bounds after a full drain, relaunch with
    carried out/mult — every grad tile re-executes (mult == 2), and the
    assembled gradients are bit-identical to the single-launch ones and
    match the closed-form dense transpose."""
    idx, gates, x, wg, wu, wd = _core_case(seed=5, T=8, E=4, k=2, d=4, f=8)
    T, k = idx.shape
    bt, P = 4, 4
    gy = jnp.asarray(np.random.RandomState(9).randn(T, 4), jnp.float32)

    records, tail, pool_off, routed = route_to_tasks_pool_jax(
        idx, gates, wg.shape[0], bt=bt
    )
    state = make_pool_queue_state_jax(
        records, tail, pool_off, routed.loads, P, n_tasks=records.shape[0]
    )
    res1 = run_moe_grad_schedule(
        state, x, gy, routed.tok_idx, routed.gates, wg, wu, wd, bt=bt
    )
    n_live = int(np.asarray(state.tail).sum())
    assert (np.asarray(res1.mult)[:n_live] == 1).all()
    g1 = _assemble_row_grads(res1, routed, idx, x, gy, bt=bt, d=4, f=8,
                             n_experts=wg.shape[0])

    state.head = jnp.zeros_like(state.head)
    state.local_head = jnp.zeros_like(state.local_head)
    res2 = run_moe_grad_schedule(
        state, x, gy, routed.tok_idx, routed.gates, wg, wu, wd, bt=bt,
        out=res1.out, mult=jnp.asarray(res1.mult),
    )
    assert (np.asarray(res2.mult)[:n_live] == 2).all(), "every tile re-ran"
    g2 = _assemble_row_grads(res2, routed, idx, x, gy, bt=bt, d=4, f=8,
                             n_experts=wg.shape[0])
    for a, b in zip(g1, g2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    dense = _grad_dense(x, idx, gates, wg, wu, wd, gy)
    for a, b in zip(g2, dense):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


def test_backward_bit_identical_across_steal_policies():
    """Schedule order (which program stole which grad tile) must be
    invisible to the VJP: ws-backward gradients are bit-identical across
    victim-selection policies, and across forward queue layouts."""
    idx, gates, x, wg, wu, wd = _core_case(seed=6)

    def grads(policy, layout):
        def ws(idx, gates, x, wg, wu, wd):
            return expert_ffn_ws(idx, gates, x, wg, wu, wd,
                                 steal_policy=policy, queue_layout=layout,
                                 grad_dispatch="ws", n_programs=4, bt=4)
        return _core_grads(ws, idx, gates, x, wg, wu, wd)

    base = grads("cost", "pool")
    for policy, layout in (("scan", "pool"), ("cost", "padded"),
                           ("scan", "padded")):
        for a, b in zip(grads(policy, layout), base):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# 5. training path: no silent dense substitution + 3-step regression
# ---------------------------------------------------------------------------


def test_no_silent_dense_substitution_in_grad_path():
    """lm_hidden probe (the grad-path twin of the PR-3 forward probe): with
    a capacity-starved config the dense dispatch computes a *different
    function*, so if a dense fallback ever crept back into the
    differentiated ws path, ws-flagged gradients would collapse onto the
    dense ones.  They must not — while staying finite and nonzero."""
    from repro.models.transformer import init_params, lm_hidden

    cfg = _smoke_cfg(capacity_factor=0.25, n_shared_experts=0)
    B, S = 1, 32
    params = init_params(jax.random.PRNGKey(5), cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(6), (B, S, cfg.d_model))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def loss(params, cfg):
        h, aux = lm_hidden(params, cfg, x, positions, remat=True)
        return (h ** 2).sum() + aux

    g_ws = jax.jit(lambda p: jax.grad(loss)(p, cfg.replace(moe_dispatch="ws"))
                   )(params)
    g_d = jax.jit(lambda p: jax.grad(loss)(p, cfg))(params)
    leaves_ws = jax.tree_util.tree_leaves(g_ws)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves_ws)
    moe_diff = float(jnp.abs(g_ws["layers"]["moe"]["we_g"]
                             - g_d["layers"]["moe"]["we_g"]).max())
    assert moe_diff > 1e-5, (
        "ws-flagged MoE gradients equal the capacity-starved dense ones — "
        "dense substitution in the backward?"
    )


def _train_cfg(**kw):
    kw.setdefault("moe_dispatch", "ws")
    return _smoke_cfg(**kw)


def _run_train_steps(cfg, n_steps=3, seed=0):
    from repro.data import make_batch
    from repro.launch.steps import make_optimizer, make_train_step
    from repro.models import init_params
    from repro.models.config import ShapeConfig

    shape = ShapeConfig("custom", "train", 16, 2)
    opt = make_optimizer(cfg, total_steps=n_steps, peak_lr=1e-3)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    state = {"params": params, "opt": opt.init(params)}
    step_fn = jax.jit(make_train_step(cfg, opt))
    losses = []
    for step in range(n_steps):
        batch = {
            k: jnp.asarray(v)
            for k, v in make_batch(cfg, shape, step, n_rows=2, seed=seed).items()
        }
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    return losses


def test_train_step_ws_three_steps_matches_dense_when_dropfree():
    """The e2e regression of the archetype: >= 3 train steps with
    moe_dispatch='ws' complete with finite loss, and — because the smoke
    config's capacity factor is drop-free — the trajectory matches the
    dense-dispatch run (dense == no-drop when nothing is dropped)."""
    losses_ws = _run_train_steps(_train_cfg())
    losses_d = _run_train_steps(_train_cfg(moe_dispatch="dense"))
    assert len(losses_ws) == 3 and all(np.isfinite(losses_ws))
    np.testing.assert_allclose(losses_ws, losses_d, rtol=1e-3, atol=1e-3)


def test_train_step_ws_diverges_from_dense_when_dense_drops():
    """Documented direction of the difference: starve the dense capacity
    (cf=0.25) and the dense run silently optimizes a *lossy* objective —
    the ws (dropless) trajectory must move away from it while staying
    finite."""
    losses_ws = _run_train_steps(_train_cfg(capacity_factor=0.25), seed=1)
    losses_d = _run_train_steps(
        _train_cfg(moe_dispatch="dense", capacity_factor=0.25), seed=1
    )
    assert all(np.isfinite(losses_ws))
    assert max(abs(a - b) for a, b in zip(losses_ws, losses_d)) > 1e-5, (
        "ws and capacity-starved dense training were identical — the "
        "dropless path was not trained"
    )


# ---------------------------------------------------------------------------
# 6. zero-cost audit of the backward lowering
# ---------------------------------------------------------------------------


def test_grad_lowering_is_fence_free():
    """audit_traced_put covers the VJP now: forward + backward jit
    lowerings (grad_dispatch dense AND ws) contain zero RMW / atomic /
    lock / fence ops.  The audit asserts internally; pin the grad rows'
    presence so the bench cannot silently drop them."""
    from benchmarks.zero_cost import audit_traced_put

    rows = audit_traced_put(n_tokens=8, n_experts=4, top_k=2, bt=4,
                            n_programs=2)
    exps = {r["experiment"] for r in rows}
    assert {"grad-dense", "grad-ws"} <= exps, exps
    for r in rows:
        assert r["rmws_per_op"] == 0 and r["locks_per_op"] == 0

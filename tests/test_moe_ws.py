"""Tests for repro.moe_ws — dropless MoE expert dispatch on the WS scheduler.

Five layers:
  1. dispatch: router output -> expert-tile tasks covers every routed
     (token, expert) pair exactly once, grouped contiguously per expert;
  2. `moe_ffn_ws` matches the dense **no-drop** oracle for both schedules,
     with the aux loss identical to the dense router's;
  3. multiplicity on-device: adversarially rewound queue state re-executes
     expert tiles and the row divisor normalizes the combine back to exact;
  4. dropless vs dropping: a hot-expert router makes the dense capacity path
     lose tokens while the ws path still equals the no-drop oracle;
  5. protocol: the expert dispatch queue (`moe-ws` in ALGORITHMS) satisfies
     the paper's properties under the adversarial simulator, and its
     instruction mix is fence-free (0 RMW / 0 locks) — plus a hypothesis
     property test that the dropless invariant survives any random
     steal/duplication (head-rewind) schedule.
"""

import random

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core import ALGORITHMS, EMPTY  # noqa: E402
from repro.core.simulator import (  # noqa: E402
    check_no_lost_tasks_fifo,
    check_no_process_duplicates,
    check_owner_fifo,
    run_program,
)
from repro.models.moe import init_moe, moe_ffn, moe_ffn_dispatch  # noqa: E402
from repro.moe_ws import (  # noqa: E402
    MoEDispatchHost,
    combine_routed,
    expert_ffn_nodrop_ref,
    moe_ffn_nodrop_ref,
    moe_ffn_ws,
    route_to_tasks,
    run_moe_schedule,
)
from repro.pallas_ws import ExpertTask, make_queue_state  # noqa: E402

KEY = jax.random.PRNGKey(11)


def _smoke_cfg(**kw):
    cfg = get_config("deepseek-v2-236b", smoke=True)
    return cfg.replace(**kw) if kw else cfg


def _moe_inputs(cfg, B=2, S=16, seed=0):
    p = init_moe(jax.random.PRNGKey(seed), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, S, cfg.d_model))
    return p, x


# ---------------------------------------------------------------------------
# 1. dispatch: routing -> tasks
# ---------------------------------------------------------------------------


def test_route_to_tasks_covers_every_routed_pair():
    rng = np.random.RandomState(0)
    T, E, k, bt = 13, 5, 2, 4
    idx = np.stack([rng.choice(E, k, replace=False) for _ in range(T)])
    gates = rng.uniform(0.1, 1.0, (T, k)).astype(np.float32)
    tasks, routed = route_to_tasks(idx, gates, E, bt=bt)

    loads = np.bincount(idx.reshape(-1), minlength=E)
    assert routed.n_routed == T * k
    np.testing.assert_array_equal(routed.expert_loads(), loads)
    # expert ranges are bt-aligned (tile output slices must be disjoint even
    # when a full bt-row slice is written)
    assert (np.diff(routed.expert_off) == -(-loads // bt) * bt).all()
    assert routed.n_rows % bt == 0

    # live rows: the first loads[e] rows of each expert's range, each in
    # exactly one tile; pad rows in none, with gate 0
    live = np.zeros(routed.n_rows, dtype=bool)
    for e in range(E):
        live[routed.expert_off[e]: routed.expert_off[e] + loads[e]] = True
    covered = np.zeros(routed.n_rows, dtype=int)
    for t in tasks:
        assert t.cost == t.row_len <= bt
        assert t.op == ExpertTask(0, 0, 1, 0, 1).op
        assert t.row_start % bt == 0
        lo, hi = routed.expert_off[t.expert], routed.expert_off[t.expert + 1]
        assert lo <= t.row_start and t.row_start + t.row_len <= hi
        # the full bt slice this tile RMWs stays inside its expert's range
        assert t.row_start + bt <= hi
        covered[t.row_start: t.row_start + t.row_len] += 1
    assert (covered[live] == 1).all(), "dropless: every routed row in one tile"
    assert (covered[~live] == 0).all() and (routed.gates[~live] == 0).all()
    assert live.sum() == T * k
    # every live row's token index is consistent with the routing
    for r in np.flatnonzero(live):
        e = int(np.searchsorted(routed.expert_off, r, side="right")) - 1
        assert e in idx[routed.tok_idx[r]]


def test_route_to_tasks_empty_expert_gets_no_tasks():
    idx = np.zeros((4, 1), dtype=np.int32)  # everything to expert 0
    gates = np.ones((4, 1), dtype=np.float32)
    tasks, routed = route_to_tasks(idx, gates, n_experts=3, bt=2)
    assert routed.expert_loads().tolist() == [4, 0, 0]
    assert {t.expert for t in tasks} == {0}
    assert sum(t.row_len for t in tasks) == 4


# ---------------------------------------------------------------------------
# 2. moe_ffn_ws == dense no-drop oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schedule", ["ws", "static"])
def test_moe_ffn_ws_matches_nodrop_oracle(schedule):
    cfg = _smoke_cfg()
    p, x = _moe_inputs(cfg)
    ref, aux_ref = moe_ffn_nodrop_ref(x, p, cfg)
    y, aux, st = moe_ffn_ws(
        x, p, cfg, schedule=schedule, n_programs=4, bt=4, return_stats=True
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(aux - aux_ref)) < 1e-6
    # single launch in interpret mode is sequentially-exact: no duplicates
    assert st.mult_max == 1
    # the dense router must agree on the aux loss (same formula, same groups)
    _, aux_dense = moe_ffn(x, p, cfg, group_size=x.shape[0] * x.shape[1])
    assert float(jnp.abs(aux - aux_dense)) < 1e-6


def test_moe_ffn_ws_no_shared_experts():
    cfg = _smoke_cfg(n_shared_experts=0)
    p, x = _moe_inputs(cfg, seed=3)
    ref, _ = moe_ffn_nodrop_ref(x, p, cfg)
    y, _ = moe_ffn_ws(x, p, cfg, n_programs=4, bt=4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_moe_dispatch_flag_eager_and_traced():
    """cfg.moe_dispatch == "ws": eager AND traced callers get the dropless
    scheduler — the deleted dense fallback must never return under jit."""
    cfg = _smoke_cfg(moe_dispatch="ws")
    p, x = _moe_inputs(cfg, seed=5)
    ref, _ = moe_ffn_nodrop_ref(x, p, cfg)
    y, _ = moe_ffn_dispatch(x, p, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5, atol=1e-5)

    y_tr, _ = jax.jit(lambda xx: moe_ffn_dispatch(xx, p, cfg))(x)
    np.testing.assert_allclose(
        np.asarray(y_tr), np.asarray(ref), rtol=1e-5, atol=1e-5
    )

    # dense runs only when the config names it
    cfg_dense = _smoke_cfg(moe_dispatch="dense")
    y_dense, _ = jax.jit(lambda xx: moe_ffn_dispatch(xx, p, cfg_dense))(x)
    y_dense_ref, _ = moe_ffn(x, p, cfg_dense)
    np.testing.assert_allclose(
        np.asarray(y_dense), np.asarray(y_dense_ref), rtol=1e-5, atol=1e-5
    )

    # return_stats needs concrete telemetry — clear error, not a crash
    with pytest.raises(ValueError, match="concrete telemetry"):
        jax.jit(lambda xx: moe_ffn_ws(xx, p, cfg, return_stats=True))(x)


# ---------------------------------------------------------------------------
# 3. multiplicity: duplicated expert tiles are count-normalized
# ---------------------------------------------------------------------------


def _routed_kernel_setup(T=12, d=8, f=16, E=4, k=2, bt=4, seed=0, n_programs=4):
    rng = np.random.RandomState(seed)
    idx = np.stack([rng.choice(E, k, replace=False) for _ in range(T)])
    gates = rng.uniform(0.1, 1.0, (T, k)).astype(np.float32)
    gates /= gates.sum(1, keepdims=True)
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    wg = jax.random.normal(ks[1], (E, d, f), jnp.float32) / np.sqrt(d)
    wu = jax.random.normal(ks[2], (E, d, f), jnp.float32) / np.sqrt(d)
    wd = jax.random.normal(ks[3], (E, f, d), jnp.float32) / np.sqrt(f)
    tasks, routed = route_to_tasks(idx, gates, E, bt=bt)
    state = make_queue_state(tasks, n_programs, n_queues=E, partition="owner")
    return idx, gates, x, (wg, wu, wd), tasks, routed, state


def test_expert_multiplicity_normalization_under_head_rewind():
    """Relaunch the expert megakernel on adversarially rewound queue state
    (every Head dragged to 0, all local bounds wiped).  Every tile is
    re-executed; mult == 2 everywhere and the combine stays exact."""
    idx, gates, x, w, tasks, routed, state = _routed_kernel_setup()
    bt = 4
    res1 = run_moe_schedule(state, x, routed.tok_idx, *w, bt=bt, steal=True)
    assert (res1.mult[: state.n_tasks] == 1).all()

    state.head = np.zeros_like(state.head)
    state.local_head = np.zeros_like(state.local_head)
    res2 = run_moe_schedule(
        state, x, routed.tok_idx, *w, bt=bt, steal=True,
        out=res1.out, mult=jnp.asarray(res1.mult),
    )
    assert (res2.mult[: state.n_tasks] == 2).all(), "every tile re-executed once"

    y = combine_routed(routed, tasks, res2)
    ref = expert_ffn_nodrop_ref(idx, gates, x, *w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_expert_no_program_re_extracts_within_launch():
    idx, gates, x, w, tasks, routed, state = _routed_kernel_setup(seed=2)
    res = run_moe_schedule(state, x, routed.tok_idx, *w, bt=4, steal=True)
    live = state.tasks[:, :, 0] != -1
    assert (res.taken[live] >= 0).all(), "every live slot extracted"
    assert (res.taken[~live] == -1).all(), "no phantom extraction"
    assert (res.mult[: state.n_tasks] == 1).all()
    np.testing.assert_array_equal(res.head, live.sum(axis=1))


# ---------------------------------------------------------------------------
# 4. dropless vs dropping
# ---------------------------------------------------------------------------


def test_ws_is_dropless_where_dense_drops():
    """A hot-expert router: the dense capacity path loses routed tokens
    (its output diverges from the no-drop oracle) while the ws dispatch
    still reproduces the oracle exactly."""
    cfg = _smoke_cfg(capacity_factor=1.0, n_shared_experts=0)
    p, x = _moe_inputs(cfg, B=2, S=16, seed=7)
    # bias the router hard toward expert 0: it gets every token's top-1
    p = dict(p)
    p["router"] = jnp.asarray(np.asarray(p["router"]) * 0.05)
    p["router"] = p["router"].at[:, 0].add(10.0)

    ref, _ = moe_ffn_nodrop_ref(x, p, cfg)
    y_ws, _, st = moe_ffn_ws(x, p, cfg, n_programs=4, bt=4, return_stats=True)
    y_dense, _ = moe_ffn(x, p, cfg, group_size=x.shape[0] * x.shape[1])

    np.testing.assert_allclose(np.asarray(y_ws), np.asarray(ref), rtol=1e-5, atol=1e-5)
    dense_err = float(jnp.abs(y_dense.astype(jnp.float32) - ref).max())
    assert dense_err > 1e-3, (
        f"expected the capacity path to drop tokens here (err={dense_err})"
    )
    # the hot expert's queue was drained by thieves, not serialized
    assert st.steals > 0


# ---------------------------------------------------------------------------
# 5. protocol: property harness, instruction mix, hypothesis invariant
# ---------------------------------------------------------------------------


def _expert_payload(i):
    return tuple(int(v) for v in ExpertTask(
        expert=i % 8, row_start=4 * i, row_len=4, tid=i, cost=4
    ).encode())


def _program(n_tasks, n_thieves, steals_per_thief, takes):
    prog = {0: [("put", _expert_payload(i)) for i in range(n_tasks)]
            + [("take", None)] * takes}
    for t in range(1, n_thieves + 1):
        prog[t] = [("steal", None)] * steals_per_thief
    return prog


@pytest.mark.parametrize("seed", range(8))
def test_moe_host_weak_multiplicity_random_schedules(seed):
    rng = random.Random(seed)
    schedule = [rng.randrange(4) for _ in range(rng.randrange(50, 400))]
    prog = _program(n_tasks=8, n_thieves=3, steals_per_thief=5, takes=5)
    records = run_program(
        lambda backend: MoEDispatchHost(backend=backend, capacity=64), prog, schedule
    )
    check_no_process_duplicates(records)  # no process extracts a tile twice
    check_no_lost_tasks_fifo(records)     # at-least-once (dropless), FIFO prefix
    check_owner_fifo(records)             # owner respects put order


def test_moe_host_put_tasks_segment_matches_put_task_loop():
    """Batched expert-segment Put (amortized synchronization): identical
    final state to the task-at-a-time loop, all-or-none on overflow."""
    tasks = [ExpertTask(expert=0, row_start=4 * i, row_len=4, tid=i, cost=4)
             for i in range(12)]
    a = MoEDispatchHost(capacity=64)
    b = MoEDispatchHost(capacity=64)
    for t in tasks:
        assert a.put_task(t)
    assert b.put_tasks(tasks)
    assert a.snapshot() == b.snapshot()
    assert a.remaining_estimate() == b.remaining_estimate()
    assert [b.take() for _ in tasks] == [
        tuple(int(v) for v in t.encode()) for t in tasks]
    # all-or-none: a segment that does not fit leaves the queue untouched
    c = MoEDispatchHost(capacity=8)
    assert not c.put_tasks(tasks)
    assert c.snapshot() == (0, 0, {})
    with pytest.raises(RuntimeError):
        c.put_tasks(tasks, strict=True)


def test_moe_host_registered_in_core_registry():
    q = ALGORITHMS["moe-ws"]()
    payloads = [_expert_payload(i) for i in range(16)]
    for t in payloads:
        assert q.put(t)
    assert [q.take() for _ in range(8)] == payloads[:8]
    assert [q.steal(1) for _ in range(8)] == payloads[8:]
    assert q.take() is EMPTY and q.steal(2) is EMPTY


def test_expert_dispatch_instruction_mix_is_fence_free():
    """The zero-cost audit inline: Put/Take and Put/Steal on the expert
    dispatch queue perform zero RMW operations and zero lock acquisitions."""
    from benchmarks.zero_cost import audit_fence_free, bench_zero_cost

    rows = bench_zero_cost(n_ops=512, algos=("moe-ws", "pallas-ws"), repeats=1)
    audit_fence_free(rows)
    for r in rows:
        assert r["extracted"] == 512


# ---------------------------------------------------------------------------
# deterministic slice of the hypothesis dropless property (always runs; the
# randomized version lives in test_moe_ws_properties.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(4))
def test_dropless_invariant_seeded_rewinds(seed):
    """Seeded adversarial rewind schedules: every routed pair executed >= 1
    time and the normalized combine equals the no-drop reference."""
    rng = np.random.RandomState(seed)
    idx, gates, x, w, tasks, routed, state = _routed_kernel_setup(
        T=4 + 3 * seed, E=3 + (seed % 2), k=1 + (seed % 2), bt=2, seed=seed,
        n_programs=3,
    )
    res = run_moe_schedule(state, x, routed.tok_idx, *w, bt=2, steal=True)
    for _ in range(1 + seed % 2):
        for q in range(state.n_queues):
            if rng.rand() < 0.5:
                state.head[q] = rng.randint(0, max(1, state.head[q] + 1))
        for pidx in range(state.local_head.shape[0]):
            if rng.rand() < 0.5:
                state.local_head[pidx] = 0
        res = run_moe_schedule(
            state, x, routed.tok_idx, *w, bt=2, steal=True,
            out=res.out, mult=jnp.asarray(res.mult),
        )
    assert (res.mult[: state.n_tasks] >= 1).all()
    y = combine_routed(routed, tasks, res)
    ref = expert_ffn_nodrop_ref(idx, gates, x, *w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-4, atol=1e-4)

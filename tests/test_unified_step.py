"""Unified mixed-mode engine step (models.unified): one `launch_ws_grid`
launch carrying decode tiles, prefill flash tiles, expert tiles and the
step-glue family, stage-gated by Graham windows.

Parity oracle is the *jitted* split-launch path: `jit(decode_step_ws)` /
`jit(prefill)`.  The unified launch is itself one jitted pallas program, so
it reproduces the jit path bitwise on float32 configs; the eager split path
differs from its own jit by ~1 ulp (XLA fusion rounding), which is exactly
the residue the old split-vs-dense tests tolerate.
"""

import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (
    decode_step_ws,
    decode_step_unified,
    init_params,
    prefill,
    unified_step_supported,
)
from repro.models.transformer import init_params as _init  # noqa: F401
from repro.wstrace.ring import EV_OP, decode_rings
from repro.pallas_ws.tasks import (
    OP_DECODE_TILE,
    OP_EXPERT_TILE,
    OP_FLASH_TILE,
    OP_STEP_GLUE,
)

CAP = 32


def _setup(arch, **kw):
    cfg = get_config(arch, smoke=True)
    if kw:
        cfg = dc.replace(cfg, **kw)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.asarray(np.array([[5, 6, 7, 8], [9, 8, 7, 6]], np.int32))}
    _, caches = prefill(params, cfg, batch, capacity=CAP)
    tok = jnp.asarray(np.array([[3], [4]], np.int32))
    pos = np.array([4, 2], np.int32)  # heterogeneous live lengths
    return cfg, params, caches, tok, pos


def _split_oracle(cfg, params, caches, tok, pos):
    return jax.jit(lambda p, c, t, q: decode_step_ws(p, cfg, c, t, q))(
        params, caches, tok, jnp.asarray(pos)
    )


# ---------------------------------------------------------------------------
# decode parity: bitwise vs the split-launch path


def test_unified_dense_decode_bitwise():
    cfg, params, caches, tok, pos = _setup("llama3.2-3b")
    assert unified_step_supported(cfg)
    l_ref, c_ref = _split_oracle(cfg, params, caches, tok, pos)
    l_u, c_u, rep = decode_step_unified(params, cfg, caches, tok, pos)
    np.testing.assert_array_equal(np.asarray(l_ref), np.asarray(l_u))
    np.testing.assert_array_equal(np.asarray(c_ref.kv.k), np.asarray(c_u.kv.k))
    np.testing.assert_array_equal(np.asarray(c_ref.kv.v), np.asarray(c_u.kv.v))
    # drained: every task (glue + attention tiles) executed at least once
    assert (np.asarray(rep.res.mult)[: rep.n_tasks] >= 1).all()


def test_unified_moe_decode_bitwise():
    """MoE config: the in-kernel router Put + pool expert tiles + combine
    reproduce the split path's host Put + per-layer expert launch bitwise."""
    cfg, params, caches, tok, pos = _setup("kimi-k2-1t-a32b", moe_dispatch="ws")
    assert unified_step_supported(cfg)
    l_ref, c_ref = _split_oracle(cfg, params, caches, tok, pos)
    l_u, c_u, rep = decode_step_unified(params, cfg, caches, tok, pos)
    np.testing.assert_array_equal(np.asarray(l_ref), np.asarray(l_u))
    np.testing.assert_array_equal(np.asarray(c_ref.kv.k), np.asarray(c_u.kv.k))
    np.testing.assert_array_equal(np.asarray(c_ref.kv.v), np.asarray(c_u.kv.v))


# ---------------------------------------------------------------------------
# folded-in prefill


def test_unified_prefill_fold():
    """Folding a prompt's prefill into the decode launch (a) leaves the
    decode half bitwise unchanged and (b) reproduces `jit(prefill)` — logits
    to float tolerance (the flash tiles reduce kv in bk-block online-softmax
    order, `flash_ref` in whole chunks), layer-0 k/v caches bitwise
    (projection + rope, no reduction upstream) and deeper layers to
    tolerance (they inherit the attention rounding via the residual)."""
    cfg, params, caches, tok, pos = _setup("llama3.2-3b")
    ptok = jnp.asarray(
        np.arange(11, 31, dtype=np.int32).reshape(1, 20)  # Lp=20, ragged tiles
    )
    l_ref, c_ref = _split_oracle(cfg, params, caches, tok, pos)
    l_u, c_u, rep = decode_step_unified(
        params, cfg, caches, tok, pos, prefill_tokens=ptok, bq=8, bk=8
    )
    np.testing.assert_array_equal(np.asarray(l_ref), np.asarray(l_u))
    np.testing.assert_array_equal(np.asarray(c_ref.kv.k), np.asarray(c_u.kv.k))

    lp_ref, cp_ref = jax.jit(lambda p, b: prefill(p, cfg, b, capacity=CAP))(
        params, {"tokens": ptok}
    )
    np.testing.assert_allclose(
        np.asarray(rep.prefill_logits), np.asarray(lp_ref), rtol=2e-5, atol=2e-5
    )
    np.testing.assert_array_equal(
        np.asarray(rep.prefill_kv.k[0]), np.asarray(cp_ref.kv.k[0])
    )
    np.testing.assert_array_equal(
        np.asarray(rep.prefill_kv.v[0]), np.asarray(cp_ref.kv.v[0])
    )
    np.testing.assert_allclose(
        np.asarray(rep.prefill_kv.k), np.asarray(cp_ref.kv.k),
        rtol=2e-5, atol=2e-5,
    )
    np.testing.assert_allclose(
        np.asarray(rep.prefill_kv.v), np.asarray(cp_ref.kv.v),
        rtol=2e-5, atol=2e-5,
    )


# ---------------------------------------------------------------------------
# the launch-count witness: ONE ring stream carrying every family


def test_unified_single_launch_all_families():
    cfg, params, caches, tok, pos = _setup("kimi-k2-1t-a32b", moe_dispatch="ws")
    ptok = jnp.asarray(np.array([[11, 12, 13, 14, 15, 16, 17]], np.int32))
    _, _, rep = decode_step_unified(
        params, cfg, caches, tok, pos, prefill_tokens=ptok, trace=True
    )
    stream, dropped = decode_rings(
        np.asarray(rep.res.events), np.asarray(rep.res.ev_cursor)
    )
    # fresh stage-gated launch: every task claimed exactly once, nothing lost
    assert len(stream) == rep.n_tasks
    assert int(dropped.sum()) == 0
    assert (np.asarray(rep.res.mult)[: rep.n_tasks] == 1).all()
    ops = set(stream[:, EV_OP].tolist())
    # one event stream, all three task families (+ glue) — the single-launch
    # witness the acceptance criteria ask for
    assert {OP_DECODE_TILE, OP_FLASH_TILE, OP_EXPERT_TILE, OP_STEP_GLUE} <= ops


def test_unified_trace_off_matches_trace_on():
    cfg, params, caches, tok, pos = _setup("llama3.2-3b")
    l0, c0, _ = decode_step_unified(params, cfg, caches, tok, pos, trace=False)
    l1, c1, _ = decode_step_unified(params, cfg, caches, tok, pos, trace=True)
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))
    np.testing.assert_array_equal(np.asarray(c0.kv.k), np.asarray(c1.kv.k))


# ---------------------------------------------------------------------------
# stage-assembly memoization: one build per unique length vector


def test_unified_stage_assembly_memoized():
    """Multi-step decode rebuilds the task stages only when the length
    vector (or pending-admission shape) changes: repeated steps at the same
    key hit the cache, and the reuse is bitwise invisible in the logits."""
    from repro.models.unified import clear_stage_cache, stage_cache_stats

    cfg, params, caches, tok, pos = _setup("llama3.2-3b")
    clear_stage_cache()
    l0, c0, _ = decode_step_unified(params, cfg, caches, tok, pos)
    assert stage_cache_stats() == {"builds": 1, "hits": 0}
    l1, _, _ = decode_step_unified(params, cfg, caches, tok, pos)
    assert stage_cache_stats() == {"builds": 1, "hits": 1}
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))
    # advancing the decode: a new length vector is exactly one more build,
    # and repeats at the new key hit again
    pos2 = pos + 1
    decode_step_unified(params, cfg, c0, tok, pos2)
    decode_step_unified(params, cfg, c0, tok, pos2)
    assert stage_cache_stats() == {"builds": 2, "hits": 2}
    # folding in a prefill changes the pending-admission shape: new key
    ptok = jnp.asarray(np.array([[11, 12, 13, 14, 15, 16, 17, 18]], np.int32))
    decode_step_unified(params, cfg, c0, tok, pos2, prefill_tokens=ptok,
                        bq=8, bk=8)
    assert stage_cache_stats() == {"builds": 3, "hits": 2}
    clear_stage_cache()
    assert stage_cache_stats() == {"builds": 0, "hits": 0}


# ---------------------------------------------------------------------------
# gate


def test_unified_step_supported_gate():
    cfg = get_config("llama3.2-3b", smoke=True)
    assert unified_step_supported(cfg)
    assert not unified_step_supported(dc.replace(cfg, dtype="bfloat16"))
    assert not unified_step_supported(dc.replace(cfg, family="ssm"))
    kimi = get_config("kimi-k2-1t-a32b", smoke=True)
    assert not unified_step_supported(kimi)  # dense dispatch: no WS oracle
    assert unified_step_supported(dc.replace(kimi, moe_dispatch="ws"))


def test_unified_rejects_unsupported():
    cfg, params, caches, tok, pos = _setup("llama3.2-3b")
    bad = dc.replace(cfg, dtype="bfloat16")
    with pytest.raises(AssertionError):
        decode_step_unified(params, bad, caches, tok, pos)

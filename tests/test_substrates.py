"""Unit tests: optim / data / checkpoint / serving substrates."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.configs import get_config
from repro.data import SyntheticCorpus, WorkStealingLoader, make_batch, pack_documents
from repro.models import init_params, loss_fn
from repro.models.config import SHAPES
from repro.optim import (
    cosine_schedule,
    int8_compress_decompress,
    make_adafactor_momentum,
    make_adamw,
    make_ef_compressor,
    wsd_schedule,
)
from repro.serving import ContinuousBatcher, Request, WorkStealingFrontend


# ---------------------------------------------------------------------------
# optim


def _quadratic_problem():
    target = {"a": jnp.array([1.0, -2.0, 3.0]), "b": {"w": jnp.ones((4, 4)) * 0.5}}
    params = jax.tree_util.tree_map(jnp.zeros_like, target)

    def loss(p):
        return sum(
            jnp.sum((x - t) ** 2)
            for x, t in zip(jax.tree_util.tree_leaves(p), jax.tree_util.tree_leaves(target))
        )

    return params, loss


@pytest.mark.parametrize("make_opt", [make_adamw, make_adafactor_momentum])
def test_optimizers_converge(make_opt):
    params, loss = _quadratic_problem()
    opt = make_opt(lambda s: 0.05, weight_decay=0.0)
    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = opt.apply(params, g, state)
    assert float(loss(params)) < 0.01 * l0


def test_schedules():
    wsd = wsd_schedule(1.0, warmup=10, stable=50, decay=40)
    assert float(wsd(0)) == 0.0
    assert abs(float(wsd(10)) - 1.0) < 1e-6
    assert abs(float(wsd(40)) - 1.0) < 1e-6
    assert float(wsd(100)) <= 0.11
    cos = cosine_schedule(1.0, warmup=10, total=100)
    assert float(cos(10)) >= 0.99 and float(cos(100)) <= 0.11


def test_int8_compression_error_feedback():
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (128,))
    val, res = int8_compress_decompress(g)
    # quantization error bounded by scale/2 per element
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(g - val))) <= scale * 0.51
    np.testing.assert_allclose(np.asarray(val + res), np.asarray(g), rtol=1e-6)

    # EF: accumulated compressed updates converge to accumulated true grads
    init, apply = make_ef_compressor(True)
    state = init({"g": g})
    total_true, total_comp = jnp.zeros_like(g), jnp.zeros_like(g)
    for i in range(50):
        gi = jax.random.normal(jax.random.fold_in(key, i), (128,)) * 0.1
        comp, state = apply({"g": gi}, state)
        total_true += gi
        total_comp += comp["g"]
    # residual carries over, so totals match to within one quantization step
    assert float(jnp.max(jnp.abs(total_true - total_comp))) < 0.05


# ---------------------------------------------------------------------------
# data


def test_synthetic_corpus_deterministic_and_learnable():
    c = SyntheticCorpus(vocab_size=256, seed=3)
    d1 = c.document(5, 64)
    d2 = c.document(5, 64)
    np.testing.assert_array_equal(d1, d2)
    toks, docs_per_row = pack_documents(c, n_rows=4, seq_len=128)
    assert toks.shape == (4, 128) and (toks[:, :8] >= 0).all()
    assert docs_per_row.min() >= 1
    assert int(docs_per_row.max()) >= int(docs_per_row.min())  # skew exists


def test_make_batch_families():
    for arch in ("llama3.2-3b", "pixtral-12b", "whisper-base"):
        cfg = get_config(arch, smoke=True)
        b = make_batch(cfg, SHAPES["train_4k"], step=0, n_rows=2)
        assert b["tokens"].shape[0] == 2
        if cfg.family == "vlm":
            assert b["patches"].shape == (2, cfg.n_patches, cfg.d_model)
        if cfg.family == "encdec":
            assert b["frames"].shape == (2, cfg.enc_seq_len, cfg.d_model)


def test_work_stealing_loader_at_least_once():
    cfg = get_config("llama3.2-3b", smoke=True)

    def prepare(task_id):
        b = make_batch(cfg, SHAPES["train_4k"], step=task_id, n_rows=1)
        return b

    loader = WorkStealingLoader(prepare, n_tasks=12, n_workers=3).start()
    batches = loader.batches(timeout=60)
    assert len(batches) == 12
    assert loader.stats["extractions"] >= 12  # at-least-once
    # determinism: duplicated prep must produce identical data
    again = prepare(4)
    np.testing.assert_array_equal(batches[4]["tokens"], again["tokens"])


# ---------------------------------------------------------------------------
# checkpoint


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
        "opt": {"m": jnp.ones((3, 4)) * 0.5, "step": jnp.int32(7)},
    }
    save(d, 10, tree, metadata={"arch": "test"})
    save(d, 20, tree)
    assert latest_step(d) == 20
    like = jax.tree_util.tree_map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    out, step = restore(d, like)
    assert step == 20
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]), np.asarray(tree["params"]["w"]))
    # no tmp dirs left behind
    assert not [n for n in os.listdir(d) if ".tmp-" in n]


def test_checkpoint_elastic_reshard(tmp_path):
    """Save under a 1x4 mesh layout, restore under 2x2 — data identical."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    d = str(tmp_path / "ckpt")
    w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    save(d, 1, {"w": w})
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh((1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    out, _ = restore(d, like, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(w))
    assert out["w"].sharding == sh["w"]


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ckpt")
    ck = AsyncCheckpointer(d, keep=2)
    for s in (1, 2, 3):
        ck.save(s, {"x": jnp.full((4,), s, jnp.float32)})
    ck.wait()
    assert latest_step(d) == 3
    steps = sorted(n for n in os.listdir(d) if n.startswith("step_"))
    assert len(steps) == 2  # gc kept 2


# ---------------------------------------------------------------------------
# serving


def test_continuous_batcher_matches_sequential_decode():
    cfg = get_config("llama3.2-3b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b = ContinuousBatcher(params, cfg, slots=2, capacity=32)
    r1 = Request(1, np.array([5, 6, 7], np.int32), max_new=4)
    r2 = Request(2, np.array([9, 8, 7, 6, 5], np.int32), max_new=4)
    assert b.admit(r1) and b.admit(r2)
    done = []
    for _ in range(8):
        done += b.step()
        if len(done) == 2:
            break
    assert sorted(r.rid for r in done) == [1, 2]
    assert all(len(r.out) == 4 for r in done)

    # oracle: single-request engine must produce the same tokens
    for orig in (r1, r2):
        solo = ContinuousBatcher(params, cfg, slots=1, capacity=32)
        rr = Request(orig.rid, orig.tokens, max_new=4)
        solo.admit(rr)
        while solo.n_live:
            solo.step()
        got = next(r for r in done if r.rid == orig.rid)
        assert rr.out == got.out, (rr.out, got.out)


def test_ws_decode_step_matches_dense_decode_step():
    """The batcher's default decode path (attention tiles through the
    repro.pallas_ws scheduler) must reproduce the jitted dense decode_step:
    same logits, same cache contents, per-slot heterogeneous positions."""
    from repro.models import decode_step, decode_step_ws, prefill

    cfg = get_config("llama3.2-3b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.asarray(np.array([[5, 6, 7, 8], [9, 8, 7, 6]], np.int32))}
    _, caches = prefill(params, cfg, batch, capacity=32)
    tok = jnp.asarray(np.array([[3], [4]], np.int32))
    pos = jnp.asarray(np.array([4, 2], np.int32))  # heterogeneous slots
    l_dense, c_dense = decode_step(params, cfg, caches, tok, pos)
    l_ws, c_ws = decode_step_ws(params, cfg, caches, tok, pos)
    np.testing.assert_allclose(
        np.asarray(l_dense), np.asarray(l_ws), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(c_dense.kv.k), np.asarray(c_ws.kv.k), rtol=1e-5, atol=1e-5
    )


def test_batcher_ws_escape_hatch_matches_default():
    """use_ws=False (jitted dense decode) and the default ws decode produce
    the same greedy token streams."""
    cfg = get_config("llama3.2-3b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    outs = {}
    for use_ws in (True, False):
        b = ContinuousBatcher(params, cfg, slots=2, capacity=32, use_ws=use_ws)
        assert b.use_ws == use_ws
        r1 = Request(1, np.array([5, 6, 7], np.int32), max_new=3)
        r2 = Request(2, np.array([9, 8, 7, 6, 5], np.int32), max_new=3)
        assert b.admit(r1) and b.admit(r2)
        while b.n_live:
            b.step()
        outs[use_ws] = (r1.out, r2.out)
    assert outs[True] == outs[False], outs


def test_work_stealing_frontend_completes_all():
    cfg = get_config("llama3.2-3b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    fe = WorkStealingFrontend(
        lambda: ContinuousBatcher(params, cfg, slots=2, capacity=32), n_replicas=2
    )
    rng = np.random.RandomState(0)
    # skewed load: all requests land on replica 0 -> replica 1 must steal
    for rid in range(6):
        fe.submit(0, Request(rid, rng.randint(1, 200, size=4).astype(np.int32), max_new=3))
    completed = fe.run()
    assert sorted(completed) == list(range(6))
    assert all(len(r.out) == 3 for r in completed.values())
    assert fe.stats()["totals"]["stolen"] >= 1

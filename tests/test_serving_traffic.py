"""Serving-engine correctness under replayed traffic (ISSUE 8 satellites).

Model-backed engine tests: the `greedy` flag actually selecting the
sampler, prompt-capacity validation at the cap-1/cap/cap+1 boundary, the
frontend honoring admit()'s verdict (rejections surfaced, never silently
dropped), a deterministic seeded arrival trace through a 2-replica
frontend, and unified-vs-split token-stream parity at the engine level.

Everything runs the llama smoke config (tiny f32 dense GQA) so the decode
launches stay interpret-mode cheap.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.configs import get_config  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.serving.engine import (  # noqa: E402
    ContinuousBatcher,
    Request,
    WorkStealingFrontend,
)

CFG = get_config("llama3.2-3b", smoke=True)
PARAMS = init_params(jax.random.PRNGKey(0), CFG)


def _batcher(**kw):
    kw.setdefault("slots", 2)
    kw.setdefault("capacity", 16)
    return ContinuousBatcher(PARAMS, CFG, **kw)


# ---------------------------------------------------------------------------
# satellite 1: the greedy flag must select the sampler


def test_greedy_flag_selects_argmax():
    b = _batcher(greedy=True)
    logits = np.array([[0.0, 3.0, 1.0], [2.0, 0.0, 0.5]], np.float32)
    np.testing.assert_array_equal(b._select(logits), [1, 0])


def test_greedy_false_samples_with_seed():
    """greedy=False must actually sample (the flag used to be stored and
    ignored): over a flat distribution the choices cannot all equal the
    argmax, and the same sample_seed reproduces the same stream."""
    logits = np.zeros((1, 50), np.float32)
    logits[0, 7] += 1e-3  # argmax is 7, but the distribution is ~uniform
    b1 = _batcher(greedy=False, temperature=1.0, sample_seed=123)
    b2 = _batcher(greedy=False, temperature=1.0, sample_seed=123)
    s1 = [int(b1._select(logits)[0]) for _ in range(16)]
    s2 = [int(b2._select(logits)[0]) for _ in range(16)]
    assert s1 == s2, "same seed must reproduce the same sampled stream"
    assert any(t != 7 for t in s1), "greedy=False still argmaxing"
    b3 = _batcher(greedy=False, temperature=1.0, sample_seed=999)
    assert [int(b3._select(logits)[0]) for _ in range(16)] != s1


def test_greedy_sampled_streams_diverge_in_generation():
    """End to end: the same prompt decoded greedy vs sampled (hot
    temperature) produces different continuations — the flag reaches the
    token choice, not just the constructor."""
    prompt = np.array([5, 6, 7], np.int32)
    r_g = Request(0, prompt, max_new=4)
    b_g = _batcher(greedy=True)
    b_g.admit(r_g)
    while b_g.n_live:
        b_g.step()
    r_s = Request(0, prompt, max_new=4)
    b_s = _batcher(greedy=False, temperature=8.0, sample_seed=7)
    b_s.admit(r_s)
    while b_s.n_live:
        b_s.step()
    assert len(r_g.out) == len(r_s.out) == 4
    assert r_g.out != r_s.out, "hot sampling reproduced the greedy stream"


# ---------------------------------------------------------------------------
# satellite 2: admission capacity validation at the boundary


@pytest.mark.parametrize("unified", [False, True])
def test_admit_capacity_boundary(unified):
    """cap-1 is the longest admissible prompt (the splice needs len rows
    plus one for the first generated token); len == cap used to corrupt
    the cache splice, len == 0 to admit an empty prompt."""
    cap = 8
    b = _batcher(capacity=cap, unified_step=unified)
    assert not b.admit(Request(1, np.arange(cap, dtype=np.int32)))      # == cap
    assert not b.admit(Request(2, np.arange(cap + 1, dtype=np.int32)))  # cap+1
    assert not b.admit(Request(3, np.zeros(0, np.int32)))               # empty
    assert b.n_live == 0, "rejected prompts must not occupy a slot"
    assert b.admit(Request(4, np.arange(1, cap, dtype=np.int32)))       # cap-1
    assert b.n_live == 1


# ---------------------------------------------------------------------------
# satellite 3: the frontend honors admit()'s verdict


def test_frontend_surfaces_rejections():
    cap = 8
    fe = WorkStealingFrontend(
        lambda: _batcher(capacity=cap), n_replicas=2
    )
    fe.submit(0, Request(0, np.array([1, 2, 3], np.int32), max_new=2))
    fe.submit(0, Request(1, np.arange(cap, dtype=np.int32), max_new=2))  # too long
    fe.submit(1, Request(2, np.array([4, 5], np.int32), max_new=2))
    completed = fe.run(max_iters=100)
    assert set(completed) == {0, 2}
    assert set(fe.rejected) == {1}, "over-capacity prompt must be surfaced"
    stats = fe.stats()
    assert stats["totals"]["rejected"] == 1
    assert stats["totals"]["admitted"] == 2
    # admitted counter counts only successful admissions: completions and
    # admissions reconcile exactly (no duplicates in a drained serial run)
    assert len(completed) == (
        stats["totals"]["admitted"] - stats["totals"]["dup_completed"]
    )


# ---------------------------------------------------------------------------
# satellite 4: deterministic seeded arrival trace, 2 replicas


def test_seeded_trace_replay_deterministic():
    """Replay a seeded bursty arrival trace twice through fresh 2-replica
    frontends: every submitted rid lands in completed or rejected exactly
    once, counters reconcile, stats() agree with the observable outcome,
    and the whole outcome (streams included) is reproducible."""
    from benchmarks.serving_traffic import make_trace, replay

    n_requests, cap = 4, 16

    def one_run():
        fe = WorkStealingFrontend(
            lambda: _batcher(capacity=cap), n_replicas=2
        )
        trace = make_trace("bursty", n_requests, cap, 2, seed=11, max_new=2)
        return fe, replay(fe, trace)

    fe, row = one_run()
    got = set(row["completed"]) | set(row["rejected"])
    assert got == set(range(n_requests))
    assert not set(row["completed"]) & set(row["rejected"])
    stats = fe.stats()
    assert stats["totals"]["rejected"] == len(row["rejected"])
    assert len(row["completed"]) == (
        stats["totals"]["admitted"] - stats["totals"]["dup_completed"]
    )
    assert sum(r["submitted"] for r in stats["per_replica"]) == n_requests
    assert row["steps"] == sum(
        s["steps"] for s in stats["batchers"] if s
    )
    for rid, out in row["streams"].items():
        assert len(out) == 2, f"rid {rid} generated {len(out)} != max_new"

    _, row2 = one_run()
    assert row2["streams"] == row["streams"], "seeded replay must reproduce"
    assert row2["completed"] == row["completed"]
    assert row2["rejected"] == row["rejected"]


# ---------------------------------------------------------------------------
# tentpole acceptance at the engine level: unified == split token streams


def test_engine_unified_matches_split_streams():
    """The same seeded 2-request load through a unified-step batcher and a
    split-launch (jitted oracle) batcher: identical greedy token streams.
    The unified engine defers each admission's prefill into the next
    step's single launch, so completion may land on a later iteration —
    but per-slot token streams must be bit-identical."""
    prompts = [
        np.array([5, 6, 7, 8], np.int32),
        np.array([9, 8, 7], np.int32),
    ]
    streams = {}
    for unified in (False, True):
        fe = WorkStealingFrontend(
            lambda: _batcher(capacity=32, unified_step=unified,
                             jit_ws=not unified),
            n_replicas=1,
        )
        for rid, p in enumerate(prompts):
            fe.submit(0, Request(rid, p, max_new=3))
        completed = fe.run(max_iters=50)
        assert set(completed) == {0, 1}
        streams[unified] = {rid: r.out for rid, r in completed.items()}
    assert streams[True] == streams[False]

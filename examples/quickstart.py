"""Quickstart: the paper's queues, the scheduler, and a model — in 2 minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------- 1. L0: the paper
from repro.core import ALGORITHMS, EMPTY

print("== L0: WS-WMULT (paper Fig. 7 — fully read/write, fence-free) ==")
q = ALGORITHMS["ws-wmult"](storage="linked", node_len=64)
for task in ("a", "b", "c", "d"):
    q.put(task)
print("owner takes:", q.take(), q.take())
print("thief steals:", q.steal(pid=1))
print("thief 2 steals:", q.steal(pid=2), "-> then empty:", q.steal(pid=2))

# ---------------------------------------------------------- 2. L1: TPU scheduler
from repro.sched import run_lockstep_rounds

print("\n== L1: work-stealing microbatch rounds (stale-board = RangeMaxRegister) ==")
tails = np.array([8, 1, 1, 1])  # queue 0 is overloaded (a straggler's backlog)
for mode in ("static", "ws-mult", "ws-wmult"):
    _, counts, stats = run_lockstep_rounds(tails, n_workers=4, mode=mode)
    print(f"  {mode:9s}: rounds={stats.rounds_used:2d} dup_ratio={stats.duplicate_ratio:.2f} "
          f"blocking_colls={stats.blocking_collectives} (every task covered: {(counts > 0).all()})")

# --------------------------------------------------------------- 3. L2: a model
from repro.configs import get_config
from repro.models import init_params, loss_fn, prefill, decode_step

print("\n== model: llama-family smoke config, one loss + prefill/decode ==")
cfg = get_config("llama3.2-3b", smoke=True)
params = init_params(jax.random.PRNGKey(0), cfg)
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
loss, metrics = jax.jit(lambda p, b: loss_fn(p, cfg, b))(params, {"tokens": tokens})
print(f"  train loss: {float(loss):.3f}")
logits, caches = prefill(params, cfg, {"tokens": tokens[:, :8]}, capacity=16)
nxt = jnp.argmax(logits, -1)[:, None]
for i in range(8, 12):
    logits, caches = decode_step(params, cfg, caches, nxt, jnp.int32(i))
    nxt = jnp.argmax(logits, -1)[:, None]
print(f"  decoded 4 tokens: ok (last logits shape {logits.shape})")
print("\nquickstart done.")

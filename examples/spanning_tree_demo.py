"""The paper's application (§8): parallel spanning tree via work-stealing.

    PYTHONPATH=src python examples/spanning_tree_demo.py [--scale 20000]
"""
import argparse, sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.spanning_tree import GRAPHS, spanning_tree

ap = argparse.ArgumentParser()
ap.add_argument("--scale", type=int, default=20_000)
ap.add_argument("--graph", default="2d-torus", choices=list(GRAPHS))
args = ap.parse_args()

adj = GRAPHS[args.graph](args.scale)
print(f"graph={args.graph} vertices={len(adj)}")
for algo in ("ws-wmult", "b-ws-wmult", "chase-lev", "idempotent-fifo"):
    for nt in (1, 2, 4):
        dt, stats = spanning_tree(adj, algo, nt)
        print(f"  {algo:16s} threads={nt}: {dt:.3f}s valid={stats['valid']} "
              f"reached={stats['reached']}/{len(adj)}")

"""End-to-end training driver: synthetic corpus -> packed batches ->
work-stealing gradient accumulation -> AdamW/WSD -> async checkpoints.

Default: a ~10M-param llama-family model, 200 steps on CPU (~ minutes),
loss visibly decreasing.  --big trains a ~100M-param config (same code;
budget several hours on this 1-core container).  --moe swaps in a tiny
MoE model; add --moe-dispatch ws to train the **dropless work-stealing**
expert dispatch end to end (forward megakernel + custom-VJP backward,
DESIGN.md §4.5) instead of the capacity-dropping dense einsums.

--devices N forces N host devices (must be set before the first jax init,
which is why argument parsing precedes every repro import here); with --moe
it finishes by running the cross-device mesh-ws dispatch
(moe_dispatch="mesh-ws", forward-only — DESIGN.md §7) over the forced mesh
and checking it bit-identical to the no-drop oracle.

    PYTHONPATH=src python examples/train_e2e.py [--big] [--steps 200]
    PYTHONPATH=src python examples/train_e2e.py --moe --moe-dispatch ws --steps 20
    PYTHONPATH=src python examples/train_e2e.py --moe --devices 8 --steps 20
"""
import argparse, sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ap = argparse.ArgumentParser()
ap.add_argument("--big", action="store_true", help="~100M params instead of ~10M")
ap.add_argument("--moe", action="store_true", help="tiny MoE model instead")
ap.add_argument("--moe-dispatch", default=None, choices=["dense", "ws"],
                help="MoE expert dispatch: ws = dropless work-stealing "
                     "scheduler, trained through its custom VJP")
ap.add_argument("--moe-grad-dispatch", default=None, choices=["dense", "ws"],
                help="backward path of the ws dispatch's custom VJP")
ap.add_argument("--devices", type=int, default=None,
                help="force N host devices (XLA_FLAGS, set before jax "
                     "initializes); with --moe also demos the mesh-ws "
                     "cross-device dispatch after training")
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--ws-mode", default="ws-wmult")
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_e2e")
args = ap.parse_args()

if args.devices:
    # must land in the env before anything imports jax — the device count
    # locks at first init, so no repro import may precede this line
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={args.devices}"
    ).strip()

import numpy as np

from repro.launch.train import train
from repro.models.config import ModelConfig
import repro.configs as configs


def model_10m():
    return ModelConfig(name="lm-10m", family="dense", n_layers=4, d_model=256,
                       n_heads=4, n_kv_heads=2, d_ff=1024, vocab_size=4096)


def model_100m():
    return ModelConfig(name="lm-100m", family="dense", n_layers=12, d_model=640,
                       n_heads=10, n_kv_heads=5, d_ff=2560, vocab_size=8192)


def model_moe():
    """Tiny MoE (8 routed top-2 + 1 shared expert) — small enough that the
    interpret-mode WS megakernel trains in minutes on CPU."""
    return ModelConfig(name="lm-moe", family="moe", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=1024,
                       n_experts=8, n_shared_experts=1, top_k=2, moe_d_ff=128)


cfg = model_moe() if args.moe else (model_100m() if args.big else model_10m())
print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params), "
      f"ws-mode={args.ws_mode}"
      + (f", moe-dispatch={args.moe_dispatch}" if args.moe_dispatch else ""))

# register the custom config so launch.train can find it
configs._MOD[cfg.name] = None
import repro.configs
_orig = repro.configs.get_config
repro.configs.get_config = lambda a, smoke=False: cfg if a == cfg.name else _orig(a, smoke)
import repro.launch.train as lt
lt.get_config = repro.configs.get_config

_, losses = train(cfg.name, smoke=True, steps=args.steps, rows=8, seq=128,
                  moe_dispatch=args.moe_dispatch,
                  moe_grad_dispatch=args.moe_grad_dispatch,
                  ws_mode=args.ws_mode, n_workers=4, skew=2.0, lr=1e-3,
                  ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10)
k = max(len(losses) // 10, 1)
first, last = float(np.mean(losses[:k])), float(np.mean(losses[-k:]))
print(f"loss: {first:.3f} -> {last:.3f}  ({'DECREASED' if last < first else 'flat'})")

if args.moe and args.devices and args.devices > 1:
    # mesh-ws is forward/serving-only (training rejects it), so the
    # multi-device demo runs after training: the cross-device dispatch on
    # the forced mesh, checked bit-identical to the no-drop oracle
    import jax
    from repro.mesh_ws.selfcheck import run_checks

    n_dev = len(jax.devices())
    print(f"mesh-ws demo: {n_dev} devices "
          f"(requested {args.devices}), n_experts=16")
    rows = run_checks(min(n_dev, args.devices), seeds=2)
    for r in rows:
        print(f"  seed={r['seed']} bit_identical={r['bit_identical']} "
              f"devices_stole={r['devices_stole']} "
              f"tiles_stolen={r['tiles_stolen']}")
    assert all(r["bit_identical"] for r in rows), rows

"""Serve a small model with batched requests through the work-stealing
frontend (paper's queues scheduling real inference).

    PYTHONPATH=src python examples/serve_demo.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

sys.exit(main(["--arch", "llama3.2-3b", "--requests", "10", "--replicas", "2"]))

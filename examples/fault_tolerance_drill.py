"""Preemption drill: kill training mid-run, restart from the latest atomic
checkpoint, verify the loss curve continues (no corruption, no lost step).

    PYTHONPATH=src python examples/fault_tolerance_drill.py
"""
import json, os, shutil, subprocess, sys

root = os.path.join(os.path.dirname(__file__), "..")
env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
ckpt = "/tmp/repro_ft_drill"
log = "/tmp/repro_ft_drill.jsonl"
shutil.rmtree(ckpt, ignore_errors=True)
for f in (log,):
    if os.path.exists(f):
        os.remove(f)

base = [sys.executable, "-m", "repro.launch.train", "--arch", "llama3.2-3b",
        "--steps", "40", "--rows", "4", "--seq", "32", "--ckpt-dir", ckpt,
        "--ckpt-every", "5", "--log-path", log, "--log-every", "2"]

print("[drill] phase 1: train, preempt (hard-exit) at step 18 ...")
p = subprocess.run(base + ["--preempt-at", "18"], env=env, capture_output=True, text=True)
assert p.returncode == 17, f"expected preemption exit 17, got {p.returncode}\n{p.stderr[-2000:]}"

print("[drill] phase 2: restart with --resume ...")
p = subprocess.run(base + ["--resume"], env=env, capture_output=True, text=True)
assert p.returncode == 0, p.stderr[-2000:]

rows = [json.loads(l) for l in open(log)]
steps = [r["step"] for r in rows]
losses = {r["step"]: r["loss"] for r in rows}
assert max(steps) == 39, steps
resume_from = min(s for s in steps if steps.count(s) >= 1 and s > 18) if 39 in steps else None
print(f"[drill] logged steps: {sorted(set(steps))}")
early, late = losses[min(steps)], losses[max(steps)]
print(f"[drill] loss {early:.3f} (step {min(steps)}) -> {late:.3f} (step {max(steps)})")
assert late < early, "loss did not keep decreasing across the preemption"
print("[drill] PASS: training resumed from checkpoint and loss curve continued")

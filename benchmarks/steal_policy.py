"""Victim-selection + queue-layout benchmark across expert counts.

ISSUE 4's claims, measured (DESIGN.md §3.6): at deepseek-v2/kimi-k2 expert
counts (E = 160–384 per-expert queues) the PR-1 sequential victim scan
dominates the extraction hot path and the PR-3 padded traced layout pays
``E · ceil(min(T,Tk)/bt)`` tiles of HBM.  Per (E, skew) cell this bench
reports, on the same skewed routing:

* ``ws_cost`` / ``ws_scan`` / ``static`` — device-measured makespan, wasted
  tile-slots, steals, and the **scan-traffic counter** (task-slot probes per
  successful extraction: O(1) for the cost policy, O(E) for the scan);
* ``pool`` — the shared-pool traced Put run under the cost policy: makespan
  must equal the host-layout run (layout changes bytes, never the
  schedule), queue-array bytes vs the padded traced layout
  (``bytes_ratio`` ≈ E× at high E), and the jit pipeline's compiled
  ``cost_analysis`` (bytes accessed / flops) for both layouts — the dryrun
  witness that the compact Put shrinks the whole computation, not just the
  allocation.

Writes BENCH_policy.json next to this file.  ``--dry-run`` shrinks the grid
for CI (Pallas interpret mode on CPU).  Exit status 1 when the headline
claims fail at the largest E and skew ≥ 4: scan traffic reduced < 10×, pool
bytes reduced < 4×, pool makespan != host ws makespan, or the cost policy's
makespan regressing past the scan policy's.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

if __package__ in (None, ""):  # run as a bare script: python benchmarks/...
    sys.path.insert(0, str(pathlib.Path(__file__).parent.parent))

from benchmarks.moe_dispatch import make_skewed_routing  # noqa: E402


def _routed_bytes(routed) -> int:
    return int(np.asarray(routed.tok_idx).size * 4
               + np.asarray(routed.gates).size * 4)


def run_cell(E, T, k, P, bt, d, f, skew, seed=0, dryrun_analysis=True):
    import jax
    import jax.numpy as jnp

    from repro.moe_ws.dispatch import (
        expert_queue_candidates,
        expert_rounds_bound,
        route_to_tasks,
        route_to_tasks_jax,
        route_to_tasks_pool_jax,
    )
    from repro.moe_ws.expert_kernel import run_moe_schedule
    from repro.pallas_ws.queues import (
        make_pool_queue_state_jax,
        make_queue_state,
        make_queue_state_jax,
    )

    idx, gates = make_skewed_routing(T, E, k, skew, seed)
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(T, d).astype(np.float32))
    wg = jnp.asarray(rng.randn(E, d, f).astype(np.float32) / np.sqrt(d))
    wu = jnp.asarray(rng.randn(E, d, f).astype(np.float32) / np.sqrt(d))
    wd = jnp.asarray(rng.randn(E, f, d).astype(np.float32) / np.sqrt(f))
    w = (wg, wu, wd)

    row = dict(E=E, T=T, k=k, n_programs=P, bt=bt, skew=skew, routed=T * k)

    def telemetry(res, n_live):
        assert (np.asarray(res.mult)[:n_live] >= 1).all(), "dropless"
        return dict(
            makespan=res.makespan,
            total_work=res.total_work,
            wasted_slots=res.wasted_slots,
            steals=int(res.steals.sum()),
            steal_ratio=round(res.steal_ratio, 3),
            slots_scanned=res.slots_scanned,
            extractions=res.extractions,
            scan_per_extraction=round(res.scan_per_extraction, 3),
        )

    # host-layout scheduler runs: the two steal policies + the static EP
    # baseline, identical routing and cost accounting
    tasks, routed = route_to_tasks(idx, gates, E, bt=bt)
    for name, sched, policy in (
        ("ws_cost", "ws", "cost"),
        ("ws_scan", "ws", "scan"),
        ("static", "static", "cost"),
    ):
        state = make_queue_state(
            tasks, P, n_queues=E if sched == "ws" else P, partition="owner"
        )
        t0 = time.perf_counter()
        res = run_moe_schedule(
            state, x, routed.tok_idx, *w, bt=bt,
            steal=(sched == "ws"), steal_policy=policy,
        )
        row[name] = telemetry(res, state.n_tasks)
        row[name]["wall_s"] = round(time.perf_counter() - t0, 3)

    # half-run amortized steal: one probe claims min(ceil(rem/2), cap)
    # contiguous slots, so the win scales with queue DEPTH in slots.  The
    # grid's mean load is ~1 tile/expert (rem <= 2 takes 1 slot — no runs),
    # so this is measured on the cell's deep-queue slice: the same T*k
    # routed rows concentrated into the E//16 hot set at fine tile
    # granularity (bt=2 -> ~32 tiles per hot queue), the regime the
    # per-slot probe traffic actually hurts in.  BOTH rows get the SAME
    # cap-adjusted round budget so the probes-per-extraction comparison is
    # launch-for-launch fair (probe traffic accumulates per round).
    half_cap, bt_deep = 4, 2
    h = max(1, E // 16)
    rng_h = np.random.RandomState(seed + 1)
    hot = rng_h.choice(E, size=h, replace=False)
    k_deep = min(k, h)
    idx_d = np.stack(
        [rng_h.choice(hot, size=k_deep, replace=False) for _ in range(T)]
    ).astype(np.int32)
    gates_d = rng_h.uniform(0.2, 1.0, size=(T, k_deep)).astype(np.float32)
    gates_d /= gates_d.sum(1, keepdims=True)
    tasks_d, routed_d = route_to_tasks(idx_d, gates_d, E, bt=bt_deep)
    rounds_hr = expert_rounds_bound(T * k_deep, bt_deep, E, P, steal=True,
                                    steal_run_cap=half_cap)
    for name, cap in (("ws_cost_eqrounds", 1), ("ws_halfrun", half_cap)):
        state = make_queue_state(tasks_d, P, n_queues=E, partition="owner")
        t0 = time.perf_counter()
        res = run_moe_schedule(
            state, x, routed_d.tok_idx, *w, bt=bt_deep, steal=True,
            steal_policy="cost", rounds=rounds_hr, steal_run_cap=cap,
        )
        row[name] = telemetry(res, state.n_tasks)
        row[name]["wall_s"] = round(time.perf_counter() - t0, 3)
    row["halfrun_cap"] = half_cap
    row["probe_reduction_halfrun"] = round(
        row["ws_cost_eqrounds"]["scan_per_extraction"]
        / max(1e-9, row["ws_halfrun"]["scan_per_extraction"]), 2)

    # traced-layout comparison: padded (PR 3) vs shared pool (this PR)
    records, live, routed_p = route_to_tasks_jax(
        jnp.asarray(idx), jnp.asarray(gates), E, bt=bt
    )
    cand, cand_live = expert_queue_candidates(records, live, E)
    sp = make_queue_state_jax(
        cand, cand_live, P, n_tasks=records.shape[0] * records.shape[1]
    )
    padded_bytes = sp.queue_array_bytes() + _routed_bytes(routed_p)

    rec, tail, pool_off, routed_q = route_to_tasks_pool_jax(
        jnp.asarray(idx), jnp.asarray(gates), E, bt=bt
    )
    sq = make_pool_queue_state_jax(
        rec, tail, pool_off, routed_q.loads, P, n_tasks=rec.shape[0]
    )
    pool_bytes = sq.queue_array_bytes() + _routed_bytes(routed_q)
    res_pool = run_moe_schedule(
        sq, x, routed_q.tok_idx, *w, bt=bt, steal=True, steal_policy="cost",
        rounds=expert_rounds_bound(T * k, bt, E, P, steal=True),
    )
    row["pool"] = telemetry(res_pool, int(np.asarray(tail).sum()))
    row["queue_bytes"] = dict(
        padded=padded_bytes,
        pool=pool_bytes,
        ratio=round(padded_bytes / max(1, pool_bytes), 2),
    )

    # batched-Put lowering audit: the queue-build pipelines emit whole
    # per-expert segments as vectorized gathers — zero HLO scatter ops
    # (the per-record formulation paid one scatter per queue column)
    def build_padded(i, g):
        rc, lv, r = route_to_tasks_jax(i, g, E, bt=bt)
        c, cl = expert_queue_candidates(rc, lv, E)
        s = make_queue_state_jax(c, cl, P, n_tasks=rc.shape[0] * rc.shape[1])
        return s.tasks, s.tail, s.remaining

    def build_pool(i, g):
        rec, tl, off, r = route_to_tasks_pool_jax(i, g, E, bt=bt)
        s = make_pool_queue_state_jax(rec, tl, off, r.loads, P,
                                      n_tasks=rec.shape[0])
        return s.tasks, s.tail, s.remaining

    row["put_scatter_ops"] = {}
    for name, fn in (("padded", build_padded), ("pool", build_pool)):
        try:
            text = jax.jit(fn).lower(
                jnp.asarray(idx), jnp.asarray(gates)).as_text()
            row["put_scatter_ops"][name] = text.count("scatter")
        except Exception as e:  # pragma: no cover - backend quirk
            row["put_scatter_ops"][name] = str(e)[:200]

    if dryrun_analysis:
        rounds = expert_rounds_bound(T * k, bt, E, P, steal=True)

        def pipe_pool(i, g, x, wg, wu, wd):
            rec, tail, off, r = route_to_tasks_pool_jax(i, g, E, bt=bt)
            s = make_pool_queue_state_jax(
                rec, tail, off, r.loads, P, n_tasks=rec.shape[0]
            )
            res = run_moe_schedule(
                s, x, r.tok_idx, wg, wu, wd, bt=bt, steal=True, rounds=rounds
            )
            return res.out, res.mult

        def pipe_padded(i, g, x, wg, wu, wd):
            rc, lv, r = route_to_tasks_jax(i, g, E, bt=bt)
            c, cl = expert_queue_candidates(rc, lv, E)
            s = make_queue_state_jax(c, cl, P, n_tasks=rc.shape[0] * rc.shape[1])
            res = run_moe_schedule(
                s, x, r.tok_idx, wg, wu, wd, bt=bt, steal=True, rounds=rounds
            )
            return res.out, res.mult

        row["dryrun"] = {}
        for name, fn in (("padded", pipe_padded), ("pool", pipe_pool)):
            try:
                comp = jax.jit(fn).lower(
                    jnp.asarray(idx), jnp.asarray(gates), x, *w
                ).compile()
                ca = comp.cost_analysis()
                if isinstance(ca, list):
                    ca = ca[0] if ca else {}
                row["dryrun"][name] = dict(
                    bytes_accessed=float(ca.get("bytes accessed", 0.0)),
                    flops=float(ca.get("flops", 0.0)),
                )
            except Exception as e:  # backend without cost_analysis
                row["dryrun"][name] = dict(error=str(e)[:200])
    return row


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true", help="tiny grid for CI smoke")
    ap.add_argument("--out", default=None, help="output JSON path")
    args = ap.parse_args(argv)
    if args.out is None:
        name = "BENCH_policy.dryrun.json" if args.dry_run else "BENCH_policy.json"
        args.out = str(pathlib.Path(__file__).parent / name)

    if args.dry_run:
        grid = [(16, 4.0), (32, 4.0)]
        k, P, bt, d, f = 2, 4, 4, 8, 16
        T_of = lambda E: 2 * E  # noqa: E731
    else:
        grid = [(64, 4.0), (64, 8.0), (160, 4.0), (160, 8.0),
                (384, 4.0), (384, 8.0)]
        k, P, bt, d, f = 2, 8, 8, 8, 16
        T_of = lambda E: 2 * E  # noqa: E731

    rows = []
    hdr = ("E,skew,cost_makespan,scan_makespan,static_makespan,"
           "cost_scan/extr,scan_scan/extr,traffic_reduction,"
           "halfrun_scan/extr,probe_red_halfrun,put_scatters,"
           "pool_makespan,bytes_padded,bytes_pool,bytes_ratio")
    print(hdr)
    for E, skew in grid:
        row = run_cell(E, T_of(E), k, P, bt, d, f, skew)
        red = row["ws_scan"]["scan_per_extraction"] / max(
            1e-9, row["ws_cost"]["scan_per_extraction"]
        )
        row["traffic_reduction"] = round(red, 1)
        rows.append(row)
        scat = row["put_scatter_ops"]
        print(
            f"{E},{skew},{row['ws_cost']['makespan']},{row['ws_scan']['makespan']},"
            f"{row['static']['makespan']},{row['ws_cost']['scan_per_extraction']},"
            f"{row['ws_scan']['scan_per_extraction']},{row['traffic_reduction']},"
            f"{row['ws_halfrun']['scan_per_extraction']},"
            f"{row['probe_reduction_halfrun']},"
            f"{scat.get('padded')}+{scat.get('pool')},"
            f"{row['pool']['makespan']},{row['queue_bytes']['padded']},"
            f"{row['queue_bytes']['pool']},{row['queue_bytes']['ratio']}"
        )

    payload = dict(
        bench="steal_policy",
        config=dict(k=k, n_programs=P, bt=bt, d=d, f=f, dry_run=args.dry_run),
        rows=rows,
    )
    pathlib.Path(args.out).write_text(json.dumps(payload, indent=2))
    print(f"[steal_policy] wrote {args.out}")

    # the ISSUE-4 acceptance claims, checked at the largest E / skew >= 4
    E_max = max(E for E, _ in grid)
    bad = []
    for r in rows:
        if r["E"] != E_max or r["skew"] < 4:
            continue
        if r["traffic_reduction"] < 10.0:
            bad.append(("scan traffic reduction < 10x", r["E"], r["skew"],
                        r["traffic_reduction"]))
        if r["queue_bytes"]["ratio"] < 4.0:
            bad.append(("pool bytes reduction < 4x", r["E"], r["skew"],
                        r["queue_bytes"]["ratio"]))
        if r["pool"]["makespan"] != r["ws_cost"]["makespan"]:
            bad.append(("pool layout changed the schedule", r["E"], r["skew"]))
        if r["ws_cost"]["makespan"] > r["ws_scan"]["makespan"] * 1.05:
            bad.append(("cost policy makespan regressed vs scan", r["E"],
                        r["skew"]))
    # amortized-synchronization claims (this PR): half-run probe reduction
    # >= 2x on deep queues (E >= 160, skew >= 4), zero-scatter batched Put
    # everywhere
    for r in rows:
        scat = r.get("put_scatter_ops", {})
        if any(isinstance(v, int) and v > 0 for v in scat.values()):
            bad.append(("batched Put lowering emits scatters", r["E"],
                        r["skew"], scat))
        if r["E"] >= 160 and r["skew"] >= 4:
            hr = r.get("probe_reduction_halfrun", 0.0)
            if hr < 2.0:
                bad.append(("half-run probe reduction < 2x", r["E"],
                            r["skew"], hr))
            # Graham slack: a claimed run can serialize at most cap extra
            # tiles (max cost bt_deep=2) on one program
            slack = r.get("halfrun_cap", 4) * 2
            if (r["ws_halfrun"]["makespan"]
                    > r["ws_cost_eqrounds"]["makespan"] + slack):
                bad.append(("half-run makespan regressed", r["E"], r["skew"]))
    if bad:
        print(f"[steal_policy] ISSUE-4 claims failed: {bad}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""MoE dispatch benchmark: dropping-dense vs ws-dropless across router skew.

Workload: top-k routing over E experts with a heavy-tailed popularity
distribution — ``skew`` is the target ratio of the hottest expert's load to
the mean load, the shape DeepSeek-V2/Kimi-K2-class routers produce.  Two
dispatches process the same routed (token, expert) pairs:

* **dropping-dense** (`models.moe.moe_ffn`): fixed per-expert capacity
  ``C = _capacity(T, k, E, cf)``; the FFN einsums are shaped [E, C]
  regardless of which slots are live, so its cost is ``E*C`` token-rows —
  balanced (capacity is uniform), but every row the router sends over C is
  **dropped** and the padded slots of cold experts are wasted work.
* **ws-dropless** (`repro.moe_ws`): one task row per routed pair, expert
  tiles through the fence-free work-stealing megakernel.  Cost is exactly
  the routed work; hot-expert queue skew is erased by thieves.  Nothing is
  dropped — the combine is exact after multiplicity normalization.

Reported per skew (units: token-rows of expert FFN, the shared cost model):

* ``dense_makespan``   — E*C/P rows (the dense grid split over P programs)
* ``ws/static makespan`` — device-measured clock of the megakernel
* ``drop_rate``        — fraction of routed pairs the dense path loses
                         (replayed with the dense cumsum slotting)
* ``max_abs_err``      — ws combine vs the dense **no-drop** oracle

Plus ``grad_rows``: jit(grad) through the dispatch's custom VJP at the
headline skew — wall clock per backward (``grad_dispatch`` dense vs ws) and
gradient parity vs ``jax.grad`` of the no-drop oracle (gated at fp32
tolerance; `benchmarks/perf_smoke.py` replays it in CI).

Writes BENCH_moe.json next to this file.  ``--dry-run`` shrinks shapes for
CI (Pallas interpret mode on CPU).  Exit status 1 when the headline claim
fails: at skew >= 4 the dense path must be dropping tokens (>0%) while the
ws makespan beats the dense makespan by >= 2x.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np


def make_skewed_routing(T: int, E: int, k: int, skew: float, seed: int = 0):
    """Sample top-k routing with hot-set popularity ``skew`` (hot/mean load).

    A hot set of ``max(1, E // 16)`` experts carries ``skew``× the mean
    per-expert load; the rest share the remainder uniformly.  Returns
    (idx [T, k], gates [T, k]) with gates normalized per token.
    """
    rng = np.random.RandomState(seed)
    h = max(1, E // 16)
    skew = min(float(skew), 0.95 * E / h)  # keep the hot weight finite
    w_hot = skew * (E - h) / max(E - skew * h, 1e-9)
    w = np.ones(E, dtype=np.float64)
    # hot experts land anywhere in [0, E): a static expert->program placement
    # cannot assume they are spread conveniently
    w[rng.choice(E, size=h, replace=False)] = w_hot
    p = w / w.sum()
    idx = np.stack(
        [rng.choice(E, size=k, replace=False, p=p) for _ in range(T)]
    ).astype(np.int32)
    gates = rng.uniform(0.2, 1.0, size=(T, k)).astype(np.float32)
    gates /= gates.sum(axis=1, keepdims=True)
    return idx, gates


def dense_drop_stats(idx, E: int, C: int):
    """Replay the dense path's capacity slotting (cumsum over the flattened
    (token, choice) axis, exactly `models.moe.moe_ffn`) and count drops."""
    T, k = idx.shape
    flat = np.zeros((T * k, E), dtype=np.int64)
    flat[np.arange(T * k), idx.reshape(-1)] = 1
    slot = np.cumsum(flat, axis=0) - flat
    in_cap = (slot[np.arange(T * k), idx.reshape(-1)] < C)
    dropped = int((~in_cap).sum())
    return dropped, dropped / float(T * k)


def run_one(T, d, f, E, k, P, bt, cf, skew, seed=0, trace=False, trace_sink=None):
    import jax
    import jax.numpy as jnp

    from repro.models.moe import _capacity
    from repro.moe_ws import (
        combine_routed,
        expert_ffn_nodrop_ref,
        route_to_tasks,
        run_moe_schedule,
    )
    from repro.pallas_ws import make_queue_state

    idx, gates = make_skewed_routing(T, E, k, skew, seed)
    loads = np.bincount(idx.reshape(-1), minlength=E)
    C = _capacity(T, k, E, cf)
    dropped, drop_rate = dense_drop_stats(idx, E, C)

    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    wg = jax.random.normal(ks[1], (E, d, f), jnp.float32) / np.sqrt(d)
    wu = jax.random.normal(ks[2], (E, d, f), jnp.float32) / np.sqrt(d)
    wd = jax.random.normal(ks[3], (E, f, d), jnp.float32) / np.sqrt(f)
    ref = expert_ffn_nodrop_ref(idx, gates, x, wg, wu, wd)

    row = dict(
        T=T, d=d, f=f, E=E, k=k, n_programs=P, bt=bt, capacity=C,
        skew=skew, routed=int(T * k),
        max_load=int(loads.max()), mean_load=float(loads.mean()),
        dense_dropped=dropped, dense_drop_rate=drop_rate,
    )
    # "ws" runs the cost-aware O(1) victim selection (the default);
    # "ws_scan" keeps the PR-1 sequential scan for comparison (§3.6)
    for name, sched, policy in (
        ("static", "static", "cost"),
        ("ws", "ws", "cost"),
        ("ws_scan", "ws", "scan"),
    ):
        tasks, routed = route_to_tasks(idx, gates, E, bt=bt)
        # ws: one queue per expert (the per-expert token list), thieves roam;
        # static: experts placed round-robin over programs (classic EP) and
        # each program drains only its own queue
        state = make_queue_state(
            tasks, P, n_queues=E if sched == "ws" else P, partition="owner"
        )
        t0 = time.perf_counter()
        res = run_moe_schedule(
            state, x, routed.tok_idx, wg, wu, wd,
            bt=bt, steal=(sched == "ws"), steal_policy=policy,
            trace=(trace and name == "ws"),
        )
        dt = time.perf_counter() - t0
        y = combine_routed(routed, tasks, res)
        err = float(jnp.abs(y - ref).max())
        assert (res.mult[: state.n_tasks] >= 1).all(), "dropless invariant"
        row[name] = dict(
            makespan=res.makespan,
            total_work=res.total_work,
            wasted_slots=res.wasted_slots,
            steals=int(res.steals.sum()),
            steal_ratio=round(res.steal_ratio, 3),
            mult_max=int(res.mult[: state.n_tasks].max()),
            slots_scanned=res.slots_scanned,
            extractions=res.extractions,
            scan_per_extraction=round(res.scan_per_extraction, 3),
            max_abs_err=err,
            wall_s=round(dt, 3),
        )
        if res.events is not None:
            from repro.wstrace import WSTrace

            tr = WSTrace.from_run(state, res)
            row[name]["trace"] = tr.summary()
            if trace_sink is not None:
                trace_sink[name] = tr
    # the dense einsums process E*C rows no matter what the router did;
    # capacity is uniform per expert, so the grid splits evenly over P
    row["dense_makespan"] = -(-E * C // P)
    row["speedup_vs_dense"] = row["dense_makespan"] / max(1, row["ws"]["makespan"])
    row["speedup_vs_static"] = row["static"]["makespan"] / max(1, row["ws"]["makespan"])
    return row


def run_grad(T, d, f, E, k, P, bt, skew, seed=0):
    """Grad-path rows (DESIGN.md §4.5): time ``jit(grad)`` through the ws
    dispatch's custom VJP — backward as the closed-form dense transpose and
    as the re-scheduled megakernel launch — and pin its parity against
    ``jax.grad`` of the no-drop oracle (``max_abs_err`` over every
    cotangent: gates, x, and all three expert weights)."""
    import jax
    import jax.numpy as jnp

    from repro.moe_ws import expert_ffn_nodrop_ref, expert_ffn_ws

    idx, gates = make_skewed_routing(T, E, k, skew, seed)
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    wg = jax.random.normal(ks[1], (E, d, f), jnp.float32) / np.sqrt(d)
    wu = jax.random.normal(ks[2], (E, d, f), jnp.float32) / np.sqrt(d)
    wd = jax.random.normal(ks[3], (E, f, d), jnp.float32) / np.sqrt(f)
    args = (jnp.asarray(gates), x, wg, wu, wd)

    def loss_ref(gates, x, wg, wu, wd):
        return (expert_ffn_nodrop_ref(idx, gates, x, wg, wu, wd) ** 2).sum()

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2, 3, 4))(*args)

    rows = []
    for gd in ("dense", "ws"):

        def loss_ws(gates, x, wg, wu, wd, gd=gd):
            return (expert_ffn_ws(idx, gates, x, wg, wu, wd, grad_dispatch=gd,
                                  n_programs=P, bt=bt) ** 2).sum()

        g_fn = jax.jit(jax.grad(loss_ws, argnums=(0, 1, 2, 3, 4)))
        t0 = time.perf_counter()
        g = jax.block_until_ready(g_fn(*args))
        compile_s = time.perf_counter() - t0
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            g = jax.block_until_ready(g_fn(*args))
            best = min(best, time.perf_counter() - t0)
        err = max(
            float(jnp.abs(a - b).max()) for a, b in zip(g, g_ref)
        )
        rows.append(
            dict(
                grad_dispatch=gd, skew=skew, T=T, E=E, k=k,
                max_abs_err=err,
                wall_s=round(best, 4),
                compile_s=round(compile_s, 3),
            )
        )
    return rows


# the CI smoke cell (T, d, f, E, k, P, bt, cf) — perf_smoke.py replays it
# with tracing off and holds the makespans to exact equality with BENCH.json
DRY_SHAPES = (48, 16, 32, 32, 2, 2, 4, 1.25)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true", help="tiny shapes for CI smoke")
    ap.add_argument("--skews", default="1,2,4,8")
    ap.add_argument("--out", default=None, help="output JSON path")
    ap.add_argument("--trace", default=None, metavar="TRACE_JSON",
                    help="write a Perfetto timeline of the highest-skew ws "
                         "run (load it at https://ui.perfetto.dev)")
    args = ap.parse_args(argv)
    if args.out is None:
        # dry-run results go to a sibling file so CI smokes never clobber
        # the committed full-size benchmark
        name = "BENCH_moe.dryrun.json" if args.dry_run else "BENCH_moe.json"
        args.out = str(pathlib.Path(__file__).parent / name)

    if args.dry_run:
        T, d, f, E, k, P, bt, cf = DRY_SHAPES
    else:
        T, d, f, E, k, P, bt, cf = 96, 32, 64, 64, 2, 4, 4, 1.25

    skews = [float(s) for s in args.skews.split(",")]
    rows = []
    traces = {}
    hdr = ("skew,dense_makespan,ws_makespan,speedup_dense,static_makespan,"
           "drop_rate,steals,mult_max,max_err")
    print(hdr)
    for skew in skews:
        sink = {}
        row = run_one(T, d, f, E, k, P, bt, cf, skew, trace=True,
                      trace_sink=sink)
        if "ws" in sink:
            traces[skew] = sink["ws"]
        rows.append(row)
        print(
            f"{skew},{row['dense_makespan']},{row['ws']['makespan']},"
            f"{row['speedup_vs_dense']:.2f},{row['static']['makespan']},"
            f"{row['dense_drop_rate']:.3f},{row['ws']['steals']},"
            f"{row['ws']['mult_max']},{row['ws']['max_abs_err']:.2e}"
        )

    # grad path: jit(grad) through the custom VJP at the headline skew —
    # wall clock per backward evaluation + parity vs the no-drop oracle
    grad_rows = run_grad(T, d, f, E, k, P, bt, skew=4.0)
    print("grad_dispatch,wall_s,compile_s,max_abs_err")
    for r in grad_rows:
        print(f"{r['grad_dispatch']},{r['wall_s']},{r['compile_s']},"
              f"{r['max_abs_err']:.2e}")

    # traced-Put audit: the jit-compatible queue construction must lower to
    # plain tensor ops — 0 RMW / 0 locks / 0 fences on Put, Take AND Steal
    # (asserts internally; the rows land in the payload as the record)
    try:
        from benchmarks.zero_cost import audit_traced_put
    except ModuleNotFoundError:  # run as a bare script: python benchmarks/...
        sys.path.insert(0, str(pathlib.Path(__file__).parent.parent))
        from benchmarks.zero_cost import audit_traced_put

    payload = dict(
        bench="moe_dispatch",
        config=dict(T=T, d=d, f=f, E=E, k=k, n_programs=P, bt=bt,
                    capacity_factor=cf, dry_run=args.dry_run),
        rows=rows,
        grad_rows=grad_rows,
        traced_put_audit=audit_traced_put(),
    )
    pathlib.Path(args.out).write_text(json.dumps(payload, indent=2))
    print(f"[moe_dispatch] wrote {args.out}")

    if args.trace and traces:
        from repro.wstrace import write_perfetto

        write_perfetto(traces[max(traces)], args.trace)
        print(f"[moe_dispatch] wrote Perfetto trace (skew={max(traces)}) to "
              f"{args.trace} — open at https://ui.perfetto.dev")

    # the headline claim this bench exists to witness: under real router
    # skew the dense path is lossy AND slower than dropless ws dispatch
    bad = [
        r for r in rows
        if r["skew"] >= 4
        and (r["speedup_vs_dense"] < 2.0 or r["dense_drop_rate"] <= 0.0)
    ]
    if bad:
        print(f"[moe_dispatch] ws-dropless claim failed at skew >= 4: {bad}")
        return 1
    # grad-path claim: both backward evaluations of the custom VJP match
    # the no-drop oracle's gradients to fp32 tolerance
    bad_grad = [r for r in grad_rows if r["max_abs_err"] > 1e-3]
    if bad_grad:
        print(f"[moe_dispatch] custom-VJP grad parity failed: {bad_grad}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

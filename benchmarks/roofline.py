"""Render the roofline table from the dry-run JSONL records."""

from __future__ import annotations

import json
import os
from typing import List

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.jsonl")


def load(path: str = RESULTS) -> List[dict]:
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    # newest record wins per (arch, shape, mesh, ws_mode)
    dedup = {}
    for r in out:
        dedup[(r["arch"], r["shape"], r["mesh"], r.get("ws_mode"))] = r
    return list(dedup.values())


def table(rows: List[dict], mesh: str = "16x16") -> str:
    cols = (
        "arch", "shape", "compute_s", "memory_s", "collective_s",
        "bottleneck", "useful_flops_ratio", "fit",
    )
    lines = [",".join(cols)]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh or r.get("plan") != "run" or r.get("ws_mode"):
            continue
        if "compute_s" not in r:
            continue
        mem = r.get("memory", {})
        dev_bytes = (
            mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
        )
        fit = "yes" if dev_bytes and dev_bytes < 16e9 else f"no({dev_bytes/1e9:.0f}GB)"
        lines.append(
            f"{r['arch']},{r['shape']},{r['compute_s']:.4f},{r['memory_s']:.4f},"
            f"{r['collective_s']:.4f},{r['bottleneck'].replace('_s','')},"
            f"{r['useful_flops_ratio']:.3f},{fit}"
        )
    skipped = [r for r in rows if r.get("plan", "").startswith("skip") and r["mesh"] == mesh]
    for r in sorted(skipped, key=lambda r: (r["arch"], r["shape"])):
        lines.append(f"{r['arch']},{r['shape']},-,-,-,SKIP,-,-")
    return "\n".join(lines)


def perf_table(path=None) -> str:
    """§Perf iteration records (tagged re-runs) vs their baselines."""
    path = path or os.path.join(os.path.dirname(__file__), "results", "perf.jsonl")
    rows = load(path)
    base = {(r["arch"], r["shape"]): r for r in load() if r["mesh"] == "16x16"}
    lines = ["tag,arch,shape,compute_s,memory_s,collective_s,useful_ratio,(baseline mem_s)"]
    for r in sorted(rows, key=lambda r: (r.get("tag") or "", r["arch"], r["shape"])):
        if "compute_s" not in r or r["mesh"] != "16x16":
            continue
        b = base.get((r["arch"], r["shape"]), {})
        lines.append(
            f"{r.get('tag','')},{r['arch']},{r['shape']},{r['compute_s']:.4f},"
            f"{r['memory_s']:.4f},{r['collective_s']:.4f},"
            f"{r['useful_flops_ratio']:.3f},({b.get('memory_s', float('nan')):.2f})"
        )
    return "\n".join(lines)


def main():
    rows = load()
    if not rows:
        print("no dry-run records yet (run scripts/run_dryrun_sweep.sh)")
        return []
    print("== roofline (single-pod 16x16) ==")
    print(table(rows, "16x16"))
    multi = [r for r in rows if r["mesh"] == "2x16x16" and r.get("plan") == "run"]
    print(f"\n== multi-pod 2x16x16: {len(multi)} cells compiled ==")
    pt = perf_table()
    if pt.count("\n"):
        print("\n== §Perf iterations (tagged) vs baseline ==")
        print(pt)
    return rows


if __name__ == "__main__":
    main()

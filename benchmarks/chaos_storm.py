"""Seeded fault-storm replay: the chaos matrix through the SafetyChecker.

Every cell drives a deterministic :class:`repro.chaos.FaultPlan` —
program stalls, advisory corruption, kill-and-relaunch, head-rewind
storms, or a whole seeded combination — through the segmented injector
(`repro.chaos.inject.run_with_faults`) over the scheduler matrix

    fault kind × steal policy {cost, scan} × queue layout {moe, attention}

plus two serving cells on the real smoke engine:

* ``replica_crash`` — a :class:`ReplicaCrashPlan` kills a replica
  mid-run; the frontend re-admits its in-flight requests idempotently and
  the greedy streams must be IDENTICAL to the fault-free run's streams;
* ``watchdog`` — an :class:`EngineFaultPlan` poisons unified-step logits;
  the batcher degrades to the split path and the streams must match the
  clean unified run bitwise.

Reported per scheduler cell: checker verdict, max multiplicity, claim
counts, ring drops, segment structure, and output parity ("bitwise" exact
float replay for the single-source moe rows, "close"-or-better normalized
parity for attention).  Per serving cell: completion/rejection sets,
re-admission + degradation counts, stream parity.  The headline claims
are absolute gates (exit 1):

* every scheduler cell is checker-clean (no lost task, per-launch
  uniqueness, the stale-republish multiplicity bound, drain) with
  acceptable output parity;
* a ``fault_off_parity`` cell proves ``fault_plan=None``, an omitted
  kwarg and a zero ``FaultPlan()`` lower to bit-identical results —
  chaos injection is free when off;
* every serving request is completed-or-rejected exactly once, with no
  duplicate token emission, and faulted streams equal fault-free streams.

Writes BENCH_chaos.json next to this file (``--dry-run``:
BENCH_chaos.dryrun.json, the smaller matrix for CI; all columns are
deterministic, so perf_smoke gates them exactly).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

# dry-run matrix: (moe tokens, fault kinds, seeds per cell)
DRY_SHAPES = (8, ("kill_storm", "combined"), 1)


def _fault_matrix():
    """Named plan constructors: seed -> FaultPlan."""
    from repro.chaos import FaultPlan

    return {
        "stalls": lambda s: FaultPlan(seed=s, stalls=(3, 0, 2, 0)),
        "advisory": lambda s: FaultPlan(seed=s, advisory="random"),
        "kill_storm": lambda s: FaultPlan(seed=s, kills=(1,), storms=1,
                                          full_first_storm=True),
        "combined": lambda s: FaultPlan.from_seed(s),
    }


# ---------------------------------------------------------------------------
# scheduler cells
# ---------------------------------------------------------------------------


def _moe_problem(seed: int, n_tokens: int, n_programs: int):
    import jax
    import jax.numpy as jnp

    from repro.moe_ws.dispatch import route_to_tasks
    from repro.pallas_ws.queues import make_queue_state

    rng = np.random.RandomState(seed % 2**31)
    E, k, bt = 4, 1, 2
    d, f = 4, 8
    idx = np.stack([rng.choice(E, k, replace=False) for _ in range(n_tokens)])
    gates = rng.uniform(0.1, 1.0, (n_tokens, k)).astype(np.float32)
    gates /= gates.sum(1, keepdims=True)
    ks = jax.random.split(jax.random.PRNGKey(seed % 997), 4)
    x = jax.random.normal(ks[0], (n_tokens, d), jnp.float32)
    w = (
        jax.random.normal(ks[1], (E, d, f), jnp.float32) / 2.0,
        jax.random.normal(ks[2], (E, d, f), jnp.float32) / 2.0,
        jax.random.normal(ks[3], (E, f, d), jnp.float32) / 2.0,
    )
    tasks, routed = route_to_tasks(idx, gates, E, bt=bt)
    state = make_queue_state(tasks, n_programs, n_queues=E, partition="owner")
    return x, w, bt, tasks, routed, state


def run_scheduler_cell(layout: str, policy: str, fault: str, seed: int,
                       *, n_tokens: int = 10) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.chaos import SafetyChecker, run_with_faults
    from repro.moe_ws.dispatch import row_divisor
    from repro.moe_ws.expert_kernel import run_moe_schedule
    from repro.pallas_ws import (
        emit_flash_tasks,
        make_queue_state,
        multiplicity_divisor,
        ragged_attention_ref,
    )
    from repro.pallas_ws.kernel import default_rounds, run_ws_schedule
    from repro.pallas_ws.queues import copy_state

    plan = _fault_matrix()[fault](seed)
    t0 = time.perf_counter()
    if layout == "moe":
        P = 3
        x, w, bt, tasks, routed, state = _moe_problem(seed, n_tokens, P)
        rounds = default_rounds(state, steal=True)
        oracle = run_moe_schedule(
            copy_state(state), x, routed.tok_idx, *w, bt=bt, steal=True,
            steal_policy=policy, rounds=rounds,
        )

        def launch(state, *, rounds, out, mult, fault_plan):
            return run_moe_schedule(
                state, x, routed.tok_idx, *w, bt=bt, steal=True,
                steal_policy=policy, rounds=rounds, out=out,
                mult=None if mult is None else jnp.asarray(mult),
                trace=True, fault_plan=fault_plan,
            )

        chaos = run_with_faults(state, launch, plan, rounds=rounds)
        report = SafetyChecker().check(
            chaos, n_tasks=state.n_tasks,
            oracle_accumulated=np.asarray(oracle.out),
            row_mult=row_divisor(tasks, chaos.res.mult, routed.n_rows),
        )
        parity_ok = report.normalized_parity == "bitwise"
    else:  # attention
        lengths = np.array([32, 8, 8, 16])
        H, bq, bk = 2, 8, 8
        B, S = len(lengths), int(max(lengths))
        ks = jax.random.split(jax.random.PRNGKey(seed % 997), 3)
        q = jax.random.normal(ks[0], (B, H, S, 8))
        k = jax.random.normal(ks[1], (B, H, S, 8))
        v = jax.random.normal(ks[2], (B, H, S, 8))
        tasks = emit_flash_tasks(lengths, H, bq, bk, causal=True)
        state = make_queue_state(tasks, n_programs=4)
        rounds = default_rounds(state, steal=True)

        def launch(state, *, rounds, out, mult, fault_plan):
            return run_ws_schedule(
                state, q, k, v, causal=True, bq=bq, bk=bk, steal=True,
                steal_policy=policy, rounds=rounds, out=out,
                mult=None if mult is None else jnp.asarray(mult),
                trace=True, fault_plan=fault_plan,
            )

        chaos = run_with_faults(state, launch, plan, rounds=rounds)
        div = multiplicity_divisor(tasks, chaos.res.mult, (B, H, S))
        normalized = np.asarray(chaos.res.out) / np.asarray(div)[..., None]
        report = SafetyChecker().check(
            chaos, n_tasks=state.n_tasks,
            normalized=normalized,
            oracle_normalized=np.asarray(
                ragged_attention_ref(q, k, v, lengths)),
            rtol=1e-5, atol=1e-5,
        )
        parity_ok = report.normalized_parity in ("bitwise", "close")

    return dict(
        section="scheduler",
        layout=layout, policy=policy, fault=fault, seed=seed,
        ok=bool(report.ok and parity_ok),
        checker_ok=bool(report.ok),
        max_mult=report.max_mult,
        n_claims=report.n_claims,
        n_tasks=report.n_tasks,
        dropped=report.dropped,
        parity=report.normalized_parity,
        segments=report.stats["segments"],
        violations=[str(v) for v in report.violations],
        wall_s=round(time.perf_counter() - t0, 3),
    )


def run_fault_off_parity(seed: int = 7, n_tokens: int = 10) -> dict:
    """fault_plan omitted vs None vs FaultPlan(): bitwise on every field."""
    from repro.chaos import FaultPlan
    from repro.moe_ws.expert_kernel import run_moe_schedule
    from repro.pallas_ws.kernel import default_rounds
    from repro.pallas_ws.queues import copy_state

    fields = ("out", "mult", "head", "local_head", "taken", "remaining",
              "clock", "work", "steals", "scanned")
    x, w, bt, tasks, routed, state = _moe_problem(seed, n_tokens, 3)
    rounds = default_rounds(state, steal=True)

    def run(**kw):
        return run_moe_schedule(
            copy_state(state), x, routed.tok_idx, *w, bt=bt, steal=True,
            rounds=rounds, **kw,
        )

    base = run()
    ok = True
    for res in (run(fault_plan=None), run(fault_plan=FaultPlan())):
        for f in fields:
            if not np.array_equal(np.asarray(getattr(base, f)),
                                  np.asarray(getattr(res, f))):
                ok = False
    return dict(section="parity", cell="fault_off_parity", seed=seed,
                ok=ok, fields=list(fields))


# ---------------------------------------------------------------------------
# serving cells (real smoke engine)
# ---------------------------------------------------------------------------


def _serving_streams(completed) -> dict:
    return {int(rid): list(map(int, r.out)) for rid, r in completed.items()}


def run_replica_crash_cell(*, crash_iter: int = 1, n_requests: int = 4,
                           max_new: int = 5) -> dict:
    import jax

    from repro.chaos import ReplicaCrashPlan
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving.engine import (
        ContinuousBatcher,
        Request,
        WorkStealingFrontend,
    )

    cfg = get_config("llama3.2-3b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    prompts = {rid: rng.integers(1, 200, size=int(rng.integers(2, 6)))
               .astype(np.int32) for rid in range(n_requests)}

    def one_run(crash_plan):
        fe = WorkStealingFrontend(
            lambda: ContinuousBatcher(params, cfg, slots=2, capacity=16),
            n_replicas=2, crash_plan=crash_plan,
        )
        for rid, p in prompts.items():
            fe.submit(rid % 2, Request(rid, p, max_new=max_new))
        completed = fe.run(max_iters=300)
        return fe, completed

    t0 = time.perf_counter()
    fe0, clean = one_run(None)
    fe1, faulted = one_run(ReplicaCrashPlan({0: crash_iter}))
    s_clean, s_faulted = _serving_streams(clean), _serving_streams(faulted)
    exactly_once = (
        set(faulted) | set(fe1.rejected) == set(prompts)
        and not (set(faulted) & set(fe1.rejected))
    )
    # readmitted >= 1 keeps the cell honest: the crash must actually land
    # on in-flight decodes, not an already-drained replica
    return dict(
        section="serving", cell="replica_crash",
        crash_iter=crash_iter,
        ok=bool(exactly_once and s_clean == s_faulted
                and fe1.counters["crashed"] == 1
                and fe1.counters["readmitted"] >= 1
                and fe1.counters["dup_completed"] == 0),
        exactly_once=bool(exactly_once),
        streams_match=bool(s_clean == s_faulted),
        completed=sorted(faulted), rejected=sorted(fe1.rejected),
        counters=fe1.stats()["totals"],
        readmitted=fe1.counters["readmitted"],
        crashed=fe1.counters["crashed"],
        wall_s=round(time.perf_counter() - t0, 3),
    )


def run_watchdog_cell(*, poison_steps=(0, 2), max_new: int = 3) -> dict:
    import jax

    from repro.chaos import EngineFaultPlan
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving.engine import ContinuousBatcher, Request

    cfg = get_config("llama3.2-3b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = [np.array([5, 6, 7, 8], np.int32), np.array([9, 8, 7], np.int32)]

    def one_run(fp):
        b = ContinuousBatcher(params, cfg, slots=2, capacity=32,
                              unified_step=True, fault_plan=fp)
        for rid, p in enumerate(prompts):
            assert b.admit(Request(rid, p, max_new=max_new))
        done = []
        for _ in range(24):
            done += b.step()
            if not b.n_live:
                break
        return b, {r.rid: list(map(int, r.out)) for r in done}

    t0 = time.perf_counter()
    b0, clean = one_run(None)
    b1, faulted = one_run(EngineFaultPlan(poison_steps=tuple(poison_steps)))
    degr = [d["kind"] for d in b1.degradations]
    return dict(
        section="serving", cell="watchdog",
        poison_steps=list(poison_steps),
        ok=bool(clean == faulted and degr
                and all(k == "non-finite" for k in degr)
                and not b0.degradations),
        streams_match=bool(clean == faulted),
        degradations=b1.degradations,
        degradation_counts=b1.stats()["degradations"],
        wall_s=round(time.perf_counter() - t0, 3),
    )


# ---------------------------------------------------------------------------
# gates + entry point
# ---------------------------------------------------------------------------


def check_claims(rows) -> int:
    status = 0
    for r in rows:
        if r["ok"]:
            continue
        status = 1
        tag = "/".join(str(r.get(k)) for k in ("section", "layout", "policy",
                                               "fault", "cell", "seed")
                       if r.get(k) is not None)
        print(f"[chaos] FAIL {tag}: "
              f"violations={r.get('violations')} parity={r.get('parity')} "
              f"streams_match={r.get('streams_match')}")
    sched = [r for r in rows if r["section"] == "scheduler"]
    if sched:
        mm = max(r["max_mult"] for r in sched)
        if not any(r["max_mult"] >= 2 for r in sched):
            print("[chaos] FAIL: no scheduler cell exercised multiplicity "
                  "(max_mult < 2 everywhere) — the storm matrix is vacuous")
            status = 1
        print(f"[chaos] scheduler: {len(sched)} cells checker-clean, "
              f"max multiplicity {mm}")
    return status


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="smaller matrix for CI")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    here = pathlib.Path(__file__).parent
    if args.out is None:
        name = ("BENCH_chaos.dryrun.json" if args.dry_run
                else "BENCH_chaos.json")
        args.out = here / name
    if args.dry_run:
        n_tokens, faults, n_seeds = DRY_SHAPES
        policies, layouts = ("cost",), ("moe", "attention")
    else:
        n_tokens, faults, n_seeds = 10, tuple(_fault_matrix()), 2
        policies, layouts = ("cost", "scan"), ("moe", "attention")

    rows = []
    for layout in layouts:
        for policy in policies:
            for fault in faults:
                for seed in range(n_seeds):
                    row = run_scheduler_cell(layout, policy, fault, seed,
                                             n_tokens=n_tokens)
                    rows.append(row)
                    print(
                        f"chaos,layout={layout},policy={policy},fault={fault},"
                        f"seed={seed},ok={row['ok']},max_mult={row['max_mult']},"
                        f"claims={row['n_claims']},parity={row['parity']},"
                        f"segments={len(row['segments'])}"
                    )
    rows.append(run_fault_off_parity())
    print(f"chaos,cell=fault_off_parity,ok={rows[-1]['ok']}")
    rows.append(run_replica_crash_cell())
    r = rows[-1]
    print(f"chaos,cell=replica_crash,ok={r['ok']},readmitted={r['readmitted']},"
          f"streams_match={r['streams_match']}")
    rows.append(run_watchdog_cell())
    r = rows[-1]
    print(f"chaos,cell=watchdog,ok={r['ok']},"
          f"degradations={r['degradation_counts']},"
          f"streams_match={r['streams_match']}")

    status = check_claims(rows)
    payload = dict(
        config=dict(n_tokens=n_tokens, faults=list(faults),
                    policies=list(policies), layouts=list(layouts),
                    n_seeds=n_seeds, dry_run=args.dry_run),
        rows=rows,
        all_ok=all(r["ok"] for r in rows),
    )
    pathlib.Path(args.out).write_text(json.dumps(payload, indent=2))
    print(f"[chaos] wrote {args.out}")
    return status


if __name__ == "__main__":
    sys.exit(main())

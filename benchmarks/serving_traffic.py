"""Serving-under-load benchmark: replayed arrival traffic through the
work-stealing frontend, unified megakernel step vs split-launch step.

Workload: a seeded arrival trace — ``poisson`` (geometric inter-arrival
gaps, arrivals spread round-robin over the replicas) or ``bursty`` (whole
bursts land on replica 0 at once, so the other replicas only get work by
STEALING it) — replayed step-by-step through a
:class:`repro.serving.engine.WorkStealingFrontend`.  Each engine iteration
first submits the arrivals whose timestamp has come due, then runs one
round-robin admission+step pass over the replicas.

Both decode paths run the SAME trace:

* ``split``    — the escape-hatch path: jitted ``decode_step_ws`` per step
  plus a standalone jitted prefill per admission (2 launches per admitting
  step, per replica);
* ``unified``  — ``ContinuousBatcher(unified_step=True)``: ONE mixed-mode
  ``launch_ws_grid`` launch per engine step carrying the decode tiles AND
  the folded-in admission prefill (models.unified, DESIGN.md §5).

Reported per path: p50/p99/mean per-step latency (ms), tokens/sec,
mean slot utilization, steps, and the frontend's scheduling counters
(admitted / stolen / rejected / duplicates).  The correctness claims are
absolute gates (exit 1):

* every submitted rid completes exactly once (or is surfaced as rejected —
  over-capacity prompts are part of the trace on purpose);
* the two paths produce **identical token streams** on the seeded trace —
  the unified launch is bitwise vs the jitted split oracle, so greedy
  streams may not diverge;
* counter consistency: completed + duplicates == total admissions.

Writes BENCH_serving.json next to this file (``--dry-run``:
BENCH_serving.dryrun.json, tiny trace for CI; wall-clock numbers are
recorded but only the deterministic columns — steps, utilization, counters,
stream parity — are regression-gated by perf_smoke).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

# dry-run trace shape: (slots, capacity, n_requests, max_new) — small enough
# for interpret-mode CI, big enough that bursts overflow the slots and the
# second replica must steal
DRY_SHAPES = (2, 32, 5, 3)


def make_trace(mode: str, n_requests: int, capacity: int, n_replicas: int,
               seed: int = 0, max_new: int = 3):
    """Seeded arrival trace: list of (arrival_step, replica, rid, tokens,
    max_new), sorted by arrival_step.

    ``poisson``: geometric inter-arrival gaps, round-robin replica choice.
    ``bursty``: bursts of 3 requests, all submitted to replica 0 at the
    same step — the skewed load the stealing frontend exists for.

    One request per 5 is deliberately over-capacity (prompt == capacity):
    the engine must reject it and the frontend must surface the rejection
    instead of silently dropping or corrupting a slot.
    """
    rng = np.random.default_rng(seed)
    trace = []
    step = 0
    for rid in range(n_requests):
        if mode == "poisson":
            step += int(rng.geometric(0.5))
            replica = rid % n_replicas
        elif mode == "bursty":
            if rid % 3 == 0:
                step += 4
            replica = 0
        else:
            raise ValueError(f"unknown trace mode {mode!r}")
        if rid % 5 == 3:
            length = capacity  # over-capacity: must be rejected, not admitted
        else:
            length = int(rng.integers(2, min(10, capacity - max_new)))
        tokens = rng.integers(1, 200, size=length).astype(np.int32)
        trace.append((step, replica, rid, tokens, max_new))
    return trace


def replay(fe, trace, max_iters: int = 10_000) -> dict:
    """Inject arrivals as their steps come due; drive the frontend one
    round-robin iteration at a time until the trace and all queues drain."""
    from repro.serving.engine import Request

    ti = 0
    t0 = time.perf_counter()
    iters = 0
    for it in range(max_iters):
        while ti < len(trace) and trace[ti][0] <= it:
            step, replica, rid, tokens, max_new = trace[ti]
            fe.submit(replica, Request(rid, tokens, max_new=max_new))
            ti += 1
        worked = fe.run_iteration()
        iters = it + 1
        if not worked and ti >= len(trace):
            break
    wall_s = time.perf_counter() - t0
    completed = fe.completed
    tokens_out = sum(len(r.out) for r in completed.values())
    stats = fe.stats()
    # merge the per-batcher step metrics into one path-level summary
    lat = []
    util = []
    steps = 0
    for snap in stats["batchers"]:
        if not snap:
            continue
        steps += snap["steps"]
        if snap["latency_ms"]:
            lat.append(snap["latency_ms"])
        if snap["slot_utilization"] is not None:
            util.append((snap["slot_utilization"], snap["steps"]))
    lat_all = None
    if lat:
        lat_all = {
            "p50": float(np.median([d["p50"] for d in lat])),
            "p99": float(max(d["p99"] for d in lat)),
            "mean": float(np.mean([d["mean"] for d in lat])),
        }
    util_mean = (
        sum(u * n for u, n in util) / max(1, sum(n for _, n in util))
        if util else 0.0
    )
    return dict(
        iters=iters,
        steps=steps,
        wall_s=round(wall_s, 3),
        tokens_out=tokens_out,
        tokens_per_sec=round(tokens_out / max(wall_s, 1e-9), 2),
        latency_ms=lat_all,
        slot_utilization=round(util_mean, 4),
        completed=sorted(completed.keys()),
        rejected=sorted(fe.rejected.keys()),
        streams={int(rid): list(map(int, r.out)) for rid, r in completed.items()},
        counters=stats["totals"],
        per_replica=stats["per_replica"],
    )


def run_one(slots: int, capacity: int, n_requests: int, max_new: int,
            mode: str, unified: bool, *, arch: str = "llama3.2-3b",
            n_replicas: int = 2, seed: int = 0) -> dict:
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving.engine import ContinuousBatcher, WorkStealingFrontend

    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)

    def make_batcher():
        # split path jits the decode step so the two paths compare the
        # compiled split-launch oracle against the (inherently compiled)
        # unified megakernel, not eager-mode rounding noise
        return ContinuousBatcher(
            params, cfg, slots=slots, capacity=capacity,
            unified_step=unified, jit_ws=not unified,
        )

    fe = WorkStealingFrontend(make_batcher, n_replicas=n_replicas)
    trace = make_trace(mode, n_requests, capacity, n_replicas,
                       seed=seed, max_new=max_new)
    row = replay(fe, trace)
    row.update(mode=mode, path="unified" if unified else "split",
               launches_per_step=1 if unified else "1 + prefill per admission")
    return row


def check_claims(rows_by_mode: dict) -> int:
    """Absolute gates over a {mode: {'split': row, 'unified': row}} grid."""
    status = 0
    for mode, pair in rows_by_mode.items():
        for path, row in pair.items():
            expect = row["_expect"]
            got = set(row["completed"]) | set(row["rejected"])
            dup = set(row["completed"]) & set(row["rejected"])
            if got != expect or dup:
                print(f"[serving] FAIL {mode}/{path}: completed+rejected "
                      f"{sorted(got)} != submitted {sorted(expect)} "
                      f"(overlap {sorted(dup)})")
                status = 1
            c = row["counters"]
            admitted_net = c["admitted"] - c["dup_completed"]
            if len(row["completed"]) != admitted_net:
                print(f"[serving] FAIL {mode}/{path}: {len(row['completed'])} "
                      f"completions vs admitted {c['admitted']} - dups "
                      f"{c['dup_completed']}")
                status = 1
        if pair["split"]["streams"] != pair["unified"]["streams"]:
            print(f"[serving] FAIL {mode}: unified token streams diverge "
                  "from the split-launch oracle")
            status = 1
        else:
            print(f"[serving] {mode}: unified == split on "
                  f"{len(pair['split']['streams'])} request streams")
    return status


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true", help="tiny trace for CI")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    here = pathlib.Path(__file__).parent
    if args.out is None:
        name = ("BENCH_serving.dryrun.json" if args.dry_run
                else "BENCH_serving.json")
        args.out = here / name
    if args.dry_run:
        slots, capacity, n_requests, max_new = DRY_SHAPES
        modes = ("bursty",)
    else:
        # interpret-mode launches are seconds each — the full grid stays
        # modest (both trace modes, deeper decode) rather than realistic-scale
        slots, capacity, n_requests, max_new = 2, 48, 10, 4
        modes = ("poisson", "bursty")

    rows_by_mode = {}
    rows = []
    for mode in modes:
        pair = {}
        for unified in (False, True):
            row = run_one(slots, capacity, n_requests, max_new, mode, unified)
            row["_expect"] = set(range(n_requests))
            pair["unified" if unified else "split"] = row
            print(
                f"serving,mode={mode},path={row['path']},steps={row['steps']},"
                f"tokens_per_sec={row['tokens_per_sec']},"
                f"util={row['slot_utilization']},"
                f"p50_ms={row['latency_ms']['p50'] if row['latency_ms'] else None},"
                f"p99_ms={row['latency_ms']['p99'] if row['latency_ms'] else None},"
                f"rejected={len(row['rejected'])},stolen={row['counters']['stolen']}"
            )
        rows_by_mode[mode] = pair
        rows.extend(pair.values())

    status = check_claims(rows_by_mode)
    for row in rows:
        row.pop("_expect", None)
    payload = dict(
        config=dict(slots=slots, capacity=capacity, n_requests=n_requests,
                    max_new=max_new, n_replicas=2, seed=0,
                    dry_run=args.dry_run),
        rows=rows,
        streams_match={m: p["split"]["streams"] == p["unified"]["streams"]
                       for m, p in rows_by_mode.items()},
    )
    pathlib.Path(args.out).write_text(json.dumps(payload, indent=2))
    print(f"[serving] wrote {args.out}")
    return status


if __name__ == "__main__":
    sys.exit(main())

"""Ragged-attention scheduling benchmark: static grid vs device-resident
fence-free work-stealing (repro.pallas_ws), across sequence-length skew.

Workload: B sequences where one is ``skew``× longer than the rest — the
canonical ragged batch a serving engine sees.  Tile tasks are partitioned to
owner queues by batch row, so the long sequence piles its quadratic causal
tile cost onto one queue.  We report, in kv-block *tile-slots* (the
device-measured cost counters of the megakernel, identical for both
schedules):

* ``makespan``      — completion round of the slowest program (parallel time)
* ``wasted_slots``  — P × makespan − total work (idle tile-slots)
* ``steals``        — successful cross-queue extractions
* ``max_abs_err``   — ws output vs the dense length-masked oracle

plus the analytic makespan of a *dense* static grid (padded-length tiles,
no length awareness) — what a non-persistent kernel would burn.

Writes BENCH_ragged.json next to this file.  ``--dry-run`` shrinks shapes
for CI (Pallas interpret mode on CPU).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np


def make_skewed_lengths(B: int, S: int, skew: float, seed: int = 0) -> np.ndarray:
    """One sequence at full S, the rest at S/skew (min one kv block)."""
    rng = np.random.RandomState(seed)
    short = min(S, max(8, int(round(S / skew))))
    lengths = np.full(B, short, dtype=np.int64)
    lengths[rng.randint(B)] = S
    return lengths


def dense_grid_makespan(lengths, S: int, H: int, bq: int, bk: int, P: int) -> int:
    """Tile-slots of a static *dense* grid: every padded (b, h, q-block) tile
    exists and sweeps its full causal kv range, round-robin over P programs."""
    B = len(lengths)
    costs = []
    for _ in range(B):
        for _h in range(H):
            for qi in range(-(-S // bq)):
                costs.append(max(1, -(-min(S, (qi + 1) * bq) // bk)))
    loads = np.zeros(P, dtype=np.int64)
    for i, c in enumerate(costs):
        loads[i % P] += c
    return int(loads.max())


def run_one(B, H, S, hd, bq, bk, P, skew, seed=0, trace=False, trace_sink=None):
    import jax
    import jax.numpy as jnp

    from repro.pallas_ws import ragged_attention_ref, ragged_flash_attention

    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, S, hd), jnp.float32)
    lengths = make_skewed_lengths(B, S, skew, seed)

    row = dict(B=B, H=H, S=S, hd=hd, bq=bq, bk=bk, n_programs=P,
               skew=skew, lengths=lengths.tolist())
    ref = ragged_attention_ref(q, k, v, lengths)
    # "ws" is the cost-aware O(1) victim selection (the default);
    # "ws_scan" keeps the PR-1 sequential scan for apples-to-apples
    # makespan and scan-traffic comparison (DESIGN.md §3.6)
    for name, sched, policy in (
        ("static", "static", "cost"),
        ("ws", "ws", "cost"),
        ("ws_scan", "ws", "scan"),
    ):
        t0 = time.perf_counter()
        out, st = ragged_flash_attention(
            q, k, v, lengths, schedule=sched, steal_policy=policy,
            n_programs=P, bq=bq, bk=bk, return_stats=True,
            trace=(trace and name == "ws"),
        )
        dt = time.perf_counter() - t0
        err = float(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)).max())
        row[name] = dict(
            makespan=st.makespan,
            total_work=st.total_work,
            wasted_slots=st.wasted_slots,
            steals=st.steals,
            mult_max=st.mult_max,
            slots_scanned=st.slots_scanned,
            extractions=st.extractions,
            scan_per_extraction=st.scan_per_extraction,
            queue_loads=st.queue_loads,
            max_abs_err=err,
            wall_s=round(dt, 3),
        )
        if getattr(st, "trace", None) is not None:
            row[name]["trace"] = st.trace.summary()
            if trace_sink is not None:
                trace_sink[name] = st.trace
    row["dense_grid_makespan"] = dense_grid_makespan(lengths, S, H, bq, bk, P)
    row["speedup_vs_static"] = row["static"]["makespan"] / max(1, row["ws"]["makespan"])
    row["speedup_vs_dense"] = row["dense_grid_makespan"] / max(1, row["ws"]["makespan"])
    row["scan_traffic_reduction"] = round(
        row["ws_scan"]["scan_per_extraction"]
        / max(1e-9, row["ws"]["scan_per_extraction"]), 1
    )
    return row


# the CI smoke cell (B, H, S, hd, bq, bk, P) — perf_smoke.py replays it with
# tracing off and holds the makespans to exact equality with BENCH.json
DRY_SHAPES = (4, 2, 64, 8, 8, 8, 4)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true", help="tiny shapes for CI smoke")
    ap.add_argument("--skews", default="1,2,4,8")
    ap.add_argument("--out", default=None, help="output JSON path")
    ap.add_argument("--trace", default=None, metavar="TRACE_JSON",
                    help="write a Perfetto timeline of the highest-skew ws "
                         "run (load it at https://ui.perfetto.dev)")
    args = ap.parse_args(argv)
    if args.out is None:
        # dry-run results go to a sibling file so CI smokes never clobber
        # the committed full-size benchmark
        name = "BENCH_ragged.dryrun.json" if args.dry_run else "BENCH_ragged.json"
        args.out = str(pathlib.Path(__file__).parent / name)

    if args.dry_run:
        B, H, S, hd, bq, bk, P = DRY_SHAPES
    else:
        B, H, S, hd, bq, bk, P = 8, 2, 256, 16, 16, 16, 4

    skews = [float(s) for s in args.skews.split(",")]
    rows = []
    traces = {}
    hdr = ("skew,static_makespan,ws_makespan,speedup,dense_makespan,steals,"
           "wasted_static,wasted_ws,scan/extr_cost,scan/extr_scan,max_err")
    print(hdr)
    for skew in skews:
        sink = {}
        row = run_one(B, H, S, hd, bq, bk, P, skew, trace=True,
                      trace_sink=sink)
        if "ws" in sink:
            traces[skew] = sink["ws"]
        rows.append(row)
        print(
            f"{skew},{row['static']['makespan']},{row['ws']['makespan']},"
            f"{row['speedup_vs_static']:.2f},{row['dense_grid_makespan']},"
            f"{row['ws']['steals']},{row['static']['wasted_slots']},"
            f"{row['ws']['wasted_slots']},{row['ws']['scan_per_extraction']},"
            f"{row['ws_scan']['scan_per_extraction']},"
            f"{row['ws']['max_abs_err']:.2e}"
        )

    payload = dict(
        bench="ragged_attention",
        config=dict(B=B, H=H, S=S, hd=hd, bq=bq, bk=bk, n_programs=P, dry_run=args.dry_run),
        rows=rows,
    )
    pathlib.Path(args.out).write_text(json.dumps(payload, indent=2))
    print(f"[ragged_attention] wrote {args.out}")

    if args.trace and traces:
        from repro.wstrace import write_perfetto

        write_perfetto(traces[max(traces)], args.trace)
        print(f"[ragged_attention] wrote Perfetto trace (skew={max(traces)}) "
              f"to {args.trace} — open at https://ui.perfetto.dev")

    # the paper-level claim this bench exists to witness, plus the §3.6
    # policy claim: cost-aware victim selection must not cost makespan
    bad = [
        r for r in rows
        if r["skew"] >= 4
        and (r["speedup_vs_static"] <= 1.0
             or r["ws"]["makespan"] > r["ws_scan"]["makespan"] * 1.05)
    ]
    if bad:
        print(f"[ragged_attention] WS failed to beat static at skew >= 4: {bad}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

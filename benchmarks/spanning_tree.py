"""Irregular-graph application (paper §8.2, Table 1 / Figs 10-14):
parallel spanning tree via work-stealing, over torus / random graphs.

Faithful setup: per-thread owner queues; a thread drains its own queue
(Take) and steals from a random victim when empty; processing a vertex
claims unvisited neighbors (benign-race check-then-write, as in the
paper's Bader-Cong-based harness — re-expansion is tolerated, which is
exactly why relaxed semantics are sound here) and Puts them.

Scaled for this container: graphs default to ~40k vertices (paper: 1-2M)
and CPython's GIL compresses parallel speedups; the quantity that remains
faithful is the *relative* ranking of algorithms at equal thread counts,
driven by their per-operation overhead (locks/CAS on the Steal path).
Tree validity is checked after every run.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core import ALGORITHMS, EMPTY

BENCH_ALGOS = (
    "ws-wmult",
    "b-ws-wmult",
    "chase-lev",
    "the-cilk",
    "idempotent-fifo",
    "idempotent-lifo",
)


# ---------------------------------------------------------------------------
# graphs (paper §8.2)


def torus_2d(side: int, keep: float = 1.0, directed: bool = False, seed: int = 0):
    n = side * side
    rng = np.random.RandomState(seed)
    adj: List[List[int]] = [[] for _ in range(n)]

    def vid(x, y):
        return (x % side) * side + (y % side)

    for x in range(side):
        for y in range(side):
            v = vid(x, y)
            for dx, dy in ((1, 0), (0, 1)) if directed else ((1, 0), (0, 1), (-1, 0), (0, -1)):
                w = vid(x + dx, y + dy)
                if keep >= 1.0 or rng.rand() < keep:
                    adj[v].append(w)
                    if not directed:
                        pass  # reverse edge added by the (-dx,-dy) iteration
    return adj


def torus_3d(side: int, keep: float = 1.0, directed: bool = False, seed: int = 0):
    n = side**3
    rng = np.random.RandomState(seed)
    adj: List[List[int]] = [[] for _ in range(n)]

    def vid(x, y, z):
        return ((x % side) * side + (y % side)) * side + (z % side)

    deltas = ((1, 0, 0), (0, 1, 0), (0, 0, 1))
    if not directed:
        deltas = deltas + ((-1, 0, 0), (0, -1, 0), (0, 0, -1))
    for x in range(side):
        for y in range(side):
            for z in range(side):
                v = vid(x, y, z)
                for dx, dy, dz in deltas:
                    if keep >= 1.0 or rng.rand() < keep:
                        adj[v].append(vid(x + dx, y + dy, z + dz))
    return adj


def random_graph(n: int, m: int, directed: bool = False, seed: int = 0):
    rng = np.random.RandomState(seed)
    adj: List[List[int]] = [[] for _ in range(n)]
    # spanning backbone so the graph is connected from vertex 0
    order = rng.permutation(n)
    for i in range(1, n):
        a, b = int(order[i]), int(order[rng.randint(i)])
        adj[a].append(b)
        if not directed:
            adj[b].append(a)
    for _ in range(m - (n - 1)):
        a, b = int(rng.randint(n)), int(rng.randint(n))
        adj[a].append(b)
        if not directed:
            adj[b].append(a)
    return adj


GRAPHS = {
    "2d-torus": lambda scale: torus_2d(int(scale**0.5)),
    "2d60-torus": lambda scale: torus_2d(int(scale**0.5), keep=0.6),
    "3d-torus": lambda scale: torus_3d(max(int(round(scale ** (1 / 3))), 4)),
    "3d40-torus": lambda scale: torus_3d(max(int(round(scale ** (1 / 3))), 4), keep=0.4),
    "random": lambda scale: random_graph(scale, 4 * scale),
}


# ---------------------------------------------------------------------------
# parallel spanning tree


def spanning_tree(adj, algo: str, n_threads: int, chunk: int = 64) -> Tuple[float, Dict]:
    """Returns (seconds, stats).  Tasks are vertex CHUNKS (the paper runs
    per-vertex tasks; chunking amortizes Python call overhead identically
    across algorithms)."""
    n = len(adj)
    kw = (
        dict(storage="linked", node_len=4096)
        if algo.startswith(("ws-", "b-ws"))
        else dict(initial_len=4096)
    )
    queues = [ALGORITHMS[algo](**kw) for _ in range(n_threads)]
    parent = [-1] * n
    parent[0] = 0
    remaining = [n - 1]
    rem_lock = threading.Lock()
    stats = {"steals": 0, "repeats": 0}

    queues[0].put([0])

    def worker(tid: int):
        rng = np.random.RandomState(tid)
        own = queues[tid]
        misses = 0
        claimed_local = 0
        buf: List[int] = []

        def flush():
            nonlocal buf
            if buf:
                own.put(buf)
                buf = []

        while remaining[0] > 0 and misses < 200:
            task = own.take()
            if task is EMPTY and n_threads > 1:
                victim = int(rng.randint(n_threads))
                if victim != tid:
                    task = queues[victim].steal(1 + tid)
            if task is EMPTY or task is None:
                misses += 1
                continue
            misses = 0
            claimed = 0
            for v in task:
                for w in adj[v]:
                    if parent[w] == -1:  # benign race (paper's deployment)
                        parent[w] = v
                        claimed += 1
                        buf.append(w)
                        if len(buf) >= chunk:
                            flush()
                    else:
                        stats["repeats"] += 0  # placeholder symmetry
            flush()
            if claimed:
                with rem_lock:
                    remaining[0] -= claimed

    t0 = time.perf_counter()
    threads = [threading.Thread(target=worker, args=(t,)) for t in range(1, n_threads)]
    for t in threads:
        t.start()
    worker(0)
    for t in threads:
        t.join(timeout=120)
    dt = time.perf_counter() - t0

    reached = sum(1 for p in parent if p != -1)
    stats["reached"] = reached
    stats["valid"] = reached == n and _acyclic(parent)
    return dt, stats


def _acyclic(parent: List[int]) -> bool:
    n = len(parent)
    depth = [-1] * n
    depth[0] = 0
    for v in range(n):
        path = []
        u = v
        while u != -1 and depth[u] == -1 and len(path) <= n:
            path.append(u)
            u = parent[u]
        if u == -1 or len(path) > n:
            return False
        d = depth[u]
        for w in reversed(path):
            d += 1
            depth[w] = d
    return True


def bench_spanning_tree(
    scale: int = 40_000,
    graphs=("2d-torus", "3d-torus", "random"),
    algos=BENCH_ALGOS,
    thread_counts=(1, 2, 4),
    repeats: int = 3,
):
    rows = []
    for gname in graphs:
        adj = GRAPHS[gname](scale)
        base = None
        for algo in algos:
            for nt in thread_counts:
                best, stats = float("inf"), None
                for _ in range(repeats):
                    dt, st = spanning_tree(adj, algo, nt)
                    if dt < best:
                        best, stats = dt, st
                if algo == "chase-lev" and nt == 1:
                    base = best  # normalization anchor, as in the paper
                rows.append(
                    dict(
                        graph=gname, n_vertices=len(adj), algorithm=algo,
                        threads=nt, seconds=best, valid=bool(stats["valid"]),
                        reached=stats["reached"],
                    )
                )
        for r in rows:
            if r["graph"] == gname and base:
                r["speedup_vs_cl1"] = base / r["seconds"]
    return rows


def main(scale: int = 40_000):
    rows = bench_spanning_tree(scale)
    hdr = "graph,algorithm,threads,seconds,speedup_vs_cl1,valid"
    print(hdr)
    for r in rows:
        print(
            f"{r['graph']},{r['algorithm']},{r['threads']},{r['seconds']:.3f},"
            f"{r.get('speedup_vs_cl1', 0):.3f},{r['valid']}"
        )
    return rows


if __name__ == "__main__":
    main()

"""Instruction-counting backend: the architecture-independent cost model.

CPython's GIL serializes execution, so wall-clock alone under-reports the
fence/RMW asymmetry the paper exploits on real hardware.  We therefore also
count the *instruction mix* per high-level operation (reads, writes, RMWs,
lock acquisitions) — the quantities the paper's theory speaks to — and
report them next to wall time.  RMW cells in the thread backend use a
mutex, so wall time still reflects part of the hardware asymmetry.
"""

from __future__ import annotations

from typing import Any

from repro.core import UNINIT
from repro.core.backend import (
    ArrayCells,
    Cell,
    MapCells,
    RMWCell,
    RMWMapCells,
    ThreadBackend,
)


class Counts:
    __slots__ = ("reads", "writes", "rmws", "locks")

    def __init__(self):
        self.reads = self.writes = self.rmws = self.locks = 0

    def snapshot(self):
        return dict(reads=self.reads, writes=self.writes, rmws=self.rmws, locks=self.locks)

    def __repr__(self):
        return f"R={self.reads} W={self.writes} RMW={self.rmws} L={self.locks}"


def _wrap(cls, counts: Counts):
    class Wrapped(cls):  # type: ignore[misc]
        def read(self, *a, **k):
            counts.reads += 1
            return super().read(*a, **k)

        def write(self, *a, **k):
            counts.writes += 1
            return super().write(*a, **k)

        def cas(self, *a, **k):
            counts.rmws += 1
            return super().cas(*a, **k)

        def swap(self, *a, **k):
            counts.rmws += 1
            return super().swap(*a, **k)

        def fetch_add(self, *a, **k):
            counts.rmws += 1
            return super().fetch_add(*a, **k)

        def write_max(self, *a, **k):
            counts.rmws += 1
            return super().write_max(*a, **k)

    Wrapped.__name__ = "Counting" + cls.__name__
    return Wrapped


class _CountingLock:
    def __init__(self, counts: Counts):
        import threading

        self.counts = counts
        self._lock = threading.Lock()

    def __enter__(self):
        self.counts.locks += 1
        self._lock.acquire()
        return self

    def __exit__(self, *exc):
        self._lock.release()
        return False


class CountingBackend(ThreadBackend):
    name = "counting"

    def __init__(self):
        self.counts = Counts()

    def cell(self, init: Any = None):
        return _wrap(Cell, self.counts)(init)

    def rmw_cell(self, init: Any = None):
        return _wrap(RMWCell, self.counts)(init)

    def array(self, size: int, init: Any = None):
        return _wrap(ArrayCells, self.counts)(size, init)

    def map_cells(self, default: Any = UNINIT):
        return _wrap(MapCells, self.counts)(default)

    def rmw_map_cells(self, default: Any = UNINIT):
        return _wrap(RMWMapCells, self.counts)(default)

    def lock(self):
        return _CountingLock(self.counts)

"""Zero-cost experiments (paper §8.2, Figs 9a/9b): put-take and put-steal.

The owner performs N Puts followed by N Takes (or a thief performs N
Steals); no task work is attached.  We report wall µs/op AND the
instruction mix per operation (reads / writes / RMWs / lock acquisitions,
via the counting backend) — CPython's GIL hides hardware fence costs, so
the instruction mix is the architecture-independent evidence for the
paper's claim (WS-WMULT: zero RMW, zero locks, O(1) R/W per op; baselines:
CAS or locks on the Steal path).

The paper's result to reproduce: WS-WMULT fastest on put-take and
put-steal; B-WS-WMULT pays for its extra bookkeeping array; idempotent/
Chase-Lev/Cilk pay CAS or fence costs on Take/Steal.
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.core import ALGORITHMS, EMPTY

from .instrument import CountingBackend

DEFAULT_ALGOS = (
    "ws-wmult",
    "ws-wmult-array",
    "pallas-ws",
    "moe-ws",
    "b-ws-wmult",
    "ws-mult",
    "b-ws-mult",
    "chase-lev",
    "the-cilk",
    "idempotent-fifo",
    "idempotent-lifo",
    "idempotent-deque",
)

# The paper's headline structural claim, asserted (not just reported) by
# `audit_fence_free`: the WS-WMULT protocol and both device-layout shims —
# including the MoE expert-dispatch queue — touch shared memory with plain
# reads/writes only.  Zero RMW, zero lock acquisitions, on Put, Take AND
# Steal.  CPython can't count hardware fences, but every fence a TSO/ARM
# lowering would need hangs off an RMW or lock in these schemes, so this is
# the architecture-independent witness.
FENCE_FREE_ALGOS = ("ws-wmult", "ws-wmult-array", "pallas-ws", "moe-ws")


def _make(name: str, backend=None, n_ops: int = 0):
    """name 'x-array' selects the growable-array storage variant (the paper's
    WS_WMULT_ARRAY, §6 approach 1); plain ws-* use the linked-list (§6.2)."""
    base = name.replace("-array", "")
    if base in ("ws-mult", "ws-wmult", "b-ws-mult", "b-ws-wmult"):
        kw = dict(
            storage="growable" if name.endswith("-array") else "linked",
        )
        if kw["storage"] == "linked":
            kw["node_len"] = 4096
        else:
            kw["initial_len"] = 4096
    elif base in ("pallas-ws", "moe-ws"):
        # fixed-capacity device layout: size for the whole run
        kw = dict(capacity=n_ops + 8)
    else:
        kw = dict(initial_len=4096)
    return ALGORITHMS[base](backend=backend, **kw) if backend else ALGORITHMS[base](**kw)


def _payload_fn(name: str):
    """moe-ws is exercised with real encoded expert-tile records, so the
    audited Put/Take/Steal path is byte-for-byte the expert dispatch."""
    if name == "moe-ws":
        from repro.pallas_ws.tasks import ExpertTask

        return lambda i: tuple(
            int(v)
            for v in ExpertTask(
                expert=i % 64, row_start=8 * i, row_len=8, tid=i, cost=8
            ).encode()
        )
    return lambda i: i


def _run_ops(q, name: str, n_ops: int, steal: bool):
    payload = _payload_fn(name)
    for i in range(n_ops):
        q.put(payload(i))
    got = 0
    if steal:
        for _ in range(n_ops + 4):
            if q.steal(1) is not EMPTY:
                got += 1
            if got >= n_ops:
                break
    else:
        for _ in range(n_ops + 4):
            if q.take() is not EMPTY:
                got += 1
            if got >= n_ops:
                break
    return got


def bench_zero_cost(n_ops: int = 100_000, algos=DEFAULT_ALGOS, repeats: int = 3) -> List[Dict]:
    rows = []
    for steal in (False, True):
        exp = "put-steal" if steal else "put-take"
        for name in algos:
            best = float("inf")
            for _ in range(repeats):
                q = _make(name, n_ops=n_ops)
                t0 = time.perf_counter()
                got = _run_ops(q, name, n_ops, steal)
                dt = time.perf_counter() - t0
                best = min(best, dt)
            # instruction mix on a smaller run (counting overhead excluded
            # from the timed path)
            cb = CountingBackend()
            qc = _make(name, backend=cb, n_ops=2048)
            _run_ops(qc, name, 2048, steal)
            per_op = {k: round(v / 4096, 2) for k, v in cb.counts.snapshot().items()}
            rows.append(
                dict(
                    experiment=exp,
                    algorithm=name,
                    us_per_op=1e6 * best / (2 * n_ops),
                    extracted=got,
                    **{f"{k}_per_op": v for k, v in per_op.items()},
                )
            )
    return rows


def audit_fence_free(rows) -> None:
    """Assert the structural claim over measured instruction mixes: every
    FENCE_FREE_ALGOS row performed zero RMW operations and zero lock
    acquisitions, and every audited algorithm was measured on BOTH
    experiments — the Steal path is the one the claim is about, so it must
    not silently drop out of the bench."""
    seen = {}
    for r in rows:
        if r["algorithm"] not in FENCE_FREE_ALGOS:
            continue
        assert r["rmws_per_op"] == 0, (
            f"{r['algorithm']} [{r['experiment']}] performed RMWs: {r}"
        )
        assert r["locks_per_op"] == 0, (
            f"{r['algorithm']} [{r['experiment']}] took locks: {r}"
        )
        seen.setdefault(r["algorithm"], set()).add(r["experiment"])
    assert seen, "fence-free audit saw no rows"
    for algo, exps in seen.items():
        assert exps == {"put-take", "put-steal"}, (
            f"{algo} audited on {sorted(exps)} only — Take AND Steal required"
        )
    print(
        f"[zero-cost] fence-free audit OK: {sorted(seen)} at "
        "0 RMW / 0 locks per op on put-take and put-steal"
    )


_FORBIDDEN_HLO = (
    # any fence an implementation needs hangs off one of these; XLA spells
    # synchronization with these tokens when it emits it at all
    r"\batomic\w*", r"\bcmpxchg\b", r"\bcompare_and_swap\b", r"\brmw\w*",
    r"\bfence\w*", r"\bmutex\w*", r"\bsemaphore\w*", r"\bcritical\w*",
    r"\block\b", r"\bspinlock\w*",
)


def _fence_free_lowering_row(text: str, label: str, experiment: str,
                             algorithm: str, n_ops: int) -> Dict:
    """Scan one jit lowering's StableHLO text for the forbidden
    synchronization tokens (asserting none) and return its audit row — the
    single scan/row-schema implementation every audited lowering (forward
    AND backward) goes through."""
    import re

    hits = {
        pat: len(re.findall(pat, text, flags=re.IGNORECASE))
        for pat in _FORBIDDEN_HLO
        if re.search(pat, text, flags=re.IGNORECASE)
    }
    assert not hits, f"{label} contains synchronization ops: {hits}"
    return dict(
        experiment=experiment,
        algorithm=algorithm,
        n_ops=n_ops,
        hlo_bytes=len(text),
        reads_per_op="traced",  # plain tensor ops only; see hlo scan
        writes_per_op="traced",
        rmws_per_op=0,
        locks_per_op=0,
        fences_per_op=0,
    )


def audit_traced_put(n_tokens: int = 16, n_experts: int = 8, top_k: int = 2,
                     bt: int = 4, n_programs: int = 4) -> List[Dict]:
    """The traced-Put analogue of :func:`audit_fence_free`: lower the whole
    jit pipeline — queue construction (the device-side Put, padded
    `route_to_tasks_jax` + `make_queue_state_jax` AND the shared-pool
    `route_to_tasks_pool_jax` + `make_pool_queue_state_jax`) plus the
    megakernel drain (Take only, and Take+Steal under **both** victim
    selections: the sequential scan and the §3.6 cost-aware advisory
    argmax) — and assert the emitted StableHLO contains **zero** RMW /
    atomic / lock / fence operations.  The advisory `remaining` updates
    and the vectorized head/tail/argmax victim reads must lower to plain
    tensor ops like everything else.

    Since the dispatch grew its custom VJP (DESIGN.md §4.5) the audit also
    lowers ``jax.grad`` through ``expert_ffn_ws`` — the VJP's forward
    launch plus its backward under both ``grad_dispatch="dense"`` (plain
    gather/scatter transpose) and ``grad_dispatch="ws"`` (the second
    megakernel launch of per-row transpose tiles) — and holds the whole
    differentiated pipeline to the same zero-synchronization bar
    (``grad-dense`` / ``grad-ws`` rows).

    Since the dispatch went cross-device (DESIGN.md §7) the audit also
    lowers ``expert_ffn_mesh_ws`` — the ``shard_map``-ped two-phase mesh
    protocol: local drains, ring all-gather advisory exchange, replicated
    steal plan, psum delivery, pair combine — and holds it to the same bar
    (``put-steal-mesh`` row).  On a single-device session this audits the
    degenerate D=1 mesh; the CI ``mesh`` job re-audits on 8 forced host
    devices, where the collectives actually lower to collective-permute /
    all-reduce (plain data movement, never synchronization primitives).

    The host audit counts instructions through the backend cells; a traced
    Put has no backend cells, so the architecture-independent witness is the
    compiled program text itself: every shared-memory touch the lowering
    emits is a plain tensor read/write (scatters/gathers/dynamic-slices),
    never a synchronization primitive.  Returns one row per experiment in
    the bench_zero_cost row format, for BENCH_moe.json / BENCH.json.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.moe_ws.dispatch import (
        expert_queue_candidates,
        expert_rounds_bound,
        route_to_tasks_jax,
        route_to_tasks_pool_jax,
    )
    from repro.moe_ws.expert_kernel import run_moe_schedule
    from repro.pallas_ws.queues import (
        make_pool_queue_state_jax,
        make_queue_state_jax,
    )

    rng = np.random.RandomState(0)
    idx = np.stack([rng.choice(n_experts, top_k, replace=False)
                    for _ in range(n_tokens)]).astype(np.int32)
    gates = rng.uniform(0.2, 1.0, (n_tokens, top_k)).astype(np.float32)
    gates /= gates.sum(1, keepdims=True)
    d, f = 8, 16
    x = rng.randn(n_tokens, d).astype(np.float32)
    wg = rng.randn(n_experts, d, f).astype(np.float32)
    wu = rng.randn(n_experts, d, f).astype(np.float32)
    wd = rng.randn(n_experts, f, d).astype(np.float32)

    from repro.chaos import FaultPlan

    # (experiment label, steal, steal_policy, layout, trace, fault_plan) —
    # the traced-on cases audit the ISSUE-7 event rings: the per-extraction
    # record stores and the plain-write cursor bump must lower to the same
    # plain tensor ops as the queue protocol they instrument.  The faulted
    # case audits the ISSUE-9 chaos injection: stalls are initial clock
    # values and advisory corruption is plain data, so a fault-injected
    # lowering must meet the identical zero-synchronization bar.
    _faulted = FaultPlan(stalls=(2, 0, 1, 0), advisory="random")
    # the half-run cases (steal_run_cap=4) audit this PR's amortized Steal:
    # claiming a contiguous run with one probe + one coalesced advisory
    # write must lower to the same plain tensor ops as per-slot claims
    cases = (
        ("put-take", False, "cost", "padded", False, None, 1),
        ("put-steal", True, "scan", "padded", False, None, 1),
        ("put-steal", True, "cost", "padded", False, None, 1),
        ("put-steal", True, "cost", "pool", False, None, 1),
        ("put-steal-halfrun", True, "cost", "padded", False, None, 4),
        ("put-steal-halfrun", True, "cost", "pool", False, None, 4),
        ("put-take-traced", False, "cost", "padded", True, None, 1),
        ("put-steal-traced", True, "cost", "padded", True, None, 1),
        ("put-steal-halfrun-traced", True, "cost", "padded", True, None, 4),
        ("put-steal-faulted", True, "cost", "padded", True, _faulted, 1),
    )
    rows = []
    for exp, steal, policy, layout, trace, fault, cap in cases:
        n_queues = n_experts if steal else n_programs

        def pipeline(idx, gates, x, wg, wu, wd, steal=steal, policy=policy,
                     layout=layout, n_queues=n_queues, trace=trace,
                     fault=fault, cap=cap):
            rounds = expert_rounds_bound(
                n_tokens * top_k, bt, n_queues, n_programs, steal,
                steal_run_cap=cap,
            )
            if layout == "pool":
                rec, tail, off, routed = route_to_tasks_pool_jax(
                    idx, gates, n_experts, bt=bt
                )
                state = make_pool_queue_state_jax(
                    rec, tail, off, routed.loads, n_programs,
                    n_tasks=rec.shape[0],
                )
            else:
                records, live, routed = route_to_tasks_jax(
                    idx, gates, n_experts, bt=bt
                )
                cand, cand_live = expert_queue_candidates(records, live, n_queues)
                state = make_queue_state_jax(
                    cand, cand_live, n_programs,
                    n_tasks=records.shape[0] * records.shape[1],
                )
            res = run_moe_schedule(
                state, x, routed.tok_idx, wg, wu, wd, bt=bt, steal=steal,
                steal_policy=policy, rounds=rounds, trace=trace,
                fault_plan=fault, steal_run_cap=cap,
            )
            outs = (res.out, res.mult, res.head, res.taken, res.remaining)
            if trace:  # keep the rings live so their stores aren't DCE'd
                outs += (res.events, res.ev_cursor)
            return outs

        text = jax.jit(pipeline).lower(
            jnp.asarray(idx), jnp.asarray(gates), jnp.asarray(x),
            jnp.asarray(wg), jnp.asarray(wu), jnp.asarray(wd),
        ).as_text()
        tag = (f"{policy},{layout}" + (",trace" if trace else "")
               + (",faulted" if fault is not None else "")
               + (f",cap{cap}" if cap > 1 else ""))
        rows.append(_fence_free_lowering_row(
            text, f"traced Put lowering [{tag}]", exp,
            f"moe-ws-traced[{tag}]", n_tokens * top_k,
        ))
    # backward lowering: jit(grad) through the custom VJP — forward
    # megakernel + no-drop-reference transpose, both backward evaluations
    from repro.moe_ws import expert_ffn_ws

    for gd in ("dense", "ws"):

        def grad_pipeline(gates, x, wg, wu, wd, gd=gd):
            loss = lambda gates, x, wg, wu, wd: (  # noqa: E731
                expert_ffn_ws(
                    idx, gates, x, wg, wu, wd, grad_dispatch=gd,
                    n_programs=n_programs, bt=bt,
                ) ** 2
            ).sum()
            return jax.grad(loss, argnums=(0, 1, 2, 3, 4))(
                gates, x, wg, wu, wd
            )

        text = jax.jit(grad_pipeline).lower(
            jnp.asarray(gates), jnp.asarray(x),
            jnp.asarray(wg), jnp.asarray(wu), jnp.asarray(wd),
        ).as_text()
        rows.append(_fence_free_lowering_row(
            text, f"custom-VJP lowering [grad_dispatch={gd}]", f"grad-{gd}",
            f"moe-ws-vjp[{gd}]", n_tokens * top_k,
        ))
    # mesh lowering: the cross-device dispatch under shard_map — advisory
    # ring all-gathers, replicated steal plan, psum delivery, pair combine
    from repro.launch.mesh import make_expert_mesh
    from repro.mesh_ws import MESH_AXIS, expert_ffn_mesh_ws

    mesh = make_expert_mesh(n_experts)
    n_dev = mesh.shape[MESH_AXIS]

    def mesh_pipeline(idx, gates, x, wg, wu, wd):
        return expert_ffn_mesh_ws(
            idx, gates, x, wg, wu, wd, mesh=mesh, bt=bt,
            n_programs=n_programs,
        )

    text = jax.jit(mesh_pipeline).lower(
        jnp.asarray(idx), jnp.asarray(gates), jnp.asarray(x),
        jnp.asarray(wg), jnp.asarray(wu), jnp.asarray(wd),
    ).as_text()
    rows.append(_fence_free_lowering_row(
        text, f"mesh dispatch lowering [D={n_dev}]", "put-steal-mesh",
        f"mesh-ws[D={n_dev}]", n_tokens * top_k,
    ))
    print(
        "[zero-cost] traced-put audit OK: moe-ws-traced jit lowering has "
        "0 RMW / 0 locks / 0 fences on put-take and put-steal "
        "(scan + cost policies, padded + pool layouts, half-run claims "
        "at steal_run_cap=4, event tracing off AND on, fault injection "
        "on), on the "
        "custom-VJP backward (grad-dense + grad-ws) and on the "
        f"shard_map mesh dispatch (D={n_dev})"
    )
    return rows


def audit_batched_put_host(n: int = 4096, segment: int = 64) -> List[Dict]:
    """Host-layout audit of the batched Put (amortized synchronization):
    count the shared-array instruction mix of :meth:`put_segment` versus
    the task-at-a-time :meth:`put` loop on the SAME payloads.  The segment
    path must issue strictly fewer queue-array writes per Put (one
    pre-clear pair and ONE advisory write per segment instead of per task),
    reach the identical final queue state, and clear the same fence-free
    bar: zero RMWs, zero lock acquisitions."""
    from benchmarks.instrument import CountingBackend
    from repro.pallas_ws import PallasWSHost

    cb_loop = CountingBackend()
    q_loop = PallasWSHost(backend=cb_loop, capacity=n + 2)
    for i in range(n):
        assert q_loop.put(i)
    cb_seg = CountingBackend()
    q_seg = PallasWSHost(backend=cb_seg, capacity=n + 2)
    for s in range(0, n, segment):
        assert q_seg.put_segment(range(s, min(s + segment, n)))
    assert q_loop.snapshot() == q_seg.snapshot(), "batched Put final-state"
    rows = []
    for exp, cb in (("put-loop", cb_loop), ("put-segment", cb_seg)):
        c = cb.counts.snapshot()
        rows.append(dict(
            experiment=exp,
            algorithm="pallas-ws-host-put",
            n_ops=n,
            reads_per_op=round(c["reads"] / n, 4),
            writes_per_op=round(c["writes"] / n, 4),
            rmws_per_op=c["rmws"],
            locks_per_op=c["locks"],
        ))
    loop_w = rows[0]["writes_per_op"]
    seg_w = rows[1]["writes_per_op"]
    assert seg_w < loop_w, (
        f"put_segment must amortize queue-array writes: {seg_w} vs {loop_w}"
    )
    assert all(r["rmws_per_op"] == 0 and r["locks_per_op"] == 0 for r in rows)
    print(
        f"[zero-cost] batched-put audit OK: {seg_w} vs {loop_w} queue-array "
        f"writes per Put (segment={segment}), 0 RMW / 0 locks on both"
    )
    return rows


def audit_unified_step() -> List[Dict]:
    """The unified-engine-step analogue of :func:`audit_traced_put`
    (DESIGN.md §5): lower the whole ``decode_step_unified`` pipeline — the
    stage-gated mixed-mode queue build (decode tiles + prefill flash tiles
    + expert tiles + step glue in ONE ``launch_ws_grid`` grid) and its
    family-dispatching megakernel drain — and assert the StableHLO carries
    **zero** RMW / atomic / lock / fence operations.

    Two cells: the dense decode-only step (llama smoke config) and the full
    mixed-mode step (MoE config with ``moe_dispatch="ws"`` AND a folded-in
    prefill chunk — all four task families in the one lowering).  ``pos``
    is static per (slots, capacity) shape — the engine re-lowers per length
    vector in interpret mode — so it is closed over concretely; params,
    caches and tokens are traced.
    """
    import dataclasses as dc

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.models import decode_step_unified, init_params, prefill

    cap = 32
    pos = np.array([4, 2], np.int32)
    cases = (
        ("put-take-unified", "llama3.2-3b", {}, False),
        ("put-steal-unified-mixed", "kimi-k2-1t-a32b",
         {"moe_dispatch": "ws"}, True),
    )
    rows = []
    for exp, arch, overrides, with_prefill in cases:
        cfg = get_config(arch, smoke=True)
        if overrides:
            cfg = dc.replace(cfg, **overrides)
        params = init_params(jax.random.PRNGKey(0), cfg)
        batch = {"tokens": jnp.asarray(
            np.array([[5, 6, 7, 8], [9, 8, 7, 6]], np.int32))}
        _, caches = prefill(params, cfg, batch, capacity=cap)
        tok = jnp.asarray(np.array([[3], [4]], np.int32))
        ptok = (jnp.asarray(np.arange(11, 18, dtype=np.int32)[None, :])
                if with_prefill else None)

        def pipeline(params, caches, tok, cfg=cfg, ptok=ptok):
            logits, c1, rep = decode_step_unified(
                params, cfg, caches, tok, pos, prefill_tokens=ptok,
            )
            outs = (logits, c1.kv.k, c1.kv.v, rep.res.mult)
            if ptok is not None:
                outs += (rep.prefill_logits, rep.prefill_kv.k)
            return outs

        text = jax.jit(pipeline).lower(params, caches, tok).as_text()
        tag = "mixed(decode+prefill+expert+glue)" if with_prefill else "decode"
        rows.append(_fence_free_lowering_row(
            text, f"unified step lowering [{tag}]", exp,
            f"unified-step[{tag}]", int(pos.size),
        ))
    print(
        "[zero-cost] unified-step audit OK: the one-launch mixed-mode "
        "engine step (decode + folded prefill + expert + glue families in "
        "a single launch_ws_grid lowering) has 0 RMW / 0 locks / 0 fences"
    )
    return rows


def main(n_ops: int = 100_000):
    rows = bench_zero_cost(n_ops)
    hdr = "experiment,algorithm,us_per_op,reads/op,writes/op,rmws/op,locks/op"
    print(hdr)
    out = [hdr]
    for r in rows:
        line = (
            f"{r['experiment']},{r['algorithm']},{r['us_per_op']:.3f},"
            f"{r['reads_per_op']},{r['writes_per_op']},{r['rmws_per_op']},{r['locks_per_op']}"
        )
        print(line)
        out.append(line)
    audit_fence_free(rows)
    rows.extend(audit_batched_put_host())
    try:
        import jax  # noqa: F401

        rows.extend(audit_traced_put())
        rows.extend(audit_unified_step())
    except ImportError:
        print("[zero-cost] jax unavailable — traced-put audit skipped")
    return rows


if __name__ == "__main__":
    main()

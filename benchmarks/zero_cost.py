"""Zero-cost experiments (paper §8.2, Figs 9a/9b): put-take and put-steal.

The owner performs N Puts followed by N Takes (or a thief performs N
Steals); no task work is attached.  We report wall µs/op AND the
instruction mix per operation (reads / writes / RMWs / lock acquisitions,
via the counting backend) — CPython's GIL hides hardware fence costs, so
the instruction mix is the architecture-independent evidence for the
paper's claim (WS-WMULT: zero RMW, zero locks, O(1) R/W per op; baselines:
CAS or locks on the Steal path).

The paper's result to reproduce: WS-WMULT fastest on put-take and
put-steal; B-WS-WMULT pays for its extra bookkeeping array; idempotent/
Chase-Lev/Cilk pay CAS or fence costs on Take/Steal.
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.core import ALGORITHMS, EMPTY

from .instrument import CountingBackend

DEFAULT_ALGOS = (
    "ws-wmult",
    "ws-wmult-array",
    "pallas-ws",
    "moe-ws",
    "b-ws-wmult",
    "ws-mult",
    "b-ws-mult",
    "chase-lev",
    "the-cilk",
    "idempotent-fifo",
    "idempotent-lifo",
    "idempotent-deque",
)

# The paper's headline structural claim, asserted (not just reported) by
# `audit_fence_free`: the WS-WMULT protocol and both device-layout shims —
# including the MoE expert-dispatch queue — touch shared memory with plain
# reads/writes only.  Zero RMW, zero lock acquisitions, on Put, Take AND
# Steal.  CPython can't count hardware fences, but every fence a TSO/ARM
# lowering would need hangs off an RMW or lock in these schemes, so this is
# the architecture-independent witness.
FENCE_FREE_ALGOS = ("ws-wmult", "ws-wmult-array", "pallas-ws", "moe-ws")


def _make(name: str, backend=None, n_ops: int = 0):
    """name 'x-array' selects the growable-array storage variant (the paper's
    WS_WMULT_ARRAY, §6 approach 1); plain ws-* use the linked-list (§6.2)."""
    base = name.replace("-array", "")
    if base in ("ws-mult", "ws-wmult", "b-ws-mult", "b-ws-wmult"):
        kw = dict(
            storage="growable" if name.endswith("-array") else "linked",
        )
        if kw["storage"] == "linked":
            kw["node_len"] = 4096
        else:
            kw["initial_len"] = 4096
    elif base in ("pallas-ws", "moe-ws"):
        # fixed-capacity device layout: size for the whole run
        kw = dict(capacity=n_ops + 8)
    else:
        kw = dict(initial_len=4096)
    return ALGORITHMS[base](backend=backend, **kw) if backend else ALGORITHMS[base](**kw)


def _payload_fn(name: str):
    """moe-ws is exercised with real encoded expert-tile records, so the
    audited Put/Take/Steal path is byte-for-byte the expert dispatch."""
    if name == "moe-ws":
        from repro.pallas_ws.tasks import ExpertTask

        return lambda i: tuple(
            int(v)
            for v in ExpertTask(
                expert=i % 64, row_start=8 * i, row_len=8, tid=i, cost=8
            ).encode()
        )
    return lambda i: i


def _run_ops(q, name: str, n_ops: int, steal: bool):
    payload = _payload_fn(name)
    for i in range(n_ops):
        q.put(payload(i))
    got = 0
    if steal:
        for _ in range(n_ops + 4):
            if q.steal(1) is not EMPTY:
                got += 1
            if got >= n_ops:
                break
    else:
        for _ in range(n_ops + 4):
            if q.take() is not EMPTY:
                got += 1
            if got >= n_ops:
                break
    return got


def bench_zero_cost(n_ops: int = 100_000, algos=DEFAULT_ALGOS, repeats: int = 3) -> List[Dict]:
    rows = []
    for steal in (False, True):
        exp = "put-steal" if steal else "put-take"
        for name in algos:
            best = float("inf")
            for _ in range(repeats):
                q = _make(name, n_ops=n_ops)
                t0 = time.perf_counter()
                got = _run_ops(q, name, n_ops, steal)
                dt = time.perf_counter() - t0
                best = min(best, dt)
            # instruction mix on a smaller run (counting overhead excluded
            # from the timed path)
            cb = CountingBackend()
            qc = _make(name, backend=cb, n_ops=2048)
            _run_ops(qc, name, 2048, steal)
            per_op = {k: round(v / 4096, 2) for k, v in cb.counts.snapshot().items()}
            rows.append(
                dict(
                    experiment=exp,
                    algorithm=name,
                    us_per_op=1e6 * best / (2 * n_ops),
                    extracted=got,
                    **{f"{k}_per_op": v for k, v in per_op.items()},
                )
            )
    return rows


def audit_fence_free(rows) -> None:
    """Assert the structural claim over measured instruction mixes: every
    FENCE_FREE_ALGOS row performed zero RMW operations and zero lock
    acquisitions, and every audited algorithm was measured on BOTH
    experiments — the Steal path is the one the claim is about, so it must
    not silently drop out of the bench."""
    seen = {}
    for r in rows:
        if r["algorithm"] not in FENCE_FREE_ALGOS:
            continue
        assert r["rmws_per_op"] == 0, (
            f"{r['algorithm']} [{r['experiment']}] performed RMWs: {r}"
        )
        assert r["locks_per_op"] == 0, (
            f"{r['algorithm']} [{r['experiment']}] took locks: {r}"
        )
        seen.setdefault(r["algorithm"], set()).add(r["experiment"])
    assert seen, "fence-free audit saw no rows"
    for algo, exps in seen.items():
        assert exps == {"put-take", "put-steal"}, (
            f"{algo} audited on {sorted(exps)} only — Take AND Steal required"
        )
    print(
        f"[zero-cost] fence-free audit OK: {sorted(seen)} at "
        "0 RMW / 0 locks per op on put-take and put-steal"
    )


def main(n_ops: int = 100_000):
    rows = bench_zero_cost(n_ops)
    hdr = "experiment,algorithm,us_per_op,reads/op,writes/op,rmws/op,locks/op"
    print(hdr)
    out = [hdr]
    for r in rows:
        line = (
            f"{r['experiment']},{r['algorithm']},{r['us_per_op']:.3f},"
            f"{r['reads_per_op']},{r['writes_per_op']},{r['rmws_per_op']},{r['locks_per_op']}"
        )
        print(line)
        out.append(line)
    audit_fence_free(rows)
    return rows


if __name__ == "__main__":
    main()

"""CI perf-smoke: replay the dry-run bench grid and fail on regression.

The interpret-mode schedulers are deterministic — makespans, wasted slots,
and scan-traffic counters are exact replays of the lockstep model — so a
perf regression shows up as a *number change*, not a noisy timing.  This
job re-runs the quick grid (`ragged_attention`, `moe_dispatch`,
`steal_policy`, `mesh_dispatch`, `serving_traffic`, all ``--dry-run``),
summarizes it with the same reducer
that builds BENCH.json, and compares against the committed BENCH.json
"smoke" trajectory:

* ws/static makespan ratio must not drop below committed × (1 − tol);
* scan traffic per extraction (cost policy) must not grow past
  committed × (1 + tol);
* the §3.6 scan-traffic reduction and pool queue-bytes ratio must not drop
  below committed × (1 − tol);
* the pool layout must still reproduce the host-layout ws makespan exactly;
* the mesh dispatch's speedup over per-device-static sharding must not drop
  below committed × (1 − tol), its collective bytes must not grow past
  committed × (1 + tol), and it must stay **bit-identical** to the no-drop
  oracle — an absolute gate, like the grad rows;
* the custom-VJP grad rows must be present (once committed) and match the
  no-drop oracle's gradients to fp32 tolerance — an absolute gate, since a
  wrong backward is a correctness bug, not noise;
* the serving replay (seeded trace, single-threaded — deterministic) must
  keep every unified/split cell: steps and utilization within tolerance,
  and the unified step's token streams **identical** to the split-launch
  oracle with no lost or duplicated request — absolute gates;
* a ``trace=False`` replay of the headline ragged/moe cells must reproduce
  the committed (traced) makespans **exactly** — event tracing must be free
  when off (ISSUE 7; the trace=False lowering is the pre-trace kernel);
* the chaos storm matrix (ISSUE 9; seeded fault plans, deterministic) must
  stay checker-clean with real multiplicity exercised, ``fault_plan=None``
  must remain bit-identical to the fault-free lowering, and the serving
  crash/watchdog cells must keep exactly-once completion and stream
  parity — all absolute gates.

Exit 1 on any violation (or if a bench's own headline claim already
failed).  Tolerance defaults to 10% — tight enough to catch a real
scheduler regression, loose enough to survive benign re-tuning of the
dry-run shapes (which should land together with a refreshed BENCH.json).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

if __package__ in (None, ""):  # run as a bare script: python benchmarks/...
    sys.path.insert(0, str(pathlib.Path(__file__).parent.parent))

from benchmarks.run import BENCH_JSON, summarize  # noqa: E402


def _check(errs, name, ok, detail):
    if not ok:
        errs.append(f"{name}: {detail}")


def compare(fresh: dict, committed: dict, tol: float) -> list:
    errs = []
    lo, hi = 1.0 - tol, 1.0 + tol
    if not committed:
        return ["BENCH.json has no 'smoke' section: run "
                "`python -m benchmarks.run --quick` and commit BENCH.json"]
    # every committed section must actually be compared — a missing fresh
    # summary (bench not run, dryrun file absent) is a failure, never a
    # silent skip, or the gate would pass vacuously
    for section in ("ragged_attention", "moe_dispatch", "steal_policy",
                    "mesh_dispatch", "serving", "chaos"):
        if committed.get(section) and not fresh.get(section):
            errs.append(f"{section}: committed reference exists but the "
                        "fresh dry-run summary is missing — bench not run?")
    r_new, r_old = fresh.get("ragged_attention"), committed.get("ragged_attention")
    if r_new and r_old:
        _check(errs, "ragged makespan ratio",
               r_new["makespan_ratio"] >= r_old["makespan_ratio"] * lo,
               f"{r_new['makespan_ratio']} < {r_old['makespan_ratio']} * {lo}")
        _check(errs, "ragged scan traffic (cost)",
               r_new["scan_per_extraction_cost"]
               <= r_old["scan_per_extraction_cost"] * hi,
               f"{r_new['scan_per_extraction_cost']} > "
               f"{r_old['scan_per_extraction_cost']} * {hi}")
    m_new, m_old = fresh.get("moe_dispatch"), committed.get("moe_dispatch")
    if m_new and m_old:
        _check(errs, "moe speedup vs dense",
               m_new["speedup_vs_dense"] >= m_old["speedup_vs_dense"] * lo,
               f"{m_new['speedup_vs_dense']} < {m_old['speedup_vs_dense']} * {lo}")
        _check(errs, "moe scan traffic (cost)",
               m_new["scan_per_extraction_cost"]
               <= m_old["scan_per_extraction_cost"] * hi,
               f"{m_new['scan_per_extraction_cost']} > "
               f"{m_old['scan_per_extraction_cost']} * {hi}")
        # grad path (custom VJP): once committed, the rows may never vanish,
        # and parity vs the no-drop oracle's gradients is an ABSOLUTE gate —
        # a wrong backward is a correctness bug, not a perf regression
        if m_old.get("grad") and not m_new.get("grad"):
            errs.append("moe grad rows: committed reference exists but the "
                        "fresh dry-run has none — grad bench not run?")
        for g in m_new.get("grad", []):
            _check(errs, f"moe grad parity [{g['grad_dispatch']}]",
                   g["max_abs_err"] <= 1e-3,
                   f"max_abs_err {g['max_abs_err']} > 1e-3 vs the no-drop "
                   "oracle gradients")
    x_new, x_old = fresh.get("mesh_dispatch"), committed.get("mesh_dispatch")
    if x_new and x_old:
        _check(errs, "mesh speedup vs static",
               x_new["speedup_vs_static"] >= x_old["speedup_vs_static"] * lo,
               f"{x_new['speedup_vs_static']} < "
               f"{x_old['speedup_vs_static']} * {lo}")
        _check(errs, "mesh collective bytes",
               x_new["collective_bytes_measured"]
               <= x_old["collective_bytes_measured"] * hi,
               f"{x_new['collective_bytes_measured']} > "
               f"{x_old['collective_bytes_measured']} * {hi}")
        # bitwise oracle parity is an absolute gate (correctness, not perf)
        _check(errs, "mesh oracle parity", x_new["bit_identical"],
               "mesh-ws output no longer bit-identical to the no-drop oracle")
    p_new = {(r["E"], r["skew"]): r for r in fresh.get("steal_policy", [])}
    p_old = {(r["E"], r["skew"]): r for r in committed.get("steal_policy", [])}
    if p_old and not set(p_new) & set(p_old):
        errs.append(
            "steal_policy: no (E, skew) cell in common between the fresh "
            f"dry-run grid {sorted(p_new)} and the committed reference "
            f"{sorted(p_old)} — refresh BENCH.json together with the grid"
        )
    for key in sorted(set(p_new) & set(p_old)):
        n, o = p_new[key], p_old[key]
        tag = f"steal_policy E={key[0]} skew={key[1]}"
        _check(errs, f"{tag} traffic reduction",
               n["scan_traffic_reduction"] >= o["scan_traffic_reduction"] * lo,
               f"{n['scan_traffic_reduction']} < "
               f"{o['scan_traffic_reduction']} * {lo}")
        _check(errs, f"{tag} queue bytes ratio",
               n["queue_bytes"]["ratio"] >= o["queue_bytes"]["ratio"] * lo,
               f"{n['queue_bytes']['ratio']} < {o['queue_bytes']['ratio']} * {lo}")
        _check(errs, f"{tag} ws makespan",
               n["ws_cost_makespan"] <= o["ws_cost_makespan"] * hi,
               f"{n['ws_cost_makespan']} > {o['ws_cost_makespan']} * {hi}")
        _check(errs, f"{tag} pool schedule parity",
               n["pool_makespan"] == n["ws_cost_makespan"],
               f"pool {n['pool_makespan']} != ws {n['ws_cost_makespan']}")
        # amortized synchronization (batched Put + half-run Steal): the
        # batched queue build must stay scatter-free (absolute — one
        # scatter per record is the regression this PR removed), and the
        # half-run probe reduction must not collapse vs the committed
        # reference.  .get guards let a fresh gate run against a
        # pre-halfrun committed BENCH.json.
        scat = n.get("put_scatter_ops") or {}
        _check(errs, f"{tag} batched-put scatter-free",
               all(v == 0 for v in scat.values() if isinstance(v, int)),
               f"queue-build lowering emits scatters: {scat}")
        if o.get("probe_reduction_halfrun") and n.get("probe_reduction_halfrun"):
            _check(errs, f"{tag} half-run probe reduction",
                   n["probe_reduction_halfrun"]
                   >= o["probe_reduction_halfrun"] * lo,
                   f"{n['probe_reduction_halfrun']} < "
                   f"{o['probe_reduction_halfrun']} * {lo}")
    s_new = {(r["mode"], r["path"]): r for r in fresh.get("serving", [])}
    s_old = {(r["mode"], r["path"]): r for r in committed.get("serving", [])}
    if s_old and not set(s_new) & set(s_old):
        errs.append(
            "serving: no (mode, path) cell in common between the fresh "
            f"dry-run {sorted(s_new)} and the committed reference "
            f"{sorted(s_old)} — refresh BENCH.json together with the trace"
        )
    for key in sorted(set(s_new) & set(s_old)):
        n, o = s_new[key], s_old[key]
        tag = f"serving {key[0]}/{key[1]}"
        # absolute gates first: correctness, not perf
        _check(errs, f"{tag} stream parity", n["streams_match"],
               "unified token streams no longer match the split-launch oracle")
        _check(errs, f"{tag} completions",
               n["completed"] == o["completed"] and n["rejected"] == o["rejected"],
               f"completed/rejected {n['completed']}/{n['rejected']} != "
               f"committed {o['completed']}/{o['rejected']} on the same "
               "seeded trace")
        # deterministic schedule shape: the seeded replay is single-threaded,
        # so step counts and utilization are exact — tolerance only covers
        # benign re-tuning landing with a refreshed BENCH.json
        _check(errs, f"{tag} steps",
               n["steps"] <= o["steps"] * hi,
               f"{n['steps']} > {o['steps']} * {hi}")
        _check(errs, f"{tag} slot utilization",
               n["slot_utilization"] >= o["slot_utilization"] * lo,
               f"{n['slot_utilization']} < {o['slot_utilization']} * {lo}")
    c_new, c_old = fresh.get("chaos"), committed.get("chaos")
    if c_new and c_old:
        # all absolute gates: the fault plans and traffic are seeded and the
        # decode greedy, so every column is deterministic — any drift is a
        # safety regression, not noise
        _check(errs, "chaos checker", c_new["checker_clean"],
               "a fault-injected scheduler cell violated the relaxed-"
               "semantics checker (lost task / double claim / mult bound)")
        _check(errs, "chaos storm coverage",
               c_new["max_mult"] >= max(2, c_old["max_mult"]),
               f"max multiplicity {c_new['max_mult']} < committed "
               f"{c_old['max_mult']} — the storm matrix stopped exercising "
               "real duplication")
        _check(errs, "chaos fault-off parity", c_new["fault_off_parity"],
               "fault_plan=None is no longer bit-identical to the omitted "
               "kwarg — chaos injection leaks into the fault-free lowering")
        _check(errs, "chaos replica crash",
               c_new["replica_crash"]["ok"]
               and c_new["replica_crash"]["streams_match"],
               f"{c_new['replica_crash']} — crash re-admission lost, "
               "duplicated, or diverged a stream")
        _check(errs, "chaos watchdog", c_new["watchdog"]["ok"],
               f"{c_new['watchdog']} — split fallback diverged from the "
               "clean unified streams")
        _check(errs, "chaos all cells", c_new["all_ok"],
               "at least one chaos cell failed its own gate")
    return errs


def trace_off_gate(committed: dict) -> list:
    """ISSUE-7 'tracing must be free when off': replay the headline dry-run
    cell of the ragged and moe benches with ``trace=False`` and hold the
    makespans to EXACT equality with the committed BENCH.json smoke values
    (which the bench mains produce with event tracing on).  Any drift means
    the trace=False lowering is no longer the pre-trace kernel."""
    errs = []
    r_old = (committed or {}).get("ragged_attention")
    if r_old:
        from benchmarks.ragged_attention import DRY_SHAPES, run_one

        row = run_one(*DRY_SHAPES, r_old["skew"], trace=False)
        assert "trace" not in row["ws"], "trace=False run must carry no rings"
        for name, key in (("ws", "ws_makespan"), ("static", "static_makespan")):
            _check(errs, f"trace-off ragged {name} makespan",
                   row[name]["makespan"] == r_old[key],
                   f"trace=False replay gives {row[name]['makespan']}, "
                   f"committed (traced) smoke says {r_old[key]} — "
                   "tracing is no longer free when off")
    m_old = (committed or {}).get("moe_dispatch")
    if m_old:
        from benchmarks.moe_dispatch import DRY_SHAPES, run_one

        row = run_one(*DRY_SHAPES, m_old["skew"], trace=False)
        assert "trace" not in row["ws"], "trace=False run must carry no rings"
        _check(errs, "trace-off moe ws makespan",
               row["ws"]["makespan"] == m_old["ws_makespan"],
               f"trace=False replay gives {row['ws']['makespan']}, "
               f"committed (traced) smoke says {m_old['ws_makespan']} — "
               "tracing is no longer free when off")
    return errs


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tolerance", type=float, default=0.10)
    ap.add_argument("--no-run", action="store_true",
                    help="compare existing *.dryrun.json instead of re-running")
    args = ap.parse_args(argv)

    status = 0
    if not args.no_run:
        from benchmarks import (
            chaos_storm,
            mesh_dispatch,
            moe_dispatch,
            ragged_attention,
            serving_traffic,
            steal_policy,
        )

        # each main asserts its own headline claim and rewrites *.dryrun.json
        status |= ragged_attention.main(["--dry-run"])
        status |= moe_dispatch.main(["--dry-run"])
        status |= steal_policy.main(["--dry-run"])
        status |= mesh_dispatch.main(["--dry-run"])  # re-execs on 8 devices
        status |= serving_traffic.main(["--dry-run"])
        status |= chaos_storm.main(["--dry-run"])

    if not BENCH_JSON.exists():
        print(f"[perf-smoke] {BENCH_JSON} missing — commit the trajectory first")
        return 1
    committed = json.loads(BENCH_JSON.read_text()).get("smoke", {})
    fresh = summarize(quick=True)
    errs = compare(fresh, committed, args.tolerance)
    errs += trace_off_gate(committed)
    for e in errs:
        print(f"[perf-smoke] REGRESSION {e}")
    if status:
        print("[perf-smoke] a bench headline claim failed (see above)")
    if errs or status:
        return 1
    print("[perf-smoke] OK — no regression vs committed BENCH.json smoke "
          f"trajectory (tolerance {args.tolerance:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""L1 scheduler benchmark: the paper's trade-off, measured at TPU scale.

Two regimes:

* lockstep (SPMD reality): repro.sched.run_lockstep_rounds — rounds to
  drain a skewed task set, duplicate ratio, blocking vs async collectives,
  per mode (static / ws-mult / ws-mult-ranked / ws-wmult / ws-wmult-deque).

* asynchronous (event-driven model): repro.sched.async_makespan — makespan
  and efficiency with stragglers, where ws-mult pays a sync cost per pick
  (the MaxRegister/blocking-collective price) and ws-wmult picks free on a
  stale board (the RangeMaxRegister/fence-free price: bounded duplicates).

This is the paper's zero-cost/fence-free story mapped onto the scheduler:
"fences" = blocking collectives; WS-WMULT = collective-free fast path.
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.sched import MODES, async_makespan, run_lockstep_rounds


def skewed_tails(n_queues: int, n_tasks: int, skew: float, seed: int = 0) -> np.ndarray:
    rng = np.random.RandomState(seed)
    w = rng.dirichlet(np.full(n_queues, 1.0 / max(skew, 1e-3)))
    tails = np.floor(w * n_tasks).astype(np.int64)
    while tails.sum() < n_tasks:
        tails[rng.randint(n_queues)] += 1
    return tails


def bench_lockstep(n_workers: int = 16, tasks_per: int = 4, skews=(0.25, 1.0, 4.0)) -> List[dict]:
    rows = []
    n_tasks = n_workers * tasks_per
    for skew in skews:
        tails = skewed_tails(n_workers, n_tasks, skew)
        for mode in MODES:
            _, counts, stats = run_lockstep_rounds(tails, n_workers, mode=mode, sync_every=1)
            rows.append(
                dict(
                    regime="lockstep", skew=skew, mode=mode,
                    rounds=stats.rounds_used,
                    ideal_rounds=tasks_per,
                    dup_ratio=round(stats.duplicate_ratio, 4),
                    idle=stats.idle_worker_rounds,
                    blocking_coll=stats.blocking_collectives,
                    async_coll=stats.async_collectives,
                    coverage=float((counts > 0).mean()),
                )
            )
    return rows


def bench_async(
    n_workers: int = 64,
    tasks_per: int = 8,
    straggler_frac: float = 0.06,
    straggler_slow: float = 4.0,
    modes=("static", "ws-mult", "ws-wmult", "b-ws-wmult"),
    seed: int = 0,
) -> List[dict]:
    rows = []
    rng = np.random.RandomState(seed)
    n_tasks = n_workers * tasks_per
    durations = rng.lognormal(mean=0.0, sigma=0.4, size=n_tasks) * 1e-3
    owner = np.repeat(np.arange(n_workers), tasks_per)
    speed = np.ones(n_workers)
    n_strag = max(int(straggler_frac * n_workers), 1)
    speed[rng.choice(n_workers, n_strag, replace=False)] = 1.0 / straggler_slow
    for mode in modes:
        r = async_makespan(
            durations, owner, n_workers, mode=mode, worker_speed=speed, seed=seed
        )
        rows.append(
            dict(
                regime="async", mode=mode,
                makespan_ms=round(1e3 * r.makespan, 3),
                ideal_ms=round(1e3 * r.ideal, 3),
                efficiency=round(r.efficiency, 4),
                duplicates=r.duplicates,
                picks=r.picks,
                sync_ms=round(1e3 * r.sync_time, 3),
            )
        )
    return rows


def main():
    rows = bench_lockstep() + bench_async()
    keys = ["regime", "mode", "skew", "rounds", "dup_ratio", "blocking_coll",
            "async_coll", "makespan_ms", "efficiency", "duplicates", "sync_ms"]
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))
    return rows


if __name__ == "__main__":
    main()

"""Benchmark orchestrator — one section per paper table/figure + the
framework-level benches.  CSV lines to stdout (tee'd to bench_output.txt).

Sections:
  [zero-cost]      paper Fig 9a/9b — put-take / put-steal µs/op + instr mix
                   (+ fence-free audit incl. the moe-ws expert dispatch)
  [spanning-tree]  paper Table 1 / Figs 10-14 — speedups per graph x algo
  [scheduler]      L1 TPU adaptation — lockstep rounds + async makespan
  [ragged]         device-resident WS tile scheduler vs static grid (pallas_ws)
  [moe]            dropless ws MoE dispatch vs capacity-dropping dense (moe_ws)
  [loader]         L2 host pipeline — work-stealing loader throughput
  [roofline]       dry-run roofline table (if results/dryrun.jsonl exists)

`python -m benchmarks.run --quick` shrinks sizes for CI.
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--sections",
        default="zero-cost,spanning-tree,scheduler,ragged,moe,loader,roofline",
    )
    args = ap.parse_args(argv)
    sections = set(args.sections.split(","))
    t0 = time.time()

    if "zero-cost" in sections:
        print("\n== [zero-cost] put-take / put-steal (paper Fig 9) ==")
        from . import zero_cost

        zero_cost.main(n_ops=20_000 if args.quick else 100_000)

    if "spanning-tree" in sections:
        print("\n== [spanning-tree] parallel spanning tree (paper Table 1) ==")
        from . import spanning_tree

        spanning_tree.main(scale=4_000 if args.quick else 40_000)

    if "scheduler" in sections:
        print("\n== [scheduler] L1 work-stealing microbatch scheduler ==")
        from . import scheduler

        scheduler.main()

    status = 0
    if "ragged" in sections:
        print("\n== [ragged] device-resident WS tile scheduler vs static grid ==")
        from . import ragged_attention

        # nonzero when ws fails to beat static at skew >= 4 — the bench's
        # regression signal must survive the suite entry point
        status |= ragged_attention.main(["--dry-run"] if args.quick else [])

    if "moe" in sections:
        print("\n== [moe] dropless ws MoE dispatch vs dropping dense ==")
        from . import moe_dispatch

        # nonzero when ws-dropless fails to beat the dropping dense path
        # >= 2x at skew >= 4 (or dense mysteriously stops dropping)
        status |= moe_dispatch.main(["--dry-run"] if args.quick else [])

    if "loader" in sections:
        print("\n== [loader] L2 work-stealing data loader ==")
        import numpy as np

        from repro.configs import get_config
        from repro.data import WorkStealingLoader, make_batch
        from repro.models.config import SHAPES

        cfg = get_config("llama3.2-3b", smoke=True)
        n_tasks = 16 if args.quick else 48

        def prepare(tid):
            return make_batch(cfg, SHAPES["train_4k"], step=tid, n_rows=1)

        for workers in (1, 2, 4):
            t = time.time()
            loader = WorkStealingLoader(prepare, n_tasks=n_tasks, n_workers=workers).start()
            loader.batches(timeout=120)
            dt = time.time() - t
            print(
                f"loader,workers={workers},tasks={n_tasks},sec={dt:.2f},"
                f"extractions={loader.stats['extractions']},dups={loader.stats['duplicates']}"
            )

    if "roofline" in sections:
        print("\n== [roofline] dry-run roofline table ==")
        from . import roofline

        roofline.main()

    print(f"\n[benchmarks] done in {time.time() - t0:.1f}s")
    return status


if __name__ == "__main__":
    sys.exit(main())

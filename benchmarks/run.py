"""Benchmark orchestrator — one section per paper table/figure + the
framework-level benches.  CSV lines to stdout (tee'd to bench_output.txt).

Sections:
  [zero-cost]      paper Fig 9a/9b — put-take / put-steal µs/op + instr mix
                   (+ fence-free audit incl. the moe-ws expert dispatch)
  [spanning-tree]  paper Table 1 / Figs 10-14 — speedups per graph x algo
  [scheduler]      L1 TPU adaptation — lockstep rounds + async makespan
  [ragged]         device-resident WS tile scheduler vs static grid (pallas_ws)
  [moe]            dropless ws MoE dispatch vs capacity-dropping dense (moe_ws)
  [policy]         cost-aware O(1) victim selection vs sequential scan +
                   shared-pool vs padded traced queue layouts (§3.6)
  [mesh]           cross-device mesh-ws vs per-device-static expert
                   sharding on 8 forced host devices (§7)
  [serving]        replayed arrival traffic through the WS frontend —
                   unified one-launch engine step vs split-launch (§5)
  [chaos]          seeded fault storms (stalls, advisory corruption,
                   kill+rewind) through the relaxed-semantics SafetyChecker,
                   plus serving crash re-admission + watchdog parity
  [loader]         L2 host pipeline — work-stealing loader throughput
  [roofline]       dry-run roofline table (if results/dryrun.jsonl exists)

`python -m benchmarks.run --quick` shrinks sizes for CI.

After the scheduler-level sections run, the canonical perf trajectory is
composed into the top-level **BENCH.json** (repo root): one summary per
bench — makespan ratios, wasted tile-slots, scan traffic per extraction,
queue-array bytes, dryrun flops/bytes, fence-free audit — under a "full"
key (normal run) or a "smoke" key (``--quick``, deterministic interpret-mode
sizes).  PR-over-PR regressions diff this one file; the CI perf-smoke job
(`benchmarks/perf_smoke.py`) replays the quick grid and fails on regression
against the committed "smoke" numbers.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

BENCH_DIR = pathlib.Path(__file__).parent
BENCH_JSON = BENCH_DIR.parent / "BENCH.json"


def _load(name: str, quick: bool):
    suffix = ".dryrun.json" if quick else ".json"
    path = BENCH_DIR / f"{name}{suffix}"
    if not path.exists():
        return None
    return json.loads(path.read_text())


def summarize(quick: bool) -> dict:
    """Reduce the per-bench JSON artifacts to the diffable trajectory rows:
    per bench the headline ratios at the interesting skews, scan traffic,
    queue bytes, and the dryrun cost-analysis numbers."""
    out = {}
    ragged = _load("BENCH_ragged", quick)
    if ragged:
        rows = [r for r in ragged["rows"] if r["skew"] >= 4] or ragged["rows"]
        r = rows[-1]
        out["ragged_attention"] = dict(
            skew=r["skew"],
            ws_makespan=r["ws"]["makespan"],
            static_makespan=r["static"]["makespan"],
            makespan_ratio=round(r["speedup_vs_static"], 3),
            wasted_ws=r["ws"]["wasted_slots"],
            wasted_static=r["static"]["wasted_slots"],
            scan_per_extraction_cost=r["ws"]["scan_per_extraction"],
            scan_per_extraction_scan=r["ws_scan"]["scan_per_extraction"],
            scan_traffic_reduction=r["scan_traffic_reduction"],
            max_abs_err=r["ws"]["max_abs_err"],
        )
    moe = _load("BENCH_moe", quick)
    if moe:
        rows = [r for r in moe["rows"] if r["skew"] >= 4] or moe["rows"]
        r = rows[-1]
        out["moe_dispatch"] = dict(
            skew=r["skew"],
            ws_makespan=r["ws"]["makespan"],
            dense_makespan=r["dense_makespan"],
            speedup_vs_dense=round(r["speedup_vs_dense"], 3),
            dense_drop_rate=round(r["dense_drop_rate"], 4),
            scan_per_extraction_cost=r["ws"]["scan_per_extraction"],
            scan_per_extraction_scan=r["ws_scan"]["scan_per_extraction"],
            max_abs_err=r["ws"]["max_abs_err"],
        )
        if moe.get("grad_rows"):
            # custom-VJP grad path: parity vs the no-drop oracle's grads
            # (perf_smoke gates on presence + fp32-tolerance correctness)
            out["moe_dispatch"]["grad"] = [
                {key: g[key] for key in ("grad_dispatch", "max_abs_err",
                                         "wall_s")}
                for g in moe["grad_rows"]
            ]
        if "traced_put_audit" in moe:
            out["traced_put_audit"] = [
                {k: a[k] for k in ("experiment", "algorithm", "rmws_per_op",
                                   "locks_per_op", "fences_per_op")}
                for a in moe["traced_put_audit"]
            ]
    mesh = _load("BENCH_mesh", quick)
    if mesh:
        rows = [r for r in mesh["rows"] if r["skew"] >= 4] or mesh["rows"]
        r = rows[-1]
        out["mesh_dispatch"] = dict(
            D=r["D"],
            skew=r["skew"],
            mesh_ws_makespan=r["mesh_ws"]["makespan"],
            static_makespan=r["static"]["makespan"],
            speedup_vs_static=round(r["speedup_vs_static"], 3),
            devices_stole=r["mesh_ws"]["devices_stole"],
            tiles_stolen=r["mesh_ws"]["tiles_stolen"],
            collective_bytes_measured=r["collective_bytes"]["measured_mesh_ws"],
            collective_bytes_analytic=r["collective_bytes"]["analytic_mesh_ws"],
            bit_identical=r["mesh_ws"]["bit_identical"],
        )
    serving = _load("BENCH_serving", quick)
    if serving:
        # deterministic columns only: the trace replay is seeded and the
        # engine single-threaded, so steps / utilization / counters / stream
        # parity are exact; wall-clock latencies stay in BENCH_serving.json
        out["serving"] = [
            dict(
                mode=r["mode"],
                path=r["path"],
                steps=r["steps"],
                tokens_out=r["tokens_out"],
                slot_utilization=r["slot_utilization"],
                completed=len(r["completed"]),
                rejected=len(r["rejected"]),
                stolen=r["counters"]["stolen"],
                dup_completed=r["counters"]["dup_completed"],
                streams_match=serving["streams_match"][r["mode"]],
            )
            for r in serving["rows"]
        ]
    chaos = _load("BENCH_chaos", quick)
    if chaos:
        # everything here is deterministic (seeded plans, seeded traffic,
        # greedy decode) — perf_smoke gates these columns exactly
        sched = [r for r in chaos["rows"] if r["section"] == "scheduler"]
        cells = {r["cell"]: r for r in chaos["rows"] if "cell" in r}
        out["chaos"] = dict(
            all_ok=chaos["all_ok"],
            scheduler_cells=len(sched),
            checker_clean=all(r["checker_ok"] for r in sched),
            max_mult=max((r["max_mult"] for r in sched), default=0),
            fault_off_parity=cells["fault_off_parity"]["ok"],
            replica_crash=dict(
                ok=cells["replica_crash"]["ok"],
                exactly_once=cells["replica_crash"]["exactly_once"],
                streams_match=cells["replica_crash"]["streams_match"],
                readmitted=cells["replica_crash"]["readmitted"],
                crashed=cells["replica_crash"]["crashed"],
            ),
            watchdog=dict(
                ok=cells["watchdog"]["ok"],
                streams_match=cells["watchdog"]["streams_match"],
                degradations=cells["watchdog"]["degradation_counts"],
            ),
        )
    policy = _load("BENCH_policy", quick)
    if policy:
        out["steal_policy"] = [
            dict(
                E=r["E"],
                skew=r["skew"],
                ws_cost_makespan=r["ws_cost"]["makespan"],
                ws_scan_makespan=r["ws_scan"]["makespan"],
                static_makespan=r["static"]["makespan"],
                pool_makespan=r["pool"]["makespan"],
                scan_per_extraction_cost=r["ws_cost"]["scan_per_extraction"],
                scan_per_extraction_scan=r["ws_scan"]["scan_per_extraction"],
                scan_traffic_reduction=r["traffic_reduction"],
                ws_halfrun_makespan=r.get("ws_halfrun", {}).get("makespan"),
                scan_per_extraction_halfrun=r.get("ws_halfrun", {}).get(
                    "scan_per_extraction"),
                probe_reduction_halfrun=r.get("probe_reduction_halfrun"),
                put_scatter_ops=r.get("put_scatter_ops"),
                queue_bytes=r["queue_bytes"],
                dryrun=r.get("dryrun"),
            )
            for r in policy["rows"]
        ]
    return out


def compose_bench_json(quick: bool) -> None:
    """Merge this run's summaries into the top-level BENCH.json under the
    "smoke" (--quick) or "full" key, preserving the other key so one file
    carries both the committed trajectory and its CI reference."""
    summary = summarize(quick)
    if not summary:
        return
    data = {}
    if BENCH_JSON.exists():
        data = json.loads(BENCH_JSON.read_text())
    data["smoke" if quick else "full"] = summary
    BENCH_JSON.write_text(json.dumps(data, indent=2))
    print(f"[benchmarks] composed {BENCH_JSON}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--sections",
        default="zero-cost,spanning-tree,scheduler,ragged,moe,policy,mesh,serving,chaos,loader,roofline",
    )
    args = ap.parse_args(argv)
    sections = set(args.sections.split(","))
    t0 = time.time()

    if "zero-cost" in sections:
        print("\n== [zero-cost] put-take / put-steal (paper Fig 9) ==")
        from . import zero_cost

        zero_cost.main(n_ops=20_000 if args.quick else 100_000)

    if "spanning-tree" in sections:
        print("\n== [spanning-tree] parallel spanning tree (paper Table 1) ==")
        from . import spanning_tree

        spanning_tree.main(scale=4_000 if args.quick else 40_000)

    if "scheduler" in sections:
        print("\n== [scheduler] L1 work-stealing microbatch scheduler ==")
        from . import scheduler

        scheduler.main()

    status = 0
    if "ragged" in sections:
        print("\n== [ragged] device-resident WS tile scheduler vs static grid ==")
        from . import ragged_attention

        # nonzero when ws fails to beat static at skew >= 4 — the bench's
        # regression signal must survive the suite entry point
        status |= ragged_attention.main(["--dry-run"] if args.quick else [])

    if "moe" in sections:
        print("\n== [moe] dropless ws MoE dispatch vs dropping dense ==")
        from . import moe_dispatch

        # nonzero when ws-dropless fails to beat the dropping dense path
        # >= 2x at skew >= 4 (or dense mysteriously stops dropping)
        status |= moe_dispatch.main(["--dry-run"] if args.quick else [])

    if "policy" in sections:
        print("\n== [policy] cost-aware victim selection + queue layouts ==")
        from . import steal_policy

        # nonzero when the §3.6 claims fail at the largest expert count:
        # scan traffic not reduced >= 10x, pool bytes not reduced >= 4x,
        # or a makespan regression vs the scan policy
        status |= steal_policy.main(["--dry-run"] if args.quick else [])

    if "mesh" in sections:
        print("\n== [mesh] cross-device mesh-ws vs per-device-static ==")
        from . import mesh_dispatch

        # nonzero when mesh-ws fails to beat static sharding at skew >= 4
        # on 8 forced host devices, or any row loses bitwise oracle parity
        status |= mesh_dispatch.main(["--dry-run"] if args.quick else [])

    if "serving" in sections:
        print("\n== [serving] replayed traffic: unified vs split engine step ==")
        from . import serving_traffic

        # nonzero when any rid is lost/duplicated or the unified one-launch
        # step's token streams diverge from the split-launch oracle
        status |= serving_traffic.main(["--dry-run"] if args.quick else [])

    if "chaos" in sections:
        print("\n== [chaos] seeded fault storms through the SafetyChecker ==")
        from . import chaos_storm

        # nonzero when any cell fails the checker (lost task, multiplicity
        # bound, double claim), output parity, the fault-off bitwise gate,
        # or serving exactly-once / stream parity under crash + watchdog
        status |= chaos_storm.main(["--dry-run"] if args.quick else [])

    if any(s in sections for s in ("ragged", "moe", "policy", "mesh", "serving", "chaos")):
        compose_bench_json(quick=args.quick)

    if "loader" in sections:
        print("\n== [loader] L2 work-stealing data loader ==")
        import numpy as np

        from repro.configs import get_config
        from repro.data import WorkStealingLoader, make_batch
        from repro.models.config import SHAPES

        cfg = get_config("llama3.2-3b", smoke=True)
        n_tasks = 16 if args.quick else 48

        def prepare(tid):
            return make_batch(cfg, SHAPES["train_4k"], step=tid, n_rows=1)

        for workers in (1, 2, 4):
            t = time.time()
            loader = WorkStealingLoader(prepare, n_tasks=n_tasks, n_workers=workers).start()
            loader.batches(timeout=120)
            dt = time.time() - t
            print(
                f"loader,workers={workers},tasks={n_tasks},sec={dt:.2f},"
                f"extractions={loader.stats['extractions']},dups={loader.stats['duplicates']}"
            )

    if "roofline" in sections:
        print("\n== [roofline] dry-run roofline table ==")
        from . import roofline

        roofline.main()

    print(f"\n[benchmarks] done in {time.time() - t0:.1f}s")
    return status


if __name__ == "__main__":
    sys.exit(main())

"""Mesh dispatch benchmark: cross-device WS vs per-device-static sharding.

Workload: top-k routing over E experts sharded round-robin-free (contiguous
blocks) across D forced host devices, with the same hot-set router skew as
``moe_dispatch.py`` — hot experts concentrate on few devices, so static
expert-parallel sharding strands every other device idle while the hot
shard grinds.  Two schedules over identical routed pairs:

* **per-device-static** (``steal=False``): each device drains only its own
  expert queues (intra-device WS still on), no advisory exchange, no
  remote steals — classic expert parallelism.  Makespan = max over devices
  of the local drain clock.
* **mesh-ws** (``steal=True``): the two-level hierarchy — balanced local
  drain, coalesced advisory exchange, replicated steal plan, remote
  segment execution, psum delivery.  Makespan = max over devices of
  ``phase1 + max(phase2_own, phase2_steal)`` (the phases are separated by
  the collective barrier).

Makespans are device-clock telemetry in tile-slot units (the shared cost
model of every scheduler bench here).  Collective traffic is reported two
ways per schedule: ``measured`` — all-reduce/collective-permute bytes
counted from the compiled HLO by ``launch.hlo_analysis.analyze`` (loop trip
counts included) — and ``analytic`` — the payload accounting of
``mesh_ws.advisory.exchange_payload_bytes``.

Writes BENCH_mesh.json next to this file (``--dry-run`` →
BENCH_mesh.dryrun.json for the CI smoke; rows are deterministic, so
``perf_smoke.py`` replays them exactly).  Exit status 1 when the headline
claim fails: at skew >= 4 mesh-ws must beat the static makespan, and every
row must be **bit-identical** to the no-drop oracle (max_abs_err == 0).

Needs D forced host devices; re-execs itself with
``XLA_FLAGS=--xla_force_host_platform_device_count=D`` when the live
process has fewer (the count locks at first jax init).
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np


def run_one(T, d, f, E, D, k, P, bt, skew, seed=0, trace_sink=None):
    import jax
    import jax.numpy as jnp

    from repro.launch.hlo_analysis import analyze
    from repro.launch.mesh import make_expert_mesh
    from repro.mesh_ws import (
        exchange_payload_bytes,
        expert_ffn_mesh_ws,
        mesh_wstrace,
    )
    from repro.moe_ws.layer import expert_ffn_nodrop_ref

    from benchmarks.moe_dispatch import make_skewed_routing

    idx, gates = make_skewed_routing(T, E, k, skew, seed)
    loads = np.bincount(idx.reshape(-1), minlength=E)
    dev_loads = loads.reshape(D, E // D).sum(axis=1)

    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    wg = jax.random.normal(ks[1], (E, d, f), jnp.float32) / np.sqrt(d)
    wu = jax.random.normal(ks[2], (E, d, f), jnp.float32) / np.sqrt(d)
    wd = jax.random.normal(ks[3], (E, f, d), jnp.float32) / np.sqrt(f)
    ref = np.asarray(expert_ffn_nodrop_ref(idx, gates, x, wg, wu, wd))

    mesh = make_expert_mesh(E, D)
    row = dict(
        T=T, d=d, f=f, E=E, D=D, k=k, n_programs=P, bt=bt, skew=skew,
        routed=int(T * k), max_dev_load=int(dev_loads.max()),
        mean_dev_load=float(dev_loads.mean()),
    )
    hlo_bytes = {}
    for name, steal in (("static", False), ("mesh_ws", True)):
        fn = lambda *a: expert_ffn_mesh_ws(  # noqa: E731
            *a, mesh=mesh, bt=bt, n_programs=P, steal=steal,
            return_telemetry=True,
        )
        args = (idx, gates, x, wg, wu, wd)
        t0 = time.perf_counter()
        y, tele = fn(*args)
        y, tele = np.asarray(y), np.asarray(tele)
        dt = time.perf_counter() - t0
        if steal:
            per_dev = tele[:, 0] + np.maximum(tele[:, 1], tele[:, 2])
            tele_ws = tele
        else:
            per_dev = tele[:, 0]
        hlo = jax.jit(fn).lower(*args).compile().as_text()
        hlo_bytes[name] = analyze(hlo)["collective_bytes"]
        row[name] = dict(
            makespan=int(per_dev.max()),
            phase1_max=int(tele[:, 0].max()),
            devices_stole=int(tele[:, 5].sum()),
            tiles_stolen=int(tele[:, 6].sum()),
            max_abs_err=float(np.abs(y - ref).max()),
            bit_identical=bool(np.array_equal(y, ref)),
            wall_s=round(dt, 3),
        )
    El = E // D
    pool_tiles = -(-T * k // bt) + El + 1
    row["collective_bytes"] = dict(
        measured_mesh_ws=hlo_bytes["mesh_ws"],
        measured_static=hlo_bytes["static"],
        analytic_mesh_ws=exchange_payload_bytes(
            n_devices=D, pool_tiles=pool_tiles, n_local=El,
            n_rows=pool_tiles * bt, n_routed=T * k, d=d, f=f,
        ),
    )
    row["speedup_vs_static"] = row["static"]["makespan"] / max(
        1, row["mesh_ws"]["makespan"]
    )
    # per-phase trace columns + the Perfetto-exportable phase timeline
    tr = mesh_wstrace(
        tele_ws,
        collective_bytes=row["collective_bytes"]["analytic_mesh_ws"],
    )
    row["mesh_ws"]["trace"] = dict(
        phase2_own_max=int(tele_ws[:, 1].max()),
        phase2_steal_max=int(tele_ws[:, 2].max()),
        advisory_total=int(tele_ws[:, 3].sum()),
        collective_bytes=row["collective_bytes"]["analytic_mesh_ws"],
    )
    if trace_sink is not None:
        trace_sink["mesh_ws"] = tr
    return row


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true", help="tiny shapes for CI smoke")
    ap.add_argument("--skews", default="1,4,16")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--out", default=None, help="output JSON path")
    ap.add_argument("--trace", default=None, metavar="TRACE_JSON",
                    help="write a Perfetto phase timeline of the "
                         "highest-skew mesh-ws run")
    args = ap.parse_args(argv)
    if args.out is None:
        name = "BENCH_mesh.dryrun.json" if args.dry_run else "BENCH_mesh.json"
        args.out = str(pathlib.Path(__file__).parent / name)

    import jax

    if len(jax.devices()) < args.devices:
        # the live process initialized jax with fewer devices (the count
        # locks at first init) — re-exec with the forcing flag in the env
        env = dict(
            os.environ,
            XLA_FLAGS=f"--xla_force_host_platform_device_count={args.devices}",
        )
        env.setdefault("PYTHONPATH", str(pathlib.Path(__file__).parent.parent / "src"))
        cmd = [sys.executable, str(pathlib.Path(__file__).resolve()),
               "--skews", args.skews, "--devices", str(args.devices),
               "--out", args.out]
        if args.trace:
            cmd += ["--trace", args.trace]
        if args.dry_run:
            cmd.append("--dry-run")
        return subprocess.run(cmd, env=env).returncode

    if args.dry_run:
        T, d, f, E, D, k, P, bt = 48, 8, 16, 16, args.devices, 2, 2, 4
    else:
        T, d, f, E, D, k, P, bt = 96, 16, 32, 32, args.devices, 2, 2, 4

    skews = [float(s) for s in args.skews.split(",")]
    rows = []
    traces = {}
    print("skew,static_makespan,mesh_makespan,speedup,devices_stole,"
          "tiles_stolen,collective_bytes,bit_identical")
    for skew in skews:
        sink = {}
        row = run_one(T, d, f, E, D, k, P, bt, skew, trace_sink=sink)
        if "mesh_ws" in sink:
            traces[skew] = sink["mesh_ws"]
        rows.append(row)
        print(
            f"{skew},{row['static']['makespan']},{row['mesh_ws']['makespan']},"
            f"{row['speedup_vs_static']:.2f},{row['mesh_ws']['devices_stole']},"
            f"{row['mesh_ws']['tiles_stolen']},"
            f"{row['collective_bytes']['measured_mesh_ws']},"
            f"{row['mesh_ws']['bit_identical']}"
        )

    payload = dict(
        bench="mesh_dispatch",
        config=dict(T=T, d=d, f=f, E=E, D=D, k=k, n_programs=P, bt=bt,
                    dry_run=args.dry_run),
        rows=rows,
    )
    pathlib.Path(args.out).write_text(json.dumps(payload, indent=2))
    print(f"[mesh_dispatch] wrote {args.out}")

    if args.trace and traces:
        from repro.wstrace import write_perfetto

        write_perfetto(traces[max(traces)], args.trace)
        print(f"[mesh_dispatch] wrote Perfetto trace (skew={max(traces)}) to "
              f"{args.trace} — open at https://ui.perfetto.dev")

    # headline claims: cross-device stealing wins under skew, and the
    # dispatch is exact — not approximately, bitwise
    bad_exact = [
        r["skew"] for r in rows
        if not (r["mesh_ws"]["bit_identical"] and r["static"]["bit_identical"])
    ]
    if bad_exact:
        print(f"[mesh_dispatch] oracle exactness failed at skews {bad_exact}")
        return 1
    bad_speed = [
        r["skew"] for r in rows
        if r["skew"] >= 4 and r["speedup_vs_static"] <= 1.0
    ]
    if bad_speed:
        print(f"[mesh_dispatch] mesh-ws did not beat static at skews {bad_speed}")
        return 1
    return 0


if __name__ == "__main__":
    if __package__ is None:  # bare script: make `benchmarks.` importable
        sys.path.insert(0, str(pathlib.Path(__file__).parent.parent))
    sys.exit(main())

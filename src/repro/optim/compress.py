"""int8 error-feedback gradient compression for the cross-pod all-reduce.

Cross-pod ICI links are the scarce resource on a 2x16x16 mesh (DESIGN.md
§8).  The pod-axis gradient reduction is therefore run in two stages:
in-pod all-reduce in bf16/f32, then an int8-quantized cross-pod exchange
with per-tensor scale and an error-feedback residual carried in the
optimizer loop (so quantization error is re-injected next step and the
compression is unbiased over time — the standard EF-SGD construction).

`make_ef_compressor` returns pure functions usable inside a jitted step;
the psum over the pod axis happens on the int8 payload (4x fewer bytes on
the cross-pod links; the dry-run collective-bytes table shows the drop).
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


def int8_compress_decompress(x, axis_name: Optional[str] = None):
    """Quantize -> (optionally psum over axis_name) -> dequantize.

    Returns (value, residual): `value` is the (reduced) dequantized tensor,
    `residual` the local quantization error (x - q(x)).
    """
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    deq_local = q.astype(jnp.float32) * scale
    residual = xf - deq_local
    if axis_name is not None:
        # int8 payload crosses the link; scales are tiny (one f32 per tensor)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        ssum = jax.lax.psum(scale, axis_name)  # conservative shared scale
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        value = qsum.astype(jnp.float32) * (ssum / n)
    else:
        value = deq_local
    return value, residual


def make_ef_compressor(enabled: bool, axis_name: Optional[str] = None):
    """Error-feedback wrapper over a gradient pytree.

    state: residual pytree (f32).  apply(grads, state) -> (grads', state').
    Disabled -> identity with empty state.
    """

    def init(grads_like) -> Any:
        if not enabled:
            return ()
        return jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
        )

    def apply(grads, state):
        if not enabled:
            return grads, state

        def one(g, r):
            val, res = int8_compress_decompress(g.astype(jnp.float32) + r, axis_name)
            return val.astype(g.dtype), res

        out = jax.tree_util.tree_map(one, grads, state)
        new_g = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_r = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_g, new_r

    return init, apply

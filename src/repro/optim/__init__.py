"""repro.optim — optimizers, schedules, clipping, gradient compression."""

from .optimizer import OptState, make_adafactor_momentum, make_adamw
from .schedules import cosine_schedule, wsd_schedule
from .compress import int8_compress_decompress, make_ef_compressor

__all__ = [
    "OptState",
    "cosine_schedule",
    "int8_compress_decompress",
    "make_adafactor_momentum",
    "make_adamw",
    "make_ef_compressor",
    "wsd_schedule",
]

"""Functional optimizers (optax-free, pytree-native).

Two flavors:

* make_adamw        — fp32 m/v states (standard; <=300B-class archs).
* make_adafactor_momentum — bf16 momentum + row/col-factored second moment.
  For the 1T-param arch: AdamW fp32 states alone are 8 TB — more than two
  v5e pods of HBM — while factored-v + bf16-m is ~2 TB (see EXPERIMENTS.md
  §Dry-run).  Optimizer state inherits the parameter sharding, so ZeRO-1
  falls out of the fsdp param specs for free.

Both apply decoupled weight decay and global-norm clipping.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray  # int32 scalar
    m: Any
    v: Any  # adamw: full; factored: (row, col) tuples for >=2D params


class Optimizer(NamedTuple):
    init: Callable[[Any], OptState]
    apply: Callable[[Any, Any, OptState], tuple]  # (params, grads, state) -> (params, state)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def make_adamw(
    lr: Callable, *, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1, clip_norm=1.0
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree_util.tree_map(zeros, params),
            v=jax.tree_util.tree_map(zeros, params),
        )

    def apply(params, grads, state):
        grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        t = step.astype(jnp.float32)
        lr_t = lr(step)
        bc1 = 1 - b1**t
        bc2 = 1 - b2**t

        def upd(p, g, m, v):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            decay = weight_decay if p.ndim >= 2 else 0.0
            new_p = p.astype(jnp.float32) - lr_t * (u + decay * p.astype(jnp.float32))
            return new_p.astype(p.dtype), m, v

        out = jax.tree_util.tree_map(upd, params, grads, state.m, state.v)
        new_p = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, OptState(step=step, m=new_m, v=new_v)

    return Optimizer(init, apply)


def _factored(p) -> bool:
    return p.ndim >= 2


def make_adafactor_momentum(
    lr: Callable, *, b1=0.9, decay=0.99, eps=1e-30, weight_decay=0.1, clip_norm=1.0
) -> Optimizer:
    """bf16 momentum + factored second moment (rows/cols over the last two dims)."""

    def init(params):
        def v_init(p):
            if _factored(p):
                return (
                    jnp.zeros(p.shape[:-1], jnp.float32),  # row: reduce last dim
                    jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),  # col
                )
            return jnp.zeros(p.shape, jnp.float32)

        return OptState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params),
            v=jax.tree_util.tree_map(v_init, params),
        )

    def apply(params, grads, state):
        grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        lr_t = lr(step)

        def upd(p, g, m, v):
            g2 = jnp.square(g) + eps
            if _factored(p):
                vr, vc = v
                vr = decay * vr + (1 - decay) * g2.mean(axis=-1)
                vc = decay * vc + (1 - decay) * g2.mean(axis=-2)
                denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                vhat = vr[..., None] * vc[..., None, :] / denom[..., None]
                new_v = (vr, vc)
            else:
                vhat = decay * v + (1 - decay) * g2
                new_v = vhat
            u = g / jnp.sqrt(vhat + eps)
            mf = b1 * m.astype(jnp.float32) + (1 - b1) * u
            dec = weight_decay if p.ndim >= 2 else 0.0
            new_p = p.astype(jnp.float32) - lr_t * (mf + dec * p.astype(jnp.float32))
            return new_p.astype(p.dtype), mf.astype(jnp.bfloat16), new_v

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.m)
        flat_v = tdef.flatten_up_to(state.v)
        res = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([r[0] for r in res])
        new_m = tdef.unflatten([r[1] for r in res])
        new_v = tdef.unflatten([r[2] for r in res])
        return new_p, OptState(step=step, m=new_m, v=new_v)

    return Optimizer(init, apply)

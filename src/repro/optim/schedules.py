"""LR schedules.  WSD (warmup-stable-decay) is first-class because minicpm-2b
(assigned arch) was trained with it [arXiv:2404.06395]."""

from __future__ import annotations

import jax.numpy as jnp


def wsd_schedule(peak_lr: float, warmup: int, stable: int, decay: int, floor: float = 0.1):
    """Warmup-Stable-Decay: linear warmup -> flat -> exponential-ish decay to
    floor*peak over `decay` steps."""

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        in_decay = jnp.maximum(step - (warmup + stable), 0.0)
        frac = jnp.minimum(in_decay / jnp.maximum(decay, 1), 1.0)
        decayed = peak_lr * (floor ** frac)
        return jnp.where(step < warmup + stable, warm, decayed)

    return lr


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, peak_lr * cos)

    return lr

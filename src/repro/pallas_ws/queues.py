"""HBM-resident per-program task queues as flat int32 arrays (WS-WMULT Fig. 7).

The paper's shared objects map onto device arrays one-to-one:

==========================  =====================================================
paper (Fig. 7)              device layout (all plain loads/stores)
==========================  =====================================================
``tasks[1..∞]`` per queue   ``tasks[q, s, :]``  [n_queues, capacity, TASK_WIDTH]
``Head`` register           ``head[q]``         [n_queues]
process-local ``head``      ``local_head[p, q]`` [n_programs, n_queues]
(announcement)              ``taken[q, s]``     [n_queues, capacity] — extractor id
``tail`` (owner-local)      ``tail[q]``         [n_queues] — static: puts happen
                                                 host-side before launch
==========================  =====================================================

``local_head[p, q]`` is the persistent per-process lower bound of the inlined
RangeMaxRegister: every Take/Steal refreshes it with ``max(local, head[q])``
(the RMaxRead) and plainly writes ``head[q] = h+1`` on success (the RMaxWrite
with its read elided).  No CAS, no fence — a stale ``head`` write can rewind a
queue and cause re-extraction, but each program's bound is strictly
increasing, so no *program* extracts the same slot twice.

``taken[q, s]`` is the announcement row: the extracting program writes its id
after claiming slot ``s``.  It is diagnostic (multiplicity accounting /
drills), never consulted by the extraction protocol itself.

Three builders produce launch-compatible states:

* :func:`make_queue_state` — the host-side Put: concrete tasks laid out with
  numpy before launch (serving's eager paths, the drills);
* :func:`make_queue_state_jax` — the **traced** Put: fixed-shape candidate
  records compacted on device with jnp ops, so queue construction lives
  inside ``jit``/``scan``;
* :func:`make_pool_queue_state_jax` — the traced Put on the compact
  **shared-pool** layout (DESIGN.md §3.6): one flat slot pool with dynamic
  per-queue segment offsets (``pool_off``), cutting the per-queue
  worst-case padding the dense traced layout pays.

Every state also carries the ``remaining[q]`` advisory cost summaries the
cost-aware victim selection ranks by (plain writes, stale-tolerant).  The
megakernel launch consumes any of them through the one
:func:`repro.pallas_ws.kernel.launch_ws_grid` code path.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .tasks import BOTTOM, TASK_WIDTH, TileTask


@dataclass
class QueueState:
    """Mirror of the device queue arrays.

    Host-built states hold numpy int32 arrays plus the concrete
    ``task_list``; trace-built states hold jnp values (possibly tracers)
    with ``task_list=None`` and the *static* ``n_tasks_hint`` sizing the
    multiplicity buffer (dead candidate slots keep mult 0).

    ``remaining[q]`` is the advisory per-queue cost summary the cost-aware
    victim selection ranks by (DESIGN.md §3.6): initialized to the enqueued
    cost, decremented best-effort by claimants with plain reads/writes.
    Stale values mis-rank victims but can never change results.

    Two layouts share the class.  The **dense** layout (``pool_off is
    None``): ``tasks[q, s, :]`` with a static per-queue ``capacity``.  The
    **shared-pool** layout: ``tasks[j, :]`` is one flat slot pool and queue
    ``q`` owns the contiguous segment ``[pool_off[q], pool_off[q+1])`` —
    slot ``(q, s)`` lives at pool index ``pool_off[q] + s`` and ``taken``
    is flat ``[pool_slots]``.  Segment boundaries are dynamic (trace-built
    from the router load), so the pool never pays the dense layout's
    per-queue worst-case padding.
    """

    tasks: np.ndarray        # [n_queues, capacity, TASK_WIDTH] | pool: [pool_slots, TASK_WIDTH]
    head: np.ndarray         # [n_queues]
    tail: np.ndarray         # [n_queues]
    local_head: np.ndarray   # [n_programs, n_queues]
    taken: np.ndarray        # [n_queues, capacity] | pool: [pool_slots]; -1 = not extracted
    task_list: Optional[List[TileTask]] = None
    n_tasks_hint: Optional[int] = None
    remaining: Optional[np.ndarray] = None  # [n_queues] advisory cost summary
    pool_off: Optional[np.ndarray] = None   # [n_queues + 1] pool segment offsets

    @property
    def n_queues(self) -> int:
        return self.head.shape[0]

    @property
    def n_programs(self) -> int:
        return self.local_head.shape[0]

    @property
    def capacity(self) -> int:
        """Global bound on slot indices: per-queue capacity on the dense
        layout, total pool slots on the shared-pool layout."""
        if self.pool_off is not None:
            return self.tasks.shape[0]
        return self.tasks.shape[1]

    @property
    def n_tasks(self) -> int:
        if self.task_list is not None:
            return len(self.task_list)
        return self.n_tasks_hint or 0

    def queue_array_bytes(self) -> int:
        """Total bytes of the queue-side arrays (tasks + head/tail +
        local bounds + announcements + advisory) — the HBM footprint the
        shared-pool layout exists to shrink."""
        arrays = [self.tasks, self.head, self.tail, self.local_head,
                  self.taken]
        if self.remaining is not None:
            arrays.append(self.remaining)
        if self.pool_off is not None:
            arrays.append(self.pool_off)
        total = 0
        for a in arrays:
            n = 1
            for d in a.shape:
                n *= int(d)
            total += n * 4  # int32 everywhere
        return total


def partition_tasks(
    tasks: Sequence[TileTask], n_queues: int, partition: str = "owner"
) -> List[List[TileTask]]:
    """Assign tasks to owner queues.

    * ``"owner"`` (alias ``"batch"``) — queue ``task.owner % n_queues``: all
      tiles of one logical owner (a sequence's batch row for attention, an
      expert for MoE dispatch) land on one queue — the natural placement and
      the one that produces the skew the thieves then erase.
    * ``"round_robin"`` — task-index striping (near-balanced baseline).
    """
    buckets: List[List[TileTask]] = [[] for _ in range(n_queues)]
    for i, t in enumerate(tasks):
        q = (t.owner if partition in ("owner", "batch") else i) % n_queues
        buckets[q].append(t)
    return buckets


def make_queue_state(
    tasks: Sequence[TileTask],
    n_programs: int,
    n_queues: int | None = None,
    partition: str = "batch",
) -> QueueState:
    """Lay tasks out in the Fig. 7 array format, ready for the megakernel.

    Slots beyond each queue's tail keep ``BOTTOM`` in field 0 — the paper's
    two-⊥-slot invariant degenerates to "the whole suffix is ⊥" because all
    Puts happen host-side before the kernel launches.
    """
    n_queues = n_programs if n_queues is None else n_queues
    buckets = partition_tasks(tasks, n_queues, partition)
    cap = max(4, max((len(b) for b in buckets), default=0) + 2)
    arr = np.full((n_queues, cap, TASK_WIDTH), BOTTOM, dtype=np.int32)
    tail = np.zeros((n_queues,), dtype=np.int32)
    remaining = np.zeros((n_queues,), dtype=np.int32)
    for q, bucket in enumerate(buckets):
        for s, t in enumerate(bucket):
            arr[q, s] = t.encode()
        tail[q] = len(bucket)
        remaining[q] = sum(t.cost for t in bucket)
    return QueueState(
        tasks=arr,
        head=np.zeros((n_queues,), dtype=np.int32),
        tail=tail,
        local_head=np.zeros((n_programs, n_queues), dtype=np.int32),
        taken=np.full((n_queues, cap), -1, dtype=np.int32),
        task_list=list(tasks),
        remaining=remaining,
    )


def make_staged_queue_state(
    stages: Sequence[Sequence[TileTask]],
    n_programs: int,
    *,
    n_queues_per_stage: Optional[int] = None,
    partition: str = "owner",
) -> Tuple[QueueState, np.ndarray, int]:
    """Host-side Put for a *stage-gated* mixed-mode launch (DESIGN.md §5).

    ``stages[s]`` is the task list of stage ``s`` (any registered family —
    the unified engine step mixes glue, attention, and expert records in
    one launch).  Each stage gets its own block of queues, laid out
    stage-major, and the whole sequence runs as ONE ``launch_ws_grid``
    call: inter-stage dependencies are enforced purely by the returned
    ``stage_open`` vector — queue ``q`` of stage ``s`` becomes visible to
    Take/Steal only at round ``open[s]``, where the open rounds are the
    prefix sums of each stage's Graham bound

        open[0] = 0;  open[s+1] = open[s] + ceil(W_s / P) + max_cost_s

    (``W_s`` total stage cost).  Because an idle program always claims a
    task whenever any open queue is non-empty (the cost policy's
    ``head < tail`` victim mask is exact), every stage-``s`` task has
    *finished* — clock-wise and write-wise — by ``open[s+1]``, so stage
    ``s+1`` bodies read completed stage-``s`` output.  No device-side
    waiting, no fence: the dependency structure is a pure input.

    Returns ``(state, stage_open, rounds)`` — ``stage_open`` is per-queue
    ([n_queues] int32) and ``rounds = open[n_stages]`` is the static grid
    bound covering the final stage's window.
    """
    q_s = n_programs if n_queues_per_stage is None else n_queues_per_stage
    buckets: List[List[TileTask]] = []
    opens = [0]
    task_list: List[TileTask] = []
    for tasks in stages:
        buckets += partition_tasks(tasks, q_s, partition)
        task_list += list(tasks)
        total = sum(t.cost for t in tasks)
        mc = max((t.cost for t in tasks), default=0)
        window = (-(-total // n_programs) + mc) if tasks else 0
        opens.append(opens[-1] + window)
    n_queues = len(buckets)
    cap = max(4, max((len(b) for b in buckets), default=0) + 2)
    arr = np.full((n_queues, cap, TASK_WIDTH), BOTTOM, dtype=np.int32)
    tail = np.zeros((n_queues,), dtype=np.int32)
    remaining = np.zeros((n_queues,), dtype=np.int32)
    for q, bucket in enumerate(buckets):
        for s, t in enumerate(bucket):
            arr[q, s] = t.encode()
        tail[q] = len(bucket)
        remaining[q] = sum(t.cost for t in bucket)
    state = QueueState(
        tasks=arr,
        head=np.zeros((n_queues,), dtype=np.int32),
        tail=tail,
        local_head=np.zeros((n_programs, n_queues), dtype=np.int32),
        taken=np.full((n_queues, cap), -1, dtype=np.int32),
        task_list=task_list,
        remaining=remaining,
    )
    stage_open = np.repeat(
        np.asarray(opens[:-1], dtype=np.int32), q_s
    )
    return state, stage_open, max(1, opens[-1])


def copy_state(state: QueueState) -> QueueState:
    """Independent copy of a host-built queue state (numpy arrays copied,
    task_list shared — tasks are immutable records).  Fault-injection
    drills mutate head/local bounds/advisories in place; the fault-free
    oracle must run from a pristine copy."""

    def cp(a):
        return None if a is None else np.array(a)

    return QueueState(
        tasks=cp(state.tasks),
        head=cp(state.head),
        tail=cp(state.tail),
        local_head=cp(state.local_head),
        taken=cp(state.taken),
        task_list=state.task_list,
        n_tasks_hint=state.n_tasks_hint,
        remaining=cp(state.remaining),
        pool_off=cp(state.pool_off),
    )


def queue_costs(state: QueueState) -> np.ndarray:
    """Total tile-slot cost enqueued per queue (the static-schedule load)."""
    from .tasks import F_COST, F_OP

    if state.pool_off is not None:
        tasks = np.asarray(state.tasks)
        off = np.asarray(state.pool_off)
        tail = np.asarray(state.tail)
        costs = np.zeros((state.n_queues,), dtype=np.int64)
        live = tasks[:, F_OP] != BOTTOM
        for q in range(state.n_queues):
            seg = slice(int(off[q]), int(off[q]) + int(tail[q]))
            costs[q] = np.where(live[seg], tasks[seg, F_COST], 0).sum()
        return costs
    live = state.tasks[:, :, F_OP] != BOTTOM
    return np.where(live, state.tasks[:, :, F_COST], 0).sum(axis=1)


# ---------------------------------------------------------------------------
# traced (jit-compatible) queue construction — the device-side Put


def owner_queue_candidates(records, live, n_queues: int) -> Tuple:
    """Regroup per-owner candidate tiles into per-queue candidate arrays.

    ``records``: [n_owners, per_owner, TASK_WIDTH]; ``live``:
    [n_owners, per_owner] bool.  Owner ``o`` lands on queue ``o % n_queues``
    (the same placement :func:`partition_tasks` uses for ``"owner"``), its
    tiles ordered by ``o // n_queues`` within the queue — all with static
    shapes, so the regrouping traces.  Owners are padded with dead rows up
    to a multiple of ``n_queues``.
    """
    import jax.numpy as jnp

    records = jnp.asarray(records)
    live = jnp.asarray(live)
    n_owners, per_owner, width = records.shape
    if n_queues == n_owners:
        return records, live
    pad = (-n_owners) % n_queues
    if pad:
        records = jnp.pad(records, ((0, pad), (0, 0), (0, 0)),
                          constant_values=BOTTOM)
        live = jnp.pad(live, ((0, pad), (0, 0)), constant_values=False)
    rows = (n_owners + pad) // n_queues
    # owner o = j * n_queues + q  ->  queue q, block j
    records = records.reshape(rows, n_queues, per_owner, width)
    records = records.transpose(1, 0, 2, 3).reshape(n_queues, rows * per_owner, width)
    live = live.reshape(rows, n_queues, per_owner)
    live = live.transpose(1, 0, 2).reshape(n_queues, rows * per_owner)
    return records, live


def make_queue_state_jax(
    records,
    live,
    n_programs: int,
    *,
    n_tasks: int,
) -> QueueState:
    """Traced Put: materialize the Fig. 7 queue arrays as jnp values.

    ``records``: [n_queues, slots, TASK_WIDTH] candidate task records at
    their static slots; ``live``: [n_queues, slots] bool masks.  Each
    queue's live records are stably compacted to the slot prefix (the order
    a host-side Put loop would have produced) and every dead slot is set to
    the ⊥ record, restoring the "whole suffix is ⊥" invariant the extraction
    protocol scans for.  ``tail[q]`` is the live count — exactly the value
    the owner's Put counter would hold.  All ops are jnp, so this works on
    tracers; on concrete inputs it produces the same layout
    :func:`make_queue_state` builds host-side (certified by
    tests/test_dispatch_conformance.py).

    ``n_tasks`` is the static candidate count sizing the multiplicity
    buffer; dead candidates keep ``mult == 0`` and their ``tid`` is never
    extracted.

    Batched-Put segment-write contract (DESIGN.md §3.6): the whole queue
    array materializes as *per-queue vectorized writes* — one stable-argsort
    compaction and one masked store per queue segment, never a store per
    task — and each queue's ``tail``/``remaining`` advisory is published
    once per segment (the reductions above), not once per Put.  The
    downstream :func:`repro.moe_ws.dispatch.route_to_tasks_jax` /
    ``route_to_tasks_pool_jax`` builders feed this with gather-only
    segment materialization, so the complete traced Put lowers with zero
    scatter ops (``benchmarks/zero_cost.py`` audits the lowering text).
    """
    import jax.numpy as jnp

    from .tasks import F_COST

    records = jnp.asarray(records, jnp.int32)
    live = jnp.asarray(live)
    n_queues, slots, _ = records.shape
    # stable partition: live records first, original order preserved
    order = jnp.argsort(jnp.where(live, 0, 1).astype(jnp.int32),
                        axis=1, stable=True)
    arr = jnp.take_along_axis(records, order[:, :, None], axis=1)
    live_sorted = jnp.take_along_axis(live, order, axis=1)
    arr = jnp.where(live_sorted[:, :, None], arr, BOTTOM)
    # two trailing ⊥ slots: the paper's pre-clear invariant (and slack so a
    # full queue's head can step one past the last live slot)
    arr = jnp.pad(arr, ((0, 0), (0, 2), (0, 0)), constant_values=BOTTOM)
    cap = slots + 2
    return QueueState(
        tasks=arr,
        head=jnp.zeros((n_queues,), jnp.int32),
        tail=live.sum(axis=1).astype(jnp.int32),
        local_head=jnp.zeros((n_programs, n_queues), jnp.int32),
        taken=jnp.full((n_queues, cap), -1, jnp.int32),
        task_list=None,
        n_tasks_hint=int(n_tasks),
        remaining=jnp.where(live, records[:, :, F_COST], 0)
        .sum(axis=1).astype(jnp.int32),
    )


def make_pool_queue_state_jax(
    records,
    tail,
    pool_off,
    remaining,
    n_programs: int,
    *,
    n_tasks: int,
) -> QueueState:
    """Traced Put, shared-pool layout: wrap pre-compacted flat records.

    ``records``: [pool_slots, TASK_WIDTH] task records where queue ``q``'s
    live slots already occupy the contiguous segment ``[pool_off[q],
    pool_off[q] + tail[q])`` in queue order, the pool suffix all-⊥ (the
    builder — e.g. :func:`repro.moe_ws.dispatch.route_to_tasks_pool_jax` —
    produces exactly this, so no compaction pass is needed).  ``pool_off``:
    [n_queues + 1] dynamic segment offsets; ``tail``: [n_queues] live slot
    counts (``tail[q] == pool_off[q+1] - pool_off[q]`` for every non-suffix
    queue); ``remaining``: [n_queues] initial advisory cost summaries.

    ``n_tasks`` is the static pool slot count sizing the multiplicity
    buffer — pool slot index == ``tid`` == multiplicity index, so dead
    suffix slots keep ``mult == 0``.

    Batched-Put segment-write contract (DESIGN.md §3.6): the pool builder
    hands over whole per-expert segments, so this wrapper issues exactly one
    vectorized record write for the pool plus one publication each of the
    per-queue ``tail``/``pool_off``/``remaining`` advisories — the traced
    analogue of :meth:`repro.pallas_ws.host.PallasWSHost.put_segment`.
    """
    import jax.numpy as jnp

    records = jnp.asarray(records, jnp.int32)
    pool_slots = records.shape[0]
    n_queues = tail.shape[0]
    return QueueState(
        tasks=records,
        head=jnp.zeros((n_queues,), jnp.int32),
        tail=jnp.asarray(tail, jnp.int32),
        local_head=jnp.zeros((n_programs, n_queues), jnp.int32),
        taken=jnp.full((pool_slots,), -1, jnp.int32),
        task_list=None,
        n_tasks_hint=int(n_tasks),
        remaining=jnp.asarray(remaining, jnp.int32),
        pool_off=jnp.asarray(pool_off, jnp.int32),
    )

"""HBM-resident per-program task queues as flat int32 arrays (WS-WMULT Fig. 7).

The paper's shared objects map onto device arrays one-to-one:

==========================  =====================================================
paper (Fig. 7)              device layout (all plain loads/stores)
==========================  =====================================================
``tasks[1..∞]`` per queue   ``tasks[q, s, :]``  [n_queues, capacity, TASK_WIDTH]
``Head`` register           ``head[q]``         [n_queues]
process-local ``head``      ``local_head[p, q]`` [n_programs, n_queues]
(announcement)              ``taken[q, s]``     [n_queues, capacity] — extractor id
``tail`` (owner-local)      ``tail[q]``         [n_queues] — static: puts happen
                                                 host-side before launch
==========================  =====================================================

``local_head[p, q]`` is the persistent per-process lower bound of the inlined
RangeMaxRegister: every Take/Steal refreshes it with ``max(local, head[q])``
(the RMaxRead) and plainly writes ``head[q] = h+1`` on success (the RMaxWrite
with its read elided).  No CAS, no fence — a stale ``head`` write can rewind a
queue and cause re-extraction, but each program's bound is strictly
increasing, so no *program* extracts the same slot twice.

``taken[q, s]`` is the announcement row: the extracting program writes its id
after claiming slot ``s``.  It is diagnostic (multiplicity accounting /
drills), never consulted by the extraction protocol itself.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence

import numpy as np

from .tasks import BOTTOM, TASK_WIDTH, TileTask


@dataclass
class QueueState:
    """Host-side mirror of the device queue arrays (numpy int32)."""

    tasks: np.ndarray        # [n_queues, capacity, TASK_WIDTH]
    head: np.ndarray         # [n_queues]
    tail: np.ndarray         # [n_queues]
    local_head: np.ndarray   # [n_programs, n_queues]
    taken: np.ndarray        # [n_queues, capacity], -1 = not extracted
    task_list: List[TileTask] = field(default_factory=list)

    @property
    def n_queues(self) -> int:
        return self.tasks.shape[0]

    @property
    def n_programs(self) -> int:
        return self.local_head.shape[0]

    @property
    def capacity(self) -> int:
        return self.tasks.shape[1]

    @property
    def n_tasks(self) -> int:
        return len(self.task_list)


def partition_tasks(
    tasks: Sequence[TileTask], n_queues: int, partition: str = "owner"
) -> List[List[TileTask]]:
    """Assign tasks to owner queues.

    * ``"owner"`` (alias ``"batch"``) — queue ``task.owner % n_queues``: all
      tiles of one logical owner (a sequence's batch row for attention, an
      expert for MoE dispatch) land on one queue — the natural placement and
      the one that produces the skew the thieves then erase.
    * ``"round_robin"`` — task-index striping (near-balanced baseline).
    """
    buckets: List[List[TileTask]] = [[] for _ in range(n_queues)]
    for i, t in enumerate(tasks):
        q = (t.owner if partition in ("owner", "batch") else i) % n_queues
        buckets[q].append(t)
    return buckets


def make_queue_state(
    tasks: Sequence[TileTask],
    n_programs: int,
    n_queues: int | None = None,
    partition: str = "batch",
) -> QueueState:
    """Lay tasks out in the Fig. 7 array format, ready for the megakernel.

    Slots beyond each queue's tail keep ``BOTTOM`` in field 0 — the paper's
    two-⊥-slot invariant degenerates to "the whole suffix is ⊥" because all
    Puts happen host-side before the kernel launches.
    """
    n_queues = n_programs if n_queues is None else n_queues
    buckets = partition_tasks(tasks, n_queues, partition)
    cap = max(4, max((len(b) for b in buckets), default=0) + 2)
    arr = np.full((n_queues, cap, TASK_WIDTH), BOTTOM, dtype=np.int32)
    tail = np.zeros((n_queues,), dtype=np.int32)
    for q, bucket in enumerate(buckets):
        for s, t in enumerate(bucket):
            arr[q, s] = t.encode()
        tail[q] = len(bucket)
    return QueueState(
        tasks=arr,
        head=np.zeros((n_queues,), dtype=np.int32),
        tail=tail,
        local_head=np.zeros((n_programs, n_queues), dtype=np.int32),
        taken=np.full((n_queues, cap), -1, dtype=np.int32),
        task_list=list(tasks),
    )


def queue_costs(state: QueueState) -> np.ndarray:
    """Total tile-slot cost enqueued per queue (the static-schedule load)."""
    from .tasks import F_COST, F_OP

    live = state.tasks[:, :, F_OP] != BOTTOM
    return np.where(live, state.tasks[:, :, F_COST], 0).sum(axis=1)

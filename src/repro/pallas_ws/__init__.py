"""repro.pallas_ws — device-resident fence-free work-stealing tile scheduler.

The on-device realization of the paper's WS-WMULT (Fig. 7): per-program task
queues laid out as HBM arrays (:mod:`queues`), a persistent-grid Pallas
megakernel whose programs Take from their own queue and Steal from stale
victim head views with plain loads/stores only (:mod:`kernel`), idempotent
tile tasks with a multiplicity counter that count-normalizes duplicated work
(:mod:`tasks`), ragged flash/decode attention front-ends (:mod:`ragged`),
and a host shim exercising the same layout under the repro.core property
harness (:mod:`host`).  See DESIGN.md §3.

Attribute access is lazy (PEP 562) so jax-free consumers — the
``pallas-ws`` entry in ``repro.core.ALGORITHMS`` only needs :mod:`host`,
which is pure Python — never pay the jax import.
"""

_EXPORTS = {
    "PallasWSHost": "host",
    "STEAL_POLICIES": "kernel",
    "WSRunResult": "kernel",
    "default_rounds": "kernel",
    "launch_ws_grid": "kernel",
    "run_ws_schedule": "kernel",
    "ws_account": "kernel",
    "ws_try_extract": "kernel",
    "QueueState": "queues",
    "make_pool_queue_state_jax": "queues",
    "make_queue_state": "queues",
    "make_queue_state_jax": "queues",
    "owner_queue_candidates": "queues",
    "partition_tasks": "queues",
    "queue_costs": "queues",
    "RaggedStats": "ragged",
    "ragged_attention_ref": "ragged",
    "ragged_decode_attention": "ragged",
    "ragged_decode_ref": "ragged",
    "ragged_flash_attention": "ragged",
    "BOTTOM": "tasks",
    "OP_DECODE_TILE": "tasks",
    "OP_EXPERT_TILE": "tasks",
    "OP_FLASH_TILE": "tasks",
    "TASK_FAMILIES": "tasks",
    "TASK_WIDTH": "tasks",
    "ExpertTask": "tasks",
    "TaskFamily": "tasks",
    "TileTask": "tasks",
    "emit_decode_tasks": "tasks",
    "emit_flash_tasks": "tasks",
    "family_of": "tasks",
    "multiplicity_divisor": "tasks",
    "register_family": "tasks",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    try:
        module = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}") from None
    import importlib

    return getattr(importlib.import_module(f".{module}", __name__), name)


def __dir__():
    return __all__

"""Ragged attention front-ends for the work-stealing tile scheduler.

Variable sequence lengths are where a static grid hemorrhages tile-slots:
grid size is fixed by the *padded* length, so short sequences burn slots on
dead tiles while the one long sequence serializes on a single core.  These
front-ends emit only the live tiles (host-side, where lengths are concrete),
lay them out in the Fig. 7 queue arrays partitioned by batch row — the
natural serving placement, and the worst-case imbalance — and let the
megakernel's thieves flatten the skew.

``schedule="ws"`` steals; ``schedule="static"`` drains owner queues only
(same kernel, same cost accounting — an apples-to-apples makespan baseline).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import WSRunResult, run_ws_schedule
from .queues import make_queue_state, make_queue_state_jax, owner_queue_candidates, queue_costs
from .tasks import (
    OP_DECODE_TILE,
    emit_decode_tasks,
    emit_flash_tasks,
    multiplicity_divisor,
)

SCHEDULES = ("ws", "static")


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


@dataclass
class RaggedStats:
    """Scheduling telemetry for one launch (units: kv-block tile-slots).

    ``slots_scanned``/``scan_per_extraction`` are the victim-scan traffic
    counters of DESIGN.md §3.6: task-slot probes issued by the extraction
    path, total and per successful claim."""

    schedule: str
    steal_policy: str
    n_tasks: int
    makespan: int
    total_work: int
    wasted_slots: int
    steals: int
    mult_max: int
    slots_scanned: int
    extractions: int
    scan_per_extraction: float
    queue_loads: list
    trace: object = None  # WSTrace when the launch recorded event rings

    @classmethod
    def from_run(cls, schedule, state, res: WSRunResult,
                 steal_policy: str = "cost") -> "RaggedStats":
        trace = None
        if res.events is not None:
            from repro.wstrace.trace import WSTrace

            trace = WSTrace.from_run(state, res)
        return cls(
            schedule=schedule,
            steal_policy=steal_policy,
            n_tasks=state.n_tasks,
            makespan=res.makespan,
            total_work=res.total_work,
            wasted_slots=res.wasted_slots,
            steals=int(res.steals.sum()),
            mult_max=int(res.mult[: max(1, state.n_tasks)].max()) if state.n_tasks else 0,
            slots_scanned=res.slots_scanned,
            extractions=res.extractions,
            scan_per_extraction=round(res.scan_per_extraction, 3),
            queue_loads=[int(c) for c in queue_costs(state)],
            trace=trace,
        )


def _pad_to(x, axis: int, multiple: int):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _check_drained(state, res: WSRunResult) -> None:
    if state.n_tasks and not (res.mult[: state.n_tasks] >= 1).all():
        missing = int((res.mult[: state.n_tasks] == 0).sum())
        raise RuntimeError(
            f"scheduler under-provisioned: {missing}/{state.n_tasks} tasks "
            "never executed (rounds bound too small?)"
        )


def ragged_flash_attention(
    q,
    k,
    v,
    lengths,
    *,
    causal: bool = True,
    schedule: str = "ws",
    steal_policy: str = "cost",
    steal_run_cap: int = 1,
    n_programs: int = 8,
    partition: str = "batch",
    bq: int = 32,
    bk: int = 32,
    interpret: bool = True,
    return_stats: bool = False,
    trace: bool = False,
):
    """Ragged flash attention via the persistent WS megakernel.

    q: [B, H, S, hd]; k, v: [B, Hkv, S, hd]; lengths: [B] host ints.
    Rows at or past ``lengths[b]`` return 0.  Output matches the dense
    length-masked reference exactly (up to fp32 accumulation order).
    ``trace=True`` records event rings and attaches the decoded
    :class:`~repro.wstrace.trace.WSTrace` to the returned stats.
    """
    assert schedule in SCHEDULES, schedule
    B, H, S, hd = q.shape
    lengths = np.asarray(lengths, dtype=np.int64)
    assert lengths.shape == (B,) and lengths.max(initial=0) <= S
    bq = min(bq, max(1, S))
    bk = min(bk, max(1, S))

    tasks = emit_flash_tasks(lengths, H, bq, bk, causal=causal)
    state = make_queue_state(tasks, n_programs, partition=partition)
    qp = _pad_to(q, 2, bq)
    kp = _pad_to(k, 2, bk)
    vp = _pad_to(v, 2, bk)
    res = run_ws_schedule(
        state, qp, kp, vp,
        causal=causal, bq=bq, bk=bk,
        steal=(schedule == "ws"), steal_policy=steal_policy,
        steal_run_cap=steal_run_cap if schedule == "ws" else 1,
        interpret=interpret, trace=trace,
    )
    _check_drained(state, res)
    div = multiplicity_divisor(tasks, res.mult, (B, H, qp.shape[2]))
    out = (res.out / jnp.asarray(div)[..., None])[:, :, :S].astype(q.dtype)
    if return_stats:
        return out, RaggedStats.from_run(schedule, state, res, steal_policy)
    return out


def emit_decode_tasks_jax(lengths, n_heads: int, bk: int):
    """Traced twin of :func:`repro.pallas_ws.tasks.emit_decode_tasks`: the
    full static ``[B, H]`` candidate grid with live masks ``lengths > 0``
    instead of a host loop that skips dead rows.  ``tid = b·H + h`` is
    static, so the multiplicity buffer is provisioned at ``B·H`` and dead
    slots simply stay 0.  Returns ``(records [B, H, TASK_WIDTH],
    live [B, H])`` ready for :func:`owner_queue_candidates`.
    """
    ln = jnp.asarray(lengths).astype(jnp.int32)
    B = ln.shape[0]
    H = n_heads
    cost = jnp.maximum(1, -(-ln // bk))             # kv blocks, >= 1 like host
    b_ids = jnp.arange(B, dtype=jnp.int32)[:, None]
    h_ids = jnp.arange(H, dtype=jnp.int32)[None, :]
    shape = (B, H)
    records = jnp.stack(
        [
            jnp.full(shape, OP_DECODE_TILE, jnp.int32),
            jnp.broadcast_to(b_ids, shape),
            jnp.broadcast_to(h_ids, shape),
            jnp.zeros(shape, jnp.int32),            # q_start
            jnp.ones(shape, jnp.int32),             # q_len
            jnp.broadcast_to(ln[:, None], shape),   # kv_end
            b_ids * H + h_ids,                      # tid (static, unique)
            jnp.broadcast_to(cost[:, None], shape),
        ],
        axis=-1,
    )
    live = jnp.broadcast_to(ln[:, None] > 0, shape)
    return records, live


def decode_rounds_bound(B: int, n_heads: int, S: int, bk: int,
                        n_queues: int, n_programs: int, steal: bool,
                        steal_run_cap: int = 1) -> int:
    """Static worst-case lockstep rounds for a traced decode launch (every
    slot at full cache length ``S``) — the trace-time stand-in for
    :func:`repro.pallas_ws.kernel.default_rounds` (cost unit: kv blocks).

    Stealing: Graham's ``ceil(total/P) + max_cost`` with no scan slack —
    both steal policies claim whenever work exists (DESIGN.md §3.6); with
    half-run steals the tail term grows to ``steal_run_cap · max_cost``.
    No-steal: run compression drains owners in their first idle round."""
    blocks = max(1, _cdiv(S, bk))
    if steal:
        return (_cdiv(B * n_heads * blocks, n_programs)
                + max(1, steal_run_cap) * blocks)
    from .kernel import STATIC_COMPRESSED_ROUNDS

    return STATIC_COMPRESSED_ROUNDS


def ragged_decode_attention(
    q,
    k,
    v,
    lengths,
    *,
    schedule: str = "ws",
    steal_policy: str = "cost",
    steal_run_cap: int = 1,
    n_programs: int = 8,
    partition: str = "batch",
    bk: int = 64,
    interpret: bool = True,
    return_stats: bool = False,
    trace: bool = False,
):
    """Single-token decode over ragged KV caches: q [B, H, hd] attends slots
    ``[0, lengths[b])`` of k, v [B, Hkv, S, hd].  Dead rows (length 0)
    return 0.

    Accepts traced ``lengths`` (the jitted serving decode): queue
    construction switches to the fixed-shape traced Put — the full [B, H]
    candidate grid live-masked by ``lengths > 0``, compacted on device —
    with the static worst-case rounds bound, and telemetry
    (``return_stats``) stays eager-only.
    """
    assert schedule in SCHEDULES, schedule
    B, H, hd = q.shape
    S = k.shape[2]
    bk = min(bk, max(1, S))
    steal = schedule == "ws"
    traced = isinstance(lengths, jax.core.Tracer)

    if traced:
        if return_stats:
            raise ValueError("return_stats needs concrete telemetry; call eagerly")
        if trace:
            raise ValueError("trace needs concrete event rings; call eagerly")
        n_queues = n_programs  # partition="batch": queue = b % n_programs
        records, live = emit_decode_tasks_jax(lengths, H, bk)
        cand, cand_live = owner_queue_candidates(records, live, n_queues)
        state = make_queue_state_jax(cand, cand_live, n_programs, n_tasks=B * H)
        rounds = decode_rounds_bound(
            B, H, S, bk, n_queues, n_programs, steal,
            steal_run_cap=steal_run_cap if steal else 1,
        )
        tasks = None
    else:
        lengths = np.asarray(lengths, dtype=np.int64)
        assert lengths.shape == (B,) and lengths.max(initial=0) <= S
        tasks = emit_decode_tasks(lengths, H, bk)
        state = make_queue_state(tasks, n_programs, partition=partition)
        rounds = None
    q4 = q[:, :, None, :]
    kp = _pad_to(k, 2, bk)
    vp = _pad_to(v, 2, bk)
    res = run_ws_schedule(
        state, q4, kp, vp,
        causal=False, bq=1, bk=bk,
        steal=steal, steal_policy=steal_policy,
        steal_run_cap=steal_run_cap if steal else 1, rounds=rounds,
        interpret=interpret, trace=trace,
    )
    if traced:
        # tid = b·H + h is static: the divisor is just the reshaped
        # multiplicity buffer (dead slots: mult 0 -> divisor 1, output 0)
        div = jnp.maximum(res.mult.reshape(B, H), 1).astype(jnp.float32)
        return (res.out / div[:, :, None, None])[:, :, 0].astype(q.dtype)
    _check_drained(state, res)
    div = multiplicity_divisor(tasks, res.mult, (B, H, 1))
    out = (res.out / jnp.asarray(div)[..., None])[:, :, 0].astype(q.dtype)
    if return_stats:
        return out, RaggedStats.from_run(schedule, state, res, steal_policy)
    return out


# ---------------------------------------------------------------------------
# dense oracles


def ragged_attention_ref(q, k, v, lengths, *, causal: bool = True):
    """O(S^2) length-masked reference; rows >= lengths[b] are zero."""
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    kf = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) * hd**-0.5
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    ln = jnp.asarray(np.asarray(lengths))[:, None, None, None]
    mask = (kpos < ln) & (qpos < ln)
    if causal:
        mask &= qpos >= kpos
    s = jnp.where(mask, s, -jnp.inf)
    pr = jax.nn.softmax(s, axis=-1)
    pr = jnp.where(jnp.isnan(pr), 0.0, pr)  # fully-masked rows -> 0
    out = jnp.einsum("bhqk,bhkd->bhqd", pr, vf)
    row_live = (qpos[:, 0][None, None, :, None] < ln)
    return jnp.where(row_live, out, 0.0).astype(q.dtype)


def ragged_decode_ref(q, k, v, lengths):
    """Decode oracle: q [B, H, hd] attends kv slots [0, lengths[b])."""
    B, H, hd = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = H // Hkv
    kf = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32), kf) * hd**-0.5
    kpos = jnp.arange(S)[None, None, :]
    ln = jnp.asarray(np.asarray(lengths))[:, None, None]
    s = jnp.where(kpos < ln, s, -jnp.inf)
    pr = jax.nn.softmax(s, axis=-1)
    pr = jnp.where(jnp.isnan(pr), 0.0, pr)
    out = jnp.einsum("bhs,bhsd->bhd", pr, vf)
    return jnp.where(ln > 0, out, 0.0).astype(q.dtype)

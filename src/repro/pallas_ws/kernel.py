"""Persistent-grid Pallas megakernel: fence-free work-stealing tile scheduler.

One ``pallas_call`` runs a whole tile workload.  Grid is ``(rounds,
n_programs)`` with the program dim innermost, so the execution order is
round-major: every program performs at most one Take/Steal per round, and a
program whose current task costs ``c`` tile-slots stays busy (``clock[p] >
r``) for the next ``c`` rounds.  This block-granular lockstep is the
deterministic serialization of P persistent cores running the same loop in
real time — the same modeling device as :mod:`repro.sched`'s lockstep
rounds, now *inside* one kernel over HBM-resident queue arrays.

The extraction protocol is WS-WMULT (paper Fig. 7) verbatim, on the
:mod:`repro.pallas_ws.queues` layout:

    h = max(local_head[p, v], head[v])          # inlined RMaxRead
    if tasks[v, h, OP] != ⊥:                    # lines 12-13
        head[v] = h + 1                         # plain write (RMaxWrite,
        local_head[p, v] = h + 1                #  read elided)
        taken[v, h] = p                         # announcement
        execute tile; mult[tid] += 1            # idempotent-accumulate

Plain loads and stores only — no CAS, no semaphore, no fence.  A stale
``head`` write may rewind a queue and hand the same tile to two programs;
the tile write is an *accumulate* and ``mult`` counts executions, so the
caller divides the duplicates back out (see ``tasks.multiplicity_divisor``
for attention, ``moe_ws.dispatch.row_divisor`` for expert tiles).  Each
program's ``local_head`` row is strictly increasing, so no program
re-extracts a slot it already extracted — the paper's weak multiplicity,
verified on-device by tests/test_pallas_ws.py.

Victim selection (DESIGN.md §3.6) is a *policy*, separate from the claim
protocol above, because it needs no synchronization at all — a victim chosen
from arbitrarily stale data costs at most wasted probes, never correctness:

* ``steal_policy="cost"`` (default) — O(1) task-slot loads per round.  An
  idle program probes its own queue, and on ⊥ picks the victim by one
  vectorized read of all heads/tails plus the plain-write advisory
  ``remaining[q]`` cost summary (argmax of remaining work over queues whose
  head view sits below their tail), then probes exactly one slot.  The
  advisory is updated best-effort by whoever claims a slot (plain read +
  plain write — stale values only mis-rank victims); the ``head < tail``
  mask alone guarantees an idle program claims *some* task whenever any
  queue is non-empty, which is what the tightened Graham rounds bound needs.
* ``steal_policy="scan"`` — the PR-1 p-relative sequential scan over every
  queue, kept for apples-to-apples comparison (`benchmarks/steal_policy.py`).

``scanned[p]`` counts the task-slot probes program ``p`` issued (the
op-field loads of the extraction scan; metadata vectors — head, tail,
remaining — are not slots).  Slot loads are guarded: a probe whose index is
out of range (``h >= capacity``, or ``h >= tail[v]`` on the pool layout)
never issues, so drained queues cost nothing per scan.

Everything scheduler-side is **task-family agnostic**: :func:`ws_try_extract`
(the protocol), :func:`ws_account` (clock/work/steal/multiplicity
bookkeeping), and :func:`launch_ws_grid` (queue-array plumbing around
``pallas_call``) never inspect the operand fields of a task record.  A family
plugs in by supplying an ``execute(rec, pure_refs, out_ref)`` body, where
``rec(field)`` reads one int32 field of the claimed task record — the
attention body lives here (:func:`run_ws_schedule`), the MoE expert-FFN body
in :mod:`repro.moe_ws.expert_kernel`.

Interpret mode (`interpret=True`, the CI path) executes grid cells
sequentially, which makes single-launch runs sequentially-exact (mult == 1
everywhere) — duplicates are exercised by seeding adversarial
``head``/``local_head`` snapshots, mirroring the §7 drills of the host
tests.  On real TPU the queue arrays would sit in SMEM/VMEM and task
operands would be DMA'd from HBM per task; the protocol itself is
memory-space agnostic.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..wstrace.ring import (
    EV_COST,
    EV_KIND,
    EV_MULT,
    EV_OP,
    EV_PROG,
    EV_RUN,
    EV_QUEUE,
    EV_ROUND,
    EV_SLOT,
    EV_TID,
    EV_VICTIM,
    EVENT_WIDTH,
    KIND_STEAL_COST,
    KIND_STEAL_REMOTE,
    KIND_STEAL_SCAN,
    KIND_TAKE,
)
from .queues import QueueState, queue_costs
from .tasks import (
    BOTTOM,
    F_B,
    F_COST,
    F_H,
    F_KV,
    F_OP,
    F_QL,
    F_QS,
    F_TID,
)

NEG_INF = -1e30

STEAL_POLICIES = ("cost", "scan")

# Order of the mutable (input-output aliased) queue/telemetry arrays every
# family launch carries: head, local_head, taken, remaining, clock, work,
# steals, scanned, mult, out.  ``launch_ws_grid`` owns this layout.  A
# multi-output launch (``out`` given as a tuple — the unified engine step)
# replaces the single ``out`` slot with one slot per output, and a traced
# launch (``trace=True``) appends two more — the event rings and their
# per-program cursors (``repro.wstrace.ring``) — after the outputs.
N_SCHED_MUTABLE = 9   # head..mult, before the family outputs
N_MUTABLE = 10        # the single-output layout every pre-unified caller uses


def _slot_field(tasks_ref, pool_off_ref, v, s, field, *, pool: bool):
    """Read one int32 field of the task record at queue-slot ``(v, s)``.

    Dense layout: ``tasks[v, s, field]``.  Pool layout: queue ``v``'s slots
    are the contiguous pool segment starting at ``pool_off[v]``, so the same
    logical slot lives at ``tasks[pool_off[v] + s, field]``.
    """
    if pool:
        return tasks_ref[pool_off_ref[v] + s, field]
    return tasks_ref[v, s, field]


def _probe_slot(
    tasks_ref, pool_off_ref, tail_ref, v, h, want,
    *, pool: bool, capacity: int,
):
    """Guarded ⊥-probe of slot ``(v, h)``: load the op field only when
    ``want`` and the index is meaningful — ``h < capacity`` on the dense
    layout (the clamp-read fix: a drained queue's probe never issues), and
    ``h < tail[v]`` on the pool layout (a read past tail would land in the
    *next* queue's pool segment, so it must never issue at all).

    Returns ``(op, issued)`` with ``op == BOTTOM`` when the load was
    suppressed; ``issued`` feeds the ``scanned`` slot-read counter.
    """
    in_range = (h < tail_ref[v]) if pool else (h < capacity)
    issue = want & in_range
    op = jax.lax.cond(
        issue,
        lambda: _slot_field(tasks_ref, pool_off_ref, v, h, F_OP, pool=pool),
        lambda: jnp.int32(BOTTOM),
    )
    return op, issue.astype(jnp.int32)


def ws_try_extract(
    r, p, head_ref, local_head_ref, tail_ref, remaining_ref, tasks_ref,
    clock_ref, pool_off_ref=None, stage_ref=None,
    *, n_queues: int, capacity: int, steal: bool,
    steal_policy: str = "cost", pool: bool = False, steal_run_cap: int = 1,
):
    """One Take/Steal attempt of WS-WMULT for program ``p`` at round ``r``.

    Probes its own queue first; when stealing, picks further victims by the
    configured policy and claims the first live slot with plain writes only.
    Returns ``(found, queue, slot, run, slots_read)``; no-op (found=False)
    while the program's clock says it is still busy with its previous tile.

    ``stage_ref`` (optional, [n_queues] int32): per-queue open rounds for
    stage-gated launches (the unified engine step) — a queue is invisible to
    probes and to the victim mask until ``stage_ref[q] <= r``.  Gating is a
    pure *input* (no cross-program signalling): the stage windows are sized
    on the host by the Graham bound so every task of stage ``s`` has
    finished before ``stage_ref`` opens stage ``s+1`` (DESIGN.md §5).

    ``steal_run_cap > 1`` (cost policy only) amortizes Steal probes: one
    successful victim probe claims ``min(ceil(rem/2), cap)`` *contiguous*
    slots — the half-run rule of ``mesh_ws/steal`` brought on device — with
    a single head-bump past the whole run.  ``rem = tail[v] - h`` is exact
    with respect to the tails (Put happens before launch, so tails are a
    static input); only *head* staleness can inflate it, and a stale head
    means the run's slots were already claimed once — re-executing them is
    a multiplicity event, never a correctness event (every claimed slot
    ``< tail[v]`` holds a live record by the compacted-prefix invariant, so
    the single ⊥-probe of the run's first slot certifies the whole run).
    ``run`` is 1 for Takes and for the default ``steal_run_cap=1`` lowering,
    which stays bit-identical to the per-slot claim.
    """
    assert steal_policy in STEAL_POLICIES, steal_policy
    assert steal_run_cap >= 1, steal_run_cap
    assert steal_run_cap == 1 or steal_policy == "cost", (
        "half-run claims are a cost-policy amortization"
    )
    idle = clock_ref[p] <= r
    probe = functools.partial(
        _probe_slot, tasks_ref, pool_off_ref, tail_ref,
        pool=pool, capacity=capacity,
    )

    def stage_open(v):
        return jnp.bool_(True) if stage_ref is None else stage_ref[v] <= r

    def claim_writes(v, h):
        head_ref[v] = h + 1            # plain write — no CAS
        local_head_ref[p, v] = h + 1   # persistent local bound

    def scan_extract():
        """PR-1 policy: p-relative sequential scan over every queue."""

        def scan_one(j, carry):
            found, fq, fs, nread = carry
            v = jax.lax.rem(p + j, n_queues)
            h = jnp.maximum(local_head_ref[p, v], head_ref[v])  # RMaxRead
            op, issued = probe(v, h, (~found) & stage_open(v))
            live = op != BOTTOM
            claim = (~found) & live

            @pl.when(claim)
            def _claim():
                claim_writes(v, h)

            return (
                found | live,
                jnp.where(claim, v, fq),
                jnp.where(claim, h, fs),
                nread + issued,
            )

        n_scan = n_queues if steal else 1
        zero = (jnp.bool_(False), jnp.int32(0), jnp.int32(0), jnp.int32(0))
        found, fq, fs, nread = jax.lax.fori_loop(0, n_scan, scan_one, zero)
        return found, fq, fs, jnp.int32(1), nread

    def cost_extract():
        """O(1) policy: own-queue probe, then cost-aware victim argmax."""
        own = jax.lax.rem(p, n_queues)
        h0 = jnp.maximum(local_head_ref[p, own], head_ref[own])  # RMaxRead
        op0, issued0 = probe(own, h0, stage_open(own))
        own_live = op0 != BOTTOM

        @pl.when(own_live)
        def _take():
            claim_writes(own, h0)

        if not steal:
            return own_live, own, h0, jnp.int32(1), issued0

        # Victim selection from plain vector reads — no slot loads.  The
        # `heads < tails` mask is exact for any state the protocol can
        # reach (head never passes tail), so an idle program always finds
        # a claimable victim when one exists; the advisory only *ranks*
        # the stealable queues, so arbitrary staleness costs ordering,
        # never progress (max(adv, 1) keeps zeroed advisories claimable).
        lh = local_head_ref[pl.ds(p, 1), :].reshape(n_queues)
        heads = jnp.maximum(lh, head_ref[:])
        stealable = heads < tail_ref[:]
        if stage_ref is not None:
            stealable &= stage_ref[:] <= r
        score = jnp.where(stealable, jnp.maximum(remaining_ref[:], 1), 0)
        v = jnp.argmax(score).astype(jnp.int32)
        can = (~own_live) & (jnp.max(score) > 0)
        h = heads[v]
        op, issued = probe(v, h, can)
        live = can & (op != BOTTOM)

        if steal_run_cap == 1:
            @pl.when(live)
            def _steal():
                claim_writes(v, h)

            take = jnp.int32(1)
        else:
            # Half-run claim: bump the head past ceil(rem/2) slots (capped)
            # in one plain write per bound.  `rem >= 1` whenever `live`
            # (the victim passed the `heads < tails` mask), and every slot
            # of [h, h + take) is below tail[v], so the run is made of live
            # records certified by the single probe above.
            rem = tail_ref[v] - h
            take = jnp.clip((rem + 1) // 2, 1, steal_run_cap).astype(jnp.int32)

            @pl.when(live)
            def _steal():
                head_ref[v] = h + take           # plain write — no CAS
                local_head_ref[p, v] = h + take  # persistent local bound

        found = own_live | live
        fq = jnp.where(own_live, own, v)
        fs = jnp.where(own_live, h0, h)
        run = jnp.where(live, take, 1).astype(jnp.int32)
        return found, fq, fs, run, issued0 + issued

    zero = (jnp.bool_(False), jnp.int32(0), jnp.int32(0), jnp.int32(1),
            jnp.int32(0))
    body = scan_extract if steal_policy == "scan" else cost_extract
    return jax.lax.cond(idle, body, lambda: zero)


def ws_account(
    r, p, fq, fs, tid, cost,
    taken_ref, remaining_ref, clock_ref, work_ref, steals_ref, mult_ref,
    pool_off_ref=None,
    *, n_queues: int, pool: bool = False, advisory: bool = True,
):
    """Post-execution bookkeeping shared by every task family: announcement
    row, multiplicity counter, work/steal telemetry, lockstep clock bump,
    and the best-effort advisory decrement (plain read + plain write — a
    lost or stale update mis-ranks future victims, nothing more).

    ``advisory=False`` suppresses the per-extraction advisory write so a
    caller that drains a whole run inside one grid cell (round compression)
    can coalesce the updates into one plain write for the run — the clamp
    commutes (``max(max(r-c1,0)-c2,0) == max(r-c1-c2,0)`` for nonnegative
    costs), so the coalesced value is bit-identical."""
    mult_ref[tid] = mult_ref[tid] + 1
    if pool:
        taken_ref[pool_off_ref[fq] + fs] = p
    else:
        taken_ref[fq, fs] = p
    if advisory:
        remaining_ref[fq] = jnp.maximum(remaining_ref[fq] - cost, 0)
    work_ref[p] = work_ref[p] + cost
    own = jax.lax.rem(p, n_queues)
    steals_ref[p] = steals_ref[p] + jnp.where(fq != own, 1, 0)
    clock_ref[p] = jnp.maximum(clock_ref[p], r) + cost


def _generic_ws_kernel(
    *refs,
    execute: Callable,
    n_pure: int,
    n_queues: int,
    capacity: int,
    steal: bool,
    steal_policy: str,
    pool: bool,
    compress: bool,
    steal_run_cap: int = 1,
    n_outs: int = 1,
    multi_out: bool = False,
    staged: bool = False,
    trace: bool = False,
    trace_capacity: int = 0,
    steal_kind: int = KIND_STEAL_COST,
):
    """Scheduler shell around a family ``execute`` body.

    Ref layout (positional, fixed by :func:`launch_ws_grid`): the mutable
    stale input snapshots (9 scheduler arrays + ``n_outs`` outputs, +2 when
    ``trace``), the tasks array, the (static) tails, the pool segment
    offsets when ``pool``, the stage-open rounds when ``staged``, ``n_pure``
    family inputs, then the live (aliased) output refs in the same order as
    the snapshots.

    ``multi_out`` launches call ``execute(rec, pure, outs, mult_ref)`` with
    the tuple of output refs plus the live multiplicity counters (the
    unified step's glue phases normalize accumulators in-kernel); the
    single-output convention stays ``execute(rec, pure, out_ref)``.
    """
    n_live = N_SCHED_MUTABLE + n_outs
    n_mut = n_live + (2 if trace else 0)
    tasks_ref = refs[n_mut]
    tail_ref = refs[n_mut + 1]
    off = n_mut + 2
    pool_off_ref = refs[off] if pool else None
    off += int(pool)
    stage_ref = refs[off] if staged else None
    off += int(staged)
    pure = refs[off: off + n_pure]
    live = refs[off + n_pure:]
    (head_ref, local_head_ref, taken_ref, remaining_ref, clock_ref, work_ref,
     steals_ref, scanned_ref, mult_ref) = live[:N_SCHED_MUTABLE]
    out_refs = live[N_SCHED_MUTABLE:n_live]
    out_ref = out_refs if multi_out else out_refs[0]
    ev_ref, ev_cursor_ref = live[n_live:] if trace else (None, None)

    r = pl.program_id(0)
    p = pl.program_id(1)

    def trace_append(fq, fs, tid, cost, t0, op, run):
        """Append one extraction record to program ``p``'s event ring —
        plain stores only (guarded slot writes + a plain cursor bump), so
        the traced lowering stays inside the fence-free audit.  The ring
        never wraps: on overflow the record is *dropped* but the cursor
        keeps counting, so the host knows exactly how many were lost."""
        own = jax.lax.rem(p, n_queues)
        is_steal = fq != own
        if steal_kind == KIND_STEAL_REMOTE:
            # remote-segment launches (mesh_ws phase 2b): every claim works
            # a stolen segment, own-queue probes included
            kind = jnp.int32(KIND_STEAL_REMOTE)
        else:
            kind = jnp.where(is_steal, steal_kind, KIND_TAKE).astype(jnp.int32)
        nprog = pl.num_programs(1)
        victim = jnp.where(is_steal & (fq < nprog), fq, -1).astype(jnp.int32)
        c = ev_cursor_ref[p]

        @pl.when(c < trace_capacity)
        def _append():
            ev_ref[p, c, EV_ROUND] = t0
            ev_ref[p, c, EV_PROG] = p
            ev_ref[p, c, EV_QUEUE] = fq
            ev_ref[p, c, EV_SLOT] = fs
            ev_ref[p, c, EV_TID] = tid
            ev_ref[p, c, EV_COST] = cost
            ev_ref[p, c, EV_KIND] = kind
            ev_ref[p, c, EV_VICTIM] = victim
            ev_ref[p, c, EV_MULT] = mult_ref[tid]
            ev_ref[p, c, EV_OP] = op
            ev_ref[p, c, EV_RUN] = run

        ev_cursor_ref[p] = c + 1

    def account(fq, fs, advisory=True, run=1):
        rec = functools.partial(
            _slot_field, tasks_ref, pool_off_ref, fq, fs, pool=pool
        )
        if trace:
            # virtual start of this execution — read before ws_account bumps
            # the lockstep clock, so the event's [t0, t0 + cost) interval is
            # the tile-slots the program is busy (also correct inside a
            # compressed drain run, where the clock advances per extraction)
            t0 = jnp.maximum(clock_ref[p], r)
        if multi_out:
            execute(rec, pure, out_ref, mult_ref)
        else:
            execute(rec, pure, out_ref)
        ws_account(
            r, p, fq, fs, rec(F_TID), rec(F_COST),
            taken_ref, remaining_ref, clock_ref, work_ref, steals_ref,
            mult_ref, pool_off_ref, n_queues=n_queues, pool=pool,
            advisory=advisory,
        )
        if trace:
            trace_append(fq, fs, rec(F_TID), rec(F_COST), t0, rec(F_OP), run)
        return rec(F_COST)

    if compress:
        # Round compression (DESIGN.md §3.6): with no thieves there is no
        # inter-round interleaving to model, so an idle owner drains its
        # whole queue as one run of consecutive Takes inside a single grid
        # cell — the clock still charges every tile-slot (identical
        # makespan/work telemetry to the per-round drain), but the grid
        # needs O(1) rounds instead of max-queue-cost rounds.
        assert not steal, "run compression models the no-steal schedule only"
        assert not staged, "stage gating needs the per-round lockstep"
        own = jax.lax.rem(p, n_queues)

        def probe_own():
            h = jnp.maximum(local_head_ref[p, own], head_ref[own])
            op, issued = _probe_slot(
                tasks_ref, pool_off_ref, tail_ref, own, h, jnp.bool_(True),
                pool=pool, capacity=capacity,
            )
            scanned_ref[p] = scanned_ref[p] + issued
            return op != BOTTOM, h

        @pl.when(clock_ref[p] <= r)
        def _drain_run():
            def cond(carry):
                return carry[0]

            def body(carry):
                _, h, acc = carry
                head_ref[own] = h + 1
                local_head_ref[p, own] = h + 1
                cost = account(own, h, advisory=False)
                live, nh = probe_own()
                return live, nh, acc + cost

            live0, h0 = probe_own()
            _, _, total = jax.lax.while_loop(
                cond, body, (live0, h0, jnp.int32(0))
            )
            # amortized synchronization (ROADMAP): ONE plain advisory write
            # for the whole drained run instead of one per extraction —
            # bit-identical to the sequential clamps since the run's costs
            # are nonnegative, and guarded so an empty run writes nothing
            # (exactly like zero per-extraction writes).
            @pl.when(total > 0)
            def _advise():
                remaining_ref[own] = jnp.maximum(remaining_ref[own] - total, 0)

        return

    found, fq, fs, run, nread = ws_try_extract(
        r, p, head_ref, local_head_ref, tail_ref, remaining_ref, tasks_ref,
        clock_ref, pool_off_ref, stage_ref,
        n_queues=n_queues, capacity=capacity, steal=steal,
        steal_policy=steal_policy, pool=pool, steal_run_cap=steal_run_cap,
    )
    scanned_ref[p] = scanned_ref[p] + nread

    if steal_run_cap == 1:
        @pl.when(found)
        def _execute():
            account(fq, fs)
    else:
        # Half-run execution (amortized synchronization, DESIGN.md §3.6):
        # the claim above already bumped the head past the whole run, so
        # execute its `run` consecutive slots back-to-back inside this grid
        # cell — per-slot events/counters keep the trace and multiplicity
        # semantics of per-slot claims, while the advisory decrement
        # coalesces into ONE plain write for the run (bit-identical to the
        # sequential clamps: costs are nonnegative, so the clamp commutes).
        @pl.when(found)
        def _execute_run():
            def body(i, total):
                return total + account(fq, fs + i, advisory=False, run=run)

            total = jax.lax.fori_loop(0, run, body, jnp.int32(0))
            remaining_ref[fq] = jnp.maximum(remaining_ref[fq] - total, 0)


@dataclass
class WSRunResult:
    """Post-launch queue/telemetry arrays.  Host numpy on eager launches;
    jax values (tracers) when the launch itself is being traced — the
    scalar properties below are host-only conveniences."""

    out: jax.Array          # family output, mult-weighted accumulation
    head: np.ndarray        # final shared heads            [n_queues]
    local_head: np.ndarray  # final per-program bounds      [n_programs, n_queues]
    taken: np.ndarray       # announcement rows             [n_queues, capacity]
                            #   (flat [capacity] on the pool layout)
    remaining: np.ndarray   # final advisory cost summaries [n_queues]
    clock: np.ndarray       # per-program completion time   [n_programs]
    work: np.ndarray        # tile-slots executed           [n_programs]
    steals: np.ndarray      # successful cross-queue grabs  [n_programs]
    scanned: np.ndarray     # task-slot probes issued       [n_programs]
    mult: np.ndarray        # per-task execution counts     [n_tasks]
    # event rings (trace=True launches only; None otherwise) — see
    # repro.wstrace.ring for the record schema and decode
    events: Optional[np.ndarray] = None     # [n_programs, cap, EVENT_WIDTH]
    ev_cursor: Optional[np.ndarray] = None  # [n_programs] appends attempted

    @property
    def makespan(self) -> int:
        return int(self.clock.max()) if self.clock.size else 0

    @property
    def total_work(self) -> int:
        return int(self.work.sum())

    @property
    def wasted_slots(self) -> int:
        """Idle tile-slots: programs waiting while the slowest one finishes."""
        return len(self.work) * self.makespan - self.total_work

    @property
    def slots_scanned(self) -> int:
        """Task-slot probes issued across the launch (scan traffic)."""
        return int(self.scanned.sum())

    @property
    def extractions(self) -> int:
        """Successful claims.  Exact for launches that started with a fresh
        multiplicity buffer (every claim bumps one counter)."""
        return int(self.mult.sum())

    @property
    def scan_per_extraction(self) -> float:
        """Slots read per successful extraction — the victim-scan overhead
        the cost policy exists to collapse."""
        return self.slots_scanned / max(1, self.extractions)

    @property
    def steal_ratio(self) -> float:
        """Fraction of extractions that were cross-queue steals (exact for
        launches that started with a fresh multiplicity buffer)."""
        return int(self.steals.sum()) / max(1, self.extractions)

    @property
    def per_queue_drained(self) -> np.ndarray:
        """Distinct slots claimed per queue.  Exact on the dense layout
        (one announcement row per queue); on the flat pool layout the
        announcement rows don't carry queue boundaries, so the final head
        watermark stands in (identical for completed drains)."""
        if self.taken.ndim == 2:
            return (np.asarray(self.taken) >= 0).sum(axis=1)
        return np.asarray(self.head).copy()


# Rounds the compressed no-steal drain needs: every owner empties its queue
# in its first idle grid cell; one slack round keeps the bound visibly safe
# for resumed states.
STATIC_COMPRESSED_ROUNDS = 2


def default_rounds(state: QueueState, steal: bool,
                   compress_runs: Optional[bool] = None,
                   steal_run_cap: int = 1) -> int:
    """Static upper bound on rounds to drain every queue (DESIGN.md §3.6).

    Stealing: Graham's greedy bound ``ceil(total/P) + max_cost`` — exact for
    this lockstep model because an idle program *always* claims a task when
    any queue is non-empty (the scan policy probes every queue; the cost
    policy's ``head < tail`` victim mask is exact), so no extra slack is
    needed.  With half-run steals (``steal_run_cap > 1``) the last claim can
    pull up to ``steal_run_cap`` slots at once, so the tail term grows to
    ``steal_run_cap * max_cost``.  No-steal: run compression drains each
    owner's queue in its first idle round, so the bound is O(1); without
    compression the heaviest queue runs alone (``max queue cost`` rounds).

    Needs concrete queue contents — trace-built states must pass an explicit
    static worst-case ``rounds`` to the launch (the grid size cannot depend
    on traced values).
    """
    if isinstance(state.tasks, jax.core.Tracer):
        raise ValueError(
            "rounds must be given explicitly for a trace-built QueueState: "
            "the grid is static, so use the family's worst-case bound "
            "(e.g. moe_ws.dispatch.expert_rounds_bound)"
        )
    compress = (not steal) if compress_runs is None else compress_runs
    costs = queue_costs(state)
    total = int(costs.sum())
    if total == 0:
        return 1
    from .tasks import max_cost

    mc = max_cost(state.task_list) if state.task_list else int(costs.max())
    if steal:
        return -(-total // state.n_programs) + max(1, steal_run_cap) * mc
    if compress:
        return STATIC_COMPRESSED_ROUNDS
    return int(costs.max())


def launch_ws_grid(
    state: QueueState,
    execute: Callable,
    pure: Sequence[jax.Array],
    out,
    *,
    steal: bool = True,
    steal_policy: str = "cost",
    steal_run_cap: int = 1,
    rounds: Optional[int] = None,
    mult: Optional[jax.Array] = None,
    compress_runs: Optional[bool] = None,
    stage_open: Optional[jax.Array] = None,
    interpret: bool = True,
    trace: bool = False,
    trace_capacity: Optional[int] = None,
    trace_remote: bool = False,
    fault_plan=None,
) -> WSRunResult:
    """Run the persistent WS grid with a family ``execute`` body.

    ``execute(rec, pure_refs, out_ref)`` performs the claimed tile —
    ``rec(field)`` reads one field of its task record — and *accumulates*
    into ``out_ref``; the shell handles extraction and bookkeeping.
    ``out``/``mult`` may be carried over from a previous launch (resume /
    multiplicity drills).  ``compress_runs`` defaults to ``not steal``:
    no-steal launches drain whole owner runs per grid cell (§3.6), steal
    launches keep the one-extraction-per-round lockstep so thief
    concurrency stays faithfully modeled.

    ``out`` may be a *tuple* of arrays (the unified engine step's caches,
    activation buffers, routing scratch, logits).  The shell then calls
    ``execute(rec, pure_refs, out_refs, mult_ref)`` — the tuple of live
    output refs plus the multiplicity counters, so mixed-family bodies can
    normalize accumulators in-kernel — and ``WSRunResult.out`` is the tuple
    in the same order.  ``stage_open`` ([n_queues] int32, optional) gates
    extraction per queue by round (see :func:`ws_try_extract`): the
    mixed-mode launch encodes its inter-stage dependencies as host-computed
    open rounds instead of device-side waiting, keeping the lowering free
    of fences.

    ``trace=True`` additionally records every extraction into per-program
    event rings (``WSRunResult.events``/``ev_cursor``; schema in
    :mod:`repro.wstrace.ring`) with plain stores only.  The default ring
    capacity is the static per-program claim bound — ``rounds`` for
    lockstep launches (one claim per round), the queue capacity for
    compressed drains — so nothing drops unless ``trace_capacity``
    deliberately shrinks the ring.  ``trace_remote`` tags every event
    ``steal-remote`` (mesh_ws stolen-segment launches).  ``trace=False``
    (the default) adds no refs and no kernel code: the lowering is
    bit-identical to the untraced build.

    ``steal_run_cap`` (cost policy, steal launches) caps the half-run Steal:
    one successful probe claims ``min(ceil(rem/2), cap)`` contiguous victim
    slots and executes them back-to-back in the claiming grid cell, with ONE
    coalesced advisory write per run (see :func:`ws_try_extract`).  The
    default ``1`` lowers bit-identically to the per-slot claim; ``> 1`` is
    incompatible with ``stage_open`` (the Graham stage windows assume
    per-slot claims) and with ``compress_runs``.  The Graham rounds bound
    and the default trace-ring capacity gain a ``cap`` slack term.

    ``fault_plan`` (a :class:`repro.chaos.FaultPlan`, optional) injects
    the plan's *launch-time* faults as initial array values only: program
    stalls become nonzero initial ``clock`` entries (a stalled program is
    "busy" until its stall round and extracts nothing before then) and
    advisory corruption replaces the initial ``remaining`` summaries.  No
    kernel code changes — ``fault_plan=None`` is the identical lowering,
    the same zero-cost bar as ``trace=False``.  When ``rounds`` is not
    given, the Graham default is extended by the maximum stall so stalled
    schedules still drain.  Cross-launch faults (storms, kills) live in
    :func:`repro.chaos.inject.run_with_faults`.
    """
    assert steal_policy in STEAL_POLICIES, steal_policy
    P = state.n_programs
    compress = (not steal) if compress_runs is None else compress_runs
    if compress and steal:
        raise ValueError("compress_runs models the no-steal schedule only")
    if stage_open is not None and compress:
        raise ValueError("stage_open needs the per-round lockstep "
                         "(compress_runs=False)")
    if steal_run_cap < 1:
        raise ValueError(f"steal_run_cap must be >= 1, got {steal_run_cap}")
    if steal_run_cap > 1:
        if not steal or steal_policy != "cost":
            raise ValueError("steal_run_cap > 1 amortizes cost-policy "
                             "steals — needs steal=True, steal_policy='cost'")
        if stage_open is not None:
            raise ValueError("steal_run_cap > 1 breaks the per-slot-claim "
                             "assumption of stage_open's Graham windows")
    multi_out = isinstance(out, (tuple, list))
    outs_in = tuple(out) if multi_out else (out,)
    rounds_given = rounds is not None
    rounds = (
        default_rounds(state, steal, compress_runs=compress,
                       steal_run_cap=steal_run_cap)
        if rounds is None else rounds
    )
    n_tasks = max(1, state.n_tasks)
    mult = jnp.zeros((n_tasks,), jnp.int32) if mult is None else mult
    pool = state.pool_off is not None
    remaining = state.remaining
    if remaining is None:
        remaining = queue_costs(state)
    clock0 = jnp.zeros((P,), jnp.int32)
    if fault_plan is not None:
        # chaos injection is pure data: a stalled program is a nonzero
        # initial clock, a stale advisory is a different initial value —
        # the lowering is the fault_plan=None build either way
        remaining = fault_plan.launch_remaining(remaining)
        if fault_plan.max_stall:
            clock0 = jnp.asarray(fault_plan.stall_vector(P), jnp.int32)
            if not rounds_given:
                rounds += fault_plan.max_stall
    if trace_capacity is None:
        # per-program events <= rounds for per-slot claims; a run of n slots
        # keeps its program busy >= n rounds, so runs only shift the bound
        # by the last (possibly cap-long) run: rounds + cap - 1.
        trace_capacity = (
            state.capacity if compress else rounds + steal_run_cap - 1
        )
    steal_kind = (
        KIND_STEAL_REMOTE if trace_remote
        else (KIND_STEAL_SCAN if steal_policy == "scan" else KIND_STEAL_COST)
    )

    kernel = functools.partial(
        _generic_ws_kernel,
        execute=execute,
        n_pure=len(pure),
        n_queues=state.n_queues,
        capacity=state.capacity,
        steal=steal,
        steal_policy=steal_policy,
        pool=pool,
        compress=compress,
        steal_run_cap=steal_run_cap,
        n_outs=len(outs_in),
        multi_out=multi_out,
        staged=stage_open is not None,
        trace=trace,
        trace_capacity=trace_capacity,
        steal_kind=steal_kind,
    )

    def full(a):
        return pl.BlockSpec(a.shape, lambda r, p, nd=a.ndim: (0,) * nd)

    mutable = [
        jnp.asarray(state.head),
        jnp.asarray(state.local_head),
        jnp.asarray(state.taken),
        jnp.asarray(remaining, dtype=jnp.int32),
        clock0,                       # clock (stall faults start nonzero)
        jnp.zeros((P,), jnp.int32),   # work
        jnp.zeros((P,), jnp.int32),   # steals
        jnp.zeros((P,), jnp.int32),   # scanned
        jnp.asarray(mult),
    ] + [jnp.asarray(o) for o in outs_in]
    if trace:
        mutable += [
            jnp.full((P, trace_capacity, EVENT_WIDTH), -1, jnp.int32),
            jnp.zeros((P,), jnp.int32),  # event cursors
        ]
    pure_arrays = [jnp.asarray(state.tasks), jnp.asarray(state.tail)]
    if pool:
        pure_arrays.append(jnp.asarray(state.pool_off))
    if stage_open is not None:
        pure_arrays.append(jnp.asarray(stage_open, dtype=jnp.int32))
    pure_arrays += [jnp.asarray(a) for a in pure]
    outs = pl.pallas_call(
        kernel,
        grid=(rounds, P),
        in_specs=[full(a) for a in mutable] + [full(a) for a in pure_arrays],
        out_specs=[full(a) for a in mutable],
        out_shape=[jax.ShapeDtypeStruct(a.shape, a.dtype) for a in mutable],
        input_output_aliases={i: i for i in range(len(mutable))},
        interpret=interpret,
    )(*mutable, *pure_arrays)
    n_live = N_SCHED_MUTABLE + len(outs_in)
    (head, local_head, taken, remaining, clock, work, steals, scanned,
     mult) = outs[:N_SCHED_MUTABLE]
    out = (
        tuple(outs[N_SCHED_MUTABLE:n_live]) if multi_out
        else outs[N_SCHED_MUTABLE]
    )
    events, ev_cursor = outs[n_live:] if trace else (None, None)

    def host(a):
        # eager launches hand numpy views back to the drills/telemetry;
        # traced launches keep the jax values (np.asarray would throw)
        if a is None or isinstance(a, jax.core.Tracer):
            return a
        return np.asarray(a)

    return WSRunResult(
        out=out,
        head=host(head),
        local_head=host(local_head),
        taken=host(taken),
        remaining=host(remaining),
        clock=host(clock),
        work=host(work),
        steals=host(steals),
        scanned=host(scanned),
        mult=host(mult),
        events=host(events),
        ev_cursor=host(ev_cursor),
    )


# ---------------------------------------------------------------------------
# attention family: flash/decode tile body


def _attention_execute(
    rec, pure, out_ref,
    *, bq: int, bk: int, causal: bool, scale: float, g: int,
):
    """Flash-attention tile: online-softmax sweep of the task's kv range,
    accumulated into the task's disjoint q-block rows."""
    q_ref, k_ref, v_ref = pure
    b = rec(F_B)
    h = rec(F_H)
    qs = rec(F_QS)
    ql = rec(F_QL)
    kv_end = rec(F_KV)
    cost = rec(F_COST)
    kh = jax.lax.div(h, g)

    qt = q_ref[pl.ds(b, 1), pl.ds(h, 1), pl.ds(qs, bq), :]
    qt = qt.reshape(bq, q_ref.shape[-1]).astype(jnp.float32)

    def kv_block(ki, mla):
        m, l, acc = mla
        kt = k_ref[pl.ds(b, 1), pl.ds(kh, 1), pl.ds(ki * bk, bk), :]
        vt = v_ref[pl.ds(b, 1), pl.ds(kh, 1), pl.ds(ki * bk, bk), :]
        kt = kt.reshape(bk, -1).astype(jnp.float32)
        vt = vt.reshape(bk, -1).astype(jnp.float32)
        s = jax.lax.dot_general(
            qt, kt, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # [bq, bk]
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = kpos < kv_end
        if causal:
            qpos = qs + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            valid &= kpos <= qpos
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        pexp = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + pexp.sum(axis=1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            pexp, vt, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new)

    hd = q_ref.shape[-1]
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, hd), jnp.float32)
    # Dynamic trip count: a real persistent core sweeps only the live
    # blocks — this is exactly the cost the work counters account.
    m, l, acc = jax.lax.fori_loop(0, cost, kv_block, (m0, l0, a0))

    tile = acc / jnp.maximum(l, 1e-30)[:, None]
    row_live = jax.lax.broadcasted_iota(jnp.int32, (bq, hd), 0) < ql
    tile = jnp.where(row_live, tile, 0.0)

    # Idempotent-accumulate: duplicates add whole extra copies of the
    # same tile, which mult[tid] normalizes out host-side.
    cur = out_ref[pl.ds(b, 1), pl.ds(h, 1), pl.ds(qs, bq), :]
    out_ref[pl.ds(b, 1), pl.ds(h, 1), pl.ds(qs, bq), :] = cur + tile[None, None]


def run_ws_schedule(
    state: QueueState,
    q,
    k,
    v,
    *,
    causal: bool,
    bq: int,
    bk: int,
    steal: bool = True,
    steal_policy: str = "cost",
    steal_run_cap: int = 1,
    rounds: Optional[int] = None,
    out: Optional[jax.Array] = None,
    mult: Optional[jax.Array] = None,
    compress_runs: Optional[bool] = None,
    interpret: bool = True,
    trace: bool = False,
    trace_capacity: Optional[int] = None,
    fault_plan=None,
) -> WSRunResult:
    """Launch the attention megakernel over a prepared :class:`QueueState`.

    ``q``: [B, H, Sq, hd] with Sq a multiple of ``bq``; ``k``/``v``:
    [B, Hkv, Sk, hd] with Sk a multiple of ``bk``.  ``out``/``mult`` may be
    carried over from a previous launch (resume / multiplicity drills);
    fresh zeros otherwise.  ``trace=True`` records per-extraction event
    rings (see :func:`launch_ws_grid`).
    """
    B, H, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    assert Sq % bq == 0, (Sq, bq)
    assert Sk % bk == 0, (Sk, bk)
    g = H // Hkv
    out = jnp.zeros((B, H, Sq, hd), jnp.float32) if out is None else out
    execute = functools.partial(
        _attention_execute, bq=bq, bk=bk, causal=causal, scale=hd**-0.5, g=g
    )
    return launch_ws_grid(
        state, execute, (q, k, v), out,
        steal=steal, steal_policy=steal_policy, steal_run_cap=steal_run_cap,
        rounds=rounds, mult=mult,
        compress_runs=compress_runs, interpret=interpret,
        trace=trace, trace_capacity=trace_capacity, fault_plan=fault_plan,
    )

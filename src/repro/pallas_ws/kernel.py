"""Persistent-grid Pallas megakernel: fence-free work-stealing tile scheduler.

One ``pallas_call`` runs a whole tile workload.  Grid is ``(rounds,
n_programs)`` with the program dim innermost, so the execution order is
round-major: every program performs at most one Take/Steal per round, and a
program whose current task costs ``c`` tile-slots stays busy (``clock[p] >
r``) for the next ``c`` rounds.  This block-granular lockstep is the
deterministic serialization of P persistent cores running the same loop in
real time — the same modeling device as :mod:`repro.sched`'s lockstep
rounds, now *inside* one kernel over HBM-resident queue arrays.

The extraction protocol is WS-WMULT (paper Fig. 7) verbatim, on the
:mod:`repro.pallas_ws.queues` layout:

    h = max(local_head[p, v], head[v])          # inlined RMaxRead
    if tasks[v, h, OP] != ⊥:                    # lines 12-13
        head[v] = h + 1                         # plain write (RMaxWrite,
        local_head[p, v] = h + 1                #  read elided)
        taken[v, h] = p                         # announcement
        execute tile; mult[tid] += 1            # idempotent-accumulate

Plain loads and stores only — no CAS, no semaphore, no fence.  A stale
``head`` write may rewind a queue and hand the same tile to two programs;
the tile write is an *accumulate* and ``mult`` counts executions, so the
caller divides the duplicates back out (see ``tasks.multiplicity_divisor``
for attention, ``moe_ws.dispatch.row_divisor`` for expert tiles).  Each
program's ``local_head`` row is strictly increasing, so no program
re-extracts a slot it already extracted — the paper's weak multiplicity,
verified on-device by tests/test_pallas_ws.py.

Everything scheduler-side is **task-family agnostic**: :func:`ws_try_extract`
(the protocol), :func:`ws_account` (clock/work/steal/multiplicity
bookkeeping), and :func:`launch_ws_grid` (queue-array plumbing around
``pallas_call``) never inspect the operand fields of a task record.  A family
plugs in by supplying an ``execute(tasks_ref, fq, fs, pure_refs, out_ref)``
body — the attention body lives here (:func:`run_ws_schedule`), the MoE
expert-FFN body in :mod:`repro.moe_ws.expert_kernel`.

Interpret mode (`interpret=True`, the CI path) executes grid cells
sequentially, which makes single-launch runs sequentially-exact (mult == 1
everywhere) — duplicates are exercised by seeding adversarial
``head``/``local_head`` snapshots, mirroring the §7 drills of the host
tests.  On real TPU the queue arrays would sit in SMEM/VMEM and task
operands would be DMA'd from HBM per task; the protocol itself is
memory-space agnostic.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .queues import QueueState, queue_costs
from .tasks import (
    BOTTOM,
    F_B,
    F_COST,
    F_H,
    F_KV,
    F_OP,
    F_QL,
    F_QS,
    F_TID,
)

NEG_INF = -1e30

# Order of the mutable (input-output aliased) queue/telemetry arrays every
# family launch carries: head, local_head, taken, clock, work, steals, mult,
# out.  ``launch_ws_grid`` owns this layout.
N_MUTABLE = 8


def ws_try_extract(
    r, p, head_ref, local_head_ref, tasks_ref, clock_ref,
    *, n_queues: int, capacity: int, steal: bool,
):
    """One Take/Steal attempt of WS-WMULT for program ``p`` at round ``r``.

    Scans its own queue first, then (when stealing) every victim in
    p-relative order, claiming the first live slot with plain writes only.
    Returns ``(found, queue, slot)``; no-op (found=False) while the
    program's clock says it is still busy with its previous tile.
    """
    idle = clock_ref[p] <= r

    def scan_one(j, carry):
        found, fq, fs = carry
        v = jax.lax.rem(p + j, n_queues)
        h = jnp.maximum(local_head_ref[p, v], head_ref[v])  # RMaxRead
        hc = jnp.minimum(h, capacity - 1)
        op = tasks_ref[v, hc, F_OP]
        live = (h < capacity) & (op != BOTTOM)
        claim = (~found) & live

        @pl.when(claim)
        def _claim():
            head_ref[v] = h + 1            # plain write — no CAS
            local_head_ref[p, v] = h + 1   # persistent local bound

        return (found | live, jnp.where(claim, v, fq), jnp.where(claim, hc, fs))

    n_scan = n_queues if steal else 1
    zero = (jnp.bool_(False), jnp.int32(0), jnp.int32(0))
    return jax.lax.cond(
        idle,
        lambda: jax.lax.fori_loop(0, n_scan, scan_one, zero),
        lambda: zero,
    )


def ws_account(
    r, p, fq, fs, tid, cost,
    taken_ref, clock_ref, work_ref, steals_ref, mult_ref,
    *, n_queues: int,
):
    """Post-execution bookkeeping shared by every task family: announcement
    row, multiplicity counter, work/steal telemetry, lockstep clock bump."""
    mult_ref[tid] = mult_ref[tid] + 1
    taken_ref[fq, fs] = p
    work_ref[p] = work_ref[p] + cost
    own = jax.lax.rem(p, n_queues)
    steals_ref[p] = steals_ref[p] + jnp.where(fq != own, 1, 0)
    clock_ref[p] = jnp.maximum(clock_ref[p], r) + cost


def _generic_ws_kernel(
    *refs,
    execute: Callable,
    n_pure: int,
    n_queues: int,
    capacity: int,
    steal: bool,
):
    """Scheduler shell around a family ``execute`` body.

    Ref layout (positional, fixed by :func:`launch_ws_grid`): N_MUTABLE stale
    input snapshots, the tasks array, ``n_pure`` family inputs, then the
    N_MUTABLE live (aliased) output refs.
    """
    tasks_ref = refs[N_MUTABLE]
    pure = refs[N_MUTABLE + 1: N_MUTABLE + 1 + n_pure]
    (head_ref, local_head_ref, taken_ref, clock_ref, work_ref, steals_ref,
     mult_ref, out_ref) = refs[N_MUTABLE + 1 + n_pure:]

    r = pl.program_id(0)
    p = pl.program_id(1)
    found, fq, fs = ws_try_extract(
        r, p, head_ref, local_head_ref, tasks_ref, clock_ref,
        n_queues=n_queues, capacity=capacity, steal=steal,
    )

    @pl.when(found)
    def _execute():
        execute(tasks_ref, fq, fs, pure, out_ref)
        ws_account(
            r, p, fq, fs, tasks_ref[fq, fs, F_TID], tasks_ref[fq, fs, F_COST],
            taken_ref, clock_ref, work_ref, steals_ref, mult_ref,
            n_queues=n_queues,
        )


@dataclass
class WSRunResult:
    """Post-launch queue/telemetry arrays.  Host numpy on eager launches;
    jax values (tracers) when the launch itself is being traced — the
    scalar properties below are host-only conveniences."""

    out: jax.Array          # family output, mult-weighted accumulation
    head: np.ndarray        # final shared heads            [n_queues]
    local_head: np.ndarray  # final per-program bounds      [n_programs, n_queues]
    taken: np.ndarray       # announcement rows             [n_queues, capacity]
    clock: np.ndarray       # per-program completion time   [n_programs]
    work: np.ndarray        # tile-slots executed           [n_programs]
    steals: np.ndarray      # successful cross-queue grabs  [n_programs]
    mult: np.ndarray        # per-task execution counts     [n_tasks]

    @property
    def makespan(self) -> int:
        return int(self.clock.max()) if self.clock.size else 0

    @property
    def total_work(self) -> int:
        return int(self.work.sum())

    @property
    def wasted_slots(self) -> int:
        """Idle tile-slots: programs waiting while the slowest one finishes."""
        return len(self.work) * self.makespan - self.total_work


def default_rounds(state: QueueState, steal: bool) -> int:
    """Static upper bound on rounds to drain every queue.

    Stealing: Graham's greedy bound ``total/P + max_cost`` (no program idles
    while any queue is non-empty).  Static: the heaviest queue runs alone.

    Needs concrete queue contents — trace-built states must pass an explicit
    static worst-case ``rounds`` to the launch (the grid size cannot depend
    on traced values).
    """
    if isinstance(state.tasks, jax.core.Tracer):
        raise ValueError(
            "rounds must be given explicitly for a trace-built QueueState: "
            "the grid is static, so use the family's worst-case bound "
            "(e.g. moe_ws.dispatch.expert_rounds_bound)"
        )
    costs = queue_costs(state)
    total = int(costs.sum())
    if total == 0:
        return 1
    from .tasks import max_cost

    mc = max_cost(state.task_list) if state.task_list else int(costs.max())
    if steal:
        return -(-total // state.n_programs) + mc + state.n_queues + 8
    return int(costs.max()) + 8


def launch_ws_grid(
    state: QueueState,
    execute: Callable,
    pure: Sequence[jax.Array],
    out: jax.Array,
    *,
    steal: bool = True,
    rounds: Optional[int] = None,
    mult: Optional[jax.Array] = None,
    interpret: bool = True,
) -> WSRunResult:
    """Run the persistent WS grid with a family ``execute`` body.

    ``execute(tasks_ref, fq, fs, pure_refs, out_ref)`` performs the tile at
    queue slot ``(fq, fs)`` and *accumulates* into ``out_ref``; the shell
    handles extraction and bookkeeping.  ``out``/``mult`` may be carried over
    from a previous launch (resume / multiplicity drills).
    """
    P = state.n_programs
    rounds = default_rounds(state, steal) if rounds is None else rounds
    n_tasks = max(1, state.n_tasks)
    mult = jnp.zeros((n_tasks,), jnp.int32) if mult is None else mult

    kernel = functools.partial(
        _generic_ws_kernel,
        execute=execute,
        n_pure=len(pure),
        n_queues=state.n_queues,
        capacity=state.capacity,
        steal=steal,
    )

    def full(a):
        return pl.BlockSpec(a.shape, lambda r, p, nd=a.ndim: (0,) * nd)

    mutable = [
        jnp.asarray(state.head),
        jnp.asarray(state.local_head),
        jnp.asarray(state.taken),
        jnp.zeros((P,), jnp.int32),   # clock
        jnp.zeros((P,), jnp.int32),   # work
        jnp.zeros((P,), jnp.int32),   # steals
        jnp.asarray(mult),
        jnp.asarray(out),
    ]
    pure_arrays = [jnp.asarray(state.tasks)] + [jnp.asarray(a) for a in pure]
    outs = pl.pallas_call(
        kernel,
        grid=(rounds, P),
        in_specs=[full(a) for a in mutable] + [full(a) for a in pure_arrays],
        out_specs=[full(a) for a in mutable],
        out_shape=[jax.ShapeDtypeStruct(a.shape, a.dtype) for a in mutable],
        input_output_aliases={i: i for i in range(len(mutable))},
        interpret=interpret,
    )(*mutable, *pure_arrays)
    head, local_head, taken, clock, work, steals, mult, out = outs

    def host(a):
        # eager launches hand numpy views back to the drills/telemetry;
        # traced launches keep the jax values (np.asarray would throw)
        return a if isinstance(a, jax.core.Tracer) else np.asarray(a)

    return WSRunResult(
        out=out,
        head=host(head),
        local_head=host(local_head),
        taken=host(taken),
        clock=host(clock),
        work=host(work),
        steals=host(steals),
        mult=host(mult),
    )


# ---------------------------------------------------------------------------
# attention family: flash/decode tile body


def _attention_execute(
    tasks_ref, fq, fs, pure, out_ref,
    *, bq: int, bk: int, causal: bool, scale: float, g: int,
):
    """Flash-attention tile: online-softmax sweep of the task's kv range,
    accumulated into the task's disjoint q-block rows."""
    q_ref, k_ref, v_ref = pure
    b = tasks_ref[fq, fs, F_B]
    h = tasks_ref[fq, fs, F_H]
    qs = tasks_ref[fq, fs, F_QS]
    ql = tasks_ref[fq, fs, F_QL]
    kv_end = tasks_ref[fq, fs, F_KV]
    cost = tasks_ref[fq, fs, F_COST]
    kh = jax.lax.div(h, g)

    qt = q_ref[pl.ds(b, 1), pl.ds(h, 1), pl.ds(qs, bq), :]
    qt = qt.reshape(bq, q_ref.shape[-1]).astype(jnp.float32)

    def kv_block(ki, mla):
        m, l, acc = mla
        kt = k_ref[pl.ds(b, 1), pl.ds(kh, 1), pl.ds(ki * bk, bk), :]
        vt = v_ref[pl.ds(b, 1), pl.ds(kh, 1), pl.ds(ki * bk, bk), :]
        kt = kt.reshape(bk, -1).astype(jnp.float32)
        vt = vt.reshape(bk, -1).astype(jnp.float32)
        s = jax.lax.dot_general(
            qt, kt, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # [bq, bk]
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = kpos < kv_end
        if causal:
            qpos = qs + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            valid &= kpos <= qpos
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        pexp = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + pexp.sum(axis=1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            pexp, vt, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new)

    hd = q_ref.shape[-1]
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, hd), jnp.float32)
    # Dynamic trip count: a real persistent core sweeps only the live
    # blocks — this is exactly the cost the work counters account.
    m, l, acc = jax.lax.fori_loop(0, cost, kv_block, (m0, l0, a0))

    tile = acc / jnp.maximum(l, 1e-30)[:, None]
    row_live = jax.lax.broadcasted_iota(jnp.int32, (bq, hd), 0) < ql
    tile = jnp.where(row_live, tile, 0.0)

    # Idempotent-accumulate: duplicates add whole extra copies of the
    # same tile, which mult[tid] normalizes out host-side.
    cur = out_ref[pl.ds(b, 1), pl.ds(h, 1), pl.ds(qs, bq), :]
    out_ref[pl.ds(b, 1), pl.ds(h, 1), pl.ds(qs, bq), :] = cur + tile[None, None]


def run_ws_schedule(
    state: QueueState,
    q,
    k,
    v,
    *,
    causal: bool,
    bq: int,
    bk: int,
    steal: bool = True,
    rounds: Optional[int] = None,
    out: Optional[jax.Array] = None,
    mult: Optional[jax.Array] = None,
    interpret: bool = True,
) -> WSRunResult:
    """Launch the attention megakernel over a prepared :class:`QueueState`.

    ``q``: [B, H, Sq, hd] with Sq a multiple of ``bq``; ``k``/``v``:
    [B, Hkv, Sk, hd] with Sk a multiple of ``bk``.  ``out``/``mult`` may be
    carried over from a previous launch (resume / multiplicity drills);
    fresh zeros otherwise.
    """
    B, H, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    assert Sq % bq == 0, (Sq, bq)
    assert Sk % bk == 0, (Sk, bk)
    g = H // Hkv
    out = jnp.zeros((B, H, Sq, hd), jnp.float32) if out is None else out
    execute = functools.partial(
        _attention_execute, bq=bq, bk=bk, causal=causal, scale=hd**-0.5, g=g
    )
    return launch_ws_grid(
        state, execute, (q, k, v), out,
        steal=steal, rounds=rounds, mult=mult, interpret=interpret,
    )

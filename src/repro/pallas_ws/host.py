"""Host-side shim: the device queue layout driven through the repro.core API.

``PallasWSHost`` is WS-WMULT (paper Fig. 7) implemented against the *exact*
array layout the megakernel uses — an indexed ``tasks`` array with ⊥
sentinels, a plain shared ``head`` register, per-process persistent local
bounds, and a ``taken`` announcement row — but built on
:mod:`repro.core.backend` cells, so it runs under ``ThreadBackend`` (real
threads) and ``SimBackend`` (deterministic adversarial interleavings) like
every other algorithm in ``repro.core.ALGORITHMS`` (registered as
``"pallas-ws"``).

This is the bridge that lets the paper-level property checkers certify the
device layout: the same slot arithmetic the kernel performs per grid cell is
performed here one shared-memory step at a time, where the simulator can
split it adversarially.  Differences from :class:`repro.core.ws_wmult.WSWMult`
are purely representational: 0-based indexing (device arrays), a fixed
capacity (device allocation), and the announcement row (device diagnostics).
"""

from __future__ import annotations

from typing import Any, Dict

from repro.core.backend import BOTTOM, EMPTY, ThreadBackend
from repro.pallas_ws.tasks import F_COST, TASK_WIDTH


def _cost_of(x: Any) -> int:
    """Tile-slot cost of a payload: encoded task records (TASK_WIDTH int
    sequences, see :mod:`repro.pallas_ws.tasks`) carry it in ``F_COST``;
    opaque payloads count one slot."""
    try:
        if len(x) == TASK_WIDTH:
            return max(1, int(x[F_COST]))
    except (TypeError, ValueError):
        pass
    return 1


class PallasWSHost:
    """Fence-free Read/Write work-stealing on the pallas_ws array layout.

    Mirrors the device layout one field for one, including the §3.6
    advisory ``remaining`` cost summary the cost-aware victim selection
    ranks by: a plain Read/Write register, incremented by Put and
    decremented best-effort by successful Take/Steal (read, then write —
    deliberately *not* an RMW; concurrent updates may lose decrements, and
    the protocol never depends on the value).  The instruction-mix audit
    (`benchmarks/zero_cost.audit_fence_free`) covers these accesses too:
    still zero RMW, zero locks on every path.
    """

    OWNER = 0

    def __init__(self, backend=None, capacity: int = 4096,
                 trace: bool = False, fault_plan=None, **_ignored: Any):
        backend = backend if backend is not None else ThreadBackend()
        self.backend = backend
        self.capacity = capacity
        # chaos shim faults (repro.chaos.FaultPlan): drop every n-th
        # advisory update (a lost plain write) and/or republish the
        # pre-claim head after every n-th claim (a §7 stale write racing
        # the claim).  Both are legal relaxed-memory behaviors the
        # protocol must absorb; counts land in ``faults_injected``.
        self.fault_plan = fault_plan
        self._advise_n = 0
        self._claim_n = 0
        self.faults_injected = {"dropped_advisories": 0,
                                "stale_republishes": 0}
        # Device mirror: tasks[s] (⊥-initialized suffix), head, taken row,
        # advisory remaining-cost summary.
        self.tasks = backend.array(capacity, init=BOTTOM)
        self.Head = backend.cell(0)
        self.taken = backend.map_cells(default=-1)  # (pid, slot) announcements
        self.remaining = backend.cell(0)  # advisory, plain R/W, stale-tolerant
        self.tail = 0  # owner-local, exactly as in Fig. 7
        self._local: Dict[int, int] = {}  # per-process persistent head bound
        # Host mirror of the device event rings (repro.wstrace.ring): one
        # record per successful claim, appended *outside* the protocol's
        # shared-memory accesses — the instruction-mix audit is unchanged.
        self.trace = trace
        self._events: list = []

    def _record(self, pid: int, slot: int, x: Any, kind: str) -> None:
        if not self.trace:
            return
        self._events.append({
            "pid": pid, "slot": slot, "kind": kind, "cost": _cost_of(x),
            "victim": self.OWNER if kind != "take" else -1,
        })

    def trace_events(self) -> list:
        """Claim-ordered host event log (``trace=True`` instances only)."""
        return list(self._events)

    def _local_head(self, pid: int) -> int:
        return self._local.get(pid, 0)

    def _advise(self, delta: int, pid: int) -> None:
        # best-effort advisory update: plain read + plain write (no CAS) —
        # a lost update mis-ranks victims, never changes extraction
        self._advise_n += 1
        fp = self.fault_plan
        if (fp is not None and fp.drop_advisory_every
                and self._advise_n % fp.drop_advisory_every == 0):
            self.faults_injected["dropped_advisories"] += 1
            return
        self.remaining.write(max(0, self.remaining.read(pid) + delta), pid)

    def _maybe_stale_republish(self, head: int, pid: int) -> None:
        # after a successful claim wrote head+1, resurface the pre-claim
        # value — exactly what a delayed plain write from a slower racer
        # could legally do; the claimed slot becomes stealable again and
        # the multiplicity bound (not prevention) must absorb it
        self._claim_n += 1
        fp = self.fault_plan
        if (fp is not None and fp.stale_head_every
                and self._claim_n % fp.stale_head_every == 0):
            self.Head.write(head, pid)
            self.faults_injected["stale_republishes"] += 1

    # -- owner ----------------------------------------------------------
    def put(self, x: Any, *, strict: bool = False) -> bool:
        """Owner Put of one task.  Returns ``False`` (no state touched) when
        the queue is full so callers can back off without catching;
        ``strict=True`` restores the raise for drill suites that treat a
        full queue as harness misconfiguration."""
        if self.tail + 1 >= self.capacity:
            if strict:
                raise RuntimeError(
                    f"pallas-ws queue full (capacity={self.capacity})"
                )
            return False
        pid = self.OWNER
        self.tasks.write(self.tail, x, pid)  # line 2 (task slot)
        if self.tail + 2 < self.capacity:
            # pre-clear invariant: the two slots past tail read as ⊥, never
            # uninitialized memory (already true at init; kept as the literal
            # Fig. 7 write so instruction-count benchmarks stay faithful)
            self.tasks.write(self.tail + 2, BOTTOM, pid)
        self.tail += 1  # line 1 ordering is owner-local, no fence needed
        self._advise(_cost_of(x), pid)
        return True

    def put_segment(self, xs, *, strict: bool = False) -> bool:
        """Batched owner Put (amortized synchronization, DESIGN.md §3.6):
        append a whole segment of tasks with one record write per task, ONE
        pre-clear pair past the segment, one owner-local tail bump, and ONE
        advisory update for the segment's total cost — versus per-task
        pre-clears and advisories from looped :meth:`put`.  All-or-none:
        returns ``False`` (no state touched) unless the whole segment fits.
        Same Fig. 7 layout and same final state as the put loop; only the
        shared-access *count* shrinks, which is the point."""
        xs = list(xs)
        n = len(xs)
        if n == 0:
            return True
        if self.tail + n >= self.capacity:
            if strict:
                raise RuntimeError(
                    f"pallas-ws queue full (capacity={self.capacity}, "
                    f"segment={n})"
                )
            return False
        pid = self.OWNER
        for i, x in enumerate(xs):
            self.tasks.write(self.tail + i, x, pid)  # line 2, batched
        for c in (self.tail + n, self.tail + n + 1):
            # pre-clear invariant published once per segment, not per task
            if c < self.capacity:
                self.tasks.write(c, BOTTOM, pid)
        self.tail += n  # one owner-local bump for the whole segment
        self._advise(sum(_cost_of(x) for x in xs), pid)
        return True

    def take(self) -> Any:
        pid = self.OWNER
        head = max(self._local_head(pid), self.Head.read(pid))  # RMaxRead
        if head < self.tail:  # line 5
            x = self.tasks.read(head, pid)  # line 6
            self.Head.write(head + 1, pid)  # plain write, read elided
            self._local[pid] = head + 1
            self.taken.write((pid, head), pid, pid)
            self._advise(-_cost_of(x), pid)
            self._record(pid, head, x, "take")
            self._maybe_stale_republish(head, pid)
            return x
        self._local[pid] = head
        return EMPTY

    # -- thieves ----------------------------------------------------------
    def steal(self, pid: int) -> Any:
        head = max(self._local_head(pid), self.Head.read(pid))  # line 11
        if head >= self.capacity:
            return EMPTY
        x = self.tasks.read(head, pid)  # line 12
        if x is not BOTTOM:  # line 13
            self.Head.write(head + 1, pid)  # line 14 — plain write
            self._local[pid] = head + 1  # line 15
            self.taken.write((pid, head), pid, pid)
            self._advise(-_cost_of(x), pid)
            self._record(pid, head, x, "steal")
            self._maybe_stale_republish(head, pid)
            return x
        self._local[pid] = head
        return EMPTY

    # -- diagnostics ------------------------------------------------------
    def remaining_estimate(self, pid: int = OWNER) -> int:
        """The advisory cost summary a §3.6 victim selection would rank by
        (possibly stale under concurrency — that is the point)."""
        return self.remaining.read(pid)

    def snapshot(self):
        """(head, tail, taken-announcements) for layout parity checks."""
        return (
            self.Head.read(self.OWNER),
            self.tail,
            dict(self.taken.m),
        )

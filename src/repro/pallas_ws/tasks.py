"""Tile-task encoding for the device-resident work-stealing scheduler.

A task is one attention tile: a (batch, head, q-block) triple plus the KV
range it must sweep.  Tasks are fixed-width int32 records so they can live in
an HBM array and be extracted with a single vector load — the device-side
analogue of the paper's ``tasks[i]`` cells (Fig. 7), where ``tasks[i] = ⊥``
becomes "field 0 == BOTTOM".

Idempotence and multiplicity
----------------------------
Every task owns a *disjoint* slice of the output (its q-block rows for its
(b, h)), and executing it sweeps that slice's **entire** KV range.  Task
execution *accumulates* into the output and bumps a per-task multiplicity
counter with plain loads/stores — so when the relaxed scheduler extracts a
task more than once (the paper's multiplicity), the output is exactly
``mult[t] ×`` the true tile and :func:`multiplicity_divisor` recovers the
exact answer.  This is why the Take/Steal path needs no CAS: duplicated tile
work is count-normalized, not forbidden.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# int32 sentinel marking a never-filled task slot (the paper's ⊥).
BOTTOM = -1

# Record layout: 8 × int32 per task.
TASK_WIDTH = 8
F_OP = 0      # op id (>= 0 live; BOTTOM empty): OP_FLASH_TILE | OP_DECODE_TILE
F_B = 1       # batch row
F_H = 2       # query head
F_QS = 3      # first q row of the tile
F_QL = 4      # number of live q rows (< bq on a ragged tail tile)
F_KV = 5      # kv end, exclusive (== sequence length)
F_TID = 6     # global task id (indexes the multiplicity counter buffer)
F_COST = 7    # kv blocks this task sweeps (the tile-slot cost model)

OP_FLASH_TILE = 0
OP_DECODE_TILE = 1


@dataclass(frozen=True)
class TileTask:
    op: int
    b: int
    h: int
    q_start: int
    q_len: int
    kv_end: int
    tid: int
    cost: int

    def encode(self) -> np.ndarray:
        return np.array(
            [self.op, self.b, self.h, self.q_start, self.q_len,
             self.kv_end, self.tid, self.cost],
            dtype=np.int32,
        )


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def emit_flash_tasks(lengths, n_heads: int, bq: int, bk: int, causal: bool = True):
    """One task per live (b, h, q-block) of a ragged batch.

    ``lengths[b]`` is the true sequence length of batch row ``b``; rows past
    it produce no tasks at all — this is where the ragged workload's
    imbalance comes from (a 4× longer sequence yields ~16× the causal tile
    cost, all landing on one batch row).
    """
    tasks = []
    tid = 0
    for b, ln in enumerate(np.asarray(lengths, dtype=np.int64)):
        ln = int(ln)
        for h in range(n_heads):
            for qi in range(_cdiv(ln, bq)):
                qs = qi * bq
                ql = min(bq, ln - qs)
                kv_end = min(qs + bq, ln) if causal else ln
                cost = max(1, _cdiv(kv_end, bk))
                tasks.append(
                    TileTask(OP_FLASH_TILE, b, h, qs, ql, ln, tid, cost)
                )
                tid += 1
    return tasks


def emit_decode_tasks(lengths, n_heads: int, bk: int):
    """One task per live (b, h): a single query row sweeping kv [0, len)."""
    tasks = []
    tid = 0
    for b, ln in enumerate(np.asarray(lengths, dtype=np.int64)):
        ln = int(ln)
        if ln <= 0:
            continue
        for h in range(n_heads):
            tasks.append(
                TileTask(
                    OP_DECODE_TILE, b, h, 0, 1, ln, tid, max(1, _cdiv(ln, bk))
                )
            )
            tid += 1
    return tasks


def multiplicity_divisor(tasks, mult, out_shape) -> np.ndarray:
    """Per-output-row divisor [B, H, Sq] normalizing accumulated duplicates.

    Each q row belongs to exactly one task, so dividing its accumulated value
    by that task's execution count is exact.  Rows owned by no task (ragged
    padding) get divisor 1 and stay zero.
    """
    B, H, Sq = out_shape
    mult = np.asarray(mult)
    div = np.ones((B, H, Sq), dtype=np.float32)
    for t in tasks:
        div[t.b, t.h, t.q_start: t.q_start + t.q_len] = max(1, int(mult[t.tid]))
    return div


def total_cost(tasks) -> int:
    return int(sum(t.cost for t in tasks))


def max_cost(tasks) -> int:
    return max((t.cost for t in tasks), default=0)

"""Task encoding + task-family registry for the device-resident WS scheduler.

A task is one idempotent tile of work.  Tasks are fixed-width int32 records
so they can live in an HBM array and be extracted with a single vector load —
the device-side analogue of the paper's ``tasks[i]`` cells (Fig. 7), where
``tasks[i] = ⊥`` becomes "field 0 == BOTTOM".

The record layout is family-agnostic: field 0 carries the op id, fields 1–5
are family-specific operands, and the tail two fields are shared by every
family (the multiplicity-counter index and the tile-slot cost the
round-lockstep clock charges).  The queue arrays, the Take/Steal extraction
protocol, and the clock/work accounting never look at the operand fields, so
new workloads plug in by registering a :class:`TaskFamily` and supplying a
kernel body — attention tiles (:mod:`repro.pallas_ws.kernel`) and MoE expert
tiles (:mod:`repro.moe_ws.expert_kernel`) share the whole scheduler.

Idempotence and multiplicity
----------------------------
Every task owns a *disjoint* slice of its family's output (q-block rows for
attention, routed-row ranges for expert FFN), and executing it computes that
slice's **entire** result.  Task execution *accumulates* into the output and
bumps a per-task multiplicity counter with plain loads/stores — so when the
relaxed scheduler extracts a task more than once (the paper's multiplicity),
the output is exactly ``mult[t] ×`` the true tile and the family's divisor
(:func:`multiplicity_divisor` / ``moe_ws.dispatch.row_divisor``) recovers the
exact answer.  This is why the Take/Steal path needs no CAS: duplicated tile
work is count-normalized, not forbidden.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

# int32 sentinel marking a never-filled task slot (the paper's ⊥).
BOTTOM = -1

# Record layout: 8 × int32 per task.  Field 0 and the tail two fields are
# family-agnostic; fields 1-5 are operands owned by the task family.
TASK_WIDTH = 8
F_OP = 0      # op id (>= 0 live; BOTTOM empty) — see TASK_FAMILIES
F_TID = 6     # global task id (indexes the multiplicity counter buffer)
F_COST = 7    # tile-slots this task occupies (the lockstep clock cost model)

# -- attention family operands (fields 1-5) ---------------------------------
F_B = 1       # batch row
F_H = 2       # query head
F_QS = 3      # first q row of the tile
F_QL = 4      # number of live q rows (< bq on a ragged tail tile)
F_KV = 5      # kv end, exclusive (== sequence length)

# -- expert family operands (fields 1-3; 4-5 unused) ------------------------
F_E = 1       # expert id (indexes the stacked expert weight arrays)
F_RS = 2      # first routed row of the tile (into the grouped routed arrays)
F_RL = 3      # number of live routed rows (< bt on a ragged tail tile)

# -- step-glue family operands (fields 1-3; 4-5 unused) ----------------------
F_PHASE = 1   # glue phase kind (models.unified.GLUE_* codes)
F_LAYER = 2   # transformer layer the glue belongs to
F_AUX = 3     # phase-specific operand (e.g. prefill slot; BOTTOM if unused)

OP_FLASH_TILE = 0
OP_DECODE_TILE = 1
OP_EXPERT_TILE = 2
OP_STEP_GLUE = 3


@dataclass(frozen=True)
class TaskFamily:
    """One workload plugged into the shared queue/kernel/clock machinery.

    ``ops``: the op codes the family owns; ``operands``: record fields 1-5 by
    name; ``cost_unit``: what one tile-slot of :data:`F_COST` measures — makespans
    are comparable only within a family.
    """

    name: str
    ops: Tuple[int, ...]
    operands: Tuple[str, ...]
    cost_unit: str


TASK_FAMILIES: Dict[str, TaskFamily] = {}
_OP_TO_FAMILY: Dict[int, TaskFamily] = {}


def register_family(family: TaskFamily) -> TaskFamily:
    """Register a task family; op codes must be globally unique."""
    for op in family.ops:
        prev = _OP_TO_FAMILY.get(op)
        if prev is not None and prev.name != family.name:
            raise ValueError(f"op {op} already owned by family {prev.name!r}")
        _OP_TO_FAMILY[op] = family
    TASK_FAMILIES[family.name] = family
    return family


def family_of(op: int) -> TaskFamily:
    return _OP_TO_FAMILY[op]


ATTENTION_FAMILY = register_family(
    TaskFamily(
        name="attention",
        ops=(OP_FLASH_TILE, OP_DECODE_TILE),
        operands=("b", "h", "q_start", "q_len", "kv_end"),
        cost_unit="kv blocks",
    )
)

EXPERT_FAMILY = register_family(
    TaskFamily(
        name="expert",
        ops=(OP_EXPERT_TILE,),
        operands=("expert", "row_start", "row_len"),
        cost_unit="routed token rows",
    )
)

# Inter-stage glue of the unified engine step (models.unified): norms, qkv
# projections + cache writes, routing, combines, logits.  Exactly one task
# per (phase, layer), so a glue task's cost is the whole phase's work — the
# unified launch charges it as the stage's max_cost term in the Graham
# window bound (DESIGN.md §5).
STEP_FAMILY = register_family(
    TaskFamily(
        name="step-glue",
        ops=(OP_STEP_GLUE,),
        operands=("phase", "layer", "aux"),
        cost_unit="glue phases",
    )
)


@dataclass(frozen=True)
class TileTask:
    """Attention-family task: one (b, h, q-block) tile sweeping kv [0, kv_end)."""

    op: int
    b: int
    h: int
    q_start: int
    q_len: int
    kv_end: int
    tid: int
    cost: int

    @property
    def owner(self) -> int:
        """Owner-queue key for ``partition_tasks(partition="owner")``."""
        return self.b

    def encode(self) -> np.ndarray:
        return np.array(
            [self.op, self.b, self.h, self.q_start, self.q_len,
             self.kv_end, self.tid, self.cost],
            dtype=np.int32,
        )


@dataclass(frozen=True)
class ExpertTask:
    """Expert-family task: ``row_len`` routed rows of one expert's FFN.

    ``row_start`` indexes the expert-grouped routed arrays (token indices /
    gates laid out contiguously per expert — see ``moe_ws.dispatch``), so
    each task owns a disjoint contiguous slice of the routed output, exactly
    as an attention tile owns its q-block rows.  ``cost`` is the number of
    live rows: expert FFN work is tokens × d_ff and d_ff is uniform across
    experts, so token rows are the tile-slot unit.
    """

    expert: int
    row_start: int
    row_len: int
    tid: int
    cost: int
    op: int = OP_EXPERT_TILE

    @property
    def owner(self) -> int:
        return self.expert

    def encode(self) -> np.ndarray:
        return np.array(
            [self.op, self.expert, self.row_start, self.row_len,
             BOTTOM, BOTTOM, self.tid, self.cost],
            dtype=np.int32,
        )


@dataclass(frozen=True)
class StepGlueTask:
    """Step-glue task: one inter-stage phase of the unified engine step.

    Glue phases are serial by construction (one task per phase, gated by the
    stage windows), so duplication is impossible on a correct schedule — but
    the body still accumulates idempotently and ``mult[tid]`` still counts,
    keeping the family honest under the relaxed scheduler's contract.
    """

    phase: int
    layer: int
    aux: int
    tid: int
    cost: int
    op: int = OP_STEP_GLUE

    @property
    def owner(self) -> int:
        return self.layer

    def encode(self) -> np.ndarray:
        return np.array(
            [self.op, self.phase, self.layer, self.aux,
             BOTTOM, BOTTOM, self.tid, self.cost],
            dtype=np.int32,
        )


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def emit_flash_tasks(lengths, n_heads: int, bq: int, bk: int, causal: bool = True):
    """One task per live (b, h, q-block) of a ragged batch.

    ``lengths[b]`` is the true sequence length of batch row ``b``; rows past
    it produce no tasks at all — this is where the ragged workload's
    imbalance comes from (a 4× longer sequence yields ~16× the causal tile
    cost, all landing on one batch row).
    """
    tasks = []
    tid = 0
    for b, ln in enumerate(np.asarray(lengths, dtype=np.int64)):
        ln = int(ln)
        for h in range(n_heads):
            for qi in range(_cdiv(ln, bq)):
                qs = qi * bq
                ql = min(bq, ln - qs)
                kv_end = min(qs + bq, ln) if causal else ln
                cost = max(1, _cdiv(kv_end, bk))
                tasks.append(
                    TileTask(OP_FLASH_TILE, b, h, qs, ql, ln, tid, cost)
                )
                tid += 1
    return tasks


def emit_decode_tasks(lengths, n_heads: int, bk: int):
    """One task per live (b, h): a single query row sweeping kv [0, len)."""
    tasks = []
    tid = 0
    for b, ln in enumerate(np.asarray(lengths, dtype=np.int64)):
        ln = int(ln)
        if ln <= 0:
            continue
        for h in range(n_heads):
            tasks.append(
                TileTask(
                    OP_DECODE_TILE, b, h, 0, 1, ln, tid, max(1, _cdiv(ln, bk))
                )
            )
            tid += 1
    return tasks


def multiplicity_divisor(tasks, mult, out_shape) -> np.ndarray:
    """Per-output-row divisor [B, H, Sq] normalizing accumulated duplicates.

    Each q row belongs to exactly one task, so dividing its accumulated value
    by that task's execution count is exact.  Rows owned by no task (ragged
    padding) get divisor 1 and stay zero.
    """
    B, H, Sq = out_shape
    mult = np.asarray(mult)
    div = np.ones((B, H, Sq), dtype=np.float32)
    for t in tasks:
        div[t.b, t.h, t.q_start: t.q_start + t.q_len] = max(1, int(mult[t.tid]))
    return div


def total_cost(tasks) -> int:
    return int(sum(t.cost for t in tasks))


def max_cost(tasks) -> int:
    return max((t.cost for t in tasks), default=0)

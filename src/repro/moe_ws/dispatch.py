"""Router output → expert-tile task queues (the MoE "Put" side).

The dense MoE path fixes per-expert capacity ahead of time and *drops* every
routed (token, expert) pair beyond it — load balance is bought with lost
tokens.  Here routing is instead lowered to the paper's scheduling problem:

1.  group the routed pairs by expert into one flat array (``RoutedSet``) —
    each expert owns a contiguous row range, so an expert tile of ``bt`` rows
    owns a *disjoint contiguous slice* of the routed output, exactly as an
    attention tile owns its q-block rows;
2.  emit one :class:`~repro.pallas_ws.tasks.ExpertTask` per tile with
    ``cost = live rows`` (expert FFN work is tokens × d_ff and d_ff is
    uniform, so token rows are the tile-slot unit);
3.  Put them into per-expert owner queues (``partition="owner"``) — a hot
    expert's queue is exactly as overloaded as its router load, which is the
    skew the megakernel's thieves erase.

No capacity anywhere: every routed pair gets a row, every row gets a task —
the dispatch is **dropless** by construction.  Duplicated tile execution
(the scheduler's multiplicity) is normalized by :func:`row_divisor`.

``MoEDispatchHost`` runs the identical Put/Take/Steal slot arithmetic
against :mod:`repro.core` backend cells so the adversarial simulator and the
instruction-mix audit certify the expert dispatch path like every other
``ALGORITHMS`` entry (registered as ``"moe-ws"``).

Three Put implementations, one protocol
---------------------------------------
:func:`route_to_tasks` is the host-side Put (concrete routing, numpy,
compact per-expert padding).  :func:`route_to_tasks_jax` is the **traced**
Put on the padded layout: the same stable-sort grouping expressed as jnp
ops over fixed shapes, so queue construction works inside ``jit``/``scan``.
Fixed shapes force the static worst case — every expert's row range is
provisioned at ``R = ceil(min(T, T·k)/bt) · bt`` rows, ``E·R`` rows total,
with per-tile live masks (``row_len``) marking the real load.
:func:`route_to_tasks_pool_jax` is the traced Put on the **shared-pool**
layout (DESIGN.md §3.6): still static shapes, but per-expert *offsets* are
data, so the whole pool is ``ceil(T·k/bt) + E`` tiles — ~E× less HBM at
high expert counts, and no ``max_expert_load`` escape needed.  Dead tiles
become ⊥ records at queue build time, dead rows carry token 0 / gate 0, so
the combine is unchanged.  The builders are certified equivalent — layout,
adversarial extraction telemetry, and normalized output — by
tests/test_dispatch_conformance.py.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.pallas_ws.host import PallasWSHost
from repro.pallas_ws.tasks import BOTTOM, OP_EXPERT_TILE, ExpertTask


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


@dataclass(frozen=True)
class RoutedSet:
    """Expert-grouped routed (token, expert) pairs, kernel-ready.

    Each expert's row range is **padded up to a multiple of the tile size**
    ``bt``, so every tile's ``[row_start, row_start + bt)`` output slice is
    disjoint from every other tile's — required because the kernel's
    accumulate is a read-modify-write of the whole ``bt`` slice, and on a
    truly parallel device an unaligned tail tile would race with the next
    expert's first tile.  Pad rows point at token 0 with gate 0 and are
    masked dead inside the kernel, so they accumulate exactly zero and the
    gate-weighted combine ignores them.
    """

    tok_idx: np.ndarray     # [n_rows] int32 — token index per row (0 on pads)
    gates: np.ndarray       # [n_rows] float32 — combine weight (0 on pads)
    expert_off: np.ndarray  # [E + 1] int32 — expert e owns rows [off[e], off[e+1])
    loads: np.ndarray       # [E] int64 — live routed rows per expert
    n_rows: int             # bt-aligned total rows (>= n_routed)
    n_routed: int           # live rows (== T * top_k)
    n_tokens: int
    # [n_rows] int32 — inverse routing map: the flat (token·k + choice) pair
    # index that produced each row, ``n_routed`` on pads.  This is the
    # residual the differentiable dispatch's backward scatters per-row
    # cotangents through (row -> (token, choice) gate slot, row -> expert);
    # ``row_src < n_routed`` doubles as the live-row mask.
    row_src: Optional[np.ndarray] = None

    @property
    def n_experts(self) -> int:
        return len(self.expert_off) - 1

    def expert_loads(self) -> np.ndarray:
        """Live routed rows per expert — the raw router skew."""
        return self.loads


def _routed_flatten(r: "RoutedSet"):
    return (
        (r.tok_idx, r.gates, r.expert_off, r.loads, r.row_src),
        (r.n_rows, r.n_routed, r.n_tokens),
    )


def _routed_unflatten(aux, children):
    tok_idx, gates, expert_off, loads, row_src = children
    n_rows, n_routed, n_tokens = aux
    return RoutedSet(tok_idx, gates, expert_off, loads, n_rows, n_routed,
                     n_tokens, row_src)


_ROUTED_REGISTERED = False


def _register_routed_pytree() -> None:
    """Pytree registration lets a RoutedSet built by route_to_tasks_jax cross
    jit/scan boundaries (array fields traced, shape fields static).  Lazy so
    the jax-free consumers of this module (the ``moe-ws`` ALGORITHMS entry,
    the instruction-mix audit) never pay the jax import."""
    global _ROUTED_REGISTERED
    if _ROUTED_REGISTERED:
        return
    import jax.tree_util as jtu

    jtu.register_pytree_node(RoutedSet, _routed_flatten, _routed_unflatten)
    _ROUTED_REGISTERED = True


def route_to_tasks(
    idx, gates, n_experts: int, bt: int = 8
) -> Tuple[List[ExpertTask], RoutedSet]:
    """Lower concrete top-k routing to expert tiles.

    ``idx``: [T, k] int expert choices; ``gates``: [T, k] float combine
    weights (already normalized).  Grouping is stable in (token, choice)
    order within each expert, so the layout is deterministic.
    """
    idx = np.asarray(idx)
    gates = np.asarray(gates, dtype=np.float32)
    T, k = idx.shape
    assert gates.shape == (T, k), (gates.shape, (T, k))

    flat_e = idx.reshape(-1)
    flat_t = np.repeat(np.arange(T, dtype=np.int32), k)
    flat_g = gates.reshape(-1)
    # stable counting sort by expert: contiguous per-expert row ranges
    order = np.argsort(flat_e, kind="stable")
    loads = np.bincount(flat_e, minlength=n_experts).astype(np.int64)
    padded = -(-loads // bt) * bt  # bt-aligned range per expert
    expert_off = np.zeros(n_experts + 1, dtype=np.int32)
    np.cumsum(padded, out=expert_off[1:])
    n_rows = max(bt, int(expert_off[-1]))

    tok_idx = np.zeros(n_rows, dtype=np.int32)
    gate_rows = np.zeros(n_rows, dtype=np.float32)
    row_src = np.full(n_rows, T * k, dtype=np.int32)
    src = 0
    for e in range(n_experts):
        lo = int(expert_off[e])
        ln = int(loads[e])
        tok_idx[lo: lo + ln] = flat_t[order[src: src + ln]]
        gate_rows[lo: lo + ln] = flat_g[order[src: src + ln]]
        row_src[lo: lo + ln] = order[src: src + ln]
        src += ln

    tasks: List[ExpertTask] = []
    tid = 0
    for e in range(n_experts):
        start = int(expert_off[e])
        for i in range(0, int(loads[e]), bt):
            rl = min(bt, int(loads[e]) - i)
            tasks.append(ExpertTask(expert=e, row_start=start + i, row_len=rl,
                                    tid=tid, cost=rl))
            tid += 1

    return tasks, RoutedSet(
        tok_idx=tok_idx,
        gates=gate_rows,
        expert_off=expert_off,
        loads=loads,
        n_rows=n_rows,
        n_routed=T * k,
        n_tokens=T,
        row_src=row_src,
    )


def _group_by_expert_jax(idx, gates, n_experts: int):
    """Stable counting sort of the routed (token, choice) pairs by expert —
    the shared grouping preamble of both traced Puts: a stable argsort over
    the flat ``[T·k]`` pair list plus per-expert segment bounds read off the
    sorted key column with ``searchsorted`` (no scatter-add — the counts are
    bit-identical and the lowering is gather-only).  Returns ``(T, k, order,
    sorted_e, flat_t, flat_g, loads, start)`` where ``start`` is the
    exclusive cumsum of ``loads`` (expert ``e``'s pairs are
    ``order[start[e] : start[e] + loads[e]]``); the caller *gathers* each
    destination row's pair from that segment — the batched-Put inverse of
    the old one-scatter-per-pair formulation."""
    import jax.numpy as jnp

    idx = jnp.asarray(idx, jnp.int32)
    gates = jnp.asarray(gates, jnp.float32)
    T, k = idx.shape
    flat_e = idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    e_ids = jnp.arange(n_experts, dtype=jnp.int32)
    start = jnp.searchsorted(sorted_e, e_ids, side="left").astype(jnp.int32)
    ends = jnp.searchsorted(sorted_e, e_ids, side="right").astype(jnp.int32)
    loads = ends - start
    return T, k, order, sorted_e, flat_t, flat_g, loads, start


def route_to_tasks_jax(idx, gates, n_experts: int, bt: int = 8,
                       max_expert_load: int | None = None):
    """Traced twin of :func:`route_to_tasks`: jit-compatible Put.

    Same stable (token, choice)-order grouping by expert — a stable argsort
    over the ``[T·k]`` routed pairs plus a cumsum rank — but laid out at the
    **static worst case**: every expert owns exactly
    ``R = ceil(min(T, T·k)/bt)·bt`` rows starting at ``e·R``, every expert
    owns ``R/bt`` candidate tiles with static ``tid = e·(R/bt) + i``, and
    the dynamic router load only moves the live masks.  The default bound
    is ``T`` rows per expert because top-k routing (``jax.lax.top_k`` in
    ``router_topk``) picks **distinct** experts per token, so one expert
    receives at most one pair per token even when the router sends it every
    token.  Callers feeding routings that may repeat an expert within a
    token's k choices must pass ``max_expert_load`` (up to ``T·k``) —
    pairs beyond the provisioned range are mask-dropped (the segment-gather
    materialization never writes outside its expert's rows).
    Returns ``(records [E, R/bt, TASK_WIDTH], live [E, R/bt], RoutedSet)``
    where the RoutedSet fields are jnp values (``expert_off`` is the static
    ``e ↦ e·R`` map) — feed the records through
    :func:`expert_queue_candidates` /
    :func:`repro.pallas_ws.queues.make_queue_state_jax` to finish the Put.

    Live-mask invariant: within expert ``e``'s range, row ``e·R + j`` is
    live iff ``j < loads[e]``; tile ``(e, i)`` is live iff ``i·bt <
    loads[e]`` and carries ``row_len = cost = clip(loads[e] - i·bt, 0,
    bt)``.  Dead rows point at token 0 with gate 0, dead tiles become ⊥ at
    queue build, so multiplicity accounting and the combine treat both
    builders identically.
    """
    import jax.numpy as jnp

    _register_routed_pytree()
    E = n_experts
    T, k, order, sorted_e, flat_t, flat_g, loads, start = _group_by_expert_jax(
        idx, gates, E
    )
    Tk = T * k
    cap = min(Tk, T if max_expert_load is None else int(max_expert_load))
    tiles_per_e = _cdiv(cap, bt)     # static
    R = tiles_per_e * bt             # static rows per expert
    # Batched Put (DESIGN.md §3.6): materialize every expert's row segment
    # as ONE masked vectorized gather per output array instead of one
    # scatter per routed pair — row e·R + j holds pair order[start[e] + j]
    # iff j < loads[e].  Bit-identical to the scatter for any in-contract
    # routing (each live row had exactly one writer), and the lowering
    # carries zero scatter ops (benchmarks/zero_cost.py audits this).
    rows = jnp.arange(E * R, dtype=jnp.int32)
    e_row = rows // R
    j_row = rows - e_row * R
    row_live = j_row < loads[e_row]
    src = jnp.minimum(start[e_row] + j_row, Tk - 1)
    pair = order[src].astype(jnp.int32)
    tok_idx = jnp.where(row_live, flat_t[pair], 0)
    gate_rows = jnp.where(row_live, flat_g[pair], jnp.float32(0))
    row_src = jnp.where(row_live, pair, Tk)

    e_ids = jnp.arange(E, dtype=jnp.int32)[:, None]          # [E, 1]
    i_ids = jnp.arange(tiles_per_e, dtype=jnp.int32)[None, :]  # [1, R/bt]
    rl = jnp.clip(loads[:, None] - i_ids * bt, 0, bt)        # live rows/tile
    live = rl > 0
    shape = (E, tiles_per_e)
    records = jnp.stack(
        [
            jnp.full(shape, OP_EXPERT_TILE, jnp.int32),
            jnp.broadcast_to(e_ids, shape),
            e_ids * R + i_ids * bt,                # row_start
            rl,                                    # row_len
            jnp.full(shape, BOTTOM, jnp.int32),
            jnp.full(shape, BOTTOM, jnp.int32),
            e_ids * tiles_per_e + i_ids,           # tid (static, unique)
            rl,                                    # cost = live rows
        ],
        axis=-1,
    )
    routed = RoutedSet(
        tok_idx=tok_idx,
        gates=gate_rows,
        expert_off=np.arange(E + 1, dtype=np.int32) * R,
        loads=loads,
        n_rows=E * R,
        n_routed=Tk,
        n_tokens=T,
        row_src=row_src,
    )
    return records, live, routed


def route_to_tasks_pool_jax(idx, gates, n_experts: int, bt: int = 8):
    """Traced Put, **shared-pool layout**: compact twin of
    :func:`route_to_tasks_jax` (DESIGN.md §3.6).

    The padded layout provisions every expert at the static worst case —
    ``E · ceil(min(T, Tk)/bt)`` tiles — because per-queue shapes must be
    static.  But only *shapes* must be static: per-queue *offsets* may be
    data.  This builder allocates one flat pool of

        ``pool_tiles = ceil(Tk/bt) + E``

    tiles (each expert wastes < 1 tile of tail padding, so
    ``Σ_e ceil(loads[e]/bt) ≤ floor(Tk/bt) + E`` always fits — for **any**
    routing, including experts repeated within a token's k choices, so no
    ``max_expert_load`` escape is needed) and lays expert ``e``'s tiles at
    the dynamic tile offset ``toff[e] = Σ_{e'<e} ceil(loads[e']/bt)``.
    Pool tile ``j`` owns routed rows ``[j·bt, (j+1)·bt)`` and is its own
    ``tid``, so the multiplicity buffer and the combine's divisor grid are
    pool-indexed with no remap.  Queue-array bytes shrink ~E× at high
    expert counts (`benchmarks/steal_policy.py`).

    Requires per-expert queues (``n_queues == n_experts``): queue ``e`` is
    exactly the pool segment ``[toff[e], toff[e+1})``, already compacted in
    the order the host Put loop produces — feed the results straight to
    :func:`repro.pallas_ws.queues.make_pool_queue_state_jax`.

    Returns ``(records [pool_tiles, TASK_WIDTH], tail [E], pool_off [E+1],
    routed)`` with all RoutedSet array fields jnp values
    (``expert_off = toff·bt`` is dynamic here).
    """
    import jax.numpy as jnp

    _register_routed_pytree()
    E = n_experts
    T, k, order, sorted_e, flat_t, flat_g, loads, start = _group_by_expert_jax(
        idx, gates, E
    )
    Tk = T * k
    pool_tiles = _cdiv(Tk, bt) + E  # static
    n_tiles = (loads + bt - 1) // bt  # live tiles per expert (dynamic)
    toff = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(n_tiles).astype(jnp.int32)]
    )
    row_off = toff * bt
    n_rows = pool_tiles * bt
    # Batched Put: per-expert pool segments materialized by one masked
    # gather per output array (no per-pair scatters) — pool row
    # row_off[e] + j holds pair order[start[e] + j] iff j < loads[e];
    # rows past each segment's live prefix (tile tail padding and the pool
    # tail) are dead.  Bit-identical to the scatter formulation.
    rows = jnp.arange(n_rows, dtype=jnp.int32)
    tile_row = rows // bt
    e_row = jnp.clip(
        jnp.searchsorted(toff, tile_row, side="right").astype(jnp.int32) - 1,
        0, E - 1,
    )
    j_row = rows - row_off[e_row]
    row_live = (tile_row < toff[E]) & (j_row < loads[e_row])
    src = jnp.minimum(start[e_row] + j_row, Tk - 1)
    pair = order[src].astype(jnp.int32)
    tok_idx = jnp.where(row_live, flat_t[pair], 0)
    gate_rows = jnp.where(row_live, flat_g[pair], jnp.float32(0))
    row_src = jnp.where(row_live, pair, Tk)

    # per-pool-tile records: tile j belongs to the expert whose segment
    # [toff[e], toff[e+1}) contains j (duplicates in toff — empty experts —
    # resolve to the owning non-empty expert under side="right")
    j = jnp.arange(pool_tiles, dtype=jnp.int32)
    e_of = jnp.clip(
        jnp.searchsorted(toff, j, side="right").astype(jnp.int32) - 1,
        0, E - 1,
    )
    i_of = j - toff[e_of]
    live = j < toff[E]
    rl = jnp.where(live, jnp.clip(loads[e_of] - i_of * bt, 0, bt), 0)
    bot = jnp.full((pool_tiles,), BOTTOM, jnp.int32)
    records = jnp.stack(
        [
            jnp.where(live, jnp.int32(OP_EXPERT_TILE), jnp.int32(BOTTOM)),
            jnp.where(live, e_of, jnp.int32(BOTTOM)),
            j * bt,                  # row_start: pool tile j owns rows j·bt..
            rl,                      # row_len
            bot,
            bot,
            j,                       # tid == pool tile index (no remap)
            rl,                      # cost = live rows
        ],
        axis=-1,
    )
    routed = RoutedSet(
        tok_idx=tok_idx,
        gates=gate_rows,
        expert_off=row_off,          # dynamic: expert e's rows start here
        loads=loads,
        n_rows=n_rows,
        n_routed=Tk,
        n_tokens=T,
        row_src=row_src,
    )
    return records, n_tiles, toff, routed


def expert_queue_candidates(records, live, n_queues: int):
    """Owner placement for trace-built expert tiles: expert ``e`` lands on
    queue ``e % n_queues`` (per-expert queues when ``n_queues == E``, the
    static baseline's round-robin expert parallelism when ``n_queues ==
    n_programs``) — same keying as ``partition_tasks(partition="owner")``."""
    from repro.pallas_ws.queues import owner_queue_candidates

    return owner_queue_candidates(records, live, n_queues)


def expert_rounds_bound(
    n_routed: int, bt: int, n_queues: int, n_programs: int, steal: bool,
    steal_run_cap: int = 1,
) -> int:
    """Static worst-case lockstep rounds to drain any routing of
    ``n_routed`` pairs — the trace-time stand-in for
    :func:`repro.pallas_ws.kernel.default_rounds` (cost unit: routed rows).

    Stealing: Graham's greedy bound ``ceil(total/P) + max_cost`` on the
    worst admissible total (every pair live; a tile costs at most ``bt``
    rows).  The PR-3 ``+ n_queues + 8`` slack is gone: both steal policies
    guarantee an idle program claims a task whenever any queue is non-empty
    (DESIGN.md §3.6), which is exactly the premise of the Graham bound.
    Half-run steals (``steal_run_cap > 1``) can pull up to ``cap`` tiles in
    the last claim, growing the tail term to ``cap·bt``.
    No-steal: run compression drains each owner's whole queue in its first
    idle round, so the bound is O(1) (kernel.STATIC_COMPRESSED_ROUNDS).
    """
    if steal:
        return _cdiv(n_routed, n_programs) + max(1, steal_run_cap) * bt
    # lazy: this module stays jax-free at import time for the host-shim
    # consumers; the static bound is only asked for around a kernel launch
    from repro.pallas_ws.kernel import STATIC_COMPRESSED_ROUNDS

    return STATIC_COMPRESSED_ROUNDS


def divisor_from_tiles(row_start, row_len, tile_mult, n_rows: int):
    """Vectorized per-row multiplicity divisor — the one implementation both
    Put paths normalize through.

    Each tile owns the disjoint rows ``[row_start[i], row_start[i] +
    row_len[i])``; those rows get divisor ``max(1, tile_mult[i])``, all
    other rows 1.  Two forms of ``row_len``:

    * a concrete int array (host path, ragged tail tiles) — the row index
      set is built with ``np.repeat`` over the tile lengths;
    * a static int (traced path, uniform ``bt``-row tiles) — the rows are a
      static-shape ``[n_tiles, bt]`` grid scattered with jnp, which traces.
      A live tile's pad rows get the tile's divisor too; they accumulate
      exactly 0 and carry gate 0, so the combine cannot see the difference.
    """
    if isinstance(row_len, (int, np.integer)):
        import jax.numpy as jnp

        bt = int(row_len)
        starts = jnp.asarray(row_start)
        rows = starts[:, None] + jnp.arange(bt, dtype=starts.dtype)[None, :]
        m = jnp.maximum(jnp.asarray(tile_mult), 1).astype(jnp.float32)
        div = jnp.ones((n_rows,), jnp.float32)
        return div.at[rows].set(jnp.broadcast_to(m[:, None], rows.shape))

    starts = np.asarray(row_start, dtype=np.int64)
    lens = np.asarray(row_len, dtype=np.int64)
    m = np.maximum(1, np.asarray(tile_mult)).astype(np.float32)
    div = np.ones((n_rows,), dtype=np.float32)
    total = int(lens.sum())
    if total:
        # concatenated aranges: [0..len0) ++ [0..len1) ++ ...
        offs = np.arange(total) - np.repeat(np.cumsum(lens) - lens, lens)
        div[np.repeat(starts, lens) + offs] = np.repeat(m, lens)
    return div


def row_divisor(tasks: Sequence[ExpertTask], mult, n_rows: int) -> np.ndarray:
    """Per-row multiplicity divisor (the expert-family analogue of
    ``tasks.multiplicity_divisor``): each live row belongs to exactly one
    tile, so dividing its accumulated output by that tile's execution count
    is exact.  Pad rows (gate 0, accumulate 0) keep divisor 1.
    """
    mult = np.asarray(mult)
    if not tasks:
        return np.ones((n_rows,), dtype=np.float32)
    starts = np.fromiter((t.row_start for t in tasks), np.int64, len(tasks))
    lens = np.fromiter((t.row_len for t in tasks), np.int64, len(tasks))
    tids = np.fromiter((t.tid for t in tasks), np.int64, len(tasks))
    return np.asarray(divisor_from_tiles(starts, lens, mult[tids], n_rows))


class MoEDispatchHost(PallasWSHost):
    """Expert-dispatch queue on the device array layout, for the property
    harness and the zero-cost instruction-mix audit.

    Identical protocol to :class:`PallasWSHost` — the point of the task-family
    generalization is that expert tiles ride the *same* fence-free slot
    arithmetic — but sized for per-layer expert queues and accepting encoded
    :class:`ExpertTask` payloads via :meth:`put_task`.
    """

    def __init__(self, backend=None, capacity: int = 4096, **kw):
        super().__init__(backend=backend, capacity=capacity, **kw)

    def put_task(self, task: ExpertTask, *, strict: bool = False) -> bool:
        return self.put(tuple(int(x) for x in task.encode()), strict=strict)

    def put_tasks(self, tasks, *, strict: bool = False) -> bool:
        """Batched Put of one expert's tile segment — one pre-clear pair and
        one advisory write for the whole segment (amortized synchronization;
        see :meth:`repro.pallas_ws.host.PallasWSHost.put_segment`)."""
        return self.put_segment(
            [tuple(int(x) for x in t.encode()) for t in tasks],
            strict=strict,
        )

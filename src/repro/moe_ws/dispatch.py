"""Router output → expert-tile task queues (the MoE "Put" side).

The dense MoE path fixes per-expert capacity ahead of time and *drops* every
routed (token, expert) pair beyond it — load balance is bought with lost
tokens.  Here routing is instead lowered to the paper's scheduling problem:

1.  group the routed pairs by expert into one flat array (``RoutedSet``) —
    each expert owns a contiguous row range, so an expert tile of ``bt`` rows
    owns a *disjoint contiguous slice* of the routed output, exactly as an
    attention tile owns its q-block rows;
2.  emit one :class:`~repro.pallas_ws.tasks.ExpertTask` per tile with
    ``cost = live rows`` (expert FFN work is tokens × d_ff and d_ff is
    uniform, so token rows are the tile-slot unit);
3.  Put them into per-expert owner queues (``partition="owner"``) — a hot
    expert's queue is exactly as overloaded as its router load, which is the
    skew the megakernel's thieves erase.

No capacity anywhere: every routed pair gets a row, every row gets a task —
the dispatch is **dropless** by construction.  Duplicated tile execution
(the scheduler's multiplicity) is normalized by :func:`row_divisor`.

``MoEDispatchHost`` runs the identical Put/Take/Steal slot arithmetic
against :mod:`repro.core` backend cells so the adversarial simulator and the
instruction-mix audit certify the expert dispatch path like every other
``ALGORITHMS`` entry (registered as ``"moe-ws"``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

from repro.pallas_ws.host import PallasWSHost
from repro.pallas_ws.tasks import ExpertTask


@dataclass(frozen=True)
class RoutedSet:
    """Expert-grouped routed (token, expert) pairs, kernel-ready.

    Each expert's row range is **padded up to a multiple of the tile size**
    ``bt``, so every tile's ``[row_start, row_start + bt)`` output slice is
    disjoint from every other tile's — required because the kernel's
    accumulate is a read-modify-write of the whole ``bt`` slice, and on a
    truly parallel device an unaligned tail tile would race with the next
    expert's first tile.  Pad rows point at token 0 with gate 0 and are
    masked dead inside the kernel, so they accumulate exactly zero and the
    gate-weighted combine ignores them.
    """

    tok_idx: np.ndarray     # [n_rows] int32 — token index per row (0 on pads)
    gates: np.ndarray       # [n_rows] float32 — combine weight (0 on pads)
    expert_off: np.ndarray  # [E + 1] int32 — expert e owns rows [off[e], off[e+1])
    loads: np.ndarray       # [E] int64 — live routed rows per expert
    n_rows: int             # bt-aligned total rows (>= n_routed)
    n_routed: int           # live rows (== T * top_k)
    n_tokens: int

    @property
    def n_experts(self) -> int:
        return len(self.expert_off) - 1

    def expert_loads(self) -> np.ndarray:
        """Live routed rows per expert — the raw router skew."""
        return self.loads


def route_to_tasks(
    idx, gates, n_experts: int, bt: int = 8
) -> Tuple[List[ExpertTask], RoutedSet]:
    """Lower concrete top-k routing to expert tiles.

    ``idx``: [T, k] int expert choices; ``gates``: [T, k] float combine
    weights (already normalized).  Grouping is stable in (token, choice)
    order within each expert, so the layout is deterministic.
    """
    idx = np.asarray(idx)
    gates = np.asarray(gates, dtype=np.float32)
    T, k = idx.shape
    assert gates.shape == (T, k), (gates.shape, (T, k))

    flat_e = idx.reshape(-1)
    flat_t = np.repeat(np.arange(T, dtype=np.int32), k)
    flat_g = gates.reshape(-1)
    # stable counting sort by expert: contiguous per-expert row ranges
    order = np.argsort(flat_e, kind="stable")
    loads = np.bincount(flat_e, minlength=n_experts).astype(np.int64)
    padded = -(-loads // bt) * bt  # bt-aligned range per expert
    expert_off = np.zeros(n_experts + 1, dtype=np.int32)
    np.cumsum(padded, out=expert_off[1:])
    n_rows = max(bt, int(expert_off[-1]))

    tok_idx = np.zeros(n_rows, dtype=np.int32)
    gate_rows = np.zeros(n_rows, dtype=np.float32)
    src = 0
    for e in range(n_experts):
        lo = int(expert_off[e])
        ln = int(loads[e])
        tok_idx[lo: lo + ln] = flat_t[order[src: src + ln]]
        gate_rows[lo: lo + ln] = flat_g[order[src: src + ln]]
        src += ln

    tasks: List[ExpertTask] = []
    tid = 0
    for e in range(n_experts):
        start = int(expert_off[e])
        for i in range(0, int(loads[e]), bt):
            rl = min(bt, int(loads[e]) - i)
            tasks.append(ExpertTask(expert=e, row_start=start + i, row_len=rl,
                                    tid=tid, cost=rl))
            tid += 1

    return tasks, RoutedSet(
        tok_idx=tok_idx,
        gates=gate_rows,
        expert_off=expert_off,
        loads=loads,
        n_rows=n_rows,
        n_routed=T * k,
        n_tokens=T,
    )


def row_divisor(tasks: Sequence[ExpertTask], mult, n_rows: int) -> np.ndarray:
    """Per-row multiplicity divisor (the expert-family analogue of
    ``tasks.multiplicity_divisor``): each live row belongs to exactly one
    tile, so dividing its accumulated output by that tile's execution count
    is exact.  Pad rows (gate 0, accumulate 0) keep divisor 1.
    """
    mult = np.asarray(mult)
    div = np.ones((n_rows,), dtype=np.float32)
    for t in tasks:
        div[t.row_start: t.row_start + t.row_len] = max(1, int(mult[t.tid]))
    return div


class MoEDispatchHost(PallasWSHost):
    """Expert-dispatch queue on the device array layout, for the property
    harness and the zero-cost instruction-mix audit.

    Identical protocol to :class:`PallasWSHost` — the point of the task-family
    generalization is that expert tiles ride the *same* fence-free slot
    arithmetic — but sized for per-layer expert queues and accepting encoded
    :class:`ExpertTask` payloads via :meth:`put_task`.
    """

    def __init__(self, backend=None, capacity: int = 4096, **kw):
        super().__init__(backend=backend, capacity=capacity, **kw)

    def put_task(self, task: ExpertTask) -> bool:
        return self.put(tuple(int(x) for x in task.encode()))

"""Expert-tile execution bodies for the persistent WS megakernel.

Forward: one task = ``row_len`` routed rows of one expert's gated FFN:

    gather   x[tok_idx[rs : rs + bt]]                  # [bt, d]
    FFN      silu(x @ wg[e]) * (x @ wu[e]) @ wd[e]     # [bt, f] -> [bt, d]
    scatter  out[rs : rs + bt] += y                    # contiguous accumulate

The scatter is *contiguous* because the routed rows are grouped by expert
(:mod:`repro.moe_ws.dispatch`): the task's output slice is disjoint from
every other task's, so duplicated execution under the relaxed scheduler adds
whole extra copies of the same rows — ``mult[tid]`` normalizes them out,
exactly as for attention q-blocks.  Dead pad rows of a ragged tail tile are
zeroed before the accumulate.

Backward (DESIGN.md §4.5): the *same* tile layout re-scheduled over the
transpose math.  A grad tile gathers its rows' activations and output
cotangents, replays the expert FFN, and emits the per-row pieces of the
no-drop reference VJP — ``d_x`` rows, the hidden-layer cotangents
``du``/``dv``, the recomputed hiddens ``h``, and the per-row gate cotangent
— packed side by side in one ``[bt, d + 3f + 1]`` block.  Everything a grad
tile writes is **per routed row**, hence disjoint across tiles, hence
idempotent-accumulable under duplication exactly like the forward; the
per-expert weight-grad reductions (outer-product segment sums over
``row_src``/experts) happen outside the kernel on the multiplicity-
normalized rows.

The Take/Steal protocol, the lockstep clocks, and the queue arrays are the
shared machinery of :mod:`repro.pallas_ws.kernel` — this module only
supplies the ``execute`` bodies and the launch wrappers.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.pallas_ws.kernel import WSRunResult, launch_ws_grid
from repro.pallas_ws.queues import QueueState
from repro.pallas_ws.tasks import F_E, F_RL, F_RS


def _expert_execute(rec, pure, out_ref, *, bt: int):
    """Gather–FFN–scatter-accumulate for one expert tile.  ``rec(field)``
    reads one field of the claimed task record (layout-agnostic — the shell
    resolves dense vs shared-pool slot addressing)."""
    tok_idx_ref, x_ref, wg_ref, wu_ref, wd_ref = pure
    e = rec(F_E)
    rs = rec(F_RS)
    rl = rec(F_RL)

    d = x_ref.shape[-1]
    f = wg_ref.shape[-1]
    idx = tok_idx_ref[pl.ds(rs, bt)]                      # [bt]
    xt = jnp.take(x_ref[...], idx, axis=0).astype(jnp.float32)  # gather [bt, d]
    wg = wg_ref[pl.ds(e, 1)].reshape(d, f).astype(jnp.float32)
    wu = wu_ref[pl.ds(e, 1)].reshape(d, f).astype(jnp.float32)
    wd = wd_ref[pl.ds(e, 1)].reshape(f, d).astype(jnp.float32)

    h = jax.nn.silu(
        jax.lax.dot_general(xt, wg, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    ) * jax.lax.dot_general(xt, wu, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    yt = jax.lax.dot_general(h, wd, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [bt, d]

    row_live = jax.lax.broadcasted_iota(jnp.int32, (bt, d), 0) < rl
    yt = jnp.where(row_live, yt, 0.0)

    # Idempotent-accumulate into this task's disjoint routed-row slice.
    cur = out_ref[pl.ds(rs, bt), :]
    out_ref[pl.ds(rs, bt), :] = cur + yt


def dsilu(u, sig):
    """d/du silu(u) given sig = sigmoid(u) — the one implementation both
    backward evaluations (the dense transpose and this tile body) share, so
    their bit-parity cannot drift."""
    return sig * (1.0 + u * (1.0 - sig))


def _expert_grad_execute(rec, pure, out_ref, *, bt: int):
    """Transpose tile: per-row VJP pieces of one expert tile's gather–FFN.

    Emits ``[dx_row | du | dv | h | dgate]`` (width ``d + 3f + 1``) for the
    tile's ``bt`` routed rows — every output is per-row, so the accumulate
    slice is disjoint from every other tile's and duplicated execution is
    normalized by the same ``mult[tid]`` divisor as the forward."""
    tok_idx_ref, x_ref, gy_ref, gate_ref, wg_ref, wu_ref, wd_ref = pure
    e = rec(F_E)
    rs = rec(F_RS)
    rl = rec(F_RL)

    d = x_ref.shape[-1]
    f = wg_ref.shape[-1]
    idx = tok_idx_ref[pl.ds(rs, bt)]                      # [bt]
    xt = jnp.take(x_ref[...], idx, axis=0).astype(jnp.float32)   # [bt, d]
    ct = jnp.take(gy_ref[...], idx, axis=0).astype(jnp.float32)  # [bt, d]
    gr = gate_ref[pl.ds(rs, bt)].astype(jnp.float32)             # [bt]
    wg = wg_ref[pl.ds(e, 1)].reshape(d, f).astype(jnp.float32)
    wu = wu_ref[pl.ds(e, 1)].reshape(d, f).astype(jnp.float32)
    wd = wd_ref[pl.ds(e, 1)].reshape(f, d).astype(jnp.float32)

    # replay the forward tile (remat: residuals are not hauled through HBM)
    u = jax.lax.dot_general(xt, wg, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    v = jax.lax.dot_general(xt, wu, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    sig = jax.nn.sigmoid(u)
    s = u * sig                                           # silu(u)
    h = s * v
    yhat = jax.lax.dot_general(h, wd, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # [bt, d]

    # closed-form transpose of gate · (silu(x·wg) ⊙ (x·wu)) · wd
    dgate = jnp.sum(ct * yhat, axis=-1, keepdims=True)    # [bt, 1]
    dy = gr[:, None] * ct                                 # [bt, d]
    dh = jax.lax.dot_general(dy, wd, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)    # [bt, f]
    dv = dh * s
    du = dh * v * dsilu(u, sig)
    dxr = jax.lax.dot_general(du, wg, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    dxr = dxr + jax.lax.dot_general(dv, wu, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)

    block = jnp.concatenate([dxr, du, dv, h, dgate], axis=1)
    row_live = jax.lax.broadcasted_iota(jnp.int32, block.shape, 0) < rl
    block = jnp.where(row_live, block, 0.0)

    cur = out_ref[pl.ds(rs, bt), :]
    out_ref[pl.ds(rs, bt), :] = cur + block


def grad_out_width(d: int, f: int) -> int:
    """Columns of the grad launch's per-row output block:
    ``[dx (d) | du (f) | dv (f) | h (f) | dgate (1)]``."""
    return d + 3 * f + 1


def run_moe_grad_schedule(
    state: QueueState,
    x,
    gy,
    tok_idx,
    gate_rows,
    wg,
    wu,
    wd,
    *,
    bt: int,
    steal: bool = True,
    steal_policy: str = "cost",
    steal_run_cap: int = 1,
    rounds: Optional[int] = None,
    out: Optional[jax.Array] = None,
    mult: Optional[jax.Array] = None,
    compress_runs: Optional[bool] = None,
    interpret: bool = True,
    trace: bool = False,
    trace_capacity: Optional[int] = None,
) -> WSRunResult:
    """Launch the transpose (backward) megakernel over a prepared
    :class:`QueueState` — the second ``launch_ws_grid`` of the custom VJP's
    ``grad_dispatch="ws"`` path.

    ``gy``: [T, d] cotangent of the combined routed output; ``gate_rows``:
    [n_padded] per-row combine gates (``RoutedSet.gates``); the rest as
    :func:`run_moe_schedule`.  ``res.out`` is the per-row VJP block
    ``[n_padded, grad_out_width(d, f)]`` (mult-weighted accumulation —
    divide by the tile divisor before use), carried over on relaunch for
    the multiplicity drills.
    """
    n_padded = tok_idx.shape[0]
    d = x.shape[-1]
    f = wg.shape[-1]
    out = (
        jnp.zeros((n_padded, grad_out_width(d, f)), jnp.float32)
        if out is None else out
    )
    execute = functools.partial(_expert_grad_execute, bt=bt)
    return launch_ws_grid(
        state, execute, (tok_idx, x, gy, gate_rows, wg, wu, wd), out,
        steal=steal, steal_policy=steal_policy, steal_run_cap=steal_run_cap,
        rounds=rounds, mult=mult,
        compress_runs=compress_runs, interpret=interpret, trace=trace,
        trace_capacity=trace_capacity,
    )


def run_moe_schedule(
    state: QueueState,
    x,
    tok_idx,
    wg,
    wu,
    wd,
    *,
    bt: int,
    steal: bool = True,
    steal_policy: str = "cost",
    steal_run_cap: int = 1,
    rounds: Optional[int] = None,
    out: Optional[jax.Array] = None,
    mult: Optional[jax.Array] = None,
    compress_runs: Optional[bool] = None,
    interpret: bool = True,
    trace: bool = False,
    trace_capacity: Optional[int] = None,
    fault_plan=None,
) -> WSRunResult:
    """Launch the expert megakernel over a prepared :class:`QueueState`.

    ``x``: [T, d] token activations; ``tok_idx``: [n_padded] routed row →
    token map (``RoutedSet.tok_idx``); ``wg``/``wu``: [E, d, f]; ``wd``:
    [E, f, d].  ``out`` is the routed-row output [n_padded, d] (f32,
    mult-weighted accumulation), carried over on relaunch for the
    multiplicity drills.
    """
    n_padded = tok_idx.shape[0]
    d = x.shape[-1]
    out = jnp.zeros((n_padded, d), jnp.float32) if out is None else out
    execute = functools.partial(_expert_execute, bt=bt)
    return launch_ws_grid(
        state, execute, (tok_idx, x, wg, wu, wd), out,
        steal=steal, steal_policy=steal_policy, steal_run_cap=steal_run_cap,
        rounds=rounds, mult=mult,
        compress_runs=compress_runs, interpret=interpret, trace=trace,
        trace_capacity=trace_capacity, fault_plan=fault_plan,
    )

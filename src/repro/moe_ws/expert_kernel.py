"""Expert-tile execution body for the persistent WS megakernel.

One task = ``row_len`` routed rows of one expert's gated FFN:

    gather   x[tok_idx[rs : rs + bt]]                  # [bt, d]
    FFN      silu(x @ wg[e]) * (x @ wu[e]) @ wd[e]     # [bt, f] -> [bt, d]
    scatter  out[rs : rs + bt] += y                    # contiguous accumulate

The scatter is *contiguous* because the routed rows are grouped by expert
(:mod:`repro.moe_ws.dispatch`): the task's output slice is disjoint from
every other task's, so duplicated execution under the relaxed scheduler adds
whole extra copies of the same rows — ``mult[tid]`` normalizes them out,
exactly as for attention q-blocks.  Dead pad rows of a ragged tail tile are
zeroed before the accumulate.

The Take/Steal protocol, the lockstep clocks, and the queue arrays are the
shared machinery of :mod:`repro.pallas_ws.kernel` — this module only
supplies the ``execute`` body and the launch wrapper.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.pallas_ws.kernel import WSRunResult, launch_ws_grid
from repro.pallas_ws.queues import QueueState
from repro.pallas_ws.tasks import F_E, F_RL, F_RS


def _expert_execute(rec, pure, out_ref, *, bt: int):
    """Gather–FFN–scatter-accumulate for one expert tile.  ``rec(field)``
    reads one field of the claimed task record (layout-agnostic — the shell
    resolves dense vs shared-pool slot addressing)."""
    tok_idx_ref, x_ref, wg_ref, wu_ref, wd_ref = pure
    e = rec(F_E)
    rs = rec(F_RS)
    rl = rec(F_RL)

    d = x_ref.shape[-1]
    f = wg_ref.shape[-1]
    idx = tok_idx_ref[pl.ds(rs, bt)]                      # [bt]
    xt = jnp.take(x_ref[...], idx, axis=0).astype(jnp.float32)  # gather [bt, d]
    wg = wg_ref[pl.ds(e, 1)].reshape(d, f).astype(jnp.float32)
    wu = wu_ref[pl.ds(e, 1)].reshape(d, f).astype(jnp.float32)
    wd = wd_ref[pl.ds(e, 1)].reshape(f, d).astype(jnp.float32)

    h = jax.nn.silu(
        jax.lax.dot_general(xt, wg, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    ) * jax.lax.dot_general(xt, wu, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    yt = jax.lax.dot_general(h, wd, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [bt, d]

    row_live = jax.lax.broadcasted_iota(jnp.int32, (bt, d), 0) < rl
    yt = jnp.where(row_live, yt, 0.0)

    # Idempotent-accumulate into this task's disjoint routed-row slice.
    cur = out_ref[pl.ds(rs, bt), :]
    out_ref[pl.ds(rs, bt), :] = cur + yt


def run_moe_schedule(
    state: QueueState,
    x,
    tok_idx,
    wg,
    wu,
    wd,
    *,
    bt: int,
    steal: bool = True,
    steal_policy: str = "cost",
    rounds: Optional[int] = None,
    out: Optional[jax.Array] = None,
    mult: Optional[jax.Array] = None,
    compress_runs: Optional[bool] = None,
    interpret: bool = True,
) -> WSRunResult:
    """Launch the expert megakernel over a prepared :class:`QueueState`.

    ``x``: [T, d] token activations; ``tok_idx``: [n_padded] routed row →
    token map (``RoutedSet.tok_idx``); ``wg``/``wu``: [E, d, f]; ``wd``:
    [E, f, d].  ``out`` is the routed-row output [n_padded, d] (f32,
    mult-weighted accumulation), carried over on relaunch for the
    multiplicity drills.
    """
    n_padded = tok_idx.shape[0]
    d = x.shape[-1]
    out = jnp.zeros((n_padded, d), jnp.float32) if out is None else out
    execute = functools.partial(_expert_execute, bt=bt)
    return launch_ws_grid(
        state, execute, (tok_idx, x, wg, wu, wd), out,
        steal=steal, steal_policy=steal_policy, rounds=rounds, mult=mult,
        compress_runs=compress_runs, interpret=interpret,
    )

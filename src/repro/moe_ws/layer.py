"""``moe_ffn_ws`` — dropless MoE FFN on the fence-free WS tile scheduler.

Drop-in for :func:`repro.models.moe.moe_ffn` (same signature, same
``(y, aux_loss)`` return, same router math) with the dense capacity-dropping
dispatch replaced by expert-tile tasks through the ``pallas_ws`` megakernel:

* router top-k → per-expert owner queues (``dispatch.route_to_tasks``) —
  **every** routed (token, expert) pair gets a task row; there is no
  capacity factor and nothing is dropped;
* programs Take their own expert's tiles and Steal from overloaded experts'
  stale head views (plain loads/stores, no CAS/fence) — the router's
  heavy-tailed load lands as queue skew and the thieves flatten it;
* the combine divides each routed row by its tile's execution count
  (``dispatch.row_divisor``) before the gate-weighted scatter-add, so
  duplicated tile execution under the relaxed scheduler is exactly
  normalized out — multiplicity makes the dropless dispatch *cheap*, not
  merely possible.

Queue construction has two Puts behind one kernel launch: eager callers go
through the host-side ``route_to_tasks``/``make_queue_state`` (concrete
numpy, compact padding, full telemetry), traced callers through the
jit-compatible ``route_to_tasks_jax``/``make_queue_state_jax`` (fixed
shapes at the static worst case, live masks) — so ``jit(moe_ffn_ws)`` and
``scan``-over-layers run the *same dropless dispatch*, not a dense
fallback.  The two builders are certified equivalent by
tests/test_dispatch_conformance.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.pallas_ws.queues import (
    make_pool_queue_state_jax,
    make_queue_state,
    make_queue_state_jax,
)
from repro.pallas_ws.ragged import RaggedStats as DispatchStats  # family-neutral telemetry

from .dispatch import (
    divisor_from_tiles,
    expert_queue_candidates,
    expert_rounds_bound,
    route_to_tasks,
    route_to_tasks_jax,
    route_to_tasks_pool_jax,
    row_divisor,
)
from .expert_kernel import run_moe_schedule

SCHEDULES = ("ws", "static")
QUEUE_LAYOUTS = ("pool", "padded")


def _router(x_flat, p, cfg, group_size: int):
    """The dense path's router (`models.moe.router_topk` — one
    implementation, shared, so routing/aux math cannot drift between the
    dispatches), reshaped to flat [T, ...] views."""
    from repro.models.moe import router_topk

    T, d = x_flat.shape
    g = min(group_size, T)
    G = T // g
    assert G * g == T, (T, g)
    probs, gate_vals, idx, aux = router_topk(x_flat.reshape(G, g, d), p, cfg)
    k = cfg.top_k
    return (
        probs.reshape(T, cfg.n_experts),
        gate_vals.reshape(T, k),
        idx.reshape(T, k),
        aux,
    )


def _shared_experts(x_flat, p):
    hs = jax.nn.silu(jnp.einsum("td,df->tf", x_flat, p["ws_g"]))
    hs = hs * jnp.einsum("td,df->tf", x_flat, p["ws_u"])
    return jnp.einsum("tf,fd->td", hs, p["ws_d"])


def _under_autodiff(x) -> bool:
    """True when ``x`` carries a differentiation trace (grad/jvp/vjp).

    The megakernel's ``pallas_call`` uses input_output_aliases and has no
    JVP rule, so autodiff through the dispatch dies deep inside jax with an
    opaque error; peeling the tracer stack lets the layer fail fast with an
    actionable one instead.  ``jit``/``scan``/``vmap`` tracers pass through
    untouched.
    """
    from jax.interpreters import ad

    t = x
    while isinstance(t, jax.core.Tracer):
        if isinstance(t, ad.JVPTracer):
            return True
        t = getattr(t, "primal", None)
    return False


def _check_drained(state, res) -> None:
    if isinstance(res.mult, jax.core.Tracer):
        # traced launches run the static worst-case rounds bound
        # (expert_rounds_bound), which drains by construction; there is no
        # concrete mult to inspect mid-trace.
        return
    if state.pool_off is not None:
        # pool layout: live slots are exactly the pool prefix [0, Σtail)
        n_live = int(np.asarray(state.tail).sum())
    else:
        n_live = state.n_tasks
    if n_live and not (res.mult[:n_live] >= 1).all():
        missing = int((res.mult[:n_live] == 0).sum())
        raise RuntimeError(
            f"expert scheduler under-provisioned: {missing}/{n_live} "
            "tiles never executed (rounds bound too small?)"
        )


def combine_routed(routed, tasks, res, *, bt: int | None = None):
    """Multiplicity-normalized, gate-weighted combine of an expert-kernel
    run: divide each row's accumulation by its tile's execution count
    (``divisor_from_tiles``), then scatter-add ``gate * row`` back to the
    tokens.  Pad rows carry gate 0, so they vanish.  Returns
    [n_tokens, d] float32.

    ``tasks`` is the host task list; pass ``tasks=None`` with the tile
    height ``bt`` for a trace-built layout, where tile ``t`` statically owns
    rows ``[t·bt, (t+1)·bt)``.  The single combine implementation —
    `moe_ffn_ws` (both Puts), the dispatch benchmark, and the dropless
    property tests all call this.
    """
    if tasks is None:
        assert bt is not None, "traced combine needs the static tile height"
        n_tiles = res.mult.shape[0]
        starts = jnp.arange(n_tiles, dtype=jnp.int32) * bt
        div = divisor_from_tiles(starts, bt, res.mult, routed.n_rows)
    else:
        div = row_divisor(tasks, res.mult, routed.n_rows)
    yr = res.out / jnp.asarray(div)[:, None]
    return jnp.zeros((routed.n_tokens, res.out.shape[-1]), jnp.float32).at[
        jnp.asarray(routed.tok_idx)
    ].add(jnp.asarray(routed.gates)[:, None] * yr)


def expert_ffn_nodrop_ref(idx, gates, x, wg, wu, wd):
    """Raw-weight O(T·E) no-drop oracle: every expert's gated FFN applied to
    every token, combined with the routed gates.  ``x``: [T, d]; returns
    [T, d] float32."""
    xf = jnp.asarray(x).astype(jnp.float32)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xf, jnp.asarray(wg).astype(jnp.float32)))
    h = h * jnp.einsum("td,edf->tef", xf, jnp.asarray(wu).astype(jnp.float32))
    y_all = jnp.einsum("tef,efd->ted", h, jnp.asarray(wd).astype(jnp.float32))
    y_sel = jnp.take_along_axis(y_all, jnp.asarray(idx)[:, :, None], axis=1)
    return (jnp.asarray(gates)[:, :, None] * y_sel).sum(axis=1)


def moe_ffn_ws(
    x,
    p,
    cfg,
    group_size: int = 1024,
    *,
    schedule: str = "ws",
    steal_policy: str = "cost",
    queue_layout: str | None = None,
    n_programs: int = 8,
    bt: int = 8,
    interpret: bool = True,
    return_stats: bool = False,
):
    """x: [B, S, d] -> (y: [B, S, d], aux_loss scalar) — dropless WS dispatch.

    ``schedule="ws"`` steals; ``"static"`` drains owner queues only (same
    kernel and cost accounting — the makespan baseline).  ``steal_policy``
    picks the victim-selection path: ``"cost"`` (default) is the O(1)
    advisory-ranked argmax, ``"scan"`` the PR-1 full sequential scan
    (DESIGN.md §3.6).  ``bt`` is the expert-tile row count; ``n_programs``
    the persistent program count.

    Accepts tracers: under ``jit``/``scan``/``vmap`` the queues are built by
    the traced Put and the kernel runs the static ``expert_rounds_bound`` —
    still dropless, no dense fallback anywhere.  ``queue_layout`` selects
    the traced Put's arrays: ``"pool"`` (the ws default) is the compact
    shared-pool layout (``ceil(Tk/bt) + E`` tiles total,
    ``route_to_tasks_pool_jax``), ``"padded"`` the PR-3 per-expert
    worst-case layout; the static schedule regroups experts onto program
    queues and always uses ``"padded"``.  ``return_stats`` needs concrete
    telemetry and is eager-only.

    Forward-only: the megakernel (aliased pallas_call) has no JVP rule, so
    differentiating through this layer raises — training objectives must
    select ``cfg.moe_dispatch="dense"`` explicitly (ROADMAP: differentiable
    dropless dispatch via a custom VJP against the no-drop reference).
    """
    assert schedule in SCHEDULES, schedule
    assert queue_layout in (None,) + QUEUE_LAYOUTS, queue_layout
    traced = isinstance(x, jax.core.Tracer)
    if traced and return_stats:
        raise ValueError("return_stats needs concrete telemetry; call eagerly")
    if _under_autodiff(x):
        raise TypeError(
            "moe_ffn_ws is forward-only (the WS megakernel has no JVP rule): "
            "use cfg.moe_dispatch='dense' for differentiated training steps"
        )
    B, S, d = x.shape
    E = cfg.n_experts
    x_flat = x.reshape(B * S, d)
    probs, gate_vals, idx, aux = _router(x_flat, p, cfg, group_size)

    # Put: routing -> expert-tile owner queues.  With stealing every expert
    # gets its own queue (the per-expert token list); the static baseline
    # needs every queue owned by a program, so experts are placed
    # round-robin over programs — classic expert parallelism.
    n_queues = E if schedule == "ws" else n_programs
    steal = schedule == "ws"
    layout = queue_layout
    if layout is None:
        # the host Put already lays rows out compactly, so "pool" is the
        # *traced* compact layout; eager callers keep the host arrays (full
        # task-list telemetry) unless they ask for pool explicitly
        layout = "pool" if (steal and traced) else "padded"
    if layout == "pool" and not steal:
        raise ValueError(
            "queue_layout='pool' needs per-expert queues (schedule='ws'); "
            "the static baseline regroups experts onto program queues"
        )
    if traced or layout == "pool":
        # trace-compatible Put (also exercisable eagerly for pool telemetry)
        if layout == "pool":
            records, tail, pool_off, routed = route_to_tasks_pool_jax(
                idx, gate_vals, E, bt=bt
            )
            tasks = None
            state = make_pool_queue_state_jax(
                records, tail, pool_off, routed.loads, n_programs,
                n_tasks=records.shape[0],
            )
        else:
            records, live, routed = route_to_tasks_jax(idx, gate_vals, E, bt=bt)
            cand, cand_live = expert_queue_candidates(records, live, n_queues)
            tasks = None
            state = make_queue_state_jax(
                cand, cand_live, n_programs,
                n_tasks=records.shape[0] * records.shape[1],
            )
        rounds = expert_rounds_bound(B * S * cfg.top_k, bt, n_queues, n_programs, steal)
    else:
        idx_h = np.asarray(jax.device_get(idx))
        gates_h = np.asarray(jax.device_get(gate_vals))
        tasks, routed = route_to_tasks(idx_h, gates_h, E, bt=bt)
        state = make_queue_state(tasks, n_programs, n_queues=n_queues, partition="owner")
        rounds = None

    res = run_moe_schedule(
        state,
        x_flat.astype(jnp.float32),
        routed.tok_idx,
        p["we_g"], p["we_u"], p["we_d"],
        bt=bt,
        steal=steal,
        steal_policy=steal_policy,
        rounds=rounds,
        interpret=interpret,
    )
    _check_drained(state, res)

    # multiplicity-divisor normalization, then the gate-weighted combine:
    # a dropless scatter-add over every routed pair.
    y = combine_routed(routed, tasks, res, bt=bt)

    if cfg.n_shared_experts:
        y = y + _shared_experts(x_flat, p).astype(jnp.float32)
    y = y.astype(x.dtype).reshape(B, S, d)
    if return_stats:
        return y, aux, DispatchStats.from_run(schedule, state, res, steal_policy)
    return y, aux


def moe_ffn_nodrop_ref(x, p, cfg, group_size: int = 1024):
    """O(T·E) dense **no-drop** oracle: every expert applied to every token,
    combined with the routed gates — the exact answer a dropless dispatch
    must reproduce (the capacity-dropping path only approximates it)."""
    B, S, d = x.shape
    x_flat = x.reshape(B * S, d)
    _, gate_vals, idx, aux = _router(x_flat, p, cfg, group_size)
    y = expert_ffn_nodrop_ref(
        idx, gate_vals, x_flat, p["we_g"], p["we_u"], p["we_d"]
    )
    if cfg.n_shared_experts:
        y = y + _shared_experts(x_flat, p).astype(jnp.float32)
    return y.astype(x.dtype).reshape(B, S, d), aux

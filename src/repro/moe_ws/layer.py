"""``moe_ffn_ws`` — dropless MoE FFN on the fence-free WS tile scheduler.

Drop-in for :func:`repro.models.moe.moe_ffn` (same signature, same
``(y, aux_loss)`` return, same router math) with the dense capacity-dropping
dispatch replaced by expert-tile tasks through the ``pallas_ws`` megakernel:

* router top-k → per-expert owner queues (``dispatch.route_to_tasks``) —
  **every** routed (token, expert) pair gets a task row; there is no
  capacity factor and nothing is dropped;
* programs Take their own expert's tiles and Steal from overloaded experts'
  stale head views (plain loads/stores, no CAS/fence) — the router's
  heavy-tailed load lands as queue skew and the thieves flatten it;
* the combine divides each routed row by its tile's execution count
  (``dispatch.row_divisor``) before the gate-weighted scatter-add, so
  duplicated tile execution under the relaxed scheduler is exactly
  normalized out — multiplicity makes the dropless dispatch *cheap*, not
  merely possible.

Queue construction has two Puts behind one kernel launch: eager callers go
through the host-side ``route_to_tasks``/``make_queue_state`` (concrete
numpy, compact padding, full telemetry), traced callers through the
jit-compatible ``route_to_tasks_jax``/``make_queue_state_jax`` (fixed
shapes at the static worst case, live masks) — so ``jit(moe_ffn_ws)`` and
``scan``-over-layers run the *same dropless dispatch*, not a dense
fallback.  The two builders are certified equivalent by
tests/test_dispatch_conformance.py.

The dispatch is **differentiable** (DESIGN.md §4.5): the routed-expert core
carries a ``jax.custom_vjp`` whose forward runs the megakernel and whose
backward is the closed-form gather–FFN–scatter transpose of
:func:`expert_ffn_nodrop_ref` — the no-drop function the scheduler provably
computes, so its VJP is *the* VJP of the dispatch regardless of which
steal/duplication schedule the forward happened to execute.  The backward
restricts the reference transpose to the routed pairs (never O(T·E)):
``grad_dispatch="dense"`` evaluates it with plain gathers/scatter-adds over
the flat ``[T·k]`` pair list, ``grad_dispatch="ws"`` re-schedules the
per-row transpose tiles through a second ``launch_ws_grid`` launch on the
same shared-pool queue layout (``run_moe_grad_schedule``).  Router gates
and the aux loss live *outside* the custom VJP, so their gradients flow
through the ordinary jnp router math unchanged.  Certified by
tests/test_moe_ws_grad.py.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.pallas_ws.queues import (
    make_pool_queue_state_jax,
    make_queue_state,
    make_queue_state_jax,
)
from repro.pallas_ws.ragged import RaggedStats as DispatchStats  # family-neutral telemetry

from .dispatch import (
    divisor_from_tiles,
    expert_queue_candidates,
    expert_rounds_bound,
    route_to_tasks,
    route_to_tasks_jax,
    route_to_tasks_pool_jax,
    row_divisor,
)
from .expert_kernel import dsilu, run_moe_grad_schedule, run_moe_schedule

SCHEDULES = ("ws", "static")
QUEUE_LAYOUTS = ("pool", "padded")
GRAD_DISPATCHES = ("dense", "ws")


def _router(x_flat, p, cfg, group_size: int):
    """The dense path's router (`models.moe.router_topk` — one
    implementation, shared, so routing/aux math cannot drift between the
    dispatches), reshaped to flat [T, ...] views."""
    from repro.models.moe import router_topk

    T, d = x_flat.shape
    g = min(group_size, T)
    G = T // g
    assert G * g == T, (T, g)
    probs, gate_vals, idx, aux = router_topk(x_flat.reshape(G, g, d), p, cfg)
    k = cfg.top_k
    return (
        probs.reshape(T, cfg.n_experts),
        gate_vals.reshape(T, k),
        idx.reshape(T, k),
        aux,
    )


def _shared_experts(x_flat, p):
    hs = jax.nn.silu(jnp.einsum("td,df->tf", x_flat, p["ws_g"]))
    hs = hs * jnp.einsum("td,df->tf", x_flat, p["ws_u"])
    return jnp.einsum("tf,fd->td", hs, p["ws_d"])


class _CoreStatic(NamedTuple):
    """Hashable launch configuration of the routed-expert core — the
    nondiff leading argument of the custom VJP (shapes/knobs only, no
    arrays)."""

    n_experts: int
    schedule: str
    steal_policy: str
    queue_layout: Optional[str]
    grad_dispatch: str
    n_programs: int
    bt: int
    interpret: bool
    steal_run_cap: int = 1


def _check_drained(state, res) -> None:
    if isinstance(res.mult, jax.core.Tracer):
        # traced launches run the static worst-case rounds bound
        # (expert_rounds_bound), which drains by construction; there is no
        # concrete mult to inspect mid-trace.
        return
    if state.pool_off is not None:
        # pool layout: live slots are exactly the pool prefix [0, Σtail)
        n_live = int(np.asarray(state.tail).sum())
    else:
        n_live = state.n_tasks
    if n_live and not (res.mult[:n_live] >= 1).all():
        missing = int((res.mult[:n_live] == 0).sum())
        raise RuntimeError(
            f"expert scheduler under-provisioned: {missing}/{n_live} "
            "tiles never executed (rounds bound too small?)"
        )


def combine_routed(routed, tasks, res, *, bt: int | None = None):
    """Multiplicity-normalized, gate-weighted combine of an expert-kernel
    run: divide each row's accumulation by its tile's execution count
    (``divisor_from_tiles``), then scatter-add ``gate * row`` back to the
    tokens.  Pad rows carry gate 0, so they vanish.  Returns
    [n_tokens, d] float32.

    ``tasks`` is the host task list; pass ``tasks=None`` with the tile
    height ``bt`` for a trace-built layout, where tile ``t`` statically owns
    rows ``[t·bt, (t+1)·bt)``.  The single combine implementation —
    `moe_ffn_ws` (both Puts), the dispatch benchmark, and the dropless
    property tests all call this.
    """
    if tasks is None:
        assert bt is not None, "traced combine needs the static tile height"
        n_tiles = res.mult.shape[0]
        starts = jnp.arange(n_tiles, dtype=jnp.int32) * bt
        div = divisor_from_tiles(starts, bt, res.mult, routed.n_rows)
    else:
        div = row_divisor(tasks, res.mult, routed.n_rows)
    yr = res.out / jnp.asarray(div)[:, None]
    return jnp.zeros((routed.n_tokens, res.out.shape[-1]), jnp.float32).at[
        jnp.asarray(routed.tok_idx)
    ].add(jnp.asarray(routed.gates)[:, None] * yr)


def expert_ffn_nodrop_ref(idx, gates, x, wg, wu, wd):
    """Raw-weight O(T·E) no-drop oracle: every expert's gated FFN applied to
    every token, combined with the routed gates.  ``x``: [T, d]; returns
    [T, d] float32."""
    xf = jnp.asarray(x).astype(jnp.float32)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xf, jnp.asarray(wg).astype(jnp.float32)))
    h = h * jnp.einsum("td,edf->tef", xf, jnp.asarray(wu).astype(jnp.float32))
    y_all = jnp.einsum("tef,efd->ted", h, jnp.asarray(wd).astype(jnp.float32))
    y_sel = jnp.take_along_axis(y_all, jnp.asarray(idx)[:, :, None], axis=1)
    return (jnp.asarray(gates)[:, :, None] * y_sel).sum(axis=1)


def _dispatch_and_run(static: _CoreStatic, x_flat, idx, gate_vals, wg, wu, wd,
                      trace: bool = False):
    """Put + megakernel launch + multiplicity-normalized combine — the
    routed-expert core shared by the custom VJP's primal/forward and the
    telemetry path.  Returns ``(y_routed [T, d] f32, state, res, routed,
    tasks)``; ``trace=True`` records event rings on the launch."""
    E, schedule = static.n_experts, static.schedule
    n_programs, bt = static.n_programs, static.bt
    T, k = idx.shape
    traced = any(
        isinstance(a, jax.core.Tracer) for a in (x_flat, idx, gate_vals)
    )

    # Put: routing -> expert-tile owner queues.  With stealing every expert
    # gets its own queue (the per-expert token list); the static baseline
    # needs every queue owned by a program, so experts are placed
    # round-robin over programs — classic expert parallelism.
    n_queues = E if schedule == "ws" else n_programs
    steal = schedule == "ws"
    layout = static.queue_layout
    if layout is None:
        # the host Put already lays rows out compactly, so "pool" is the
        # *traced* compact layout; eager callers keep the host arrays (full
        # task-list telemetry) unless they ask for pool explicitly
        layout = "pool" if (steal and traced) else "padded"
    if layout == "pool" and not steal:
        raise ValueError(
            "queue_layout='pool' needs per-expert queues (schedule='ws'); "
            "the static baseline regroups experts onto program queues"
        )
    if traced or layout == "pool":
        # trace-compatible Put (also exercisable eagerly for pool telemetry)
        if layout == "pool":
            records, tail, pool_off, routed = route_to_tasks_pool_jax(
                idx, gate_vals, E, bt=bt
            )
            tasks = None
            state = make_pool_queue_state_jax(
                records, tail, pool_off, routed.loads, n_programs,
                n_tasks=records.shape[0],
            )
        else:
            records, live, routed = route_to_tasks_jax(idx, gate_vals, E, bt=bt)
            cand, cand_live = expert_queue_candidates(records, live, n_queues)
            tasks = None
            state = make_queue_state_jax(
                cand, cand_live, n_programs,
                n_tasks=records.shape[0] * records.shape[1],
            )
        rounds = expert_rounds_bound(
            T * k, bt, n_queues, n_programs, steal,
            steal_run_cap=static.steal_run_cap,
        )
    else:
        idx_h = np.asarray(jax.device_get(idx))
        gates_h = np.asarray(jax.device_get(gate_vals))
        tasks, routed = route_to_tasks(idx_h, gates_h, E, bt=bt)
        state = make_queue_state(tasks, n_programs, n_queues=n_queues, partition="owner")
        rounds = None

    res = run_moe_schedule(
        state,
        x_flat.astype(jnp.float32),
        routed.tok_idx,
        wg, wu, wd,
        bt=bt,
        steal=steal,
        steal_policy=static.steal_policy,
        steal_run_cap=static.steal_run_cap if steal else 1,
        rounds=rounds,
        interpret=static.interpret,
        trace=trace,
    )

    # multiplicity-divisor normalization, then the gate-weighted combine:
    # a dropless scatter-add over every routed pair.
    y = combine_routed(routed, tasks, res, bt=bt)
    return y, state, res, routed, tasks


def _core_primal(static: _CoreStatic, x_flat, idx, gate_vals, wg, wu, wd):
    y, state, res, _, _ = _dispatch_and_run(
        static, x_flat, idx, gate_vals, wg, wu, wd
    )
    _check_drained(state, res)
    return y


def _grad_dense(x_flat, idx, gate_vals, wg, wu, wd, gy):
    """Closed-form VJP of the no-drop routed-expert function, evaluated
    directly over the flat ``[T·k]`` routed pair list with plain
    gathers/scatter-adds — the always-available transpose (no scheduler, no
    pads, no masks).  Returns ``(dx [T,d], dgates [T,k], dwg, dwu, dwd)``
    in f32."""
    T, d = x_flat.shape
    k = idx.shape[1]
    f = wg.shape[-1]
    fe = jnp.asarray(idx, jnp.int32).reshape(-1)
    ft = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    fg = jnp.asarray(gate_vals, jnp.float32).reshape(-1)
    xf = jnp.asarray(x_flat, jnp.float32)
    wg32 = jnp.asarray(wg, jnp.float32)
    wu32 = jnp.asarray(wu, jnp.float32)
    wd32 = jnp.asarray(wd, jnp.float32)

    xr = xf[ft]                                   # [Tk, d] gather
    ct = gy[ft]                                   # [Tk, d] cotangent gather
    wg_r = wg32[fe]
    wu_r = wu32[fe]
    wd_r = wd32[fe]
    u = jnp.einsum("rd,rdf->rf", xr, wg_r)
    v = jnp.einsum("rd,rdf->rf", xr, wu_r)
    sig = jax.nn.sigmoid(u)
    s = u * sig
    h = s * v
    yhat = jnp.einsum("rf,rfd->rd", h, wd_r)      # unweighted pair output
    dgates = jnp.sum(ct * yhat, axis=-1).reshape(T, k)
    dy = fg[:, None] * ct
    dh = jnp.einsum("rd,rfd->rf", dy, wd_r)
    dv = dh * s
    du = dh * v * dsilu(u, sig)
    dxr = (jnp.einsum("rf,rdf->rd", du, wg_r)
           + jnp.einsum("rf,rdf->rd", dv, wu_r))
    dx = jnp.zeros((T, d), jnp.float32).at[ft].add(dxr)
    dwg = jnp.zeros((wg.shape[0], d, f), jnp.float32).at[fe].add(
        xr[:, :, None] * du[:, None, :]
    )
    dwu = jnp.zeros((wu.shape[0], d, f), jnp.float32).at[fe].add(
        xr[:, :, None] * dv[:, None, :]
    )
    dwd = jnp.zeros((wd.shape[0], f, d), jnp.float32).at[fe].add(
        h[:, :, None] * dy[:, None, :]
    )
    return dx, dgates, dwg, dwu, dwd


def _grad_ws(static: _CoreStatic, x_flat, idx, gate_vals, wg, wu, wd, gy):
    """The same transpose with its d-gather/d-FFN tiles re-scheduled through
    a second fence-free ``launch_ws_grid`` launch (``run_moe_grad_schedule``)
    on the shared-pool queue layout — per-row outputs are disjoint across
    tiles, so backward duplication is multiplicity-normalized exactly like
    the forward, and the weight-grad segment reductions run on the
    normalized rows."""
    E, bt, P = static.n_experts, static.bt, static.n_programs
    T, d = x_flat.shape
    k = idx.shape[1]
    f = wg.shape[-1]
    Tk = T * k

    # re-derive the routing residuals (pure, certified function of the saved
    # idx/gates — cheaper than hauling the padded queue arrays through the
    # residual pytree under scan/remat)
    records, tail, pool_off, routed = route_to_tasks_pool_jax(
        idx, gate_vals, E, bt=bt
    )
    state = make_pool_queue_state_jax(
        records, tail, pool_off, routed.loads, P, n_tasks=records.shape[0],
    )
    rounds = expert_rounds_bound(
        Tk, bt, E, P, True, steal_run_cap=static.steal_run_cap
    )
    res = run_moe_grad_schedule(
        state, jnp.asarray(x_flat, jnp.float32), gy,
        routed.tok_idx, routed.gates, wg, wu, wd,
        bt=bt, steal=True, steal_policy=static.steal_policy,
        steal_run_cap=static.steal_run_cap, rounds=rounds,
        interpret=static.interpret,
    )
    # an unexecuted grad tile would contribute exactly-zero gradients (the
    # divisor clamps at 1), so under-provisioning must raise here exactly
    # as it does on the forward path
    _check_drained(state, res)
    return _assemble_row_grads(
        res, routed, idx, x_flat, gy, bt=bt, d=d, f=f, n_experts=E
    )


def _assemble_row_grads(res, routed, idx, x_flat, gy, *, bt, d, f, n_experts):
    """Normalize a grad launch's per-row output block by the tile
    multiplicity divisor, then scatter it into the core's cotangents:
    ``dx`` by routed row -> token, ``dgates`` by row -> (token, choice) via
    ``RoutedSet.row_src``, and the per-expert weight grads as outer-product
    segment sums over the rows' experts.  Split out so the multiplicity
    drills can drive it on adversarially re-executed launches."""
    T, k = idx.shape
    Tk = T * k
    n_tiles = res.mult.shape[0]
    starts = jnp.arange(n_tiles, dtype=jnp.int32) * bt
    div = divisor_from_tiles(starts, bt, res.mult, routed.n_rows)
    G = jnp.asarray(res.out) / jnp.asarray(div)[:, None]
    dxr = G[:, :d]
    du = G[:, d: d + f]
    dv = G[:, d + f: d + 2 * f]
    h = G[:, d + 2 * f: d + 3 * f]
    dgate_rows = G[:, -1]

    tok = jnp.asarray(routed.tok_idx)
    grow = jnp.asarray(routed.gates, jnp.float32)
    src = jnp.asarray(routed.row_src)
    live = src < Tk
    fe_all = jnp.asarray(idx, jnp.int32).reshape(-1)
    row_e = jnp.where(live, fe_all[jnp.clip(src, 0, Tk - 1)], 0)

    xr = jnp.asarray(x_flat, jnp.float32)[tok]
    dy = grow[:, None] * gy[tok]                  # 0 on pad rows (gate 0)
    dx = jnp.zeros((T, d), jnp.float32).at[tok].add(dxr)
    dwg = jnp.zeros((n_experts, d, f), jnp.float32).at[row_e].add(
        xr[:, :, None] * du[:, None, :]
    )
    dwu = jnp.zeros((n_experts, d, f), jnp.float32).at[row_e].add(
        xr[:, :, None] * dv[:, None, :]
    )
    dwd = jnp.zeros((n_experts, f, d), jnp.float32).at[row_e].add(
        h[:, :, None] * dy[:, None, :]
    )
    # pad rows scatter their (zero) gate cotangent to the sacrificial slot Tk
    dgates = (
        jnp.zeros((Tk + 1,), jnp.float32)
        .at[jnp.minimum(src, Tk)].add(dgate_rows)[:Tk]
        .reshape(T, k)
    )
    return dx, dgates, dwg, dwu, dwd


def _core_fwd(static, x_flat, idx, gate_vals, wg, wu, wd):
    y = _core_primal(static, x_flat, idx, gate_vals, wg, wu, wd)
    # residual contract (DESIGN.md §4.5): the routing is a pure certified
    # function of (idx, gates), so the residuals are exactly the core's
    # inputs — nothing scheduler-side (queue arrays, mult, schedule order)
    # may enter the backward.
    return y, (x_flat, idx, gate_vals, wg, wu, wd)


def _core_bwd(static, resids, gy):
    x_flat, idx, gate_vals, wg, wu, wd = resids
    gy = jnp.asarray(gy, jnp.float32)
    if static.grad_dispatch == "ws":
        dx, dgates, dwg, dwu, dwd = _grad_ws(
            static, x_flat, idx, gate_vals, wg, wu, wd, gy
        )
    else:
        dx, dgates, dwg, dwu, dwd = _grad_dense(
            x_flat, idx, gate_vals, wg, wu, wd, gy
        )
    d_idx = np.zeros(idx.shape, jax.dtypes.float0)  # int routing: no tangent
    return (
        dx.astype(x_flat.dtype),
        d_idx,
        dgates.astype(gate_vals.dtype),
        dwg.astype(wg.dtype),
        dwu.astype(wu.dtype),
        dwd.astype(wd.dtype),
    )


_moe_ws_core = jax.custom_vjp(_core_primal, nondiff_argnums=(0,))
_moe_ws_core.defvjp(_core_fwd, _core_bwd)


def expert_ffn_ws(
    idx,
    gates,
    x,
    wg,
    wu,
    wd,
    *,
    schedule: str = "ws",
    steal_policy: str = "cost",
    steal_run_cap: int = 1,
    queue_layout: str | None = None,
    grad_dispatch: str = "dense",
    n_programs: int = 8,
    bt: int = 8,
    interpret: bool = True,
):
    """Router-free routed-expert core on the WS scheduler — the
    differentiable twin of :func:`expert_ffn_nodrop_ref` (same argument
    order, same [T, d] f32 return), carrying the custom VJP.  ``idx`` is
    integer routing (no tangent); ``gates``/``x``/weights differentiate
    against the no-drop reference math."""
    assert schedule in SCHEDULES, schedule
    assert queue_layout in (None,) + QUEUE_LAYOUTS, queue_layout
    assert grad_dispatch in GRAD_DISPATCHES, grad_dispatch
    static = _CoreStatic(
        n_experts=wg.shape[0], schedule=schedule, steal_policy=steal_policy,
        queue_layout=queue_layout, grad_dispatch=grad_dispatch,
        n_programs=n_programs, bt=bt, interpret=bool(interpret),
        steal_run_cap=int(steal_run_cap),
    )
    return _moe_ws_core(
        static, jnp.asarray(x), jnp.asarray(idx, jnp.int32),
        jnp.asarray(gates, jnp.float32), wg, wu, wd,
    )


def moe_ffn_ws(
    x,
    p,
    cfg,
    group_size: int = 1024,
    *,
    schedule: str = "ws",
    steal_policy: str = "cost",
    steal_run_cap: int = 1,
    queue_layout: str | None = None,
    grad_dispatch: str = "dense",
    n_programs: int = 8,
    bt: int = 8,
    interpret: bool = True,
    return_stats: bool = False,
    trace: bool = False,
):
    """x: [B, S, d] -> (y: [B, S, d], aux_loss scalar) — dropless WS dispatch.

    ``schedule="ws"`` steals; ``"static"`` drains owner queues only (same
    kernel and cost accounting — the makespan baseline).  ``steal_policy``
    picks the victim-selection path: ``"cost"`` (default) is the O(1)
    advisory-ranked argmax, ``"scan"`` the PR-1 full sequential scan
    (DESIGN.md §3.6).  ``steal_run_cap > 1`` (cost policy) amortizes Steal:
    one probe claims up to ``min(ceil(rem/2), cap)`` contiguous victim tiles
    (half-run rule — §3.6); the default ``1`` keeps the bit-identical
    per-tile lowering.  ``bt`` is the expert-tile row count; ``n_programs``
    the persistent program count.

    Accepts tracers: under ``jit``/``scan``/``vmap`` the queues are built by
    the traced Put and the kernel runs the static ``expert_rounds_bound`` —
    still dropless, no dense fallback anywhere.  ``queue_layout`` selects
    the traced Put's arrays: ``"pool"`` (the ws default) is the compact
    shared-pool layout (``ceil(Tk/bt) + E`` tiles total,
    ``route_to_tasks_pool_jax``), ``"padded"`` the PR-3 per-expert
    worst-case layout; the static schedule regroups experts onto program
    queues and always uses ``"padded"``.  ``return_stats`` needs concrete
    telemetry and is eager-only; ``trace=True`` (with ``return_stats``)
    additionally records per-extraction event rings and attaches the
    decoded :class:`~repro.wstrace.trace.WSTrace` to the stats.

    **Differentiable** (DESIGN.md §4.5): the routed-expert core carries a
    ``jax.custom_vjp`` whose backward is the closed-form transpose of the
    no-drop reference restricted to the routed pairs — ``grad_dispatch``
    selects its evaluation: ``"dense"`` (default) plain gathers/scatters,
    ``"ws"`` a second megakernel launch over the same tile layout.  Router
    and aux-loss gradients flow outside the VJP unchanged, so
    ``jax.grad``/``value_and_grad`` of a loss through this layer — eager,
    jitted, or scanned-over-layers — trains the dropless dispatch.
    """
    assert schedule in SCHEDULES, schedule
    assert queue_layout in (None,) + QUEUE_LAYOUTS, queue_layout
    assert grad_dispatch in GRAD_DISPATCHES, grad_dispatch
    traced = isinstance(x, jax.core.Tracer)
    if traced and return_stats:
        raise ValueError("return_stats needs concrete telemetry; call eagerly")
    if trace and not return_stats:
        raise ValueError("trace=True attaches the WSTrace to the stats; "
                         "pass return_stats=True as well")
    B, S, d = x.shape
    x_flat = x.reshape(B * S, d)
    probs, gate_vals, idx, aux = _router(x_flat, p, cfg, group_size)

    static = _CoreStatic(
        n_experts=cfg.n_experts, schedule=schedule, steal_policy=steal_policy,
        queue_layout=queue_layout, grad_dispatch=grad_dispatch,
        n_programs=n_programs, bt=bt, interpret=bool(interpret),
        steal_run_cap=int(steal_run_cap),
    )
    if return_stats:
        # eager telemetry path: same impl, no VJP wrapper in the way
        y, state, res, _, _ = _dispatch_and_run(
            static, x_flat, idx, gate_vals, p["we_g"], p["we_u"], p["we_d"],
            trace=trace,
        )
        _check_drained(state, res)
    else:
        y = _moe_ws_core(
            static, x_flat, idx, gate_vals, p["we_g"], p["we_u"], p["we_d"]
        )

    if cfg.n_shared_experts:
        y = y + _shared_experts(x_flat, p).astype(jnp.float32)
    y = y.astype(x.dtype).reshape(B, S, d)
    if return_stats:
        return y, aux, DispatchStats.from_run(schedule, state, res, steal_policy)
    return y, aux


def moe_ffn_nodrop_ref(x, p, cfg, group_size: int = 1024):
    """O(T·E) dense **no-drop** oracle: every expert applied to every token,
    combined with the routed gates — the exact answer a dropless dispatch
    must reproduce (the capacity-dropping path only approximates it)."""
    B, S, d = x.shape
    x_flat = x.reshape(B * S, d)
    _, gate_vals, idx, aux = _router(x_flat, p, cfg, group_size)
    y = expert_ffn_nodrop_ref(
        idx, gate_vals, x_flat, p["we_g"], p["we_u"], p["we_d"]
    )
    if cfg.n_shared_experts:
        y = y + _shared_experts(x_flat, p).astype(jnp.float32)
    return y.astype(x.dtype).reshape(B, S, d), aux

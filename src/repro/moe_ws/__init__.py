"""repro.moe_ws — dropless MoE expert dispatch on the fence-free WS scheduler.

MoE routing is the most skewed real workload in this repo (top-k over
160–384 experts with heavy-tailed loads), and the dense dispatch's answer —
fixed per-expert capacity, over-capacity tokens dropped — is exactly the
static-schedule trade the paper's work stealing removes.  Per-expert token
lists become WS task queues (:mod:`dispatch`), expert FFN tiles run through
the shared ``pallas_ws`` megakernel machinery (:mod:`expert_kernel`), and
multiplicity-divisor normalization in the combine makes duplicated tile
execution harmless (:mod:`layer`) — a **dropless** dispatch whose makespan
under router skew beats the dropping dense path (benchmarks/moe_dispatch.py).
The dispatch is differentiable: a ``jax.custom_vjp`` on the routed-expert
core backpropagates the closed-form no-drop-reference transpose
(``grad_dispatch="dense"`` plain gathers/scatters, ``"ws"`` a second
megakernel launch), so training steps run the scheduler too.
See DESIGN.md §4 (§4.5 for the VJP).

Attribute access is lazy (PEP 562) so jax-free consumers — the ``moe-ws``
entry in ``repro.core.ALGORITHMS`` only needs :mod:`dispatch`'s host shim —
never pay the jax import.
"""

_EXPORTS = {
    "MoEDispatchHost": "dispatch",
    "RoutedSet": "dispatch",
    "divisor_from_tiles": "dispatch",
    "expert_queue_candidates": "dispatch",
    "expert_rounds_bound": "dispatch",
    "route_to_tasks": "dispatch",
    "route_to_tasks_jax": "dispatch",
    "route_to_tasks_pool_jax": "dispatch",
    "row_divisor": "dispatch",
    "grad_out_width": "expert_kernel",
    "run_moe_grad_schedule": "expert_kernel",
    "run_moe_schedule": "expert_kernel",
    "DispatchStats": "layer",
    "GRAD_DISPATCHES": "layer",
    "combine_routed": "layer",
    "expert_ffn_nodrop_ref": "layer",
    "expert_ffn_ws": "layer",
    "moe_ffn_nodrop_ref": "layer",
    "moe_ffn_ws": "layer",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    try:
        module = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}") from None
    import importlib

    return getattr(importlib.import_module(f".{module}", __name__), name)


def __dir__():
    return __all__

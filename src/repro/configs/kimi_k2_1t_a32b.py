"""kimi-k2-1t-a32b — trillion-parameter MoE [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8) d_ff(expert)=2048 vocab=163840;
MoE 384 routed top-8 + 1 shared expert.  Trains with the factored-second-
moment optimizer + ZeRO over ("data","pod") — AdamW fp32 states for 1T
params exceed 2 v5e pods (see EXPERIMENTS.md §Dry-run).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab_size=163840,
    n_experts=384, n_shared_experts=1, top_k=8, moe_d_ff=2048,
    dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="kimi-k2-1t-a32b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=32, vocab_size=256,
    n_experts=8, n_shared_experts=1, top_k=2, moe_d_ff=32, capacity_factor=8.0,
    )

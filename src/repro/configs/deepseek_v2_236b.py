"""deepseek-v2-236b — MLA + MoE [arXiv:2405.04434; hf].

60L d_model=5120 128H d_ff(expert)=1536 vocab=102400; MLA kv_lora=512
q_lora=1536 rope_head_dim=64; MoE 2 shared + 160 routed top-6.
Deviation from the HF checkpoint (recorded in DESIGN.md): the assignment
spec lists all layers MoE, so first_k_dense=0 here.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=1536, vocab_size=102400, attn_kind="mla",
    kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64, v_head_dim=128,
    n_experts=160, n_shared_experts=2, top_k=6, moe_d_ff=1536,
    dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="deepseek-v2-236b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=32, vocab_size=256, attn_kind="mla",
    kv_lora_rank=16, q_lora_rank=16, rope_head_dim=8, v_head_dim=16,
    n_experts=8, n_shared_experts=2, top_k=2, moe_d_ff=32, capacity_factor=8.0,
    )

"""minicpm-2b — dense MHA, tied embeddings, depth-scaled residuals, trained
with the WSD schedule (wired in repro.optim) [arXiv:2404.06395; hf].

40L d_model=2304 36H (GQA kv=36 = MHA) d_ff=5760 vocab=122753.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab_size=122753, tie_embeddings=True,
    depth_scaled_residual=True, dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="minicpm-2b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=250, tie_embeddings=True, depth_scaled_residual=True,
)

"""Assigned-architecture registry: ``--arch <id>`` resolves here.

Each module exports CONFIG (the exact assigned full-size config) and SMOKE
(a reduced same-family config for CPU smoke tests).  ``cell_plan(cfg)``
returns which of the four assigned input shapes run vs. skip (with the
reason), per the mandate's sub-quadratic / encoder-decoder rules.
"""

from __future__ import annotations

import importlib
from typing import Dict

from repro.models.config import SHAPES, ModelConfig, ShapeConfig

ARCH_IDS = (
    "llama3.2-3b",
    "h2o-danube-1.8b",
    "minicpm-2b",
    "gemma3-12b",
    "deepseek-v2-236b",
    "kimi-k2-1t-a32b",
    "pixtral-12b",
    "whisper-base",
    "mamba2-2.7b",
    "zamba2-2.7b",
)

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MOD:
        raise KeyError(f"unknown arch {arch!r}; known: {list(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{_MOD[arch]}")
    return mod.SMOKE if smoke else mod.CONFIG


def cell_plan(cfg: ModelConfig) -> Dict[str, str]:
    """shape name -> 'run' or 'skip: <reason>' (see DESIGN.md §4/§7)."""
    plan = {}
    for name, sh in SHAPES.items():
        if cfg.family == "encdec" and sh.kind == "decode":
            plan[name] = (
                "skip: encoder-decoder audio backbone has no 32k/500k decode "
                "context (whisper native decoder ctx 448)"
            )
            continue
        if name == "long_500k":
            windowed = any(w > 0 for w in cfg.layer_windows)
            if cfg.family not in ("ssm", "hybrid") and not windowed:
                plan[name] = (
                    "skip: pure full-attention arch; 500k decode needs "
                    "sub-quadratic attention (mandate rule)"
                )
                continue
        plan[name] = "run"
    return plan

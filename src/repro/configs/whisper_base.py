"""whisper-base — encoder-decoder audio backbone [arXiv:2212.04356; unverified].

6L enc + 6L dec, d_model=512 8H d_ff=2048 vocab=51865.  The conv frontend
is a STUB per the mandate: input_specs() provides precomputed frame
embeddings [B, 1500, 512] to the encoder.  decode_32k/long_500k skip
(native decoder context 448) — see configs.cell_plan.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_enc_layers=6, n_dec_layers=6,
    d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=51865, frontend="frames", enc_seq_len=1500,
    dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="whisper-base-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, n_dec_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, frontend="frames", enc_seq_len=16,
)

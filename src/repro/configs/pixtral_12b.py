"""pixtral-12b — VLM: pixtral-ViT + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409; unverified].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.  Per the mandate
the ViT frontend is a STUB: input_specs()/the data pipeline provide
precomputed patch embeddings [B, n_patches, d_model] prepended to the text
sequence; loss is over text positions only.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=131072, frontend="patch", n_patches=256,
    rope_theta=1_000_000.0, dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="pixtral-12b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256, frontend="patch", n_patches=4,
)

"""gemma3-12b — dense GQA with 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt; unverified].

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144; head_dim 256,
local window 1024, RoPE theta 1M (global layers).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab_size=262144, window=1024, locals_per_global=5,
    rope_theta=1_000_000.0, dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="gemma3-12b-smoke", family="dense",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, window=8, locals_per_global=5,
)

"""mamba2-2.7b — attention-free SSD state-space model [arXiv:2405.21060;
unverified].  64L d_model=2560 vocab=50280 ssm_state=128; expand 2 ->
d_inner 5120, headdim 64 -> 80 SSD heads, chunk 128.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_chunk=128,
    dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="mamba2-2.7b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab_size=256,
    ssm_state=16, ssm_expand=2, ssm_headdim=8, ssm_chunk=8,
)

"""zamba2-2.7b — hybrid: mamba2 backbone + two alternating SHARED attention
blocks applied every 6 layers [arXiv:2411.15242; hf].

54L d_model=2560 32H (GQA kv=32) d_ff=10240 (shared-block MLP)
ssm_state=64.  Shared-block weights are counted once (2 sets).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_chunk=128,
    hybrid_attn_every=6, dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="zamba2-2.7b-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256,
    ssm_state=16, ssm_expand=2, ssm_headdim=8, ssm_chunk=8,
    hybrid_attn_every=2,
)

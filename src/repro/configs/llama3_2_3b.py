"""llama3.2-3b — dense GQA decoder [hf:meta-llama/Llama-3.2-1B; unverified].

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256; RoPE theta 500k.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab_size=128256, rope_theta=500000.0, dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="llama3.2-3b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256, rope_theta=10000.0,
)

"""Atomic, resumable, elastically reshardable checkpoints.

Layout: <dir>/step_<N>/ containing
  manifest.json — pytree structure, per-leaf shape/dtype, logical sharding
                  axes (mesh-independent), framework metadata.
  arrays.npz    — leaf data keyed by flattened path ("params/layers/attn/wq").

Design points (the 1000-node story):
* *Atomicity* — written to step_<N>.tmp-<nonce> then os.rename'd; a crash
  mid-save can never corrupt the latest checkpoint; restore picks the
  largest complete step directory.
* *Elasticity* — the manifest stores LOGICAL shardings (the models.sharding
  rule names), not device assignments; `restore(..., mesh=new_mesh)` lays
  leaves out for a *different* mesh shape than the one that saved them
  (tested: save on 1x4, restore on 2x2).
* *Async* — AsyncCheckpointer snapshots to host memory synchronously
  (cheap) and writes in a background thread, overlapping the next training
  steps; `wait()` joins before the next save or on exit.
* On multi-host deployments each host writes its addressable shards to
  arrays-<host>.npz; on this single-process container that degenerates to
  one file, but the format keeps the host dimension.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "shape"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def save(directory: str, step: int, tree: Any, metadata: Optional[dict] = None):
    """Atomically write `tree` (pytree of arrays) as step `step`."""
    os.makedirs(directory, exist_ok=True)
    flat, treedef = jax.tree_util.tree_flatten(tree)
    paths = jax.tree_util.tree_map_with_path(
        lambda p, _: jax.tree_util.keystr(p), tree
    )
    flat_paths = jax.tree_util.tree_leaves(paths)
    manifest = {
        "step": int(step),
        "treedef": str(treedef),
        "leaves": [
            {"path": p, "shape": list(x.shape), "dtype": str(jnp.asarray(x).dtype)}
            for p, x in zip(flat_paths, flat)
        ],
        "metadata": metadata or {},
    }
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp-", dir=directory)
    try:
        arrays = {p: np.asarray(x) for p, x in zip(flat_paths, flat)}
        np.savez(os.path.join(tmp, "arrays-0.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and ".tmp-" not in name:
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, like: Any, step: Optional[int] = None, shardings: Any = None):
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings`: optional pytree of NamedShardings for a
    possibly *different* mesh — elastic reshard-on-load (data is placed
    according to the new mesh, not the saving one).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(d, "arrays-0.npz")) as z:
        data = {k: z[k] for k in z.files}

    paths = jax.tree_util.tree_map_with_path(lambda p, _: jax.tree_util.keystr(p), like)
    flat_paths = jax.tree_util.tree_leaves(paths)
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    flat_sh = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(flat_like)
    )
    leaves = []
    for p, lk, sh in zip(flat_paths, flat_like, flat_sh):
        if p not in data:
            raise KeyError(f"checkpoint missing leaf {p}")
        arr = data[p]
        want = tuple(lk.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"shape mismatch at {p}: ckpt {arr.shape} vs model {want}")
        arr = arr.astype(np.dtype(lk.dtype))
        leaves.append(jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr))
    return treedef.unflatten(leaves), step


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write in a background thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot now

        def _write():
            try:
                save(self.directory, step, host_tree, metadata)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and ".tmp-" not in n
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

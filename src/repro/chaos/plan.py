"""Seeded, deterministic fault plans for the fence-free scheduler stack.

The paper's safety argument (arXiv:2008.04424 §7) is adversarial by
construction: a stale fence-free ``head`` write may rewind a queue and hand
one task to several programs, and WS-WMULT's answer is *bounded
multiplicity*, not prevention.  :class:`FaultPlan` turns the ad-hoc rewind
drills the test suites grew into one reproducible object: every fault —
program stalls, head-rewind storms, advisory corruption, kill-and-relaunch
— is derived from a single integer seed, so a failing storm replays
bit-for-bit from its plan.

Faults are injected as *data*, never as kernel code:

* **stalls** — per-program initial clock offsets: program ``p`` with stall
  ``k`` is "busy" until round ``k`` and extracts nothing before then.  The
  megakernel's lockstep clock already models busy programs, so a stall is
  just a nonzero initial value for ``clock[p]``.
* **advisory corruption** — garbage ``remaining[q]`` summaries (zeros /
  reversed / random), modeling arbitrarily stale or dropped plain-write
  advisory updates.  Selection quality only: the ``head < tail`` victim
  mask alone guarantees progress.
* **head-rewind storms** — between launch segments, drag ``head[q]`` back
  to drawn targets and wipe drawn ``local_head`` rows: the forced stale
  republish of §7, re-arming already-claimed slots for re-extraction.
* **kill-and-relaunch** — run a segment with a deliberately under-provisioned
  round budget (the "killed" partial launch), then resume a fresh launch
  from the surviving queue arrays.

Because every fault is an initial-array value or a host-side mutation
between launches, ``fault_plan=None`` and a zero plan lower to the *same*
``pallas_call`` — injection is free when off, the same bar ``trace=False``
meets (verified by tests/test_chaos.py and the zero-cost audit).

This module is numpy-only at import time (jax is imported lazily inside
the tracer-aware helpers) so the host shim and the test fixtures can use
it without a device runtime.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

ADVISORY_MODES = ("exact", "zeros", "reversed", "random")


def _is_tracer(x) -> bool:
    try:
        import jax

        return isinstance(x, jax.core.Tracer)
    except ImportError:  # numpy-only environment: nothing can be a tracer
        return False


def corrupt_advisory(remaining, mode: str, seed: int = 0):
    """Return an adversarially stale copy of the ``remaining[q]`` advisory
    summaries: garbage the cost-aware victim selection must survive
    (selection quality only — never correctness, never progress).

    ``mode``: ``"exact"`` (unchanged), ``"zeros"``, ``"reversed"``, or
    ``"random"`` (seeded, bounded by twice the true maximum).  Works on
    concrete numpy arrays and on traced jnp values (the corruption itself
    is plain data, so it composes with jitted queue builds).
    """
    assert mode in ADVISORY_MODES, mode
    if mode == "exact":
        return remaining
    if _is_tracer(remaining) or not isinstance(remaining, np.ndarray):
        import jax.numpy as jnp

        remaining = jnp.asarray(remaining, jnp.int32)
        if mode == "zeros":
            return jnp.zeros_like(remaining)
        if mode == "reversed":
            return remaining[::-1]
        rng = np.random.RandomState(seed)
        hi = 2 * max(1, int(remaining.shape[0]) * 64)
        return jnp.asarray(
            rng.randint(0, hi, size=remaining.shape).astype(np.int32)
        )
    remaining = np.asarray(remaining, np.int32)
    if mode == "zeros":
        return np.zeros_like(remaining)
    if mode == "reversed":
        return remaining[::-1].copy()
    rng = np.random.RandomState(seed)
    hi = 1 + 2 * int(remaining.max(initial=1))
    return rng.randint(0, hi, size=remaining.shape).astype(np.int32)


def seed_advisory(state, mode: str, rng=None):
    """In-place advisory corruption of a host-built ``QueueState`` (the
    drill the steal-policy suite grew; ``rng`` may be a
    ``np.random.RandomState`` for the legacy call shape)."""
    from repro.pallas_ws.queues import queue_costs

    true = np.asarray(queue_costs(state), dtype=np.int32)
    if mode == "random":
        rng = rng if rng is not None else np.random.RandomState(0)
        hi = 1 + 2 * int(true.max(initial=1))
        state.remaining = rng.randint(0, hi, size=true.shape).astype(np.int32)
    elif mode == "exact":
        state.remaining = true
    else:
        state.remaining = corrupt_advisory(true, mode)
    return state


@dataclass(frozen=True)
class RewindSpec:
    """One head-rewind storm: the forced stale republish of §7.

    ``head_targets[q]`` (present keys only) is the stale value republished
    to ``head[q]`` — must be ≤ the current head, exactly what a delayed
    plain write could legally contain.  ``wiped`` lists the programs whose
    persistent ``local_head`` rows are reset to 0 (fresh thieves with no
    local bound).  ``advisory`` optionally re-corrupts the cost summaries
    on top (the worst staleness for victim selection).
    """

    head_targets: Dict[int, int] = field(default_factory=dict)
    wiped: Tuple[int, ...] = ()
    advisory: str = "exact"
    advisory_seed: int = 0

    @classmethod
    def full(cls, state) -> "RewindSpec":
        """Every head dragged to 0, every local bound wiped — the maximal
        §7 staleness (the classic multiplicity-normalization drill)."""
        return cls(
            head_targets={q: 0 for q in range(state.n_queues)},
            wiped=tuple(range(state.n_programs)),
        )

    @classmethod
    def draw(cls, state, draw_int, draw_bool, *, heads=None,
             advisory_modes: Sequence[str] = ("exact",)) -> "RewindSpec":
        """Draw a storm from a ``draw_int``/``draw_bool`` source (hypothesis
        or a seeded rng): per-queue optional rewind to a target ≤ the
        current head (``heads`` overrides where to read current heads —
        conformance drills pass the *post-run* heads so the same spec is
        valid for two layout-parity states), per-program optional wipe,
        and an optional advisory corruption mode."""
        cur = np.asarray(state.head if heads is None else heads)
        targets = {}
        for q in range(state.n_queues):
            if draw_bool():
                targets[q] = draw_int(0, max(0, int(cur[q])))
        wiped = tuple(p for p in range(state.n_programs) if draw_bool())
        mode = advisory_modes[draw_int(0, len(advisory_modes) - 1)] \
            if len(advisory_modes) > 1 else advisory_modes[0]
        return cls(head_targets=targets, wiped=wiped, advisory=mode,
                   advisory_seed=draw_int(0, 2**16))


def apply_rewind(state, spec: RewindSpec):
    """Apply one :class:`RewindSpec` to a host-built ``QueueState`` in
    place (numpy arrays).  Returns the state for chaining.  The same spec
    can be applied to several layout-parity states — the mutation depends
    only on the spec, never on the state's contents."""
    head = np.asarray(state.head)
    local = np.asarray(state.local_head)
    for q, tgt in spec.head_targets.items():
        head[q] = tgt
    for p in spec.wiped:
        local[p] = 0
    state.head, state.local_head = head, local
    if spec.advisory != "exact":
        seed_advisory(state, spec.advisory,
                      np.random.RandomState(spec.advisory_seed))
    return state


@dataclass(frozen=True)
class FaultPlan:
    """A complete seeded fault schedule for one scheduler run.

    Scheduler-side fields (consumed by ``launch_ws_grid`` and
    :func:`repro.chaos.inject.run_with_faults`):

    * ``stalls`` — per-program initial stall rounds (padded with 0 to P).
    * ``advisory`` — launch-time advisory corruption mode.
    * ``kills`` — round budgets of killed partial launches: each entry runs
      a segment with that many rounds, then a fresh launch resumes from the
      surviving queue state.
    * ``storms`` — number of head-rewind storms injected between segments
      (specs drawn deterministically from ``seed``).
    * ``full_first_storm`` — make storm 0 the maximal rewind (every head to
      0, every local wiped) so the classic mult==2 drill is a plan.

    Host-shim fields (consumed by :class:`repro.pallas_ws.host.PallasWSHost`):

    * ``drop_advisory_every`` — drop every n-th advisory update (a lost
      plain write).
    * ``stale_head_every`` — after every n-th successful claim, republish
      the *pre-claim* head value (a §7 stale write racing the claim).
    """

    seed: int = 0
    stalls: Tuple[int, ...] = ()
    advisory: str = "exact"
    kills: Tuple[int, ...] = ()
    storms: int = 0
    full_first_storm: bool = False
    drop_advisory_every: int = 0
    stale_head_every: int = 0

    def __post_init__(self):
        assert self.advisory in ADVISORY_MODES, self.advisory
        assert all(k >= 1 for k in self.kills), self.kills
        assert self.storms >= 0 and self.drop_advisory_every >= 0
        assert self.stale_head_every >= 0

    # -- deterministic derivation --------------------------------------
    def rng(self, salt: int = 0) -> np.random.RandomState:
        return np.random.RandomState((self.seed ^ (0x9E37 * (salt + 1))) % 2**31)

    @classmethod
    def from_seed(cls, seed: int, *, max_stall: int = 3, max_kills: int = 2,
                  max_storms: int = 2, n_programs: int = 4) -> "FaultPlan":
        """Draw a whole plan from one integer — the hypothesis-friendly
        constructor: any int32 names a reproducible storm."""
        rng = np.random.RandomState(seed % 2**31)
        stalls = tuple(int(v) for v in rng.randint(0, max_stall + 1,
                                                   size=n_programs))
        advisory = ADVISORY_MODES[rng.randint(0, len(ADVISORY_MODES))]
        kills = tuple(int(v) for v in
                      rng.randint(1, 4, size=rng.randint(0, max_kills + 1)))
        storms = int(rng.randint(0, max_storms + 1))
        return cls(seed=seed, stalls=stalls, advisory=advisory, kills=kills,
                   storms=storms, full_first_storm=bool(rng.randint(0, 2)))

    @property
    def is_off(self) -> bool:
        """True when the plan injects nothing — must behave exactly like
        ``fault_plan=None`` (the bit-parity contract)."""
        return (not any(self.stalls) and self.advisory == "exact"
                and not self.kills and self.storms == 0
                and self.drop_advisory_every == 0
                and self.stale_head_every == 0)

    @property
    def max_stall(self) -> int:
        return max(self.stalls, default=0)

    def stall_vector(self, n_programs: int) -> np.ndarray:
        """[n_programs] int32 initial clock values (stalls padded with 0)."""
        v = np.zeros((n_programs,), np.int32)
        s = np.asarray(self.stalls[:n_programs], np.int32)
        v[: s.shape[0]] = s
        return v

    def launch_remaining(self, remaining):
        """The advisory summaries the first launch segment starts from."""
        return corrupt_advisory(remaining, self.advisory, self.seed)

    def storm_specs(self, state) -> List[RewindSpec]:
        """The plan's rewind storms, drawn deterministically from the seed
        against the given state's shape (targets are drawn ≤ capacity and
        clamped to the live head at apply time by the injector)."""
        specs = []
        for i in range(self.storms):
            if i == 0 and self.full_first_storm:
                specs.append(RewindSpec.full(state))
                continue
            rng = self.rng(salt=100 + i)
            draw_int = lambda lo, hi: int(rng.randint(lo, hi + 1))  # noqa: E731
            draw_bool = lambda: bool(rng.randint(0, 2))  # noqa: E731
            specs.append(RewindSpec.draw(
                state, draw_int, draw_bool,
                advisory_modes=("exact", "zeros", "reversed", "random"),
            ))
        return specs

    def without_launch_faults(self) -> "FaultPlan":
        """The plan with the per-launch injections stripped (stalls and
        advisory corruption apply to segment 0 only — resumed segments
        start from the surviving arrays)."""
        return replace(self, stalls=(), advisory="exact")


def resume_state(state, res):
    """A launch-resume snapshot: the queue state a *fresh* launch continues
    from after a kill or a storm — surviving shared arrays (head, local
    bounds, announcements, advisory) copied out of the previous launch's
    result, task arrays unchanged.  Host layouts only (numpy)."""
    state.head = np.array(res.head)
    state.local_head = np.array(res.local_head)
    state.taken = np.array(res.taken)
    state.remaining = np.array(res.remaining)
    return state

"""Segmented fault-injection driver for the fence-free megakernel.

A :class:`repro.chaos.plan.FaultPlan` names a deterministic sequence of
launch *segments*:

    [kill × len(plan.kills)]  [storm × plan.storms]  [final]

* the first segment starts from the pristine queue state with the plan's
  launch faults applied (program stalls via the initial clock vector,
  advisory corruption via ``remaining``);
* a **kill** segment runs with a deliberately under-provisioned round
  budget — the launch dies mid-schedule; the next segment resumes from the
  surviving shared arrays (head / local bounds / announcements / advisory),
  exactly the state a relaunch after a preempted kernel would see;
* a **storm** segment first applies a head-rewind storm (stale ``head``
  republishes + wiped ``local_head`` rows, clamped to legally-stale values
  ≤ the current head) and then relaunches with a full round budget;
* the **final** segment always runs with the full Graham budget from a
  fresh clock, so every surviving task drains.

Each segment records its start snapshot (head, local bounds) and its
decoded trace stream; :mod:`repro.chaos.checker` replays the paper's §7
contract over those records.  Outputs and multiplicity counters are
carried across segments (``out=``/``mult=`` relaunch kwargs), so the final
``out`` is the duplicated accumulation that multiplicity normalization
must recover the fault-free answer from.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, List, Optional

import numpy as np

from repro.chaos.plan import FaultPlan, RewindSpec, apply_rewind, resume_state


@dataclass
class Segment:
    """One launch segment plus the snapshot the checker needs."""

    kind: str                 # "initial" | "kill" | "storm" | "final"
    budget: int               # rounds provisioned for this launch
    start_head: np.ndarray    # [n_queues] head at segment start (post-fault)
    start_local: np.ndarray   # [n_programs, n_queues] local bounds at start
    stream: np.ndarray        # decoded (round, prog)-sorted events
                              #   [n, ring.EVENT_WIDTH]
    dropped: int              # ring-overflow drops in this segment
    res: object               # the raw WSRunResult


@dataclass
class ChaosRunResult:
    plan: FaultPlan
    segments: List[Segment]
    rounds_full: int
    tails: Optional[np.ndarray] = None  # [n_queues] static queue tails

    @property
    def res(self):
        """The final segment's WSRunResult (carried out / mult / arrays)."""
        return self.segments[-1].res

    @property
    def mult(self) -> np.ndarray:
        return np.asarray(self.res.mult)

    @property
    def dropped(self) -> int:
        return sum(s.dropped for s in self.segments)


def _clamped(spec: RewindSpec, head: np.ndarray) -> RewindSpec:
    """Storm targets are drawn at plan time against the queue *capacity*;
    clamp them to the live head so every republish is a legally-stale
    value (a plain write can only resurface something head once held)."""
    tgts = {q: min(t, int(head[q])) for q, t in spec.head_targets.items()}
    return dataclasses.replace(spec, head_targets=tgts)


def run_with_faults(state, launch: Callable, plan: Optional[FaultPlan], *,
                    rounds: int) -> ChaosRunResult:
    """Drive ``launch`` through the plan's segment sequence.

    ``launch(state, *, rounds, out, mult, fault_plan)`` must run the
    schedule with ``trace=True`` and return a ``WSRunResult`` (see
    tests/test_chaos.py for the one-line wrappers around
    ``run_moe_schedule`` / ``run_ws_schedule``).  ``rounds`` is the
    fault-free Graham budget; segment 0 gets ``plan.max_stall`` extra
    rounds so stalled programs still meet the bound.
    """
    from repro.wstrace.ring import decode_rings

    plan = plan if plan is not None else FaultPlan()
    specs = plan.storm_specs(state)

    # (kind, budget, rewind-spec-or-None); the final segment always runs
    # the full budget so the schedule is guaranteed to drain
    seq = [("kill", int(k), None) for k in plan.kills]
    seq += [("storm", rounds, s) for s in specs]
    seq += [("final", rounds, None)]

    segments: List[Segment] = []
    out = mult = None
    for i, (kind, budget, spec) in enumerate(seq):
        if i > 0:
            resume_state(state, segments[-1].res)
        if spec is not None:
            apply_rewind(state, _clamped(spec, np.asarray(state.head)))
        seg_plan = plan if i == 0 else None
        if i == 0:
            budget += plan.max_stall
        start_head = np.array(state.head)
        start_local = np.array(state.local_head)
        res = launch(state, rounds=budget, out=out, mult=mult,
                     fault_plan=seg_plan)
        stream, dropped = decode_rings(np.asarray(res.events),
                                       np.asarray(res.ev_cursor))
        segments.append(Segment(kind=kind, budget=budget,
                                start_head=start_head,
                                start_local=start_local,
                                stream=stream,
                                dropped=int(np.sum(dropped)), res=res))
        out, mult = res.out, res.mult

    return ChaosRunResult(plan=plan, segments=segments, rounds_full=rounds,
                          tails=np.array(state.tail))

"""Relaxed-semantics safety checker for fault-injected scheduler runs.

WS-WMULT's contract under arbitrary asynchrony (arXiv:2008.04424 §7) is
*work-stealing with multiplicity*: a task may run more than once, but

1. **no lost task** — every Put task is extracted at least once;
2. **bounded multiplicity** — a slot is re-extractable only when a stale
   ``head`` republish (a storm) or a wiped ``local_head`` (a fresh thief)
   re-arms it; within one launch a program's ``local_head`` is strictly
   increasing, so each (program, queue, slot) is claimed at most once, and
   per round no slot is claimed twice;
3. **exactness via normalization** — outputs accumulated with duplicates,
   divided by the multiplicity counters, are bit-identical to a fault-free
   run.

The checker replays those clauses over a :class:`repro.chaos.inject.
ChaosRunResult`: each segment's decoded trace stream plus its start-of-
segment snapshot (head, local bounds).  The multiplicity bound is checked
in its *exact* form — claims of slot ``(q, s)`` in a segment require a
program whose effective head view at segment start was ≤ ``s``, so

    mult(q, s)  ≤  #{segments i : start_head_i[q] ≤ s < tail[q]
                                  and min_p start_local_i[p, q] ≤ s}

which specializes to the paper's "1 + concurrent thieves" phrasing: one
claim for the pristine segment plus one per storm that re-armed the slot.
All checks are numpy-only; violations carry enough detail to replay the
offending plan from its seed.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.wstrace.ring import (  # noqa: F401  (EV_* re-exported for tests)
    EV_KIND, EV_PROG, EV_QUEUE, EV_ROUND, EV_SLOT, EV_TID,
)


@dataclass
class Violation:
    kind: str
    detail: str

    def __str__(self) -> str:  # pragma: no cover - repr convenience
        return f"[{self.kind}] {self.detail}"


@dataclass
class ChaosReport:
    ok: bool
    violations: List[Violation]
    max_mult: int
    n_claims: int
    n_tasks: int
    dropped: int
    # "bitwise" (exact replay / exact normalization), "close" (within
    # float-normalization tolerance), "diverged", or None (not checked)
    normalized_parity: Optional[str] = None
    stats: dict = field(default_factory=dict)

    def summary(self) -> dict:
        return dict(ok=self.ok, max_mult=self.max_mult,
                    n_claims=self.n_claims, n_tasks=self.n_tasks,
                    dropped=self.dropped,
                    normalized_parity=self.normalized_parity,
                    violations=[str(v) for v in self.violations])


class SafetyChecker:
    """Verify the §7 contract over a segmented fault-injection run."""

    def check(self, chaos, *, n_tasks: int,
              normalized=None, oracle_normalized=None,
              oracle_accumulated=None, row_mult=None,
              rtol: float = 1e-6, atol: float = 1e-6) -> ChaosReport:
        """``chaos`` is a :class:`repro.chaos.inject.ChaosRunResult` from a
        traced run.  Output parity vs the fault-free oracle comes in two
        strengths:

        * **exact replay** (``oracle_accumulated`` [rows, ...] — the
          fault-free accumulated output, every row mult 1 — plus
          ``row_mult`` [rows]): rows whose every element comes from ONE
          tile (the moe layout) accumulate the *same* float value mult
          times, so the checker replays that float addition and demands
          the faulted output **bitwise** — the "mult-normalized outputs
          bit-identical to the fault-free run" clause in its exact-
          arithmetic form;
        * **normalized closeness** (``normalized`` / ``oracle_normalized``):
          multi-source rows (attention: several k-tiles per output element,
          each duplicated independently) normalize by division, where
          float non-associativity costs ULPs — compared with
          ``rtol``/``atol`` (same bar as the repo's rewind drills)."""
        violations: List[Violation] = []
        segs = chaos.segments
        final = chaos.res
        mult = np.asarray(final.mult)[:n_tasks]
        dropped = chaos.dropped

        # -- clause 1: no lost task ------------------------------------
        lost = np.flatnonzero(mult < 1)
        if lost.size:
            violations.append(Violation(
                "lost-task",
                f"tasks {lost[:8].tolist()} never executed (mult==0)"))

        # -- stream / counter balance (exact when nothing overflowed) --
        claims = np.zeros((n_tasks,), np.int64)
        for seg in segs:
            if seg.stream.shape[0]:
                tids = seg.stream[:, EV_TID]
                live = (tids >= 0) & (tids < n_tasks)
                np.add.at(claims, tids[live], 1)
        if dropped == 0 and not np.array_equal(claims, mult.astype(np.int64)):
            bad = np.flatnonzero(claims != mult)
            violations.append(Violation(
                "stream-mult-mismatch",
                f"trace stream claim counts != mult for tids "
                f"{bad[:8].tolist()} (stream {claims[bad[:8]].tolist()} vs "
                f"mult {mult[bad[:8]].tolist()})"))

        # -- clause 2a: per-segment (program, queue, slot) uniqueness --
        # a program's local_head is strictly increasing within a launch,
        # so no program can re-extract a slot it already claimed
        for i, seg in enumerate(segs):
            if not seg.stream.shape[0]:
                continue
            keys = (seg.stream[:, EV_PROG], seg.stream[:, EV_QUEUE],
                    seg.stream[:, EV_SLOT])
            _, counts = np.unique(np.stack(keys, 1), axis=0,
                                  return_counts=True)
            if (counts > 1).any():
                violations.append(Violation(
                    "program-reclaim",
                    f"segment {i} ({seg.kind}): a program claimed the same "
                    f"(queue, slot) twice within one launch"))

        # -- clause 2b: per (segment, round) no slot claimed twice -----
        for i, seg in enumerate(segs):
            if not seg.stream.shape[0]:
                continue
            keys = np.stack((seg.stream[:, EV_ROUND], seg.stream[:, EV_QUEUE],
                             seg.stream[:, EV_SLOT]), 1)
            _, counts = np.unique(keys, axis=0, return_counts=True)
            if (counts > 1).any():
                violations.append(Violation(
                    "round-double-claim",
                    f"segment {i} ({seg.kind}): a slot was claimed twice "
                    f"in the same round"))

        # -- clause 2c: the multiplicity bound -------------------------
        # claims of (q, s) in segment i need an effective head view ≤ s at
        # segment start: head_i[q] ≤ s and some program's local bound ≤ s
        if dropped == 0:
            per_slot: dict = {}
            armed: dict = {}
            for i, seg in enumerate(segs):
                h = np.asarray(seg.start_head)
                lo = np.asarray(seg.start_local).min(axis=0)  # [n_queues]
                for ev in seg.stream:
                    q, s = int(ev[EV_QUEUE]), int(ev[EV_SLOT])
                    per_slot[(q, s)] = per_slot.get((q, s), 0) + 1
                for (q, s) in per_slot:
                    if h[q] <= s and lo[q] <= s:
                        armed[(q, s, i)] = True
            for (q, s), n in per_slot.items():
                bound = sum(1 for i in range(len(segs))
                            if armed.get((q, s, i)))
                if n > bound:
                    violations.append(Violation(
                        "multiplicity-bound",
                        f"slot (q={q}, s={s}) claimed {n}× but only "
                        f"{bound} segment(s) had it armed (stale-republish "
                        f"bound exceeded)"))

        # -- drain: the final full-budget segment must finish the queue -
        head = np.asarray(final.head)
        tails = getattr(chaos, "tails", None)
        if tails is not None and (head < np.asarray(tails)).any():
            q = np.flatnonzero(head < np.asarray(tails))
            violations.append(Violation(
                "not-drained",
                f"queues {q.tolist()} still hold unextracted slots after "
                f"the final full-budget segment"))

        # -- clause 3: output parity vs the fault-free oracle ----------
        parity: Optional[str] = None
        if oracle_accumulated is not None and row_mult is not None:
            got = np.asarray(final.out)
            orc = np.asarray(oracle_accumulated)
            m = np.asarray(row_mult).astype(np.int64)
            acc = np.zeros_like(orc)
            armed_rows = m.reshape(m.shape + (1,) * (orc.ndim - m.ndim))
            for i in range(int(m.max(initial=0))):
                acc = np.where(armed_rows > i,
                               (acc + orc).astype(orc.dtype), acc)
            if np.array_equal(acc, got):
                parity = "bitwise"
            else:
                parity = "diverged"
                bad = np.flatnonzero(acc.ravel() != got.ravel())[:4]
                violations.append(Violation(
                    "normalized-parity",
                    f"faulted accumulation is not the exact float replay "
                    f"of the fault-free output × mult (first diffs at "
                    f"flat idx {bad.tolist()})"))
        elif normalized is not None and oracle_normalized is not None:
            a = np.asarray(normalized)
            b = np.asarray(oracle_normalized)
            if a.shape == b.shape and np.array_equal(a, b):
                parity = "bitwise"
            elif a.shape == b.shape and np.allclose(a, b, rtol=rtol,
                                                    atol=atol):
                parity = "close"
            else:
                parity = "diverged"
                where = (np.flatnonzero(
                    ~np.isclose(a, b, rtol=rtol, atol=atol))[:4].tolist()
                    if a.shape == b.shape else "shape mismatch")
                violations.append(Violation(
                    "normalized-parity",
                    f"mult-normalized output differs from the fault-free "
                    f"oracle (first diffs at flat idx {where})"))

        return ChaosReport(
            ok=not violations,
            violations=violations,
            max_mult=int(mult.max(initial=0)),
            n_claims=int(claims.sum()),
            n_tasks=int(n_tasks),
            dropped=int(dropped),
            normalized_parity=parity,
            stats=dict(
                segments=[dict(kind=s.kind, budget=int(s.budget),
                               events=int(s.stream.shape[0]),
                               dropped=int(s.dropped)) for s in segs],
            ),
        )

"""Fault plans for the serving layer (frontend + engine step).

Two small, seedable descriptions consumed by
:mod:`repro.serving.engine`:

* :class:`ReplicaCrashPlan` — kill replicas at chosen frontend iterations.
  The frontend collects the dead replica's in-flight requests and
  re-admits them to survivors **idempotently**: the resume request's
  prompt is the original prompt plus the tokens already emitted, its
  budget is the remaining budget, and completion reassembly splices the
  pre-crash emission back in front — so a request's final stream is
  identical to an uninterrupted run (greedy decode is deterministic) and
  no token is ever emitted twice.  The dead replica's *queue* survives the
  crash: queued-but-unadmitted work is stolen by the survivors, which is
  the paper's whole point.

* :class:`EngineFaultPlan` — per-step faults inside one
  ``ContinuousBatcher``: ``poison_steps`` corrupts the unified launch's
  logits to NaN (a wedged kernel), ``slow_steps`` inflates the observed
  step latency past the watchdog deadline.  Both trigger the unified→split
  graceful-degradation fallback rather than a crash or a wrong token.

Both plans are data-only (no engine imports) so chaos stays a leaf
package.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple


@dataclass(frozen=True)
class ReplicaCrashPlan:
    """``crash_at[replica] = frontend iteration`` at which that replica's
    batcher dies (slots lost, queue surviving)."""

    crash_at: Dict[int, int] = field(default_factory=dict)

    def due(self, iteration: int):
        return [r for r, it in self.crash_at.items() if it == iteration]


@dataclass(frozen=True)
class EngineFaultPlan:
    """Per-step fault injection for one ``ContinuousBatcher``."""

    poison_steps: Tuple[int, ...] = ()   # unified logits -> NaN at these steps
    slow_steps: Tuple[int, ...] = ()     # observed latency += added_latency_s
    added_latency_s: float = 1e9

    def poisons(self, step_idx: int) -> bool:
        return step_idx in self.poison_steps

    def slows(self, step_idx: int) -> bool:
        return step_idx in self.slow_steps

"""repro.chaos — deterministic fault injection + relaxed-semantics safety
checking for the fence-free work-stealing stack (DESIGN.md §9).

Scheduler layer: :class:`FaultPlan` (seeded stalls / advisory corruption /
head-rewind storms / kill-and-relaunch) driven through launch segments by
:func:`run_with_faults`, with :class:`SafetyChecker` verifying the paper's
§7 contract (no lost task, bounded multiplicity, normalized bit-parity)
over the trace rings.  Serving layer: :class:`ReplicaCrashPlan` and
:class:`EngineFaultPlan` for replica crashes and watchdog drills.
"""

from repro.chaos.checker import ChaosReport, SafetyChecker, Violation
from repro.chaos.inject import ChaosRunResult, Segment, run_with_faults
from repro.chaos.plan import (
    ADVISORY_MODES,
    FaultPlan,
    RewindSpec,
    apply_rewind,
    corrupt_advisory,
    resume_state,
    seed_advisory,
)
from repro.chaos.serving import EngineFaultPlan, ReplicaCrashPlan

__all__ = [
    "ADVISORY_MODES",
    "ChaosReport",
    "ChaosRunResult",
    "EngineFaultPlan",
    "FaultPlan",
    "ReplicaCrashPlan",
    "RewindSpec",
    "SafetyChecker",
    "Segment",
    "Violation",
    "apply_rewind",
    "corrupt_advisory",
    "resume_state",
    "run_with_faults",
    "seed_advisory",
]

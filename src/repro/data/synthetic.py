"""Deterministic synthetic corpus.

Offline environment => no real datasets; we generate a *learnable*
deterministic token stream (orderless-ngram-ish: next token is a hash of a
short context window plus a slowly-varying topic id), so e2e training runs
show a genuinely decreasing loss rather than noise-floor flatlining.

Documents have heavy-tailed lengths; `pack_documents` packs them into
fixed-length rows and reports per-row document counts — the data-dependent
work skew that feeds the L1 work-stealing scheduler's `tails`.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def _hash(a: np.ndarray) -> np.ndarray:
    a = (a ^ 61) ^ (a >> 16)
    a = (a + (a << 3)) & 0xFFFFFFFF
    a = a ^ (a >> 4)
    a = (a * 0x27D4EB2D) & 0xFFFFFFFF
    return a ^ (a >> 15)


@dataclass
class SyntheticCorpus:
    vocab_size: int
    seed: int = 0
    context: int = 3

    def document(self, doc_id: int, length: int) -> np.ndarray:
        """Deterministic pseudo-document; learnable local structure."""
        rng = np.random.RandomState((self.seed * 1_000_003 + doc_id) % (2**31))
        topic = rng.randint(0, 64)
        toks = np.zeros(length, dtype=np.int64)
        lead = min(self.context, length)
        toks[:lead] = rng.randint(1, self.vocab_size, size=lead)
        base = np.uint32((topic * 2654435761 + self.seed) & 0xFFFFFFFF)
        for i in range(self.context, length):
            ctx = np.uint32(0)
            for j in range(1, self.context + 1):
                ctx = np.uint32(ctx * 1000003) ^ np.uint32(toks[i - j])
            toks[i] = int(_hash(np.uint32(ctx ^ base))) % (self.vocab_size - 1) + 1
        return toks

    def doc_lengths(self, n_docs: int, mean_len: int) -> np.ndarray:
        """Heavy-tailed (lognormal) document lengths >= 8."""
        rng = np.random.RandomState(self.seed + 7)
        ln = rng.lognormal(mean=np.log(mean_len), sigma=0.8, size=n_docs)
        return np.maximum(ln.astype(np.int64), 8)


def pack_documents(corpus: SyntheticCorpus, n_rows: int, seq_len: int):
    """Greedy-pack documents into [n_rows, seq_len] (+1 for labels shift).

    Returns (tokens [n_rows, seq_len], docs_per_row [n_rows]) — the latter is
    the per-row work proxy used as scheduler queue tails in examples.
    """
    tokens = np.zeros((n_rows, seq_len), dtype=np.int64)
    docs_per_row = np.zeros(n_rows, dtype=np.int64)
    doc_id = 0
    lengths = corpus.doc_lengths(n_rows * 8, max(seq_len // 4, 16))
    for r in range(n_rows):
        filled = 0
        while filled < seq_len:
            L = int(lengths[doc_id % len(lengths)])
            take = min(L, seq_len - filled)
            tokens[r, filled : filled + take] = corpus.document(doc_id, take)
            filled += take
            doc_id += 1
            docs_per_row[r] += 1
    return tokens, docs_per_row


def make_batch(cfg, shape, step: int, *, n_rows: int | None = None, seed: int = 0):
    """Materialize one global batch dict for (cfg, shape) as numpy arrays."""
    rows = n_rows if n_rows is not None else shape.global_batch
    corpus = SyntheticCorpus(cfg.vocab_size, seed=seed + step)
    seq = shape.seq_len if shape.kind != "decode" else 1
    tokens, _ = pack_documents(corpus, rows, max(seq, 8))
    batch = {"tokens": tokens[:, :seq].astype(np.int32)}
    rng = np.random.RandomState(seed + step + 1)
    if cfg.family == "vlm":
        batch["patches"] = rng.randn(rows, cfg.n_patches, cfg.d_model).astype(np.float32) * 0.02
    if cfg.family == "encdec":
        batch["frames"] = rng.randn(rows, cfg.enc_seq_len, cfg.d_model).astype(np.float32) * 0.02
    return batch

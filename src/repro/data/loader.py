"""Host-side work-stealing data loader — the paper's L2 deployment.

The *literal* WS-WMULT algorithm (repro.core, Figure 7) runs on Python
threads: the owner (feeder) Puts batch-preparation tasks; worker threads
Take/Steal them and materialize the numpy microbatches.  Weak multiplicity
means a microbatch may be materialized twice under contention; preparation
is idempotent (deterministic synthetic corpus), and the assembly point
deduplicates by task id — exactly the paper's "repeatable work" deployment
(§1: idempotent contexts), with the stronger ≤-once-per-thread guarantee.

This is deliberately the real algorithm rather than a queue.Queue: the
loader doubles as a liveness/soak test of the core implementation, and its
stats (duplicates, steals) are reported by the data benchmarks.
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional

from repro.core import EMPTY, WSWMult


class WorkStealingLoader:
    """Prefetching loader over an idempotent `prepare(task_id) -> batch` fn."""

    def __init__(
        self,
        prepare: Callable[[int], dict],
        n_tasks: int,
        n_workers: int = 2,
        storage: str = "linked",
        node_len: int = 64,
    ):
        self.prepare = prepare
        self.n_tasks = n_tasks
        self.queue = WSWMult(storage=storage, node_len=node_len)
        self._results: Dict[int, dict] = {}
        self._lock = threading.Lock()
        self._done = threading.Event()
        self.stats = {"extractions": 0, "duplicates": 0}
        self._workers = [
            threading.Thread(target=self._worker, args=(pid,), daemon=True)
            for pid in range(1, n_workers + 1)
        ]

    # -- owner thread -------------------------------------------------------
    def start(self):
        for t in range(self.n_tasks):
            self.queue.put(t)
        for w in self._workers:
            w.start()
        # the owner also works (Take), per the paper's roles
        while True:
            task = self.queue.take()
            if task is EMPTY:
                break
            self._complete(task)
        return self

    # -- thief threads --------------------------------------------------------
    def _worker(self, pid: int):
        misses = 0
        while not self._done.is_set() and misses < 64:
            task = self.queue.steal(pid)
            if task is EMPTY:
                misses += 1
                continue
            misses = 0
            self._complete(task)

    def _complete(self, task_id: int):
        batch = self.prepare(task_id)  # idempotent; may run more than once
        with self._lock:
            self.stats["extractions"] += 1
            if task_id in self._results:
                self.stats["duplicates"] += 1  # weak multiplicity in action
            else:
                self._results[task_id] = batch
            if len(self._results) == self.n_tasks:
                self._done.set()

    # -- consumer -------------------------------------------------------------
    def batches(self, timeout: float = 60.0) -> List[dict]:
        """Block until every task is materialized at least once (the paper's
        at-least-once guarantee), then return batches in task order."""
        if not self._done.wait(timeout):
            missing = [t for t in range(self.n_tasks) if t not in self._results]
            raise TimeoutError(f"loader incomplete; missing tasks {missing[:8]}...")
        return [self._results[t] for t in range(self.n_tasks)]

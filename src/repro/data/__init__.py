"""repro.data — deterministic synthetic corpus + work-stealing host loader."""

from .synthetic import SyntheticCorpus, make_batch, pack_documents
from .loader import WorkStealingLoader

__all__ = ["SyntheticCorpus", "WorkStealingLoader", "make_batch", "pack_documents"]

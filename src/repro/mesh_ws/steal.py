"""Remote victim ranking, stolen-segment transfer, donation accounting.

The cross-device Steal is the paper's Steal lifted one level, with one
twist that keeps the whole thing fence-free: the plan is **replicated**.
Every device holds the same exchanged advisories and the same gathered
head/tail snapshots, so every device runs the identical deterministic
planning loop (a static sweep over device ids) and arrives at the *same*
assignment — thief ``t`` takes the tail half-run of each queue of its
best-scored victim, successive thieves see tails already truncated by
earlier (lower-id) thieves.  Consequences:

* stolen segments are **disjoint** across thieves and disjoint from the
  victim's retained prefix, so a clean run has multiplicity <= 1 per tile
  and the normalized combine is bit-identical to the no-drop oracle;
* the victim needs no message to learn what it donated — it reads its own
  truncated tails out of the replicated plan and issues the coalesced
  advisory correction locally (zero extra collectives for donation
  accounting);
* staleness stays harmless: the plan is computed from a snapshot, and a
  victim that drained past the snapshot's head simply hands over a short
  (possibly empty) segment — the thief's launch bounds-checks against
  ``s_head >= s_tail`` and no-ops, exactly like an intra-chip thief losing
  a race to a stale head.

Victim ranking is locality-weighted (*On the Efficiency of Localized Work
Stealing*, arXiv:1804.04773): ``score(v) = advisory(v) - alpha·hops(t, v)``
— prefer loaded victims, discount by ring distance, since a steal from a
far device pays proportionally more interconnect time for the operand
transfer.  ``alpha`` is in tile-slot units per hop.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.pallas_ws.queues import QueueState

INF = jnp.int32(1 << 30)


def hops_matrix(n_devices: int) -> jnp.ndarray:
    """Ring distance between devices: ``hops[t, v]`` peer hops t→v."""
    ids = jnp.arange(n_devices, dtype=jnp.int32)
    fwd = (ids[None, :] - ids[:, None]) % n_devices
    bwd = (ids[:, None] - ids[None, :]) % n_devices
    return jnp.minimum(fwd, bwd).astype(jnp.int32)


class StealPlan(NamedTuple):
    """One device's slice of the replicated plan.

    ``victim``/``stole`` describe this device *as thief*; ``s_head`` /
    ``s_tail`` bound its stolen per-queue segments of the victim's pool
    (empty when ``stole`` is False).  ``new_tail`` describes this device
    *as victim*: its own queue tails after all donations this round."""

    victim: jnp.ndarray    # scalar i32: device whose segment we execute
    stole: jnp.ndarray     # scalar bool: did this device steal at all
    s_head: jnp.ndarray    # [El] stolen segment start (victim tile index)
    s_tail: jnp.ndarray    # [El] stolen segment end
    new_tail: jnp.ndarray  # [El] own tails after donation truncation
    take_tiles: jnp.ndarray  # scalar i32: tiles this device stole


def plan_steals(adv, g_head, g_tail, me, *, n_devices: int, bt: int,
                alpha: int = 1) -> StealPlan:
    """The replicated planning sweep.  All inputs are post-exchange
    snapshots identical on every device: ``adv [D]`` advisory scalars,
    ``g_head [D, El]`` per-queue head snapshots, ``g_tail [D, El]`` queue
    tails.  ``me`` is this device's mesh index (the only non-replicated
    input — it selects which slice of the plan to return).

    Thieves are the advisory-idle devices; they plan in device-id order
    (a static python loop — D is a mesh constant), each choosing the victim
    maximizing ``advisory - alpha·hops`` and taking the tail half of every
    remaining queue segment (``ceil(rem/2)`` tiles — the classic half-run
    steal).  Earlier thieves' takes update the working tails and
    advisories, so later thieves see them and plans never overlap."""
    adv = jnp.asarray(adv, jnp.int32)
    g_head = jnp.asarray(g_head, jnp.int32)
    g_tail = jnp.asarray(g_tail, jnp.int32)
    n_local = g_tail.shape[1]
    ids = jnp.arange(n_devices, dtype=jnp.int32)
    hops = hops_matrix(n_devices)

    cur_tail = g_tail
    adv_cur = adv
    victim = jnp.int32(0)
    stole = jnp.bool_(False)
    s_head = jnp.zeros((n_local,), jnp.int32)
    s_tail = jnp.zeros((n_local,), jnp.int32)
    take_tiles = jnp.int32(0)
    for t in range(n_devices):
        idle_t = adv[t] == 0
        score = adv_cur - alpha * hops[t]
        score = jnp.where(ids == t, -INF, score)
        score = jnp.where(adv_cur > 0, score, -INF)
        v = jnp.argmax(score).astype(jnp.int32)
        can_t = idle_t & (jnp.max(score) > -INF)
        rem = jnp.maximum(cur_tail[v] - jnp.maximum(g_head[v], 0), 0)
        take = jnp.where(can_t, (rem + 1) // 2, 0)
        h_mid = cur_tail[v] - take
        if_me = can_t & (me == t)
        victim = jnp.where(if_me, v, victim)
        stole = stole | if_me
        s_head = jnp.where(if_me, h_mid, s_head)
        s_tail = jnp.where(if_me, cur_tail[v], s_tail)
        take_tiles = jnp.where(if_me, jnp.sum(take), take_tiles)
        cur_tail = cur_tail.at[v].set(jnp.where(can_t, h_mid, cur_tail[v]))
        adv_cur = adv_cur.at[v].add(jnp.where(can_t, -jnp.sum(take) * bt, 0))
    return StealPlan(
        victim=victim, stole=stole, s_head=s_head, s_tail=s_tail,
        new_tail=cur_tail[me], take_tiles=take_tiles,
    )


def steal_queue_state(g_records, g_toff, plan: StealPlan, *,
                      n_programs: int, pool_tiles: int,
                      bt: int) -> QueueState:
    """Queue state for the thief's launch over the victim's gathered pool.

    A fresh view of the stolen segments only: shared heads start at
    ``s_head``, tails at ``s_tail`` (no other tile is visible), local heads
    and announcements fresh.  Records carry the victim's LOCAL expert ids,
    so the thief feeds the victim's gathered weight shard directly.  A
    non-thief gets ``s_head == s_tail == 0`` — every probe misses and the
    launch is a bounded no-op."""
    n_local = plan.s_head.shape[0]
    return QueueState(
        tasks=g_records[plan.victim],
        head=plan.s_head,
        tail=plan.s_tail,
        local_head=jnp.zeros((n_programs, n_local), jnp.int32),
        taken=jnp.full((pool_tiles,), -1, jnp.int32),
        task_list=None,
        n_tasks_hint=pool_tiles,
        remaining=(plan.s_tail - plan.s_head) * bt,
        pool_off=g_toff[plan.victim],
    )


def deliver_home(out_s, mult_s, plan: StealPlan, axis: str, *,
                 n_devices: int):
    """Route stolen contributions back to their home device: each thief
    drops its launch output into the box row addressed by its victim, one
    ``psum`` merges the boxes, and each device reads its own row.  Disjoint
    stolen segments mean every (row, element) has at most one nonzero
    contributor, so the reduction is exact in any order."""
    me = jax.lax.axis_index(axis)
    n_rows, d = out_s.shape
    pool_tiles = mult_s.shape[0]
    out_box = jnp.zeros((n_devices, n_rows, d), jnp.float32).at[
        plan.victim
    ].set(jnp.where(plan.stole, out_s, 0.0))
    mult_box = jnp.zeros((n_devices, pool_tiles), jnp.int32).at[
        plan.victim
    ].set(jnp.where(plan.stole, mult_s, 0))
    out_in = jax.lax.psum(out_box, axis)[me]
    mult_in = jax.lax.psum(mult_box, axis)[me]
    return out_in, mult_in

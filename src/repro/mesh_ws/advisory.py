"""Per-device load advisories: coalesced summaries + collective exchange.

Intra-chip, every program ranks steal victims from the plain-write
``remaining[q]`` advisory vector — stale reads cost ranking quality, never
correctness (``steal_policy="cost"``, DESIGN.md §3.6).  The mesh layer
lifts the same contract one level: each device *reduces* its advisory
vector to one scalar (total remaining tile-slot cost) after its local
drain and exchanges that scalar over the mesh axis.  The exchanged view is
stale by construction — the reducing device keeps draining while the
collective is in flight — and that is fine for exactly the intra-chip
reason: advisories only *rank* victims; the thief's actual extraction is
bounds-checked against the gathered head/tail state, so arbitrary
staleness degrades locality of the choice, never the answer.

No RDMA, no atomics: the exchange is ``jax.lax.ppermute`` hops (a ring
all-gather) and ``jax.lax.psum`` — data-parallel collectives that lower to
``collective-permute``/``all-reduce``, leaving the fence-free audit clean.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ring_allgather(x, axis: str, n_devices: int):
    """All-gather ``x`` along ``axis`` via D-1 ``ppermute`` hops.

    Returns ``[D, *x.shape]`` with row ``m`` holding device ``m``'s value.
    Written as an explicit ring (not ``all_gather``) so the collective
    traffic the benchmark accounts for is exactly D-1 peer-to-peer hops of
    ``x`` — the shape a TPU torus actually moves.
    """
    me = jax.lax.axis_index(axis)
    x = jnp.asarray(x)
    buf = jnp.zeros((n_devices,) + x.shape, x.dtype).at[me].set(x)
    if n_devices == 1:
        return buf
    perm = [(i, (i + 1) % n_devices) for i in range(n_devices)]

    def hop(i, carry):
        buf, cur = carry
        cur = jax.lax.ppermute(cur, axis, perm)
        src = (me - i - 1) % n_devices
        return buf.at[src].set(cur), cur

    buf, _ = jax.lax.fori_loop(0, n_devices - 1, hop, (buf, x))
    return buf


def reduce_advisory(remaining) -> jnp.ndarray:
    """One device's load summary: total remaining advisory cost, clamped
    nonnegative per queue first (a queue's advisory may be stale-low but the
    summary must never let one negative queue cancel another's real load)."""
    return jnp.maximum(jnp.asarray(remaining), 0).sum().astype(jnp.int32)


def donated_cost(put, new_tail) -> jnp.ndarray:
    """Coalesced advisory correction for donated segments.

    When the replicated steal plan truncates this device's queue tails from
    ``put.tail`` to ``new_tail``, the tiles in ``[new_tail[e], tail[e])``
    leave the owner's advisory scope.  Rather than one write per donated
    tile, sum the donated cost per queue — ONE plain subtraction per queue
    per dispatch (the same clamp-commutation argument as the kernel's
    coalesced run write: costs are nonnegative, so
    ``max(r - Σc, 0) == fold(max(·-c, 0))``).
    """
    cost = put.records[:, 7]
    tail = jnp.asarray(put.tail)
    donated = (put.tile_index >= new_tail[put.tile_expert]) & (
        put.tile_index < tail[put.tile_expert]
    )
    n_local = tail.shape[0]
    return jnp.zeros((n_local,), jnp.int32).at[put.tile_expert].add(
        jnp.where(donated, cost, 0)
    )


def apply_donation(remaining, don_cost) -> jnp.ndarray:
    """The coalesced plain write: per-queue advisory minus donated cost."""
    return jnp.maximum(jnp.asarray(remaining) - don_cost, 0)


def exchange_payload_bytes(*, n_devices: int, pool_tiles: int, n_local: int,
                           n_rows: int, n_routed: int, d: int, f: int) -> int:
    """Analytic per-device collective payload of one mesh dispatch step.

    Counts what the ring moves: the advisory scalar plus the victim-side
    context (records, heads, tails, offsets, token rows, gates, weight
    shards), each traversing D-1 hops, plus the two psum deliveries (stolen
    outputs, multiplicities, pair buffer — psum ≈ 2(D-1)/D · bytes on a
    ring, rounded up to 2(D-1) hops of the payload/D for the bound).  The
    benchmark reports this next to the HLO-measured number so the two can
    be cross-checked.
    """
    hops = n_devices - 1
    i32, f32 = 4, 4
    gathered = (
        1 * i32                      # advisory scalar
        + pool_tiles * 8 * i32       # records
        + n_local * i32 * 3          # head, tail, toff (toff: n_local+1 ≈)
        + (n_local + 1) * i32
        + n_rows * (i32 + f32)       # tok_idx + gates
        + n_local * d * f * f32 * 2  # wg, wu shards
        + n_local * f * d * f32      # wd shard
    )
    psum_payload = (
        n_devices * n_rows * d * f32   # stolen-output delivery box
        + n_devices * pool_tiles * i32  # stolen-mult delivery box
        + (n_routed + 1) * d * f32     # pair-slot combine buffer
    )
    return hops * gathered + 2 * hops * (psum_payload // n_devices)

"""Forced-device self-check of the mesh dispatch: run seeded routings on N
fake host devices and assert the shard_map output bit-identical to the
single-device no-drop oracle.

Run as a module so device forcing precedes first jax init (the dryrun.py
pattern)::

    python -m repro.mesh_ws.selfcheck --devices 8 --seeds 3

The tier-1 conformance suite subprocess-runs this (so a 1-device pytest
session still exercises the real 8-device shard_map path), the CI ``mesh``
job runs it directly, and ``examples/train_e2e.py --devices N`` reuses the
routing generator for its forward-parity demo.
"""

import argparse
import json
import os
import sys


def skewed_routing(rng, n_tokens: int, n_experts: int, top_k: int,
                   hot_frac: float = 0.75, hot_experts: int | None = None):
    """Seeded routing with a hot expert block (device 0's shard by
    default): ``hot_frac`` of tokens route entirely inside the hot block,
    the rest uniformly — the load shape cross-device stealing exists for."""
    import numpy as np

    if hot_experts is None:
        hot_experts = max(1, n_experts // 8)
    idx = np.zeros((n_tokens, top_k), np.int32)
    for t in range(n_tokens):
        pool = hot_experts if t < int(n_tokens * hot_frac) else n_experts
        idx[t] = rng.choice(pool, size=top_k, replace=False)
    gates = rng.random((n_tokens, top_k), dtype=np.float32)
    gates = gates / gates.sum(1, keepdims=True)
    return idx, gates


def run_checks(n_devices: int, seeds: int, *, n_tokens: int = 24,
               n_experts: int = 16, top_k: int = 2, d: int = 8, f: int = 16,
               bt: int = 4, n_programs: int = 2):
    import numpy as np

    from repro.launch.mesh import make_expert_mesh
    from repro.mesh_ws import expert_ffn_mesh_ws
    from repro.moe_ws.layer import expert_ffn_nodrop_ref

    mesh = make_expert_mesh(n_experts, n_devices)
    rows = []
    for seed in range(seeds):
        rng = np.random.default_rng(seed)
        idx, gates = skewed_routing(rng, n_tokens, n_experts, top_k)
        x = rng.standard_normal((n_tokens, d), dtype=np.float32)
        wg = 0.1 * rng.standard_normal((n_experts, d, f), dtype=np.float32)
        wu = 0.1 * rng.standard_normal((n_experts, d, f), dtype=np.float32)
        wd = 0.1 * rng.standard_normal((n_experts, f, d), dtype=np.float32)
        y, tele = expert_ffn_mesh_ws(
            idx, gates, x, wg, wu, wd, mesh=mesh, bt=bt,
            n_programs=n_programs, return_telemetry=True,
        )
        ref = expert_ffn_nodrop_ref(idx, gates, x, wg, wu, wd)
        y, ref, tele = np.asarray(y), np.asarray(ref), np.asarray(tele)
        rows.append({
            "seed": seed,
            "bit_identical": bool(np.array_equal(y, ref)),
            "max_abs_err": float(np.abs(y - ref).max()),
            "devices_stole": int(tele[:, 5].sum()),
            "tiles_stolen": int(tele[:, 6].sum()),
        })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--seeds", type=int, default=3)
    args = ap.parse_args(argv)

    import jax

    if len(jax.devices()) < args.devices:
        # this process initialized jax with too few devices (the count locks
        # at first init) — re-exec with the forcing flag in the child's env,
        # where it precedes every import
        import subprocess

        env = dict(
            os.environ,
            XLA_FLAGS=f"--xla_force_host_platform_device_count={args.devices}",
        )
        return subprocess.run(
            [sys.executable, "-m", "repro.mesh_ws.selfcheck",
             "--devices", str(args.devices), "--seeds", str(args.seeds)],
            env=env,
        ).returncode

    rows = run_checks(args.devices, args.seeds)
    ok = all(r["bit_identical"] for r in rows)
    stole = any(r["devices_stole"] for r in rows)
    print(json.dumps({"devices": args.devices, "ok": ok,
                      "any_steals": stole, "rows": rows}, indent=2))
    if not ok:
        print("FAIL: mesh dispatch diverged from the no-drop oracle",
              file=sys.stderr)
        return 1
    if not stole:
        print("FAIL: no seed exercised a cross-device steal", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

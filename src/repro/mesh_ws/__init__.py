"""Cross-device expert-parallel work stealing over a device mesh.

DESIGN.md §7.  Shards ``moe_ws``'s expert queues along the mesh ``"model"``
axis and lets advisory-idle devices steal remote expert tiles through a
two-level hierarchy — local megakernel drain, then a replicated
deterministic steal plan computed from coalesced per-device advisories
exchanged with ``ppermute``/``psum`` (plain-write summaries + data-parallel
collectives; no atomics, no fences, no RDMA synchronization)."""

from .advisory import (
    apply_donation,
    donated_cost,
    exchange_payload_bytes,
    reduce_advisory,
    ring_allgather,
)
from .layer import (
    MESH_AXIS,
    TELE_FIELDS,
    EmulatedDispatch,
    emulate_mesh_dispatch,
    expert_ffn_mesh_ws,
    mesh_dispatch_body,
    mesh_wstrace,
    moe_ffn_mesh_ws,
    phase_rounds,
)
from .partition import (
    LocalPut,
    expert_shard,
    local_pool_state,
    route_local_pool_jax,
)
from .steal import (
    StealPlan,
    deliver_home,
    hops_matrix,
    plan_steals,
    steal_queue_state,
)

__all__ = [
    "MESH_AXIS",
    "TELE_FIELDS",
    "EmulatedDispatch",
    "LocalPut",
    "StealPlan",
    "apply_donation",
    "deliver_home",
    "donated_cost",
    "emulate_mesh_dispatch",
    "exchange_payload_bytes",
    "expert_ffn_mesh_ws",
    "expert_shard",
    "hops_matrix",
    "local_pool_state",
    "mesh_dispatch_body",
    "mesh_wstrace",
    "moe_ffn_mesh_ws",
    "phase_rounds",
    "plan_steals",
    "reduce_advisory",
    "ring_allgather",
    "route_local_pool_jax",
    "steal_queue_state",
]

"""Expert→device partitioning and the per-device traced Put.

The mesh layer shards the expert axis the way the large configs already do
(`deepseek_v2_236b`, `kimi_k2_1t_a32b`: experts partitioned along the
``"model"`` mesh axis): device ``m`` owns the contiguous expert block
``[m·El, (m+1)·El)`` with ``El = E // D``.  Every device sees the *full*
replicated routing ``(idx, gates)`` and Puts only its own experts' pairs —
a masked variant of ``route_to_tasks_pool_jax`` where non-local pairs land
in a dead sacrificial bucket (gate 0, ``row_src = T·k``) so shapes stay
static and the foreign rows vanish from every downstream reduction.

Expert indices inside the device pool are **local** (``0..El-1``) so the
shard of the weight arrays (``[El, d, f]`` under ``P("model")``) indexes
directly — and so a thief executing a stolen remote segment can feed the
victim's gathered weight shard to the same kernel unchanged.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.moe_ws.dispatch import RoutedSet, _register_routed_pytree
from repro.pallas_ws.queues import QueueState, make_pool_queue_state_jax
from repro.pallas_ws.tasks import BOTTOM, OP_EXPERT_TILE

_register_routed_pytree()


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def expert_shard(n_experts: int, n_devices: int) -> int:
    """Experts per device; the partition is even or it is a config error."""
    if n_devices < 1:
        raise ValueError(f"need >= 1 device, got {n_devices}")
    if n_experts % n_devices:
        raise ValueError(
            f"n_experts={n_experts} not divisible by mesh size {n_devices}; "
            "pick a mesh whose model axis divides the expert count"
        )
    return n_experts // n_devices


class LocalPut(NamedTuple):
    """Arrays of one device's masked pool Put (all shapes static).

    ``records``/``tail``/``toff`` feed ``make_pool_queue_state_jax``;
    ``tile_expert``/``tile_index`` locate each pool tile inside its (local)
    expert segment — the donation accounting in ``advisory.py`` re-derives
    per-queue donated cost from them with no extra collective."""

    records: jnp.ndarray      # [pool_tiles, 8] task rows, LOCAL expert ids
    tail: jnp.ndarray         # [El] live tile count per local expert queue
    toff: jnp.ndarray         # [El+2] tile-offset prefix (incl. foreign blk)
    routed: RoutedSet         # row-space views (tok_idx/gates/row_src/...)
    tile_expert: jnp.ndarray  # [pool_tiles] owning local expert of tile j
    tile_index: jnp.ndarray   # [pool_tiles] tile rank inside that segment


def route_local_pool_jax(idx, gates, n_experts: int, lo, n_local: int,
                         bt: int) -> LocalPut:
    """Masked per-device traced Put over experts ``[lo, lo+n_local)``.

    Same shared-pool layout as ``route_to_tasks_pool_jax`` restricted to the
    local experts, plus one sacrificial bucket (key ``n_local``) holding
    every foreign pair: its rows get gate 0 and ``row_src = T·k`` so the
    pair-slot combine and the gradient scatters drop them, and its tiles are
    never recorded (``live = j < toff[n_local]``), so no queue ever serves
    them.  ``lo`` may be traced (it is ``axis_index * El`` under shard_map);
    ``n_local``/``bt`` are static.
    """
    idx = jnp.asarray(idx, jnp.int32)
    gates = jnp.asarray(gates, jnp.float32)
    T, k = idx.shape
    Tk = T * k
    flat_e = idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    flat_g = gates.reshape(-1)
    local = (flat_e >= lo) & (flat_e < lo + n_local)
    key = jnp.where(local, flat_e - lo, n_local)
    order = jnp.argsort(key, stable=True)
    sorted_key = key[order]
    loads_all = jnp.zeros((n_local + 1,), jnp.int32).at[key].add(1)
    start = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(loads_all)[:-1]]
    )
    rank = jnp.arange(Tk, dtype=jnp.int32) - start[sorted_key]
    loads = loads_all[:n_local]

    # static worst case: every local expert half-full plus the foreign block
    pool_tiles = _cdiv(Tk, bt) + n_local + 1
    n_tiles = (loads_all + bt - 1) // bt
    toff = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(n_tiles).astype(jnp.int32)]
    )
    row_off = toff * bt                      # [El+2]; entry El = foreign blk
    dest = row_off[sorted_key] + rank
    n_rows = pool_tiles * bt
    loc_s = local[order]
    tok_idx = jnp.zeros((n_rows,), jnp.int32).at[dest].set(flat_t[order])
    gate_rows = jnp.zeros((n_rows,), jnp.float32).at[dest].set(
        jnp.where(loc_s, flat_g[order], 0.0)
    )
    row_src = jnp.full((n_rows,), Tk, jnp.int32).at[dest].set(
        jnp.where(loc_s, order.astype(jnp.int32), Tk)
    )

    j = jnp.arange(pool_tiles, dtype=jnp.int32)
    tile_expert = jnp.clip(
        jnp.searchsorted(toff, j, side="right").astype(jnp.int32) - 1,
        0, n_local - 1,
    )
    tile_index = j - toff[tile_expert]
    live = j < toff[n_local]
    rl = jnp.where(live, jnp.clip(loads[tile_expert] - tile_index * bt, 0, bt), 0)
    bot = jnp.full((pool_tiles,), BOTTOM, jnp.int32)
    records = jnp.stack(
        [
            jnp.where(live, jnp.int32(OP_EXPERT_TILE), jnp.int32(BOTTOM)),
            jnp.where(live, tile_expert, jnp.int32(BOTTOM)),  # LOCAL expert
            j * bt,     # row_start: tile j statically owns rows [j·bt, ...)
            rl,         # row_len
            bot, bot,
            j,          # tid == pool slot index
            rl,         # cost
        ],
        axis=-1,
    )
    routed = RoutedSet(
        tok_idx=tok_idx, gates=gate_rows, expert_off=row_off[: n_local + 1],
        loads=loads, n_rows=n_rows, n_routed=Tk, n_tokens=T, row_src=row_src,
    )
    return LocalPut(
        records=records, tail=n_tiles[:n_local], toff=toff, routed=routed,
        tile_expert=tile_expert, tile_index=tile_index,
    )


def local_pool_state(put: LocalPut, n_programs: int) -> QueueState:
    """Fresh QueueState over one device's local pool (phase-1 launch)."""
    return make_pool_queue_state_jax(
        put.records, put.tail, put.toff[: put.tail.shape[0] + 1],
        put.routed.loads, n_programs, n_tasks=put.records.shape[0],
    )

"""``moe_ffn_mesh_ws`` — cross-device expert-parallel WS dispatch.

Two-level hierarchy (arXiv:2211.00838's remote-steal shape on the paper's
fence-free substrate):

* **level 1 — intra-device**: each device Puts its local experts' pairs
  into a shared-pool queue layout and drains them through the existing
  ``launch_ws_grid`` megakernel (plain loads/stores, multiplicity absorbs
  races) for a *balanced-share* round budget ``ceil(Tk/(D·P)) + bt``;
* **level 2 — cross-device**: devices exchange one coalesced advisory
  scalar each (``advisory.py``), every device replicates the deterministic
  steal plan (``steal.py``), and phase 2 runs two more megakernel launches
  per device — continue the own pool to its donation-truncated tails, and
  execute the stolen half-run of the chosen victim's gathered pool.

Stolen contributions ride home on one ``psum`` addressed by victim id, the
multiplicity totals merge (own + stolen execution counts), and the combine
normalizes each row by its tile's total count before the gate-weighted
reduction — duplicated cross-device extraction is exact for exactly the
intra-chip reason.  The combine scatters normalized rows into per-(token,
choice) pair slots and reduces with the oracle's own expression tree, so a
clean (duplicate-free) schedule is **bit-identical** to
``expert_ffn_nodrop_ref`` — the conformance suite asserts equality, not
closeness.

``emulate_mesh_dispatch`` runs the identical protocol on one device with
collectives replaced by stacking — the adversarial conformance drills
(stale advisories, overlapping forced plans) drive it directly.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.moe_ws.dispatch import divisor_from_tiles
from repro.moe_ws.expert_kernel import run_moe_schedule
from repro.pallas_ws.queues import QueueState

from .advisory import (
    apply_donation,
    donated_cost,
    reduce_advisory,
    ring_allgather,
)
from .partition import (
    _cdiv,
    expert_shard,
    local_pool_state,
    route_local_pool_jax,
)
from .steal import StealPlan, deliver_home, plan_steals, steal_queue_state

MESH_AXIS = "model"

#: telemetry row layout of one device's dispatch step ([D, len] output)
TELE_FIELDS = (
    "phase1_clock",   # local balanced-drain makespan
    "phase2_clock",   # own-continue makespan
    "steal_clock",    # stolen-segment makespan
    "advisory",       # exchanged load summary (post phase 1)
    "victim",         # chosen victim id (0 when no steal)
    "stole",          # 1 iff this device pulled a remote segment
    "take_tiles",     # tiles stolen by this device
    "mult_sum",       # Σ own-pool multiplicity (own + delivered stolen)
)


def phase_rounds(n_routed: int, bt: int, n_programs: int,
                 n_devices: int) -> tuple[int, int]:
    """Static round budgets.  Phase 1 is a deliberate *truncation* budget:
    rounds are cost-gated (a program that claims a tile of cost c stays
    busy for c rounds, and a claim in the final round overruns by up to
    ``bt`` rows), so ``r1`` rounds let each device retire about
    ``(r1 + bt) * P`` rows — subtracting the overrun tail lands the
    effective phase-1 drain at the balanced 1/D row share.  An overloaded
    device is cut off with its surplus still queued, everyone else drains
    dry, and the advisory exchange routes the idle devices to the surplus.
    Phase 2 keeps the full single-device safety bound
    (``expert_rounds_bound``'s Graham form), which drains any post-steal
    residue regardless of how phase 1 was cut."""
    r1 = max(1, _cdiv(n_routed, n_devices * n_programs) - bt + 1)
    r2 = _cdiv(n_routed, n_programs) + bt
    return r1, r2


def _pair_combine_part(routed, out_total, mult_total, *, bt: int):
    """Normalize a device's accumulated rows by total multiplicity and
    scatter them to (token, choice) pair slots ``[Tk+1, d]`` (slot Tk is
    sacrificial: pads and foreign rows land there, then get zeroed).  Each
    live pair slot is filled by exactly one device, so the cross-device sum
    of these parts is exact and the final gate-weighted reduction can reuse
    the oracle's expression tree."""
    pool_tiles = mult_total.shape[0]
    Tk = routed.n_routed
    starts = jnp.arange(pool_tiles, dtype=jnp.int32) * bt
    div = divisor_from_tiles(starts, bt, mult_total, routed.n_rows)
    yr = out_total / div[:, None]
    src = jnp.minimum(jnp.asarray(routed.row_src), Tk)
    part = jnp.zeros((Tk + 1, out_total.shape[-1]), jnp.float32).at[src].set(yr)
    return part.at[Tk].set(0.0)


def _combine_pairs(y_pairs, gates):
    """The oracle's combine: ``(gates * pairs).sum(choice)``."""
    T, k = gates.shape
    d = y_pairs.shape[-1]
    return (
        jnp.asarray(gates, jnp.float32)[:, :, None]
        * y_pairs[:T * k].reshape(T, k, d)
    ).sum(axis=1)


def mesh_dispatch_body(
    x_flat, idx, gates, wg, wu, wd, *,
    n_experts: int, n_devices: int, bt: int, n_programs: int,
    alpha: int = 1, steal: bool = True, axis: str = MESH_AXIS,
    interpret: bool = True,
):
    """shard_map body of one mesh dispatch step (see module docstring).

    Replicated inputs: ``x_flat [T,d]``, ``idx [T,k]``, ``gates [T,k]``.
    Sharded inputs (``P(axis)`` on the expert dim): ``wg/wu [El,d,f]``,
    ``wd [El,f,d]``.  Returns the replicated combined rows ``[T,d]`` f32
    and this device's telemetry row ``[1, len(TELE_FIELDS)]``.

    ``steal=False`` is the per-device-static baseline: phase 1 runs to the
    full single-device round bound and no advisory/steal traffic happens —
    the benchmark's comparison point.
    """
    El = expert_shard(n_experts, n_devices)
    me = jax.lax.axis_index(axis)
    lo = me * El
    T, k = idx.shape
    Tk = T * k
    xf = jnp.asarray(x_flat, jnp.float32)
    r1, r2 = phase_rounds(Tk, bt, n_programs, n_devices)

    put = route_local_pool_jax(idx, gates, n_experts, lo, El, bt)
    pool_tiles = put.records.shape[0]
    state = local_pool_state(put, n_programs)

    if not steal:
        res = run_moe_schedule(
            state, xf, put.routed.tok_idx, wg, wu, wd, bt=bt, steal=True,
            steal_policy="cost", rounds=r2, interpret=interpret,
        )
        part = _pair_combine_part(put.routed, res.out, res.mult, bt=bt)
        y = _combine_pairs(jax.lax.psum(part, axis), gates)
        tele = jnp.stack([
            res.clock.max(), jnp.int32(0), jnp.int32(0),
            reduce_advisory(res.remaining), jnp.int32(0), jnp.int32(0),
            jnp.int32(0), res.mult.sum(),
        ])
        return y, tele[None]

    # ---- phase 1: balanced local drain -----------------------------------
    res1 = run_moe_schedule(
        state, xf, put.routed.tok_idx, wg, wu, wd, bt=bt, steal=True,
        steal_policy="cost", rounds=r1, interpret=interpret,
    )

    # ---- advisory exchange + victim-context gather -----------------------
    adv_self = reduce_advisory(res1.remaining)
    adv = ring_allgather(adv_self, axis, n_devices).reshape(n_devices)
    g_rec = ring_allgather(put.records, axis, n_devices)
    g_head = ring_allgather(res1.head, axis, n_devices)
    g_tail = ring_allgather(jnp.asarray(put.tail, jnp.int32), axis, n_devices)
    g_toff = ring_allgather(put.toff[: El + 1], axis, n_devices)
    g_tok = ring_allgather(put.routed.tok_idx, axis, n_devices)
    g_wg = ring_allgather(jnp.asarray(wg, jnp.float32), axis, n_devices)
    g_wu = ring_allgather(jnp.asarray(wu, jnp.float32), axis, n_devices)
    g_wd = ring_allgather(jnp.asarray(wd, jnp.float32), axis, n_devices)

    # ---- replicated steal plan + coalesced donation advisory -------------
    plan = plan_steals(adv, g_head, g_tail, me,
                       n_devices=n_devices, bt=bt, alpha=alpha)
    rem2 = apply_donation(res1.remaining, donated_cost(put, plan.new_tail))

    # ---- phase 2a: continue own pool to the truncated tails --------------
    state2 = QueueState(
        tasks=put.records, head=res1.head, tail=plan.new_tail,
        local_head=res1.local_head, taken=res1.taken, task_list=None,
        n_tasks_hint=pool_tiles, remaining=rem2,
        pool_off=put.toff[: El + 1],
    )
    res2 = run_moe_schedule(
        state2, xf, put.routed.tok_idx, wg, wu, wd, bt=bt, steal=True,
        steal_policy="cost", rounds=r2, out=res1.out, mult=res1.mult,
        interpret=interpret,
    )

    # ---- phase 2b: execute the stolen remote segment ---------------------
    state_s = steal_queue_state(
        g_rec, g_toff, plan, n_programs=n_programs, pool_tiles=pool_tiles,
        bt=bt,
    )
    res_s = run_moe_schedule(
        state_s, xf, g_tok[plan.victim], g_wg[plan.victim],
        g_wu[plan.victim], g_wd[plan.victim], bt=bt, steal=True,
        steal_policy="cost", rounds=r2, interpret=interpret,
    )

    # ---- deliver stolen contributions home, merge multiplicity -----------
    out_in, mult_in = deliver_home(res_s.out, res_s.mult, plan, axis,
                                   n_devices=n_devices)
    out_total = res2.out + out_in
    mult_total = res2.mult + mult_in

    # ---- multiplicity-normalized pair combine ----------------------------
    part = _pair_combine_part(put.routed, out_total, mult_total, bt=bt)
    y = _combine_pairs(jax.lax.psum(part, axis), gates)
    tele = jnp.stack([
        res1.clock.max(), res2.clock.max(), res_s.clock.max(), adv_self,
        plan.victim, plan.stole.astype(jnp.int32), plan.take_tiles,
        mult_total.sum(),
    ])
    return y, tele[None]


def mesh_wstrace(tele, *, collective_bytes=None):
    """Lift a ``[D, len(TELE_FIELDS)]`` telemetry block into a
    :class:`~repro.wstrace.trace.WSTrace` carrying per-device *phase*
    counters (``mesh_phases``) instead of per-extraction events — the
    cross-device granularity the two-phase protocol exposes.  The Perfetto
    exporter renders one track per device with phase slices, remote-steal
    flow arrows (victim → thief), and advisory / collective-bytes counter
    tracks.  ``collective_bytes`` (per-device, e.g.
    :func:`~repro.mesh_ws.advisory.exchange_payload_bytes`) is attached to
    every device's counters when given."""
    import numpy as np

    from repro.wstrace.ring import EVENT_WIDTH
    from repro.wstrace.trace import WSTrace

    tele = np.asarray(tele)
    D = tele.shape[0]
    phases = []
    for dev in range(D):
        row = {name: int(tele[dev, i]) for i, name in enumerate(TELE_FIELDS)}
        if collective_bytes is not None:
            row["collective_bytes"] = int(collective_bytes)
        phases.append(row)
    # per-device wall: phase 1 then the longer of own-continue / steal
    span = tele[:, 0] + np.maximum(tele[:, 1], tele[:, 2])
    return WSTrace(
        events=np.zeros((0, EVENT_WIDTH), np.int32),
        n_programs=D,
        n_queues=D,
        makespan=int(span.max(initial=0)),
        dropped=np.zeros(D, np.int64),
        queue_loads=None,
        mesh_phases=phases,
    )


def expert_ffn_mesh_ws(
    idx, gates, x, wg, wu, wd, *,
    mesh, bt: int = 8, n_programs: int = 2, alpha: int = 1,
    steal: bool = True, interpret: bool = True, axis: str = MESH_AXIS,
    return_telemetry: bool = False,
):
    """Router-free mesh twin of :func:`expert_ffn_nodrop_ref`: same argument
    order, same ``[T, d]`` f32 return, expert dim sharded over ``mesh``'s
    ``axis``.  The conformance suite asserts this bit-identical to the
    oracle on clean schedules."""
    n_devices = mesh.shape[axis]
    n_experts = wg.shape[0]
    body = functools.partial(
        mesh_dispatch_body, n_experts=n_experts, n_devices=n_devices,
        bt=bt, n_programs=n_programs, alpha=alpha, steal=steal,
        axis=axis, interpret=interpret,
    )
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), P(), P(axis), P(axis), P(axis)),
        out_specs=(P(), P(axis)),
        check_rep=False,
    )
    y, tele = fn(
        jnp.asarray(x), jnp.asarray(idx, jnp.int32),
        jnp.asarray(gates, jnp.float32), wg, wu, wd,
    )
    return (y, tele) if return_telemetry else y


def moe_ffn_mesh_ws(
    x, p, cfg, group_size: int = 1024, *,
    mesh=None, bt: int = 8, n_programs: int = 2, alpha: int = 1,
    interpret: bool = True,
):
    """x: [B, S, d] -> (y, aux_loss) — `moe_ffn` drop-in with the dropless
    dispatch sharded over a device mesh (``cfg.moe_dispatch="mesh-ws"``).

    Same router, shared-expert, and aux-loss math as ``moe_ffn_ws``; the
    routed-expert core runs the two-level cross-device scheduler.  With
    ``mesh=None`` an expert mesh over the available devices is built via
    :func:`repro.launch.mesh.make_expert_mesh` (largest divisor of
    ``cfg.n_experts`` that fits the host's device count — 1 device
    degenerates to intra-chip WS with the same code path).  Forward-only:
    training keeps ``moe_dispatch="ws"`` (`launch.steps` enforces this).
    """
    from repro.moe_ws.layer import _router, _shared_experts

    if mesh is None:
        from repro.launch.mesh import make_expert_mesh

        mesh = make_expert_mesh(cfg.n_experts)
    B, S, d = x.shape
    x_flat = x.reshape(B * S, d)
    _, gate_vals, idx, aux = _router(x_flat, p, cfg, group_size)
    y = expert_ffn_mesh_ws(
        idx, gate_vals, x_flat, p["we_g"], p["we_u"], p["we_d"],
        mesh=mesh, bt=bt, n_programs=n_programs, alpha=alpha,
        interpret=interpret,
    )
    if cfg.n_shared_experts:
        y = y + _shared_experts(x_flat, p).astype(jnp.float32)
    return y.astype(x.dtype).reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# single-device emulation: the identical protocol with collectives replaced
# by stacking — tier-1 conformance and the adversarial drills drive this.


class EmulatedDispatch(NamedTuple):
    y: jnp.ndarray                  # [T, d] combined rows
    plans: tuple                    # per-device StealPlan actually applied
    adv: jnp.ndarray                # [D] exchanged advisories (pre-override)
    mult_total: tuple               # per-device merged multiplicity
    clocks: tuple                   # per-device (c1, c2, cs) makespans
    tails: tuple                    # per-device live tile counts [El]


def emulate_mesh_dispatch(
    x_flat, idx, gates, wg, wu, wd, *,
    n_devices: int, bt: int = 8, n_programs: int = 2, alpha: int = 1,
    adv_override=None,
    plans_override: Optional[Sequence[StealPlan]] = None,
) -> EmulatedDispatch:
    """Run the mesh protocol on one device, devices emulated by a python
    loop and every collective replaced by the stacked equivalent.

    The numerics are the deployed path's: psum deliveries become adds over
    slots with at most one nonzero contributor per thief, so emulated and
    shard_map outputs agree bitwise.  Two adversarial hooks exercise what a
    live mesh cannot be forced into deterministically:

    * ``adv_override [D]`` replaces the exchanged advisories — arbitrarily
      stale/corrupt load summaries (claiming load where none remains, or
      hiding real load) may mis-rank victims but must not break exactness;
    * ``plans_override`` replaces the replicated plan wholesale — segments
      may overlap the victim's retained prefix or each other, forcing
      cross-device duplicate execution that only the multiplicity
      normalization can absorb.
    """
    n_experts = wg.shape[0]
    El = expert_shard(n_experts, n_devices)
    T, k = jnp.asarray(idx).shape
    Tk = T * k
    xf = jnp.asarray(x_flat, jnp.float32)
    wg = jnp.asarray(wg, jnp.float32)
    wu = jnp.asarray(wu, jnp.float32)
    wd = jnp.asarray(wd, jnp.float32)
    r1, r2 = phase_rounds(Tk, bt, n_programs, n_devices)

    puts, res1s = [], []
    for m in range(n_devices):
        put = route_local_pool_jax(idx, gates, n_experts, m * El, El, bt)
        state = local_pool_state(put, n_programs)
        sl = slice(m * El, (m + 1) * El)
        res1 = run_moe_schedule(
            state, xf, put.routed.tok_idx, wg[sl], wu[sl], wd[sl], bt=bt,
            steal=True, steal_policy="cost", rounds=r1, interpret=True,
        )
        puts.append(put)
        res1s.append(res1)
    pool_tiles = puts[0].records.shape[0]

    adv = jnp.stack([reduce_advisory(r.remaining) for r in res1s])
    g_head = jnp.stack([jnp.asarray(r.head, jnp.int32) for r in res1s])
    g_tail = jnp.stack([jnp.asarray(p.tail, jnp.int32) for p in puts])
    adv_eff = adv if adv_override is None else jnp.asarray(adv_override,
                                                           jnp.int32)
    if plans_override is not None:
        plans = list(plans_override)
    else:
        plans = [
            plan_steals(adv_eff, g_head, g_tail, jnp.int32(m),
                        n_devices=n_devices, bt=bt, alpha=alpha)
            for m in range(n_devices)
        ]

    out_in = [jnp.zeros_like(res1s[m].out) for m in range(n_devices)]
    mult_in = [jnp.zeros_like(res1s[m].mult) for m in range(n_devices)]
    res2s, res_ss = [], []
    for m in range(n_devices):
        put, res1, plan = puts[m], res1s[m], plans[m]
        sl = slice(m * El, (m + 1) * El)
        rem2 = apply_donation(res1.remaining,
                              donated_cost(put, plan.new_tail))
        state2 = QueueState(
            tasks=put.records, head=res1.head, tail=plan.new_tail,
            local_head=res1.local_head, taken=res1.taken, task_list=None,
            n_tasks_hint=pool_tiles, remaining=rem2,
            pool_off=put.toff[: El + 1],
        )
        res2 = run_moe_schedule(
            state2, xf, put.routed.tok_idx, wg[sl], wu[sl], wd[sl], bt=bt,
            steal=True, steal_policy="cost", rounds=r2, out=res1.out,
            mult=res1.mult, interpret=True,
        )
        res2s.append(res2)

        if not bool(plan.stole):
            res_ss.append(None)
            continue
        v = int(plan.victim)
        vput = puts[v]
        vsl = slice(v * El, (v + 1) * El)
        state_s = QueueState(
            tasks=vput.records, head=plan.s_head, tail=plan.s_tail,
            local_head=jnp.zeros((n_programs, El), jnp.int32),
            taken=jnp.full((pool_tiles,), -1, jnp.int32), task_list=None,
            n_tasks_hint=pool_tiles,
            remaining=(plan.s_tail - plan.s_head) * bt,
            pool_off=vput.toff[: El + 1],
        )
        res_s = run_moe_schedule(
            state_s, xf, vput.routed.tok_idx, wg[vsl], wu[vsl], wd[vsl],
            bt=bt, steal=True, steal_policy="cost", rounds=r2,
            interpret=True,
        )
        res_ss.append(res_s)
        out_in[v] = out_in[v] + res_s.out
        mult_in[v] = mult_in[v] + jnp.asarray(res_s.mult)

    pairs = jnp.zeros((Tk + 1, xf.shape[-1]), jnp.float32)
    mult_total = []
    clocks = []
    for m in range(n_devices):
        out_t = res2s[m].out + out_in[m]
        mult_t = jnp.asarray(res2s[m].mult) + mult_in[m]
        mult_total.append(mult_t)
        pairs = pairs + _pair_combine_part(puts[m].routed, out_t, mult_t,
                                           bt=bt)
        cs = 0 if res_ss[m] is None else int(jnp.asarray(res_ss[m].clock).max())
        clocks.append((int(jnp.asarray(res1s[m].clock).max()),
                       int(jnp.asarray(res2s[m].clock).max()), cs))
    y = _combine_pairs(pairs, gates)
    return EmulatedDispatch(
        y=y, plans=tuple(plans), adv=adv, mult_total=tuple(mult_total),
        clocks=tuple(clocks), tails=tuple(jnp.asarray(p.tail) for p in puts),
    )

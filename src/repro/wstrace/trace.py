"""Structured host-side view of a traced WS launch.

:class:`WSTrace` wraps the decoded event stream of one ``launch_ws_grid``
run (see :mod:`repro.wstrace.ring` for the record schema) plus enough
launch context — program/queue counts, makespan, the initial per-queue cost
loads — to answer the scheduling questions the aggregate ``WSRunResult``
counters cannot: which program stole from whom in which round, how deep
each queue drained, and where the idle rounds went.

All analyses are plain numpy over the int32 stream; nothing here touches
jax, so the module is importable in bare environments (bench decode, CI).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from .ring import (
    EV_COST,
    EV_KIND,
    EV_OP,
    EV_PROG,
    EV_QUEUE,
    EV_ROUND,
    EV_VICTIM,
    EVENT_WIDTH,
    KIND_TAKE,
    decode_rings,
)


def _family_name(op: int) -> str:
    """Resolve an EV_OP code to its task-family name via the registry;
    falls back to the bare op code in bare (registry-less) environments."""
    try:
        from repro.pallas_ws.tasks import family_of

        return family_of(int(op)).name
    except Exception:
        return f"op{int(op)}"


@dataclass
class WSTrace:
    """Decoded event stream + per-launch context of one traced WS run."""

    events: np.ndarray       # [n_events, EVENT_WIDTH], (round, program)-sorted
    n_programs: int
    n_queues: int
    makespan: int
    dropped: np.ndarray      # [n_programs] ring-overflow drops
    queue_loads: Optional[np.ndarray] = None  # initial cost per queue
    mesh_phases: Optional[List[dict]] = field(default=None)
    # per-device phase counters (mesh_ws): phase1_clock, phase2_clock,
    # steal_clock, advisory, victim, stole, take_tiles, collective_bytes

    @classmethod
    def from_run(cls, state, res) -> "WSTrace":
        """Build from a ``QueueState`` + traced ``WSRunResult`` pair."""
        if res.events is None:
            raise ValueError(
                "run has no event rings — launch with trace=True to record"
            )
        stream, dropped = decode_rings(res.events, res.ev_cursor)
        loads = state.remaining
        if loads is None:
            from repro.pallas_ws.queues import queue_costs

            loads = queue_costs(state)
        return cls(
            events=stream,
            n_programs=int(res.events.shape[0]),
            n_queues=int(state.n_queues),
            makespan=int(res.makespan),
            dropped=np.asarray(dropped),
            queue_loads=np.asarray(loads).copy(),
        )

    # -- basic views ------------------------------------------------------

    @property
    def n_events(self) -> int:
        return int(self.events.shape[0])

    @property
    def steal_mask(self) -> np.ndarray:
        return self.events[:, EV_KIND] != KIND_TAKE

    @property
    def n_steals(self) -> int:
        return int(self.steal_mask.sum())

    @property
    def steal_ratio(self) -> float:
        """Fraction of extractions that were cross-queue steals."""
        return self.n_steals / max(1, self.n_events)

    # -- analyses ---------------------------------------------------------

    def utilization(self) -> np.ndarray:
        """Per-round fraction of programs busy, length ``makespan``.

        Each event occupies the tile-slot interval
        ``[EV_ROUND, EV_ROUND + EV_COST)``; intervals are accumulated with a
        difference array, so the cost is O(events + makespan).
        """
        util = np.zeros(max(self.makespan, 1) + 1, np.int64)
        if self.n_events:
            t0 = self.events[:, EV_ROUND]
            t1 = np.minimum(t0 + self.events[:, EV_COST], self.makespan)
            np.add.at(util, t0, 1)
            np.add.at(util, t1, -1)
        busy = np.cumsum(util)[: max(self.makespan, 1)]
        return busy / max(self.n_programs, 1)

    def steal_locality(self) -> dict:
        """Histogram of ring distance ``min(|p - victim|, P - |p - victim|)``
        over steal events whose queue has an owner program (victim >= 0) —
        the locality metric of arXiv:1804.04773.  Unowned-queue steals
        (expert layouts with n_queues > P) are reported under ``"unowned"``.
        """
        ev = self.events[self.steal_mask]
        victims = ev[:, EV_VICTIM]
        owned = victims >= 0
        d = np.abs(ev[owned, EV_PROG] - victims[owned])
        d = np.minimum(d, self.n_programs - d)
        hist = {int(k): int(n) for k, n in zip(*np.unique(d, return_counts=True))}
        unowned = int((~owned).sum())
        if unowned:
            hist["unowned"] = unowned
        return hist

    def family_counts(self) -> dict:
        """Extractions per task family (via EV_OP) — in a unified mixed-mode
        launch this shows all families flowing through ONE ring stream."""
        out: dict = {}
        if self.n_events:
            ops, counts = np.unique(self.events[:, EV_OP], return_counts=True)
            for op, n in zip(ops, counts):
                name = _family_name(int(op))
                out[name] = out.get(name, 0) + int(n)
        return out

    def per_queue_drain(self) -> np.ndarray:
        """Claim events per queue, ``[n_queues]`` — how deep each queue was
        drained (duplicate claims of a rewound slot each count: this is
        extraction traffic, not distinct-slot coverage)."""
        drain = np.zeros(self.n_queues, np.int64)
        if self.n_events:
            np.add.at(drain, self.events[:, EV_QUEUE], 1)
        return drain

    def idle_attribution(self) -> dict:
        """Split each program's idle rounds into *tail* idle (after its last
        event ended — nothing left to claim) and *gap* idle (between events —
        probes that found nothing while work still existed elsewhere)."""
        busy = np.zeros(self.n_programs, np.int64)
        last_end = np.zeros(self.n_programs, np.int64)
        for p in range(self.n_programs):
            ev = self.events[self.events[:, EV_PROG] == p]
            busy[p] = int(ev[:, EV_COST].sum())
            if len(ev):
                last_end[p] = int((ev[:, EV_ROUND] + ev[:, EV_COST]).max())
        idle = np.maximum(self.makespan - busy, 0)
        tail = np.maximum(self.makespan - last_end, 0)
        tail = np.minimum(tail, idle)
        return {
            "idle": idle,
            "tail_idle": tail,
            "gap_idle": idle - tail,
            "total_idle": int(idle.sum()),
            "total_tail_idle": int(tail.sum()),
            "total_gap_idle": int((idle - tail).sum()),
        }

    def summary(self) -> dict:
        """Compact JSON-able digest — the trace-derived bench columns."""
        util = self.utilization()
        idle = self.idle_attribution()
        return {
            "events": self.n_events,
            "dropped": int(self.dropped.sum()),
            "steals": self.n_steals,
            "steal_ratio": round(self.steal_ratio, 4),
            "utilization_mean": round(float(util.mean()), 4),
            "families": self.family_counts(),
            "steal_locality": {str(k): v for k, v in self.steal_locality().items()},
            "tail_idle": idle["total_tail_idle"],
            "gap_idle": idle["total_gap_idle"],
        }

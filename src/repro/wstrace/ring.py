"""Device event-ring schema + host-side decode (numpy only — this module is
imported by the megakernel, so it must not pull in jax or any repro layer).

Device half (written by ``pallas_ws.kernel`` when ``trace=True``): every
successful Take/Steal appends one fixed-width int32 record to the claiming
program's ring row of a preallocated ``[n_programs, capacity, EVENT_WIDTH]``
HBM array, then bumps that program's plain-write cursor.  Both the record
stores and the cursor bump are plain stores — no RMW, no lock, no fence —
so tracing composes with the zero-cost audit instead of breaking it
(``benchmarks/zero_cost.py`` audits the traced-on lowering).

Rings never wrap: a record is written only while ``cursor < capacity``
(overflow-**drop**, not overwrite — the prefix of the run survives), but the
cursor keeps counting, so the host recovers the exact number of dropped
events as ``max(0, cursor - capacity)`` per program.

Record fields (all int32):

=========  ================================================================
EV_ROUND   virtual start round of the execution — ``max(clock[p], r)`` read
           *before* the lockstep clock bump, so ``[round, round + cost)`` is
           exactly the tile-slot interval the program was busy
EV_PROG    claiming program (redundant with the ring row; kept so a
           flattened event stream is self-describing)
EV_QUEUE   queue the slot was claimed from
EV_SLOT    logical slot index within that queue
EV_TID     task id of the claimed record
EV_COST    task cost in tile-slots
EV_KIND    KIND_TAKE / KIND_STEAL_SCAN / KIND_STEAL_COST / KIND_STEAL_REMOTE
EV_VICTIM  owner program of the stolen queue (steals where the queue has a
           same-numbered owner, i.e. ``queue < n_programs``); -1 for takes
           and for unowned queues (expert layouts with n_queues > P)
EV_MULT    the task's multiplicity counter *after* this execution
EV_OP      the claimed record's op id (``tasks.F_OP``) — identifies the task
           family of the event, so a mixed-mode launch (unified engine step)
           decodes into per-family timelines
EV_RUN     slots claimed by the extraction this event belongs to — 1 for
           single-slot Take/Steal, the half-run length for amortized steals
           (``steal_run_cap > 1``), where one probe claims a contiguous run
           and every slot of the run records the same run length
=========  ================================================================
"""

from __future__ import annotations

import numpy as np

EVENT_WIDTH = 11
(EV_ROUND, EV_PROG, EV_QUEUE, EV_SLOT, EV_TID, EV_COST, EV_KIND, EV_VICTIM,
 EV_MULT, EV_OP, EV_RUN) = range(EVENT_WIDTH)

KIND_TAKE = 0
KIND_STEAL_SCAN = 1
KIND_STEAL_COST = 2
KIND_STEAL_REMOTE = 3
KIND_NAMES = ("take", "steal-scan", "steal-cost", "steal-remote")
STEAL_KINDS = (KIND_STEAL_SCAN, KIND_STEAL_COST, KIND_STEAL_REMOTE)


def decode_rings(events, cursor):
    """Flatten per-program rings into one event stream.

    ``events``: ``[n_programs, capacity, EVENT_WIDTH]`` int32 (unwritten
    slots hold -1); ``cursor``: ``[n_programs]`` total appends *attempted*
    per program (valid records are the first ``min(cursor, capacity)``).

    Returns ``(stream, dropped)`` — ``stream`` is ``[n_events, EVENT_WIDTH]``
    sorted by (round, program) so it reads as a timeline, ``dropped`` is the
    per-program count of records lost to ring overflow.
    """
    events = np.asarray(events)
    cursor = np.asarray(cursor)
    n_programs, capacity, width = events.shape
    assert width == EVENT_WIDTH, events.shape
    # Row-major boolean selection over [P, cap] is exactly the per-program
    # prefix concatenation (program-major, slot order preserved) the old
    # Python loop produced — one vectorized gather instead of P slices.
    valid = np.arange(capacity)[None, :] < np.minimum(cursor, capacity)[:, None]
    stream = events[valid].reshape(-1, EVENT_WIDTH)
    if stream.size:
        order = np.lexsort((stream[:, EV_PROG], stream[:, EV_ROUND]))
        stream = stream[order]
    dropped = np.maximum(cursor.astype(np.int64) - capacity, 0)
    return stream.astype(np.int32, copy=False), dropped

"""Chrome/Perfetto ``trace_event`` export of a :class:`WSTrace`.

The exported JSON loads directly in https://ui.perfetto.dev (or
``chrome://tracing``).  Timeline mapping — the scheduler's virtual clock is
the lockstep *tile-slot round*, exported 1 round = 1 µs:

* **pid 0 "ws programs"** — one thread track per program; every extraction
  is a complete ("X") slice ``[EV_ROUND, EV_ROUND + EV_COST)`` named by its
  kind and queue, with slot/tid/multiplicity/victim in ``args``.
* **flow arrows** — each steal event emits a flow start ("s") on the victim
  program's track (or on the stolen queue's track under pid 1 when the
  queue has no owner program) and a flow finish ("f") on the thief's slice,
  so work migration renders as arrows.
* **pid 1 "ws queues"** — anchor slices for steals of unowned queues
  (expert layouts with more queues than programs).
* **counter tracks ("C")** — per-queue ``remaining[q]`` advisory
  reconstructed from the initial queue loads minus each claim's cost at its
  start round: round-aligned sawtooth counters next to the slices.
* **pid 2 "mesh devices"** — when the trace carries ``mesh_phases``
  (cross-device runs): per-device phase slices (local drain / steal) plus
  advisory and collective-bytes counters.
* **pid 3 "ws task families"** — one thread track per task family
  (resolved from EV_OP): the same extraction intervals re-grouped by
  family, so a unified mixed-mode launch renders its decode / prefill /
  expert / glue phases as parallel per-family timelines.  Slices only — no
  extra counters or flows.

Everything is derived from the plain-store event rings — the export adds
zero cost to the traced run.
"""

from __future__ import annotations

import json

import numpy as np

from .ring import (
    EV_COST,
    EV_KIND,
    EV_MULT,
    EV_OP,
    EV_PROG,
    EV_QUEUE,
    EV_ROUND,
    EV_RUN,
    EV_SLOT,
    EV_TID,
    EV_VICTIM,
    KIND_NAMES,
    KIND_TAKE,
)
from .trace import _family_name

PID_PROGRAMS = 0
PID_QUEUES = 1
PID_MESH = 2
PID_FAMILIES = 3


def _meta(pid, name, tid=None, tname=None):
    ev = []
    if name is not None:
        ev.append({"ph": "M", "pid": pid, "name": "process_name",
                   "args": {"name": name}})
    if tid is not None:
        ev.append({"ph": "M", "pid": pid, "tid": tid, "name": "thread_name",
                   "args": {"name": tname}})
    return ev


def to_perfetto(trace) -> dict:
    """Render a :class:`~repro.wstrace.trace.WSTrace` as a trace_event dict."""
    out = []
    out += _meta(PID_PROGRAMS, "ws programs")
    for p in range(trace.n_programs):
        out += _meta(PID_PROGRAMS, None, tid=p, tname=f"program {p}")

    queue_anchor_tracks = set()
    flow_id = 0
    for ev in np.asarray(trace.events):
        t0, p, q, slot, tid, cost, kind, victim, mult = (
            int(ev[EV_ROUND]), int(ev[EV_PROG]), int(ev[EV_QUEUE]),
            int(ev[EV_SLOT]), int(ev[EV_TID]), int(ev[EV_COST]),
            int(ev[EV_KIND]), int(ev[EV_VICTIM]), int(ev[EV_MULT]),
        )
        kname = KIND_NAMES[kind] if 0 <= kind < len(KIND_NAMES) else str(kind)
        out.append({
            "ph": "X", "pid": PID_PROGRAMS, "tid": p,
            "ts": t0, "dur": max(cost, 1),
            "name": f"{kname} q{q}", "cat": kname,
            "args": {"queue": q, "slot": slot, "task": tid,
                     "multiplicity": mult, "victim": victim,
                     "run": int(ev[EV_RUN])},
        })
        if kind == KIND_TAKE:
            continue
        # steal: arrow from the victim's track (owner program when the
        # queue has one, else the queue's own anchor track) to the thief
        flow_id += 1
        if victim >= 0:
            src = {"pid": PID_PROGRAMS, "tid": victim}
        else:
            src = {"pid": PID_QUEUES, "tid": q}
            if q not in queue_anchor_tracks:
                queue_anchor_tracks.add(q)
            out.append({
                "ph": "X", "pid": PID_QUEUES, "tid": q,
                "ts": t0, "dur": max(cost, 1),
                "name": f"stolen by p{p}", "cat": "steal-victim",
                "args": {"thief": p, "slot": slot, "task": tid},
            })
        out.append({"ph": "s", "id": flow_id, "cat": "steal",
                    "name": "steal", "ts": t0, **src})
        out.append({"ph": "f", "bp": "e", "id": flow_id, "cat": "steal",
                    "name": "steal", "ts": t0,
                    "pid": PID_PROGRAMS, "tid": p})
    if queue_anchor_tracks:
        out += _meta(PID_QUEUES, "ws queues")
        for q in sorted(queue_anchor_tracks):
            out += _meta(PID_QUEUES, None, tid=q, tname=f"queue {q}")

    # per-family timelines: the same extraction intervals keyed by EV_OP,
    # one thread track per family.  "X" slices ONLY — the pid-0 tracks stay
    # the canonical per-program view and keep all counters/flows.
    events = np.asarray(trace.events)
    if events.size:
        family_ops = sorted(int(op) for op in np.unique(events[:, EV_OP]))
        out += _meta(PID_FAMILIES, "ws task families")
        for op in family_ops:
            out += _meta(PID_FAMILIES, None, tid=op,
                         tname=f"{_family_name(op)} (op {op})")
        for ev in events:
            op = int(ev[EV_OP])
            out.append({
                "ph": "X", "pid": PID_FAMILIES, "tid": op,
                "ts": int(ev[EV_ROUND]), "dur": max(int(ev[EV_COST]), 1),
                "name": f"{_family_name(op)} t{int(ev[EV_TID])}",
                "cat": "family",
                "args": {"program": int(ev[EV_PROG]),
                         "queue": int(ev[EV_QUEUE]),
                         "task": int(ev[EV_TID]),
                         "multiplicity": int(ev[EV_MULT])},
            })

    # remaining[q] advisory counters: initial load at ts 0, then one sample
    # after each claim at the claim's start round
    if trace.queue_loads is not None:
        remaining = np.asarray(trace.queue_loads, np.int64).copy()
        for q in range(trace.n_queues):
            out.append({"ph": "C", "pid": PID_PROGRAMS, "ts": 0,
                        "name": f"remaining q{q}",
                        "args": {"tiles": int(remaining[q])}})
        for ev in np.asarray(trace.events):
            q = int(ev[EV_QUEUE])
            remaining[q] = max(int(remaining[q]) - int(ev[EV_COST]), 0)
            out.append({"ph": "C", "pid": PID_PROGRAMS,
                        "ts": int(ev[EV_ROUND]),
                        "name": f"remaining q{q}",
                        "args": {"tiles": int(remaining[q])}})

    if trace.mesh_phases:
        out += _meta(PID_MESH, "mesh devices")
        for d, ph in enumerate(trace.mesh_phases):
            out += _meta(PID_MESH, None, tid=d, tname=f"device {d}")
            c1 = int(ph.get("phase1_clock", 0))
            c2 = int(ph.get("phase2_clock", 0))
            cs = int(ph.get("steal_clock", 0))
            out.append({"ph": "X", "pid": PID_MESH, "tid": d, "ts": 0,
                        "dur": max(c1, 1), "name": "phase1 local drain",
                        "cat": "mesh", "args": {"clock": c1}})
            if cs or ph.get("stole"):
                out.append({
                    "ph": "X", "pid": PID_MESH, "tid": d, "ts": c1,
                    "dur": max(cs, 1), "name": "phase2 remote steal",
                    "cat": "mesh",
                    "args": {"victim": int(ph.get("victim", -1)),
                             "tiles": int(ph.get("take_tiles", 0))},
                })
                victim = int(ph.get("victim", -1))
                if victim >= 0:
                    flow_id += 1
                    out.append({"ph": "s", "id": flow_id, "cat": "steal",
                                "name": "remote-steal", "ts": c1,
                                "pid": PID_MESH, "tid": victim})
                    out.append({"ph": "f", "bp": "e", "id": flow_id,
                                "cat": "steal", "name": "remote-steal",
                                "ts": c1, "pid": PID_MESH, "tid": d})
            elif c2:
                out.append({"ph": "X", "pid": PID_MESH, "tid": d, "ts": c1,
                            "dur": max(c2, 1), "name": "phase2 idle",
                            "cat": "mesh", "args": {"clock": c2}})
            for cname, key in (("advisory tiles", "advisory"),
                               ("collective bytes", "collective_bytes")):
                if key in ph:
                    out.append({"ph": "C", "pid": PID_MESH, "ts": 0,
                                "name": f"{cname} d{d}",
                                "args": {"value": int(ph[key])}})

    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": {"time_unit": "1 tile-slot round = 1 us"}}


def write_perfetto(trace, path) -> None:
    """Write the Perfetto JSON for ``trace`` to ``path``."""
    with open(path, "w") as f:
        json.dump(to_perfetto(trace), f, indent=1)

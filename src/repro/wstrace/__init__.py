"""repro.wstrace — observability for the fence-free WS scheduler.

Device half: a per-program event ring buffer the megakernel appends to with
plain stores only (schema in :mod:`.ring`); host half: structured
:class:`~repro.wstrace.trace.WSTrace` analyses, Chrome/Perfetto timeline
export (:mod:`.perfetto`), and the serving-side
:class:`~repro.wstrace.metrics.SchedulerMetrics` sink.

Lazy exports (PEP 562) keep this importable from the kernel layer without
dragging the analysis modules into every launch.
"""

_EXPORTS = {
    "EVENT_WIDTH": ".ring",
    "EV_ROUND": ".ring",
    "EV_PROG": ".ring",
    "EV_QUEUE": ".ring",
    "EV_SLOT": ".ring",
    "EV_TID": ".ring",
    "EV_COST": ".ring",
    "EV_KIND": ".ring",
    "EV_VICTIM": ".ring",
    "EV_MULT": ".ring",
    "EV_OP": ".ring",
    "EV_RUN": ".ring",
    "KIND_TAKE": ".ring",
    "KIND_STEAL_SCAN": ".ring",
    "KIND_STEAL_COST": ".ring",
    "KIND_STEAL_REMOTE": ".ring",
    "KIND_NAMES": ".ring",
    "STEAL_KINDS": ".ring",
    "decode_rings": ".ring",
    "WSTrace": ".trace",
    "to_perfetto": ".perfetto",
    "write_perfetto": ".perfetto",
    "SchedulerMetrics": ".metrics",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    try:
        module = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(module, __name__), name)


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))

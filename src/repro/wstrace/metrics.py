"""Serving-side scheduler metrics sink.

:class:`SchedulerMetrics` is the host-process counterpart of the device
event rings: ``ContinuousBatcher`` records one sample per engine step
(wall latency + live-slot occupancy) and one event per admission /
completion, and ``stats()``/``snapshot()`` reduce them to the serving
numbers the ROADMAP's traffic-harness item tracks — per-step latency
percentiles (p50/p99), slot utilization, and admission/completion totals.

Pure-python lists + numpy percentiles; recording is O(1) appends so the
sink adds no measurable cost to the step loop it instruments.
"""

from __future__ import annotations

import numpy as np


class SchedulerMetrics:
    """Accumulates per-step serving telemetry; reduce with :meth:`snapshot`."""

    def __init__(self, slots: int | None = None):
        self.slots = slots
        self.step_latency_s: list[float] = []
        self.step_live: list[int] = []
        self.admitted = 0
        self.completed = 0
        # watchdog trips (unified→split fallback events), keyed by kind
        # ("non-finite", "deadline") — the graceful-degradation ledger
        self.degradations: dict[str, int] = {}

    def record_step(self, latency_s: float, n_live: int) -> None:
        self.step_latency_s.append(float(latency_s))
        self.step_live.append(int(n_live))

    def record_admission(self, n: int = 1) -> None:
        self.admitted += n

    def record_completion(self, n: int = 1) -> None:
        self.completed += n

    def record_degradation(self, kind: str) -> None:
        self.degradations[kind] = self.degradations.get(kind, 0) + 1

    def snapshot(self) -> dict:
        """Reduce to a JSON-able dict: latency histogram summary (ms),
        mean slot utilization, and admission/completion counters."""
        lat = np.asarray(self.step_latency_s, np.float64) * 1e3
        live = np.asarray(self.step_live, np.float64)
        out = {
            "steps": int(lat.size),
            "admitted": self.admitted,
            "completed": self.completed,
            "latency_ms": None,
            "slot_utilization": None,
            "live_mean": float(live.mean()) if live.size else 0.0,
            "degradations": dict(self.degradations),
        }
        if lat.size:
            out["latency_ms"] = {
                "p50": float(np.percentile(lat, 50)),
                "p99": float(np.percentile(lat, 99)),
                "mean": float(lat.mean()),
                "max": float(lat.max()),
            }
        if live.size and self.slots:
            out["slot_utilization"] = float(live.mean() / self.slots)
        return out

"""Mamba-2 SSD (state-space duality) block — chunked train/prefill + O(1) decode.

The chunked algorithm (Dao & Gu 2024): within a chunk the recurrence is
computed as a masked quadratic form (MXU-friendly); across chunks a small
recurrent state [H, P, N] is carried by a scan.  This file is the pure-jnp
path (also the oracle for kernels/ssd_scan); heads are sharded over the
`model` mesh axis (H = d_inner/headdim is a multiple of 16 for both SSM
archs).

Projections are kept separate (w_z/w_x/w_B/w_C/w_dt) rather than one packed
in_proj: a depthwise conv over concat(x,B,C) factors exactly into three
depthwise convs, and separate tensors shard cleanly.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import dense_init


def init_mamba(key, cfg, dtype):
    d, di, N, H, W = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv_width
    ks = jax.random.split(key, 9)
    return {
        "w_z": dense_init(ks[0], d, (d, di), dtype),
        "w_x": dense_init(ks[1], d, (d, di), dtype),
        "w_B": dense_init(ks[2], d, (d, N), dtype),
        "w_C": dense_init(ks[3], d, (d, N), dtype),
        "w_dt": dense_init(ks[4], d, (d, H), dtype),
        "conv_x": dense_init(ks[5], W, (W, di), dtype),
        "conv_B": dense_init(ks[6], W, (W, N), dtype),
        "conv_C": dense_init(ks[7], W, (W, N), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.zeros((di,), dtype),
        "w_out": dense_init(ks[8], di, (di, d), dtype),
    }


def _causal_conv(x, w):
    """Depthwise causal conv. x: [B, S, C]; w: [W, C]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):  # W=4: unrolled adds, no conv primitive needed
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return jax.nn.silu(out)


def _segsum(dA):
    """dA: [..., q] -> [..., q, q] lower-triangular segment sums."""
    q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]  # ss[i,j] = sum(j+1..i)
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, ss, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, init_state=None):
    """SSD scan. x: [B,S,H,P]; dt: [B,S,H]; A: [H]; B,C: [B,S,N] (1 group).

    Returns (y: [B,S,H,P], final_state: [B,H,P,N]).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    c = S // Q
    assert c * Q == S, (S, Q)
    xr = x.reshape(b, c, Q, H, P)
    dtr = dt.reshape(b, c, Q, H)
    Br = B.reshape(b, c, Q, N)
    Cr = C.reshape(b, c, Q, N)

    xdt = xr * dtr[..., None]  # discretized input
    dA = dtr * A  # [b,c,Q,H]
    dA_cs = jnp.cumsum(dA, axis=2)

    # intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))  # [b,c,H,Q,Q]
    scores = jnp.einsum("bcin,bcjn->bcij", Cr, Br)
    y_diag = jnp.einsum("bcij,bchij,bcjhp->bcihp", scores, L, xdt)

    # per-chunk end states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # [b,c,Q,H]
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Br, decay_states, xdt)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])  # [b,c,H]

    def step(s, inp):
        st_c, dec_c = inp
        out = s
        s = s * dec_c[:, :, None, None] + st_c
        return s, out

    s0 = (
        init_state
        if init_state is not None
        else jnp.zeros((b, H, P, N), jnp.float32)
    )
    final, prev = jax.lax.scan(
        step,
        s0,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32), chunk_decay.transpose(1, 0, 2)),
    )
    prev = prev.transpose(1, 0, 2, 3, 4).astype(x.dtype)  # state entering chunk c

    # state -> output within each chunk
    state_decay = jnp.exp(dA_cs)  # [b,c,Q,H]
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cr, prev, state_decay)
    y = (y_diag + y_off).reshape(b, S, H, P).astype(x.dtype)
    return y, final


def _gated_norm(y, z, weight, eps=1e-6):
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    return (y.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(y.dtype) * (
        1.0 + weight.astype(y.dtype)
    )


def mamba_train(x, p, cfg, *, return_cache: bool = False):
    """x: [B, S, d] -> [B, S, d] (optionally also the decode-resume cache)."""
    b, S, d = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    raw_x = jnp.einsum("bsd,de->bse", x, p["w_x"])
    raw_B = jnp.einsum("bsd,dn->bsn", x, p["w_B"])
    raw_C = jnp.einsum("bsd,dn->bsn", x, p["w_C"])
    xi = _causal_conv(raw_x, p["conv_x"])
    B_ = _causal_conv(raw_B, p["conv_B"])
    C_ = _causal_conv(raw_C, p["conv_C"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_dt"]).astype(jnp.float32) + p["dt_bias"]
    )
    A = -jnp.exp(p["A_log"])
    y, final = ssd_chunked(xi.reshape(b, S, H, P), dt, A, B_, C_, cfg.ssm_chunk)
    y = y + xi.reshape(b, S, H, P) * p["D"][None, None, :, None].astype(y.dtype)
    y = _gated_norm(y.reshape(b, S, -1), z, p["norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"]).astype(x.dtype)
    if not return_cache:
        return out
    W = cfg.ssm_conv_width
    cache = SSMCache(
        state=final,
        conv_x=raw_x[:, -(W - 1):],
        conv_B=raw_B[:, -(W - 1):],
        conv_C=raw_C[:, -(W - 1):],
    )
    return out, cache


class SSMCache(NamedTuple):
    state: jnp.ndarray  # [B, H, P, N] f32
    conv_x: jnp.ndarray  # [B, W-1, di]
    conv_B: jnp.ndarray  # [B, W-1, N]
    conv_C: jnp.ndarray  # [B, W-1, N]


def init_ssm_cache(batch, cfg, dtype):
    H, P, N, W, di = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_conv_width, cfg.d_inner
    return SSMCache(
        state=jnp.zeros((batch, H, P, N), jnp.float32),
        conv_x=jnp.zeros((batch, W - 1, di), dtype),
        conv_B=jnp.zeros((batch, W - 1, N), dtype),
        conv_C=jnp.zeros((batch, W - 1, N), dtype),
    )


def _conv_step(x_new, conv_state, w):
    """x_new: [B, C]; conv_state: [B, W-1, C] (previous inputs, oldest first)."""
    full = jnp.concatenate([conv_state, x_new[:, None, :]], axis=1)  # [B, W, C]
    out = jax.nn.silu(jnp.einsum("bwc,wc->bc", full, w))
    return out, full[:, 1:, :]


def mamba_decode(x, p, cfg, cache: SSMCache):
    """One-token decode. x: [B, 1, d]. O(1) in context length."""
    b = x.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    xt = x[:, 0, :]
    z = jnp.einsum("bd,de->be", xt, p["w_z"])
    xi, cx = _conv_step(jnp.einsum("bd,de->be", xt, p["w_x"]), cache.conv_x, p["conv_x"])
    B_, cb = _conv_step(jnp.einsum("bd,dn->bn", xt, p["w_B"]), cache.conv_B, p["conv_B"])
    C_, cc = _conv_step(jnp.einsum("bd,dn->bn", xt, p["w_C"]), cache.conv_C, p["conv_C"])
    dt = jax.nn.softplus(
        jnp.einsum("bd,dh->bh", xt, p["w_dt"]).astype(jnp.float32) + p["dt_bias"]
    )
    A = -jnp.exp(p["A_log"])
    xh = xi.reshape(b, H, P)
    dA = jnp.exp(dt * A)  # [B, H]
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh.astype(jnp.float32), B_.astype(jnp.float32))
    state = cache.state * dA[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state.astype(x.dtype), C_)
    y = y.astype(x.dtype) + xh * p["D"][None, :, None].astype(x.dtype)
    y = _gated_norm(y.reshape(b, -1), z, p["norm"])
    out = jnp.einsum("be,ed->bd", y, p["w_out"]).astype(x.dtype)[:, None, :]
    return out, SSMCache(state, cx, cb, cc)

"""Mixture-of-Experts layer (deepseek-v2 / kimi-k2 style: shared + routed top-k).

Dense "dropping" dispatch: tokens are processed in fixed-size groups; each
group assigns its tokens to per-expert capacity slots with a cumsum over the
top-k one-hot.  The dispatch/combine einsums contract the token axis against
the expert axis, which is what GSPMD turns into the EP all-to-all when
experts are sharded over the `model` mesh axis.  Tokens over capacity are
dropped from the routed path (they still get the shared-expert output) —
the standard capacity-factor trade.

Peak memory per layer is O(group_size² · top_k · cf) for the dispatch tensor
(independent of expert count), so group_size is the knob that keeps 160- and
384-expert layers compilable at 1M tokens.

The router's load-balancing aux loss (Shazeer/Switch style) is returned to
the caller and summed across scanned layers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init


def init_moe(key, cfg, dtype):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], d, (d, E), jnp.float32),
        "we_g": dense_init(ks[1], d, (E, d, f), dtype),
        "we_u": dense_init(ks[2], d, (E, d, f), dtype),
        "we_d": dense_init(ks[3], f, (E, f, d), dtype),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["ws_g"] = dense_init(ks[4], d, (d, fs), dtype)
        p["ws_u"] = dense_init(ks[5], d, (d, fs), dtype)
        p["ws_d"] = dense_init(ks[6], fs, (fs, d), dtype)
    return p


def _capacity(group_size: int, top_k: int, n_experts: int, cf: float) -> int:
    c = int(cf * group_size * top_k / n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8, floor 8


def router_topk(xg, p, cfg):
    """Grouped router shared by every dispatch: xg [G, g, d] ->
    (probs [G, g, E], normalized top-k gates [G, g, k], idx [G, g, k],
    Switch-style aux loss).  The ws dropless path (repro.moe_ws) reshapes
    through this same function, so routing/aux math cannot drift between
    the traced dense path and the eager scheduler path.
    """
    E, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)  # [G, g, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss: E * sum_e fraction_tokens_e * mean_prob_e
    onehot_any = jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(axis=2)  # [G, g, E]
    frac = onehot_any.mean(axis=1)  # [G, E]
    aux = E * jnp.mean(frac * probs.mean(axis=1))
    return probs, gate_vals, idx, aux


def moe_ffn(x, p, cfg, group_size: int = 1024):
    """x: [B, S, d] -> (y: [B, S, d], aux_loss scalar).

    Memory napkin: the dispatch/combine one-hots are [G, g, E, C] with
    C = cf*g*k/E, i.e. cf*k*g^2 entries per group *independent of E* —
    group_size=1024 keeps them ~10-20 MB/group (bf16) for top-6/top-8
    routers, which is what makes the 160/384-expert archs lowerable at
    1M-token batches.
    """
    B, S, d = x.shape
    E, k, cf = cfg.n_experts, cfg.top_k, cfg.capacity_factor
    T = B * S
    g = min(group_size, T)
    G = T // g
    assert G * g == T, (T, g)
    C = _capacity(g, k, E, cf)
    xg = x.reshape(G, g, d)

    _, gate_vals, idx, aux = router_topk(xg, p, cfg)

    # capacity slots: position of each (token, choice) within its expert queue
    sel = jax.nn.one_hot(idx, E, dtype=jnp.int32)  # [G, g, k, E]
    flat = sel.reshape(G, g * k, E)
    slot = jnp.cumsum(flat, axis=1) - flat  # [G, g*k, E] slot index per choice
    slot = slot.reshape(G, g, k, E)
    in_cap = (slot < C) & (sel > 0)

    # dispatch [G, g, E, C] / combine (gated) — bf16 to halve the big tensor
    slot_oh = jax.nn.one_hot(jnp.where(in_cap, slot, C), C, dtype=x.dtype)  # drops -> all-zero
    disp = jnp.einsum("gtke,gtkec->gtec", sel.astype(x.dtype), slot_oh * in_cap[..., None].astype(x.dtype))
    comb = jnp.einsum(
        "gtke,gtkec->gtec",
        gate_vals[..., None].astype(x.dtype) * sel.astype(x.dtype),
        slot_oh,
    )

    xe = jnp.einsum("gtec,gtd->gecd", disp, xg)  # -> EP all-to-all
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["we_g"]))
    h = h * jnp.einsum("gecd,edf->gecf", xe, p["we_u"])
    pet = jnp.bfloat16 if getattr(cfg, "bf16_reduce", False) else None
    ye = jnp.einsum("gecf,efd->gecd", h, p["we_d"])
    # contraction over the EP-sharded expert axis: the implicit all-reduce
    # moves `pet` (bf16 halves the EP boundary traffic; see §Perf)
    y = jnp.einsum("gecd,gtec->gtd", ye, comb, preferred_element_type=pet).astype(x.dtype)

    if cfg.n_shared_experts:
        hs = jax.nn.silu(jnp.einsum("gtd,df->gtf", xg, p["ws_g"]))
        hs = hs * jnp.einsum("gtd,df->gtf", xg, p["ws_u"])
        y = y + jnp.einsum("gtf,fd->gtd", hs, p["ws_d"])
    return y.reshape(B, S, d), aux


def moe_ffn_dispatch(x, p, cfg, group_size: int = 1024):
    """Route through the cfg-selected dispatch: ``cfg.moe_dispatch == "ws"``
    runs the dropless work-stealing path (repro.moe_ws),
    ``"mesh-ws"`` the cross-device expert-parallel scheduler
    (repro.mesh_ws: expert queues sharded over the mesh "model" axis, idle
    devices steal remote expert tiles), the explicit default ``"dense"``
    the capacity-dropping einsum path.

    ``"ws"`` holds for eager, traced AND differentiated callers:
    ``moe_ffn_ws`` builds its queues with the traced Put under
    ``jit``/``scan`` (fixed worst-case shapes, see repro.moe_ws.dispatch)
    and carries a custom VJP against the no-drop reference transpose
    (``cfg.moe_grad_dispatch`` picks the backward's evaluation, see
    repro.moe_ws.layer), so the capacity-dropping dense path can never
    silently substitute inside a compiled or differentiated step — it runs
    only when the config asks for it by name.  ``"mesh-ws"`` is
    forward/serving-only (launch.steps rejects it for training).
    """
    dispatch = getattr(cfg, "moe_dispatch", "dense")
    if dispatch == "ws":
        from repro.moe_ws import moe_ffn_ws

        return moe_ffn_ws(
            x, p, cfg, group_size,
            grad_dispatch=getattr(cfg, "moe_grad_dispatch", "dense"),
        )
    if dispatch == "mesh-ws":
        from repro.mesh_ws import moe_ffn_mesh_ws

        return moe_ffn_mesh_ws(x, p, cfg, group_size)
    return moe_ffn(x, p, cfg, group_size)

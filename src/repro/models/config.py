"""Unified model configuration covering all assigned architecture families.

One frozen dataclass drives the generic stack in transformer.py: dense GQA
(llama3.2), GQA+SWA (h2o-danube), depth-scaled dense (minicpm), 5:1
local:global (gemma3), MLA+MoE (deepseek-v2), large MoE (kimi-k2), VLM
backbone (pixtral), encoder-decoder audio backbone (whisper), SSD state-space
(mamba2) and hybrid mamba+shared-attention (zamba2).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None  # default d_model // n_heads

    # -- attention variants -------------------------------------------------
    attn_kind: str = "gqa"  # gqa | mla
    window: int = 0  # sliding-window size; 0 = full attention
    # per-layer window pattern: e.g. gemma3 = 5 local then 1 global per group.
    # locals_per_global == 0 -> uniform (window applies to all layers if set)
    locals_per_global: int = 0

    # -- MLA (deepseek-v2) ---------------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 0  # defaults to head_dim

    # -- MoE -----------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden (deepseek/kimi "d_ff" column)
    first_k_dense: int = 0  # leading dense layers (deepseek-v2: 1)
    capacity_factor: float = 1.25
    # "dense" = capacity-dropping dispatch/combine einsums; "ws" = dropless
    # expert tiles through the repro.moe_ws work-stealing scheduler, eager,
    # traced (jit/scan build queues with the traced Put) AND differentiated
    # (custom VJP against the no-drop reference transpose, DESIGN.md §4.5)
    # — dense never substitutes silently, see moe_ffn_dispatch.  "mesh-ws" =
    # the same dropless dispatch sharded over a device mesh (repro.mesh_ws,
    # DESIGN.md §7): experts partitioned along the "model" axis, idle
    # devices steal remote expert tiles; forward/serving-only.
    moe_dispatch: str = "dense"
    # Backward evaluation of the ws dispatch's custom VJP: "dense" = the
    # closed-form transpose as plain gathers/scatter-adds over the routed
    # pairs (always available); "ws" = the same transpose re-scheduled as
    # per-row tiles through a second megakernel launch.  Ignored unless
    # moe_dispatch == "ws".
    moe_grad_dispatch: str = "dense"

    # -- SSM (mamba2 / zamba2) -------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    ssm_conv_width: int = 4

    # -- hybrid (zamba2): shared attention block applied every k ssm layers ---
    hybrid_attn_every: int = 0

    # -- encoder-decoder (whisper) --------------------------------------------
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    enc_seq_len: int = 1500  # whisper 30s @ 50Hz after conv stub

    # -- modality frontend stub ----------------------------------------------
    # 'none' | 'patch' (vlm: precomputed patch embeddings prepended)
    #        | 'frames' (audio: precomputed frame embeddings into the encoder)
    frontend: str = "none"
    n_patches: int = 0  # vlm: patches per image prepended to the text sequence

    # -- misc -----------------------------------------------------------------
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    depth_scaled_residual: bool = False  # minicpm
    dtype: str = "float32"  # compute/param dtype: float32 for smoke, bfloat16 for dry-run

    # -- beyond-paper perf knobs (see EXPERIMENTS.md §Perf) --------------------
    # Zero-pad attention heads so the head dim divides the 16-way model axis
    # (exactly training-equivalent: padded slices init to zero and receive
    # zero gradients).  Llama 24H -> 32 (group-major, G 3->4); MHA archs pad
    # q and kv together (minicpm 36 -> 48, whisper 8 -> 16).
    pad_heads: bool = False
    # Accumulate TP partial sums in bf16 so the implicit all-reduce moves
    # bf16 instead of f32 (Megatron-style bf16 tensor-parallel comm; XLA
    # otherwise reduces the f32 dot accumulators).  Applied to the einsums
    # whose contraction is model-sharded: attention o-proj, MLP down-proj,
    # MoE combine.
    bf16_reduce: bool = False

    # ------------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 (TP divisibility + MXU lanes).

        Embedding/unembedding tables are allocated at this size; the pad
        columns are masked to -inf in the loss and decode logits.
        """
        return -(-self.vocab_size // 256) * 256

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def eff_heads(self) -> Tuple[int, int]:
        """(n_heads, n_kv_heads) actually allocated (>= config when
        pad_heads; padded slices are zero)."""
        H, Hkv = self.n_heads, self.n_kv_heads
        if not self.pad_heads:
            return H, Hkv
        pad16 = lambda x: -(-x // 16) * 16
        if H == Hkv:  # MHA: pad both together (grouping stays 1:1)
            return pad16(H), pad16(Hkv)
        if H % 16 == 0:
            return H, Hkv  # q already divides; kv stays replicated
        # GQA: grow the group size until Hkv * G divides 16 (group-major
        # layout keeps each q head attached to its original kv head)
        G = H // Hkv
        while (Hkv * G) % 16:
            G += 1
        return Hkv * G, Hkv

    @property
    def v_hd(self) -> int:
        return self.v_head_dim if self.v_head_dim else self.hd

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def layer_windows(self) -> Tuple[int, ...]:
        """Per-layer window (0 = full attention) for attention archs."""
        n = self.n_layers
        if self.locals_per_global > 0:
            k = self.locals_per_global
            return tuple(
                self.window if (i % (k + 1)) < k else 0 for i in range(n)
            )
        return tuple(self.window for _ in range(n))

    @property
    def sub_quadratic(self) -> bool:
        """True if decode cost does not scale with full context (long_500k ok)."""
        if self.family in ("ssm",):
            return True
        if self.family == "hybrid":
            return True  # mamba state + windowed/shared attention
        ws = self.layer_windows
        return all(w > 0 for w in ws) if ws else False

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter count (for 6ND model flops) --------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (embedding included once)."""
        d, V = self.d_model, self.vocab_size
        total = V * d  # embed
        if not self.tie_embeddings:
            total += V * d  # lm head

        def attn_params() -> int:
            if self.attn_kind == "mla":
                hd_n = self.hd
                p = 0
                if self.q_lora_rank:
                    p += d * self.q_lora_rank
                    p += self.q_lora_rank * self.n_heads * (hd_n + self.rope_head_dim)
                else:
                    p += d * self.n_heads * (hd_n + self.rope_head_dim)
                p += d * (self.kv_lora_rank + self.rope_head_dim)
                p += self.kv_lora_rank * self.n_heads * (hd_n + self.v_hd)
                p += self.n_heads * self.v_hd * d
                return p
            q = d * self.n_heads * self.hd
            kv = 2 * d * self.n_kv_heads * self.hd
            o = self.n_heads * self.hd * d
            return q + kv + o

        def dense_ffn(dff) -> int:
            return 3 * d * dff  # SwiGLU

        def moe_ffn() -> int:
            p = d * self.n_experts  # router
            p += self.n_experts * dense_ffn(self.moe_d_ff) // 1
            p += self.n_shared_experts * dense_ffn(self.moe_d_ff)
            return p

        def ssm_block() -> int:
            di, ds, H = self.d_inner, self.ssm_state, self.ssm_heads
            p = d * (2 * di + 2 * ds + H)  # in_proj -> x, z, B, C, dt
            p += di * self.ssm_conv_width  # conv
            p += H + H + di  # A, D, dt_bias-ish
            p += di * d  # out_proj
            return p

        if self.family == "encdec":
            enc = self.n_enc_layers * (attn_params() + dense_ffn(self.d_ff))
            dec = self.n_dec_layers * (2 * attn_params() + dense_ffn(self.d_ff))
            return total + enc + dec
        if self.family == "ssm":
            return total + self.n_layers * ssm_block()
        if self.family == "hybrid":
            # mamba layers have no per-layer MLP; two alternating SHARED
            # attention+MLP blocks are counted once each (zamba2).
            shared = 2 * (attn_params() + dense_ffn(self.d_ff))
            return total + self.n_layers * ssm_block() + shared
        per_layer_attn = attn_params()
        if self.is_moe:
            dense_layers = self.first_k_dense
            moe_layers = self.n_layers - dense_layers
            return (
                total
                + self.n_layers * per_layer_attn
                + dense_layers * dense_ffn(self.d_ff if self.d_ff else self.moe_d_ff * 4)
                + moe_layers * moe_ffn()
            )
        return total + self.n_layers * (per_layer_attn + dense_ffn(self.d_ff))

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        d = self.d_model
        inactive_experts = self.n_experts - self.top_k
        moe_layers = self.n_layers - self.first_k_dense
        return full - moe_layers * inactive_experts * 3 * d * self.moe_d_ff


@dataclass(frozen=True)
class ShapeConfig:
    """One of the assigned input-shape cells."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

"""Sharding rules: logical axes -> mesh axes, with divisibility fallback.

Logical axes used by the model code:

* ``dp``   — batch / token dim: all data-parallel mesh axes (("pod","data")).
* ``tp``   — tensor-parallel dim (heads / ffn inner / vocab / experts): "model".
* ``fsdp`` — ZeRO-style parameter sharding dim: "data" (params are re-gathered
             per scanned layer by GSPMD; the optimizer state inherits the
             sharding, giving ZeRO-1/3 for free).  Enabled per-config
             (`fsdp=True` for the multi-hundred-B archs).
* ``sp``   — sequence dim of decode KV caches: "model" (flash-decoding
             split-K).

``shard(x, *axes)`` applies a with_sharding_constraint if a mesh is active
and the corresponding dim is divisible by the mesh axes' size; otherwise
that dim is left unsharded (e.g. llama's 24 heads on a 16-way model axis
fall back to replicated attention — recorded as a baseline inefficiency in
EXPERIMENTS.md and addressed in the perf pass).

No mesh active (unit tests, CPU smoke) -> everything is a no-op.
"""

from __future__ import annotations

import contextlib
import math
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = {"mesh": None, "fsdp": False}

LOGICAL = {
    "dp": ("pod", "data"),
    "tp": ("model",),
    "fsdp": ("data",),
    "fsdp+": ("data", "pod"),  # ZeRO across pods too (1T-class archs)
    "sp": ("model",),
}


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], fsdp: bool = False):
    prev = dict(_STATE)
    _STATE["mesh"] = mesh
    _STATE["fsdp"] = fsdp
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _STATE.update(prev)


def active_mesh() -> Optional[Mesh]:
    return _STATE["mesh"]


def fsdp_enabled() -> bool:
    return _STATE["fsdp"] and _STATE["mesh"] is not None


def _resolve(axis: Optional[str], dim: int, mesh: Mesh):
    """Logical axis -> tuple of mesh axes that evenly divide dim (or None)."""
    if axis is None:
        return None
    names = LOGICAL.get(axis, (axis,))
    present = tuple(n for n in names if n in mesh.axis_names)
    if not present:
        return None
    size = math.prod(mesh.shape[n] for n in present)
    if dim % size != 0:
        # try a prefix (e.g. dp=("pod","data") but only "pod" divides)
        for k in range(len(present) - 1, 0, -1):
            size = math.prod(mesh.shape[n] for n in present[:k])
            if dim % size == 0:
                return present[:k]
        return None
    return present if len(present) > 1 else present[0]


def spec_for(shape: Sequence[int], axes: Sequence[Optional[str]], mesh: Mesh) -> P:
    assert len(shape) == len(axes), (shape, axes)
    return P(*(_resolve(a, d, mesh) for d, a in zip(shape, axes)))


def shard(x, *axes: Optional[str]):
    """Constrain x's sharding by logical axes (one per dim; None = any)."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return x
    spec = spec_for(x.shape, axes, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# parameter specs (by tree-path name)

_PARAM_RULES = (
    # (name, logical axes per dim) — <fsdp> resolves to fsdp axis iff enabled.
    ("embed", ("tp", "<fsdp>")),  # [V, d]
    ("unembed", ("<fsdp>", "tp")),  # [d, V]
    ("pos_embed", (None, "<fsdp>")),
    ("wq", ("<fsdp>", "tp", None)),
    ("wk", ("<fsdp>", "tp", None)),
    ("wv", ("<fsdp>", "tp", None)),
    ("wo", ("tp", None, "<fsdp>")),
    ("wdq", ("<fsdp>", None)),
    ("wuq", (None, "tp", None)),
    ("wdkv", ("<fsdp>", None)),
    ("wkr", ("<fsdp>", None)),
    ("wuk", (None, "tp", None)),
    ("wuv", (None, "tp", None)),
    ("wg", ("<fsdp>", "tp")),
    ("wu", ("<fsdp>", "tp")),
    ("wd", ("tp", "<fsdp>")),
    ("router", ("<fsdp>", None)),
    ("we_g", ("tp", "<fsdp>", None)),  # experts = EP over model
    ("we_u", ("tp", "<fsdp>", None)),
    ("we_d", ("tp", None, "<fsdp>")),
    ("ws_g", ("<fsdp>", "tp")),
    ("ws_u", ("<fsdp>", "tp")),
    ("ws_d", ("tp", "<fsdp>")),
    ("w_z", ("<fsdp>", "tp")),
    ("w_x", ("<fsdp>", "tp")),
    ("w_B", ("<fsdp>", None)),
    ("w_C", ("<fsdp>", None)),
    ("w_dt", ("<fsdp>", None)),
    ("conv_x", (None, "tp")),
    ("w_out", ("tp", "<fsdp>")),
)
_RULES = dict(_PARAM_RULES)


def param_spec(path_name: str, shape: Sequence[int], mesh: Mesh, fsdp: bool, stacked: bool) -> P:
    """Spec for one parameter; `stacked` => leading layer dim (unsharded)."""
    axes = _RULES.get(path_name)
    if axes is None:
        return P()  # norms, biases, small vectors: replicated
    fa = ("fsdp+" if fsdp == "pods" else "fsdp") if fsdp else None
    axes = tuple(fa if a == "<fsdp>" else a for a in axes)
    if stacked:
        axes = (None,) + tuple(axes)
    if len(axes) != len(shape):  # e.g. unstacked variant of a rule
        axes = axes[-len(shape):] if len(axes) > len(shape) else axes + (None,) * (len(shape) - len(axes))
    return spec_for(shape, axes, mesh)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "name"):
            return str(entry.name)
    return ""


def param_shardings(params, mesh: Mesh, fsdp=False, stacked_prefixes=("layers",)):
    """NamedSharding pytree for a params pytree (shapes or arrays)."""

    def one(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        stacked = any(
            getattr(e, "key", None) in stacked_prefixes for e in path if hasattr(e, "key")
        )
        return NamedSharding(mesh, param_spec(name, shape, mesh, fsdp, stacked))

    return jax.tree_util.tree_map_with_path(one, params)

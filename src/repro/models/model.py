"""Public model API: loss_fn (train), prefill, decode_step, cache init.

All functions are pure and mesh-agnostic; sharding enters only through
`repro.models.sharding.shard` constraints, which no-op without a mesh.

Batch dict layouts per family (everything int32/bf16 jnp arrays):
  lm / moe / ssm / hybrid : {"tokens": [B, S]}
  vlm                     : {"tokens": [B, S_text], "patches": [B, n_patches, d]}
  encdec                  : {"tokens": [B, S_text], "frames": [B, enc_S, d]}

Loss is next-token CE over the token positions (VLM: text only).  The vocab
axis stays sharded end-to-end (gold logit via an iota==label mask, reductions
lower to psum over the `model` axis).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import transformer as tf
from .common import rms_norm, swiglu
from .sharding import shard

AUX_LOSS_W = 0.01


# ---------------------------------------------------------------------------
# helpers


def _embed(params, cfg, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    return shard(x, "dp", None, None)


def _unembed_matrix(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T  # [d, V]
    return params["unembed"]


def _positions(B, S, offset=0):
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32) + offset, (B, S))


def vocab_parallel_xent(hidden, w_un, labels, mask=None, valid_vocab=None, row_weights=None):
    """CE keeping V sharded: logits [.., V]; gold via iota==label reduction.

    `valid_vocab` masks the padded vocab columns (cfg.padded_vocab > vocab).
    `row_weights` [B]: return sum_b w_b * token-mean(nll_b) instead of the
    global token mean (the WS scheduler's 1/count multiplicity weighting).
    """
    logits = jnp.einsum("bsd,dv->bsv", hidden, w_un).astype(jnp.float32)
    logits = shard(logits, "dp", None, "tp")
    vpos = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    if valid_vocab is not None and valid_vocab < logits.shape[-1]:
        logits = jnp.where(vpos < valid_vocab, logits, -1e30)
    gold = jnp.sum(jnp.where(vpos == labels[..., None], logits, 0.0), axis=-1)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1))
    lse = jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1)) + m
    nll = lse - gold
    mk = (
        mask.astype(jnp.float32)
        if mask is not None
        else jnp.ones(nll.shape, jnp.float32)
    )
    if row_weights is not None:
        row_mean = (nll * mk).sum(axis=1) / jnp.maximum(mk.sum(axis=1), 1.0)
        return (row_mean * row_weights).sum()
    return (nll * mk).sum() / jnp.maximum(mk.sum(), 1.0)


# ---------------------------------------------------------------------------
# training loss


def loss_fn(params, cfg, batch, *, remat: bool = True, chunk: int = 1024, row_weights=None):
    """Mean next-token CE (+ MoE aux). Returns (loss, metrics).

    `row_weights` [B]: weighted per-row losses (see vocab_parallel_xent) —
    the work-stealing scheduler's multiplicity correction."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    if cfg.family == "encdec":
        enc_out = tf.encode(params, cfg, batch["frames"], remat=remat, chunk=chunk)
        x = _embed(params, cfg, tokens)
        h = tf.decoder_hidden(
            params, cfg, x, _positions(B, S), enc_out, remat=remat, chunk=chunk
        )
        aux = jnp.float32(0.0)
    elif cfg.family == "vlm":
        patches = batch["patches"].astype(jnp.dtype(cfg.dtype))
        x = jnp.concatenate([patches, _embed(params, cfg, tokens)], axis=1)
        Sp = x.shape[1]
        h, aux = tf.lm_hidden(params, cfg, x, _positions(B, Sp), remat=remat, chunk=chunk)
        h = h[:, patches.shape[1]:, :]  # text positions only
    else:
        x = _embed(params, cfg, tokens)
        h, aux = tf.lm_hidden(params, cfg, x, _positions(B, S), remat=remat, chunk=chunk)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
    ce = vocab_parallel_xent(
        h, _unembed_matrix(params, cfg), labels, mask,
        valid_vocab=cfg.vocab_size, row_weights=row_weights,
    )
    loss = ce + AUX_LOSS_W * aux * (
        jnp.sum(row_weights) if row_weights is not None else 1.0
    )
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# caches


class Caches(NamedTuple):
    """Stacked per-layer decode state.  Unused fields are ()."""

    kv: Any = ()  # attention archs: KVCache/MLACache of [L, B, S, ...]
    ssm: Any = ()  # ssm/hybrid: SSMCache of [L, B, ...]
    shared_kv: Any = ()  # hybrid: KVCache of [n_apps, B, S, ...]
    cross_kv: Any = ()  # encdec: KVCache [L, B, enc_S, Hkv, hd]


def init_caches(cfg, batch: int, capacity: int, dtype=None) -> Caches:
    """Zeroed caches with `capacity` sequence slots."""
    dt = jnp.dtype(dtype or cfg.dtype)
    L = cfg.n_layers
    if cfg.family in ("ssm", "hybrid"):
        one = ssm_mod.init_ssm_cache(batch, cfg, dt)
        ssm_c = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (L,) + a.shape), one
        )
        shared = ()
        if cfg.family == "hybrid" and cfg.hybrid_attn_every:
            n_apps = L // cfg.hybrid_attn_every
            shared = attn.KVCache(
                k=jnp.zeros((n_apps, batch, capacity, cfg.eff_heads[1], cfg.hd), dt),
                v=jnp.zeros((n_apps, batch, capacity, cfg.eff_heads[1], cfg.hd), dt),
            )
        return Caches(ssm=ssm_c, shared_kv=shared)
    if cfg.attn_kind == "mla":
        kv = attn.MLACache(
            ckv=jnp.zeros((L, batch, capacity, cfg.kv_lora_rank), dt),
            kr=jnp.zeros((L, batch, capacity, cfg.rope_head_dim), dt),
        )
        return Caches(kv=kv)
    n_layers = cfg.n_dec_layers if cfg.family == "encdec" else L
    kv = attn.KVCache(
        k=jnp.zeros((n_layers, batch, capacity, cfg.eff_heads[1], cfg.hd), dt),
        v=jnp.zeros((n_layers, batch, capacity, cfg.eff_heads[1], cfg.hd), dt),
    )
    if cfg.family == "encdec":
        cross = attn.KVCache(
            k=jnp.zeros((n_layers, batch, cfg.enc_seq_len, cfg.eff_heads[1], cfg.hd), dt),
            v=jnp.zeros((n_layers, batch, cfg.enc_seq_len, cfg.eff_heads[1], cfg.hd), dt),
        )
        return Caches(kv=kv, cross_kv=cross)
    return Caches(kv=kv)


def shard_caches(caches: Caches) -> Caches:
    """Decode caches: sequence-shard over `model` (split-K), batch over dp."""

    def kv_con(a):  # [L, B, S, ...]: seq over sp
        axes = [None, "dp", "sp"] + [None] * (a.ndim - 3)
        return shard(a, *axes)

    def ssm_con(a):  # [L, B, ...]: batch over dp only
        return shard(a, None, "dp", *([None] * (a.ndim - 2)))

    rep = lambda t, f: jax.tree_util.tree_map(f, t) if t != () else ()
    return Caches(
        kv=rep(caches.kv, kv_con),
        ssm=rep(caches.ssm, ssm_con),
        shared_kv=rep(caches.shared_kv, kv_con),
        cross_kv=rep(caches.cross_kv, kv_con),
    )


# ---------------------------------------------------------------------------
# decode


def _layer_cache(full, idx):
    return jax.tree_util.tree_map(
        lambda a: jax.lax.dynamic_index_in_dim(a, idx, 0, keepdims=False), full
    )


def _set_layer_cache(full, one, idx):
    return jax.tree_util.tree_map(
        lambda f, o: jax.lax.dynamic_update_slice_in_dim(f, o[None].astype(f.dtype), idx, 0),
        full,
        one,
    )


def decode_step(params, cfg, caches: Caches, tokens, pos):
    """One decode step. tokens: [B, 1] int32; pos: scalar int32 (slot for the
    new token; attends over cache[0..pos]).  Returns (logits [B, V], caches).
    """
    x = _embed(params, cfg, tokens)
    s = tf._res_scale(cfg)

    if cfg.family in ("ssm", "hybrid"):
        every = cfg.hybrid_attn_every
        shared_kv = caches.shared_kv

        def body(carry, xs):
            h, ssm_full, shared_c = carry
            p, idx = xs
            cache = _layer_cache(ssm_full, idx)
            hn = rms_norm(h, p["norm"], cfg.norm_eps)
            out, new_cache = ssm_mod.mamba_decode(hn, p["mamba"], cfg, cache)
            h = h + out
            ssm_full = _set_layer_cache(ssm_full, new_cache, idx)
            if cfg.family == "hybrid" and every:
                sp = tf._shared_block_params(params, idx, every)
                app = idx // every

                def with_attn(operand):
                    hh, sc = operand
                    hn2 = rms_norm(hh, sp["attn_norm"], cfg.norm_eps)
                    one = jax.tree_util.tree_map(
                        lambda a: jax.lax.dynamic_index_in_dim(a, app, 0, False), sc
                    )
                    a, new_one = attn.gqa_decode(hn2, sp["attn"], cfg, one, pos, 0)
                    hh = hh + a
                    hn3 = rms_norm(hh, sp["mlp_norm"], cfg.norm_eps)
                    hh = hh + swiglu(hn3, sp["mlp"]["wg"], sp["mlp"]["wu"], sp["mlp"]["wd"])
                    sc = jax.tree_util.tree_map(
                        lambda full, o: jax.lax.dynamic_update_slice_in_dim(
                            full, o[None], app, 0
                        ),
                        sc,
                        new_one,
                    )
                    return hh, sc

                h, shared_c = jax.lax.cond(
                    (idx + 1) % every == 0, with_attn, lambda o: o, (h, shared_c)
                )
            return (h, ssm_full, shared_c), None

        (h, new_ssm, shared_kv), _ = jax.lax.scan(
            body, (x, caches.ssm, shared_kv),
            (params["layers"], jnp.arange(cfg.n_layers)),
        )
        new_caches = Caches(ssm=new_ssm, shared_kv=shared_kv)
    elif cfg.family == "encdec":

        def body(carry, xs):
            h, kv_full = carry
            p, cross, idx = xs
            cache = _layer_cache(kv_full, idx)
            hn = rms_norm(h, p["attn_norm"], cfg.norm_eps)
            a, new_cache = attn.gqa_decode(hn, p["attn"], cfg, cache, pos, 0)
            h = h + a
            hn = rms_norm(h, p["cross_norm"], cfg.norm_eps)
            h = h + _cross_decode(hn, p["cross"], cfg, cross)
            hn = rms_norm(h, p["mlp_norm"], cfg.norm_eps)
            h = h + swiglu(hn, p["mlp"]["wg"], p["mlp"]["wu"], p["mlp"]["wd"])
            return (h, _set_layer_cache(kv_full, new_cache, idx)), None

        L = cfg.n_dec_layers
        (h, new_kv), _ = jax.lax.scan(
            body, (x, caches.kv), (params["layers"], caches.cross_kv, jnp.arange(L))
        )
        new_caches = Caches(kv=new_kv, cross_kv=caches.cross_kv)
    else:
        wtuple = cfg.layer_windows

        def one_layer(h, kv_full, p, w, idx):
            # the stacked cache rides the scan CARRY and is updated in place
            # (dynamic-update-slice aliases); emitting per-layer caches as
            # scan outputs would double-buffer the whole KV cache.
            cache = _layer_cache(kv_full, idx)
            hn = rms_norm(h, p["attn_norm"], cfg.norm_eps)
            if cfg.attn_kind == "mla":
                a, new_cache = attn.mla_decode(hn, p["attn"], cfg, cache, pos)
            else:
                a, new_cache = attn.gqa_decode(hn, p["attn"], cfg, cache, pos, w)
            h = h + s * a
            hn = rms_norm(h, p["mlp_norm"], cfg.norm_eps)
            if "moe" in p:
                m, _ = moe_mod.moe_ffn_dispatch(hn, p["moe"], cfg)
            else:
                m = swiglu(hn, p["mlp"]["wg"], p["mlp"]["wu"], p["mlp"]["wd"])
            h = h + s * m
            return h, _set_layer_cache(kv_full, new_cache, idx)

        if len(set(wtuple)) == 1:
            w_static = int(wtuple[0])  # static -> banded cache reads

            def body(carry, xs):
                h, kv_full = carry
                p, idx = xs
                h, kv_full = one_layer(h, kv_full, p, w_static, idx)
                return (h, kv_full), None

            (h, new_kv), _ = jax.lax.scan(
                body, (x, caches.kv), (params["layers"], jnp.arange(cfg.n_layers))
            )
        elif cfg.locals_per_global > 0:
            period = cfg.locals_per_global + 1
            n_groups = cfg.n_layers // period
            grouped = jax.tree_util.tree_map(
                lambda a: a.reshape((n_groups, period) + a.shape[1:]), params["layers"]
            )

            def body(carry, xs):
                h, kv_full = carry
                pgroup, gi = xs
                for j in range(period):
                    pj = jax.tree_util.tree_map(lambda a: a[j], pgroup)
                    h, kv_full = one_layer(
                        h, kv_full, pj, int(wtuple[j]), gi * period + j
                    )
                return (h, kv_full), None

            (h, new_kv), _ = jax.lax.scan(
                body, (x, caches.kv), (grouped, jnp.arange(n_groups))
            )
        else:
            windows = jnp.asarray(wtuple, jnp.int32)

            def body(carry, xs):
                h, kv_full = carry
                p, w, idx = xs
                h, kv_full = one_layer(h, kv_full, p, w, idx)
                return (h, kv_full), None

            (h, new_kv), _ = jax.lax.scan(
                body, (x, caches.kv),
                (params["layers"], windows, jnp.arange(cfg.n_layers)),
            )
        new_caches = Caches(kv=new_kv)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, _unembed_matrix(params, cfg))[:, 0]
    logits = _mask_pad_vocab(logits.astype(jnp.float32), cfg)
    return shard(logits, "dp", "tp"), new_caches


def ws_decode_supported(cfg) -> bool:
    """True when :func:`decode_step_ws` covers this architecture: full
    (unwindowed) GQA decoder families — the shapes continuous batching
    serves.  SSM/hybrid/encdec/MLA keep the dense jitted path."""
    return (
        cfg.family not in ("ssm", "hybrid", "encdec")
        and cfg.attn_kind == "gqa"
        and all(w == 0 for w in cfg.layer_windows)
    )


def decode_step_ws(
    params, cfg, caches: Caches, tokens, pos,
    *, schedule: str = "ws", bk: int = 64, n_programs: int = 8,
):
    """One decode step with attention routed through the device-resident
    work-stealing scheduler (repro.pallas_ws) instead of the dense masked
    contraction baked into :func:`decode_step`.

    Same signature and semantics as :func:`decode_step` (``pos`` may be [B]
    for continuous batching's heterogeneous slots).  Jit-compatible: under
    tracing the per-slot lengths stay on device and the tile queues are
    built by the traced Put (``make_queue_state_jax``); eager calls keep
    the host-side Put with its telemetry.  The layer loop is a plain Python
    loop over the stacked params (statically unrolled when traced — see
    ``repro.serving.engine.jit_decode_step_ws`` for the compiled serving
    entry).  MoE layers route through ``moe_ffn_dispatch`` — with
    ``cfg.moe_dispatch == "ws"`` both the attention *and* the expert FFN of
    a decode step run on the scheduler, eager or compiled.
    """
    assert ws_decode_supported(cfg), cfg.name
    x = _embed(params, cfg, tokens)
    s = tf._res_scale(cfg)
    kv = caches.kv
    h = x
    for idx in range(cfg.n_layers):
        p = jax.tree_util.tree_map(lambda a: a[idx], params["layers"])
        cache = _layer_cache(kv, idx)
        hn = rms_norm(h, p["attn_norm"], cfg.norm_eps)
        a, new_cache = attn.gqa_decode_ws(
            hn, p["attn"], cfg, cache, pos,
            schedule=schedule, bk=bk, n_programs=n_programs,
        )
        h = h + s * a
        hn = rms_norm(h, p["mlp_norm"], cfg.norm_eps)
        if "moe" in p:
            m, _ = moe_mod.moe_ffn_dispatch(hn, p["moe"], cfg)
        else:
            m = swiglu(hn, p["mlp"]["wg"], p["mlp"]["wu"], p["mlp"]["wd"])
        h = h + s * m
        kv = _set_layer_cache(kv, new_cache, idx)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, _unembed_matrix(params, cfg))[:, 0]
    logits = _mask_pad_vocab(logits.astype(jnp.float32), cfg)
    return shard(logits, "dp", "tp"), Caches(kv=kv)


def _cross_decode(x, p, cfg, cross: attn.KVCache):
    """Single-query cross-attention against precomputed encoder K/V."""
    B = x.shape[0]
    hd = cfg.hd
    H, Hkv = p["wq"].shape[1], p["wk"].shape[1]
    G = H // Hkv
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"]).reshape(B, Hkv, G, hd)
    sc = jnp.einsum("bkgd,bskd->bskg", q, cross.k).astype(jnp.float32) * hd**-0.5
    w = jax.nn.softmax(sc, axis=1)
    o = jnp.einsum("bskg,bske->bkge", w.astype(cross.v.dtype), cross.v)
    return jnp.einsum("bshe,hed->bsd", o.reshape(B, 1, H, hd), p["wo"])


# ---------------------------------------------------------------------------
# prefill


def _pad_seq(k, cap):
    """[B, S, ...] -> [B, cap, ...] (zero-padded cache slots)."""
    S = k.shape[1]
    if cap == S:
        return k
    pad = [(0, 0)] * k.ndim
    pad[1] = (0, cap - S)
    return jnp.pad(k, pad)


def prefill(params, cfg, batch, *, capacity: int | None = None, chunk: int = 1024):
    """Process a full prompt; returns (last-token logits [B, V], Caches)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed(params, cfg, tokens)
    positions = _positions(B, S)
    dt = jnp.dtype(cfg.dtype)
    s = tf._res_scale(cfg)

    if cfg.family in ("ssm", "hybrid"):
        h, caches = _prefill_ssm(params, cfg, x, positions, capacity or S, chunk)
    elif cfg.family == "encdec":
        cap = capacity or S
        enc_out = tf.encode(params, cfg, batch["frames"], remat=False, chunk=chunk)

        def body(h, p):
            hn = rms_norm(h, p["attn_norm"], cfg.norm_eps)
            k = jnp.einsum("bsd,dhe->bshe", hn, p["attn"]["wk"])
            v = jnp.einsum("bsd,dhe->bshe", hn, p["attn"]["wv"])
            k = attn.apply_rope(k, positions, cfg.rope_theta)
            h = h + tf._attn_fwd(hn, p, cfg, positions, 0, chunk)
            hn = rms_norm(h, p["cross_norm"], cfg.norm_eps)
            ck = jnp.einsum("bsd,dhe->bshe", enc_out, p["cross"]["wk"])
            cv = jnp.einsum("bsd,dhe->bshe", enc_out, p["cross"]["wv"])
            h = h + tf._cross_attn(hn, p["cross"], cfg, (ck, cv), chunk)
            hn = rms_norm(h, p["mlp_norm"], cfg.norm_eps)
            h = h + swiglu(hn, p["mlp"]["wg"], p["mlp"]["wu"], p["mlp"]["wd"])
            kv = attn.KVCache(_pad_seq(k.astype(dt), cap), _pad_seq(v.astype(dt), cap))
            return h, (kv, attn.KVCache(ck.astype(dt), cv.astype(dt)))

        h, (kv, cross) = jax.lax.scan(body, x, params["layers"])
        caches = Caches(kv=kv, cross_kv=cross)
    else:
        if cfg.family == "vlm":
            patches = batch["patches"].astype(dt)
            x = jnp.concatenate([patches, x], axis=1)
            S = x.shape[1]
            positions = _positions(B, S)
        cap = capacity or S
        windows = jnp.asarray(cfg.layer_windows, jnp.int32)

        def body(h, xs):
            p, w = xs
            hn = rms_norm(h, p["attn_norm"], cfg.norm_eps)
            if cfg.attn_kind == "mla":
                ckv = jnp.einsum("bsd,dr->bsr", hn, p["attn"]["wdkv"])
                kr = attn.apply_rope(
                    jnp.einsum("bsd,de->bse", hn, p["attn"]["wkr"])[:, :, None, :],
                    positions, cfg.rope_theta,
                )[:, :, 0, :]
                a = attn.mla_train(hn, p["attn"], cfg, positions, window=w, chunk=chunk)
                kv = attn.MLACache(_pad_seq(ckv.astype(dt), cap), _pad_seq(kr.astype(dt), cap))
            else:
                k = jnp.einsum("bsd,dhe->bshe", hn, p["attn"]["wk"])
                v = jnp.einsum("bsd,dhe->bshe", hn, p["attn"]["wv"])
                k = attn.apply_rope(k, positions, cfg.rope_theta)
                a = attn.gqa_train(hn, p["attn"], cfg, positions, window=w, chunk=chunk)
                kv = attn.KVCache(_pad_seq(k.astype(dt), cap), _pad_seq(v.astype(dt), cap))
            h = h + s * a
            hn = rms_norm(h, p["mlp_norm"], cfg.norm_eps)
            if "moe" in p:
                m, _ = moe_mod.moe_ffn_dispatch(hn, p["moe"], cfg)
            else:
                m = swiglu(hn, p["mlp"]["wg"], p["mlp"]["wu"], p["mlp"]["wd"])
            h = h + s * m
            return h, kv

        wtuple = cfg.layer_windows
        if len(set(wtuple)) == 1:
            w0 = int(wtuple[0])  # static -> banded flash for windowed archs
            h, kv = jax.lax.scan(lambda hh, p: body(hh, (p, w0)), x, params["layers"])
        elif cfg.locals_per_global > 0:
            period = cfg.locals_per_global + 1
            n_groups = cfg.n_layers // period
            grouped = jax.tree_util.tree_map(
                lambda a: a.reshape((n_groups, period) + a.shape[1:]), params["layers"]
            )

            def group_body(h, pgroup):
                kvs = []
                for j in range(period):
                    pj = jax.tree_util.tree_map(lambda a: a[j], pgroup)
                    h, kv_j = body(h, (pj, int(wtuple[j])))
                    kvs.append(kv_j)
                stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, 0), *kvs)
                return h, stacked

            h, kv = jax.lax.scan(group_body, x, grouped)
            kv = jax.tree_util.tree_map(
                lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), kv
            )
        else:
            h, kv = jax.lax.scan(body, x, (params["layers"], windows))
        caches = Caches(kv=kv)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h[:, -1], _unembed_matrix(params, cfg))
    logits = _mask_pad_vocab(logits.astype(jnp.float32), cfg)
    return shard(logits, "dp", "tp"), caches


def _mask_pad_vocab(logits, cfg):
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    vpos = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(vpos < cfg.vocab_size, logits, -1e30)


def _prefill_ssm(params, cfg, x, positions, cap, chunk):
    """SSM/hybrid prefill.  Hybrid runs as a scan over super-blocks
    (`every` mamba layers + one shared attention block) so the shared-block
    K/V can be collected as scan outputs without an [L, ...] blow-up.
    """
    every = cfg.hybrid_attn_every
    dt = jnp.dtype(cfg.dtype)

    def mamba_layer(h, p):
        hn = rms_norm(h, p["norm"], cfg.norm_eps)
        out, cache = ssm_mod.mamba_train(hn, p["mamba"], cfg, return_cache=True)
        return h + out, cache

    if cfg.family == "ssm" or not every:
        h, caches = jax.lax.scan(mamba_layer, x, params["layers"])
        return h, Caches(ssm=caches)

    n_apps = cfg.n_layers // every
    grouped = jax.tree_util.tree_map(
        lambda a: a.reshape((n_apps, every) + a.shape[1:]), params["layers"]
    )

    def super_block(carry, xs):
        h = carry
        pgroup, app = xs
        h, ssm_caches = jax.lax.scan(mamba_layer, h, pgroup)
        sp = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_index_in_dim(a, app % 2, 0, False),
            params["shared_attn"],
        )
        hn = rms_norm(h, sp["attn_norm"], cfg.norm_eps)
        k = jnp.einsum("bsd,dhe->bshe", hn, sp["attn"]["wk"])
        v = jnp.einsum("bsd,dhe->bshe", hn, sp["attn"]["wv"])
        k = attn.apply_rope(k, positions, cfg.rope_theta)
        h = h + attn.gqa_train(hn, sp["attn"], cfg, positions, window=0, chunk=chunk)
        hn = rms_norm(h, sp["mlp_norm"], cfg.norm_eps)
        h = h + swiglu(hn, sp["mlp"]["wg"], sp["mlp"]["wu"], sp["mlp"]["wd"])
        kv = attn.KVCache(_pad_seq(k.astype(dt), cap), _pad_seq(v.astype(dt), cap))
        return h, (ssm_caches, kv)

    h, (ssm_caches, shared_kv) = jax.lax.scan(
        super_block, x, (grouped, jnp.arange(n_apps))
    )
    # [n_apps, every, ...] -> [L, ...]
    ssm_caches = jax.tree_util.tree_map(
        lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), ssm_caches
    )
    return h, Caches(ssm=ssm_caches, shared_kv=shared_kv)

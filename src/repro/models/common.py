"""Shared building blocks for every architecture family.

Pure-functional JAX: parameters are nested dicts of jnp arrays; repeated
layers are *stacked* along a leading axis and executed with ``lax.scan`` so
the HLO stays small enough to compile 60-layer models against a 512-device
mesh.  Everything here is shape-polymorphic over a batch of tokens
``[B, S, d]`` and takes dtypes from the config.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init


def uniform_init(key, shape, scale, dtype):
    """Scaled uniform init (LeCun-ish); cheap and deterministic."""
    return jax.random.uniform(key, shape, jnp.float32, -scale, scale).astype(dtype)


def dense_init(key, d_in, shape, dtype):
    return uniform_init(key, shape, (3.0 / max(d_in, 1)) ** 0.5, dtype)


# ---------------------------------------------------------------------------
# norms / activations


def rms_norm(x, weight, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def swiglu(x, wg, wu, wd, pet=None):
    """SwiGLU MLP: (silu(x@wg) * (x@wu)) @ wd.  `pet` sets the down-proj
    accumulation dtype (bf16 -> the TP all-reduce moves bf16)."""
    g = jnp.einsum("bsd,df->bsf", x, wg)
    u = jnp.einsum("bsd,df->bsf", x, wu)
    out = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, wd, preferred_element_type=pet)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE


def rope_freqs(head_dim: int, theta: float):
    # iota-based so the table traces as a primitive (jnp.arange with static
    # bounds evaluates eagerly, which a pallas kernel body cannot capture);
    # iota * 2 hits the same exact small-integer floats as arange(0, hd, 2).
    evens = jax.lax.iota(jnp.float32, head_dim // 2) * 2.0
    return 1.0 / (theta ** (evens / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [B, S, H, hd]; positions: [B, S] (int32). Pairs (even, odd) rotated."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, hd/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# cross-entropy (vocab-sharded friendly)


def softmax_xent(logits, labels, mask=None):
    """Mean next-token CE. logits [.., V] f32-upcast; labels [..] int32.

    Stays einsum-friendly for GSPMD when V is sharded: max/logsumexp reduce
    over the sharded axis lowers to a psum.
    """
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def chunked_softmax_xent(hidden, w_unembed, labels, mask=None, chunk: int = 1024):
    """CE without materializing the full [B,S,V] logits: scan over S-chunks.

    The beyond-paper memory optimization for big-vocab archs (gemma3 262k):
    peak activation drops from O(S·V) to O(chunk·V).
    """
    B, S, _ = hidden.shape
    n = S // chunk
    assert n * chunk == S, (S, chunk)
    hid = hidden.reshape(B, n, chunk, -1).swapaxes(0, 1)  # [n, B, c, d]
    lab = labels.reshape(B, n, chunk).swapaxes(0, 1)
    msk = (
        mask.reshape(B, n, chunk).swapaxes(0, 1).astype(jnp.float32)
        if mask is not None
        else jnp.ones((n, B, chunk), jnp.float32)
    )

    def body(acc, xs):
        h, l, mk = xs
        logits = jnp.einsum("bcd,dv->bcv", h, w_unembed).astype(jnp.float32)
        mx = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
        lse = jnp.log(jnp.sum(jnp.exp(logits - mx), axis=-1)) + mx[..., 0]
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        s, c = acc
        return (s + ((lse - gold) * mk).sum(), c + mk.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (hid, lab, msk))
    return tot / jnp.maximum(cnt, 1.0)

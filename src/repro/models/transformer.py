"""Unified stack: one scan-over-stacked-layers implementation drives all ten
assigned architectures (dense GQA / SWA / local:global, MLA+MoE, large MoE,
VLM backbone, encoder-decoder audio backbone, SSD state-space, hybrid).

Per-layer heterogeneity (sliding window size, local-vs-global) is carried as
*scanned data* (an int32 window per layer) rather than unrolled branches, so
the HLO stays one-layer-sized for 60-layer models on a 512-device mesh.
Layers are rematerialized (jax.checkpoint) in training.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .common import dense_init, rms_norm, swiglu
from .attention import _pet
from .sharding import shard


# ---------------------------------------------------------------------------
# init


def _init_mlp(key, d, f, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wg": dense_init(k1, d, (d, f), dtype),
        "wu": dense_init(k2, d, (d, f), dtype),
        "wd": dense_init(k3, f, (f, d), dtype),
    }


def _init_attn_layer(key, cfg, dtype, cross: bool = False):
    ka, km, kc = jax.random.split(key, 3)
    init_a = attn.init_mla if cfg.attn_kind == "mla" else attn.init_gqa
    p = {
        "attn_norm": jnp.zeros((cfg.d_model,), dtype),
        "attn": init_a(ka, cfg, dtype),
        "mlp_norm": jnp.zeros((cfg.d_model,), dtype),
        "mlp": _init_mlp(km, cfg.d_model, cfg.d_ff, dtype),
    }
    if cross:
        p["cross_norm"] = jnp.zeros((cfg.d_model,), dtype)
        p["cross"] = attn.init_gqa(kc, cfg, dtype)
    return p


def _init_moe_layer(key, cfg, dtype):
    ka, km = jax.random.split(key)
    return {
        "attn_norm": jnp.zeros((cfg.d_model,), dtype),
        "attn": (attn.init_mla if cfg.attn_kind == "mla" else attn.init_gqa)(ka, cfg, dtype),
        "mlp_norm": jnp.zeros((cfg.d_model,), dtype),
        "moe": moe_mod.init_moe(km, cfg, dtype),
    }


def _init_ssm_layer(key, cfg, dtype):
    return {
        "norm": jnp.zeros((cfg.d_model,), dtype),
        "mamba": ssm_mod.init_mamba(key, cfg, dtype),
    }


def _stack(layer_fn, keys):
    return jax.vmap(layer_fn)(keys)


def init_params(key, cfg) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    d, V, L = cfg.d_model, cfg.padded_vocab, cfg.n_layers
    params: Dict[str, Any] = {
        "embed": dense_init(keys[0], d, (V, d), dtype),
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[1], d, (d, V), dtype)

    if cfg.family == "encdec":
        enc_keys = jax.random.split(keys[2], cfg.n_enc_layers)
        dec_keys = jax.random.split(keys[3], cfg.n_dec_layers)
        params["enc_layers"] = _stack(lambda k: _init_attn_layer(k, cfg, dtype), enc_keys)
        params["layers"] = _stack(
            lambda k: _init_attn_layer(k, cfg, dtype, cross=True), dec_keys
        )
        params["enc_norm"] = jnp.zeros((d,), dtype)
        return params

    if cfg.family == "ssm":
        lk = jax.random.split(keys[2], L)
        params["layers"] = _stack(lambda k: _init_ssm_layer(k, cfg, dtype), lk)
        return params

    if cfg.family == "hybrid":
        lk = jax.random.split(keys[2], L)
        params["layers"] = _stack(lambda k: _init_ssm_layer(k, cfg, dtype), lk)
        sk = jax.random.split(keys[3], 2)  # two alternating shared blocks
        params["shared_attn"] = _stack(lambda k: _init_attn_layer(k, cfg, dtype), sk)
        return params

    if cfg.is_moe:
        # NOTE: all layers are MoE (the assignment spec lists no leading dense
        # layers; deviation from the HF checkpoint recorded in DESIGN.md).
        lk = jax.random.split(keys[3], L)
        params["layers"] = _stack(lambda k: _init_moe_layer(k, cfg, dtype), lk)
        return params

    # dense decoder (llama / danube / minicpm / gemma3 / pixtral backbone)
    lk = jax.random.split(keys[2], L)
    params["layers"] = _stack(lambda k: _init_attn_layer(k, cfg, dtype), lk)
    return params


# ---------------------------------------------------------------------------
# train/prefill forward


def _res_scale(cfg) -> float:
    return 1.4 / math.sqrt(cfg.n_layers) if cfg.depth_scaled_residual else 1.0


def _attn_fwd(x, p, cfg, positions, window, chunk):
    """p is the *layer* dict (contains 'attn')."""
    if cfg.attn_kind == "mla":
        return attn.mla_train(x, p["attn"], cfg, positions, window=window, chunk=chunk)
    return attn.gqa_train(x, p["attn"], cfg, positions, window=window, chunk=chunk)


def _attn_block(h, p, cfg, positions, window, chunk, causal=True):
    s = _res_scale(cfg)
    hn = rms_norm(h, p["attn_norm"], cfg.norm_eps)
    if causal:
        a = _attn_fwd(hn, p, cfg, positions, window, chunk)
    else:  # encoder self-attention
        q = jnp.einsum("bsd,dhe->bshe", hn, p["attn"]["wq"])
        k = jnp.einsum("bsd,dhe->bshe", hn, p["attn"]["wk"])
        v = jnp.einsum("bsd,dhe->bshe", hn, p["attn"]["wv"])
        q = attn.apply_rope(q, positions, cfg.rope_theta)
        k = attn.apply_rope(k, positions, cfg.rope_theta)
        rep = p["attn"]["wq"].shape[1] // p["attn"]["wk"].shape[1]
        o = attn.flash_ref(
            q, attn.expand_kv(k, rep), attn.expand_kv(v, rep),
            causal=False, window=0, chunk=chunk,
        )
        a = jnp.einsum("bshe,hed->bsd", o, p["attn"]["wo"])
    h = h + s * a
    h = shard(h, "dp", None, None)
    hn = rms_norm(h, p["mlp_norm"], cfg.norm_eps)
    if "moe" in p:
        m, aux = moe_mod.moe_ffn_dispatch(hn, p["moe"], cfg)
    else:
        m, aux = swiglu(hn, p["mlp"]["wg"], p["mlp"]["wu"], p["mlp"]["wd"], pet=_pet(cfg)), 0.0
    h = shard(h + s * m, "dp", None, None)
    return h, aux


def _ssm_block(h, p, cfg):
    hn = rms_norm(h, p["norm"], cfg.norm_eps)
    return shard(h + ssm_mod.mamba_train(hn, p["mamba"], cfg), "dp", None, None)


def _shared_block_params(params, layer_idx, every):
    blk = (layer_idx // every) % 2
    return jax.tree_util.tree_map(
        lambda a: jax.lax.dynamic_index_in_dim(a, blk, 0, keepdims=False),
        params["shared_attn"],
    )


def lm_hidden(params, cfg, x, positions, *, remat: bool = True, chunk: int = 1024):
    """Run the decoder stack on embeddings x -> (hidden, moe_aux)."""
    windows = jnp.asarray(cfg.layer_windows[-params_n_layers(params):], jnp.int32)

    if cfg.family == "ssm" or (cfg.family == "hybrid" and not cfg.hybrid_attn_every):

        def body(h, p):
            return _ssm_block(h, p, cfg), None

        body_fn = jax.checkpoint(body) if remat else body
        h, _ = jax.lax.scan(body_fn, x, params["layers"])
        return h, jnp.float32(0.0)

    if cfg.family == "hybrid":
        # super-block scan: `every` mamba layers then one shared attention
        # block — n_apps attention blocks in the HLO (a lax.cond per layer
        # would lower the attention branch n_layers times).
        every = cfg.hybrid_attn_every
        n_apps = cfg.n_layers // every
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape((n_apps, every) + a.shape[1:]), params["layers"]
        )

        def super_block(h, xs):
            pgroup, app = xs

            def mamba_layer(hh, p):
                return _ssm_block(hh, p, cfg), None

            h, _ = jax.lax.scan(mamba_layer, h, pgroup)
            sp = jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_index_in_dim(a, app % 2, 0, False),
                params["shared_attn"],
            )
            h, _ = _attn_block(h, sp, cfg, positions, 0, chunk)
            return h, None

        body_fn = jax.checkpoint(super_block) if remat else super_block
        h, _ = jax.lax.scan(body_fn, x, (grouped, jnp.arange(n_apps)))
        return h, jnp.float32(0.0)

    # attention stacks (dense / moe / vlm backbone / decoder of encdec)
    aux0 = jnp.float32(0.0)
    wtuple = cfg.layer_windows

    if len(set(wtuple)) == 1:
        # uniform windows: STATIC python int -> banded flash when > 0
        w_static = int(wtuple[0])

        def body(carry, p):
            h, aux = carry
            h, a = _attn_block(h, p, cfg, positions, w_static, chunk)
            return (h, aux + a), None

        body_fn = jax.checkpoint(body) if remat else body
        (h, aux), _ = jax.lax.scan(body_fn, (x, aux0), params["layers"])
        return h, aux

    if cfg.locals_per_global > 0:
        # local:global pattern: scan over period-sized groups, positions
        # unrolled inside so every window is STATIC (banded locals)
        period = cfg.locals_per_global + 1
        n_groups = cfg.n_layers // period
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape((n_groups, period) + a.shape[1:]), params["layers"]
        )

        def group_body(carry, pgroup):
            h, aux = carry
            for j in range(period):
                pj = jax.tree_util.tree_map(lambda a: a[j], pgroup)
                h, a = _attn_block(h, pj, cfg, positions, int(wtuple[j]), chunk)
                aux = aux + a
            return (h, aux), None

        body_fn = jax.checkpoint(group_body) if remat else group_body
        (h, aux), _ = jax.lax.scan(body_fn, (x, aux0), grouped)
        return h, aux

    def body(carry, xs):
        h, aux = carry
        p, w = xs
        h, a = _attn_block(h, p, cfg, positions, w, chunk)
        return (h, aux + a), None

    body_fn = jax.checkpoint(body) if remat else body
    (h, aux), _ = jax.lax.scan(body_fn, (x, aux0), (params["layers"], windows))
    return h, aux


def params_n_layers(params) -> int:
    return jax.tree_util.tree_leaves(params["layers"])[0].shape[0]


# ---------------------------------------------------------------------------
# encoder (whisper)


def encode(params, cfg, frames, *, remat: bool = True, chunk: int = 1024):
    """frames: [B, enc_S, d] precomputed frame embeddings (stub frontend)."""
    B, S, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(h, p):
        h, _ = _attn_block(h, p, cfg, positions, 0, chunk, causal=False)
        return h, None

    body_fn = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(body_fn, frames, params["enc_layers"])
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def _cross_attn(x, p, cfg, enc_kv, chunk):
    """Cross-attention: queries from x, K/V precomputed from encoder output."""
    k, v = enc_kv
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    rep = p["wq"].shape[1] // p["wk"].shape[1]
    o = attn.flash_ref(
        q, attn.expand_kv(k, rep), attn.expand_kv(v, rep),
        causal=False, window=0, chunk=chunk,
    )
    return jnp.einsum("bshe,hed->bsd", o, p["wo"])


def decoder_hidden(params, cfg, x, positions, enc_out, *, remat=True, chunk=1024):
    """Whisper decoder: causal self-attn + cross-attn + mlp, scanned."""

    def body(h, p):
        hn = rms_norm(h, p["attn_norm"], cfg.norm_eps)
        h = h + _attn_fwd(hn, p, cfg, positions, 0, chunk)
        hn = rms_norm(h, p["cross_norm"], cfg.norm_eps)
        k = jnp.einsum("bsd,dhe->bshe", enc_out, p["cross"]["wk"])
        v = jnp.einsum("bsd,dhe->bshe", enc_out, p["cross"]["wv"])
        h = h + _cross_attn(hn, p["cross"], cfg, (k, v), chunk)
        hn = rms_norm(h, p["mlp_norm"], cfg.norm_eps)
        h = h + swiglu(hn, p["mlp"]["wg"], p["mlp"]["wu"], p["mlp"]["wd"])
        return shard(h, "dp", None, None), None

    body_fn = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(body_fn, x, params["layers"])
    return h

"""repro.models — unified multi-family model zoo (see DESIGN.md §4)."""

from .config import SHAPES, ModelConfig, ShapeConfig
from .model import (
    Caches,
    decode_step,
    decode_step_ws,
    init_caches,
    loss_fn,
    prefill,
    shard_caches,
    ws_decode_supported,
)
from .sharding import param_shardings, shard, use_mesh
from .transformer import init_params
from .unified import UnifiedStepReport, decode_step_unified, unified_step_supported

__all__ = [
    "Caches",
    "ModelConfig",
    "UnifiedStepReport",
    "SHAPES",
    "ShapeConfig",
    "decode_step",
    "decode_step_unified",
    "decode_step_ws",
    "init_caches",
    "init_params",
    "loss_fn",
    "param_shardings",
    "prefill",
    "shard",
    "shard_caches",
    "unified_step_supported",
    "use_mesh",
    "ws_decode_supported",
]

"""One megakernel per engine step: the unified mixed-mode launch.

`decode_step_ws` already routes decode attention and the MoE expert FFN
through the fence-free WS scheduler — but as *separate* `launch_ws_grid`
launches per layer, and prefill bypasses the scheduler entirely.  Serving
pays per-launch overhead ~2L+1 times per step and idle programs in one
launch cannot steal the other launch's work.

This module collapses a whole engine step — one decode token for every live
slot, optionally one folded-in prefill prompt — into a SINGLE persistent-grid
`launch_ws_grid` launch mixing all three task families of
`repro.pallas_ws.tasks`:

* **attention** — decode tiles (one `(b, h)` query row sweeping its live kv
  range) and prefill flash tiles (causal `(h, q-block)` tiles), exactly the
  records `emit_decode_tasks` / `emit_flash_tasks` produce;
* **expert** — shared-pool expert-FFN tiles per MoE layer and segment, with
  the *routing gathered in-kernel* from buffers a glue phase wrote;
* **step-glue** — the inter-stage phases (`GLUE_*` codes below): embed,
  per-layer norm/qkv/rope/cache-splice, attention combine + router Put,
  expert combine + shared experts, final logits.

Inter-stage dependencies are the host-computed `stage_open` windows of
`make_staged_queue_state` (Graham-bound prefix sums — DESIGN.md §5): a
stage's queues become visible to Take/Steal only after every task of the
previous stage has finished, so the launch needs no device-side waiting and
the lowering stays fence-free (`benchmarks/zero_cost.py` audits it).

Cost model per family (the mixed-mode queue build): attention tiles charge
kv blocks (`ceil(kv_end / bk)`), expert tiles charge their row capacity
`bt`, glue phases charge 1 — costs are only compared *within* a stage's
Graham window, so the units never mix.

Parity contract (tests/test_unified_step.py): on `float32` configs the
decode half is **bitwise** identical to the split-launch path
(`decode_step_ws`) — every glue phase replays the exact op sequence of the
eager step, the decode tiles are the same records `ragged_decode_attention`
schedules, and interpret mode executes grid cells sequentially so fresh
launches have mult == 1 and the divisors are exact 1.0.  The prefill half
matches `model.prefill` to float tolerance (the flash tiles reduce kv in
`bk`-block online-softmax order, not `flash_ref`'s chunk order); the spliced
k/v caches are bitwise (projection + rope, no reduction reorder).

Multiplicity stays honest in-kernel: tile accumulators are normalized by
`mult[tid]` gathers *inside* the consuming glue phase (the reason
`launch_ws_grid` hands multi-output bodies the live mult ref).
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.pallas_ws.kernel import WSRunResult, _attention_execute, launch_ws_grid
from repro.pallas_ws.queues import QueueState, make_staged_queue_state
from repro.pallas_ws.ragged import _pad_to
from repro.pallas_ws.tasks import (
    BOTTOM,
    F_COST,
    F_E,
    F_LAYER,
    F_OP,
    F_PHASE,
    F_RL,
    F_RS,
    OP_DECODE_TILE,
    OP_EXPERT_TILE,
    OP_FLASH_TILE,
    OP_STEP_GLUE,
    StepGlueTask,
    emit_decode_tasks,
    emit_flash_tasks,
)

from . import attention as attn
from . import transformer as tf
from .common import apply_rope, rms_norm, swiglu
from .model import (
    Caches,
    _mask_pad_vocab,
    _pad_seq,
    _positions,
    _unembed_matrix,
    ws_decode_supported,
)

# Glue phase codes (tasks.F_PHASE of a step-glue record).  One glue task per
# (phase, layer) handles BOTH segments — the decode batch and the optional
# folded-in prefill prompt — since the phases are serial either way.
GLUE_EMBED = 0    # token embedding -> residual stream buffers
GLUE_PRE = 1      # attn norm, qkv + rope, cache splice, tile input staging
GLUE_POST = 2     # attention combine (mult-normalized), wo, mlp norm,
                  # then dense MLP or the MoE router Put
GLUE_COMB = 3     # expert combine (mult-normalized), shared experts, residual
GLUE_LOGITS = 4   # final norm + unembed -> logits buffers

GLUE_COST = 1  # glue phases are serial; cost only sizes their stage window

SEG_DECODE = 0
SEG_PREFILL = 1


@dataclass(frozen=True)
class _UTask:
    """Pre-encoded task record (the unified expert tiles): the queue builder
    only needs `.cost`, `.owner` and `.encode()`, so a raw field tuple is
    enough — operands are resolved in-kernel from the routing buffers."""

    fields: Tuple[int, ...]
    owner: int

    @property
    def cost(self) -> int:
        return int(self.fields[F_COST])

    def encode(self) -> np.ndarray:
        return np.asarray(self.fields, dtype=np.int32)


def _expert_pool_tiles(n_tokens: int, top_k: int, n_experts: int, bt: int) -> int:
    """Static shared-pool tile count for any routing of n_tokens·top_k pairs
    (`route_to_tasks_pool_jax`): ceil(Tk/bt) + E."""
    return -(-(n_tokens * top_k) // bt) + n_experts


# Per-length-vector stage-assembly memo (ROADMAP PR-8 follow-on): the host
# queue build is pure in its geometry inputs — the decode length vector, the
# pending-admission (prefill) shape, and the static tile/config knobs — so
# repeated steps with the same key (steady-state decode advances every
# length by 1, but batches that shrink/regrow repeat keys; repeated replays
# and drills repeat them constantly) reuse the built QueueState verbatim.
# Reuse is safe because launch_ws_grid never mutates its host inputs: every
# mutable array is copied via jnp.asarray and the aliased outputs are new
# buffers.  Bounded: the cache resets when it would exceed _STAGE_CACHE_MAX.
_STAGE_CACHE: Dict[tuple, tuple] = {}
_STAGE_CACHE_STATS = {"builds": 0, "hits": 0}
_STAGE_CACHE_MAX = 128


def stage_cache_stats() -> Dict[str, int]:
    """Copy of the unified-step stage-assembly cache counters (regression
    hook: one ``builds`` increment per unique key, ``hits`` otherwise)."""
    return dict(_STAGE_CACHE_STATS)


def clear_stage_cache() -> None:
    _STAGE_CACHE.clear()
    _STAGE_CACHE_STATS["builds"] = 0
    _STAGE_CACHE_STATS["hits"] = 0


def unified_step_supported(cfg) -> bool:
    """True when :func:`decode_step_unified` covers this architecture with
    its bitwise-decode parity contract: full-attention GQA decoder families
    in float32, token-only prompts, and (for MoE) the WS expert dispatch so
    the split-launch oracle runs the same dropless Put."""
    return (
        ws_decode_supported(cfg)
        and cfg.family != "vlm"
        and cfg.dtype == "float32"
        and (not cfg.is_moe or cfg.moe_dispatch == "ws")
    )


@dataclass
class UnifiedStepReport:
    """Telemetry and prefill results of one unified launch."""

    res: WSRunResult
    state: QueueState
    stage_open: np.ndarray
    rounds: int
    n_tasks: int
    prefill_logits: Optional[jax.Array] = None   # [1, V] when a prompt folded in
    prefill_kv: Optional[attn.KVCache] = None    # [L, 1, cap, Hkv, hd]
    tid_bases: Optional[Dict[str, int]] = None


def _check_drained(n_tasks: int, res: WSRunResult) -> None:
    mult = res.mult
    if isinstance(mult, jax.core.Tracer):
        return  # static Graham windows drain by construction
    if n_tasks and not (np.asarray(mult)[:n_tasks] >= 1).all():
        missing = int((np.asarray(mult)[:n_tasks] == 0).sum())
        raise RuntimeError(
            f"unified step under-provisioned: {missing}/{n_tasks} tasks "
            "never executed (stage windows too small?)"
        )


def decode_step_unified(
    params,
    cfg,
    caches: Caches,
    tokens,
    pos,
    *,
    prefill_tokens=None,
    bk: int = 64,
    bq: int = 32,
    bt: int = 8,
    n_programs: int = 8,
    steal: bool = True,
    steal_policy: str = "cost",
    trace: bool = False,
    check: bool = True,
):
    """One engine step as ONE `launch_ws_grid` launch (DESIGN.md §5).

    Decode semantics match :func:`model.decode_step_ws` bitwise on supported
    configs: ``tokens`` [B, 1] int32, ``pos`` scalar or [B] concrete int32
    (the host Put needs the live lengths), returns ``(logits [B, V] f32,
    Caches, UnifiedStepReport)``.  ``prefill_tokens`` [1, Lp] int32 folds one
    prompt's prefill into the same launch: its flash tiles and (MoE) expert
    tiles share the stage windows with the decode tiles, and the report
    carries the prompt's last-token logits plus its spliced [L, 1, cap, ...]
    k/v cache for the engine to install.

    ``trace=True`` records the per-extraction event rings — a single ring
    stream containing every family's ops, the launch-count witness the
    acceptance criteria ask for.
    """
    assert unified_step_supported(cfg), cfg.name
    B = tokens.shape[0]
    L = cfg.n_layers
    H, Hkv = cfg.eff_heads
    hd = cfg.hd
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    eps = cfg.norm_eps
    theta = cfg.rope_theta
    s = tf._res_scale(cfg)
    is_moe = cfg.is_moe
    E, top_k = cfg.n_experts, cfg.top_k

    cap = caches.kv.k.shape[2]
    pos_h = np.broadcast_to(
        np.asarray(jax.device_get(pos), dtype=np.int64).reshape(-1), (B,)
    )
    lengths = pos_h + 1

    # -- decode tile geometry: exactly what ragged_decode_attention schedules
    bk_d = min(bk, max(1, cap))
    S_pad = -(-cap // bk_d) * bk_d

    has_prefill = prefill_tokens is not None
    if has_prefill:
        assert prefill_tokens.shape[0] == 1, prefill_tokens.shape
        Lp = int(prefill_tokens.shape[1])
        assert 0 < Lp <= cap, (Lp, cap)
        bq_p = min(bq, max(1, Lp))
        bk_p = min(bk, max(1, Lp))
        nq_p = -(-Lp // bq_p)
        Lp_pad = nq_p * bq_p
        Lpk_pad = -(-Lp // bk_p) * bk_p
        n_flash_l = H * nq_p
    else:
        Lp = Lp_pad = Lpk_pad = nq_p = n_flash_l = 0
        bq_p = bk_p = 1

    pool_dec = _expert_pool_tiles(B, top_k, E, bt) if is_moe else 0
    n_rows_dec = pool_dec * bt
    pool_pre = _expert_pool_tiles(Lp, top_k, E, bt) if (is_moe and has_prefill) else 0
    n_rows_pre = pool_pre * bt

    # -- tid allocation: family-grouped contiguous blocks with a constant
    # per-layer stride, so glue phases compute their mult-gather bases from
    # the traced layer index.  tids only index the multiplicity buffer —
    # they are independent of queue/stage placement.
    n_glue = 2 + L * (2 + int(is_moe))
    dec_att_base = n_glue
    pre_att_base = dec_att_base + L * B * H
    exp_dec_base = pre_att_base + L * n_flash_l
    exp_pre_base = exp_dec_base + L * pool_dec
    n_tasks = exp_pre_base + L * pool_pre
    tid_bases = {
        "glue": 0,
        "dec_att": dec_att_base,
        "pre_att": pre_att_base,
        "exp_dec": exp_dec_base,
        "exp_pre": exp_pre_base,
        "n_tasks": n_tasks,
    }

    # -- mixed-mode queue build (the host Put)
    glue_tid = [0]

    def glue(phase, layer):
        t = StepGlueTask(phase, layer, BOTTOM, glue_tid[0], GLUE_COST)
        glue_tid[0] += 1
        return t

    def dec_tiles(layer):
        tasks = emit_decode_tasks(lengths, H, bk_d)
        base = dec_att_base + layer * B * H
        return [dataclasses.replace(t, tid=base + t.tid) for t in tasks]

    def flash_tiles(layer):
        tasks = emit_flash_tasks([Lp], H, bq_p, bk_p, causal=True)
        base = pre_att_base + layer * n_flash_l
        return [dataclasses.replace(t, tid=base + t.tid) for t in tasks]

    def expert_tiles(layer, seg, pool, base_all):
        base = base_all + layer * pool
        return [
            _UTask(
                fields=(OP_EXPERT_TILE, layer, seg, j, BOTTOM, BOTTOM,
                        base + j, bt),
                owner=j,
            )
            for j in range(pool)
        ]

    def build_stages():
        stages = [[glue(GLUE_EMBED, 0)]]
        for lyr in range(L):
            stages.append([glue(GLUE_PRE, lyr)])
            att = dec_tiles(lyr)
            if has_prefill:
                att += flash_tiles(lyr)
            stages.append(att)
            stages.append([glue(GLUE_POST, lyr)])
            if is_moe:
                exp = expert_tiles(lyr, SEG_DECODE, pool_dec, exp_dec_base)
                if has_prefill:
                    exp += expert_tiles(lyr, SEG_PREFILL, pool_pre,
                                        exp_pre_base)
                stages.append(exp)
                stages.append([glue(GLUE_COMB, lyr)])
        stages.append([glue(GLUE_LOGITS, 0)])
        assert glue_tid[0] == n_glue, (glue_tid[0], n_glue)
        return make_staged_queue_state(stages, n_programs, partition="owner")

    # memo key: everything the assembly reads — the length vector, the
    # pending-admission shape, and the static geometry knobs
    cache_key = (
        tuple(int(x) for x in lengths), Lp, B, L, H, bk_d, bq_p, bk_p,
        bt, n_programs, bool(is_moe), E, top_k, pool_dec, pool_pre,
    )
    cached = _STAGE_CACHE.get(cache_key)
    if cached is None:
        state, stage_open, rounds = build_stages()
        _STAGE_CACHE_STATS["builds"] += 1
        if len(_STAGE_CACHE) >= _STAGE_CACHE_MAX:
            _STAGE_CACHE.clear()
        _STAGE_CACHE[cache_key] = (state, stage_open, rounds)
    else:
        _STAGE_CACHE_STATS["hits"] += 1
        state, stage_open, rounds = cached
    assert state.n_tasks == n_tasks, (state.n_tasks, n_tasks)

    # -- output buffers (all accumulated/overwritten in-kernel)
    names = []
    outs = []

    def buf(name, arr):
        names.append(name)
        outs.append(arr)

    Vp = _unembed_matrix(params, cfg).shape[-1]
    buf("kc", jnp.asarray(caches.kv.k))
    buf("vc", jnp.asarray(caches.kv.v))
    buf("h", jnp.zeros((B, 1, d), dt))
    buf("qd", jnp.zeros((B, H, 1, hd), dt))
    buf("ktd", jnp.zeros((B, Hkv, S_pad, hd), dt))
    buf("vtd", jnp.zeros((B, Hkv, S_pad, hd), dt))
    buf("attd", jnp.zeros((B, H, 1, hd), jnp.float32))
    buf("logits", jnp.zeros((B, Vp), jnp.float32))
    if is_moe:
        buf("xfd", jnp.zeros((B, d), dt))
        buf("tokd", jnp.zeros((n_rows_dec,), jnp.int32))
        buf("gated", jnp.zeros((n_rows_dec,), jnp.float32))
        buf("ed", jnp.zeros((pool_dec,), jnp.int32))
        buf("rld", jnp.zeros((pool_dec,), jnp.int32))
        buf("yrd", jnp.zeros((n_rows_dec, d), jnp.float32))
    if has_prefill:
        buf("hp", jnp.zeros((1, Lp, d), dt))
        buf("qp", jnp.zeros((1, H, Lp_pad, hd), dt))
        buf("ktp", jnp.zeros((1, Hkv, Lpk_pad, hd), dt))
        buf("vtp", jnp.zeros((1, Hkv, Lpk_pad, hd), dt))
        buf("attp", jnp.zeros((1, H, Lp_pad, hd), jnp.float32))
        buf("kp", jnp.zeros((L, 1, cap, Hkv, hd), dt))
        buf("vp", jnp.zeros((L, 1, cap, Hkv, hd), dt))
        buf("logp", jnp.zeros((1, Vp), jnp.float32))
        if is_moe:
            buf("xfp", jnp.zeros((Lp, d), dt))
            buf("tokp", jnp.zeros((n_rows_pre,), jnp.int32))
            buf("gatep", jnp.zeros((n_rows_pre,), jnp.float32))
            buf("ep", jnp.zeros((pool_pre,), jnp.int32))
            buf("rlp", jnp.zeros((pool_pre,), jnp.int32))
            buf("yrp", jnp.zeros((n_rows_pre, d), jnp.float32))
    ix = {n: i for i, n in enumerate(names)}

    pos_arr = jnp.asarray(pos_h, jnp.int32)
    pure = [jnp.asarray(tokens, jnp.int32), pos_arr]
    if has_prefill:
        pure.append(jnp.asarray(prefill_tokens, jnp.int32))
        # prompt positions ride in as a pure input — host-built concrete
        # arrays cannot be captured by the kernel trace
        pure.append(jnp.asarray(_positions(1, Lp), jnp.int32))
    leaves, treedef = jax.tree_util.tree_flatten(params)
    n_fixed = len(pure)
    pure += [jnp.asarray(a) for a in leaves]

    # ------------------------------------------------------------------
    # the family-dispatching execute body

    def execute(rec, pure_refs, out_refs, mult_ref):
        def o(name):
            return out_refs[ix[name]]

        tok_ref, posr = pure_refs[0], pure_refs[1]
        ptok_ref = pure_refs[2] if has_prefill else None
        pos_p = pure_refs[3][...] if has_prefill else None
        pv = jax.tree_util.tree_unflatten(
            treedef, [r[...] for r in pure_refs[n_fixed:]]
        )

        op = rec(F_OP)

        @pl.when(op == OP_DECODE_TILE)
        def _decode_tile():
            _attention_execute(
                rec, (o("qd"), o("ktd"), o("vtd")), o("attd"),
                bq=1, bk=bk_d, causal=False, scale=hd**-0.5, g=H // Hkv,
            )

        if has_prefill:

            @pl.when(op == OP_FLASH_TILE)
            def _flash_tile():
                _attention_execute(
                    rec, (o("qp"), o("ktp"), o("vtp")), o("attp"),
                    bq=bq_p, bk=bk_p, causal=True, scale=hd**-0.5,
                    g=H // Hkv,
                )

        def layer_params(lyr):
            return jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_index_in_dim(a, lyr, 0, keepdims=False),
                pv["layers"],
            )

        if is_moe:
            f32 = jnp.float32

            def expert_ffn(xf_ref, tok_r, e_ref, rl_ref, yr_ref, lyr, j):
                """`moe_ws.expert_kernel._expert_execute` verbatim, with the
                (expert, row_len) operands gathered from the routing buffers
                the post-glue wrote and the weights indexed [layer, expert]
                from the stacked params."""
                e = e_ref[j]
                rl = rl_ref[j]
                rs = j * bt
                p_l = layer_params(lyr)
                wg = jax.lax.dynamic_index_in_dim(
                    p_l["moe"]["we_g"], e, 0, keepdims=False
                ).astype(f32)
                wu = jax.lax.dynamic_index_in_dim(
                    p_l["moe"]["we_u"], e, 0, keepdims=False
                ).astype(f32)
                wd = jax.lax.dynamic_index_in_dim(
                    p_l["moe"]["we_d"], e, 0, keepdims=False
                ).astype(f32)
                idxr = tok_r[pl.ds(rs, bt)]
                xt = jnp.take(xf_ref[...], idxr, axis=0).astype(f32)
                hh = jax.nn.silu(
                    jax.lax.dot_general(
                        xt, wg, (((1,), (0,)), ((), ())),
                        preferred_element_type=f32,
                    )
                ) * jax.lax.dot_general(
                    xt, wu, (((1,), (0,)), ((), ())),
                    preferred_element_type=f32,
                )
                yt = jax.lax.dot_general(
                    hh, wd, (((1,), (0,)), ((), ())),
                    preferred_element_type=f32,
                )
                row_live = jax.lax.broadcasted_iota(jnp.int32, (bt, d), 0) < rl
                yt = jnp.where(row_live, yt, 0.0)
                cur = yr_ref[pl.ds(rs, bt), :]
                yr_ref[pl.ds(rs, bt), :] = cur + yt

            @pl.when(op == OP_EXPERT_TILE)
            def _expert_tile():
                lyr = rec(F_E)
                seg = rec(F_RS)
                j = rec(F_RL)

                @pl.when(seg == SEG_DECODE)
                def _dec():
                    expert_ffn(
                        o("xfd"), o("tokd"), o("ed"), o("rld"), o("yrd"),
                        lyr, j,
                    )

                if has_prefill:

                    @pl.when(seg == SEG_PREFILL)
                    def _pre():
                        expert_ffn(
                            o("xfp"), o("tokp"), o("ep"), o("rlp"), o("yrp"),
                            lyr, j,
                        )

        def route_put(x_flat, p_l, tok_r, gate_r, e_ref, rl_ref):
            """The MoE router + traced shared-pool Put (`moe_ffn_ws`'s exact
            routing math), landing in the segment's routing buffers for the
            expert tiles to gather."""
            from repro.moe_ws.dispatch import route_to_tasks_pool_jax
            from repro.moe_ws.layer import _router

            probs, gate_vals, idxs, aux = _router(x_flat, p_l["moe"], cfg, 1024)
            records, n_tiles, toff, routed = route_to_tasks_pool_jax(
                idxs, gate_vals, E, bt=bt
            )
            tok_r[...] = routed.tok_idx
            gate_r[...] = routed.gates
            e_ref[...] = jnp.clip(records[:, F_E], 0, E - 1)
            rl_ref[...] = records[:, F_RL]

        def combine(yr_ref, tok_r, gate_r, mult_base, pool, n_rows, x_flat,
                    p_l, n_tokens):
            """`moe_ws.layer.combine_routed` on the pool layout + shared
            experts — the gate-weighted, multiplicity-normalized scatter."""
            from repro.moe_ws.dispatch import divisor_from_tiles
            from repro.moe_ws.layer import _shared_experts

            mult_e = mult_ref[pl.ds(mult_base, pool)]
            starts = jnp.arange(pool, dtype=jnp.int32) * bt
            div = divisor_from_tiles(starts, bt, mult_e, n_rows)
            yr = yr_ref[...] / div[:, None]
            y = jnp.zeros((n_tokens, d), jnp.float32).at[tok_r[...]].add(
                gate_r[...][:, None] * yr
            )
            if cfg.n_shared_experts:
                y = y + _shared_experts(x_flat, p_l["moe"]).astype(jnp.float32)
            return y

        @pl.when(op == OP_STEP_GLUE)
        def _glue():
            phase = rec(F_PHASE)
            lyr = rec(F_LAYER)

            @pl.when(phase == GLUE_EMBED)
            def _embed_glue():
                o("h")[...] = jnp.take(
                    pv["embed"], tok_ref[...], axis=0
                ).astype(dt)
                if has_prefill:
                    o("hp")[...] = jnp.take(
                        pv["embed"], ptok_ref[...], axis=0
                    ).astype(dt)

            @pl.when(phase == GLUE_PRE)
            def _pre_glue():
                p_l = layer_params(lyr)
                # decode: qkv + rope + cache splice (attention._decode_qkv)
                h = o("h")[...]
                hn = rms_norm(h, p_l["attn_norm"], eps)
                pos_b = posr[...]
                kc_full = o("kc")[...]
                vc_full = o("vc")[...]
                cache = attn.KVCache(
                    jax.lax.dynamic_index_in_dim(kc_full, lyr, 0, keepdims=False),
                    jax.lax.dynamic_index_in_dim(vc_full, lyr, 0, keepdims=False),
                )
                q, new_cache = attn._decode_qkv(hn, p_l["attn"], cfg, cache, pos_b)
                o("kc")[...] = jax.lax.dynamic_update_slice_in_dim(
                    kc_full, new_cache.k[None].astype(kc_full.dtype), lyr, 0
                )
                o("vc")[...] = jax.lax.dynamic_update_slice_in_dim(
                    vc_full, new_cache.v[None].astype(vc_full.dtype), lyr, 0
                )
                o("qd")[...] = q.reshape(B, H, hd)[:, :, None, :]
                o("ktd")[...] = _pad_to(
                    new_cache.k.transpose(0, 2, 1, 3), 2, bk_d
                )
                o("vtd")[...] = _pad_to(
                    new_cache.v.transpose(0, 2, 1, 3), 2, bk_d
                )
                if has_prefill:
                    hp = o("hp")[...]
                    hnp = rms_norm(hp, p_l["attn_norm"], eps)
                    k = jnp.einsum("bsd,dhe->bshe", hnp, p_l["attn"]["wk"])
                    v = jnp.einsum("bsd,dhe->bshe", hnp, p_l["attn"]["wv"])
                    k = apply_rope(k, pos_p, theta)
                    o("kp")[...] = jax.lax.dynamic_update_slice_in_dim(
                        o("kp")[...], _pad_seq(k.astype(dt), cap)[None], lyr, 0
                    )
                    o("vp")[...] = jax.lax.dynamic_update_slice_in_dim(
                        o("vp")[...], _pad_seq(v.astype(dt), cap)[None], lyr, 0
                    )
                    q_p = jnp.einsum("bsd,dhe->bshe", hnp, p_l["attn"]["wq"])
                    q_p = apply_rope(q_p, pos_p, theta)
                    o("qp")[...] = _pad_to(q_p.transpose(0, 2, 1, 3), 2, bq_p)
                    o("ktp")[...] = _pad_to(k.transpose(0, 2, 1, 3), 2, bk_p)
                    o("vtp")[...] = _pad_to(v.transpose(0, 2, 1, 3), 2, bk_p)

            @pl.when(phase == GLUE_POST)
            def _post_glue():
                p_l = layer_params(lyr)
                # decode: multiplicity-normalized attention combine
                # (ragged_decode_attention's divisor), wo, mlp norm
                mult_a = mult_ref[pl.ds(dec_att_base + lyr * (B * H), B * H)]
                div = jnp.maximum(mult_a, 1).astype(jnp.float32).reshape(B, H, 1)
                att = o("attd")[...]
                ob = (att / div[..., None])[:, :, 0].astype(dt)
                a = jnp.einsum(
                    "bshe,hed->bsd", ob.reshape(B, 1, H, hd), p_l["attn"]["wo"]
                )
                h2 = o("h")[...] + s * a
                hn2 = rms_norm(h2, p_l["mlp_norm"], eps)
                if is_moe:
                    x_flat = hn2.reshape(B, d)
                    o("xfd")[...] = x_flat
                    route_put(x_flat, p_l, o("tokd"), o("gated"),
                              o("ed"), o("rld"))
                    o("h")[...] = h2
                else:
                    m = swiglu(hn2, p_l["mlp"]["wg"], p_l["mlp"]["wu"],
                               p_l["mlp"]["wd"])
                    o("h")[...] = h2 + s * m
                o("attd")[...] = jnp.zeros((B, H, 1, hd), jnp.float32)
                if has_prefill:
                    mult_f = mult_ref[
                        pl.ds(pre_att_base + lyr * n_flash_l, n_flash_l)
                    ]
                    divf = jnp.repeat(
                        jnp.maximum(mult_f, 1).astype(jnp.float32).reshape(H, nq_p),
                        bq_p, axis=1,
                    )  # [H, Lp_pad]
                    of = (
                        o("attp")[...] / divf[None, :, :, None]
                    ).transpose(0, 2, 1, 3)[:, :Lp].astype(dt)
                    ap = jnp.einsum(
                        "bshe,hed->bsd", of, p_l["attn"]["wo"],
                        preferred_element_type=attn._pet(cfg),
                    ).astype(dt)
                    hp2 = o("hp")[...] + s * ap
                    hnp2 = rms_norm(hp2, p_l["mlp_norm"], eps)
                    if is_moe:
                        xp_flat = hnp2.reshape(Lp, d)
                        o("xfp")[...] = xp_flat
                        route_put(xp_flat, p_l, o("tokp"), o("gatep"),
                                  o("ep"), o("rlp"))
                        o("hp")[...] = hp2
                    else:
                        mp = swiglu(hnp2, p_l["mlp"]["wg"], p_l["mlp"]["wu"],
                                    p_l["mlp"]["wd"])
                        o("hp")[...] = hp2 + s * mp
                    o("attp")[...] = jnp.zeros(
                        (1, H, Lp_pad, hd), jnp.float32
                    )

            if is_moe:

                @pl.when(phase == GLUE_COMB)
                def _comb_glue():
                    p_l = layer_params(lyr)
                    y = combine(
                        o("yrd"), o("tokd"), o("gated"),
                        exp_dec_base + lyr * pool_dec, pool_dec, n_rows_dec,
                        o("xfd")[...], p_l, B,
                    )
                    m = y.astype(dt).reshape(B, 1, d)
                    o("h")[...] = o("h")[...] + s * m
                    o("yrd")[...] = jnp.zeros((n_rows_dec, d), jnp.float32)
                    if has_prefill:
                        yp = combine(
                            o("yrp"), o("tokp"), o("gatep"),
                            exp_pre_base + lyr * pool_pre, pool_pre,
                            n_rows_pre, o("xfp")[...], p_l, Lp,
                        )
                        mpre = yp.astype(dt).reshape(1, Lp, d)
                        o("hp")[...] = o("hp")[...] + s * mpre
                        o("yrp")[...] = jnp.zeros(
                            (n_rows_pre, d), jnp.float32
                        )

            @pl.when(phase == GLUE_LOGITS)
            def _logits_glue():
                w_un = _unembed_matrix(pv, cfg)
                hf = rms_norm(o("h")[...], pv["final_norm"], eps)
                lg = jnp.einsum("bsd,dv->bsv", hf, w_un)[:, 0]
                o("logits")[...] = _mask_pad_vocab(lg.astype(jnp.float32), cfg)
                if has_prefill:
                    hpf = rms_norm(o("hp")[...], pv["final_norm"], eps)
                    lp = jnp.einsum("bd,dv->bv", hpf[:, -1], w_un)
                    o("logp")[...] = _mask_pad_vocab(lp.astype(jnp.float32), cfg)

    res = launch_ws_grid(
        state, execute, pure, tuple(outs),
        steal=steal, steal_policy=steal_policy, rounds=rounds,
        compress_runs=False, stage_open=stage_open, interpret=True,
        trace=trace,
    )
    if check:
        _check_drained(n_tasks, res)

    out = dict(zip(names, res.out))
    new_caches = Caches(kv=attn.KVCache(k=out["kc"], v=out["vc"]))
    report = UnifiedStepReport(
        res=res, state=state, stage_open=stage_open, rounds=rounds,
        n_tasks=n_tasks,
        prefill_logits=out.get("logp"),
        prefill_kv=(
            attn.KVCache(k=out["kp"], v=out["vp"]) if has_prefill else None
        ),
        tid_bases=tid_bases,
    )
    return out["logits"], new_caches, report

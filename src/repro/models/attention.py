"""Attention variants: GQA (full / causal / sliding-window), MLA (deepseek-v2).

Two execution paths per variant:

* train/prefill — chunked flash-style attention in pure jnp (`flash_ref`):
  outer scan over query chunks, inner scan over key chunks with an online
  softmax, so peak memory is O(chunk²) not O(S²).  This is also the oracle
  for the Pallas kernels in ``repro.kernels``; the dry-run lowers this path.
* decode — one query token against a [B, S, ...] KV cache.  The cache is
  sequence-sharded over the `model` mesh axis (flash-decoding split-K: the
  softmax reduction over S lowers to a psum), which is the only layout that
  both fits HBM at decode_32k/long_500k and needs no head divisibility.

MLA decode uses the *absorbed* formulation: the cache stores the kv_lora
latent (512+64 floats/token instead of 2·H·hd) and W_uk / W_uv are folded
into the query / output projections.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .common import apply_rope, dense_init
from .sharding import shard


def _pet(cfg):
    """Accumulation dtype for model-sharded contractions (cfg.bf16_reduce)."""
    return jnp.bfloat16 if getattr(cfg, "bf16_reduce", False) else None


# ---------------------------------------------------------------------------
# parameter init


def init_gqa(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.hd
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    Hp, Hkvp = cfg.eff_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, (d, Hp, hd), dtype),
        "wk": dense_init(ks[1], d, (d, Hkvp, hd), dtype),
        "wv": dense_init(ks[2], d, (d, Hkvp, hd), dtype),
        "wo": dense_init(ks[3], H * hd, (Hp, hd, d), dtype),
    }
    if Hp != H or Hkvp != Hkv:
        # zero the padded slices: exactly fwd/bwd-equivalent (EXPERIMENTS §Perf)
        G, Gp = H // Hkv, Hp // Hkvp
        q_real = (jnp.arange(Hp) % Gp < G) & (jnp.arange(Hp) // Gp < Hkv)
        kv_real = jnp.arange(Hkvp) < Hkv
        p["wq"] = p["wq"] * q_real[None, :, None].astype(dtype)
        p["wo"] = p["wo"] * q_real[:, None, None].astype(dtype)
        p["wk"] = p["wk"] * kv_real[None, :, None].astype(dtype)
        p["wv"] = p["wv"] * kv_real[None, :, None].astype(dtype)
    return p


def init_mla(key, cfg, dtype):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    qlr, kvlr, rhd, vhd = cfg.q_lora_rank, cfg.kv_lora_rank, cfg.rope_head_dim, cfg.v_hd
    ks = jax.random.split(key, 7)
    p = {
        "wdkv": dense_init(ks[0], d, (d, kvlr), dtype),
        "wkr": dense_init(ks[1], d, (d, rhd), dtype),
        "wuk": dense_init(ks[2], kvlr, (kvlr, H, hd), dtype),
        "wuv": dense_init(ks[3], kvlr, (kvlr, H, vhd), dtype),
        "wo": dense_init(ks[4], H * vhd, (H, vhd, d), dtype),
    }
    if qlr:
        p["wdq"] = dense_init(ks[5], d, (d, qlr), dtype)
        p["wuq"] = dense_init(ks[6], qlr, (qlr, H, hd + rhd), dtype)
    else:
        p["wq"] = dense_init(ks[5], d, (d, H, hd + rhd), dtype)
    return p


# ---------------------------------------------------------------------------
# chunked flash reference (train / prefill)


def _pick_chunk(S: int, chunk: int) -> int:
    """Largest divisor of S that is <= chunk (handles e.g. S=4352 for VLM
    patches+text sequences)."""
    c = min(chunk, S)
    while S % c != 0:
        c -= 1
    return c


def flash_ref(q, k, v, *, causal: bool, window, chunk: int = 1024):
    """Online-softmax attention. q,k,v: [B, S, H, hd] (kv already head-expanded).

    Returns [B, S, H, hd_v].  Masking: causal and/or sliding window
    (key within `window` positions behind the query).  `window` may be a
    *traced* int32 scalar (per-layer windows ride the layer scan); window<=0
    means full attention.

    A STATIC python-int window > 0 selects the *banded* implementation:
    each query chunk contracts only the ceil(window/chunk)+1 key chunks it
    can see, so compute and HBM traffic scale with S*window instead of S²
    (§Perf: the sliding-window archs' prefill/train win).

    Carries a custom VJP: the backward recomputes P blockwise from the saved
    logsumexp (flash semantics), so training memory is O(S·hd) per layer
    instead of O(S²/chunk) saved score blocks.
    """
    if (
        isinstance(window, int)
        and window > 0
        and causal
        and q.shape[1] == k.shape[1]
        and q.shape[1] > window
    ):
        return _flash_banded(q, k, v, window, chunk)
    win = jnp.asarray(window, jnp.int32)
    return _flash(q, k, v, win, jnp.int32(0), causal, chunk)


def _flash_banded(q, k, v, window: int, chunk: int):
    """Causal sliding-window attention over a static band of key chunks.

    Key chunks are gathered per query chunk with dynamic slices (scan-
    friendly: the band width nb = ceil(window/c)+1 is static), then handed
    to the same custom-VJP flash core with a query-position offset so the
    masking stays exact.
    """
    B, S, H, hd = q.shape
    c = _pick_chunk(S, min(chunk, max(window, 16)))
    nq = S // c
    nb = min(-(-window // c) + 1, nq)  # key chunks visible to one q chunk
    kr = k.reshape(B, nq, c, H, hd)
    vr = v.reshape(B, nq, c, H, v.shape[-1])
    qr = q.reshape(B, nq, c, H, hd).transpose(1, 0, 2, 3, 4)  # [nq, B, c, H, hd]
    win = jnp.asarray(window, jnp.int32)

    def q_block(_, qi_qb):
        qi, qb = qi_qb  # [B, c, H, hd]
        lo = jnp.maximum(qi - (nb - 1), 0)
        kb = jax.lax.dynamic_slice_in_dim(kr, lo, nb, axis=1)  # [B, nb, c, ...]
        vb = jax.lax.dynamic_slice_in_dim(vr, lo, nb, axis=1)
        kf = kb.reshape(B, nb * c, H, hd)
        vf = vb.reshape(B, nb * c, H, vb.shape[-1])
        qoff = (qi - lo) * c  # q-chunk start within the gathered band
        out = _flash(qb, kf, vf, win, qoff, True, c)
        return None, out

    _, outs = jax.lax.scan(q_block, None, (jnp.arange(nq), qr))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, v.shape[-1])


def _flash_mask(s, qpos, kpos, causal, win):
    mask = jnp.ones(s.shape[-2:], dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    mask &= jnp.where(win > 0, qpos[:, None] - kpos[None, :] < win, True)
    return jnp.where(mask, s, -1e30)


def _flash_fwd_impl(q, k, v, win, qoff, causal, chunk):
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    hdv = v.shape[-1]
    c = _pick_chunk(Sq, chunk)
    ck = _pick_chunk(Sk, chunk)
    nq, nk = Sq // c, Sk // ck
    scale = hd ** -0.5
    qs = q.reshape(B, nq, c, H, hd).transpose(1, 0, 3, 2, 4)  # [nq, B, H, c, hd]
    ks_ = k.reshape(B, nk, ck, H, hd).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(B, nk, ck, H, hdv).transpose(1, 0, 3, 2, 4)
    pos = jnp.arange(c, dtype=jnp.int32)
    posk = jnp.arange(ck, dtype=jnp.int32)

    def q_block(_, qi_qb):
        qi, qb = qi_qb  # [B, H, c, hd]
        qpos = qoff + qi * c + pos

        def k_block(carry, ki_kb_vb):
            m, l, acc = carry
            ki, kb, vb = ki_kb_vb
            s = jnp.einsum("bhqd,bhkd->bhqk", qb, kb).astype(jnp.float32) * scale
            s = _flash_mask(s, qpos, ki * ck + posk, causal, win)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vb.dtype), vb
            ).astype(jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, H, c), -1e30, jnp.float32)
        l0 = jnp.zeros((B, H, c), jnp.float32)
        a0 = jnp.zeros((B, H, c, hdv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_block, (m0, l0, a0), (jnp.arange(nk), ks_, vs)
        )
        l = jnp.maximum(l, 1e-30)
        out = acc / l[..., None]
        return None, (out.astype(q.dtype), m + jnp.log(l))

    _, (outs, lses) = jax.lax.scan(q_block, None, (jnp.arange(nq), qs))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, Sq, H, hdv)
    lse = lses.transpose(1, 0, 3, 2).reshape(B, Sq, H)  # [nq,B,H,c]->[B,Sq,H]
    return out, lse


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _flash(q, k, v, win, qoff, causal, chunk):
    out, _ = _flash_fwd_impl(q, k, v, win, qoff, causal, chunk)
    return out


def _flash_vjp_fwd(q, k, v, win, qoff, causal, chunk):
    out, lse = _flash_fwd_impl(q, k, v, win, qoff, causal, chunk)
    return out, (q, k, v, win, qoff, out, lse)


def _flash_vjp_bwd(causal, chunk, res, do):
    """Flash backward: P recomputed per (q-chunk, k-chunk) block from the
    saved lse; transients are O(chunk²), dk/dv accumulate in f32."""
    q, k, v, win, qoff, out, lse = res
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    hdv = v.shape[-1]
    scale = hd ** -0.5
    c = _pick_chunk(Sq, chunk)
    ck = _pick_chunk(Sk, chunk)
    nq, nk = Sq // c, Sk // ck
    D = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)  # [B,Sq,H]
    kpos_base = jnp.arange(ck, dtype=jnp.int32)
    qpos_base = jnp.arange(c, dtype=jnp.int32)
    ks_ = k.reshape(B, nk, ck, H, hd).transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    vs = v.reshape(B, nk, ck, H, hdv).transpose(1, 0, 2, 3, 4).astype(jnp.float32)

    def q_chunk(carry, xs):
        dk, dv = carry  # [nk, B, ck, H, hd/v] f32
        qi, qb, dob, lseb, Db = xs  # qb [B,c,H,hd] f32
        qpos = qoff + qi * c + qpos_base

        def k_chunk(inner, xs2):
            dq_i, dk, dv = inner
            ki, kb, vb = xs2  # [B, ck, H, hd]
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb) * scale
            s = _flash_mask(s, qpos, ki * ck + kpos_base, causal, win)
            p = jnp.exp(s - lseb.transpose(0, 2, 1)[..., None])  # [B,H,c,ck]
            dv_blk = jnp.einsum("bhqk,bqhd->bkhd", p, dob)
            dp = jnp.einsum("bqhd,bkhd->bhqk", dob, vb)
            ds = p * (dp - Db.transpose(0, 2, 1)[..., None]) * scale
            dq_i = dq_i + jnp.einsum("bhqk,bkhd->bqhd", ds, kb)
            dk_blk = jnp.einsum("bhqk,bqhd->bkhd", ds, qb)
            dk = dk.at[ki].add(dk_blk)
            dv = dv.at[ki].add(dv_blk)
            return (dq_i, dk, dv), None

        dq0 = jnp.zeros((B, c, H, hd), jnp.float32)
        (dq_i, dk, dv), _ = jax.lax.scan(
            k_chunk, (dq0, dk, dv), (jnp.arange(nk), ks_, vs)
        )
        return (dk, dv), dq_i

    zk = jnp.zeros((nk, B, ck, H, hd), jnp.float32)
    zv = jnp.zeros((nk, B, ck, H, hdv), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(
        q_chunk,
        (zk, zv),
        (
            jnp.arange(nq),
            q.reshape(B, nq, c, H, hd).transpose(1, 0, 2, 3, 4).astype(jnp.float32),
            do.reshape(B, nq, c, H, hdv).transpose(1, 0, 2, 3, 4).astype(jnp.float32),
            lse.reshape(B, nq, c, H).transpose(1, 0, 2, 3),
            D.reshape(B, nq, c, H).transpose(1, 0, 2, 3),
        ),
    )
    dq = dqs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(B, Sk, H, hd)
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(B, Sk, H, hdv)
    import numpy as _np

    dwin = _np.zeros(jnp.shape(win), jax.dtypes.float0)
    dqoff = _np.zeros(jnp.shape(qoff), jax.dtypes.float0)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype), dwin, dqoff





_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def expand_kv(k, n_rep: int):
    """[B, S, Hkv, hd] -> [B, S, Hkv*n_rep, hd] (GQA head expansion)."""
    if n_rep == 1:
        return k
    B, S, Hkv, hd = k.shape
    return jnp.broadcast_to(
        k[:, :, :, None, :], (B, S, Hkv, n_rep, hd)
    ).reshape(B, S, Hkv * n_rep, hd)


# ---------------------------------------------------------------------------
# GQA


def gqa_train(x, p, cfg, positions, window, chunk: int = 1024):
    """Causal (optionally windowed) self-attention over [B, S, d].

    Head counts come from the weight shapes (cfg.eff_heads at init), so
    zero-padded-head configs flow through unchanged.
    """
    H, Hkv = p["wq"].shape[1], p["wk"].shape[1]
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    q = shard(q, "dp", None, "tp", None)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    k = expand_kv(k, H // Hkv)
    v = expand_kv(v, H // Hkv)
    k = shard(k, "dp", None, "tp", None)
    v = shard(v, "dp", None, "tp", None)
    o = flash_ref(q, k, v, causal=True, window=window, chunk=chunk)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"], preferred_element_type=_pet(cfg)).astype(x.dtype)


class KVCache(NamedTuple):
    k: jnp.ndarray  # [B, S, Hkv, hd]
    v: jnp.ndarray


def broadcast_pos(pos, B: int):
    """Scalar or [B] int32 -> [B] (per-slot decode positions)."""
    return jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))


def _update_at(cache, new, pos_b):
    """cache: [B, S, ...]; new: [B, 1, ...]; pos_b: [B] -> per-row write."""
    return jax.vmap(
        lambda c, n, p_: jax.lax.dynamic_update_slice(
            c, n.astype(c.dtype), (p_,) + (0,) * (c.ndim - 1)
        )
    )(cache, new, pos_b)


def _decode_qkv(x, p, cfg, cache: KVCache, pos_b):
    """Shared decode prologue: project q/k/v for the new token, rope at the
    per-slot positions, and splice k/v into the cache.  Returns
    (q [B, 1, H, hd], updated KVCache)."""
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k_new = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v_new = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    q = apply_rope(q, pos_b[:, None], cfg.rope_theta)
    k_new = apply_rope(k_new, pos_b[:, None], cfg.rope_theta)
    kc = _update_at(cache.k, k_new, pos_b)
    vc = _update_at(cache.v, v_new, pos_b)
    return q, KVCache(kc, vc)


def gqa_decode(x, p, cfg, cache: KVCache, pos, window):
    """One-token decode. x: [B, 1, d]; pos: scalar or [B] int32 (tokens so
    far per slot — continuous batching runs heterogeneous positions).

    Attends over cache slots [0, pos_b]; the new token's K/V is written at
    `pos_b`.  Scores are computed in the grouped layout (no head expansion)
    so the S-sharded cache is contracted directly: softmax over S -> psum.
    """
    B = x.shape[0]
    hd = cfg.hd
    H, Hkv = p["wq"].shape[1], p["wk"].shape[1]
    G = H // Hkv
    S = cache.k.shape[1]
    pos_b = broadcast_pos(pos, B)
    q, new_cache = _decode_qkv(x, p, cfg, cache, pos_b)
    kc, vc = new_cache.k, new_cache.v

    qg = q.reshape(B, Hkv, G, hd)
    # NOTE: a banded decode (dynamic window slice of the cache) was tried
    # and REFUTED in §Perf: with the split-K sequence-sharded cache the
    # per-slot window slice forces a reshard (collective) and net-loses;
    # the full-S masked contraction below keeps the reduction local.
    s = jnp.einsum("bkgd,bskd->bskg", qg, kc).astype(jnp.float32) * hd**-0.5
    kpos = jnp.arange(S, dtype=jnp.int32)
    valid = kpos[None, :] <= pos_b[:, None]  # [B, S]
    win = jnp.asarray(window, jnp.int32)
    valid &= jnp.where(win > 0, pos_b[:, None] - kpos[None, :] < win, True)
    s = jnp.where(valid[:, :, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=1)
    o = jnp.einsum("bskg,bske->bkge", w.astype(vc.dtype), vc)
    o = o.reshape(B, 1, H, hd)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"]), new_cache


def gqa_decode_ws(x, p, cfg, cache: KVCache, pos, *, schedule="ws", bk=64,
                  n_programs=8):
    """One-token decode with the attention core routed through the
    device-resident work-stealing scheduler (repro.pallas_ws).

    Same projections/rope/cache splice as :func:`gqa_decode`; the masked
    dense contraction is replaced by ragged decode tiles over the *live*
    per-slot lengths ``pos_b + 1`` — short slots stop at their length
    instead of sweeping the padded cache, and thieves drain the long slot's
    queue.  Full attention only (window == 0).  Traced positions (the
    jitted serving path) route through the fixed-shape traced Put inside
    ``ragged_decode_attention``; concrete positions keep the host-side Put
    with its scheduling telemetry.
    """
    from repro.pallas_ws.ragged import ragged_decode_attention

    B = x.shape[0]
    hd = cfg.hd
    H = p["wq"].shape[1]
    pos_b = broadcast_pos(pos, B)
    q, new_cache = _decode_qkv(x, p, cfg, cache, pos_b)

    if isinstance(pos_b, jax.core.Tracer):
        lengths = pos_b.astype(jnp.int32) + 1
    else:
        lengths = np.asarray(jax.device_get(pos_b)).astype(np.int64) + 1
    o = ragged_decode_attention(
        q.reshape(B, H, hd),
        new_cache.k.transpose(0, 2, 1, 3),  # [B, S, Hkv, hd] -> [B, Hkv, S, hd]
        new_cache.v.transpose(0, 2, 1, 3),
        lengths,
        schedule=schedule,
        n_programs=n_programs,
        bk=bk,
    )
    o = o.reshape(B, 1, H, hd).astype(x.dtype)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# MLA (deepseek-v2)


def _mla_q(x, p, cfg, positions):
    H, hd, rhd = cfg.n_heads, cfg.hd, cfg.rope_head_dim
    if cfg.q_lora_rank:
        cq = jnp.einsum("bsd,dr->bsr", x, p["wdq"])
        q = jnp.einsum("bsr,rhe->bshe", cq, p["wuq"])
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_train(x, p, cfg, positions, window: int, chunk: int = 1024):
    B, S, _ = x.shape
    H, hd, rhd, vhd = cfg.n_heads, cfg.hd, cfg.rope_head_dim, cfg.v_hd
    q_nope, q_rope = _mla_q(x, p, cfg, positions)
    ckv = jnp.einsum("bsd,dr->bsr", x, p["wdkv"])
    k_rope = apply_rope(
        jnp.einsum("bsd,de->bse", x, p["wkr"])[:, :, None, :], positions, cfg.rope_theta
    )  # [B, S, 1, rhd] shared across heads
    k_nope = jnp.einsum("bsr,rhe->bshe", ckv, p["wuk"])
    v = jnp.einsum("bsr,rhe->bshe", ckv, p["wuv"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, rhd))], axis=-1)
    o = flash_ref(q, k, v, causal=True, window=window, chunk=chunk)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"], preferred_element_type=_pet(cfg)).astype(x.dtype)


class MLACache(NamedTuple):
    ckv: jnp.ndarray  # [B, S, kv_lora]
    kr: jnp.ndarray  # [B, S, rhd]


def mla_decode(x, p, cfg, cache: MLACache, pos):
    """Absorbed MLA decode: scores/outputs computed in the latent space."""
    B = x.shape[0]
    H, hd, rhd, vhd = cfg.n_heads, cfg.hd, cfg.rope_head_dim, cfg.v_hd
    S = cache.ckv.shape[1]
    pos_b = broadcast_pos(pos, B)
    q_nope, q_rope = _mla_q(x, p, cfg, pos_b[:, None])  # [B, 1, H, hd/rhd]
    ckv_new = jnp.einsum("bsd,dr->bsr", x, p["wdkv"])
    kr_new = apply_rope(
        jnp.einsum("bsd,de->bse", x, p["wkr"])[:, :, None, :],
        pos_b[:, None], cfg.rope_theta,
    )[:, :, 0, :]
    ckv = _update_at(cache.ckv, ckv_new, pos_b)
    kr = _update_at(cache.kr, kr_new, pos_b)

    # absorb W_uk into q: [B, H, kv_lora]
    q_abs = jnp.einsum("bhe,rhe->bhr", q_nope[:, 0], p["wuk"])
    s = jnp.einsum("bhr,bsr->bsh", q_abs, ckv)
    s = s + jnp.einsum("bhe,bse->bsh", q_rope[:, 0], kr)
    s = s.astype(jnp.float32) * (hd + rhd) ** -0.5
    kpos = jnp.arange(S, dtype=jnp.int32)
    s = jnp.where((kpos[None, :] <= pos_b[:, None])[:, :, None], s, -1e30)
    w = jax.nn.softmax(s, axis=1)
    o_lat = jnp.einsum("bsh,bsr->bhr", w.astype(ckv.dtype), ckv)
    o = jnp.einsum("bhr,rhe->bhe", o_lat, p["wuv"])[:, None]  # [B, 1, H, vhd]
    return jnp.einsum("bshe,hed->bsd", o, p["wo"]), MLACache(ckv, kr)

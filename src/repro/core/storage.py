"""Task-array storage schemes (paper §6: implementing infinite-length arrays).

All the paper's queues address ``Tasks`` with *absolute, monotonically
increasing* 1-based indices and every slot is written at most a couple of
times by the owner only (a task value, or ⊥) — no wraparound.  That write-once
discipline is what makes the three schemes below interchangeable:

* ``InfiniteStore``   — the idealized infinite array used in §3–§5 analysis
                        (dict-backed; missing entries read as UNINIT so that
                        tests catch reads of never-initialized memory).
* ``GrowableStore``   — §6 approach (1): a finite array that the owner copies
                        into a double-size array when full.  Put stays
                        wait-free but with unbounded step complexity.  Thieves
                        may keep reading a *stale* array object; that is safe
                        because slots are write-once and copied verbatim.
* ``LinkedStore``     — §6 approach (2): a linked list of fixed-size node
                        arrays; the owner links a fresh node when the current
                        one fills.  Put stays wait-free with O(1) steps.  An
                        absolute index maps to (node, offset); we follow the
                        paper in making that mapping O(1).

Only the owner calls :meth:`write`; owner and thieves call :meth:`read`.
"""

from __future__ import annotations

from typing import Any

from .backend import ThreadBackend, UNINIT


class InfiniteStore:
    def __init__(self, backend, default: Any = UNINIT):
        self.cells = backend.map_cells(default)

    def read(self, i: int, pid: int = 0) -> Any:
        return self.cells.read(i, pid)

    def write(self, i: int, v: Any, pid: int = 0) -> None:
        self.cells.write(i, v, pid)


class GrowableStore:
    """Copy-double finite array (§6 approach 1). 1-based absolute indices."""

    def __init__(self, backend, initial_len: int = 256, default: Any = UNINIT):
        self.backend = backend
        self.default = default
        # The array *reference* is itself a shared register: the owner swings
        # it after copying; thieves snapshot it with a single read.
        self.ref = backend.cell(backend.array(initial_len, default))

    def read(self, i: int, pid: int = 0) -> Any:
        arr = self.ref.read(pid)
        if i - 1 >= arr.size:
            # Thief raced ahead of an expansion it has not observed; the
            # freshest array also has nothing there yet -> reads as default.
            return self.default
        return arr.read(i - 1, pid)

    def write(self, i: int, v: Any, pid: int = 0) -> None:
        arr = self.ref.read(pid)
        if i - 1 >= arr.size:
            new_len = arr.size
            while i - 1 >= new_len:
                new_len *= 2
            new = self.backend.array(new_len, self.default)
            for j in range(arr.size):  # owner-only copy
                new.write(j, arr.read(j, pid), pid)
            self.ref.write(new, pid)
            arr = new
        arr.write(i - 1, v, pid)


class LinkedStore:
    """Linked list of fixed-size node arrays (§6 approach 2).

    ``node_table`` plays the role of the chain of next-pointers: entry k holds
    the k-th node's array, written exactly once by the owner when it links the
    node.  Index i (1-based) lives at node (i-1)//node_len, offset (i-1)%node_len
    — comparing / incrementing indices is O(1) as required by the paper.
    """

    def __init__(self, backend, node_len: int = 256, default: Any = UNINIT):
        self.backend = backend
        self.node_len = node_len
        self.default = default
        self.node_table = backend.map_cells(default=None)
        self.node_table.write(0, backend.array(node_len, default))

    def read(self, i: int, pid: int = 0) -> Any:
        node = self.node_table.read((i - 1) // self.node_len, pid)
        if node is None:
            return self.default  # thief ahead of the owner's link step
        return node.read((i - 1) % self.node_len, pid)

    def write(self, i: int, v: Any, pid: int = 0) -> None:
        k = (i - 1) // self.node_len
        node = self.node_table.read(k, pid)
        if node is None:  # owner links a fresh node: O(1) steps
            node = self.backend.array(self.node_len, self.default)
            self.node_table.write(k, node, pid)
        node.write((i - 1) % self.node_len, v, pid)


def make_store(kind: str, backend=None, **kw):
    backend = backend if backend is not None else ThreadBackend()
    if kind == "infinite":
        return InfiniteStore(backend, **kw)
    if kind == "growable":
        return GrowableStore(backend, **kw)
    if kind == "linked":
        return LinkedStore(backend, **kw)
    raise ValueError(f"unknown store kind: {kind!r}")

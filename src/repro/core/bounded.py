"""Bounded-multiplicity variants (paper §5).

B-WS-MULT / B-WS-WMULT: an extra array ``A`` of booleans (init true) and a
single ``Swap`` instruction in Steal bound extraction of each task to at most
one Take *plus* one Steal.  Put and Take are unchanged (Put additionally
initializes A[tail] — the third write the paper blames for B-WS-WMULT's Put
slowdown); Steal claims A[head] with Swap(false) and only a successful claim
publishes head+1 and returns the task.  Steal becomes nonblocking rather than
wait-free.

On a failed claim the paper says the thief "increments head and goes back to
the read of Head".  For B-WS-WMULT the increment survives the retry through
the max(local, Head) refresh.  For B-WS-MULT a MaxRead would discard the local
increment, so we additionally *help* by MaxWriting head+1 before retrying —
without the help a thief could spin on a slot claimed by a crashed process,
which would break even nonblocking progress; the help is the standard fix and
does not change the set-linearization argument (the claim point is the Swap).

ExactWS (§5 "Removing multiplicity"): the same Swap-claim applied to Take as
well yields an *exact* FIFO work-stealing algorithm (every task extracted at
most once overall) at the price of RMW in both extraction operations.
"""

from __future__ import annotations

from typing import Any, Dict

from .backend import BOTTOM, EMPTY, ThreadBackend
from .max_register import AtomicMaxRegister, TreeMaxRegister
from .storage import make_store


class BWSMult:
    """B-WS-MULT: WS-MULT + Swap-claimed Steal."""

    OWNER = 0

    def __init__(self, backend=None, max_register: str = "tree",
                 capacity: int = 1 << 20, storage: str = "infinite", **store_kw: Any):
        backend = backend if backend is not None else ThreadBackend()
        self.backend = backend
        if max_register == "tree":
            self.head_reg = TreeMaxRegister(capacity + 2, backend)
            self.head_reg.max_write(1, self.OWNER)
        else:
            self.head_reg = AtomicMaxRegister(backend, init=1)
        self.tasks = make_store(storage, backend, **store_kw)
        self.tasks.write(1, BOTTOM, self.OWNER)
        self.tasks.write(2, BOTTOM, self.OWNER)
        self.claims = backend.rmw_map_cells(default=True)  # array A, init true
        self.tail = 0

    def put(self, x: Any) -> bool:
        pid = self.OWNER
        self.tail += 1
        # §8.3's "third write" (re-arming A[tail]) must precede the task
        # write: a thief only swaps A[i] after reading a non-⊥ task from
        # Tasks[i], so ordering the re-arm before the task publish makes the
        # reset invisible to any claimer of this slot.  (The formal §5 spec
        # has A pre-initialized and Put unchanged; we keep the write for
        # benchmark fidelity with the paper's measured 3-write Put.)
        self.claims.write(self.tail, True, pid)
        self.tasks.write(self.tail, x, pid)
        self.tasks.write(self.tail + 2, BOTTOM, pid)
        return True

    def take(self) -> Any:
        pid = self.OWNER
        head = self.head_reg.max_read(pid)
        if head <= self.tail:
            x = self.tasks.read(head, pid)
            self.head_reg.max_write(head + 1, pid)
            return x
        return EMPTY

    def steal(self, pid: int) -> Any:
        while True:
            head = self.head_reg.max_read(pid)  # line 10
            x = self.tasks.read(head, pid)  # line 11
            if x is BOTTOM:
                return EMPTY
            if self.claims.swap(head, False, pid):  # claim via single Swap
                self.head_reg.max_write(head + 1, pid)  # line 13
                return x  # line 14
            # lost the claim: help advance Head, then start over (see module doc)
            self.head_reg.max_write(head + 1, pid)


class BWSWMult:
    """B-WS-WMULT: WS-WMULT + Swap-claimed Steal (the paper's benchmarked variant)."""

    OWNER = 0

    def __init__(self, backend=None, storage: str = "infinite", **store_kw: Any):
        backend = backend if backend is not None else ThreadBackend()
        self.backend = backend
        self.Head = backend.cell(1)
        self.tasks = make_store(storage, backend, **store_kw)
        self.tasks.write(1, BOTTOM, self.OWNER)
        self.tasks.write(2, BOTTOM, self.OWNER)
        self.claims = backend.rmw_map_cells(default=True)
        self.tail = 0
        self._head: Dict[int, int] = {}

    def _local_head(self, pid: int) -> int:
        return self._head.get(pid, 1)

    def put(self, x: Any) -> bool:
        pid = self.OWNER
        self.tail += 1
        self.claims.write(self.tail, True, pid)  # re-arm BEFORE publish (see BWSMult.put)
        self.tasks.write(self.tail, x, pid)
        self.tasks.write(self.tail + 2, BOTTOM, pid)
        return True

    def take(self) -> Any:
        pid = self.OWNER
        head = max(self._local_head(pid), self.Head.read(pid))
        if head <= self.tail:
            x = self.tasks.read(head, pid)
            self.Head.write(head + 1, pid)
            self._head[pid] = head + 1
            return x
        self._head[pid] = head
        return EMPTY

    def steal(self, pid: int) -> Any:
        while True:
            head = max(self._local_head(pid), self.Head.read(pid))
            x = self.tasks.read(head, pid)
            if x is BOTTOM:
                self._head[pid] = head
                return EMPTY
            if self.claims.swap(head, False, pid):
                self.Head.write(head + 1, pid)
                self._head[pid] = head + 1
                return x
            # lost the claim: local increment survives the retry via max()
            self._head[pid] = head + 1


class ExactWS:
    """§5 'Removing multiplicity': Swap-claims in both Take and Steal.

    Exactly-once extraction (useful as the ground-truth oracle in tests and as
    the exact-WS baseline in the scheduler benchmarks).
    """

    OWNER = 0

    def __init__(self, backend=None, storage: str = "infinite", **store_kw: Any):
        backend = backend if backend is not None else ThreadBackend()
        self.backend = backend
        self.head_reg = AtomicMaxRegister(backend, init=1)
        self.tasks = make_store(storage, backend, **store_kw)
        self.tasks.write(1, BOTTOM, self.OWNER)
        self.tasks.write(2, BOTTOM, self.OWNER)
        self.claims = backend.rmw_map_cells(default=True)
        self.tail = 0

    def put(self, x: Any) -> bool:
        pid = self.OWNER
        self.tail += 1
        self.claims.write(self.tail, True, pid)  # re-arm BEFORE publish (see BWSMult.put)
        self.tasks.write(self.tail, x, pid)
        self.tasks.write(self.tail + 2, BOTTOM, pid)
        return True

    def take(self) -> Any:
        pid = self.OWNER
        while True:
            head = self.head_reg.max_read(pid)
            if head > self.tail:
                return EMPTY
            if self.claims.swap(head, False, pid):
                x = self.tasks.read(head, pid)
                self.head_reg.max_write(head + 1, pid)
                return x
            self.head_reg.max_write(head + 1, pid)

    def steal(self, pid: int) -> Any:
        while True:
            head = self.head_reg.max_read(pid)
            x = self.tasks.read(head, pid)
            if x is BOTTOM:
                return EMPTY
            if self.claims.swap(head, False, pid):
                self.head_reg.max_write(head + 1, pid)
                return x
            self.head_reg.max_write(head + 1, pid)

"""WS-MULT (paper Figure 3): work-stealing with multiplicity from a MaxRegister.

The queue's head is synchronized by a single MaxRegister ``Head``; the tail is
the owner's local persistent variable.  Every operation is wait-free; Put is
fully Read/Write and O(1); with the AACH tree MaxRegister (Theorem 3.3) the
whole object is fully Read/Write with O(log m) Take/Steal and no
Read-After-Write pattern in any operation.

Faithfulness notes:
* ``Tasks`` is 1-based, slots 1 and 2 pre-initialized to ⊥, and each Put(x)
  performs {Tasks[tail].Write(x), Tasks[tail+2].Write(⊥)} — the brace notation
  means the two writes may run in either order (fence-free); we expose
  ``put_order`` so the interleaving tests can exercise both orders.
* Take reads Tasks[head] and MaxWrites head+1 in either order (line 6 braces);
  likewise exposed for tests.
"""

from __future__ import annotations

from typing import Any

from .backend import BOTTOM, EMPTY, ThreadBackend
from .max_register import AtomicMaxRegister, TreeMaxRegister
from .storage import make_store


class WSMult:
    OWNER = 0

    def __init__(
        self,
        backend=None,
        max_register: str = "tree",
        capacity: int = 1 << 20,
        storage: str = "infinite",
        put_order: str = "task_first",
        **store_kw: Any,
    ):
        backend = backend if backend is not None else ThreadBackend()
        self.backend = backend
        if max_register == "tree":
            self.head_reg = TreeMaxRegister(capacity + 2, backend)
            self.head_reg.max_write(1, self.OWNER)  # Head initialized to 1
        elif max_register == "atomic":
            self.head_reg = AtomicMaxRegister(backend, init=1)
        else:
            raise ValueError(max_register)
        self.tasks = make_store(storage, backend, **store_kw)
        # first two objects initialized to ⊥
        self.tasks.write(1, BOTTOM, self.OWNER)
        self.tasks.write(2, BOTTOM, self.OWNER)
        self.tail = 0  # owner-local persistent variable
        self.put_order = put_order

    # -- owner ----------------------------------------------------------
    def put(self, x: Any) -> bool:
        pid = self.OWNER
        self.tail += 1  # line 1 (local)
        if self.put_order == "task_first":  # line 2: {W(tail,x), W(tail+2,⊥)}
            self.tasks.write(self.tail, x, pid)
            self.tasks.write(self.tail + 2, BOTTOM, pid)
        else:
            self.tasks.write(self.tail + 2, BOTTOM, pid)
            self.tasks.write(self.tail, x, pid)
        return True  # line 3

    def take(self) -> Any:
        pid = self.OWNER
        head = self.head_reg.max_read(pid)  # line 4
        if head <= self.tail:  # line 5
            x = self.tasks.read(head, pid)  # line 6 (either order)
            self.head_reg.max_write(head + 1, pid)
            return x  # line 7
        return EMPTY  # line 9

    # -- thieves ----------------------------------------------------------
    def steal(self, pid: int) -> Any:
        head = self.head_reg.max_read(pid)  # line 10
        x = self.tasks.read(head, pid)  # line 11
        if x is not BOTTOM:  # line 12
            self.head_reg.max_write(head + 1, pid)  # line 13
            return x  # line 14
        return EMPTY  # line 16

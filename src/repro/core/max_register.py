"""MaxRegister and RangeMaxRegister objects (paper §3.1, §4.1).

Three implementations:

* ``AtomicMaxRegister``  — a MaxRegister as a single atomic object (what
  Theorem 3.2 assumes).  Each MaxRead/MaxWrite is one atomic step; on the
  thread backend the GIL provides the atomicity, on the sim backend it is one
  scheduled step.  Constant step complexity but *not* derived from Read/Write.

* ``TreeMaxRegister``    — the fully Read/Write wait-free bounded MaxRegister
  of Aspnes–Attiya–Censor-Hillel [3] used by Theorem 3.3: a binary tree of
  atomic bits over capacity m.  MaxRead is a root-to-leaf descent (a sequence
  of reads); MaxWrite reads down the value's path and then sets the path's
  switch bits bottom-up (a sequence of reads followed by a sequence of
  writes).  Hence neither operation contains a Read-After-Write pattern and
  both run in O(log m) steps.

* ``RangeMaxRegister``   — Figure 6: one shared plain register R plus a
  per-process persistent local maximum r.  RMaxRead returns max(r, R.Read())
  — a value in the range [r, true max]; RMaxWrite publishes only fresh local
  maxima.  Fully Read/Write, fence-free, O(1), and sequentially-exact.
"""

from __future__ import annotations

from typing import Any, Dict

from .backend import ThreadBackend


class AtomicMaxRegister:
    def __init__(self, backend=None, init: int = 1):
        backend = backend if backend is not None else ThreadBackend()
        self._cell = backend.rmw_cell(init)

    def max_read(self, pid: int = 0) -> int:
        return self._cell.read(pid)

    def max_write(self, v: int, pid: int = 0) -> None:
        # One atomic step (cf. model): equivalent to a hardware atomic-max.
        self._cell.write_max(v, pid)


class TreeMaxRegister:
    """AACH Read/Write MaxRegister over values 0..capacity-1.

    The recursive structure MaxReg(m) = (switch bit, MaxReg(m/2) left for the
    low half, MaxReg(m/2) right for the high half) is flattened into a heap
    array of switch bits.  Leaves carry no state.

    MaxRead: descend from the root taking the right child whenever the switch
    is set; the leaf index reached is the maximum written so far.
    MaxWrite(v): walk v's root-to-leaf path; abandon if a switch already says
    the register holds something >= the high half v sits under; otherwise set
    the switch bits of v's path that should be 1, *bottom-up* (this order is
    what makes the algorithm linearizable, per the paper's Theorem 3.3
    discussion).  Reads all precede writes: no Read-After-Write pattern.
    """

    def __init__(self, capacity: int, backend=None):
        backend = backend if backend is not None else ThreadBackend()
        self.capacity = 1
        self.height = 0
        while self.capacity < capacity:
            self.capacity *= 2
            self.height += 1
        # Heap-indexed internal nodes: 1..capacity-1 (node i's children 2i, 2i+1).
        self.bits = backend.array(max(2 * self.capacity, 2), 0)

    def max_read(self, pid: int = 0) -> int:
        node, lo, span = 1, 0, self.capacity
        while span > 1:
            half = span // 2
            if self.bits.read(node, pid):
                node, lo, span = 2 * node + 1, lo + half, half
            else:
                node, lo, span = 2 * node, lo, half
        return lo

    def max_write(self, v: int, pid: int = 0) -> None:
        if not 0 <= v < self.capacity:
            raise ValueError(f"value {v} out of MaxRegister capacity {self.capacity}")
        # Phase 1 (reads): walk v's path; if at any node v lies in the LOW
        # half but the switch is already 1, the register already exceeds v.
        node, lo, span = 1, 0, self.capacity
        path_high = []  # nodes where v goes high -> their switch must be 1
        while span > 1:
            half = span // 2
            if v >= lo + half:
                path_high.append(node)
                node, lo, span = 2 * node + 1, lo + half, half
            else:
                if self.bits.read(node, pid):
                    return  # current max already >= lo + half > v
                node, lo, span = 2 * node, lo, half
        # Phase 2 (writes): set the high-path switches bottom-up.
        for node in reversed(path_high):
            self.bits.write(node, 1, pid)


class RangeMaxRegister:
    """Figure 6 algorithm.  ``r`` is process-local persistent state."""

    def __init__(self, backend=None, init: int = 1):
        backend = backend if backend is not None else ThreadBackend()
        self.R = backend.cell(init)
        self._r: Dict[int, int] = {}
        self._init = init

    def _local(self, pid: int) -> int:
        return self._r.get(pid, self._init)

    def rmax_write(self, x: int, pid: int = 0) -> bool:
        r = max(self._local(pid), self.R.read(pid))  # line 1
        if x > r:  # line 2
            self._r[pid] = x  # line 3 (local)
            self.R.write(x, pid)  # line 3 (shared) — any order
        else:
            self._r[pid] = r
        return True

    def rmax_read(self, pid: int = 0) -> int:
        r = max(self._local(pid), self.R.read(pid))  # line 6
        self._r[pid] = r
        return r

"""Baseline work-stealing algorithms the paper compares against (§8).

* ChaseLev        — dynamic circular work-stealing deque [11].  Owner LIFO,
                    thieves FIFO; CAS on ``top`` in Steal and in Take's
                    last-element race; a store-load fence in Take (no-op here,
                    see backend docstring).
* TheCilk         — THE protocol of Cilk-5 [14]: Take is Read/Write on the
                    fast path with a lock on the near-empty slow path; Steal
                    is serialized by the lock.
* IdempotentFIFO  — Michael-Vechev-Saraswat idempotent FIFO queue [24]
                    (paper Figure 8), including ``expand``.
* IdempotentLIFO  — idempotent LIFO stack [24]: single (tail, tag) anchor,
                    CAS'd by thieves.
* IdempotentDeque — idempotent double-ended extraction [24]: (head, size, tag)
                    anchor; owner puts/takes at one end, thieves steal at the
                    other.

All use growable arrays; the idempotent ones follow their papers' expand
(copy into a double-size array, republish the array reference).  These back
the paper-table reproductions in benchmarks/ and the §7 separation witness in
tests (a task extracted an unbounded number of times by *non-concurrent*
steals — impossible for WS-MULT/WS-WMULT).
"""

from __future__ import annotations

from typing import Any

from .backend import EMPTY, ThreadBackend


class _Buf:
    """Plain object array with a size attribute (snapshot-published)."""

    __slots__ = ("a", "size")

    def __init__(self, size: int):
        self.size = size
        self.a = [None] * size


class ChaseLev:
    OWNER = 0

    def __init__(self, backend=None, initial_len: int = 256):
        backend = backend if backend is not None else ThreadBackend()
        self.backend = backend
        self.top = backend.rmw_cell(0)  # steal end
        self.bottom = backend.cell(0)  # owner end
        self.buf_ref = backend.cell(_Buf(initial_len))

    def _grow(self, b: int, t: int, pid: int) -> None:
        old = self.buf_ref.read(pid)
        new = _Buf(old.size * 2)
        for i in range(t, b):
            new.a[i % new.size] = old.a[i % old.size]
        self.buf_ref.write(new, pid)

    def put(self, x: Any) -> bool:
        pid = self.OWNER
        b = self.bottom.read(pid)
        t = self.top.read(pid)
        buf = self.buf_ref.read(pid)
        if b - t >= buf.size - 1:
            self._grow(b, t, pid)
            buf = self.buf_ref.read(pid)
        buf.a[b % buf.size] = x
        self.backend.fence()  # store-store
        self.bottom.write(b + 1, pid)
        return True

    def take(self) -> Any:
        pid = self.OWNER
        b = self.bottom.read(pid) - 1
        buf = self.buf_ref.read(pid)
        self.bottom.write(b, pid)
        self.backend.fence()  # store-load fence — the expensive one
        t = self.top.read(pid)
        if b < t:  # empty
            self.bottom.write(t, pid)
            return EMPTY
        x = buf.a[b % buf.size]
        if b > t:
            return x
        # last element: race with thieves via CAS
        if not self.top.cas(t, t + 1, pid):
            x = EMPTY
        self.bottom.write(t + 1, pid)
        return x

    def steal(self, pid: int) -> Any:
        while True:
            t = self.top.read(pid)
            self.backend.fence()  # load-load
            b = self.bottom.read(pid)
            if t >= b:
                return EMPTY
            buf = self.buf_ref.read(pid)
            x = buf.a[t % buf.size]
            if self.top.cas(t, t + 1, pid):
                return x
            # lost the race: retry (nonblocking)


class TheCilk:
    """THE protocol (T = tail/owner end, H = head/steal end, lock E)."""

    OWNER = 0

    def __init__(self, backend=None, initial_len: int = 256):
        backend = backend if backend is not None else ThreadBackend()
        self.backend = backend
        self.T = backend.cell(0)
        self.H = backend.cell(0)
        self.lock = backend.lock()
        self.buf_ref = backend.cell(_Buf(initial_len))

    def _grow(self, h: int, t: int, pid: int) -> None:
        old = self.buf_ref.read(pid)
        new = _Buf(old.size * 2)
        for i in range(h, t):
            new.a[i % new.size] = old.a[i % old.size]
        self.buf_ref.write(new, pid)

    def put(self, x: Any) -> bool:
        pid = self.OWNER
        t = self.T.read(pid)
        h = self.H.read(pid)
        buf = self.buf_ref.read(pid)
        if t - h >= buf.size - 1:
            with self.lock:  # growth serialized against thieves
                self._grow(self.H.read(pid), t, pid)
            buf = self.buf_ref.read(pid)
        buf.a[t % buf.size] = x
        self.backend.fence()
        self.T.write(t + 1, pid)
        return True

    def take(self) -> Any:
        pid = self.OWNER
        t = self.T.read(pid) - 1
        buf = self.buf_ref.read(pid)
        self.T.write(t, pid)
        self.backend.fence()  # store-load
        h = self.H.read(pid)
        if h <= t:
            return buf.a[t % buf.size]
        # potential conflict: restore and retry under the lock
        self.T.write(t + 1, pid)
        with self.lock:
            t = self.T.read(pid) - 1
            self.T.write(t, pid)
            h = self.H.read(pid)
            if h <= t:
                return buf.a[t % buf.size]
            self.T.write(h, pid)  # deque empty: reset
            return EMPTY

    def steal(self, pid: int) -> Any:
        with self.lock:
            h = self.H.read(pid)
            self.backend.fence()
            t = self.T.read(pid)
            if h >= t:
                return EMPTY
            buf = self.buf_ref.read(pid)
            x = buf.a[h % buf.size]
            self.H.write(h + 1, pid)
            return x


class IdempotentFIFO:
    """Paper Figure 8 (Michael et al. [24]), faithful including expand."""

    OWNER = 0

    def __init__(self, backend=None, initial_len: int = 256):
        backend = backend if backend is not None else ThreadBackend()
        self.backend = backend
        self.head = backend.rmw_cell(0)
        self.tail = backend.cell(0)
        self.tasks_ref = backend.cell(_Buf(initial_len))

    def _expand(self, pid: int) -> None:
        old = self.tasks_ref.read(pid)
        h = self.head.read(pid)
        t = self.tail.read(pid)
        new = _Buf(old.size * 2)
        for i in range(h, t):
            new.a[i % new.size] = old.a[i % old.size]
        self.backend.fence()  # order copies before publishing the array
        self.tasks_ref.write(new, pid)
        self.backend.fence()  # order publish before the put's tail write

    def put(self, x: Any) -> bool:
        pid = self.OWNER
        while True:
            h = self.head.read(pid)  # line 1
            t = self.tail.read(pid)  # line 2
            tasks = self.tasks_ref.read(pid)
            if t == h + tasks.size:  # line 3
                self._expand(pid)
                continue
            tasks.a[t % tasks.size] = x  # line 4
            self.backend.fence()  # order write at 4 before write at 5
            self.tail.write(t + 1, pid)  # line 5
            return True

    def take(self) -> Any:
        pid = self.OWNER
        h = self.head.read(pid)  # line 1
        t = self.tail.read(pid)  # line 2
        if h == t:  # line 3
            return EMPTY
        tasks = self.tasks_ref.read(pid)
        x = tasks.a[h % tasks.size]  # line 4
        self.head.write(h + 1, pid)  # line 5
        return x

    def steal(self, pid: int) -> Any:
        while True:
            h = self.head.read(pid)  # line 1
            self.backend.fence()  # order read 1 before read 2
            t = self.tail.read(pid)  # line 2
            if h == t:  # line 3
                return EMPTY
            self.backend.fence()  # order read 1 before read 4
            a = self.tasks_ref.read(pid)  # line 4
            x = a.a[h % a.size]  # line 5
            self.backend.fence()  # order read 5 before CAS 6
            if self.head.cas(h, h + 1, pid):  # line 6
                return x


class IdempotentLIFO:
    """Idempotent LIFO [24]: single-word (tail, tag) anchor."""

    OWNER = 0

    def __init__(self, backend=None, initial_len: int = 256):
        backend = backend if backend is not None else ThreadBackend()
        self.backend = backend
        self.anchor = backend.rmw_cell((0, 0))  # (tail, tag)
        self.tasks_ref = backend.cell(_Buf(initial_len))

    def _expand(self, t: int, pid: int) -> None:
        old = self.tasks_ref.read(pid)
        new = _Buf(old.size * 2)
        for i in range(t):
            new.a[i] = old.a[i]
        self.backend.fence()
        self.tasks_ref.write(new, pid)

    def put(self, x: Any) -> bool:
        pid = self.OWNER
        t, g = self.anchor.read(pid)
        tasks = self.tasks_ref.read(pid)
        if t == tasks.size:
            self._expand(t, pid)
            tasks = self.tasks_ref.read(pid)
        tasks.a[t] = x
        self.backend.fence()  # order task write before anchor publish
        self.anchor.write((t + 1, g + 1), pid)
        return True

    def take(self) -> Any:
        pid = self.OWNER
        t, g = self.anchor.read(pid)
        if t == 0:
            return EMPTY
        tasks = self.tasks_ref.read(pid)
        x = tasks.a[t - 1]
        self.anchor.write((t - 1, g), pid)
        return x

    def steal(self, pid: int) -> Any:
        while True:
            t, g = self.anchor.read(pid)
            if t == 0:
                return EMPTY
            self.backend.fence()
            tasks = self.tasks_ref.read(pid)
            x = tasks.a[t - 1]
            if self.anchor.cas((t, g), (t - 1, g), pid):
                return x


class IdempotentDeque:
    """Idempotent double-ended extraction [24]: (head, size, tag) anchor.

    Owner puts at the tail and takes from the tail; thieves steal from the
    head — the 'deque' insert/extract order of [24].
    """

    OWNER = 0

    def __init__(self, backend=None, initial_len: int = 256):
        backend = backend if backend is not None else ThreadBackend()
        self.backend = backend
        self.anchor = backend.rmw_cell((0, 0, 0))  # (head, size, tag)
        self.tasks_ref = backend.cell(_Buf(initial_len))

    def _expand(self, h: int, sz: int, pid: int) -> None:
        old = self.tasks_ref.read(pid)
        new = _Buf(old.size * 2)
        for i in range(h, h + sz):
            new.a[i % new.size] = old.a[i % old.size]
        self.backend.fence()
        self.tasks_ref.write(new, pid)

    def put(self, x: Any) -> bool:
        pid = self.OWNER
        h, sz, g = self.anchor.read(pid)
        tasks = self.tasks_ref.read(pid)
        if sz == tasks.size:
            self._expand(h, sz, pid)
            tasks = self.tasks_ref.read(pid)
        tasks.a[(h + sz) % tasks.size] = x
        self.backend.fence()
        self.anchor.write((h, sz + 1, g + 1), pid)
        return True

    def take(self) -> Any:
        pid = self.OWNER
        h, sz, g = self.anchor.read(pid)
        if sz == 0:
            return EMPTY
        tasks = self.tasks_ref.read(pid)
        x = tasks.a[(h + sz - 1) % tasks.size]
        self.anchor.write((h, sz - 1, g), pid)
        return x

    def steal(self, pid: int) -> Any:
        while True:
            h, sz, g = self.anchor.read(pid)
            if sz == 0:
                return EMPTY
            self.backend.fence()
            tasks = self.tasks_ref.read(pid)
            x = tasks.a[h % tasks.size]
            if self.anchor.cas((h, sz, g), ((h + 1) % tasks.size, sz - 1, g), pid):
                return x

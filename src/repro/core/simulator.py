"""Deterministic-interleaving harness + property checkers for the core queues.

``run_program`` executes per-process operation sequences under a supplied
schedule (sequence of pids deciding which process performs the next
shared-memory step) and returns timestamped :class:`OpRecord`s.  The property
checkers encode the paper's correctness conditions:

* weak multiplicity (Def. 4.1 consequence): each process extracts a task at
  most once; every extracted-past task was extracted at least once (no loss).
* multiplicity (Def. 3.1 / Remark 3.2): additionally, all operations that
  return the same task are *pairwise concurrent*.
* sequentially-exact (Remark 3.1 / §4): a sequential execution behaves like
  exact FIFO work-stealing.

These are necessary conditions implied by (set-)linearizability and are what
the hypothesis property tests check over randomized adversarial schedules.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Sequence, Tuple

from .backend import EMPTY, SimBackend, SimController, set_sim_pid


@dataclass
class OpRecord:
    pid: int
    kind: str  # 'put' | 'take' | 'steal'
    arg: Any
    result: Any
    inv: int  # controller step count at invocation
    res: int  # controller step count at response

    def overlaps(self, other: "OpRecord") -> bool:
        """op || op' in the sense of §2 (neither response precedes the other's
        invocation)."""
        return not (self.res <= other.inv or other.res <= self.inv)


Program = Dict[int, List[Tuple[str, Any]]]  # pid -> [(kind, arg), ...]


def run_program(
    make_queue: Callable[[Any], Any],
    program: Program,
    schedule: Sequence[int],
    timeout: float = 60.0,
) -> List[OpRecord]:
    """Run ``program`` on a fresh queue under ``schedule``; return op records."""
    ctrl = SimController(schedule)
    backend = SimBackend(ctrl)
    q = make_queue(backend)
    records: List[OpRecord] = []

    def runner(pid: int, ops: List[Tuple[str, Any]]) -> None:
        set_sim_pid(pid)
        for kind, arg in ops:
            inv = ctrl.now()
            if kind == "put":
                r = q.put(arg)
            elif kind == "take":
                r = q.take()
            elif kind == "steal":
                r = q.steal(pid)
            else:  # pragma: no cover - defensive
                raise ValueError(kind)
            records.append(OpRecord(pid, kind, arg, r, inv, ctrl.now()))

    ctrl.run(
        {pid: (lambda pid=pid, ops=ops: runner(pid, ops)) for pid, ops in program.items()},
        timeout=timeout,
    )
    return records


def extractions(records: List[OpRecord]) -> List[OpRecord]:
    return [r for r in records if r.kind in ("take", "steal") and r.result is not EMPTY]


def check_no_process_duplicates(records: List[OpRecord]) -> None:
    """Each process extracts a given task at most once (multiplicity family)."""
    seen = set()
    for r in extractions(records):
        key = (r.pid, r.result)
        assert key not in seen, (
            f"process {r.pid} extracted task {r.result!r} more than once "
            f"(violates weak multiplicity)"
        )
        seen.add(key)


def check_no_lost_tasks_fifo(records: List[OpRecord]) -> None:
    """FIFO at-least-once: nothing older than the newest extracted task was skipped.

    Put values must be distinct for this check (tests put 1..k).
    """
    put_order = [r.arg for r in records if r.kind == "put"]
    got = {r.result for r in extractions(records)}
    if not got:
        return
    newest = max(put_order.index(v) for v in got)
    for v in put_order[: newest + 1]:
        assert v in got, f"task {v!r} was skipped (lost) — violates at-least-once"


def check_pairwise_concurrent_duplicates(records: List[OpRecord]) -> None:
    """Multiplicity (Def. 3.1): same-task extractors are pairwise concurrent."""
    by_task: Dict[Any, List[OpRecord]] = {}
    for r in extractions(records):
        by_task.setdefault(r.result, []).append(r)
    for task, ops in by_task.items():
        for i in range(len(ops)):
            for j in range(i + 1, len(ops)):
                assert ops[i].overlaps(ops[j]), (
                    f"task {task!r} extracted by non-concurrent operations "
                    f"{ops[i]} and {ops[j]} (violates multiplicity)"
                )


def check_owner_fifo(records: List[OpRecord]) -> None:
    """The owner's successful Takes return tasks in strictly increasing put order."""
    put_order = [r.arg for r in records if r.kind == "put"]
    idx = {v: i for i, v in enumerate(put_order)}
    last = -1
    for r in records:
        if r.pid == 0 and r.kind == "take" and r.result is not EMPTY:
            assert idx[r.result] > last, (
                f"owner takes out of FIFO order: {r.result!r} after index {last}"
            )
            last = idx[r.result]


def run_sequential(queue, program_flat: List[Tuple[int, str, Any]]):
    """Execute ops one-at-a-time (a sequential execution in the paper's sense).

    ``queue`` should be built on ThreadBackend; with a single caller thread the
    execution is trivially sequential.  Returns [(pid, kind, arg, result)].
    """
    out = []
    for pid, kind, arg in program_flat:
        if kind == "put":
            r = queue.put(arg)
        elif kind == "take":
            r = queue.take()
        else:
            r = queue.steal(pid)
        out.append((pid, kind, arg, r))
    return out


class ExactFIFOOracle:
    """Reference exact FIFO work-stealing semantics (Def. 3.1 restricted to
    singleton concurrency classes) for sequentially-exact checks."""

    def __init__(self):
        self.q: List[Any] = []

    def put(self, x):
        self.q.append(x)
        return True

    def take(self):
        return self.q.pop(0) if self.q else EMPTY

    def steal(self, pid):
        return self.q.pop(0) if self.q else EMPTY


class ExactLIFOOracle:
    """Owner-LIFO oracle for the deque-order baselines in sequential
    executions.  ``steal_end='head'`` for Chase-Lev / THE Cilk / idempotent
    deque (thieves at the opposite end); ``steal_end='tail'`` for the
    idempotent LIFO stack (thieves pop the same end as the owner)."""

    def __init__(self, steal_end: str = "head"):
        self.q: List[Any] = []
        self.steal_end = steal_end

    def put(self, x):
        self.q.append(x)
        return True

    def take(self):
        return self.q.pop() if self.q else EMPTY

    def steal(self, pid):
        if not self.q:
            return EMPTY
        return self.q.pop(0) if self.steal_end == "head" else self.q.pop()

"""repro.core — the paper's contribution: fence-free work-stealing with multiplicity.

Faithful shared-memory algorithms (WS-MULT, WS-WMULT, bounded variants, the
MaxRegister/RangeMaxRegister objects they reduce to, and the THE Cilk /
Chase-Lev / Idempotent baselines), runnable on real threads or under the
deterministic interleaving simulator.  The JAX/TPU adaptation of the same
synchronization structure lives in :mod:`repro.sched`.
"""

from .backend import (
    BOTTOM,
    EMPTY,
    UNINIT,
    SimBackend,
    SimController,
    ThreadBackend,
    set_sim_pid,
)
from .baselines import ChaseLev, IdempotentDeque, IdempotentFIFO, IdempotentLIFO, TheCilk
from .bounded import BWSMult, BWSWMult, ExactWS
from .max_register import AtomicMaxRegister, RangeMaxRegister, TreeMaxRegister
from .storage import GrowableStore, InfiniteStore, LinkedStore, make_store
from .ws_mult import WSMult
from .ws_wmult import WSWMult


def _pallas_ws_host(backend=None, **kw):
    """Lazy factory for the device-layout shim (avoids importing jax-adjacent
    modules when only the pure shared-memory algorithms are needed)."""
    from repro.pallas_ws.host import PallasWSHost

    return PallasWSHost(backend=backend, **kw)


def _moe_ws_host(backend=None, **kw):
    """Lazy factory for the MoE expert-dispatch queue (same WS-WMULT slot
    arithmetic as pallas-ws, expert-tile payloads — see repro.moe_ws)."""
    from repro.moe_ws.dispatch import MoEDispatchHost

    return MoEDispatchHost(backend=backend, **kw)


# Registry used by tests / benchmarks.  Each factory takes (backend=None, **kw).
ALGORITHMS = {
    "ws-mult": WSMult,
    "ws-wmult": WSWMult,
    "pallas-ws": _pallas_ws_host,
    "moe-ws": _moe_ws_host,
    "b-ws-mult": BWSMult,
    "b-ws-wmult": BWSWMult,
    "exact-ws": ExactWS,
    "chase-lev": ChaseLev,
    "the-cilk": TheCilk,
    "idempotent-fifo": IdempotentFIFO,
    "idempotent-lifo": IdempotentLIFO,
    "idempotent-deque": IdempotentDeque,
}

# Algorithms whose relaxation guarantees each *process* extracts a task at
# most once (the paper's multiplicity family).  "pallas-ws" is the device
# queue layout's host shim and "moe-ws" the expert-dispatch queue on the
# same layout — same WS-WMULT protocol, so same guarantees.
MULTIPLICITY_FAMILY = (
    "ws-mult", "ws-wmult", "b-ws-mult", "b-ws-wmult", "pallas-ws", "moe-ws"
)
# Exactly-once algorithms (ground truth).
EXACT_FAMILY = ("exact-ws", "chase-lev", "the-cilk")
# At-least-once with unbounded duplicates (idempotent relaxation).
IDEMPOTENT_FAMILY = ("idempotent-fifo", "idempotent-lifo", "idempotent-deque")

__all__ = [
    "ALGORITHMS",
    "MULTIPLICITY_FAMILY",
    "EXACT_FAMILY",
    "IDEMPOTENT_FAMILY",
    "AtomicMaxRegister",
    "BOTTOM",
    "BWSMult",
    "BWSWMult",
    "ChaseLev",
    "EMPTY",
    "ExactWS",
    "GrowableStore",
    "IdempotentDeque",
    "IdempotentFIFO",
    "IdempotentLIFO",
    "InfiniteStore",
    "LinkedStore",
    "RangeMaxRegister",
    "SimBackend",
    "SimController",
    "TheCilk",
    "ThreadBackend",
    "TreeMaxRegister",
    "UNINIT",
    "WSMult",
    "WSWMult",
    "make_store",
    "set_sim_pid",
]

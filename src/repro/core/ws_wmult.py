"""WS-WMULT (paper Figure 7): fully Read/Write *fence-free* work-stealing with
weak multiplicity, with the RangeMaxRegister of Figure 6 inlined.

``Head`` degrades to a plain atomic Read/Write register; every process keeps a
persistent local lower bound ``head`` on the true head.  A Take/Steal first
refreshes its bound with max(local, Head.Read()) — the inlined RMaxRead — and
on success plainly writes head+1 — the inlined RMaxWrite with its read dropped
(the paper notes this stays sequentially-exact because the operation just
performed the RMaxRead).

Consequences (Theorem 4.5): fully Read/Write, fence-free, wait-free,
sequentially-exact, linearizable w.r.t. work-stealing with weak multiplicity,
O(1) steps in every operation.  A slow process may drag ``Head`` backwards,
which is exactly the weak-multiplicity relaxation: another process can then
re-extract a task, but each process's local bound is strictly increasing, so
*no process extracts the same task twice*.
"""

from __future__ import annotations

from typing import Any, Dict

from .backend import BOTTOM, EMPTY, ThreadBackend
from .storage import make_store


class WSWMult:
    OWNER = 0

    def __init__(
        self,
        backend=None,
        storage: str = "infinite",
        put_order: str = "task_first",
        **store_kw: Any,
    ):
        backend = backend if backend is not None else ThreadBackend()
        self.backend = backend
        self.Head = backend.cell(1)  # shared plain register, init 1
        self.tasks = make_store(storage, backend, **store_kw)
        self.tasks.write(1, BOTTOM, self.OWNER)
        self.tasks.write(2, BOTTOM, self.OWNER)
        self.tail = 0  # owner-local
        self._head: Dict[int, int] = {}  # per-process persistent local head
        self.put_order = put_order

    def _local_head(self, pid: int) -> int:
        return self._head.get(pid, 1)

    # -- owner ----------------------------------------------------------
    def put(self, x: Any) -> bool:
        pid = self.OWNER
        self.tail += 1  # line 1
        if self.put_order == "task_first":  # line 2 (either order)
            self.tasks.write(self.tail, x, pid)
            self.tasks.write(self.tail + 2, BOTTOM, pid)
        else:
            self.tasks.write(self.tail + 2, BOTTOM, pid)
            self.tasks.write(self.tail, x, pid)
        return True  # line 3

    def take(self) -> Any:
        pid = self.OWNER
        head = max(self._local_head(pid), self.Head.read(pid))  # line 4
        if head <= self.tail:  # line 5
            x = self.tasks.read(head, pid)  # line 6 (either order)
            self.Head.write(head + 1, pid)
            self._head[pid] = head + 1  # line 7
            return x  # line 8
        self._head[pid] = head
        return EMPTY  # line 10

    # -- thieves ----------------------------------------------------------
    def steal(self, pid: int) -> Any:
        head = max(self._local_head(pid), self.Head.read(pid))  # line 11
        x = self.tasks.read(head, pid)  # line 12
        if x is not BOTTOM:  # line 13
            self.Head.write(head + 1, pid)  # line 14
            self._head[pid] = head + 1  # line 15
            return x  # line 16
        self._head[pid] = head
        return EMPTY  # line 18

"""Shared-memory abstraction layer for the work-stealing algorithms.

The paper's model is an asynchronous shared-memory system where processes
communicate through atomic base objects (Read/Write registers, plus the
occasional RMW instruction in the baselines / bounded variants).  We code every
algorithm once against this tiny cell/array API and execute it on two
interchangeable backends:

* ``ThreadBackend`` -- plain attribute/list accesses.  Under CPython's GIL an
  aligned object-slot read/write is atomic, the analogue of an aligned word
  access in the paper's model.  RMW cells use a per-cell mutex, mirroring the
  hardware cost asymmetry the paper targets (CAS/Swap >> Read/Write).  Used by
  the real-thread stress tests and the paper-table benchmarks.

* ``SimBackend`` -- every shared-memory access is a *step* gated by a
  deterministic :class:`SimController`, enabling randomized/adversarial
  interleaving exploration and the set-linearizability property checks
  (tests/test_core_properties.py).  Local (per-process) variables are free,
  exactly as in the paper's step-complexity accounting.

``fence()`` is a no-op on both backends: the algorithms under test are
fence-free by construction, and baselines that *do* require ordering get it
for free from the GIL's sequential consistency.  We keep the call sites as
documentation of where a real implementation would need a barrier.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional, Sequence


class Empty:
    """Sentinel returned by Take/Steal on an empty queue."""

    _instance: Optional["Empty"] = None

    def __new__(cls) -> "Empty":
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return "EMPTY"


class Bottom:
    """The paper's ⊥ value marking a not-yet-filled task slot."""

    _instance: Optional["Bottom"] = None

    def __new__(cls) -> "Bottom":
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return "⊥"


class Uninit:
    """Distinguished value for memory the owner never initialized.

    The paper (end of §3.1) points out that reading a never-written slot would
    be a correctness bug; surfacing it as a distinct sentinel lets the tests
    assert the algorithms never observe one.
    """

    _instance: Optional["Uninit"] = None

    def __new__(cls) -> "Uninit":
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return "UNINIT"


EMPTY = Empty()
BOTTOM = Bottom()
UNINIT = Uninit()


# ---------------------------------------------------------------------------
# Thread backend: raw cells (GIL-atomic), RMW via per-cell mutex.
# ---------------------------------------------------------------------------


class Cell:
    """An atomic Read/Write register."""

    __slots__ = ("v",)

    def __init__(self, v: Any = None):
        self.v = v

    def read(self, pid: int = 0) -> Any:
        return self.v

    def write(self, v: Any, pid: int = 0) -> None:
        self.v = v


class RMWCell(Cell):
    """A register additionally supporting CAS / Swap / Fetch&Add."""

    __slots__ = ("_lock",)

    def __init__(self, v: Any = None):
        super().__init__(v)
        self._lock = threading.Lock()

    def cas(self, expect: Any, new: Any, pid: int = 0) -> bool:
        with self._lock:
            if self.v == expect:
                self.v = new
                return True
            return False

    def swap(self, new: Any, pid: int = 0) -> Any:
        with self._lock:
            old = self.v
            self.v = new
            return old

    def fetch_add(self, delta: int = 1, pid: int = 0) -> Any:
        with self._lock:
            old = self.v
            self.v = old + delta
            return old

    def write_max(self, v: Any, pid: int = 0) -> None:
        """Atomic max (a single RMW step) — backs AtomicMaxRegister."""
        with self._lock:
            if v > self.v:
                self.v = v


class ArrayCells:
    """A fixed-length array of atomic Read/Write registers."""

    __slots__ = ("a", "size")

    def __init__(self, size: int, init: Any = None):
        self.size = size
        self.a = [init] * size

    def read(self, i: int, pid: int = 0) -> Any:
        return self.a[i]

    def write(self, i: int, v: Any, pid: int = 0) -> None:
        self.a[i] = v


class MapCells:
    """An unbounded array of atomic Read/Write registers (paper's infinite array).

    Backed by a dict; a missing key reads as ``default`` which models an
    infinite array whose every entry was pre-initialized to ``default``
    (``UNINIT`` by default so tests catch reads the owner never wrote).
    """

    __slots__ = ("m", "default")

    def __init__(self, default: Any = UNINIT):
        self.m = {}
        self.default = default

    def read(self, i: int, pid: int = 0) -> Any:
        return self.m.get(i, self.default)

    def write(self, i: int, v: Any, pid: int = 0) -> None:
        self.m[i] = v


class RMWMapCells(MapCells):
    """Unbounded array of RMW registers (used by the bounded B-WS-* variants)."""

    __slots__ = ("_lock",)

    def __init__(self, default: Any = UNINIT):
        super().__init__(default)
        self._lock = threading.Lock()

    def swap(self, i: int, v: Any, pid: int = 0) -> Any:
        with self._lock:
            old = self.m.get(i, self.default)
            self.m[i] = v
            return old

    def cas(self, i: int, expect: Any, new: Any, pid: int = 0) -> bool:
        with self._lock:
            if self.m.get(i, self.default) == expect:
                self.m[i] = new
                return True
            return False


class ThreadBackend:
    """Raw shared memory for real threads / benchmarks."""

    name = "thread"

    def cell(self, init: Any = None) -> Cell:
        return Cell(init)

    def rmw_cell(self, init: Any = None) -> RMWCell:
        return RMWCell(init)

    def array(self, size: int, init: Any = None) -> ArrayCells:
        return ArrayCells(size, init)

    def map_cells(self, default: Any = UNINIT) -> MapCells:
        return MapCells(default)

    def rmw_map_cells(self, default: Any = UNINIT) -> RMWMapCells:
        return RMWMapCells(default)

    def lock(self) -> threading.Lock:
        return threading.Lock()

    def fence(self) -> None:  # documented no-op, see module docstring
        pass


# ---------------------------------------------------------------------------
# Deterministic-interleaving simulator backend.
# ---------------------------------------------------------------------------


class SimController:
    """Serializes shared-memory steps of concurrently running operations.

    Each process's operation sequence runs in its own thread; every shared
    access first *arrives* at the gate, then the controller grants exactly one
    arrived process a step according to ``schedule`` (a sequence of process
    ids; unmatched/done entries fall through round-robin).  Because a thread
    only proceeds when granted, and performs exactly one access per grant,
    shared accesses are totally ordered and reproducible.

    The controller also timestamps operation invocations/responses with the
    global step counter so tests can decide operation concurrency (the
    ``op || op'`` relation of §2) — this is what the set-linearizability
    property checks are built on.
    """

    def __init__(self, schedule: Optional[Sequence[int]] = None):
        self.schedule = list(schedule) if schedule is not None else []
        self.cv = threading.Condition()
        self.state: Dict[int, str] = {}
        self.granted: Optional[int] = None
        self.step_no = 0
        self.trace: List[int] = []
        self.active = False  # gates are open until run() starts (setup phase)

    # -- called from worker threads --------------------------------------
    def gate(self, pid: int) -> None:
        if not self.active:
            return  # queue construction / post-run inspection is free
        with self.cv:
            self.state[pid] = "at_gate"
            self.cv.notify_all()
            while self.granted != pid:
                self.cv.wait()
            self.granted = None
            self.state[pid] = "running"

    def now(self) -> int:
        return self.step_no

    def _finish(self, pid: int) -> None:
        with self.cv:
            self.state[pid] = "done"
            self.cv.notify_all()

    # -- controller loop ---------------------------------------------------
    def run(self, procs: Dict[int, Callable[[], None]], timeout: float = 60.0) -> None:
        """Run the per-process callables to completion under the schedule."""
        self.active = True
        threads = {}
        for pid, fn in procs.items():
            self.state[pid] = "running"

            def wrapper(pid=pid, fn=fn):
                try:
                    fn()
                finally:
                    self._finish(pid)

            t = threading.Thread(target=wrapper, daemon=True)
            threads[pid] = t
        for t in threads.values():
            t.start()

        sched_i = 0
        while True:
            with self.cv:
                while any(s == "running" for s in self.state.values()):
                    if not self.cv.wait(timeout):  # pragma: no cover - hang guard
                        raise RuntimeError("simulator stalled (deadlock in algorithm?)")
                waiting = [p for p, s in self.state.items() if s == "at_gate"]
                if not waiting:
                    break  # everyone done
                pick = None
                while sched_i < len(self.schedule):
                    cand = self.schedule[sched_i]
                    sched_i += 1
                    if cand in self.state and self.state[cand] == "at_gate":
                        pick = cand
                        break
                if pick is None:  # schedule exhausted -> round-robin fallback
                    pick = waiting[self.step_no % len(waiting)]
                self.granted = pick
                self.step_no += 1
                self.trace.append(pick)
                self.cv.notify_all()
        for t in threads.values():
            t.join(timeout)
        self.active = False


class SimCell:
    __slots__ = ("v", "ctrl")

    def __init__(self, ctrl: SimController, v: Any = None):
        self.ctrl = ctrl
        self.v = v

    def read(self, pid: int = 0) -> Any:
        self.ctrl.gate(pid)
        return self.v

    def write(self, v: Any, pid: int = 0) -> None:
        self.ctrl.gate(pid)
        self.v = v


class SimRMWCell(SimCell):
    __slots__ = ()

    def cas(self, expect: Any, new: Any, pid: int = 0) -> bool:
        self.ctrl.gate(pid)
        if self.v == expect:
            self.v = new
            return True
        return False

    def swap(self, new: Any, pid: int = 0) -> Any:
        self.ctrl.gate(pid)
        old = self.v
        self.v = new
        return old

    def fetch_add(self, delta: int = 1, pid: int = 0) -> Any:
        self.ctrl.gate(pid)
        old = self.v
        self.v = old + delta
        return old

    def write_max(self, v: Any, pid: int = 0) -> None:
        self.ctrl.gate(pid)
        if v > self.v:
            self.v = v


class SimArrayCells:
    __slots__ = ("a", "size", "ctrl")

    def __init__(self, ctrl: SimController, size: int, init: Any = None):
        self.ctrl = ctrl
        self.size = size
        self.a = [init] * size

    def read(self, i: int, pid: int = 0) -> Any:
        self.ctrl.gate(pid)
        return self.a[i]

    def write(self, i: int, v: Any, pid: int = 0) -> None:
        self.ctrl.gate(pid)
        self.a[i] = v


class SimMapCells:
    __slots__ = ("m", "default", "ctrl")

    def __init__(self, ctrl: SimController, default: Any = UNINIT):
        self.ctrl = ctrl
        self.m = {}
        self.default = default

    def read(self, i: int, pid: int = 0) -> Any:
        self.ctrl.gate(pid)
        return self.m.get(i, self.default)

    def write(self, i: int, v: Any, pid: int = 0) -> None:
        self.ctrl.gate(pid)
        self.m[i] = v


class SimRMWMapCells(SimMapCells):
    __slots__ = ()

    def swap(self, i: int, v: Any, pid: int = 0) -> Any:
        self.ctrl.gate(pid)
        old = self.m.get(i, self.default)
        self.m[i] = v
        return old

    def cas(self, i: int, expect: Any, new: Any, pid: int = 0) -> bool:
        self.ctrl.gate(pid)
        if self.m.get(i, self.default) == expect:
            self.m[i] = new
            return True
        return False


class _SimLock:
    """A lock whose acquire/release are shared-memory steps (for THE Cilk)."""

    def __init__(self, ctrl: SimController):
        self.ctrl = ctrl
        self._lock = threading.Lock()

    def __enter__(self):
        # Model acquire as a step; the underlying mutex keeps real threads
        # honest if the schedule interleaves inside a critical section.
        self.ctrl.gate(getattr(_tls, "pid", 0))
        self._lock.acquire()
        return self

    def __exit__(self, *exc):
        self._lock.release()
        return False


_tls = threading.local()


def set_sim_pid(pid: int) -> None:
    """Declare the calling thread's process id (used by _SimLock gating)."""
    _tls.pid = pid


class SimBackend:
    """Shared memory whose every access is a controller-scheduled step."""

    name = "sim"

    def __init__(self, ctrl: SimController):
        self.ctrl = ctrl

    def cell(self, init: Any = None) -> SimCell:
        return SimCell(self.ctrl, init)

    def rmw_cell(self, init: Any = None) -> SimRMWCell:
        return SimRMWCell(self.ctrl, init)

    def array(self, size: int, init: Any = None) -> SimArrayCells:
        return SimArrayCells(self.ctrl, size, init)

    def map_cells(self, default: Any = UNINIT) -> SimMapCells:
        return SimMapCells(self.ctrl, default)

    def rmw_map_cells(self, default: Any = UNINIT) -> SimRMWMapCells:
        return SimRMWMapCells(self.ctrl, default)

    def lock(self) -> _SimLock:
        return _SimLock(self.ctrl)

    def fence(self) -> None:
        pass

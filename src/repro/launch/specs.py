"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

`input_specs(cfg, shape, mesh)` produces weak-type-correct, shardable SDS
trees for the step functions — no device allocation ever happens in the
dry-run; `.lower()` consumes these directly.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import init_caches, init_params
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.sharding import param_shardings, spec_for


def _sds(shape, dtype, mesh=None, axes=None):
    sh = None
    if mesh is not None:
        sh = NamedSharding(mesh, spec_for(shape, axes or (None,) * len(shape), mesh))
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh=None) -> Dict[str, Any]:
    """SDS dict for one global batch of (cfg, shape)."""
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else shape.seq_len  # ctx len
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "decode":
        return {"tokens": _sds((B, 1), jnp.int32, mesh, ("dp", None))}
    out = {"tokens": _sds((B, S), jnp.int32, mesh, ("dp", None))}
    if cfg.family == "vlm":
        out["patches"] = _sds((B, cfg.n_patches, cfg.d_model), dt, mesh, ("dp", None, None))
    if cfg.family == "encdec":
        out["frames"] = _sds((B, cfg.enc_seq_len, cfg.d_model), dt, mesh, ("dp", None, None))
    return out


def params_specs(cfg: ModelConfig, mesh=None, fsdp=False):
    """(SDS tree, shardings tree) for the model parameters."""
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    if mesh is None:
        return shapes, None
    sh = param_shardings(shapes, mesh, fsdp=fsdp)
    sds = jax.tree_util.tree_map(
        lambda s, h: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=h), shapes, sh
    )
    return sds, sh


def _cache_axes(leaf_ndim: int, kind: str) -> Tuple:
    if kind == "kv":  # [L, B, S, ...] — seq split-K over model
        return (None, "dp", "sp") + (None,) * (leaf_ndim - 3)
    return (None, "dp") + (None,) * (leaf_ndim - 2)  # ssm: [L, B, ...]


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, mesh=None):
    """SDS tree (+shardings) for decode caches at this shape's context."""
    B, S = shape.global_batch, shape.seq_len
    shapes = jax.eval_shape(lambda: init_caches(cfg, B, S))
    if mesh is None:
        return shapes, None

    def one_field(tree, kind):
        if tree == ():
            return (), ()
        sh = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, spec_for(s.shape, _cache_axes(s.ndim, kind), mesh)),
            tree,
        )
        sds = jax.tree_util.tree_map(
            lambda s, h: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=h), tree, sh
        )
        return sds, sh

    kv_sds, kv_sh = one_field(shapes.kv, "kv")
    ssm_sds, ssm_sh = one_field(shapes.ssm, "ssm")
    sh_sds, sh_sh = one_field(shapes.shared_kv, "kv")
    cr_sds, cr_sh = one_field(shapes.cross_kv, "kv")
    make = type(shapes)
    return make(kv_sds, ssm_sds, sh_sds, cr_sds), make(kv_sh, ssm_sh, sh_sh, cr_sh)


def opt_state_shardings(opt_shapes, p_shard, mesh):
    """Optimizer-state shardings derived from the parameter shardings:
    m/v mirror params; factored-v tuples drop the corresponding dim."""
    if p_shard is None:
        return None
    rep = NamedSharding(mesh, P())

    def v_like(ps, leaf):
        spec = tuple(ps.spec)
        if isinstance(leaf, tuple):  # factored (row, col)
            spec = spec + (None,) * (len(leaf[0].shape) + 1 - len(spec))
            row = NamedSharding(mesh, P(*spec[:-1][: len(leaf[0].shape)]))
            col_spec = tuple(spec[:-2]) + (spec[-1],)
            col = NamedSharding(mesh, P(*col_spec[: len(leaf[1].shape)]))
            return (row, col)
        spec = spec + (None,) * (len(leaf.shape) - len(spec))
        return NamedSharding(mesh, P(*spec[: len(leaf.shape)]))

    is_pair = lambda x: isinstance(x, tuple) and not hasattr(x, "shape")
    m_sh = jax.tree_util.tree_map(lambda ps, l: v_like(ps, l), p_shard, opt_shapes.m)
    v_sh = jax.tree_util.tree_map(
        lambda ps, l: v_like(ps, l), p_shard, opt_shapes.v, is_leaf=lambda x: is_pair(x)
    )
    # tree_map with is_leaf on the SECOND tree needs care; rebuild manually
    flat_p, tdef = jax.tree_util.tree_flatten(p_shard)
    flat_v = tdef.flatten_up_to(opt_shapes.v)
    v_sh = tdef.unflatten([v_like(ps, lv) for ps, lv in zip(flat_p, flat_v)])
    return type(opt_shapes)(step=rep, m=m_sh, v=v_sh)


def attach(sds_tree, sh_tree):
    """Attach shardings to an SDS tree (leaf-wise, tolerating tuples)."""
    flat_s, tdef = jax.tree_util.tree_flatten(sds_tree)
    flat_h = jax.tree_util.tree_leaves(sh_tree)
    assert len(flat_s) == len(flat_h), (len(flat_s), len(flat_h))
    out = [
        jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=h)
        for s, h in zip(flat_s, flat_h)
    ]
    return tdef.unflatten(out)

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing import: jax locks the device count on
# first init.  Only the dry-run forces 512 host devices; tests/benches see 1.

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, cell_plan, get_config  # noqa: E402
from repro.launch.hlo_analysis import analyze  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import (  # noqa: E402
    attach,
    batch_specs,
    cache_specs,
    opt_state_shardings,
    params_specs,
)
from repro.launch.steps import (  # noqa: E402
    make_decode_step,
    make_optimizer,
    make_prefill_step,
    make_train_step,
    train_policy,
)
from repro.models.config import SHAPES  # noqa: E402
from repro.models.sharding import use_mesh  # noqa: E402

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

def memory_report(compiled) -> dict:
    """memory_analysis() when the backend provides it; else analytic
    per-device argument/output byte totals from the compiled avals."""
    out = {}
    try:
        ma = compiled.memory_analysis()
    except Exception:
        ma = None
    if ma is not None:
        for f in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            v = getattr(ma, f, None)
            if v is not None:
                out[f] = int(v)
    return out


def _per_device_bytes(sds_tree, n_devices: int) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(sds_tree):
        n = 1
        for d in leaf.shape:
            n *= d
        nb = n * jnp.dtype(leaf.dtype).itemsize
        sh = getattr(leaf, "sharding", None)
        if sh is not None and sh.spec is not None:
            try:
                nb = sh.shard_shape(leaf.shape)
                m = 1
                for d in nb:
                    m *= d
                nb = m * jnp.dtype(leaf.dtype).itemsize
            except Exception:
                nb = n * jnp.dtype(leaf.dtype).itemsize
        total += nb
    return total


def model_flops(cfg, shape) -> float:
    """6*N_active*D for the step's token throughput (fwd+bwd for train,
    2*N*D for fwd-only serve steps)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def build_lowered(cfg, shape, mesh, *, ws_mode=None, chunk=1024):
    """Lower the right step for this cell; returns (lowered, extras)."""
    pol = train_policy(cfg)
    fsdp = pol["fsdp"] if shape.kind == "train" else (pol["fsdp"] or False)
    with use_mesh(mesh, fsdp=bool(fsdp)):
        p_sds, p_sh = params_specs(cfg, mesh, fsdp=fsdp)
        if shape.kind == "train":
            opt = make_optimizer(cfg)
            opt_shapes = jax.eval_shape(opt.init, p_sds)
            o_sh = opt_state_shardings(opt_shapes, p_sh, mesh)
            o_sds = attach(opt_shapes, o_sh)
            state = {"params": p_sds, "opt": o_sds}
            batch = batch_specs(cfg, shape, mesh)
            if ws_mode is not None:
                n_w = mesh.devices.size // mesh.shape["model"]
                n_tasks = 2 * n_w
                rows = max(shape.global_batch // n_tasks, 1)
                tok = batch["tokens"]
                batch = dict(batch)
                batch["tokens"] = jax.ShapeDtypeStruct(
                    (n_tasks, rows, tok.shape[1]), tok.dtype,
                    sharding=jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec(("pod", "data") if "pod" in mesh.axis_names else "data")
                    ),
                )
                batch["tails"] = jax.ShapeDtypeStruct((n_w,), jnp.int32)
                # bounded rounds: tasks_per_worker(2) + slack(2) — a fixed
                # step-time budget, comparable across scheduler modes
                step = make_train_step(
                    cfg, opt, ws_mode=ws_mode, n_workers=n_w, chunk=chunk,
                    max_rounds=4,
                )
            else:
                step = make_train_step(cfg, opt, chunk=chunk)
            state_sh = {"params": p_sh, "opt": o_sh}
            # donate the old state: params/opt are updated in place
            lowered = jax.jit(
                step, out_shardings=(state_sh, None), donate_argnums=(0,)
            ).lower(state, batch)
        elif shape.kind == "prefill":
            batch = batch_specs(cfg, shape, mesh)
            _, c_sh = cache_specs(cfg, shape, mesh)
            step = make_prefill_step(cfg, chunk=chunk)
            lowered = jax.jit(step, out_shardings=(None, c_sh)).lower(p_sds, batch)
        else:  # decode
            batch = batch_specs(cfg, shape, mesh)
            c_sds, c_sh = cache_specs(cfg, shape, mesh)
            step = make_decode_step(cfg)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            # donate the KV/SSM caches: decode updates them in place
            lowered = jax.jit(
                step, out_shardings=(None, c_sh), donate_argnums=(1,)
            ).lower(p_sds, c_sds, batch["tokens"], pos)
        extras = {
            "fsdp": str(fsdp),
            "optimizer": pol["optimizer"] if shape.kind == "train" else None,
            "params_bytes_per_device": _per_device_bytes(p_sds, mesh.devices.size),
        }
        return lowered, extras


_SMOKE_SHAPES = {
    "train_4k": ("train", 64, 8),
    "prefill_32k": ("prefill", 256, 4),
    "decode_32k": ("decode", 256, 8),
    "long_500k": ("decode", 512, 2),
}


def run_cell(
    arch: str, shape_name: str, multi_pod: bool, *, ws_mode=None, chunk=1024,
    smoke: bool = False, pad_heads: bool = False, tag: str = "",
):
    from repro.models.config import ShapeConfig

    cfg = get_config(arch, smoke=smoke)
    if pad_heads:
        cfg = cfg.replace(pad_heads=True)
    if tag == "bf16-reduce":
        cfg = cfg.replace(bf16_reduce=True)
    if smoke:
        kind, seq, gb = _SMOKE_SHAPES[shape_name]
        shape = ShapeConfig(shape_name, kind, seq, gb)
        chunk = min(chunk, 32)
    else:
        shape = SHAPES[shape_name]
    plan = cell_plan(cfg if not smoke else get_config(arch))[shape_name]
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": ("2x2x2" if multi_pod else "2x4") if smoke else ("2x16x16" if multi_pod else "16x16"),
        "plan": plan, "ws_mode": ws_mode, "smoke": smoke,
        "tag": tag, "pad_heads": pad_heads, "chunk": chunk,
    }
    if plan != "run":
        return rec
    if smoke:
        from repro.launch.mesh import make_host_mesh

        mesh = (
            make_host_mesh((2, 2, 2), ("pod", "data", "model"))
            if multi_pod
            else make_host_mesh((2, 4), ("data", "model"))
        )
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    t0 = time.time()
    lowered, extras = build_lowered(cfg, shape, mesh, ws_mode=ws_mode, chunk=chunk)
    rec["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)
    rec.update(extras)

    mem = memory_report(compiled)
    print(f"[{arch} x {shape_name} x {rec['mesh']}] memory_analysis: {mem}")
    rec["memory"] = mem

    # XLA's cost_analysis counts while bodies once (scan => ~n_layers
    # undercount); keep it as reference, use the trip-aware HLO walk as
    # the roofline numerator.
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax: one dict per computation
        ca = ca[0] if ca else {}
    rec["xla_cost_flops"] = float(ca.get("flops", 0.0))
    rec["xla_cost_bytes"] = float(ca.get("bytes accessed", 0.0))
    res = analyze(compiled.as_text())
    flops = res["flops"]
    bytes_accessed = res["mem_bytes"]
    rec["hlo_flops_per_device"] = flops
    rec["hlo_bytes_per_device"] = bytes_accessed
    print(
        f"  trip-aware: flops/device={flops:.3e} bytes/device={bytes_accessed:.3e} "
        f"(xla-once-through: {rec['xla_cost_flops']:.3e} / {rec['xla_cost_bytes']:.3e})"
    )
    per_kind, coll_bytes = res["per_kind"], res["collective_bytes"]
    rec["collectives"] = {k: v for k, v in per_kind.items() if v["count"]}
    rec["collective_bytes_per_device"] = coll_bytes

    # roofline terms (seconds); flops/bytes above are per-device post-SPMD
    rec["compute_s"] = flops / PEAK_FLOPS
    rec["memory_s"] = bytes_accessed / HBM_BW
    rec["collective_s"] = coll_bytes / ICI_BW
    terms = {k: rec[k] for k in ("compute_s", "memory_s", "collective_s")}
    rec["bottleneck"] = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    rec["model_flops_global"] = mf
    rec["useful_flops_ratio"] = mf / max(flops * n_chips, 1.0)
    print(
        f"  roofline: compute={rec['compute_s']:.4f}s memory={rec['memory_s']:.4f}s "
        f"collective={rec['collective_s']:.4f}s -> {rec['bottleneck']}; "
        f"useful_ratio={rec['useful_flops_ratio']:.3f}"
    )
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ws-mode", default=None)
    ap.add_argument("--chunk", type=int, default=1024)
    ap.add_argument("--smoke", action="store_true", help="reduced config + 8 fake devices")
    ap.add_argument("--pad-heads", action="store_true", help="TP head padding (§Perf)")
    ap.add_argument("--tag", default="", help="label for the JSONL record")
    ap.add_argument("--out", default=None, help="append-to JSONL path")
    args = ap.parse_args(argv)

    rec = run_cell(
        args.arch, args.shape, args.multi_pod, ws_mode=args.ws_mode,
        chunk=args.chunk, smoke=args.smoke, pad_heads=args.pad_heads, tag=args.tag,
    )
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
    print(json.dumps(rec, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())

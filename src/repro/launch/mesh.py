"""Production meshes.  A FUNCTION, not a module constant: importing this
module must never touch jax device state (the dry-run sets the fake device
count before first jax init; everything else sees the single real CPU).
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax.sharding.AxisType only exists on newer jax; older versions are
    # implicitly Auto on every axis, so omitting the kwarg is equivalent.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 ("data","model") = 256 chips.
    Multi-pod: 2x16x16 ("pod","data","model") = 512 chips (2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Tiny mesh over forced host devices — used by reduced-scale dry-run
    tests (8 fake devices) so CI exercises the same code path."""
    return _make_mesh(shape, axes)


def make_expert_mesh(n_experts: int, n_devices: int | None = None):
    """1-D ``("model",)`` mesh for expert-parallel dispatch
    (``moe_dispatch="mesh-ws"``): the model axis spans the largest divisor
    of ``n_experts`` that the host's device count allows, so the expert
    partition is always even.  One device degenerates to a 1-mesh (the
    mesh_ws code path with no remote victims).  Pass ``n_devices`` to pin
    the size (it must divide ``n_experts`` and be available)."""
    avail = len(jax.devices())
    if n_devices is None:
        n_devices = max(
            d for d in range(1, min(avail, n_experts) + 1)
            if n_experts % d == 0
        )
    if n_devices > avail:
        raise ValueError(f"mesh size {n_devices} > {avail} available devices")
    if n_experts % n_devices:
        raise ValueError(
            f"mesh size {n_devices} does not divide n_experts={n_experts}"
        )
    return _make_mesh((n_devices,), ("model",))

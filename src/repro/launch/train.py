"""End-to-end training driver (CPU-runnable at smoke scale, mesh-ready).

Features exercised here and by examples/tests:
  * real data pipeline (synthetic corpus, packed documents),
  * the paper's L1 scheduler as the gradient-accumulation engine
    (--ws-mode static|ws-mult|ws-mult-ranked|ws-wmult|ws-wmult-deque),
  * checkpoint / resume (atomic, async) and a preemption drill
    (--preempt-at N exits mid-run; rerun with --resume continues),
  * WSD/cosine schedules via launch.steps.make_optimizer.

Usage: python -m repro.launch.train --arch llama3.2-3b --steps 60 ...
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.configs import ARCH_IDS, get_config
from repro.data import make_batch
from repro.launch.steps import make_optimizer, make_train_step
from repro.models import init_params
from repro.models.config import ShapeConfig


def _skewed_tails(n_tasks: int, n_workers: int, step: int, skew: float) -> np.ndarray:
    """Deterministic per-step queue skew (the straggler/imbalance model)."""
    rng = np.random.RandomState(step * 7919 + 13)
    w = rng.dirichlet(np.full(n_workers, max(1e-3, 1.0 / max(skew, 1e-3))))
    tails = np.floor(w * n_tasks).astype(np.int64)
    while tails.sum() < n_tasks:
        tails[rng.randint(n_workers)] += 1
    return tails


def train(
    arch: str,
    *,
    smoke: bool = True,
    steps: int = 100,
    rows: int = 8,
    seq: int = 64,
    moe_dispatch: str | None = None,
    moe_grad_dispatch: str | None = None,
    ws_mode: str | None = None,
    n_workers: int = 4,
    tasks_per_worker: int = 2,
    skew: float = 1.0,
    lr: float = 3e-3,
    ckpt_dir: str | None = None,
    ckpt_every: int = 20,
    resume: bool = False,
    preempt_at: int | None = None,
    seed: int = 0,
    log_every: int = 10,
    log_path: str | None = None,
):
    cfg = get_config(arch, smoke=smoke)
    # MoE archs: "ws" trains the dropless work-stealing dispatch end to end
    # (forward megakernel + custom-VJP backward, repro.moe_ws); default
    # keeps whatever the arch config names.
    if moe_dispatch is not None:
        cfg = cfg.replace(moe_dispatch=moe_dispatch)
    if moe_grad_dispatch is not None:
        cfg = cfg.replace(moe_grad_dispatch=moe_grad_dispatch)
    shape = ShapeConfig("custom", "train", seq, rows)
    opt = make_optimizer(cfg, total_steps=steps, peak_lr=lr)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    state = {"params": params, "opt": opt.init(params)}
    start = 0
    if resume and ckpt_dir and latest_step(ckpt_dir) is not None:
        like = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
        )
        state, start = restore(ckpt_dir, like)
        start += 1
        print(f"[train] resumed from step {start - 1}")

    n_tasks = n_workers * tasks_per_worker
    step_fn = jax.jit(
        make_train_step(cfg, opt, ws_mode=ws_mode, n_workers=n_workers)
    )
    ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    losses = []
    t0 = time.time()
    for step in range(start, steps):
        if ws_mode is None:
            batch = {
                k: jnp.asarray(v)
                for k, v in make_batch(cfg, shape, step, n_rows=rows, seed=seed).items()
            }
        else:
            nb = make_batch(cfg, shape, step, n_rows=n_tasks * max(rows // n_tasks, 1), seed=seed)
            rpt = max(rows // n_tasks, 1)
            batch = {
                k: jnp.asarray(v).reshape((n_tasks, rpt) + v.shape[1:])
                for k, v in nb.items()
            }
            batch["tails"] = jnp.asarray(_skewed_tails(n_tasks, n_workers, step, skew))
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            msg = {"step": step, "loss": round(loss, 4), "t": round(time.time() - t0, 1)}
            if "ws_coverage" in metrics:
                msg["ws_coverage"] = float(metrics["ws_coverage"])
            print(f"[train] {json.dumps(msg)}")
            if log_path:
                with open(log_path, "a") as f:
                    f.write(json.dumps(msg) + "\n")
        if ckpt and (step % ckpt_every == 0 or step == steps - 1):
            ckpt.save(step, state)
        if preempt_at is not None and step == preempt_at:
            print(f"[train] simulating preemption at step {step}", flush=True)
            if ckpt:
                ckpt.wait()
            os._exit(17)  # hard kill, as a real preemption would be
    if ckpt:
        ckpt.wait()
    return state, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=list(ARCH_IDS))
    ap.add_argument("--full-config", action="store_true", help="full (non-smoke) config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--rows", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ws-mode", default=None)
    ap.add_argument("--moe-dispatch", default=None, choices=["dense", "ws"],
                    help="override cfg.moe_dispatch (MoE archs): 'ws' trains "
                         "the dropless work-stealing dispatch")
    ap.add_argument("--moe-grad-dispatch", default=None,
                    choices=["dense", "ws"],
                    help="backward path of the ws dispatch's custom VJP")
    ap.add_argument("--n-workers", type=int, default=4)
    ap.add_argument("--skew", type=float, default=1.0)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--preempt-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-path", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)
    _, losses = train(
        args.arch,
        smoke=not args.full_config,
        steps=args.steps,
        rows=args.rows,
        seq=args.seq,
        moe_dispatch=args.moe_dispatch,
        moe_grad_dispatch=args.moe_grad_dispatch,
        ws_mode=args.ws_mode,
        n_workers=args.n_workers,
        skew=args.skew,
        lr=args.lr,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        resume=args.resume,
        preempt_at=args.preempt_at,
        seed=args.seed,
        log_path=args.log_path,
        log_every=args.log_every,
    )
    k = max(len(losses) // 10, 1)
    print(
        f"[train] done: first-{k} mean loss {np.mean(losses[:k]):.4f} -> "
        f"last-{k} mean loss {np.mean(losses[-k:]):.4f}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""repro.launch — meshes, input specs, jitted steps, dry-run, train/serve."""

"""Jitted step builders: train_step / prefill_step / decode_step.

`train_policy(cfg)` centralizes the scale-dependent choices (ZeRO/fsdp
axes, optimizer flavor) so dryrun/train/serve agree:

* < 8B params      — AdamW fp32 states, no fsdp (TP+DP only).
* 8B – 500B        — AdamW fp32 states, params+opt ZeRO-sharded over "data".
* > 500B (kimi-1t) — bf16-momentum + factored-v optimizer, ZeRO over
                     ("data","pod"): AdamW fp32 states for 1T params are
                     8 TB > 2 pods of HBM (see EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import decode_step as model_decode
from repro.models import loss_fn as model_loss
from repro.models import prefill as model_prefill
from repro.optim import make_adafactor_momentum, make_adamw, wsd_schedule, cosine_schedule
from repro.sched import ws_accumulate_grads


def train_policy(cfg) -> Dict[str, Any]:
    n = cfg.param_count()
    if n > 500e9:
        return {"fsdp": "pods", "optimizer": "adafactor_momentum"}
    if n > 8e9:
        return {"fsdp": True, "optimizer": "adamw"}
    return {"fsdp": False, "optimizer": "adamw"}


def make_optimizer(cfg, total_steps: int = 10_000, peak_lr: float = 3e-4):
    pol = train_policy(cfg)
    if cfg.depth_scaled_residual:  # minicpm trains with WSD
        lr = wsd_schedule(peak_lr, warmup=total_steps // 100 + 1,
                          stable=int(total_steps * 0.8), decay=total_steps // 5 + 1)
    else:
        lr = cosine_schedule(peak_lr, warmup=total_steps // 100 + 1, total=total_steps)
    if pol["optimizer"] == "adafactor_momentum":
        return make_adafactor_momentum(lr)
    return make_adamw(lr)


def make_train_step(
    cfg,
    opt,
    *,
    ws_mode: Optional[str] = None,
    n_workers: int = 0,
    sync_every: int = 1,
    max_rounds: Optional[int] = None,
    remat: bool = True,
    chunk: int = 1024,
) -> Callable:
    """state = {"params", "opt"}; batch per models.model docstring.

    ws_mode=None: one full-batch loss (baseline).
    ws_mode in repro.sched.MODES: the batch's leading dim is a FIFO of
    microbatch tasks scheduled by the paper's work-stealing rounds;
    batch["tails"] gives per-worker-queue task counts.

    MoE configs may set ``cfg.moe_dispatch == "ws"``: the loss's expert FFN
    then runs the dropless work-stealing dispatch forward AND backward —
    ``value_and_grad`` differentiates through ``moe_ffn_ws``'s custom VJP
    (the no-drop reference transpose, ``cfg.moe_grad_dispatch`` selecting
    its evaluation), so no dense fallback ever substitutes on the training
    path (DESIGN.md §4.5).
    """
    for knob in ("moe_dispatch", "moe_grad_dispatch"):
        val = getattr(cfg, knob, "dense")
        if val not in ("dense", "ws"):
            # an unknown value would flow to moe_ffn_dispatch and silently
            # select the capacity-dropping dense path; "mesh-ws" is real but
            # forward/serving-only (no custom VJP through the cross-device
            # collectives), so training rejects it too
            raise ValueError(
                f"cfg.{knob}={val!r}: expected 'dense' or 'ws' "
                "(training-capable dispatches; 'mesh-ws' is forward-only)"
            )

    def step(state, batch):
        params = state["params"]
        if ws_mode is None:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: model_loss(p, cfg, batch, remat=remat, chunk=chunk),
                has_aux=True,
            )(params)
            aux = {}
        else:
            tails = batch["tails"]
            micro = {k: v for k, v in batch.items() if k != "tails"}

            def flat_loss(p, flat, row_w):
                # flat leaves [n_workers*rows, ...] stay dp-sharded (no
                # vmap: GSPMD keeps the batch dim partitioned)
                return model_loss(
                    p, cfg, flat, remat=remat, chunk=chunk, row_weights=row_w
                )[0]

            loss, grads, aux = ws_accumulate_grads(
                flat_loss, params, micro, tails,
                n_workers=n_workers, mode=ws_mode, sync_every=sync_every,
                max_rounds=max_rounds, flat_loss=True,
            )
            metrics = {"ce": loss}
        new_params, new_opt = opt.apply(params, grads, state["opt"])
        out_metrics = {"loss": loss, **{k: v for k, v in metrics.items()}}
        if aux:
            out_metrics["ws_coverage"] = aux["coverage"]
            out_metrics["ws_extractions"] = aux["extractions"]
        return {"params": new_params, "opt": new_opt}, out_metrics

    return step


def make_prefill_step(cfg, chunk: int = 1024) -> Callable:
    def step(params, batch):
        return model_prefill(params, cfg, batch, chunk=chunk)

    return step


def make_decode_step(cfg) -> Callable:
    def step(params, caches, tokens, pos):
        return model_decode(params, cfg, caches, tokens, pos)

    return step

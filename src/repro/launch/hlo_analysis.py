"""Post-SPMD HLO analysis: per-device collective bytes, FLOPs and HBM bytes
— all TRIP-COUNT AWARE.

XLA's `compiled.cost_analysis()` counts each `while` body ONCE, so a
scan-over-layers program under-reports flops/bytes by ~n_layers; and it
reports no collective traffic at all.  We therefore parse
`compiled.as_text()` (the post-SPMD, post-fusion per-device module):

* split the module into computations,
* per computation, tally
    - collective operand bytes per kind (operand sizes resolved from their
      defining instructions; result size as fallback),
    - dot/convolution FLOPs (2 * prod(output dims) * prod(contracting
      dims), read off the dot_dimension_numbers),
    - HBM traffic: operands + result of every fusion/dot/conv/copy/
      elementwise instruction (post-fusion, a fusion's operands/result ARE
      its memory traffic),
* walk the call graph from ENTRY, multiplying everything inside `while`
  bodies by the loop trip count (recovered from the condition's compare
  constant — exact for lax.scan/fori, the only loops this stack emits).

All counts are PER DEVICE (the compiled module is the per-device SPMD
program).
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^{]*)?\{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+([a-z0-9\-]+)")
_CONST = re.compile(r"=\s*s32\[\]\s+constant\((\d+)\)")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = _COMP_HEADER.match(line.strip()) if line.strip().endswith("{") else None
        if m and ("(" in line and "->" in line):
            name = m.group(2)
            cur = comps.setdefault(name, [])
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            cur.append(line)
    return comps


_COMMENT = re.compile(r"/\*.*?\*/")
_SKIP_MEM = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "call", "conditional", "after-all", "iota",
}
_CALL_OPS = {"fusion", "call", "conditional", "custom-call", "reduce", "sort", "scatter", "map"}


def _shape_dims(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _elems(text: str) -> int:
    n = 0
    for _, dims in _shape_dims(text):
        e = 1
        for d in dims:
            e *= d
        n += e
    return n


def analyze(hlo: str) -> Dict[str, object]:
    """Trip-aware per-device analysis: collectives per kind, dot FLOPs,
    HBM bytes.  Returns dict(per_kind, collective_bytes, flops, mem_bytes)."""
    comps = _split_computations(hlo)

    shapes: Dict[str, Dict[str, str]] = {}  # comp -> instr -> type text
    colls: Dict[str, List[Tuple[str, int]]] = {}
    flops_c: Dict[str, float] = {}
    mem_c: Dict[str, float] = {}
    edges: Dict[str, List[Tuple[str, str]]] = {}  # comp -> [(callee, cond)]

    # fusions rooted in dynamic-update-slice alias their buffer in place:
    # traffic is the slice, not the buffer.
    fused_root: Dict[str, str] = {}
    for cname, lines in comps.items():
        for ln in lines:
            if ln.strip().startswith("ROOT"):
                m = _INSTR.match(_COMMENT.sub("", ln))
                if m:
                    fused_root[cname] = m.group(3)

    for cname, lines in comps.items():
        ty_of = shapes.setdefault(cname, {})
        for ln in lines:
            m = _INSTR.match(_COMMENT.sub("", ln))
            if m:
                ty_of[m.group(1)] = m.group(2)
        cl = colls.setdefault(cname, [])
        ed = edges.setdefault(cname, [])
        fl = 0.0
        mb = 0.0
        for raw in lines:
            ln = _COMMENT.sub("", raw)
            m = _INSTR.match(ln)
            if not m:
                continue
            name, ty, opcode = m.groups()
            rest = ln[m.end():]
            opnds = []
            om = re.match(r"\s*\((.*?)\)", rest)
            if om:
                opnds = [o.strip().lstrip("%") for o in om.group(1).split(",") if o.strip()]

            kind = next(
                (k for k in _COLLECTIVES if opcode == k or opcode == k + "-start"), None
            )
            if kind:
                ob = sum(_shape_bytes(ty_of.get(o, "")) for o in opnds)
                cl.append((kind, ob if ob else _shape_bytes(ty)))

            if opcode == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ln)
                cm = re.search(r"condition=%?([\w.\-]+)", ln)
                if bm and cm:
                    ed.append((bm.group(1), cm.group(1)))
                continue
            if opcode in _CALL_OPS:
                # fusions' inner computations are elementwise; don't recurse
                # for flops (counted via result elems) but do for nested
                # control flow in call/conditional.
                if opcode in ("call", "conditional"):
                    for cm in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", ln):
                        ed.append((cm.group(1), None))
                    for cm in re.finditer(r"branch_computations=\{([^}]*)\}", ln):
                        for b in cm.group(1).split(","):
                            ed.append((b.strip().lstrip("%"), None))

            # flops
            if opcode == "dot":
                out_e = _elems(ty)
                contract = 1
                lm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ln)
                if lm and opnds:
                    lhs_dims = _shape_dims(ty_of.get(opnds[0], ""))
                    if lhs_dims:
                        dims = lhs_dims[0][1]
                        for i in lm.group(1).split(","):
                            if i and int(i) < len(dims):
                                contract *= dims[int(i)]
                fl += 2.0 * out_e * contract
            elif opcode not in _SKIP_MEM:
                fl += _elems(ty)  # elementwise estimate: 1 flop / output elem

            # memory traffic: operands + result for real ops
            if opcode not in _SKIP_MEM:
                rb = _shape_bytes(ty)
                obs = [_shape_bytes(ty_of.get(o, "")) for o in opnds]
                is_dus = opcode == "dynamic-update-slice"
                if opcode == "fusion":
                    cm2 = re.search(r"calls=%?([\w.\-]+)", ln)
                    if cm2 and fused_root.get(cm2.group(1)) == "dynamic-update-slice":
                        is_dus = True
                if is_dus and any(b == rb for b in obs):
                    # in-place update: read+write the small operands only
                    mb += 2.0 * (sum(obs) - rb)
                else:
                    mb += rb + sum(obs)
        flops_c[cname] = fl
        mem_c[cname] = mb

    def trip_count(cond_name: str) -> int:
        consts = [int(c) for ln in comps.get(cond_name, []) for c in _CONST.findall(ln)]
        return max(consts) if consts else 1

    per_kind = {k: {"bytes": 0.0, "count": 0.0} for k in _COLLECTIVES}
    total = {"flops": 0.0, "mem": 0.0}
    stack: List[str] = []

    def walk(cname: str, mult: float):
        if cname not in comps or cname in stack or len(stack) > 200:
            return
        stack.append(cname)
        for kind, b in colls.get(cname, []):
            per_kind[kind]["bytes"] += b * mult
            per_kind[kind]["count"] += mult
        total["flops"] += flops_c.get(cname, 0.0) * mult
        total["mem"] += mem_c.get(cname, 0.0) * mult
        for body, cond in edges.get(cname, []):
            walk(body, mult * (trip_count(cond) if cond else 1))
        stack.pop()

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is not None:
        walk(entry, 1.0)
    else:
        for cname in comps:  # pragma: no cover - fallback
            walk(cname, 1.0)
    per_kind = {k: v for k, v in per_kind.items() if v["count"]}
    return {
        "per_kind": per_kind,
        "collective_bytes": sum(v["bytes"] for v in per_kind.values()),
        "flops": total["flops"],
        "mem_bytes": total["mem"],
    }


def analyze_collectives(hlo: str) -> Tuple[Dict[str, Dict[str, float]], float]:
    """Back-compat wrapper: (per-kind collectives, total bytes)."""
    res = analyze(hlo)
    return res["per_kind"], res["collective_bytes"]

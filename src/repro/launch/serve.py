"""Serving driver: continuous batching behind the work-stealing frontend.

Usage: python -m repro.launch.serve --arch llama3.2-3b --requests 12
Runs at smoke scale on CPU; the engine/scheduler code is scale-free.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import init_params
from repro.serving import ContinuousBatcher, Request, WorkStealingFrontend


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=list(ARCH_IDS))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--capacity", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--no-steal", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=True)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    fe = WorkStealingFrontend(
        lambda: ContinuousBatcher(params, cfg, slots=args.slots, capacity=args.capacity),
        n_replicas=args.replicas,
        steal=not args.no_steal,
    )
    rng = np.random.RandomState(args.seed)
    t0 = time.time()
    for rid in range(args.requests):
        # skewed arrival: most requests hit replica 0 — stealing balances
        rep = 0 if rng.rand() < 0.8 else rng.randint(args.replicas)
        prompt = rng.randint(1, cfg.vocab_size, size=rng.randint(3, 9)).astype(np.int32)
        fe.submit(rep, Request(rid, prompt, max_new=args.max_new))
    completed = fe.run()
    dt = time.time() - t0
    ok = sorted(completed) == list(range(args.requests))
    print(
        f"[serve] {len(completed)}/{args.requests} completed in {dt:.1f}s "
        f"(all={ok}); stats={fe.stats}"
    )
    for rid in sorted(completed)[:4]:
        print(f"  req {rid}: out={completed[rid].out}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

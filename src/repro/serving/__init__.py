"""repro.serving — KV-cache serving with work-stealing request scheduling."""

from .engine import (
    ContinuousBatcher,
    Request,
    WorkStealingFrontend,
    ragged_slot_attention,
)

__all__ = [
    "ContinuousBatcher",
    "Request",
    "WorkStealingFrontend",
    "ragged_slot_attention",
]

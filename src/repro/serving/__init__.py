"""repro.serving — KV-cache serving with work-stealing request scheduling."""

from .engine import ContinuousBatcher, Request, WorkStealingFrontend

__all__ = ["ContinuousBatcher", "Request", "WorkStealingFrontend"]
